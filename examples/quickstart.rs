//! Quickstart — the end-to-end driver (DESIGN.md deliverable (b)/E2E).
//!
//! Brings up the full three-layer stack on a real small workload:
//!   1. mini-HDFS cluster (8 racks × 3 DataNodes, throttled links),
//!   2. D³ placement of (3,2)-RS stripes,
//!   3. real data written, encoded through the AOT-compiled PJRT GF
//!      kernels (Layer 1/2), falling back to native if artifacts missing,
//!   4. a node failure, D³ minimum-cross-rack recovery,
//!   5. bit-exact verification of every data block + the headline metric
//!      (recovery throughput, λ) vs the RDD baseline.
//!
//! Run: `make artifacts && cargo run --release --example quickstart`

use std::sync::Arc;

use d3ec::cluster::MiniCluster;
use d3ec::codes::CodeSpec;
use d3ec::placement::{D3Placement, RddPlacement};
use d3ec::runtime::default_artifacts_dir;
use d3ec::topology::{Location, SystemSpec};


fn main() -> anyhow::Result<()> {
    let backend = if default_artifacts_dir().join("manifest.json").exists() {
        "pjrt"
    } else {
        eprintln!("(artifacts missing — using the native GF backend; run `make artifacts`)");
        "native"
    };
    // Scaled testbed: paper topology and the paper's *bandwidth ratios*
    // (1000 / 100 Mb/s) with 1 MiB blocks, so recovery is network-bound —
    // the regime the paper measures — while the demo finishes in seconds.
    // (The single-core host serializes coding work that the paper's 27
    // DataNodes did in parallel, so compute must stay off the critical
    // path; see EXPERIMENTS.md §E2E.)
    let mut spec = SystemSpec::paper_default();
    spec.block_size = 1 << 20;
    let code = CodeSpec::Rs { k: 3, m: 2 };
    // one full D³ placement cycle: r(r-1) regions × n² stripes = 504
    let stripes = 504u64;

    println!("== D³ quickstart: {} on 8 racks × 3 nodes, {} stripes, backend={backend} ==",
        code.name(), stripes);

    let mut results = Vec::new();
    for policy_name in ["d3", "rdd"] {
        let policy: Arc<dyn d3ec::placement::Placement> = match policy_name {
            "d3" => Arc::new(D3Placement::new(code, spec.cluster)?),
            _ => Arc::new(RddPlacement::new(code, spec.cluster, 42)),
        };
        let cluster = MiniCluster::new(spec, policy, backend, 42)?;

        // write real data (32 concurrent clients); stripes move straight
        // into the cluster, the verification pass regenerates them
        let gen = |sid: u64| -> Vec<Vec<u8>> {
            (0..3u64)
                .map(|b| {
                    let mut v = vec![0u8; spec.block_size as usize];
                    let mut s = sid.wrapping_mul(0x9e3779b9).wrapping_add(b) | 1;
                    for byte in v.iter_mut() {
                        s ^= s << 13;
                        s ^= s >> 7;
                        s ^= s << 17;
                        *byte = (s >> 24) as u8;
                    }
                    v
                })
                .collect()
        };
        cluster.write_stripes_parallel(stripes, 32, &gen)?;
        let originals: Vec<Vec<Vec<u8>>> = (0..stripes).map(|sid| gen(sid)).collect();

        // kill a node with a typical block load (fair comparison: RDD's
        // weighted placement loads nodes unevenly), recover
        let failed = d3ec::experiments::typical_failed_node(
            cluster.policy(), &spec, stripes);
        cluster.fail_node(failed);
        let stats = cluster.recover_node(failed, stripes, 12)?;

        // verify EVERY data block of EVERY stripe reads back bit-identical
        // (client colocated with each block: verification shouldn't pay
        // network time; a handful of remote reads exercise the read path)
        let mut verified = 0usize;
        for sid in 0..stripes {
            for b in 0..3usize {
                let loc = cluster.locate(sid, b);
                let got = cluster.read_block(sid, b, loc)?;
                assert_eq!(got, originals[sid as usize][b], "stripe {sid} block {b}");
                verified += 1;
            }
        }
        let remote_client = Location::new(7, 2);
        for sid in [0u64, stripes / 2, stripes - 1] {
            let got = cluster.read_block(sid, 0, remote_client)?;
            assert_eq!(got, originals[sid as usize][0]);
        }
        println!(
            "{policy_name:<4} recovered {:>3} blocks ({:>6.1} MB) in {:>6.2?} → {:>6.1} MB/s, λ={:.3} | verified {verified} blocks bit-exact",
            stats.blocks,
            stats.bytes as f64 / 1e6,
            stats.wall,
            stats.throughput_mb_s,
            stats.lambda,
        );
        results.push((policy_name, stats.throughput_mb_s));
    }
    let d3 = results[0].1;
    let rdd = results[1].1;
    println!("\nheadline: D³ recovery throughput = {:.2}× RDD (paper Exp 1: D³ ≈ 1.36× on average)",
        d3 / rdd);
    Ok(())
}
