//! Degraded read (paper Exp 3) on the real mini-HDFS data path: a client
//! reads a block whose node just died; the stack rebuilds it on the fly
//! through the PJRT GF kernels with D³'s inner-rack aggregation.
//!
//! Run: `make artifacts && cargo run --release --example degraded_read`

use std::sync::Arc;

use d3ec::cluster::MiniCluster;
use d3ec::codes::CodeSpec;
use d3ec::placement::{D3Placement, RddPlacement};
use d3ec::runtime::default_artifacts_dir;
use d3ec::topology::{Location, SystemSpec};

fn main() -> anyhow::Result<()> {
    let backend = if default_artifacts_dir().join("manifest.json").exists() {
        "pjrt"
    } else {
        "native"
    };
    let mut spec = SystemSpec::paper_default();
    spec.block_size = 1 << 20; // 1 MiB blocks for a fast demo
    spec.net.inner_mbps = 4000.0;
    spec.net.cross_mbps = 400.0;
    println!("degraded read demo — (6,3)-RS, 1 MiB blocks, backend={backend}\n");
    println!("{:<6} {:>12} {:>14}", "policy", "latency", "rate(MB/s)");
    for name in ["d3", "rdd"] {
        let code = CodeSpec::Rs { k: 6, m: 3 };
        let policy: Arc<dyn d3ec::placement::Placement> = match name {
            "d3" => Arc::new(D3Placement::new(code, spec.cluster)?),
            _ => Arc::new(RddPlacement::new(code, spec.cluster, 9)),
        };
        let cluster = MiniCluster::new(spec, policy, backend, 9)?;
        let mut total = std::time::Duration::ZERO;
        let samples = 5u64;
        for sid in 0..samples {
            let data: Vec<Vec<u8>> =
                (0..6).map(|b| vec![(sid as u8) ^ (b as u8 * 7); spec.block_size as usize]).collect();
            cluster.write_stripe(sid, data.clone())?;
            let victim = cluster.locate(sid, 0);
            cluster.fail_node(victim);
            let (got, lat) = cluster.degraded_read(sid, 0, Location::new(7, 1))?;
            assert_eq!(got, data[0], "degraded read returned wrong bytes");
            total += lat;
        }
        let avg = total / samples as u32;
        println!(
            "{name:<6} {:>12.2?} {:>14.1}",
            avg,
            spec.block_size as f64 / avg.as_secs_f64() / 1e6
        );
    }
    println!("\n(paper Fig 10: D³ cuts (6,3) degraded-read latency by ~47% vs RDD)");
    Ok(())
}
