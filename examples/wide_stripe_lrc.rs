//! Wide-stripe LRC (the paper's §1 motivation: VAST-style wide stripes
//! where RS repair traffic is "insufferable" and LRCs save bandwidth):
//! a (12,2,2)-LRC on 17 racks, D³-placed, with typed repair costs and a
//! full simulated node recovery vs the RS equivalent.
//!
//! Run: `cargo run --release --example wide_stripe_lrc`

use d3ec::codes::{CodeSpec, LrcCode};
use d3ec::experiments::{avg_recovery, build_policy};
use d3ec::recovery::mu::mu_rs;
use d3ec::topology::SystemSpec;

fn main() {
    let mut spec = SystemSpec::paper_default();
    spec.cluster.racks = 17; // prime → OA(17, len+1) exists for len 16
    spec.cluster.nodes_per_rack = 4;

    let lrc = CodeSpec::Lrc { k: 12, l: 2, g: 2 };
    let rs = CodeSpec::Rs { k: 12, m: 4 };
    println!("wide stripes on 17 racks × 4 nodes: {} vs {}\n", lrc.name(), rs.name());

    // per-block repair read costs
    let code = LrcCode::new(12, 2, 2);
    println!("repair reads per failed block:");
    println!("  LRC data/local parity: {} blocks (local group)", code.group_size());
    println!("  LRC global parity:     {} blocks (other parities)", 2 + 2 - 1);
    println!("  RS (any block):        12 blocks; D³ aggregated cross-rack μ = {:.2}", mu_rs(12, 4));

    for (name, codespec) in [("lrc", lrc), ("rs", rs)] {
        let d3 = avg_recovery(&build_policy("d3", codespec, &spec, 0), &spec, 500, 3, 0);
        let rdd = avg_recovery(&build_policy("rdd", codespec, &spec, 1), &spec, 500, 3, 1);
        println!(
            "\n{name}: D³ {:.1} MB/s (λ={:.3})  vs  RDD {:.1} MB/s (λ={:.3})  → {:.2}×",
            d3.throughput_mb_s, d3.lambda, rdd.throughput_mb_s, rdd.lambda,
            d3.throughput_mb_s / rdd.throughput_mb_s
        );
    }
    println!("\n(paper §1/§6.2.3: LRC repair traffic ≪ RS for wide stripes; D³ balances it)");
}
