//! Reproduces the paper's worked examples (Figs 2–5, 7) verbatim so the
//! construction can be eyeballed against the PDF:
//!   * Fig 3(b): OA(3,3) and the (3,2)-RS region layout on 3 racks,
//!   * Fig 5(d): OA(5,4) with identical first five rows,
//!   * the 20-region 𝓜 placement on 5 racks (Fig 5(c)),
//!   * Fig 7: (4,2,1)-LRC column assignment,
//!   * Fig 2: cross-rack read counts for (3,2)-RS repairs (μ = 1.2).
//!
//! Run: `cargo run --example paper_walkthrough`

use d3ec::codes::CodeSpec;
use d3ec::oa::OrthogonalArray;
use d3ec::placement::{D3LrcPlacement, D3Placement, Placement};
use d3ec::recovery::mu::mu_rs;
use d3ec::recovery::plan::plan_repair;
use d3ec::topology::ClusterSpec;

fn main() {
    println!("— Fig 3(b): an OA(3,3) —");
    let oa3 = OrthogonalArray::construct(3, 3).unwrap();
    for r in 0..9 {
        println!("  {:?}", oa3.row(r));
    }
    assert!(oa3.verify());

    println!("\n— Fig 3(c): one region of (3,2)-RS on racks R0..R2 —");
    let p = D3Placement::new(CodeSpec::Rs { k: 3, m: 2 }, ClusterSpec::new(5, 3)).unwrap();
    for sid in 0..9u64 {
        let sp = p.stripe(sid);
        let row: Vec<String> =
            sp.locs.iter().enumerate().map(|(b, l)| format!("B{b}→{l}")).collect();
        println!("  S{sid}: {}", row.join("  "));
    }

    println!("\n— Fig 5(d): OA(5,4), first five rows identical —");
    let oa5 = OrthogonalArray::construct(5, 4).unwrap();
    for r in 0..25 {
        println!("  {:?}", oa5.row(r));
    }
    assert!(oa5.verify() && oa5.first_rows_identical());

    println!("\n— Fig 5(c): 20 stripe regions → racks via 𝓜 —");
    let m = oa5.m_matrix();
    for r in 0..20 {
        println!(
            "  region {r:>2}: G0→R{} G1→R{} G2→R{}  (recovery rack R{})",
            m.entry(r, 0),
            m.entry(r, 1),
            m.entry(r, 2),
            m.entry(r, 3)
        );
    }

    println!("\n— Fig 2: cross-rack blocks for (3,2)-RS repairs —");
    let mut counts = Vec::new();
    for b in 0..5 {
        let plan = plan_repair(&p, 0, b, 0);
        counts.push(plan.cross_rack_blocks());
        println!("  repair B{b}: {} cross-rack block(s)", plan.cross_rack_blocks());
    }
    let avg = counts.iter().sum::<usize>() as f64 / 5.0;
    println!("  average μ = {:.1} (Lemma 4 closed form: {:.1})", avg, mu_rs(3, 2));
    assert!((avg - mu_rs(3, 2)).abs() < 1e-9);

    println!("\n— Fig 7: (4,2,1)-LRC column assignment —");
    let lrc =
        D3LrcPlacement::new(CodeSpec::Lrc { k: 4, l: 2, g: 1 }, ClusterSpec::new(8, 3)).unwrap();
    let names = ["d0", "d1", "d2", "d3", "p0(local)", "p1(local)", "p2(global)"];
    for (b, name) in names.iter().enumerate() {
        println!("  {name:<10} → OA column {}", lrc.col_of(b));
    }
    println!("  (paper: {{p0,d2}} col 0, {{d0,p1}} col 1, {{d1,d3,p2}} col 2)");
}
