//! Recovery comparison — D³ vs RDD vs HDD on the fluid simulator at the
//! paper's full scale (Exp 1/2 scenario), plus the ablation variants.
//!
//! Run: `cargo run --release --example recovery_comparison`

use d3ec::codes::CodeSpec;
use d3ec::experiments::{avg_recovery, build_policy};
use d3ec::topology::SystemSpec;

fn main() {
    let spec = SystemSpec::paper_default();
    println!("simulated testbed: 8 racks × 3 DataNodes, 16 MB blocks, 1000 Mb/s ToR, 100 Mb/s core");
    for (k, m) in [(2usize, 1usize), (3, 2), (6, 3)] {
        let code = CodeSpec::Rs { k, m };
        println!("\n--- {} ---", code.name());
        println!("{:<10} {:>12} {:>8}", "policy", "MB/s", "λ");
        let mut base = 0.0;
        for name in ["rdd", "hdd", "d3-norot", "d3-rr", "d3"] {
            let policy = build_policy(name, code, &spec, 3);
            let out = avg_recovery(&policy, &spec, 1008, 3, 3);
            if name == "rdd" {
                base = out.throughput_mb_s;
            }
            println!(
                "{:<10} {:>8.1} ({:>4.2}x) {:>8.3}",
                name,
                out.throughput_mb_s,
                out.throughput_mb_s / base,
                out.lambda
            );
        }
    }
}
