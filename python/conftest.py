"""Pytest path shim: the test modules import `compile.kernels ...`, which
lives next to this file — make `python/` importable no matter which
directory pytest is invoked from (repo root in CI)."""

import pathlib
import sys

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parent))
