"""AOT path: lowering produces parseable HLO text with the right signature."""

import json
import pathlib
import subprocess
import sys

import jax
import pytest

from compile import aot, model


def test_combine_lowers_to_hlo_text():
    text = aot.lower_entry(model.combine, model.combine_spec(3, 256))
    assert "HloModule" in text
    assert "u8[3,8]" in text        # btab param
    assert "u8[3,256]" in text      # data param
    assert "u8[1,256]" in text      # output panel


def test_matmul_lowers_to_hlo_text():
    text = aot.lower_entry(model.matmul, model.matmul_spec(2, 3, 256))
    assert "HloModule" in text
    assert "u8[2,256]" in text


def test_xor_lowers_to_hlo_text():
    text = aot.lower_entry(model.xor, model.xor_spec(4, 256))
    assert "HloModule" in text
    assert "u8[1,256]" in text


def test_no_elided_constants_in_lowered_module():
    """The printer must embed the GF tables (not elide them as {...})."""
    text = aot.lower_entry(model.combine, model.combine_spec(3, 256))
    assert "{...}" not in text


def test_no_custom_calls_in_lowered_module():
    """interpret=True must lower pallas to plain HLO ops (no Mosaic)."""
    for fn, spec in [
        (model.combine, model.combine_spec(4, 256)),
        (model.xor, model.xor_spec(3, 256)),
    ]:
        text = aot.lower_entry(fn, spec)
        assert "custom-call" not in text, "Mosaic custom-call leaked into HLO"
