"""L1 kernel correctness: Pallas gf_combine / xor_reduce vs the independent
polynomial-basis oracle in ref.py, swept over shapes with hypothesis."""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import gf, ref

SEED = np.random.default_rng(1234)


def rand_u8(shape):
    return SEED.integers(0, 256, size=shape, dtype=np.uint8)


# ---------------------------------------------------------------- tables


def test_tables_match_polynomial_basis():
    """Every exp-table entry agrees with repeated polynomial multiplication."""
    log, exp = gf._build_tables()
    x = 1
    for i in range(255):
        assert exp[i] == x
        assert log[x] == i
        x = ref.gf_mul(x, gf.GF_GENERATOR)
    assert np.array_equal(exp[255:510], exp[:255])


def test_table_mul_equals_ref_mul_exhaustive_diagonalish():
    """gfmul via tables == polynomial mul on a dense sample of pairs."""
    log, exp = gf._build_tables()

    def tmul(a, b):
        if a == 0 or b == 0:
            return 0
        return int(exp[log[a] + log[b]])

    for a in range(0, 256, 7):
        for b in range(256):
            assert tmul(a, b) == ref.gf_mul(a, b), (a, b)


# ---------------------------------------------------------------- combine


@settings(max_examples=40, deadline=None)
@given(
    k=st.integers(1, 12),
    w=st.sampled_from([1, 2, 16, 64, 256, 1024]),
    seed=st.integers(0, 2**31),
)
def test_gf_combine_matches_ref(k, w, seed):
    rng = np.random.default_rng(seed)
    coeffs = rng.integers(0, 256, size=(k,), dtype=np.uint8)
    data = rng.integers(0, 256, size=(k, w), dtype=np.uint8)
    out = np.asarray(gf.gf_combine(jnp.asarray(gf.coeffs_to_btab(coeffs)), jnp.asarray(data)))
    np.testing.assert_array_equal(out, ref.gf_combine_ref(coeffs, data))
    # cross-validate the table-based variant against the bit-linear one
    out_t = np.asarray(gf.gf_combine_tables(jnp.asarray(coeffs), jnp.asarray(data)))
    np.testing.assert_array_equal(out_t, out)


def test_gf_combine_multi_tile():
    """W spanning several TILE_W grid steps."""
    k, w = 3, gf.TILE_W * 3
    coeffs, data = rand_u8((k,)), rand_u8((k, w))
    out = np.asarray(gf.gf_combine(jnp.asarray(gf.coeffs_to_btab(coeffs)), jnp.asarray(data)))
    np.testing.assert_array_equal(out, ref.gf_combine_ref(coeffs, data))


def test_gf_combine_zero_coeffs_is_zero():
    data = rand_u8((4, 128))
    out = np.asarray(gf.gf_combine(jnp.zeros((4, 8), jnp.uint8), jnp.asarray(data)))
    assert not out.any()


def test_gf_combine_identity_coeff_selects_row():
    data = rand_u8((3, 128))
    coeffs = np.array([0, 1, 0], dtype=np.uint8)
    btab = jnp.asarray(gf.coeffs_to_btab(coeffs))
    out = np.asarray(gf.gf_combine(btab, jnp.asarray(data)))
    np.testing.assert_array_equal(out[0], data[1])


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 2**31))
def test_gf_combine_is_linear(seed):
    """combine(c, a ^ b) == combine(c, a) ^ combine(c, b) (GF addition = xor)."""
    rng = np.random.default_rng(seed)
    c = rng.integers(0, 256, size=(5,), dtype=np.uint8)
    bt = jnp.asarray(gf.coeffs_to_btab(c))
    a = rng.integers(0, 256, size=(5, 64), dtype=np.uint8)
    b = rng.integers(0, 256, size=(5, 64), dtype=np.uint8)
    lhs = np.asarray(gf.gf_combine(bt, jnp.asarray(a ^ b)))
    rhs = np.asarray(gf.gf_combine(bt, jnp.asarray(a))) ^ np.asarray(
        gf.gf_combine(bt, jnp.asarray(b))
    )
    np.testing.assert_array_equal(lhs, rhs)


# ---------------------------------------------------------------- xor


@settings(max_examples=25, deadline=None)
@given(k=st.integers(2, 12), w=st.sampled_from([1, 8, 128, 1024]), seed=st.integers(0, 2**31))
def test_xor_reduce_matches_ref(k, w, seed):
    rng = np.random.default_rng(seed)
    data = rng.integers(0, 256, size=(k, w), dtype=np.uint8)
    out = np.asarray(gf.xor_reduce(jnp.asarray(data)))
    np.testing.assert_array_equal(out, ref.xor_reduce_ref(data))


def test_xor_reduce_self_inverse():
    data = rand_u8((2, 256))
    dup = np.concatenate([data, data], axis=0)
    out = np.asarray(gf.xor_reduce(jnp.asarray(dup)))
    assert not out.any()


# ---------------------------------------------------------------- field oracle


@settings(max_examples=60, deadline=None)
@given(a=st.integers(0, 255), b=st.integers(0, 255), c=st.integers(0, 255))
def test_ref_field_axioms(a, b, c):
    m = ref.gf_mul
    assert m(a, b) == m(b, a)
    assert m(a, m(b, c)) == m(m(a, b), c)
    assert m(a, b ^ c) == m(a, b) ^ m(a, c)
    assert m(a, 1) == a


@settings(max_examples=40, deadline=None)
@given(a=st.integers(1, 255))
def test_ref_inverse(a):
    assert ref.gf_mul(a, ref.gf_inv(a)) == 1
