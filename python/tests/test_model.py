"""L2 graph correctness: encode -> erase -> decode round trips through the
Pallas-backed model, plus partial-aggregation equivalence (the identity D^3's
inner-rack aggregation relies on)."""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels import gf as gfk
from compile.kernels import ref


def btab(coeffs):
    import jax.numpy as jnp
    return jnp.asarray(gfk.coeffs_to_btab(coeffs))


def btab2(mat):
    import numpy as np, jax.numpy as jnp
    return jnp.asarray(np.stack([gfk.coeffs_to_btab(row) for row in mat]))

CODES = [(2, 1), (3, 2), (6, 3), (4, 2)]


def stripe(k, w, seed):
    rng = np.random.default_rng(seed)
    data = rng.integers(0, 256, size=(k, w), dtype=np.uint8)
    parity = np.asarray(model.matmul(btab2(ref.rs_generator(k, m_for(k))), jnp.asarray(data)))
    return data, parity


def m_for(k):
    return dict(CODES)[k]


@pytest.mark.parametrize("k,m", CODES)
def test_encode_matches_oracle(k, m):
    rng = np.random.default_rng(7)
    data = rng.integers(0, 256, size=(k, 128), dtype=np.uint8)
    got = np.asarray(model.matmul(btab2(ref.rs_generator(k, m)), jnp.asarray(data)))
    np.testing.assert_array_equal(got, ref.rs_encode_ref(data, m))


@settings(max_examples=20, deadline=None)
@given(
    km=st.sampled_from(CODES),
    seed=st.integers(0, 2**31),
    data=st.data(),
)
def test_any_k_of_n_recovers_any_block(km, seed, data):
    """MDS property end-to-end: pick k random survivors, rebuild any block."""
    k, m = km
    rng = np.random.default_rng(seed)
    blocks = rng.integers(0, 256, size=(k, 64), dtype=np.uint8)
    parity = ref.rs_encode_ref(blocks, m)
    full = np.concatenate([blocks, parity], axis=0)
    n = k + m
    target = data.draw(st.integers(0, n - 1))
    survivors = data.draw(
        st.permutations([i for i in range(n) if i != target]).map(lambda p: sorted(p[:k]))
    )
    coeffs = ref.rs_decode_coeffs(k, m, survivors, target)
    rebuilt = np.asarray(
        model.combine(btab(coeffs), jnp.asarray(full[survivors]))
    )
    np.testing.assert_array_equal(rebuilt[0], full[target])


def test_partial_aggregation_equivalence():
    """D^3 recovery identity (paper fig 2b): aggregating a rack-local subset
    and combining the aggregate equals the direct k-wise combination."""
    k, m = 6, 3
    rng = np.random.default_rng(42)
    blocks = rng.integers(0, 256, size=(k, 64), dtype=np.uint8)
    parity = ref.rs_encode_ref(blocks, m)
    full = np.concatenate([blocks, parity], axis=0)
    target = 0
    survivors = [1, 2, 3, 4, 5, 6]
    coeffs = ref.rs_decode_coeffs(k, m, survivors, target)

    direct = np.asarray(model.combine(btab(coeffs), jnp.asarray(full[survivors])))

    # Split survivors into two "racks" {1,2,3} and {4,5,6}; aggregate each
    # inner-rack, then combine the two aggregates with unit coefficients.
    agg_a = np.asarray(model.combine(btab(coeffs[:3]), jnp.asarray(full[[1, 2, 3]])))
    agg_b = np.asarray(model.combine(btab(coeffs[3:]), jnp.asarray(full[[4, 5, 6]])))
    two = np.concatenate([agg_a, agg_b], axis=0)
    ones = np.array([1, 1], dtype=np.uint8)
    via_agg = np.asarray(model.combine(btab(ones), jnp.asarray(two)))
    np.testing.assert_array_equal(direct, via_agg)
    np.testing.assert_array_equal(direct[0], full[target])


def test_lrc_local_parity_xor_repairs_within_group():
    """(4,2,1)-LRC: a data block is the XOR of the rest of its local group."""
    rng = np.random.default_rng(3)
    d = rng.integers(0, 256, size=(4, 64), dtype=np.uint8)
    # local groups {d0, d1} -> l0 and {d2, d3} -> l1 (XOR parities)
    l0 = np.asarray(model.xor(jnp.asarray(d[[0, 1]])))[0]
    l1 = np.asarray(model.xor(jnp.asarray(d[[2, 3]])))[0]
    # repair d1 from {d0, l0}
    rebuilt = np.asarray(model.xor(jnp.asarray(np.stack([d[0], l0]))))[0]
    np.testing.assert_array_equal(rebuilt, d[1])
    rebuilt2 = np.asarray(model.xor(jnp.asarray(np.stack([d[3], l1]))))[0]
    np.testing.assert_array_equal(rebuilt2, d[2])


def test_decode_coeffs_reject_bad_inputs():
    with pytest.raises(AssertionError):
        ref.rs_decode_coeffs(3, 2, [0, 1], 4)  # wrong survivor count
