"""AOT-lower the Layer-2 graphs to HLO *text* artifacts for the Rust runtime.

HLO text (NOT ``lowered.compile().serialize()``) is the interchange format:
jax >= 0.5 emits HloModuleProto with 64-bit instruction ids which the xla
crate's xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text
parser on the Rust side reassigns ids and round-trips cleanly.

Outputs (under --out-dir, default ../artifacts):
  gf_combine_k{k}_w{W}.hlo.txt       k in 1..=KMAX (btab (k,8) + data (k,W))
  gf_matmul_m{m}_k{k}_w{W}.hlo.txt   (m, k) per supported code variant
  xor_k{k}_w{W}.hlo.txt              k in 2..=KMAX (LRC local groups)
  manifest.json                      shape/dtype index consumed by runtime/

Python runs ONCE at build time (``make artifacts``); the Rust binary is
self-contained afterwards.
"""

from __future__ import annotations

import argparse
import json
import pathlib

import jax
from jax._src.lib import xla_client as xc

from . import model

# Largest per-combination fan-in we lower.  Covers (6,3)-RS (k=6 decode,
# aggregation fan-in <= m=3+...), (4,2,1)-LRC (global repair fan-in l+g=3),
# and headroom for wide-stripe demos.
KMAX = 12
# (m, k) encode variants: HDFS-EC built-ins + the paper's LRC + wide-stripe.
MATMUL_VARIANTS = [(1, 2), (2, 3), (3, 6), (1, 4), (2, 4), (4, 10), (4, 12)]


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    # return_tuple=False: all entry points are single-output, and a bare
    # array result lets the rust side use pjrt_buffer_copy_raw_to_host_sync
    # (no tuple unwrap / literal round-trip — §Perf).
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=False
    )
    # print_large_constants=True: the GF log/exp tables are embedded as
    # dense constants; the default printer elides them to "{...}" which the
    # rust-side parser silently turns into garbage.
    return comp.as_hlo_text(True)


def lower_entry(fn, specs) -> str:
    return to_hlo_text(jax.jit(fn).lower(*specs))


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default=str(pathlib.Path(__file__).resolve().parents[2] / "artifacts"))
    ap.add_argument("--out", default=None, help="compat: ignored single-file target")
    ap.add_argument("--width", type=int, default=model.DEFAULT_W)
    ap.add_argument("--kmax", type=int, default=KMAX)
    args = ap.parse_args()

    out_dir = pathlib.Path(args.out_dir)
    out_dir.mkdir(parents=True, exist_ok=True)
    w = args.width
    manifest: dict = {"width": w, "dtype": "u8", "iface": "btab-v2", "entries": []}

    def emit(name: str, fn, specs, io: dict) -> None:
        path = out_dir / f"{name}.hlo.txt"
        path.write_text(lower_entry(fn, specs))
        manifest["entries"].append({"name": name, "file": path.name, **io})
        print(f"  wrote {path.name}")

    for k in range(1, args.kmax + 1):
        emit(
            f"gf_combine_k{k}_w{w}",
            model.combine,
            model.combine_spec(k, w),
            {"op": "combine", "k": k, "w": w},
        )
    for m, k in MATMUL_VARIANTS:
        emit(
            f"gf_matmul_m{m}_k{k}_w{w}",
            model.matmul,
            model.matmul_spec(m, k, w),
            {"op": "matmul", "m": m, "k": k, "w": w},
        )
    for k in range(2, args.kmax + 1):
        emit(
            f"xor_k{k}_w{w}",
            model.xor,
            model.xor_spec(k, w),
            {"op": "xor", "k": k, "w": w},
        )

    (out_dir / "manifest.json").write_text(json.dumps(manifest, indent=2))
    print(f"wrote {len(manifest['entries'])} artifacts to {out_dir}")


if __name__ == "__main__":
    main()
