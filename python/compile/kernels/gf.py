"""Layer-1 Pallas kernels: GF(2^8) linear combination and XOR reduction.

The erasure-coding hot-spot of the D^3 paper is the byte-wise Galois-field
matrix multiply ``out = coeffs (x) data`` over GF(2^8) with the standard
erasure-coding polynomial x^8 + x^4 + x^3 + x^2 + 1 (0x11d, as used by
ISA-L / Jerasure).  By RS *linearity* (paper section 2.2) one primitive covers

  * encode      - coeffs = generator-matrix rows,
  * decode      - coeffs = rows of the inverted sub-generator,
  * aggregation - coeffs = the partial sums D^3's recovery sends inner-rack.

The kernels use log/exp-table arithmetic: ``mul(a, b) = exp[log a + log b]``
with a doubled exp table so no ``mod 255`` is needed on the summed logs.

TPU adaptation (DESIGN.md section 3): the kernel is tiled over the block
width W with BlockSpec ``(k, TILE_W)``; on TPU TILE_W would be ~8 KiB so a
grid step holds <= ~128 KiB in VMEM (the CPU artifacts use panel-sized
tiles - see TILE_W below). GF math cannot use the MXU, so this is a
VPU/memory-bound kernel - the roofline is bytes moved, ~(k+1) bytes per
output byte. ``interpret=True`` everywhere: the CPU PJRT client cannot
execute Mosaic custom-calls.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

# GF(2^8) modulus used throughout the repo (must match rust/src/gf/mod.rs).
GF_POLY = 0x11D
# 0x02 is a generator of GF(256)* for poly 0x11d.
GF_GENERATOR = 0x02

# Width (in bytes) of one kernel tile per grid step.
#
# Target-dependent (perf pass, EXPERIMENTS.md §Perf): on a real TPU this
# would be ~8192 so a (k, TILE_W) tile fits VMEM with double-buffering
# headroom. The shipped artifacts target the CPU PJRT backend, where the
# pallas interpret-mode grid lowers to an XLA while-loop whose per-step
# overhead dominates at small tiles (measured 6 MB/s at 8 KiB vs 790 MB/s
# at 1 MiB for k=6); panel-sized tiles (grid=1) let XLA fuse and vectorize
# the whole bit-linear combine.
TILE_W = 1 << 20


def _build_tables() -> tuple[np.ndarray, np.ndarray]:
    """Build log/exp tables for GF(2^8) mod GF_POLY.

    Returns (log, exp2) where ``log`` has 256 entries (log[0] is a sentinel,
    never consumed because zero operands are masked) and ``exp2`` has 512
    entries: exp2[i] = g^(i mod 255), doubled so ``log a + log b`` (< 510)
    indexes directly.
    """
    exp = np.zeros(512, dtype=np.uint8)
    log = np.zeros(256, dtype=np.int32)
    x = 1
    for i in range(255):
        exp[i] = x
        log[x] = i
        x <<= 1
        if x & 0x100:
            x ^= GF_POLY
    exp[255:510] = exp[:255]
    # exp2[510], exp2[511] unused (max log sum = 254 + 254 = 508).
    return log, exp

_LOG_NP, _EXP_NP = _build_tables()


def gf_combine_kernel(btab_ref, data_ref, out_ref, *, k: int):
    """out[0, :] = XOR_i gfmul(c_i, data[i, :]) over one W-tile (bit-linear).

    GF(2^8) multiplication by a constant c is GF(2)-LINEAR: with
    btab[i][b] = gfmul(c_i, 1 << b), the product of c_i and byte x is
    XOR_{b: bit b of x set} btab[i][b]. The kernel therefore needs only
    shifts, masks and XORs - no gathers - which vectorizes on any VPU
    (TPU VPUs and XLA:CPU both execute gathers scalarly; this formulation
    is the perf-pass replacement for the log/exp-table version, kept below
    as gf_combine_tables_kernel for cross-validation). See EXPERIMENTS.md
    section Perf.

    btab_ref: (k, 8)    uint8   - per-coefficient bit tables
    data_ref: (k, Wt)   uint8   - the k surviving/source shards (one tile)
    out_ref:  (1, Wt)   uint8
    """
    acc = jnp.zeros(out_ref.shape, dtype=jnp.uint8)
    for i in range(k):
        row = data_ref[i, :][None, :]
        for b in range(8):
            bit = (row >> b) & jnp.uint8(1)
            # bit is 0/1; multiply selects btab[i, b] where the bit is set
            acc = acc ^ (bit * btab_ref[i, b])
    out_ref[...] = acc


def gf_combine_tables_kernel(coef_ref, data_ref, log_ref, exp_ref, out_ref, *, k: int):
    """Log/exp-table variant (original formulation; cross-validation and
    ablation baseline for the bit-linear kernel above)."""
    logt = log_ref[...]
    expt = exp_ref[...]
    acc = jnp.zeros(out_ref.shape, dtype=jnp.uint8)
    # k <= 16 in any deployed code; unroll so the accumulator stays live.
    for i in range(k):
        c = coef_ref[i]
        row = data_ref[i, :][None, :]
        log_sum = logt[c.astype(jnp.int32)] + jnp.take(logt, row.astype(jnp.int32))
        prod = jnp.take(expt, log_sum)
        # gfmul(a, 0) = gfmul(0, b) = 0: mask both operand-zero cases.
        prod = jnp.where((row == 0) | (c == 0), jnp.uint8(0), prod)
        acc = acc ^ prod
    out_ref[...] = acc


def gf_mul_scalar(a: int, b: int) -> int:
    """Host-side scalar GF multiply (table-based) for btab construction."""
    if a == 0 or b == 0:
        return 0
    return int(_EXP_NP[int(_LOG_NP[a]) + int(_LOG_NP[b])])


def coeffs_to_btab(coeffs) -> np.ndarray:
    """btab[i][b] = gfmul(coeffs[i], 1 << b) - the kernel's bit tables."""
    coeffs = np.asarray(coeffs, dtype=np.uint8)
    out = np.zeros((coeffs.shape[0], 8), dtype=np.uint8)
    for i, c in enumerate(coeffs):
        for b in range(8):
            out[i, b] = gf_mul_scalar(int(c), 1 << b)
    return out


def xor_reduce_kernel(data_ref, out_ref, *, k: int):
    """out[0, :] = XOR_i data[i, :] - LRC local-parity special case."""
    acc = jnp.zeros(out_ref.shape, dtype=jnp.uint8)
    for i in range(k):
        acc = acc ^ data_ref[i, :][None, :]
    out_ref[...] = acc


def _tile_width(w: int) -> int:
    return min(w, TILE_W)


@functools.partial(jax.jit, static_argnames=("interpret",))
def _noop(x, interpret=True):  # pragma: no cover - keeps jit cache warm in tests
    return x


def gf_combine(btab: jax.Array, data: jax.Array, *, interpret: bool = True) -> jax.Array:
    """Pallas-backed GF(2^8) linear combination (bit-linear kernel).

    btab: (k, 8) uint8 (see coeffs_to_btab); data: (k, W) uint8 -> (1, W).
    W must be a multiple of the tile width (the AOT path guarantees this;
    tests pick small W where one tile covers everything).
    """
    k, w = data.shape
    assert btab.shape == (k, 8), (btab.shape, k)
    tw = _tile_width(w)
    assert w % tw == 0, f"W={w} not a multiple of tile width {tw}"
    grid = (w // tw,)
    return pl.pallas_call(
        functools.partial(gf_combine_kernel, k=k),
        grid=grid,
        in_specs=[
            pl.BlockSpec((k, 8), lambda j: (0, 0)),
            pl.BlockSpec((k, tw), lambda j: (0, j)),
        ],
        out_specs=pl.BlockSpec((1, tw), lambda j: (0, j)),
        out_shape=jax.ShapeDtypeStruct((1, w), jnp.uint8),
        interpret=interpret,
    )(btab, data)


def gf_combine_tables(coeffs: jax.Array, data: jax.Array, *, interpret: bool = True) -> jax.Array:
    """Log/exp-table variant of gf_combine (ablation / cross-validation)."""
    k, w = data.shape
    assert coeffs.shape == (k,), (coeffs.shape, k)
    tw = _tile_width(w)
    assert w % tw == 0, f"W={w} not a multiple of tile width {tw}"
    log_t = jnp.asarray(_LOG_NP)
    exp_t = jnp.asarray(_EXP_NP)
    grid = (w // tw,)
    return pl.pallas_call(
        functools.partial(gf_combine_tables_kernel, k=k),
        grid=grid,
        in_specs=[
            pl.BlockSpec((k,), lambda j: (0,)),
            pl.BlockSpec((k, tw), lambda j: (0, j)),
            pl.BlockSpec((256,), lambda j: (0,)),
            pl.BlockSpec((512,), lambda j: (0,)),
        ],
        out_specs=pl.BlockSpec((1, tw), lambda j: (0, j)),
        out_shape=jax.ShapeDtypeStruct((1, w), jnp.uint8),
        interpret=interpret,
    )(coeffs, data, log_t, exp_t)


def xor_reduce(data: jax.Array, *, interpret: bool = True) -> jax.Array:
    """Pallas-backed XOR reduction over axis 0: (k, W) uint8 -> (1, W)."""
    k, w = data.shape
    tw = _tile_width(w)
    assert w % tw == 0, f"W={w} not a multiple of tile width {tw}"
    grid = (w // tw,)
    return pl.pallas_call(
        functools.partial(xor_reduce_kernel, k=k),
        grid=grid,
        in_specs=[pl.BlockSpec((k, tw), lambda j: (0, j))],
        out_specs=pl.BlockSpec((1, tw), lambda j: (0, j)),
        out_shape=jax.ShapeDtypeStruct((1, w), jnp.uint8),
        interpret=interpret,
    )(data)
