"""Pure-numpy correctness oracle for the GF(2^8) kernels.

Deliberately *independent* of the table construction in ``gf.py``: multiply
is implemented polynomial-basis (Russian-peasant shift/xor, reducing by
x^8 + x^4 + x^3 + x^2 + 1) so a table bug cannot self-validate.  Also hosts
the small dense-matrix GF linear algebra (inversion) the python tests use to
exercise full encode -> erase -> decode round trips.
"""

from __future__ import annotations

import numpy as np

GF_POLY = 0x11D


def gf_mul(a: int, b: int) -> int:
    """Polynomial-basis GF(2^8) multiply (scalar oracle)."""
    a, b, acc = int(a), int(b), 0
    for _ in range(8):
        if b & 1:
            acc ^= a
        b >>= 1
        a <<= 1
        if a & 0x100:
            a ^= GF_POLY
    return acc


def gf_mul_vec(c: int, v: np.ndarray) -> np.ndarray:
    """Vectorized polynomial-basis multiply of a scalar by a uint8 vector."""
    acc = np.zeros_like(v, dtype=np.uint16)
    a = np.asarray(v, dtype=np.uint16)
    c = int(c)
    for _ in range(8):
        if c & 1:
            acc ^= a
        c >>= 1
        a = a << 1
        overflow = (a & 0x100) != 0
        a = np.where(overflow, a ^ GF_POLY, a)
    return acc.astype(np.uint8)


def gf_pow(a: int, e: int) -> int:
    acc = 1
    for _ in range(e):
        acc = gf_mul(acc, a)
    return acc


def gf_inv(a: int) -> int:
    """Multiplicative inverse via Fermat: a^(2^8 - 2)."""
    if a == 0:
        raise ZeroDivisionError("gf_inv(0)")
    return gf_pow(a, 254)


def gf_combine_ref(coeffs: np.ndarray, data: np.ndarray) -> np.ndarray:
    """Oracle for kernels.gf.gf_combine: (k,), (k, W) -> (1, W)."""
    k, w = data.shape
    acc = np.zeros((w,), dtype=np.uint8)
    for i in range(k):
        acc ^= gf_mul_vec(int(coeffs[i]), data[i])
    return acc[None, :]


def gf_matmul_ref(mat: np.ndarray, data: np.ndarray) -> np.ndarray:
    """(m, k) x (k, W) GF matmul oracle."""
    return np.concatenate([gf_combine_ref(row, data) for row in mat], axis=0)


def xor_reduce_ref(data: np.ndarray) -> np.ndarray:
    out = np.zeros((1, data.shape[1]), dtype=np.uint8)
    for row in data:
        out[0] ^= row
    return out


def gf_matrix_inv(m: np.ndarray) -> np.ndarray:
    """Invert a square GF(2^8) matrix by Gauss-Jordan (oracle-grade, O(n^3))."""
    n = m.shape[0]
    assert m.shape == (n, n)
    a = m.astype(np.uint8).copy()
    inv = np.eye(n, dtype=np.uint8)
    for col in range(n):
        piv = next((r for r in range(col, n) if a[r, col] != 0), None)
        if piv is None:
            raise ValueError("singular GF matrix")
        if piv != col:
            a[[col, piv]] = a[[piv, col]]
            inv[[col, piv]] = inv[[piv, col]]
        s = gf_inv(int(a[col, col]))
        a[col] = gf_mul_vec(s, a[col])
        inv[col] = gf_mul_vec(s, inv[col])
        for r in range(n):
            if r != col and a[r, col] != 0:
                f = int(a[r, col])
                a[r] ^= gf_mul_vec(f, a[col])
                inv[r] ^= gf_mul_vec(f, inv[col])
    return inv


def rs_generator(k: int, m: int) -> np.ndarray:
    """Parity rows of the systematic Cauchy generator used across the repo.

    Must match rust/src/codes/rs.rs: entry (i, j) = 1 / (x_i + y_j) with
    x_i = i + k, y_j = j for i in [0, m), j in [0, k).  Cauchy matrices have
    every square submatrix nonsingular, so the systematic code is MDS.
    """
    g = np.zeros((m, k), dtype=np.uint8)
    for i in range(m):
        for j in range(k):
            g[i, j] = gf_inv((i + k) ^ j)
    return g


def rs_encode_ref(data: np.ndarray, m: int) -> np.ndarray:
    """(k, W) data -> (m, W) parity via the systematic Cauchy generator."""
    k = data.shape[0]
    return gf_matmul_ref(rs_generator(k, m), data)


def full_generator(k: int, m: int) -> np.ndarray:
    """(k+m, k) systematic generator: identity stacked on Cauchy parity."""
    return np.concatenate([np.eye(k, dtype=np.uint8), rs_generator(k, m)], axis=0)


def rs_decode_coeffs(k: int, m: int, available: list[int], target: int) -> np.ndarray:
    """Coefficients expressing stripe block ``target`` from ``available``.

    ``available`` is a list of k distinct surviving block indices in
    [0, k+m); returns (k,) uint8 c with  B_target = XOR_i c_i * B_available[i].
    """
    assert len(available) == k
    g = full_generator(k, m)
    sub = g[available, :]           # (k, k) rows of the generator
    inv = gf_matrix_inv(sub)        # data = inv @ avail
    trow = g[target, :]             # target = trow @ data
    # target = trow @ inv @ avail
    out = np.zeros(k, dtype=np.uint8)
    for j in range(k):
        acc = 0
        for t in range(k):
            acc ^= gf_mul(int(trow[t]), int(inv[t, j]))
        out[j] = acc
    return out
