"""Layer-2: the erasure-coding compute graph, built on the Layer-1 kernels.

By RS linearity (paper section 2.2) every coding operation the D^3 recovery
pipeline performs - encode, single-block decode, and the inner-rack *partial
aggregation* that minimizes cross-rack traffic - is one GF(2^8) linear
combination ``out = XOR_i c_i * shard_i``.  The coefficients are computed by
the Rust coordinator (rust/src/gf, rust/src/codes); this module only defines
the data-plane graphs that get AOT-lowered to HLO.

Entry points (all uint8, fixed chunk width W; Rust chunks blocks into
W-column panels). Coefficients enter as *bit tables* btab[i][b] =
gfmul(c_i, 1 << b) — see kernels.gf.gf_combine (bit-linear form):

  combine(k)   : btab (k, 8), data (k, W)        -> (1, W)
  matmul(m, k) : btab (m, k, 8), data (k, W)     -> (m, W)   (encode: all
                 parities of one stripe in one PJRT call)
  xor(k)       : data (k, W)                     -> (1, W)   (LRC local
                 parity / replication-style aggregation)
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .kernels import gf as gfk

# Chunk width the AOT artifacts are lowered at. 1 MiB panels (perf pass,
# EXPERIMENTS.md section Perf): 16x fewer PJRT dispatches than the original
# 64 KiB panels; a (k=12, W) input panel is 12 MiB - fine for host memory,
# while the Pallas grid still tiles VMEM at TILE_W = 8 KiB.
DEFAULT_W = 1 << 20


def combine(btab: jax.Array, data: jax.Array) -> jax.Array:
    """One GF(2^8) linear combination (decode / aggregate primitive)."""
    return gfk.gf_combine(btab, data)


def matmul(btab: jax.Array, data: jax.Array) -> jax.Array:
    """(m, k, 8) x (k, W) GF matmul - encodes all m parities in one call.

    Row-wise over the Layer-1 combine kernel; XLA fuses the shared data
    loads across rows at lowering time.
    """
    m = btab.shape[0]
    rows = [gfk.gf_combine(btab[i], data) for i in range(m)]
    return jnp.concatenate(rows, axis=0)


def xor(data: jax.Array) -> jax.Array:
    """XOR reduce over shards - LRC local parity."""
    return gfk.xor_reduce(data)


def combine_spec(k: int, w: int = DEFAULT_W):
    return (
        jax.ShapeDtypeStruct((k, 8), jnp.uint8),
        jax.ShapeDtypeStruct((k, w), jnp.uint8),
    )


def matmul_spec(m: int, k: int, w: int = DEFAULT_W):
    return (
        jax.ShapeDtypeStruct((m, k, 8), jnp.uint8),
        jax.ShapeDtypeStruct((k, w), jnp.uint8),
    )


def xor_spec(k: int, w: int = DEFAULT_W):
    return (jax.ShapeDtypeStruct((k, w), jnp.uint8),)
