//! Bounded-memory regression for the synthetic block store (DESIGN.md
//! §16): a scenario whose *virtual* payload footprint is several GB must
//! run with a live-heap peak orders of magnitude smaller, because the
//! synthetic store regenerates payloads on read instead of holding them
//! resident. Enforced with a counting global allocator — the same
//! mechanism that would catch an accidental `Vec<Vec<u8>>` block map
//! sneaking back into the scenario path.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use d3ec::cluster::{ClusterBackend, StoreMode};
use d3ec::codes::CodeSpec;
use d3ec::placement::{D3Placement, Placement};
use d3ec::scenario::{FailureScenario, RecoveryBackend};
use d3ec::topology::{ClusterSpec, SystemSpec};

/// Live bytes right now, and the high-water mark since process start.
static CURRENT: AtomicU64 = AtomicU64::new(0);
static PEAK: AtomicU64 = AtomicU64::new(0);

struct CountingAlloc;

fn bump(n: u64) {
    let live = CURRENT.fetch_add(n, Ordering::Relaxed) + n;
    PEAK.fetch_max(live, Ordering::Relaxed);
}

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        let p = System.alloc(layout);
        if !p.is_null() {
            bump(layout.size() as u64);
        }
        p
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        let p = System.alloc_zeroed(layout);
        if !p.is_null() {
            bump(layout.size() as u64);
        }
        p
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        let p = System.realloc(ptr, layout, new_size);
        if !p.is_null() {
            CURRENT.fetch_sub(layout.size() as u64, Ordering::Relaxed);
            bump(new_size as u64);
        }
        p
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout);
        CURRENT.fetch_sub(layout.size() as u64, Ordering::Relaxed);
    }
}

#[global_allocator]
static ALLOC: CountingAlloc = CountingAlloc;

#[test]
fn multi_gb_scenario_peaks_far_below_its_virtual_footprint() {
    // 128 nodes (n = 16 per rack keeps the D³ orthogonal array wide
    // enough for rs-6-3), 20k stripes, 32 KiB blocks: ~5.9 GB of virtual
    // payload. Auto mode must flip to the synthetic store and the whole
    // run — populate, probe, plan, recover — must stay O(metadata).
    let mut spec = SystemSpec::paper_default();
    spec.cluster = ClusterSpec::new(8, 16);
    spec.block_size = 32 << 10;
    let code = CodeSpec::Rs { k: 6, m: 3 };
    let stripes = 20_000u64;
    let virt = stripes as u128 * code.len() as u128 * spec.block_size as u128;
    assert!(virt > 5 << 30, "test footprint shrank — bump stripes");
    assert!(StoreMode::Auto.synthetic_for(stripes, code.len(), spec.block_size));

    let policy: Arc<dyn Placement> = Arc::new(D3Placement::new(code, spec.cluster).unwrap());
    let backend = ClusterBackend { block_size: spec.block_size, ..ClusterBackend::default() };
    let scenario = FailureScenario::single_node(stripes, 2);

    PEAK.store(CURRENT.load(Ordering::Relaxed), Ordering::Relaxed);
    let out = backend.run(&scenario, &policy, &spec).unwrap();
    let peak = PEAK.load(Ordering::Relaxed);

    assert!(out.blocks > 500, "failed node held suspiciously few blocks: {}", out.blocks);
    assert!(peak > 0, "allocator counter never engaged");
    let cap: u64 = 192 << 20;
    assert!(
        peak < cap,
        "live-heap peak {} MB exceeds the {} MB bound (virtual footprint {} MB)",
        peak >> 20,
        cap >> 20,
        (virt >> 20) as u64
    );
    assert!(
        (peak as u128) * 20 < virt,
        "peak {} MB is not far below the {} MB virtual footprint",
        peak >> 20,
        (virt >> 20) as u64
    );
}
