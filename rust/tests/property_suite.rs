//! Randomized cross-configuration property suite (hand-rolled in lieu of
//! proptest, which is unavailable offline): sweeps random valid
//! (code, cluster) configurations and asserts the coordinator invariants
//! the paper's theorems promise, for every policy. The seed-driven
//! generator below samples full (racks, nodes/rack, k, m, block size,
//! policy) tuples — ≥ 200 of them — and checks placement uniformity,
//! round-trip decode through the shared slice kernel, and plan validity.

use d3ec::codes::{CodeSpec, RsCode};
use d3ec::metrics;
use d3ec::placement::{
    D3LrcPlacement, D3Placement, HddPlacement, Placement, RddPlacement,
};
use d3ec::recovery::execute_plan_bytes;
use d3ec::recovery::mu::mu_rs;
use d3ec::recovery::plan::{plan_coefficients, plan_repair};
use d3ec::topology::ClusterSpec;
use d3ec::util::Rng;

/// Random valid (k, m, racks, nodes) D³ configurations.
fn random_rs_configs(count: usize, seed: u64) -> Vec<(usize, usize, usize, usize)> {
    let mut rng = Rng::new(seed);
    let mut out = Vec::new();
    while out.len() < count {
        let k = 2 + rng.below(9); // 2..=10
        let m = 1 + rng.below(4); // 1..=4
        let len = k + m;
        let ng = len.div_ceil(m);
        let size_max = len.div_ceil(ng);
        // nodes per rack: >= group size, keep OA constructible (prime powers
        // are guaranteed; composites may cap columns)
        let n_candidates: Vec<usize> = (size_max.max(2)..=9)
            .filter(|&n| d3ec::oa::max_columns(n) >= ng)
            .collect();
        if n_candidates.is_empty() {
            continue;
        }
        let n = *rng.choose(&n_candidates);
        let r_candidates: Vec<usize> = (ng + 1..=13)
            .filter(|&r| d3ec::oa::max_columns(r) >= ng + 1 && r * m >= len)
            .collect();
        if r_candidates.is_empty() {
            continue;
        }
        let r = *rng.choose(&r_candidates);
        out.push((k, m, r, n));
    }
    out
}

/// Encode one full stripe (k data + m parity shards of `len` bytes).
fn encode_stripe(k: usize, m: usize, len: usize, seed: u64) -> Vec<Vec<u8>> {
    let mut s = seed.wrapping_mul(0x9e3779b97f4a7c15) | 1;
    let data: Vec<Vec<u8>> = (0..k)
        .map(|_| {
            (0..len)
                .map(|_| {
                    s ^= s << 13;
                    s ^= s >> 7;
                    s ^= s << 17;
                    (s >> 24) as u8
                })
                .collect()
        })
        .collect();
    let refs: Vec<&[u8]> = data.iter().map(|v| v.as_slice()).collect();
    let parity = RsCode::new(k, m).encode(&refs);
    let mut all = data;
    all.extend(parity);
    all
}

/// Scalar reference for the fused data path: per-byte `gf::mul`
/// accumulation of a plan's sources (aggregation staging collapses under
/// GF linearity, so the flat sum is the ground truth for any staging).
fn naive_plan_bytes(
    code: &CodeSpec,
    plan: &d3ec::recovery::RepairPlan,
    shards: &[Vec<u8>],
) -> Vec<u8> {
    let sources = plan.source_blocks();
    let coeffs = plan_coefficients(code, plan);
    let width = sources.first().map_or(0, |&b| shards[b].len());
    let mut acc = vec![0u8; width];
    for (&b, &c) in sources.iter().zip(&coeffs) {
        for (a, &s) in acc.iter_mut().zip(&shards[b]) {
            *a ^= d3ec::gf::mul(c, s);
        }
    }
    acc
}

/// Deterministic property harness over ≥ 200 sampled configurations of
/// (racks, nodes/rack, k, m, block size, policy). For every sample:
///
/// * **placement uniformity** — per-node block counts over a policy-
///   appropriate stripe window (one full period for D³, a 600-stripe
///   window for the randomized baselines) stay within a λ bound: D³'s
///   deterministic balance must beat the random policies' tail by a wide
///   margin;
/// * **round-trip decode** — a seeded failed block is rebuilt from real
///   encoded bytes at the sampled block size via `execute_plan_bytes`
///   (the *fused* cache-blocked kernel twin of the cluster data path,
///   DESIGN.md §9) and must match; every tenth sample additionally
///   cross-checks the fused result against a naive per-byte `gf::mul`
///   accumulation, so the wide-word engine stays pinned to the scalar
///   field arithmetic across the whole configuration space;
/// * **plan validity** — exactly k distinct sources, failed block never
///   read, decode coefficients exist.
#[test]
fn seeded_sweep_200_configs_uniformity_decode_validity() {
    let mut rng = Rng::new(0xd3c0de);
    let mut sampled = 0usize;
    let mut attempts = 0usize;
    while sampled < 200 {
        attempts += 1;
        assert!(attempts < 100_000, "generator starved after {sampled} configs");
        let k = 2 + rng.below(7); // 2..=8
        let m = 1 + rng.below(3); // 1..=3
        let len_blocks = k + m;
        let ng = len_blocks.div_ceil(m);
        let size_max = len_blocks.div_ceil(ng);
        let n_candidates: Vec<usize> = (size_max.max(2)..=9)
            .filter(|&n| d3ec::oa::max_columns(n) >= ng)
            .collect();
        if n_candidates.is_empty() {
            continue;
        }
        let n = *rng.choose(&n_candidates);
        let r_candidates: Vec<usize> = (ng + 1..=13)
            .filter(|&r| d3ec::oa::max_columns(r) >= ng + 1 && r * m >= len_blocks)
            .collect();
        if r_candidates.is_empty() {
            continue;
        }
        let r = *rng.choose(&r_candidates);
        let block_len = *rng.choose(&[64usize, 128, 512, 2048]);
        let code = CodeSpec::Rs { k, m };
        let cluster = ClusterSpec::new(r, n);
        if cluster.node_count() < len_blocks + 1 {
            continue; // recovery targets need a spare node
        }
        // (policy, uniformity window, per-node λ bound)
        let (policy, window, lambda_bound): (Box<dyn Placement>, u64, f64) =
            match rng.below(3) {
                0 => {
                    let p = D3Placement::new(code, cluster)
                        .unwrap_or_else(|e| panic!("({k},{m}) on {r}x{n}: {e}"));
                    // one full period: the rack rotation must have cycled
                    // for the paper's uniformity theorem to apply
                    let w = (p.region_cycle() * p.region_size()) as u64;
                    (Box::new(p), w, 0.5)
                }
                // idealized IID RDD: the calibrated-skew default is
                // *designed* to exceed any uniformity bound (Fig 8)
                1 => (
                    Box::new(RddPlacement::uniform(code, cluster, sampled as u64)),
                    600,
                    1.6,
                ),
                _ => (
                    Box::new(HddPlacement::new(code, cluster, sampled as u32)),
                    600,
                    1.6,
                ),
            };
        // --- placement uniformity
        let mut per_node = vec![0f64; cluster.node_count()];
        for sid in 0..window {
            for &loc in &policy.stripe(sid).locs {
                per_node[cluster.flat(loc)] += 1.0;
            }
        }
        let lam = metrics::lambda(&per_node);
        assert!(
            lam <= lambda_bound,
            "{} ({k},{m}) on {r}x{n}: per-node λ {lam:.3} > {lambda_bound}",
            policy.name()
        );
        // --- structural invariants + plan validity on a seeded stripe
        let sid = rng.below(window as usize) as u64;
        let sp = policy.stripe(sid);
        assert!(sp.nodes_distinct(), "{} sid={sid}", policy.name());
        assert!(sp.rack_limit_ok(m), "{} sid={sid}", policy.name());
        let failed_block = rng.below(len_blocks);
        let plan = plan_repair(policy.as_ref(), sid, failed_block, sampled as u64);
        assert_eq!(plan.blocks_read(), k, "{} sid={sid}", policy.name());
        let srcs = plan.source_blocks();
        assert!(!srcs.contains(&failed_block), "plan reads the failed block");
        let distinct: std::collections::HashSet<usize> = srcs.iter().copied().collect();
        assert_eq!(distinct.len(), k, "duplicate sources");
        let coeffs = plan_coefficients(&code, &plan);
        assert_eq!(coeffs.len(), k, "undecodable source set");
        // --- round-trip decode at the sampled block size (fused kernel)
        let all = encode_stripe(k, m, block_len, 0x5eed ^ sampled as u64);
        let rebuilt = execute_plan_bytes(&code, &plan, &all);
        assert_eq!(
            rebuilt, all[failed_block],
            "{} ({k},{m}) {r}x{n} sid={sid} b={failed_block} len={block_len}",
            policy.name()
        );
        if sampled % 10 == 0 {
            // differential check: fused engine vs per-byte scalar reference
            assert_eq!(
                rebuilt,
                naive_plan_bytes(&code, &plan, &all),
                "fused path diverged from scalar gf::mul at sample {sampled}"
            );
        }
        sampled += 1;
    }
    assert!(sampled >= 200);
}

#[test]
fn d3_invariants_over_random_configs() {
    for (k, m, r, n) in random_rs_configs(25, 0xd3) {
        let code = CodeSpec::Rs { k, m };
        let cluster = ClusterSpec::new(r, n);
        let p = match D3Placement::new(code, cluster) {
            Ok(p) => p,
            Err(e) => panic!("({k},{m}) on {r}x{n} rejected: {e}"),
        };
        let mut mu_total = 0usize;
        let stripes = (p.region_size() * 2) as u64;
        for sid in 0..stripes {
            let sp = p.stripe(sid);
            assert!(sp.nodes_distinct(), "({k},{m}) {r}x{n} sid={sid}");
            assert!(sp.rack_limit_ok(m), "({k},{m}) {r}x{n} sid={sid}");
            for (bi, &loc) in sp.locs.iter().enumerate() {
                let tgt = p.recovery_target(sid, bi, loc);
                assert_ne!(tgt, loc);
                assert!(
                    !sp.locs.iter().enumerate().any(|(o, l)| o != bi && *l == tgt),
                    "({k},{m}) {r}x{n} sid={sid} b={bi}: target collides"
                );
                let plan = plan_repair(&p, sid, bi, 0);
                assert_eq!(plan.blocks_read(), k, "plan must read exactly k");
                let coeffs = plan_coefficients(&code, &plan);
                assert_eq!(coeffs.len(), k, "decodable source set");
                mu_total += plan.cross_rack_blocks();
            }
        }
        // Lemma 4: average cross-rack accessed blocks equals the closed form
        let avg = mu_total as f64 / (stripes as usize * (k + m)) as f64;
        assert!(
            (avg - mu_rs(k, m)).abs() < 1e-9,
            "({k},{m}) {r}x{n}: μ {avg} vs closed-form {}",
            mu_rs(k, m)
        );
    }
}

#[test]
fn baseline_invariants_over_random_configs() {
    for (i, (k, m, r, n)) in random_rs_configs(12, 0xbade).into_iter().enumerate() {
        let code = CodeSpec::Rs { k, m };
        let cluster = ClusterSpec::new(r, n);
        if cluster.node_count() < k + m + 1 {
            continue;
        }
        let policies: Vec<Box<dyn Placement>> = vec![
            Box::new(RddPlacement::new(code, cluster, i as u64)),
            Box::new(RddPlacement::uniform(code, cluster, i as u64)),
            Box::new(HddPlacement::new(code, cluster, i as u32)),
        ];
        for p in &policies {
            for sid in 0..80u64 {
                let sp = p.stripe(sid);
                assert!(sp.nodes_distinct(), "{} ({k},{m}) {r}x{n}", p.name());
                assert!(sp.rack_limit_ok(m), "{} ({k},{m}) {r}x{n}", p.name());
                let bi = sid as usize % sp.locs.len();
                let tgt = p.recovery_target(sid, bi, sp.locs[bi]);
                assert_ne!(tgt, sp.locs[bi]);
            }
        }
    }
}

#[test]
fn d3_lrc_invariants_over_random_configs() {
    let mut rng = Rng::new(0x17c);
    let mut tested = 0;
    while tested < 10 {
        let l = 1 + rng.below(3); // 1..=3
        let group = 2 + rng.below(3); // 2..=4 data per group
        let k = l * group;
        let g = 1 + rng.below(2); // 1..=2
        let ng = k + l + g;
        let ng_lrc = (group + 1).max(l + g);
        let n_candidates: Vec<usize> =
            (2..=9).filter(|&n| d3ec::oa::max_columns(n) >= ng_lrc).collect();
        let r_candidates: Vec<usize> =
            (ng + 1..=17).filter(|&r| d3ec::oa::max_columns(r) >= ng + 1).collect();
        if n_candidates.is_empty() || r_candidates.is_empty() {
            continue;
        }
        let n = *rng.choose(&n_candidates);
        let r = *rng.choose(&r_candidates);
        let code = CodeSpec::Lrc { k, l, g };
        let p = D3LrcPlacement::new(code, ClusterSpec::new(r, n)).expect("valid config");
        for sid in 0..(p.region_size() as u64) {
            let sp = p.stripe(sid);
            assert!(sp.rack_limit_ok(1), "({k},{l},{g}) {r}x{n}: >1 block/rack");
            for (bi, &loc) in sp.locs.iter().enumerate() {
                let tgt = p.recovery_target(sid, bi, loc);
                // §5.2: recovered block goes to a rack the stripe does not occupy
                assert!(sp.locs.iter().all(|ll| ll.rack != tgt.rack));
            }
            // typed repair plans read the minimal set
            let plan = plan_repair(&p, sid, 0, 0);
            assert_eq!(plan.blocks_read(), group, "data repair reads k/l");
            let plan_g = plan_repair(&p, sid, k + l, 0);
            assert_eq!(plan_g.blocks_read(), l + g - 1, "global repair reads l+g-1");
        }
        tested += 1;
    }
}

/// Randomized unaligned-window property for the lane-dispatched kernels
/// (DESIGN.md §12): for random (offset, length) windows into a shared
/// buffer — misaligning the AVX2/NEON/SWAR vector widths on both ends —
/// every runnable lane's fused combine must match the per-byte `gf::mul`
/// reference, and every byte outside the window must be untouched.
#[test]
fn fused_combine_handles_random_unaligned_windows_on_every_lane() {
    use d3ec::gf;
    use d3ec::gf::dispatch;
    use d3ec::gf::kernel::combine_many_into_lane;

    let n = 9001;
    let mut rng = Rng::new(0xd3);
    let base: Vec<u8> = (0..n).map(|_| (rng.next_u64() >> 17) as u8).collect();
    let srcs: Vec<Vec<u8>> =
        (0..3).map(|_| (0..n).map(|_| (rng.next_u64() >> 9) as u8).collect()).collect();
    for lane in dispatch::available_lanes() {
        for case in 0..60u32 {
            let off = rng.below(n - 1);
            let len = rng.below(n - off);
            let coeffs = [
                (rng.next_u64() % 4 == 0) as u8, // mix 0/1 in
                (rng.next_u64() & 0xff) as u8,
                0x8e,
            ];
            let mut acc = base.clone();
            let mut want = base.clone();
            for (&c, src) in coeffs.iter().zip(&srcs) {
                for (w, &s) in want[off..off + len].iter_mut().zip(&src[off..off + len]) {
                    *w ^= gf::mul(c, s);
                }
            }
            let pairs: Vec<(u8, &[u8])> =
                coeffs.iter().zip(&srcs).map(|(&c, s)| (c, &s[off..off + len])).collect();
            combine_many_into_lane(lane, &mut acc[off..off + len], &pairs);
            assert_eq!(acc, want, "lane={lane:?} case={case} off={off} len={len}");
        }
    }
}
