//! Adversarial protocol sweep (DESIGN.md §14, a satellite of the chaos
//! fabric): a canonical corpus covering every `Msg` and `Reply` shape is
//! subjected to exhaustive truncation, a seeded bit-flip sweep, and
//! hostile length prefixes. The decoder must never panic; any body it
//! does accept must re-encode byte-identically (the encoding is
//! canonical — fixed-width integers, length-prefixed strings, no
//! trailing slack); and the frame layer must reject every single-bit
//! wire mutation through its FNV integrity trailer.

use d3ec::net::proto::{self, Msg, PlanSource, Reply, MAX_FRAME};
use d3ec::util::Rng;

fn msg_corpus() -> Vec<Msg> {
    vec![
        Msg::Heartbeat,
        Msg::Join,
        Msg::Drain,
        Msg::Fail,
        Msg::WriteBlock { sid: 7, block: 3, bytes: vec![0xa5; 24] },
        Msg::FetchBlock { sid: u64::MAX, block: 11 },
        Msg::FetchChunk { sid: 9, block: 0, off: 1 << 40, len: 4096 },
        Msg::RemoveBlock { sid: 1, block: 2 },
        Msg::ListBlocks,
        Msg::Encode { k: 3, rows: vec![1, 2, 3, 4, 5, 6], shard_len: 2, shards: vec![9; 6] },
        Msg::RecoverPlan {
            sid: 42,
            block: 4,
            block_len: 65536,
            sources: vec![
                PlanSource { coeff: 0x1d, block: 0, addr: "127.0.0.1:4000".into() },
                PlanSource { coeff: 1, block: 2, addr: "127.0.0.1:4001".into() },
            ],
        },
        Msg::HashBlock { sid: 8, block: 4 },
    ]
}

fn reply_corpus() -> Vec<Reply> {
    vec![
        Reply::Ok,
        Reply::Err("node N1,2 is failed".into()),
        Reply::Data(vec![0xab; 40]),
        Reply::Blocks(vec![(0, 1), (9, 4), (u64::MAX, u32::MAX)]),
        Reply::Beat { state: 1, blocks: 12 },
        Reply::Sum(0xdead_beef_cafe),
    ]
}

#[test]
fn corpus_roundtrips() {
    for m in msg_corpus() {
        assert_eq!(Msg::decode(&m.encode()).unwrap(), m);
    }
    for r in reply_corpus() {
        assert_eq!(Reply::decode(&r.encode()).unwrap(), r);
    }
}

#[test]
fn every_truncation_errs_or_reencodes_identically() {
    for m in msg_corpus() {
        let body = m.encode();
        for cut in 0..body.len() {
            if let Ok(decoded) = Msg::decode(&body[..cut]) {
                assert_eq!(
                    decoded.encode(),
                    &body[..cut],
                    "{m:?} truncated to {cut} bytes decoded non-canonically"
                );
            }
        }
    }
    for r in reply_corpus() {
        let body = r.encode();
        for cut in 0..body.len() {
            if let Ok(decoded) = Reply::decode(&body[..cut]) {
                assert_eq!(
                    decoded.encode(),
                    &body[..cut],
                    "{r:?} truncated to {cut} bytes decoded non-canonically"
                );
            }
        }
    }
}

#[test]
fn seeded_bit_flips_never_panic_and_accepted_bodies_are_canonical() {
    let mut rng = Rng::keyed(0xd3, 0xfa117, 0);
    for m in msg_corpus() {
        let body = m.encode();
        for _ in 0..256 {
            let bit = rng.below(body.len() * 8);
            let mut mutated = body.clone();
            mutated[bit / 8] ^= 1 << (bit % 8);
            if let Ok(decoded) = Msg::decode(&mutated) {
                // a flipped body may still be a VALID message (e.g. a bit
                // of `sid` changed) — but then it must be that message's
                // canonical encoding, never a sloppy parse
                assert_eq!(decoded.encode(), mutated, "non-canonical accept of a mutation");
            }
        }
    }
    for r in reply_corpus() {
        let body = r.encode();
        for _ in 0..256 {
            let bit = rng.below(body.len() * 8);
            let mut mutated = body.clone();
            mutated[bit / 8] ^= 1 << (bit % 8);
            if let Ok(decoded) = Reply::decode(&mutated) {
                assert_eq!(decoded.encode(), mutated, "non-canonical accept of a mutation");
            }
        }
    }
}

#[test]
fn frame_integrity_rejects_every_seeded_wire_flip() {
    // at the WIRE level nothing mutated may get through: a flip in the
    // length prefix misframes the trailer, a flip in body or trailer
    // fails the FNV check — this is what turns injected corruption into
    // a clean connection error instead of silent data poisoning
    let mut rng = Rng::keyed(0xd3, 0xf1a6, 1);
    for m in msg_corpus() {
        let body = m.encode();
        let mut wire = Vec::new();
        proto::write_frame(&mut wire, &body).unwrap();
        for _ in 0..128 {
            let bit = rng.below(wire.len() * 8);
            let mut bad = wire.clone();
            bad[bit / 8] ^= 1 << (bit % 8);
            let mut r = &bad[..];
            assert!(
                proto::read_frame(&mut r).is_err(),
                "{m:?}: single-bit wire flip at bit {bit} slipped through framing"
            );
        }
        let mut r = &wire[..];
        assert_eq!(proto::read_frame(&mut r).unwrap(), body, "pristine frame must read back");
    }
}

#[test]
fn hostile_length_prefixes_never_panic_or_overallocate() {
    for claimed in [MAX_FRAME as u64 + 1, u32::MAX as u64, 1 << 31] {
        let mut wire = (claimed as u32).to_le_bytes().to_vec();
        wire.extend_from_slice(&[0u8; 16]);
        let mut r = &wire[..];
        assert!(proto::read_frame(&mut r).is_err(), "length {claimed} accepted");
    }
    // a frame that claims more bytes than the stream holds
    let mut wire = 100u32.to_le_bytes().to_vec();
    wire.extend_from_slice(&[0u8; 10]);
    let mut r = &wire[..];
    assert!(proto::read_frame(&mut r).is_err());
}

#[test]
fn adversarial_source_count_errs_without_allocating() {
    // a RecoverPlan body claiming u32::MAX sources must fail at the
    // first missing source, not reserve gigabytes up front
    let mut body = vec![0x0bu8]; // TAG_RECOVER_PLAN
    body.extend_from_slice(&7u64.to_le_bytes());
    body.extend_from_slice(&1u32.to_le_bytes());
    body.extend_from_slice(&65536u32.to_le_bytes());
    body.extend_from_slice(&u32::MAX.to_le_bytes());
    assert!(Msg::decode(&body).is_err());
    // same for a Blocks reply with a hostile count
    let mut body = vec![0x83u8]; // TAG_BLOCKS
    body.extend_from_slice(&u32::MAX.to_le_bytes());
    assert!(Reply::decode(&body).is_err());
}

#[test]
fn random_garbage_never_panics() {
    let mut rng = Rng::keyed(0xd3, 0x6a5ba6e, 2);
    for len in 0..96usize {
        let mut buf = vec![0u8; len];
        for b in buf.iter_mut() {
            *b = rng.below(256) as u8;
        }
        if let Ok(decoded) = Msg::decode(&buf) {
            assert_eq!(decoded.encode(), buf);
        }
        if let Ok(decoded) = Reply::decode(&buf) {
            assert_eq!(decoded.encode(), buf);
        }
    }
}
