//! Chaos-fabric integration (DESIGN.md §14): recovery under injected
//! faults. Frame-level chaos (drop / delay / corrupt / truncate) must
//! not change WHAT gets moved — only how long it takes — so a fault run
//! is byte-identical to a fault-free run; same-seed runs reproduce the
//! same injection counters; a worker crash mid-recovery is detected by
//! the heartbeat sweep, the lost blocks are re-planned, and everything
//! still matches the populate oracle; the scrub pass finds and repairs
//! latent storage corruption on both physical fabrics; and trace-driven
//! failure arrivals produce identical counters on the fluid simulator
//! and the physical fabrics.
//!
//! The `net_`-prefixed tests are the loopback-socket suite CI runs under
//! a hard timeout (`cargo test --test chaos_fabric net_`).

use std::sync::Arc;

use d3ec::cluster::fabric::{crash_victim, recover_with_replan, run_scrub};
use d3ec::cluster::{deterministic_data, BlockFabric, MiniCluster};
use d3ec::codes::CodeSpec;
use d3ec::net::chaos::FaultSpec;
use d3ec::net::{NetCluster, NetClusterBackend};
use d3ec::placement::{D3Placement, Placement};
use d3ec::recovery::{scenario_recovery_plans, ExecutorConfig};
use d3ec::scenario::trace::{run_trace, run_trace_sim, TraceSpec};
use d3ec::scenario::{FailureScenario, RecoveryBackend};
use d3ec::sim::recovery::RecoveryConfig;
use d3ec::topology::{Location, SystemSpec};

fn fast_spec() -> SystemSpec {
    let mut spec = SystemSpec::paper_default();
    spec.block_size = 16 << 10;
    spec.net.inner_mbps = 8000.0;
    spec.net.cross_mbps = 1600.0;
    spec
}

fn d3_policy(spec: &SystemSpec) -> Arc<dyn Placement> {
    let code = CodeSpec::Rs { k: 3, m: 2 };
    Arc::new(D3Placement::new(code, spec.cluster).unwrap())
}

fn cfg() -> ExecutorConfig {
    ExecutorConfig { workers: 4, ..ExecutorConfig::default() }
}

/// Every live replica must match its write-time checksum — the oracle
/// registered at populate, before any fault was armed.
fn assert_oracle_clean<F: BlockFabric>(fabric: &F, stripes: u64, dead: &[Location]) {
    let code_len = fabric.code().len();
    let mut verified = 0u64;
    for sid in 0..stripes {
        for b in 0..code_len {
            let loc = fabric.locate(sid, b);
            assert!(
                !dead.contains(&loc),
                "stripe {sid} block {b} still homed on dead node {loc}"
            );
            let want = fabric.expected_checksum(sid, b).expect("missing registry entry");
            let got = fabric.stored_checksum(sid, b).expect("unreadable replica");
            assert_eq!(got, want, "stripe {sid} block {b} fails the oracle check");
            verified += 1;
        }
    }
    assert_eq!(verified, stripes * code_len as u64);
}

#[test]
fn net_same_seed_fault_counters_are_deterministic() {
    let spec = fast_spec();
    let p = d3_policy(&spec);
    let scenario = FailureScenario::single_node(40, 2);
    let backend = NetClusterBackend {
        block_size: 16 << 10,
        faults: Some(FaultSpec::uniform(0.05, 42)),
        ..NetClusterBackend::default()
    };
    let a = backend.run(&scenario, &p, &spec).unwrap();
    let b = backend.run(&scenario, &p, &spec).unwrap();
    let (fa, fb) = (a.faults.expect("no fault report"), b.faults.expect("no fault report"));
    assert!(fa.total_injected() > 0, "5% chaos injected nothing over a full recovery");
    // the injection stream is content-keyed, so identical seeds reproduce
    // identical counters regardless of thread timing (failovers/replans
    // are detector-path counters and excluded from this contract)
    assert_eq!(fa.drops, fb.drops);
    assert_eq!(fa.delays, fb.delays);
    assert_eq!(fa.corrupts, fb.corrupts);
    assert_eq!(fa.truncates, fb.truncates);
    assert_eq!(fa.retries, fb.retries);
    assert_eq!(fa.evictions, fb.evictions);
    assert_eq!(fa.crashes, fb.crashes);
    // a different seed draws a different stream
    let other = NetClusterBackend {
        block_size: 16 << 10,
        faults: Some(FaultSpec::uniform(0.05, 43)),
        ..NetClusterBackend::default()
    };
    let c = other.run(&scenario, &p, &spec).unwrap();
    let fc = c.faults.unwrap();
    assert_ne!(
        (fa.drops, fa.delays, fa.corrupts, fa.truncates),
        (fc.drops, fc.delays, fc.corrupts, fc.truncates),
        "different chaos seeds drew identical injection streams"
    );
}

#[test]
fn net_chaos_parity_fault_run_matches_fault_free_bytes() {
    // the chaos-parity acceptance: drop/delay/corrupt/truncate at 5%
    // change retry counts and wall time, NEVER the byte accounting —
    // transfers are charged exactly once, on success
    let spec = fast_spec();
    let p = d3_policy(&spec);
    let scenario = FailureScenario::single_node(40, 2);
    let clean = NetClusterBackend { block_size: 16 << 10, ..NetClusterBackend::default() };
    let chaotic = NetClusterBackend {
        block_size: 16 << 10,
        faults: Some(FaultSpec::uniform(0.05, 42)),
        ..NetClusterBackend::default()
    };
    let a = clean.run(&scenario, &p, &spec).unwrap();
    let b = chaotic.run(&scenario, &p, &spec).unwrap();
    assert!(b.faults.unwrap().total_injected() > 0);
    assert_eq!(a.blocks, b.blocks, "chaos changed the rebuilt block count");
    assert_eq!(
        a.rack_cross_bytes, b.rack_cross_bytes,
        "injected faults leaked into the byte accounting"
    );
}

#[test]
fn net_crash_mid_recovery_is_detected_replanned_and_oracle_clean() {
    // tentpole acceptance: the busiest repair writer crashes mid-recovery
    // (stops heartbeating), the coordinator's sweep escalates it to
    // Failed, its blocks are re-planned onto survivors, and every block
    // in the system still matches the populate oracle
    let spec = fast_spec();
    let p = d3_policy(&spec);
    let stripes = 40u64;
    let net = NetCluster::new(spec, p.clone(), 9).unwrap();
    net.write_stripes_parallel(stripes, 4, |sid| {
        deterministic_data(sid, 3, spec.block_size as usize)
    })
    .unwrap();
    let failed = vec![Location::new(0, 0)];
    BlockFabric::fail_node(&net, failed[0]);
    let plans = scenario_recovery_plans(p.as_ref(), stripes, &failed, 9).unwrap();
    assert!(!plans.is_empty());
    net.arm_chaos(FaultSpec { crash_after_rpcs: Some(10), seed: 9, ..FaultSpec::default() });
    let victim = crash_victim(&plans, &failed).expect("no live writer to crash");
    assert!(!failed.contains(&victim));
    BlockFabric::arm_crash_victim(&net, victim);
    let (stats, replan) =
        recover_with_replan(&net, p.as_ref(), stripes, failed.clone(), plans, cfg(), 9, 4)
            .expect("recovery must survive the crash");
    assert!(stats.blocks > 0);
    assert!(replan.rounds >= 2, "crash should have forced a second round");
    assert!(replan.detected >= 1, "the crashed worker was never detected");
    assert!(replan.replanned > 0, "no blocks were re-planned after the failover");
    let report = BlockFabric::fault_report(&net).expect("chaos armed but no report");
    assert!(report.crashes >= 1, "the armed crash never fired");
    assert!(report.failovers >= 1, "the heartbeat sweep never escalated the worker");
    // the membership view agrees
    let dead = BlockFabric::failed_nodes(&net);
    assert!(dead.contains(&victim), "victim not in the failed set");
    assert_oracle_clean(&net, stripes, &dead);
}

fn scrub_finds_and_repairs<F: BlockFabric>(fabric: &F, policy: &dyn Placement, stripes: u64) {
    // three latent corruptions, two of them in the SAME stripe — the
    // case that must go through the multi-erasure planner, because each
    // corrupt block would otherwise be a repair source for the other
    let planted = [(2u64, 0usize), (2, 1), (7, 4)];
    for &(sid, b) in &planted {
        fabric.corrupt_stored(sid, b).unwrap();
        assert_ne!(
            fabric.stored_checksum(sid, b).unwrap(),
            fabric.expected_checksum(sid, b).unwrap(),
            "corruption did not take"
        );
    }
    let report = run_scrub(fabric, policy, stripes, cfg(), 3).unwrap();
    assert_eq!(report.scanned, stripes * fabric.code().len() as u64);
    assert_eq!(report.quarantined, planted.len() as u64);
    assert_eq!(report.repaired, planted.len() as u64);
    assert_oracle_clean(fabric, stripes, &[]);
    // a second pass over the repaired system is clean
    let again = run_scrub(fabric, policy, stripes, cfg(), 3).unwrap();
    assert_eq!(again.quarantined, 0, "scrub re-quarantined a repaired block");
}

#[test]
fn scrub_quarantines_and_repairs_on_the_minicluster() {
    let spec = fast_spec();
    let p = d3_policy(&spec);
    let stripes = 20u64;
    let mini = MiniCluster::new(spec, p.clone(), "native", 3).unwrap();
    mini.write_stripes_parallel(stripes, 4, |sid| {
        deterministic_data(sid, 3, spec.block_size as usize)
    })
    .unwrap();
    scrub_finds_and_repairs(&mini, p.as_ref(), stripes);
}

#[test]
fn net_scrub_quarantines_and_repairs() {
    let spec = fast_spec();
    let p = d3_policy(&spec);
    let stripes = 20u64;
    let net = NetCluster::new(spec, p.clone(), 3).unwrap();
    net.write_stripes_parallel(stripes, 4, |sid| {
        deterministic_data(sid, 3, spec.block_size as usize)
    })
    .unwrap();
    scrub_finds_and_repairs(&net, p.as_ref(), stripes);
}

#[test]
fn net_silent_worker_is_escalated_by_the_heartbeat_sweep() {
    let spec = fast_spec();
    let p = d3_policy(&spec);
    let net = NetCluster::new(spec, p.clone(), 5).unwrap();
    net.write_stripes_parallel(8, 4, |sid| {
        deterministic_data(sid, 3, spec.block_size as usize)
    })
    .unwrap();
    let silent = Location::new(4, 1);
    net.crash_worker(silent);
    let found = BlockFabric::detect_failures(&net);
    assert_eq!(found, vec![silent], "sweep missed the silent worker");
    assert!(BlockFabric::failed_nodes(&net).contains(&silent));
    // a second sweep reports nothing new
    assert!(BlockFabric::detect_failures(&net).is_empty());
}

/// A deterministic four-event trace whose modeled repair rate is slow
/// enough that the second and third failures batch into one round.
fn batching_trace() -> TraceSpec {
    TraceSpec {
        horizon_s: 4000.0,
        repair_mb_s: 0.0001,
        events: Some(vec![
            (0.0, Location::new(0, 0)),
            (1.0, Location::new(3, 1)),
            (2.0, Location::new(5, 2)),
            (2000.0, Location::new(0, 0)),
        ]),
        ..TraceSpec::default()
    }
}

#[test]
fn trace_counters_agree_between_sim_and_minicluster() {
    let spec = fast_spec();
    let p = d3_policy(&spec);
    let stripes = 24u64;
    let tspec = batching_trace();
    let sim = run_trace_sim(
        &spec,
        p.as_ref(),
        stripes,
        &tspec,
        RecoveryConfig { workers: 4, ..RecoveryConfig::default() },
        7,
    )
    .unwrap();
    let mini = MiniCluster::new(spec, p.clone(), "native", 7).unwrap();
    mini.write_stripes_parallel(stripes, 4, |sid| {
        deterministic_data(sid, 3, spec.block_size as usize)
    })
    .unwrap();
    let phys = run_trace(&mini, p.as_ref(), stripes, &tspec, cfg(), 7).unwrap();
    assert_eq!(sim.failures, 4);
    assert_eq!(sim.failures, phys.failures);
    assert_eq!(sim.rounds, phys.rounds, "backends batched events differently");
    assert_eq!(sim.blocks_repaired, phys.blocks_repaired);
    assert_eq!(sim.lost_stripes, phys.lost_stripes);
    assert_eq!(sim.backlog_peak, phys.backlog_peak);
    assert_eq!(sim.lost_stripes, 0, "a ≤2-failure batch lost a stripe under rs-3-2");
    assert!(sim.rounds >= 2 && sim.rounds < sim.failures, "no batching happened");
    assert!(sim.blocks_repaired > 0);
    assert!(sim.sustained_mb_s > 0.0 && phys.sustained_mb_s > 0.0);
    assert!(sim.arrival_mb_s > 0.0);
    // after the last rejoin the layout is canonical and oracle-clean
    assert_oracle_clean(&mini, stripes, &[]);
}

#[test]
fn net_trace_counters_match_the_sim_twin() {
    let spec = fast_spec();
    let p = d3_policy(&spec);
    let stripes = 16u64;
    let tspec = batching_trace();
    let sim = run_trace_sim(
        &spec,
        p.as_ref(),
        stripes,
        &tspec,
        RecoveryConfig { workers: 4, ..RecoveryConfig::default() },
        7,
    )
    .unwrap();
    let net = NetCluster::new(spec, p.clone(), 7).unwrap();
    net.write_stripes_parallel(stripes, 4, |sid| {
        deterministic_data(sid, 3, spec.block_size as usize)
    })
    .unwrap();
    let phys = run_trace(&net, p.as_ref(), stripes, &tspec, cfg(), 7).unwrap();
    assert_eq!(sim.failures, phys.failures);
    assert_eq!(sim.rounds, phys.rounds);
    assert_eq!(sim.blocks_repaired, phys.blocks_repaired);
    assert_eq!(sim.lost_stripes, phys.lost_stripes);
    assert_eq!(sim.backlog_peak, phys.backlog_peak);
    assert!(phys.sustained_mb_s > 0.0);
    assert_oracle_clean(&net, stripes, &[]);
}
