//! Whole-stack integration: mini-HDFS + D³ placement + PJRT coding.
//! Real bytes flow write → fail → recover → verify through every layer:
//! L3 planning/orchestration, the throttled network, and the AOT-compiled
//! L1/L2 GF kernels via PJRT.

use std::sync::Arc;

use d3ec::cluster::MiniCluster;
use d3ec::codes::CodeSpec;
use d3ec::placement::{D3LrcPlacement, D3Placement, RddPlacement};
use d3ec::runtime::default_artifacts_dir;
use d3ec::topology::{Location, SystemSpec};

fn backend() -> &'static str {
    if default_artifacts_dir().join("manifest.json").exists() {
        "pjrt"
    } else {
        eprintln!("WARN: artifacts missing — exercising the native backend only");
        "native"
    }
}

fn small_spec(block: usize) -> SystemSpec {
    let mut s = SystemSpec::paper_default();
    s.block_size = block as u64;
    s.net.inner_mbps = 8000.0;
    s.net.cross_mbps = 1600.0;
    s
}

fn stripe_data(sid: u64, k: usize, len: usize) -> Vec<Vec<u8>> {
    (0..k)
        .map(|b| {
            let mut v = vec![0u8; len];
            let mut s = sid.wrapping_mul(0x9e3779b9).wrapping_add(b as u64) | 1;
            for byte in v.iter_mut() {
                s ^= s << 13;
                s ^= s >> 7;
                s ^= s << 17;
                *byte = (s >> 24) as u8;
            }
            v
        })
        .collect()
}

#[test]
fn d3_rs_full_lifecycle_through_pjrt() {
    let spec = small_spec(64 * 1024);
    let policy = Arc::new(D3Placement::new(CodeSpec::Rs { k: 3, m: 2 }, spec.cluster).unwrap());
    let cluster = MiniCluster::new(spec, policy, backend(), 11).unwrap();
    let stripes = 20u64;
    let mut originals = Vec::new();
    for sid in 0..stripes {
        let d = stripe_data(sid, 3, 64 * 1024);
        cluster.write_stripe(sid, d.clone()).unwrap();
        originals.push(d);
    }
    let failed = Location::new(0, 1);
    cluster.fail_node(failed);
    let stats = cluster.recover_node(failed, stripes, 6).unwrap();
    assert!(stats.blocks > 0, "failed node held no blocks");
    // every data block of every stripe must read back bit-identical
    let client = Location::new(7, 2);
    for sid in 0..stripes {
        for b in 0..3 {
            let got = cluster.read_block(sid, b, client).unwrap();
            assert_eq!(got, originals[sid as usize][b], "stripe {sid} block {b}");
        }
    }
}

#[test]
fn d3_lrc_full_lifecycle_through_pjrt() {
    let spec = small_spec(32 * 1024);
    let policy =
        Arc::new(D3LrcPlacement::new(CodeSpec::Lrc { k: 4, l: 2, g: 1 }, spec.cluster).unwrap());
    let cluster = MiniCluster::new(spec, policy, backend(), 5).unwrap();
    let stripes = 18u64;
    let mut originals = Vec::new();
    for sid in 0..stripes {
        let d = stripe_data(sid, 4, 32 * 1024);
        cluster.write_stripe(sid, d.clone()).unwrap();
        originals.push(d);
    }
    let failed = Location::new(3, 0);
    cluster.fail_node(failed);
    let stats = cluster.recover_node(failed, stripes, 6).unwrap();
    let client = Location::new(6, 1);
    for sid in 0..stripes {
        for b in 0..4 {
            let got = cluster.read_block(sid, b, client).unwrap();
            assert_eq!(got, originals[sid as usize][b], "stripe {sid} block {b}");
        }
    }
    let _ = stats;
}

#[test]
fn degraded_read_under_pjrt_matches_original() {
    let spec = small_spec(128 * 1024);
    let policy = Arc::new(D3Placement::new(CodeSpec::Rs { k: 6, m: 3 }, spec.cluster).unwrap());
    let cluster = MiniCluster::new(spec, policy, backend(), 2).unwrap();
    let d = stripe_data(3, 6, 128 * 1024);
    cluster.write_stripe(3, d.clone()).unwrap();
    let victim = cluster.locate(3, 4);
    cluster.fail_node(victim);
    let (got, latency) = cluster.degraded_read(3, 4, Location::new(5, 2)).unwrap();
    assert_eq!(got, d[4]);
    assert!(latency.as_secs_f64() < 30.0);
}

#[test]
fn rdd_baseline_recovers_correctly_too() {
    // baselines share the same data path — correctness must hold there as well
    let spec = small_spec(32 * 1024);
    let policy = Arc::new(RddPlacement::new(CodeSpec::Rs { k: 3, m: 2 }, spec.cluster, 9));
    let cluster = MiniCluster::new(spec, policy, backend(), 9).unwrap();
    let stripes = 15u64;
    let mut originals = Vec::new();
    for sid in 0..stripes {
        let d = stripe_data(sid, 3, 32 * 1024);
        cluster.write_stripe(sid, d.clone()).unwrap();
        originals.push(d);
    }
    let failed = Location::new(4, 2);
    cluster.fail_node(failed);
    cluster.recover_node(failed, stripes, 4).unwrap();
    let client = Location::new(0, 0);
    for sid in 0..stripes {
        for b in 0..3 {
            assert_eq!(
                cluster.read_block(sid, b, client).unwrap(),
                originals[sid as usize][b]
            );
        }
    }
}
