//! Client-engine integration (DESIGN.md §11): the QoS-aware foreground
//! path is ONE implementation across backends — identical generated
//! request sequences, cross-backend served-count agreement, byte-exact
//! equivalence of the `recovery_share = 1.0` data path with plain
//! recovery, and the acceptance property: throttling recovery improves
//! foreground tail latency while recovery still completes bit-exact.

use std::sync::atomic::AtomicBool;
use std::sync::Arc;

use d3ec::client::{ArrivalModel, FgSpec, QosConfig};
use d3ec::cluster::{ClusterBackend, MiniCluster};
use d3ec::codes::CodeSpec;
use d3ec::placement::{D3Placement, Placement};
use d3ec::recovery::{node_recovery_plans, ExecutorConfig};
use d3ec::scenario::{FailureScenario, RecoveryBackend};
use d3ec::sim::SimBackend;
use d3ec::topology::{Location, SystemSpec};

fn policy(spec: &SystemSpec) -> Arc<dyn Placement> {
    Arc::new(D3Placement::new(CodeSpec::Rs { k: 3, m: 2 }, spec.cluster).unwrap())
}

fn data_for(sid: u64, k: usize, len: usize) -> Vec<Vec<u8>> {
    (0..k)
        .map(|b| {
            let mut v = vec![0u8; len];
            let mut s = sid.wrapping_mul(97).wrapping_add(b as u64) | 1;
            for byte in v.iter_mut() {
                s ^= s << 13;
                s ^= s >> 7;
                s ^= s << 17;
                *byte = (s >> 24) as u8;
            }
            v
        })
        .collect()
}

#[test]
fn both_backends_serve_the_identical_generated_sequence() {
    let spec = SystemSpec::paper_default();
    let p = policy(&spec);
    let scenario = FailureScenario::frontend_mix("grep", 30, 5);
    // the sequence itself is backend-free and reproducible
    let (fgspec, reqs) = scenario.fg_requests(&p).unwrap().expect("mix has fg");
    assert_eq!(reqs, scenario.fg_requests(&p).unwrap().unwrap().1);
    assert_eq!(reqs.len(), fgspec.requests);

    let sim = SimBackend::default();
    let cluster = ClusterBackend { block_size: 16 << 10, ..ClusterBackend::default() };
    let s = sim.run(&scenario, &p, &spec).unwrap();
    let c = cluster.run(&scenario, &p, &spec).unwrap();
    // every generated request was served, on both backends
    let sl = s.fg_latency.as_ref().expect("sim fg latency");
    let cl = c.fg_latency.as_ref().expect("cluster fg latency");
    assert_eq!(sl.count, reqs.len(), "sim dropped requests");
    assert_eq!(cl.count, reqs.len(), "cluster dropped requests");
    assert!(sl.p50 <= sl.p99 && sl.p99 <= sl.max);
    assert!(cl.p50 <= cl.p99 && cl.p99 <= cl.max);
    assert!(s.frontend_seconds.unwrap() > 0.0);
    assert!(c.frontend_seconds.unwrap() > 0.0);
    // both executed the same recovery plans alongside
    assert_eq!(s.blocks, c.blocks);
    assert_eq!(s.planned_cross_rack_blocks, c.planned_cross_rack_blocks);
    // the interference factor is measured on both backends; the fluid
    // backend's is deterministic (sharing can only slow recovery)
    assert!(s.recovery_slowdown.unwrap() >= 1.0 - 1e-9);
    assert!(c.recovery_slowdown.unwrap() > 0.0);
}

#[test]
fn mixed_load_on_any_kind_reports_fg_latency() {
    // with_fg generalizes FrontendMix/DegradedBurst: a rack failure with
    // an open-loop read stream is a first-class mixed-load scenario
    let spec = SystemSpec::paper_default();
    let p = policy(&spec);
    let scenario = FailureScenario::rack_failure(1, 24, 3)
        .with_fg(FgSpec::reads(16, ArrivalModel::Open { rate_rps: 200.0 }))
        .with_qos(QosConfig { recovery_share: 0.5, fg_weight: 1.0 });
    let out = SimBackend::default().run(&scenario, &p, &spec).unwrap();
    let fg = out.fg_latency.expect("fg latency on mixed rack failure");
    assert_eq!(fg.count, 16);
    assert!(out.recovery_slowdown.is_some());
    assert!(out.blocks > 0, "recovery still rebuilt the rack");
}

#[test]
fn full_share_reproduces_plain_recovery_byte_accounting_exactly() {
    // recovery_share = 1.0 must leave the recovery data path byte-for-byte
    // identical to the pre-QoS executor (PR 4): same plans, same config,
    // same rack byte accounting, whether or not the QoS runtime is
    // installed.
    let mut spec = SystemSpec::paper_default();
    spec.block_size = 32 << 10;
    spec.net.inner_mbps = 8000.0;
    spec.net.cross_mbps = 1600.0;
    let stripes = 24u64;
    let failed = Location::new(2, 1);
    let run = |with_qos: bool| -> Vec<(u64, u64)> {
        let p = policy(&spec);
        let cluster = MiniCluster::new(spec, p.clone(), "native", 5).unwrap();
        cluster
            .write_stripes_parallel(stripes, 4, |sid| data_for(sid, 3, 32 << 10))
            .unwrap();
        cluster.fail_node(failed);
        if with_qos {
            let flag = Arc::new(AtomicBool::new(true));
            cluster.set_qos(
                QosConfig { recovery_share: 1.0, fg_weight: 1.0 },
                flag,
            );
        }
        let plans = node_recovery_plans(p.as_ref(), stripes, failed, 5);
        let cfg = ExecutorConfig { workers: 4, chunk_size: 8 << 10, ..Default::default() };
        let stats = cluster.recover_with_plans_cfg(plans, cfg, &[failed.rack]).unwrap();
        if with_qos {
            cluster.clear_qos();
        }
        stats.rack_bytes
    };
    let plain = run(false);
    let qos = run(true);
    assert_eq!(plain, qos, "share=1.0 changed the byte accounting");
    assert!(plain.iter().any(|&(u, d)| u + d > 0), "no cross-rack traffic?");
}

#[test]
fn qos_split_improves_fg_p99_and_recovery_stays_bit_exact() {
    // The acceptance property: under mixed load on contended links, a
    // recovery_share < 1.0 improves foreground p99 versus the unthrottled
    // run, while recovery still completes and every rebuilt block is
    // bit-identical to the original data.
    let mut spec = SystemSpec::paper_default();
    spec.cluster = d3ec::topology::ClusterSpec::new(4, 4);
    spec.block_size = 64 << 10;
    spec.net.inner_mbps = 1600.0;
    spec.net.cross_mbps = 160.0; // scarce 20 MB/s rack ports
    let stripes = 60u64;
    let fg_spec = FgSpec::reads(120, ArrivalModel::Closed { clients: 6, think_s: 0.0 });
    let run = |qos: QosConfig| -> (f64, f64) {
        let p = policy(&spec);
        let cluster = MiniCluster::new(spec, p.clone(), "native", 7).unwrap();
        cluster
            .write_stripes_parallel(stripes, 8, |sid| data_for(sid, 3, 64 << 10))
            .unwrap();
        // a failed node that holds blocks (the period-aware scenario probe
        // guarantees this for scenario runs; mirror it here)
        let failed = (0..spec.cluster.node_count())
            .map(|i| spec.cluster.unflat(i))
            .find(|&l| (0..stripes).any(|sid| p.stripe(sid).locs.contains(&l)))
            .unwrap();
        cluster.fail_node(failed);
        let plans = node_recovery_plans(p.as_ref(), stripes, failed, 7);
        let lost: Vec<(u64, usize)> =
            plans.iter().map(|pl| (pl.stripe, pl.failed_block)).collect();
        let reqs = fg_spec.generate(&p, stripes, &[failed], 7).unwrap();
        let cfg = ExecutorConfig { workers: 8, chunk_size: 16 << 10, ..Default::default() };
        let (stats, fgout) = cluster
            .run_mixed_load(plans, cfg, &[failed.rack], &reqs, fg_spec.arrival, 8, qos)
            .unwrap();
        assert_eq!(stats.blocks, lost.len(), "recovery incomplete");
        // bit-exact: every rebuilt block matches the regenerated original
        let client_loc = (0..spec.cluster.node_count())
            .map(|i| spec.cluster.unflat(i))
            .find(|&l| l != failed)
            .unwrap();
        for (sid, b) in lost {
            let got = cluster.read_block(sid, b, client_loc).unwrap();
            if b < 3 {
                assert_eq!(got, data_for(sid, 3, 64 << 10)[b], "sid={sid} b={b}");
            }
            assert_ne!(cluster.locate(sid, b), failed);
        }
        let p99 = fgout.summary().expect("latencies").p99;
        (p99, stats.wall.as_secs_f64())
    };
    let (unthrottled_p99, _) = run(QosConfig { recovery_share: 1.0, fg_weight: 0.0 });
    let (throttled_p99, throttled_wall) =
        run(QosConfig { recovery_share: 0.2, fg_weight: 2.0 });
    assert!(
        throttled_p99 < unthrottled_p99,
        "QoS split did not improve fg p99: {throttled_p99:.4}s (share 0.2) vs \
         {unthrottled_p99:.4}s (share 1.0)"
    );
    assert!(throttled_wall > 0.0);
}

#[test]
fn degraded_burst_runs_through_the_engine_on_both_backends() {
    let spec = SystemSpec::paper_default();
    let p = policy(&spec);
    let scenario = FailureScenario::degraded_burst(10, 40, 6);
    let s = SimBackend::default().run(&scenario, &p, &spec).unwrap();
    let cluster = ClusterBackend { block_size: 16 << 10, ..ClusterBackend::default() };
    let c = cluster.run(&scenario, &p, &spec).unwrap();
    assert_eq!(s.blocks, 10);
    assert_eq!(c.blocks, 10);
    assert_eq!(s.planned_cross_rack_blocks, c.planned_cross_rack_blocks);
    for out in [&s, &c] {
        let fg = out.fg_latency.as_ref().expect("burst fg latency");
        assert_eq!(fg.count, 10);
        let mean = out.degraded_read_mean_s.expect("burst mean latency");
        assert!((mean - fg.mean).abs() < 1e-9, "mean must come from the engine");
    }
}
