//! Durability integration (DESIGN.md §15): the continuous scrub daemon
//! and the Monte-Carlo MTTDL engine. The daemon's cycle reports must be
//! a pure function of the registry on a quiet fabric — bit-identical
//! across reruns, backends, and test-thread counts; an infeasible cycle
//! deadline is reported as missed, never silently blown; a daemon
//! running beside foreground traffic must not wreck foreground tail
//! latency. Durability trials must replay exactly for a (seed, trial)
//! pair and produce identical counters on the pure model, the
//! MiniCluster, and the socket-backed NetCluster — the spot check that
//! lets the model run the big MTTDL sweeps on the physical fabrics'
//! behalf.
//!
//! The `net_`-prefixed tests are the loopback-socket suite CI runs
//! under a hard timeout.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Instant;

use d3ec::cluster::links::TrafficClass;
use d3ec::cluster::{deterministic_data, BlockFabric, MiniCluster};
use d3ec::codes::CodeSpec;
use d3ec::metrics::summarize;
use d3ec::net::NetCluster;
use d3ec::placement::{D3Placement, Placement};
use d3ec::recovery::ExecutorConfig;
use d3ec::scenario::durability::{
    estimate_mttdl, run_durability_trial, run_durability_trial_model, run_matrix,
    DurabilitySpec,
};
use d3ec::scenario::trace::TraceSummary;
use d3ec::scrub::{run_daemon, DaemonReport, ScrubConfig};
use d3ec::topology::{Location, SystemSpec};

fn fast_spec() -> SystemSpec {
    let mut spec = SystemSpec::paper_default();
    spec.block_size = 16 << 10;
    spec.net.inner_mbps = 8000.0;
    spec.net.cross_mbps = 1600.0;
    spec
}

fn d3_policy(spec: &SystemSpec) -> Arc<dyn Placement> {
    let code = CodeSpec::Rs { k: 3, m: 2 };
    Arc::new(D3Placement::new(code, spec.cluster).unwrap())
}

fn cfg() -> ExecutorConfig {
    ExecutorConfig { workers: 4, ..ExecutorConfig::default() }
}

/// Three latent corruptions, two in the same stripe (the multi-erasure
/// planner case), planted straight into stored replicas.
fn plant_corruption<F: BlockFabric>(fabric: &F) -> usize {
    let planted = [(2u64, 0usize), (2, 1), (7, 4)];
    for &(sid, b) in &planted {
        fabric.corrupt_stored(sid, b).unwrap();
    }
    planted.len()
}

fn populated_mini(spec: SystemSpec, p: &Arc<dyn Placement>, stripes: u64, seed: u64) -> MiniCluster {
    let mini = MiniCluster::new(spec, p.clone(), "native", seed).unwrap();
    mini.write_stripes_parallel(stripes, 4, |sid| {
        deterministic_data(sid, 3, spec.block_size as usize)
    })
    .unwrap();
    mini
}

fn populated_net(spec: SystemSpec, p: &Arc<dyn Placement>, stripes: u64, seed: u64) -> NetCluster {
    let net = NetCluster::new(spec, p.clone(), seed).unwrap();
    net.write_stripes_parallel(stripes, 4, |sid| {
        deterministic_data(sid, 3, spec.block_size as usize)
    })
    .unwrap();
    net
}

/// One daemon run: plant, scrub for two cycles, return the report.
fn daemon_pass<F: BlockFabric>(fabric: &F, p: &dyn Placement, stripes: u64) -> DaemonReport {
    let planted = plant_corruption(fabric);
    let stop = AtomicBool::new(false);
    let report =
        run_daemon(fabric, p, stripes, &ScrubConfig::default(), cfg(), 2, 3, &stop).unwrap();
    assert_eq!(report.cycles.len(), 2);
    let total = stripes * fabric.code().len() as u64;
    // cycle 0 finds and repairs everything planted; cycle 1 is clean
    assert_eq!(report.cycles[0].scanned, total, "cycle 0 skipped live replicas");
    assert_eq!(report.cycles[0].corrupt_found, planted as u64);
    assert_eq!(report.cycles[0].repaired, planted as u64);
    assert_eq!(report.cycles[1].corrupt_found, 0, "repair did not stick");
    assert_eq!(report.cycles[1].scanned, total);
    assert_eq!(report.deadline_misses, 0, "default config missed its deadline");
    assert!(report.cycles.iter().all(|c| c.deadline_met && c.skipped == 0));
    report
}

#[test]
fn scrub_daemon_report_is_deterministic_on_the_minicluster() {
    let spec = fast_spec();
    let p = d3_policy(&spec);
    let stripes = 20u64;
    let a = daemon_pass(&populated_mini(spec, &p, stripes, 3), p.as_ref(), stripes);
    let b = daemon_pass(&populated_mini(spec, &p, stripes, 3), p.as_ref(), stripes);
    // a quiet fabric never trips the activity signals, so the whole
    // report — modeled seconds included — replays bit-for-bit
    assert_eq!(a, b, "same registry, different daemon report");
    assert_eq!(a.cycles[0].throttled_batches, 0, "idle fabric throttled the daemon");
}

#[test]
fn net_scrub_daemon_matches_the_minicluster_report() {
    let spec = fast_spec();
    let p = d3_policy(&spec);
    let stripes = 20u64;
    let mini = daemon_pass(&populated_mini(spec, &p, stripes, 3), p.as_ref(), stripes);
    let net = daemon_pass(&populated_net(spec, &p, stripes, 3), p.as_ref(), stripes);
    // same registry and block size → same pure-function report on both
    // physical fabrics
    assert_eq!(mini, net, "daemon report diverged between physical fabrics");
}

#[test]
fn scrub_daemon_reports_an_infeasible_deadline_as_missed() {
    let spec = fast_spec();
    let p = d3_policy(&spec);
    let stripes = 20u64;
    let mini = populated_mini(spec, &p, stripes, 3);
    // 100 × 16 KiB at the 64 MB/s ceiling needs ~25 ms — a 1 ms interval
    // is infeasible by arithmetic, so the daemon must run at the ceiling
    // and say so rather than pretend
    let scfg = ScrubConfig { interval_s: 0.001, ..ScrubConfig::default() };
    let stop = AtomicBool::new(false);
    let report = run_daemon(&mini, p.as_ref(), stripes, &scfg, cfg(), 1, 3, &stop).unwrap();
    assert_eq!(report.deadline_misses, 1);
    assert!(!report.cycles[0].deadline_met);
    assert!(report.cycles[0].modeled_s > scfg.interval_s);
    // feasibility restored → the same registry meets the default deadline
    let stop = AtomicBool::new(false);
    let ok = run_daemon(&mini, p.as_ref(), stripes, &ScrubConfig::default(), cfg(), 1, 3, &stop)
        .unwrap();
    assert_eq!(ok.deadline_misses, 0);
}

#[test]
fn scrub_daemon_stop_flag_interrupts_the_cycle() {
    let spec = fast_spec();
    let p = d3_policy(&spec);
    let stripes = 20u64;
    let mini = populated_mini(spec, &p, stripes, 3);
    let stop = AtomicBool::new(true); // raised before the first batch
    let report =
        run_daemon(&mini, p.as_ref(), stripes, &ScrubConfig::default(), cfg(), 5, 3, &stop)
            .unwrap();
    assert!(report.cycles.len() <= 1, "stop flag did not end the daemon");
    assert!(report.scanned() == 0, "a pre-raised stop flag still scanned");
}

#[test]
fn scrub_daemon_keeps_foreground_tail_latency_bounded() {
    // the throttle acceptance: foreground p99 with an active scrub
    // daemon stays within a bounded factor of the no-scrub baseline
    let spec = fast_spec();
    let p = d3_policy(&spec);
    let stripes = 20u64;
    let mini = populated_mini(spec, &p, stripes, 3);
    let fg = Arc::new(AtomicBool::new(true));
    mini.links().set_qos(0.5, fg.clone());
    let bs = spec.block_size;
    let fg_burst = |n: usize| -> Vec<f64> {
        (0..n)
            .map(|i| {
                let src = Location::new(i % 8, 0);
                let dst = Location::new((i + 1) % 8, 1);
                let t0 = Instant::now();
                mini.links().transfer_class(src, dst, bs, TrafficClass::Foreground);
                t0.elapsed().as_secs_f64()
            })
            .collect()
    };
    let baseline = summarize(&fg_burst(64));
    let stop = AtomicBool::new(false);
    let scfg = ScrubConfig { busy_mb_s: 2.0, ..ScrubConfig::default() };
    let under_scrub = std::thread::scope(|s| {
        s.spawn(|| {
            // enough cycles to keep probing until the stop flag fires
            let _ = run_daemon(&mini, p.as_ref(), stripes, &scfg, cfg(), 10_000, 3, &stop);
        });
        let lat = summarize(&fg_burst(64));
        stop.store(true, Ordering::Relaxed);
        lat
    });
    mini.links().clear_qos();
    // generous bound (wall-clock test): the daemon shares the QoS bank
    // and backs off under load, so it must not multiply fg tail latency;
    // the absolute floor absorbs scheduler noise on micro-transfers
    assert!(
        under_scrub.p99 <= baseline.p99 * 8.0 + 0.01,
        "scrub wrecked fg p99: {} vs baseline {}",
        under_scrub.p99,
        baseline.p99
    );
}

/// Reduced-spec durability trial: a few hours of accelerated failures,
/// rack-correlated ones included, with corruption and a scrub schedule.
fn spot_dspec() -> DurabilitySpec {
    DurabilitySpec {
        horizon_s: 4.0 * 3600.0,
        fail_rate_per_hour: 5.0,
        rack_fail_prob: 0.3,
        corrupt_rate_per_hour: 10.0,
        scrub_interval_s: Some(3600.0),
        repair_mb_s: 0.05,
        trials: 1,
    }
}

fn assert_counters_equal(a: &TraceSummary, b: &TraceSummary, what: &str) {
    // everything except sustained_mb_s, which is backend-measured
    assert_eq!(a.failures, b.failures, "{what}: failures");
    assert_eq!(a.rounds, b.rounds, "{what}: rounds");
    assert_eq!(a.blocks_repaired, b.blocks_repaired, "{what}: blocks_repaired");
    assert_eq!(a.lost_stripes, b.lost_stripes, "{what}: lost_stripes");
    assert_eq!(a.corruptions, b.corruptions, "{what}: corruptions");
    assert_eq!(a.scrub_detections, b.scrub_detections, "{what}: scrub_detections");
    assert_eq!(a.corrupt_repaired, b.corrupt_repaired, "{what}: corrupt_repaired");
    assert_eq!(a.backlog_peak, b.backlog_peak, "{what}: backlog_peak");
    assert_eq!(a.arrival_mb_s, b.arrival_mb_s, "{what}: arrival_mb_s");
    assert_eq!(a.first_loss_s, b.first_loss_s, "{what}: first_loss_s");
}

#[test]
fn durability_trial_counters_agree_between_model_and_minicluster() {
    let spec = fast_spec();
    let p = d3_policy(&spec);
    let stripes = 24u64;
    let dspec = spot_dspec();
    let model =
        run_durability_trial_model(p.as_ref(), spec.block_size, stripes, &dspec, 11, 0).unwrap();
    assert!(model.failures > 0, "no failures over 4 accelerated hours");
    assert!(model.corruptions > 0, "no corruption arrivals");
    let replay =
        run_durability_trial_model(p.as_ref(), spec.block_size, stripes, &dspec, 11, 0).unwrap();
    assert_eq!(model, replay, "same (seed, trial) did not replay exactly");
    let mini = populated_mini(spec, &p, stripes, 11);
    let phys = run_durability_trial(&mini, p.as_ref(), stripes, &dspec, cfg(), 11, 0).unwrap();
    assert_counters_equal(&model, &phys, "model vs cluster");
    assert!(phys.sustained_mb_s > 0.0 || phys.blocks_repaired == 0);
}

#[test]
fn net_durability_trial_counters_match_the_model() {
    let spec = fast_spec();
    let p = d3_policy(&spec);
    let stripes = 24u64;
    let dspec = spot_dspec();
    let model =
        run_durability_trial_model(p.as_ref(), spec.block_size, stripes, &dspec, 11, 0).unwrap();
    let net = populated_net(spec, &p, stripes, 11);
    let phys = run_durability_trial(&net, p.as_ref(), stripes, &dspec, cfg(), 11, 0).unwrap();
    assert_counters_equal(&model, &phys, "model vs net");
}

#[test]
fn durability_matrix_is_deterministic_with_coherent_intervals() {
    let spec = SystemSpec::paper_default();
    let dspec = DurabilitySpec {
        horizon_s: 24.0 * 3600.0,
        fail_rate_per_hour: 8.0,
        rack_fail_prob: 0.3,
        corrupt_rate_per_hour: 6.0,
        scrub_interval_s: Some(6.0 * 3600.0),
        repair_mb_s: 0.25,
        trials: 6,
    };
    let policies = vec!["d3".to_string(), "rdd".to_string()];
    let codes = vec![("rs-6-3".to_string(), CodeSpec::Rs { k: 6, m: 3 })];
    let a = run_matrix(&spec, &dspec, &policies, &codes, 30, 5).unwrap();
    let b = run_matrix(&spec, &dspec, &policies, &codes, 30, 5).unwrap();
    assert_eq!(a, b, "matrix is not deterministic");
    assert_eq!(a.len(), 2);
    for cell in &a {
        let e = &cell.est;
        assert_eq!(e.trials, dspec.trials);
        assert!(e.observed_s > 0.0);
        assert!(e.loss_prob_lo <= e.loss_prob && e.loss_prob <= e.loss_prob_hi);
        if e.losses > 0 {
            let point = e.mttdl_s.unwrap();
            assert!(
                e.mttdl_lo_s <= point && point <= e.mttdl_hi_s,
                "CI does not bracket the MLE: [{}, {}] vs {point}",
                e.mttdl_lo_s,
                e.mttdl_hi_s
            );
        } else {
            assert!(e.mttdl_s.is_none());
            assert!(e.mttdl_hi_s.is_infinite());
            assert!(e.mttdl_lo_s > 0.0);
        }
    }
}

#[test]
fn rack_correlated_failures_favor_d3_over_rdd() {
    // the structural durability gap: rack failures erase at most
    // ⌈len/racks⌉ = 2 blocks of any D³ rs-6-3 stripe but up to
    // rack_limit = m = 3 under RDD, so overlapping rack + node failures
    // push RDD past the correction radius more often — across enough
    // trials RDD must lose at least as many stripes as D³
    let spec = SystemSpec::paper_default();
    let dspec = DurabilitySpec {
        horizon_s: 24.0 * 3600.0,
        fail_rate_per_hour: 12.0,
        rack_fail_prob: 0.5,
        corrupt_rate_per_hour: 4.0,
        scrub_interval_s: Some(6.0 * 3600.0),
        repair_mb_s: 0.1,
        trials: 10,
    };
    let code = CodeSpec::Rs { k: 6, m: 3 };
    let mut lost = std::collections::HashMap::new();
    for pname in ["d3", "rdd"] {
        let policy = d3ec::experiments::build_policy(pname, code, &spec, 5);
        let mut summaries = Vec::new();
        for trial in 0..dspec.trials {
            summaries.push(
                run_durability_trial_model(
                    policy.as_ref(),
                    spec.block_size,
                    30,
                    &dspec,
                    5,
                    trial,
                )
                .unwrap(),
            );
        }
        let est = estimate_mttdl(&summaries);
        lost.insert(pname, (summaries.iter().map(|s| s.lost_stripes).sum::<u64>(), est));
    }
    let (d3_lost, _) = lost["d3"];
    let (rdd_lost, _) = lost["rdd"];
    assert!(
        rdd_lost >= d3_lost,
        "RDD lost fewer stripes ({rdd_lost}) than D³ ({d3_lost}) under rack-correlated failures"
    );
}
