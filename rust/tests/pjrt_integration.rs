//! Integration: the PJRT data path (AOT artifacts) must be bit-identical
//! to the native GF path, end to end through encode → erase → recover.
//!
//! Requires `make artifacts`; tests no-op with a loud warning otherwise
//! (the Makefile's `test` target always builds artifacts first).

use d3ec::codes::{CodeSpec, RsCode};
use d3ec::gf;
use d3ec::placement::{D3Placement, Placement};
use d3ec::recovery::plan::{plan_coefficients, plan_repair};
use d3ec::runtime::{default_artifacts_dir, Coder};
use d3ec::topology::ClusterSpec;

fn pjrt_or_skip() -> Option<Coder> {
    if !default_artifacts_dir().join("manifest.json").exists() {
        eprintln!("SKIP: artifacts missing — run `make artifacts`");
        return None;
    }
    Some(Coder::pjrt().expect("artifacts present but PJRT load failed"))
}

fn rand_shards(k: usize, len: usize, seed: u64) -> Vec<Vec<u8>> {
    let mut s = seed | 1;
    (0..k)
        .map(|_| {
            (0..len)
                .map(|_| {
                    s ^= s << 13;
                    s ^= s >> 7;
                    s ^= s << 17;
                    (s >> 24) as u8
                })
                .collect()
        })
        .collect()
}

#[test]
fn pjrt_combine_matches_native_across_k_and_lengths() {
    let Some(coder) = pjrt_or_skip() else { return };
    for k in [1usize, 2, 3, 6, 9, 12] {
        // lengths: sub-panel, exact panel, multi-panel with ragged tail
        for len in [100usize, 65536, 65536 * 2 + 1234] {
            let shards = rand_shards(k, len, (k * 1000 + len) as u64);
            let refs: Vec<&[u8]> = shards.iter().map(|v| v.as_slice()).collect();
            let coeffs: Vec<u8> = (0..k).map(|i| (i * 37 + 5) as u8).collect();
            let got = coder.combine(&coeffs, &refs).unwrap();
            let want = gf::combine(&coeffs, &refs);
            assert_eq!(got, want, "k={k} len={len}");
        }
    }
}

#[test]
fn pjrt_encode_erase_recover_roundtrip() {
    let Some(coder) = pjrt_or_skip() else { return };
    let code = RsCode::new(6, 3);
    let data = rand_shards(6, 200_000, 99);
    let refs: Vec<&[u8]> = data.iter().map(|v| v.as_slice()).collect();
    // encode through PJRT
    let parity = coder.encode(&code.parity_rows(), &refs).unwrap();
    let mut all: Vec<&[u8]> = refs.clone();
    all.extend(parity.iter().map(|v| v.as_slice()));
    // erase block 2, rebuild through PJRT with planner coefficients
    let policy = D3Placement::new(CodeSpec::Rs { k: 6, m: 3 }, ClusterSpec::new(8, 3)).unwrap();
    let plan = plan_repair(&policy, 7, 2, 0);
    let coeffs = plan_coefficients(&CodeSpec::Rs { k: 6, m: 3 }, &plan);
    let sources = plan.source_blocks();
    let shards: Vec<&[u8]> = sources.iter().map(|&b| all[b]).collect();
    let rebuilt = coder.combine(&coeffs, &shards).unwrap();
    assert_eq!(rebuilt, data[2]);
}

#[test]
fn pjrt_partial_aggregation_identity() {
    // D³'s two-stage aggregation through PJRT equals the direct combine
    // (the identity the recovery pipeline rests on).
    let Some(coder) = pjrt_or_skip() else { return };
    let code = RsCode::new(6, 3);
    let data = rand_shards(6, 70_000, 3);
    let refs: Vec<&[u8]> = data.iter().map(|v| v.as_slice()).collect();
    let parity = coder.encode(&code.parity_rows(), &refs).unwrap();
    let mut all: Vec<&[u8]> = refs.clone();
    all.extend(parity.iter().map(|v| v.as_slice()));
    let avail = vec![1usize, 2, 3, 4, 5, 6];
    let c = code.decode_coeffs(&avail, 0).unwrap();
    let shards: Vec<&[u8]> = avail.iter().map(|&b| all[b]).collect();
    let direct = coder.combine(&c, &shards).unwrap();
    let agg_a = coder.combine(&c[..3], &shards[..3]).unwrap();
    let agg_b = coder.combine(&c[3..], &shards[3..]).unwrap();
    let via = coder.combine(&[1, 1], &[&agg_a, &agg_b]).unwrap();
    assert_eq!(direct, via);
    assert_eq!(direct, data[0]);
}

#[test]
fn pjrt_xor_path_for_lrc() {
    let Some(coder) = pjrt_or_skip() else { return };
    use d3ec::codes::LrcCode;
    let code = LrcCode::new(4, 2, 1);
    let data = rand_shards(4, 100_000, 5);
    let refs: Vec<&[u8]> = data.iter().map(|v| v.as_slice()).collect();
    let parity = coder.encode(&code.parity_rows(), &refs).unwrap();
    // local parity 0 = d0 ^ d1 — verify through the unit-coefficient path
    let via_combine = coder.combine(&[1, 1], &[refs[0], refs[1]]).unwrap();
    assert_eq!(parity[0], via_combine);
    // repair d1 from (d0, l0) with the LRC plan coefficients
    let (src, coeffs) = code.repair_plan(1);
    assert_eq!(src, vec![0, 4]);
    let rebuilt = coder.combine(&coeffs, &[refs[0], &parity[0]]).unwrap();
    assert_eq!(rebuilt, data[1]);
}
