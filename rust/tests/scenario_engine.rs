//! Scenario-engine integration: the same `FailureScenario` runs on the
//! fluid-simulator, MiniCluster, and socket-backed NetCluster backends,
//! outcomes are cross-checkable (exactly, for the two real data paths),
//! and D³'s headline property — fewer cross-rack repair bytes than RDD —
//! holds on *both* physical backends.
//!
//! The `net_`-prefixed tests are the loopback-socket suite CI runs under
//! a hard timeout (`cargo test --test scenario_engine net_`).

use std::sync::Arc;

use d3ec::client::ClientIo;
use d3ec::cluster::{deterministic_data, BlockFabric, ClusterBackend, MiniCluster};
use d3ec::codes::CodeSpec;
use d3ec::net::{proto, NetCluster, NetClusterBackend, NodeState};
use d3ec::placement::{D3Placement, Placement, PlacementTable, RddPlacement};
use d3ec::recovery::migration::plan_migration;
use d3ec::recovery::multi::scenario_recovery_plans;
use d3ec::recovery::plan::RepairPlan;
use d3ec::recovery::{node_recovery_plans, plan_repair, ExecutorConfig, SchedulePolicy};
use d3ec::scenario::{FailureScenario, RecoveryBackend};
use d3ec::sim::SimBackend;
use d3ec::topology::{Location, SystemSpec};

fn policy(name: &str, spec: &SystemSpec) -> Arc<dyn Placement> {
    let code = CodeSpec::Rs { k: 6, m: 3 };
    match name {
        "d3" => Arc::new(D3Placement::new(code, spec.cluster).unwrap()),
        _ => Arc::new(RddPlacement::new(code, spec.cluster, 5)),
    }
}

fn fast_cluster_backend() -> ClusterBackend {
    ClusterBackend { block_size: 16 << 10, ..ClusterBackend::default() }
}

#[test]
fn d3_beats_rdd_on_cross_rack_bytes_on_both_backends() {
    let spec = SystemSpec::paper_default();
    let scenario = FailureScenario::single_node(60, 2);
    let sim = SimBackend::default();
    let cluster = fast_cluster_backend();
    let backends: [(&str, &dyn RecoveryBackend); 2] = [("sim", &sim), ("cluster", &cluster)];
    for (bname, backend) in backends {
        let d3 = backend.run(&scenario, &policy("d3", &spec), &spec).unwrap();
        let rdd = backend.run(&scenario, &policy("rdd", &spec), &spec).unwrap();
        assert!(d3.blocks > 0, "{bname}: empty scenario");
        assert!(rdd.blocks > 0, "{bname}: empty scenario");
        // the headline claim, per backend: D³ moves fewer cross-rack bytes
        // per rebuilt block than RDD
        let d3_per_block = d3.total_cross_rack_bytes() as f64 / d3.blocks as f64;
        let rdd_per_block = rdd.total_cross_rack_bytes() as f64 / rdd.blocks as f64;
        assert!(
            d3_per_block < rdd_per_block,
            "{bname}: D³ {d3_per_block:.0} B/block !< RDD {rdd_per_block:.0} B/block"
        );
        // and the plans say the same thing in block units
        assert!(
            (d3.planned_cross_rack_blocks as f64 / d3.blocks as f64)
                < (rdd.planned_cross_rack_blocks as f64 / rdd.blocks as f64),
            "{bname}: planner disagrees with the byte accounting"
        );
    }
}

#[test]
fn backends_execute_identical_plans() {
    let spec = SystemSpec::paper_default();
    let scenario = FailureScenario::multi_node(2, 50, 9);
    let p = policy("d3", &spec);
    let sim_out = SimBackend::default().run(&scenario, &p, &spec).unwrap();
    let cl_out = fast_cluster_backend().run(&scenario, &p, &spec).unwrap();
    assert_eq!(sim_out.blocks, cl_out.blocks, "different plan sets");
    assert_eq!(
        sim_out.planned_cross_rack_blocks, cl_out.planned_cross_rack_blocks,
        "different plan structure"
    );
    assert!(sim_out.seconds > 0.0);
    assert!(cl_out.seconds > 0.0);
}

#[test]
fn rack_failure_scenario_completes_on_both_backends() {
    let spec = SystemSpec::paper_default();
    let scenario = FailureScenario::rack_failure(0, 45, 3);
    let p = policy("d3", &spec);
    let sim_out = SimBackend::default().run(&scenario, &p, &spec).unwrap();
    let cl_out = fast_cluster_backend().run(&scenario, &p, &spec).unwrap();
    assert!(sim_out.blocks > 0, "rack held no blocks?");
    assert_eq!(sim_out.blocks, cl_out.blocks);
    // the dead rack's ports carry no recovery traffic: every source and
    // every recovery target avoids its nodes
    let (up, down) = sim_out.rack_cross_bytes[0];
    assert_eq!(up + down, 0, "traffic through the failed rack's ports");
    let others: u64 = sim_out
        .rack_cross_bytes
        .iter()
        .skip(1)
        .map(|&(u, d)| u + d)
        .sum();
    assert!(others > 0, "no cross-rack recovery traffic at all?");
}

#[test]
fn rack_failure_recovers_real_bytes_in_the_minicluster() {
    // end-to-end multi-erasure proof: write real stripes, kill a whole
    // rack, run the scenario planner's plans, read every data block back.
    let mut spec = SystemSpec::paper_default();
    spec.block_size = 16 << 10;
    spec.net.inner_mbps = 8000.0;
    spec.net.cross_mbps = 1600.0;
    let code = CodeSpec::Rs { k: 6, m: 3 };
    let policy: Arc<dyn Placement> =
        Arc::new(D3Placement::new(code, spec.cluster).unwrap());
    let cluster = MiniCluster::new(spec, policy.clone(), "native", 4).unwrap();
    let stripes = 36u64;
    let gen = |sid: u64| -> Vec<Vec<u8>> {
        (0..6)
            .map(|b| {
                let mut v = vec![0u8; 16 << 10];
                let mut s = sid.wrapping_mul(77).wrapping_add(b as u64) | 1;
                for byte in v.iter_mut() {
                    s ^= s << 13;
                    s ^= s >> 7;
                    s ^= s << 17;
                    *byte = (s >> 24) as u8;
                }
                v
            })
            .collect()
    };
    // stripes move into the cluster (zero-copy ingest); regenerate the
    // deterministic data for the verification pass below
    cluster.write_stripes_parallel(stripes, 4, gen).unwrap();
    let originals: Vec<Vec<Vec<u8>>> = (0..stripes).map(gen).collect();
    let failed: Vec<Location> = (0..3).map(|j| Location::new(1, j)).collect();
    for &f in &failed {
        cluster.fail_node(f);
    }
    let table = PlacementTable::build(policy.clone(), stripes);
    let plans = scenario_recovery_plans(&table, stripes, &failed, 4).unwrap();
    assert!(!plans.is_empty());
    let stats = cluster.recover_with_plans(plans, 6, &[1]).unwrap();
    assert!(stats.blocks > 0);
    // every data block of every stripe reads back bit-identical
    let client = Location::new(7, 2);
    for sid in 0..stripes {
        for b in 0..6 {
            let got = cluster.read_block(sid, b, client).unwrap();
            assert_eq!(got, originals[sid as usize][b], "stripe {sid} block {b}");
        }
    }
}

#[test]
fn balanced_schedule_keeps_rack_link_balance_no_worse_than_fifo() {
    // Rack failure under both admission schedules. Per-rack-link repair
    // *bytes* are a plan property, so the interesting assertion is the
    // exact byte-vector equality below: it proves the balanced schedule
    // moved exactly the same traffic over exactly the same links (and
    // with it, its max/min per-rack-link byte ratio trivially can't
    // exceed FIFO's — asserted as the ISSUE's acceptance wording). The
    // schedule's *runtime* difference lives in time, not bytes, and is
    // surfaced through `link_busy_stall`, whose presence and plausibility
    // are checked at the end; the conflict-free round structure itself is
    // pinned deterministically by recovery::schedule's unit tests.
    let spec = SystemSpec::paper_default();
    let scenario = FailureScenario::rack_failure(1, 48, 6);
    let p = policy("d3", &spec);
    let run = |schedule| {
        let backend = ClusterBackend {
            schedule,
            coalesce: 2,
            batched_fetch: true,
            ..fast_cluster_backend()
        };
        backend.run(&scenario, &p, &spec).unwrap()
    };
    let fifo = run(SchedulePolicy::Fifo);
    let balanced = run(SchedulePolicy::Balanced);
    assert!(fifo.blocks > 0);
    assert_eq!(fifo.blocks, balanced.blocks, "different plan sets");
    assert_eq!(
        fifo.rack_cross_bytes, balanced.rack_cross_bytes,
        "schedule changed the byte accounting"
    );
    let link_ratio = |out: &d3ec::scenario::ScenarioOutcome| {
        let loads: Vec<f64> = out
            .rack_cross_bytes
            .iter()
            .enumerate()
            .filter(|&(r, _)| r != 1) // the dead rack moves no repair bytes
            .map(|(_, &(u, d))| (u + d) as f64)
            .collect();
        d3ec::metrics::max_min_ratio(&loads)
    };
    let (rf, rb) = (link_ratio(&fifo), link_ratio(&balanced));
    assert!(
        rb <= rf + 1e-9,
        "balanced max/min per-rack-link byte ratio {rb} exceeds FIFO's {rf}"
    );
    // the cluster backend must actually report per-link busy/stall time
    let ls = balanced.link_busy_stall.as_ref().expect("link accounting missing");
    assert_eq!(ls.len(), spec.cluster.racks);
    assert!(ls.iter().any(|&(b, _)| b > 0.0), "no link ever went busy");
}

#[test]
fn degraded_burst_scenario_reports_latencies() {
    let spec = SystemSpec::paper_default();
    let scenario = FailureScenario::degraded_burst(12, 60, 5);
    let p = policy("d3", &spec);
    let out = SimBackend::default().run(&scenario, &p, &spec).unwrap();
    assert_eq!(out.blocks, 12);
    let mean = out.degraded_read_mean_s.expect("burst reports latency");
    assert!(mean > 0.0 && mean <= out.seconds + 1e-9);
}

#[test]
fn frontend_mix_scenario_reports_workload_time() {
    let spec = SystemSpec::paper_default();
    let scenario = FailureScenario::frontend_mix("grep", 40, 5);
    let p = policy("d3", &spec);
    let out = SimBackend::default().run(&scenario, &p, &spec).unwrap();
    assert!(out.blocks > 0);
    let t = out.frontend_seconds.expect("mix reports workload time");
    assert!(t > 0.0);
}

#[test]
fn every_scenario_kind_cross_checks_between_backends() {
    // Both backends execute the *same* plans, so cross-rack traffic in
    // block units is a plan property and must match EXACTLY, rack by
    // rack. Recovery time is backend-physical — fluid max-min sharing vs
    // real token buckets + thread scheduling — so with both backends
    // configured to identical link rates, block size and worker count we
    // assert agreement within one order of magnitude (the stated
    // tolerance; the byte counts are the exact cross-check).
    let mut spec = SystemSpec::paper_default();
    spec.block_size = 256 << 10;
    spec.net.inner_mbps = 1600.0;
    spec.net.cross_mbps = 160.0;
    let p = policy("d3", &spec);
    let mut sim = SimBackend::default();
    sim.cfg.task_overhead_s = 0.0; // the cluster has no NameNode RPC delay
    sim.cfg.workers = 8;
    let cluster = ClusterBackend {
        data_backend: "native".into(),
        block_size: spec.block_size,
        inner_mbps: spec.net.inner_mbps,
        cross_mbps: spec.net.cross_mbps,
        workers: 8,
        chunk_size: 64 << 10,
        ..ClusterBackend::default()
    };
    let stripes = 60u64;
    let kinds = [
        FailureScenario::single_node(stripes, 2),
        FailureScenario::multi_node(2, stripes, 2),
        FailureScenario::rack_failure(1, stripes, 2),
        FailureScenario::degraded_burst(10, stripes, 2),
        FailureScenario::frontend_mix("grep", stripes, 2),
    ];
    for scenario in kinds {
        let name = scenario.name();
        let s = sim.run(&scenario, &p, &spec).unwrap();
        let c = cluster.run(&scenario, &p, &spec).unwrap();
        assert_eq!(s.blocks, c.blocks, "{name}: different plan sets");
        assert_eq!(
            s.planned_cross_rack_blocks, c.planned_cross_rack_blocks,
            "{name}: different plan structure"
        );
        if matches!(scenario.kind, d3ec::scenario::ScenarioKind::FrontendMix { .. }) {
            // foreground traffic differs by construction (sim places the
            // workload analytically; the cluster samples real reads), so
            // only the plan-level quantities are comparable
            continue;
        }
        let in_blocks = |bytes: &[(u64, u64)], bs: u64| -> Vec<(u64, u64)> {
            bytes
                .iter()
                .map(|&(u, d)| {
                    (
                        (u as f64 / bs as f64).round() as u64,
                        (d as f64 / bs as f64).round() as u64,
                    )
                })
                .collect()
        };
        assert_eq!(
            in_blocks(&s.rack_cross_bytes, spec.block_size),
            in_blocks(&c.rack_cross_bytes, cluster.block_size),
            "{name}: per-rack cross-rack block counts diverge"
        );
        assert!(s.seconds > 0.0 && c.seconds > 0.0, "{name}");
        let ratio = c.seconds / s.seconds;
        assert!(
            (0.1..=10.0).contains(&ratio),
            "{name}: cluster {:.3}s vs sim {:.3}s (ratio {ratio:.2}) outside tolerance",
            c.seconds,
            s.seconds
        );
    }
}

/// A small, fast testbed for the socket-backed suite: tiny blocks, fat
/// modeled links, the shared deterministic populate oracle.
fn fast_spec() -> SystemSpec {
    let mut spec = SystemSpec::paper_default();
    spec.block_size = 16 << 10;
    spec.net.inner_mbps = 8000.0;
    spec.net.cross_mbps = 1600.0;
    spec
}

fn net_pair(spec: SystemSpec, seed: u64) -> (Arc<dyn Placement>, MiniCluster, NetCluster) {
    let code = CodeSpec::Rs { k: 3, m: 2 };
    let p: Arc<dyn Placement> = Arc::new(D3Placement::new(code, spec.cluster).unwrap());
    let mini = MiniCluster::new(spec, p.clone(), "native", seed).unwrap();
    let net = NetCluster::new(spec, p.clone(), seed).unwrap();
    (p, mini, net)
}

fn populate_both(mini: &MiniCluster, net: &NetCluster, stripes: u64, k: usize, bs: usize) {
    mini.write_stripes_parallel(stripes, 4, |sid| deterministic_data(sid, k, bs)).unwrap();
    net.write_stripes_parallel(stripes, 4, |sid| deterministic_data(sid, k, bs)).unwrap();
}

#[test]
fn net_three_backend_parity() {
    // The tentpole's acceptance: identical seeds agree EXACTLY on per-rack
    // repair bytes between the two physical backends (both charge the same
    // modeled transfers; timing cannot perturb byte counters), and agree
    // with the fluid simulator at block granularity.
    let mut spec = SystemSpec::paper_default();
    spec.block_size = 256 << 10;
    spec.net.inner_mbps = 1600.0;
    spec.net.cross_mbps = 160.0;
    let p = policy("d3", &spec);
    let mut sim = SimBackend::default();
    sim.cfg.task_overhead_s = 0.0;
    sim.cfg.workers = 8;
    let cluster = fast_cluster_backend();
    let net = NetClusterBackend { block_size: 16 << 10, ..NetClusterBackend::default() };
    let stripes = 40u64;
    let kinds = [
        FailureScenario::single_node(stripes, 2),
        FailureScenario::multi_node(2, stripes, 2),
        FailureScenario::rack_failure(1, stripes, 2),
        FailureScenario::degraded_burst(10, stripes, 2),
    ];
    for scenario in kinds {
        let name = scenario.name();
        let s = sim.run(&scenario, &p, &spec).unwrap();
        let c = cluster.run(&scenario, &p, &spec).unwrap();
        let n = net.run(&scenario, &p, &spec).unwrap();
        // served / rebuilt block counts agree three ways
        assert_eq!(s.blocks, c.blocks, "{name}: sim vs cluster plan sets");
        assert_eq!(c.blocks, n.blocks, "{name}: cluster vs net plan sets");
        assert_eq!(
            c.planned_cross_rack_blocks, n.planned_cross_rack_blocks,
            "{name}: plan structure diverges"
        );
        // the headline acceptance: exact per-rack repair-byte agreement
        // between the in-process and socket-backed data paths
        assert_eq!(
            c.rack_cross_bytes, n.rack_cross_bytes,
            "{name}: cluster and net per-rack cross-rack bytes differ"
        );
        // and block-granular agreement with the fluid model
        let in_blocks = |bytes: &[(u64, u64)], bs: u64| -> Vec<(u64, u64)> {
            bytes
                .iter()
                .map(|&(u, d)| {
                    (
                        (u as f64 / bs as f64).round() as u64,
                        (d as f64 / bs as f64).round() as u64,
                    )
                })
                .collect()
        };
        assert_eq!(
            in_blocks(&s.rack_cross_bytes, spec.block_size),
            in_blocks(&n.rack_cross_bytes, net.block_size),
            "{name}: sim vs net per-rack block counts diverge"
        );
        assert!(n.seconds > 0.0, "{name}: net backend reported no wall time");
    }
}

#[test]
fn net_recovered_block_checksum_parity() {
    // Same populate, same failure, same plans on both physical backends:
    // every recovered block must hash identically on both, and data
    // blocks must hash to the populate oracle's bytes.
    let spec = fast_spec();
    let (p, mini, net) = net_pair(spec, 2);
    let stripes = 24u64;
    populate_both(&mini, &net, stripes, 3, spec.block_size as usize);
    let failed = Location::new(0, 0);
    mini.fail_node(failed);
    net.fail(failed).unwrap();
    let plans = node_recovery_plans(p.as_ref(), stripes, failed, 2);
    assert!(!plans.is_empty(), "node held no blocks");
    let cfg = ExecutorConfig { workers: 6, ..ExecutorConfig::default() };
    let ms = mini.recover_with_plans_cfg(plans.clone(), cfg, &[0]).unwrap();
    let ns = net.recover_with_plans_cfg(plans.clone(), cfg, &[0]).unwrap();
    assert_eq!(ms.blocks, ns.blocks);
    assert_eq!(ms.rack_bytes, ns.rack_bytes, "recovery byte accounting diverges");
    let client = Location::new(7, 2);
    for plan in &plans {
        let (sid, b) = (plan.stripe, plan.failed_block);
        let from_mini = mini.read_block(sid, b, client).unwrap();
        let from_net = ClientIo::read_block(&net, sid, b, client).unwrap();
        assert_eq!(
            proto::checksum(&from_mini),
            proto::checksum(&from_net),
            "stripe {sid} block {b}: recovered checksums diverge"
        );
        if b < 3 {
            let oracle = deterministic_data(sid, 3, spec.block_size as usize);
            assert_eq!(from_net, oracle[b], "stripe {sid} block {b}: wrong bytes rebuilt");
        }
    }
}

#[test]
fn net_recover_plan_rpc_rebuilds_on_worker() {
    // One RecoverPlan RPC: the writer worker pulls sources from its peers
    // over worker-to-worker sockets, GF-combines, stores, and returns the
    // rebuilt block's checksum.
    let spec = fast_spec();
    let (p, _mini, net) = net_pair(spec, 3);
    let data = deterministic_data(4, 3, spec.block_size as usize);
    net.write_stripe(4, data.clone()).unwrap();
    let victim = BlockFabric::locate(&net, 4, 1);
    net.fail(victim).unwrap();
    let plan = plan_repair(p.as_ref(), 4, 1, 3);
    let sum = net.recover_block_on_worker(&plan).unwrap();
    assert_eq!(sum, proto::checksum(&data[1]), "worker rebuilt the wrong bytes");
    let got = ClientIo::read_block(&net, 4, 1, Location::new(6, 1)).unwrap();
    assert_eq!(got, data[1]);
}

#[test]
fn net_membership_join_rebalance_fail_recover() {
    // The RPC membership state machine end to end: fail → recover →
    // (heartbeat sees Failed/empty) → join → rebalance restores the
    // canonical layout → fail again → recover again → still readable.
    let spec = fast_spec();
    let (p, _mini, net) = net_pair(spec, 5);
    let stripes = 18u64;
    let bs = spec.block_size as usize;
    net.write_stripes_parallel(stripes, 4, |sid| deterministic_data(sid, 3, bs)).unwrap();
    let failed = BlockFabric::locate(&net, 0, 0);
    assert_eq!(net.heartbeat(failed).unwrap().0, NodeState::Up);

    let recover = |seed_plans: &[RepairPlan]| {
        let cfg = ExecutorConfig { workers: 4, ..ExecutorConfig::default() };
        net.recover_with_plans_cfg(seed_plans.to_vec(), cfg, &[failed.rack]).unwrap()
    };
    let plans = node_recovery_plans(p.as_ref(), stripes, failed, 5);
    assert!(!plans.is_empty());

    net.fail(failed).unwrap();
    let (state, blocks) = net.heartbeat(failed).unwrap();
    assert_eq!(state, NodeState::Failed);
    assert_eq!(blocks, 0, "Fail must drop the worker's store");
    let stats = recover(&plans);
    assert_eq!(stats.blocks, plans.len());

    // recovered copies live AWAY from the failed node
    for plan in &plans {
        assert_ne!(BlockFabric::locate(&net, plan.stripe, plan.failed_block), failed);
    }

    // a replacement machine joins: rebalance moves every parked block home
    let rebalanced = net.join(failed).unwrap();
    assert_eq!(rebalanced, plans.len(), "join must restore the canonical layout");
    let (state, blocks) = net.heartbeat(failed).unwrap();
    assert_eq!(state, NodeState::Up);
    assert_eq!(blocks as usize, plans.len());
    let client = Location::new(7, 2);
    for plan in &plans {
        let (sid, b) = (plan.stripe, plan.failed_block);
        assert_eq!(
            BlockFabric::locate(&net, sid, b),
            p.stripe(sid).locs[b],
            "stripe {sid} block {b} not back on its canonical node"
        );
        if b < 3 {
            let got = ClientIo::read_block(&net, sid, b, client).unwrap();
            assert_eq!(got, deterministic_data(sid, 3, bs)[b], "stripe {sid} block {b}");
        }
    }

    // the same machine can fail and be recovered a second time
    net.fail(failed).unwrap();
    recover(&plans);
    for plan in &plans {
        let (sid, b) = (plan.stripe, plan.failed_block);
        if b < 3 {
            let got = ClientIo::read_block(&net, sid, b, client).unwrap();
            assert_eq!(got, deterministic_data(sid, 3, bs)[b], "second recovery broke {sid}/{b}");
        }
    }
}

#[test]
fn net_drain_rehomes_blocks_and_keeps_them_readable() {
    let spec = fast_spec();
    let (_p, _mini, net) = net_pair(spec, 9);
    let stripes = 12u64;
    let bs = spec.block_size as usize;
    net.write_stripes_parallel(stripes, 4, |sid| deterministic_data(sid, 3, bs)).unwrap();
    let drained = BlockFabric::locate(&net, 0, 2);
    let held_before = net.block_count(drained);
    assert!(held_before > 0);
    let moved = net.drain(drained).unwrap();
    assert_eq!(moved, held_before, "drain must re-home every held block");
    assert_eq!(net.heartbeat(drained).unwrap(), (NodeState::Draining, 0));
    let client = Location::new(6, 0);
    for sid in 0..stripes {
        for b in 0..3 {
            assert_ne!(BlockFabric::locate(&net, sid, b), drained, "block left on drained node");
            let got = ClientIo::read_block(&net, sid, b, client).unwrap();
            assert_eq!(got, deterministic_data(sid, 3, bs)[b], "stripe {sid} block {b}");
        }
    }
}

#[test]
fn migration_restores_layout_on_minicluster_and_net_and_matches_sim() {
    // Satellite: the §5.3 migration batches execute against real stores on
    // BOTH physical fabrics — recovered blocks end up back on the relived
    // node with the canonical layout and oracle bytes — and the simulator
    // prices the identical batch sequence.
    let mut spec = SystemSpec::paper_default();
    spec.cluster.racks = 5;
    spec.block_size = 16 << 10;
    spec.net.inner_mbps = 8000.0;
    spec.net.cross_mbps = 1600.0;
    let code = CodeSpec::Rs { k: 3, m: 2 };
    let d3 = D3Placement::new(code, spec.cluster).unwrap();
    let p: Arc<dyn Placement> = Arc::new(D3Placement::new(code, spec.cluster).unwrap());
    let mini = MiniCluster::new(spec, p.clone(), "native", 4).unwrap();
    let net = NetCluster::new(spec, p.clone(), 4).unwrap();
    let stripes = 45u64;
    let bs = spec.block_size as usize;
    populate_both(&mini, &net, stripes, 3, bs);

    let failed = Location::new(0, 0);
    mini.fail_node(failed);
    net.fail(failed).unwrap();
    let plans = node_recovery_plans(p.as_ref(), stripes, failed, 4);
    assert!(!plans.is_empty());
    let cfg = ExecutorConfig { workers: 4, ..ExecutorConfig::default() };
    mini.recover_with_plans_cfg(plans.clone(), cfg, &[0]).unwrap();
    net.recover_with_plans_cfg(plans.clone(), cfg, &[0]).unwrap();

    // the replacement machine arrives empty; migration restores onto it
    mini.relive_node(failed);
    net.relive(failed).unwrap();
    let appended = |plan: &RepairPlan| {
        let sp = d3.stripe(plan.stripe);
        sp.locs
            .iter()
            .enumerate()
            .any(|(bi, l)| bi != plan.failed_block && l.rack == plan.writer.rack)
    };
    let batches =
        plan_migration(&plans, appended, d3.region_size(), spec.cluster.nodes_per_rack);
    assert!(!batches.is_empty());
    let moves: usize = batches.iter().map(|b| b.moves.len()).sum();
    assert_eq!(moves, plans.len(), "every recovered block migrates exactly once");

    let mini_times = mini.run_migration(&batches, failed).unwrap();
    let net_times = net.run_migration(&batches, failed).unwrap();
    let sim_times = d3ec::sim::recovery::run_migration(&spec, &batches, failed);
    assert_eq!(mini_times.len(), batches.len());
    assert_eq!(net_times.len(), batches.len());
    assert_eq!(sim_times.len(), batches.len(), "sim prices a different batch sequence");
    assert!(sim_times.iter().all(|&t| t > 0.0));

    // final placement: canonical layout restored on both fabrics, bytes
    // identical to the populate oracle
    let client = Location::new(4, 2);
    for plan in &plans {
        let (sid, b) = (plan.stripe, plan.failed_block);
        let canonical = p.stripe(sid).locs[b];
        assert_eq!(canonical, failed, "plan for a block the failed node never held");
        assert_eq!(BlockFabric::locate(&mini, sid, b), canonical, "mini layout not restored");
        assert_eq!(BlockFabric::locate(&net, sid, b), canonical, "net layout not restored");
        if b < 3 {
            let oracle = deterministic_data(sid, 3, bs);
            assert_eq!(mini.read_block(sid, b, client).unwrap(), oracle[b]);
            assert_eq!(ClientIo::read_block(&net, sid, b, client).unwrap(), oracle[b]);
        }
    }
}

#[test]
fn table_backed_planning_matches_raw_policy() {
    let spec = SystemSpec::paper_default();
    let p = policy("d3", &spec);
    let table = PlacementTable::build(p.clone(), 1000);
    let failed = Location::new(0, 0);
    let raw = node_recovery_plans(p.as_ref(), 1000, failed, 0);
    let cached = node_recovery_plans(&table, 1000, failed, 0);
    assert_eq!(raw.len(), cached.len());
    for (a, b) in raw.iter().zip(&cached) {
        assert_eq!(a.stripe, b.stripe);
        assert_eq!(a.failed_block, b.failed_block);
        assert_eq!(a.writer, b.writer);
        assert_eq!(a.cross_rack_blocks(), b.cross_rack_blocks());
        assert_eq!(a.source_blocks(), b.source_blocks());
    }
}
