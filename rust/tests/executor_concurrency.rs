//! Concurrency soundness of the pipelined recovery executor (DESIGN.md
//! §8): scheduling may reorder chunk tasks freely, but for a fixed seed
//! the recovered bytes must be byte-identical and the cross-rack traffic
//! accounting must not drift, for *any* worker count or chunk size.

use std::sync::Arc;

use d3ec::cluster::MiniCluster;
use d3ec::codes::CodeSpec;
use d3ec::placement::{D3Placement, Placement};
use d3ec::recovery::{node_recovery_plans, ExecutorConfig, SchedulePolicy};
use d3ec::topology::{Location, SystemSpec};

const SEED: u64 = 11;
const STRIPES: u64 = 24;
const BLOCK: usize = 64 * 1024;

fn spec() -> SystemSpec {
    let mut s = SystemSpec::paper_default();
    s.block_size = BLOCK as u64;
    s.net.inner_mbps = 8000.0; // keep the test fast
    s.net.cross_mbps = 1600.0;
    s
}

fn data_for(sid: u64, k: usize) -> Vec<Vec<u8>> {
    (0..k)
        .map(|b| {
            let mut v = vec![0u8; BLOCK];
            let mut s = sid.wrapping_mul(0x51ed).wrapping_add(b as u64) | 1;
            for byte in v.iter_mut() {
                s ^= s << 13;
                s ^= s >> 7;
                s ^= s << 17;
                *byte = (s >> 24) as u8;
            }
            v
        })
        .collect()
}

/// Run one full node recovery with the given executor config and return
/// `(recovered (sid, block, writer, bytes) sorted, rack byte snapshot,
/// per-worker utilization)`.
fn recover_fixture(
    cfg: ExecutorConfig,
) -> (Vec<(u64, usize, Location, Vec<u8>)>, Vec<(u64, u64)>, Vec<f64>) {
    let spec = spec();
    let policy: Arc<dyn Placement> =
        Arc::new(D3Placement::new(CodeSpec::Rs { k: 3, m: 2 }, spec.cluster).unwrap());
    let cluster = MiniCluster::new(spec, policy.clone(), "native", SEED).unwrap();
    for sid in 0..STRIPES {
        cluster.write_stripe(sid, data_for(sid, 3)).unwrap();
    }
    let failed = Location::new(2, 1);
    cluster.fail_node(failed);
    let plans = node_recovery_plans(policy.as_ref(), STRIPES, failed, SEED);
    assert!(!plans.is_empty(), "failed node holds no blocks");
    let lost: Vec<(u64, usize)> =
        plans.iter().map(|p| (p.stripe, p.failed_block)).collect();
    let stats = cluster.recover_with_plans_cfg(plans, cfg, &[failed.rack]).unwrap();
    assert_eq!(stats.blocks, lost.len());
    let mut recovered = Vec::with_capacity(lost.len());
    for (sid, b) in lost {
        let loc = cluster.locate(sid, b);
        assert_ne!(loc, failed, "metadata still points at the dead node");
        // reading at the block's own location moves no bytes, so the
        // snapshot below still covers exactly writes + recovery
        let bytes = cluster.read_block(sid, b, loc).unwrap();
        recovered.push((sid, b, loc, bytes));
    }
    recovered.sort_by_key(|&(sid, b, _, _)| (sid, b));
    (recovered, cluster.rack_byte_snapshot(), stats.worker_utilization)
}

#[test]
fn worker_counts_1_2_8_recover_identical_bytes_and_metrics() {
    let base = ExecutorConfig { chunk_size: 16 << 10, ..ExecutorConfig::default() };
    let (blocks1, snap1, util1) = recover_fixture(ExecutorConfig { workers: 1, ..base });
    assert_eq!(util1.len(), 1);
    for workers in [2usize, 8] {
        let (blocks, snap, util) = recover_fixture(ExecutorConfig { workers, ..base });
        assert_eq!(util.len(), workers);
        assert!(util.iter().all(|&u| (0.0..=1.0).contains(&u)));
        assert_eq!(
            blocks, blocks1,
            "{workers} workers recovered different bytes/targets than 1 worker"
        );
        assert_eq!(
            snap, snap1,
            "{workers} workers drifted the rack byte accounting"
        );
    }
}

#[test]
fn chunk_sizes_recover_identical_bytes_and_metrics() {
    // whole-block, aligned sub-chunks, and a deliberately odd chunk size
    let base = ExecutorConfig { workers: 4, ..ExecutorConfig::default() };
    let (blocks_whole, snap_whole, _) =
        recover_fixture(ExecutorConfig { chunk_size: BLOCK as u64, ..base });
    for chunk in [16u64 << 10, 7 * 1024 + 13] {
        let (blocks, snap, _) =
            recover_fixture(ExecutorConfig { chunk_size: chunk, ..base });
        assert_eq!(blocks, blocks_whole, "chunk={chunk} changed recovered bytes");
        assert_eq!(snap, snap_whole, "chunk={chunk} changed byte accounting");
    }
}

#[test]
fn schedule_policies_recover_identical_bytes_and_metrics() {
    // the balanced wavefront may reorder and coalesce tasks freely, but
    // recovered bytes and per-rack byte accounting must be identical to
    // FIFO for every worker count, window size, and fetch mode
    let base = ExecutorConfig { chunk_size: 8 << 10, ..ExecutorConfig::default() };
    let (blocks0, snap0, _) = recover_fixture(ExecutorConfig {
        workers: 1,
        schedule: SchedulePolicy::Fifo,
        ..base
    });
    let cases = [
        (2usize, SchedulePolicy::Balanced, 1usize, true),
        (8, SchedulePolicy::Balanced, 1, true),
        (8, SchedulePolicy::Balanced, 4, true),
        (8, SchedulePolicy::Balanced, 3, false),
        (8, SchedulePolicy::Fifo, 2, false),
        (4, SchedulePolicy::Balanced, 2, true),
    ];
    for (workers, schedule, coalesce, batched_fetch) in cases {
        let cfg = ExecutorConfig { workers, schedule, coalesce, batched_fetch, ..base };
        let (blocks, snap, util) = recover_fixture(cfg);
        assert_eq!(util.len(), workers);
        assert_eq!(
            blocks, blocks0,
            "{schedule}/{workers}w/coalesce={coalesce}/batched={batched_fetch} \
             recovered different bytes or targets"
        );
        assert_eq!(
            snap, snap0,
            "{schedule}/{workers}w/coalesce={coalesce}/batched={batched_fetch} \
             drifted the rack byte accounting"
        );
    }
}

#[test]
fn recovered_bytes_match_the_originals() {
    // determinism alone could hide a consistently-wrong decode; pin the
    // content against the written data (data blocks) too
    let (blocks, _, _) = recover_fixture(ExecutorConfig {
        workers: 8,
        chunk_size: 8 << 10,
        ..ExecutorConfig::default()
    });
    let mut data_checked = 0usize;
    for (sid, b, _, bytes) in blocks {
        if b < 3 {
            assert_eq!(bytes, data_for(sid, 3)[b], "sid={sid} b={b}");
            data_checked += 1;
        }
    }
    assert!(data_checked > 0, "fixture never lost a data block");
}
