//! Differential tests for the fused GF combine engine (DESIGN.md §9,
//! §12): the wide-word, table-cached, cache-blocked kernels must be
//! byte-identical to a naive per-byte `gf::mul` accumulation for every
//! coefficient class (0, 1, arbitrary), every small length, large
//! unaligned lengths that straddle the fusion block, and
//! mixed-coefficient source sets — on **every lane this CPU can run**
//! (scalar oracle, SWAR, and the AVX2/NEON shuffle kernels when
//! detected), forced through the `dispatch::*_lane` surface so one test
//! process covers them all regardless of `D3_FORCE_KERNEL`.

use d3ec::gf;
use d3ec::gf::dispatch::{self, Lane};
use d3ec::gf::kernel::{combine_many_into_lane, FUSE_BLOCK};
use d3ec::util::rng::xorshift_bytes as bytes;

/// The scalar reference: per-byte multiply-accumulate over `gf::mul`
/// (itself exhaustively pinned against the polynomial basis in gf::tests).
fn mac_ref(acc: &mut [u8], c: u8, src: &[u8]) {
    for (a, &s) in acc.iter_mut().zip(src) {
        *a ^= gf::mul(c, s);
    }
}

/// Every coefficient class: the no-op lane, the XOR lane, a generator
/// power, a high-bit value, and the all-ones byte.
const COEFF_CLASSES: [u8; 6] = [0, 1, 2, 0x8e, 0x80, 0xff];

#[test]
fn swar_xor_lane_matches_scalar_for_every_length_0_to_64() {
    let src = bytes(64, 7);
    for len in 0..=64 {
        let mut acc = bytes(len, 8);
        let mut want = acc.clone();
        mac_ref(&mut want, 1, &src[..len]);
        gf::xor_into(&mut acc, &src[..len]);
        assert_eq!(acc, want, "len={len}");
    }
}

#[test]
fn swar_xor_lane_matches_scalar_for_large_unaligned_lengths() {
    // prime-ish lengths around and beyond the 16 KiB fusion block, never
    // a multiple of the 8-byte SWAR word
    for len in [4093usize, (16 << 10) - 1, (16 << 10) + 9, 100_003] {
        let src = bytes(len, len as u64);
        let mut acc = bytes(len, 13);
        let mut want = acc.clone();
        mac_ref(&mut want, 1, &src);
        gf::xor_into(&mut acc, &src);
        assert_eq!(acc, want, "len={len}");
    }
}

#[test]
fn combine_into_matches_reference_for_all_coefficient_classes() {
    let src = bytes(611, 5);
    for &c in &COEFF_CLASSES {
        for len in [0usize, 1, 7, 8, 9, 63, 64, 65, 611] {
            let mut acc = bytes(len, 77);
            let mut want = acc.clone();
            mac_ref(&mut want, c, &src[..len]);
            gf::combine_into(&mut acc, c, &src[..len]);
            assert_eq!(acc, want, "c={c} len={len}");
        }
    }
}

#[test]
fn fused_combine_matches_reference_for_every_length_0_to_64() {
    // k = 3 with one coefficient from each class per position
    let srcs: Vec<Vec<u8>> = (0..3).map(|i| bytes(64, 100 + i)).collect();
    for &c0 in &[0u8, 1, 0x8e] {
        for &c1 in &[1u8, 0x53] {
            let coeffs = [c0, c1, 0xff];
            for len in 0..=64usize {
                let mut acc = bytes(len, 9);
                let mut want = acc.clone();
                for (&c, src) in coeffs.iter().zip(&srcs) {
                    mac_ref(&mut want, c, &src[..len]);
                }
                let pairs: Vec<(u8, &[u8])> =
                    coeffs.iter().zip(&srcs).map(|(&c, s)| (c, &s[..len])).collect();
                gf::combine_many_into(&mut acc, &pairs);
                assert_eq!(acc, want, "coeffs={coeffs:?} len={len}");
            }
        }
    }
}

#[test]
fn fused_combine_matches_reference_across_fusion_block_boundaries() {
    // lengths that exercise: exactly one block, one block ± 1, several
    // blocks plus an unaligned tail
    let block = 16 << 10;
    for len in [block - 1, block, block + 1, 3 * block + 4093] {
        let k = 6;
        let srcs: Vec<Vec<u8>> = (0..k).map(|i| bytes(len, 1000 + i as u64)).collect();
        let coeffs: Vec<u8> = (0..k).map(|i| COEFF_CLASSES[i % COEFF_CLASSES.len()]).collect();
        let mut acc = bytes(len, 31);
        let mut want = acc.clone();
        for (&c, src) in coeffs.iter().zip(&srcs) {
            mac_ref(&mut want, c, src);
        }
        let pairs: Vec<(u8, &[u8])> =
            coeffs.iter().zip(&srcs).map(|(&c, s)| (c, s.as_slice())).collect();
        gf::combine_many_into(&mut acc, &pairs);
        assert_eq!(acc, want, "len={len}");
    }
}

#[test]
fn fused_combine_equals_sequential_combine_into() {
    // the fused engine must agree with the sequential per-source path it
    // replaced, for a randomized mixed-coefficient source set
    let len = 40_961; // 2.5 fusion blocks + 1
    let k = 8;
    let srcs: Vec<Vec<u8>> = (0..k).map(|i| bytes(len, 2000 + i as u64)).collect();
    let coeffs = bytes(k, 0xc0ffee);
    let mut fused = vec![0u8; len];
    let pairs: Vec<(u8, &[u8])> =
        coeffs.iter().zip(&srcs).map(|(&c, s)| (c, s.as_slice())).collect();
    gf::combine_many_into(&mut fused, &pairs);
    let mut seq = vec![0u8; len];
    for (&c, src) in coeffs.iter().zip(&srcs) {
        gf::combine_into(&mut seq, c, src);
    }
    assert_eq!(fused, seq);
}

#[test]
fn every_lane_mac_matches_reference_for_lengths_0_to_64() {
    // full coefficient-class × length sweep on each runnable lane; the
    // lane surface routes 0 and 1 through the MAC kernel too, so the
    // shuffle tables for those degenerate coefficients are also covered
    let src = bytes(64, 21);
    for lane in dispatch::available_lanes() {
        for &c in &COEFF_CLASSES {
            for len in 0..=64usize {
                let mut acc = bytes(len, 22);
                let mut want = acc.clone();
                mac_ref(&mut want, c, &src[..len]);
                dispatch::mac_into_lane(lane, c, &mut acc, &src[..len]);
                assert_eq!(acc, want, "lane={lane:?} c={c} len={len}");
            }
        }
    }
}

#[test]
fn every_lane_handles_unaligned_offsets_1_to_31() {
    // slide the window start across every sub-vector offset (AVX2 reads
    // 32 bytes, NEON 16, SWAR 8 — 1..=31 misaligns all of them) so the
    // unaligned loads and ragged heads/tails are exercised directly
    let n = 4096;
    let src = bytes(n, 23);
    let base = bytes(n, 24);
    for lane in dispatch::available_lanes() {
        for off in 1..=31usize {
            let mut acc = base.clone();
            let mut want = base.clone();
            mac_ref(&mut want[off..], 0x8e, &src[off..]);
            dispatch::mac_into_lane(lane, 0x8e, &mut acc[off..], &src[off..]);
            assert_eq!(acc, want, "lane={lane:?} mac off={off}");
            let mut acc = base.clone();
            let mut want = base.clone();
            mac_ref(&mut want[off..], 1, &src[off..]);
            dispatch::xor_into_lane(lane, &mut acc[off..], &src[off..]);
            assert_eq!(acc, want, "lane={lane:?} xor off={off}");
        }
    }
}

#[test]
fn every_lane_fused_combine_matches_reference_for_mixed_sets() {
    // k = 6 with all three coefficient classes present, at lengths on
    // both sides of the fusion-block boundary, on every runnable lane
    let k = 6;
    for lane in dispatch::available_lanes() {
        for len in [63usize, 4093, FUSE_BLOCK - 1, FUSE_BLOCK + 1, 2 * FUSE_BLOCK + 77] {
            let srcs: Vec<Vec<u8>> = (0..k).map(|i| bytes(len, 3000 + i as u64)).collect();
            let coeffs: Vec<u8> =
                (0..k).map(|i| COEFF_CLASSES[i % COEFF_CLASSES.len()]).collect();
            let mut acc = bytes(len, 25);
            let mut want = acc.clone();
            for (&c, src) in coeffs.iter().zip(&srcs) {
                mac_ref(&mut want, c, src);
            }
            let pairs: Vec<(u8, &[u8])> =
                coeffs.iter().zip(&srcs).map(|(&c, s)| (c, s.as_slice())).collect();
            combine_many_into_lane(lane, &mut acc, &pairs);
            assert_eq!(acc, want, "lane={lane:?} len={len}");
        }
    }
}

#[test]
fn forced_lane_resolution_matches_documented_policy() {
    // the pure resolver behind D3_FORCE_KERNEL: known lanes pin, simd
    // falls back when undetected, junk falls back — and whatever the
    // process actually selected must be runnable here
    assert_eq!(dispatch::resolve_lane(Some("scalar")), Lane::Scalar);
    assert_eq!(dispatch::resolve_lane(Some("swar")), Lane::Swar);
    let best = dispatch::resolve_lane(None);
    if dispatch::simd_available() {
        assert_eq!(best, Lane::Simd);
    } else {
        assert_eq!(best, Lane::Swar);
        assert_eq!(dispatch::resolve_lane(Some("simd")), Lane::Swar);
    }
    assert_eq!(dispatch::resolve_lane(Some("sse9")), best);
    assert!(dispatch::available_lanes().contains(&dispatch::active_lane()));
}

#[test]
fn gf_combine_wrapper_runs_through_the_fused_engine_correctly() {
    let len = 1025;
    let a = bytes(len, 1);
    let b = bytes(len, 2);
    let c = bytes(len, 3);
    let got = gf::combine(&[0x1d, 1, 0], &[&a, &b, &c]);
    let mut want = vec![0u8; len];
    mac_ref(&mut want, 0x1d, &a);
    mac_ref(&mut want, 1, &b);
    assert_eq!(got, want);
}
