//! Differential tests for the fused GF combine engine (DESIGN.md §9): the
//! wide-word, table-cached, cache-blocked kernels must be byte-identical
//! to a naive per-byte `gf::mul` accumulation for every coefficient class
//! (0, 1, arbitrary), every small length, large unaligned lengths that
//! straddle the fusion block, and mixed-coefficient source sets.

use d3ec::gf;
use d3ec::util::rng::xorshift_bytes as bytes;

/// The scalar reference: per-byte multiply-accumulate over `gf::mul`
/// (itself exhaustively pinned against the polynomial basis in gf::tests).
fn mac_ref(acc: &mut [u8], c: u8, src: &[u8]) {
    for (a, &s) in acc.iter_mut().zip(src) {
        *a ^= gf::mul(c, s);
    }
}

/// Every coefficient class: the no-op lane, the XOR lane, a generator
/// power, a high-bit value, and the all-ones byte.
const COEFF_CLASSES: [u8; 6] = [0, 1, 2, 0x8e, 0x80, 0xff];

#[test]
fn swar_xor_lane_matches_scalar_for_every_length_0_to_64() {
    let src = bytes(64, 7);
    for len in 0..=64 {
        let mut acc = bytes(len, 8);
        let mut want = acc.clone();
        mac_ref(&mut want, 1, &src[..len]);
        gf::xor_into(&mut acc, &src[..len]);
        assert_eq!(acc, want, "len={len}");
    }
}

#[test]
fn swar_xor_lane_matches_scalar_for_large_unaligned_lengths() {
    // prime-ish lengths around and beyond the 16 KiB fusion block, never
    // a multiple of the 8-byte SWAR word
    for len in [4093usize, (16 << 10) - 1, (16 << 10) + 9, 100_003] {
        let src = bytes(len, len as u64);
        let mut acc = bytes(len, 13);
        let mut want = acc.clone();
        mac_ref(&mut want, 1, &src);
        gf::xor_into(&mut acc, &src);
        assert_eq!(acc, want, "len={len}");
    }
}

#[test]
fn combine_into_matches_reference_for_all_coefficient_classes() {
    let src = bytes(611, 5);
    for &c in &COEFF_CLASSES {
        for len in [0usize, 1, 7, 8, 9, 63, 64, 65, 611] {
            let mut acc = bytes(len, 77);
            let mut want = acc.clone();
            mac_ref(&mut want, c, &src[..len]);
            gf::combine_into(&mut acc, c, &src[..len]);
            assert_eq!(acc, want, "c={c} len={len}");
        }
    }
}

#[test]
fn fused_combine_matches_reference_for_every_length_0_to_64() {
    // k = 3 with one coefficient from each class per position
    let srcs: Vec<Vec<u8>> = (0..3).map(|i| bytes(64, 100 + i)).collect();
    for &c0 in &[0u8, 1, 0x8e] {
        for &c1 in &[1u8, 0x53] {
            let coeffs = [c0, c1, 0xff];
            for len in 0..=64usize {
                let mut acc = bytes(len, 9);
                let mut want = acc.clone();
                for (&c, src) in coeffs.iter().zip(&srcs) {
                    mac_ref(&mut want, c, &src[..len]);
                }
                let pairs: Vec<(u8, &[u8])> =
                    coeffs.iter().zip(&srcs).map(|(&c, s)| (c, &s[..len])).collect();
                gf::combine_many_into(&mut acc, &pairs);
                assert_eq!(acc, want, "coeffs={coeffs:?} len={len}");
            }
        }
    }
}

#[test]
fn fused_combine_matches_reference_across_fusion_block_boundaries() {
    // lengths that exercise: exactly one block, one block ± 1, several
    // blocks plus an unaligned tail
    let block = 16 << 10;
    for len in [block - 1, block, block + 1, 3 * block + 4093] {
        let k = 6;
        let srcs: Vec<Vec<u8>> = (0..k).map(|i| bytes(len, 1000 + i as u64)).collect();
        let coeffs: Vec<u8> = (0..k).map(|i| COEFF_CLASSES[i % COEFF_CLASSES.len()]).collect();
        let mut acc = bytes(len, 31);
        let mut want = acc.clone();
        for (&c, src) in coeffs.iter().zip(&srcs) {
            mac_ref(&mut want, c, src);
        }
        let pairs: Vec<(u8, &[u8])> =
            coeffs.iter().zip(&srcs).map(|(&c, s)| (c, s.as_slice())).collect();
        gf::combine_many_into(&mut acc, &pairs);
        assert_eq!(acc, want, "len={len}");
    }
}

#[test]
fn fused_combine_equals_sequential_combine_into() {
    // the fused engine must agree with the sequential per-source path it
    // replaced, for a randomized mixed-coefficient source set
    let len = 40_961; // 2.5 fusion blocks + 1
    let k = 8;
    let srcs: Vec<Vec<u8>> = (0..k).map(|i| bytes(len, 2000 + i as u64)).collect();
    let coeffs = bytes(k, 0xc0ffee);
    let mut fused = vec![0u8; len];
    let pairs: Vec<(u8, &[u8])> =
        coeffs.iter().zip(&srcs).map(|(&c, s)| (c, s.as_slice())).collect();
    gf::combine_many_into(&mut fused, &pairs);
    let mut seq = vec![0u8; len];
    for (&c, src) in coeffs.iter().zip(&srcs) {
        gf::combine_into(&mut seq, c, src);
    }
    assert_eq!(fused, seq);
}

#[test]
fn gf_combine_wrapper_runs_through_the_fused_engine_correctly() {
    let len = 1025;
    let a = bytes(len, 1);
    let b = bytes(len, 2);
    let c = bytes(len, 3);
    let got = gf::combine(&[0x1d, 1, 0], &[&a, &b, &c]);
    let mut want = vec![0u8; len];
    mac_ref(&mut want, 0x1d, &a);
    mac_ref(&mut want, 1, &b);
    assert_eq!(got, want);
}
