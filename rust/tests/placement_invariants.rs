//! Placement-invariant suite (ISSUE 1 satellite): `rack_limit_ok` and
//! `nodes_distinct` must hold for every policy — D³, D³-LRC, RDD, HDD —
//! across several (k, m) configurations and cluster shapes, including
//! after recovery-target placement.

use d3ec::codes::CodeSpec;
use d3ec::placement::{
    D3LrcPlacement, D3Placement, HddPlacement, Placement, RddPlacement,
};
use d3ec::topology::ClusterSpec;

/// Valid D³/RS combinations: (k, m, racks, nodes_per_rack).
const RS_CONFIGS: &[(usize, usize, usize, usize)] = &[
    (2, 1, 8, 3),
    (2, 1, 5, 3),
    (3, 2, 8, 3),
    (3, 2, 5, 3),
    (3, 2, 11, 4),
    (4, 2, 8, 3),
    (6, 3, 8, 3),
    (6, 3, 11, 4),
];

/// Valid D³-LRC combinations: (k, l, g, racks, nodes_per_rack).
const LRC_CONFIGS: &[(usize, usize, usize, usize, usize)] = &[
    (4, 2, 1, 8, 3),
    (4, 2, 1, 9, 3),
    (6, 2, 2, 11, 4),
];

/// `target_keeps_rack_limit`: D³, D³-LRC, and HDD re-establish the rack
/// limit when placing the recovered copy; RDD deliberately does not
/// (paper §6.1 — node-level exclusion only), so only the node invariant is
/// asserted for it.
fn check_policy(
    policy: &dyn Placement,
    stripes: u64,
    label: &str,
    target_keeps_rack_limit: bool,
) {
    let limit = policy.code().rack_limit();
    for sid in 0..stripes {
        let sp = policy.stripe(sid);
        assert_eq!(sp.locs.len(), policy.code().len(), "{label} sid={sid}");
        assert!(sp.nodes_distinct(), "{label} sid={sid}: node collision");
        assert!(
            sp.rack_limit_ok(limit),
            "{label} sid={sid}: more than {limit} blocks in one rack"
        );
        // the recovered copy of any block keeps the node invariant
        let bi = sid as usize % sp.locs.len();
        let tgt = policy.recovery_target(sid, bi, sp.locs[bi]);
        let mut locs = sp.locs.clone();
        locs[bi] = tgt;
        let moved = d3ec::placement::StripePlacement { locs };
        assert!(moved.nodes_distinct(), "{label} sid={sid}: target collides");
        if target_keeps_rack_limit {
            assert!(
                moved.rack_limit_ok(limit),
                "{label} sid={sid}: target breaks the rack limit"
            );
        }
    }
}

#[test]
fn d3_rs_invariants_across_configs() {
    for &(k, m, r, n) in RS_CONFIGS {
        let code = CodeSpec::Rs { k, m };
        let cluster = ClusterSpec::new(r, n);
        let p = D3Placement::new(code, cluster)
            .unwrap_or_else(|e| panic!("({k},{m}) on {r}x{n}: {e}"));
        // at least one full placement cycle when affordable
        let cycle = p.period().unwrap_or(500).min(1200);
        check_policy(&p, cycle, &format!("d3 ({k},{m}) {r}x{n}"), true);
    }
}

#[test]
fn d3_lrc_invariants_across_configs() {
    for &(k, l, g, r, n) in LRC_CONFIGS {
        let code = CodeSpec::Lrc { k, l, g };
        let cluster = ClusterSpec::new(r, n);
        let p = D3LrcPlacement::new(code, cluster)
            .unwrap_or_else(|e| panic!("({k},{l},{g}) on {r}x{n}: {e}"));
        check_policy(&p, 500, &format!("d3-lrc ({k},{l},{g}) {r}x{n}"), true);
    }
}

#[test]
fn rdd_invariants_across_configs() {
    for &(k, m, r, n) in RS_CONFIGS {
        let code = CodeSpec::Rs { k, m };
        let cluster = ClusterSpec::new(r, n);
        if cluster.node_count() < code.len() + 1 {
            continue;
        }
        for seed in [1u64, 9] {
            let p = RddPlacement::new(code, cluster, seed);
            check_policy(&p, 300, &format!("rdd ({k},{m}) {r}x{n} seed={seed}"), false);
        }
    }
    // LRC under RDD (rack limit 1)
    let p = RddPlacement::new(
        CodeSpec::Lrc { k: 4, l: 2, g: 1 },
        ClusterSpec::new(8, 3),
        3,
    );
    check_policy(&p, 300, "rdd (4,2,1)-lrc 8x3", false);
}

#[test]
fn hdd_invariants_across_configs() {
    for &(k, m, r, n) in RS_CONFIGS {
        let code = CodeSpec::Rs { k, m };
        let cluster = ClusterSpec::new(r, n);
        if cluster.node_count() < code.len() + 1 {
            continue;
        }
        let p = HddPlacement::new(code, cluster, 2);
        check_policy(&p, 300, &format!("hdd ({k},{m}) {r}x{n}"), true);
    }
    let p = HddPlacement::new(
        CodeSpec::Lrc { k: 4, l: 2, g: 1 },
        ClusterSpec::new(8, 3),
        2,
    );
    check_policy(&p, 300, "hdd (4,2,1)-lrc 8x3", true);
}
