//! Differential suite for the DESIGN.md §16 block-store refactor: the
//! synthetic regenerate-on-read store must be observationally identical
//! to the materialized store everywhere except resident memory. Scenario
//! outcomes (blocks, bytes, per-rack byte accounting, λ, plan structure)
//! are compared field-for-field, block reads are compared byte-for-byte,
//! and the scrub/repair loop is exercised against the synthetic overlay.
//! Wall-clock fields (seconds, latency values) are explicitly *not*
//! compared — only sample counts.

use std::sync::Arc;

use d3ec::client::FgSpec;
use d3ec::cluster::fabric::run_scrub;
use d3ec::cluster::{
    deterministic_data, BlockFabric, ClusterBackend, MiniCluster, StoreMode,
};
use d3ec::codes::CodeSpec;
use d3ec::placement::{D3Placement, Placement};
use d3ec::recovery::ExecutorConfig;
use d3ec::scenario::{FailureScenario, RecoveryBackend, ScenarioOutcome};
use d3ec::topology::{Location, SystemSpec};

fn d3_policy(spec: &SystemSpec, code: CodeSpec) -> Arc<dyn Placement> {
    Arc::new(D3Placement::new(code, spec.cluster).unwrap())
}

fn backend(store: StoreMode, cache_mb: u64) -> ClusterBackend {
    ClusterBackend { block_size: 16 << 10, store, cache_mb, ..ClusterBackend::default() }
}

/// The deterministic half of a [`ScenarioOutcome`]: everything that must
/// be bit-identical across block-store representations.
fn deterministic_fields(
    out: &ScenarioOutcome,
) -> (usize, u64, usize, f64, Vec<(u64, u64)>, Option<usize>) {
    (
        out.blocks,
        out.bytes,
        out.planned_cross_rack_blocks,
        out.lambda,
        out.rack_cross_bytes.clone(),
        out.fg_latency.as_ref().map(|s| s.count),
    )
}

#[test]
fn synthetic_and_materialized_backends_agree_exactly() {
    let spec = SystemSpec::paper_default();
    let policy = d3_policy(&spec, CodeSpec::Rs { k: 6, m: 3 });
    let scenarios = [
        FailureScenario::single_node(40, 2),
        FailureScenario::multi_node(2, 40, 9),
        FailureScenario::rack_failure(0, 30, 3),
        FailureScenario::degraded_burst(24, 30, 5),
    ];
    for scenario in scenarios {
        let mat = backend(StoreMode::Materialized, 0).run(&scenario, &policy, &spec).unwrap();
        let syn = backend(StoreMode::Synthetic, 0).run(&scenario, &policy, &spec).unwrap();
        assert!(mat.blocks > 0, "{}: empty scenario", scenario.name());
        assert_eq!(
            deterministic_fields(&mat),
            deterministic_fields(&syn),
            "{}: synthetic store diverged from materialized",
            scenario.name()
        );
    }
}

#[test]
fn synthetic_cluster_serves_byte_identical_blocks() {
    let mut spec = SystemSpec::paper_default();
    spec.block_size = 16 << 10;
    spec.net.inner_mbps = 8000.0;
    spec.net.cross_mbps = 1600.0;
    let code = CodeSpec::Rs { k: 3, m: 2 };
    let policy = d3_policy(&spec, code);
    let stripes = 30u64;
    let bs = spec.block_size as usize;

    let written = MiniCluster::new(spec, policy.clone(), "native", 7).unwrap();
    written
        .write_stripes_parallel(stripes, 4, |sid| deterministic_data(sid, 3, bs))
        .unwrap();
    let synthetic = MiniCluster::new_synthetic(spec, policy.clone(), "native", 7).unwrap();
    synthetic.populate_synthetic(stripes).unwrap();

    let client = Location::new(0, 0);
    for sid in 0..stripes {
        for b in 0..code.len() {
            let want = written.read_block(sid, b, client).unwrap();
            let got = synthetic.read_block(sid, b, client).unwrap();
            assert_eq!(got, want, "sid={sid} b={b}: synthetic bytes diverged");
            assert_eq!(
                BlockFabric::stored_checksum(&synthetic, sid, b).unwrap(),
                BlockFabric::stored_checksum(&written, sid, b).unwrap(),
                "sid={sid} b={b}: checksum diverged"
            );
        }
    }

    // degraded reads reconstruct the same bytes on both representations
    let victim = written.locate(5, 1);
    written.fail_node(victim);
    synthetic.fail_node(victim);
    let (want, _) = written.degraded_read(5, 1, Location::new(1, 0)).unwrap();
    let (got, _) = synthetic.degraded_read(5, 1, Location::new(1, 0)).unwrap();
    assert_eq!(got, want, "degraded read diverged across stores");
}

#[test]
fn scrub_repairs_planted_corruption_on_the_synthetic_store() {
    let mut spec = SystemSpec::paper_default();
    spec.block_size = 16 << 10;
    spec.net.inner_mbps = 8000.0;
    spec.net.cross_mbps = 1600.0;
    let policy = d3_policy(&spec, CodeSpec::Rs { k: 3, m: 2 });
    let stripes = 20u64;
    let cluster = MiniCluster::new_synthetic(spec, policy.clone(), "native", 3).unwrap();
    cluster.populate_synthetic(stripes).unwrap();

    // two corruptions in the same stripe force the multi-erasure planner;
    // the synthetic store represents them as overlay entries over an
    // otherwise unmaterialized base population
    let planted = [(2u64, 0usize), (2, 1), (7, 4)];
    for &(sid, b) in &planted {
        cluster.corrupt_stored(sid, b).unwrap();
        assert_ne!(
            BlockFabric::stored_checksum(&cluster, sid, b).unwrap(),
            cluster.expected_checksum(sid, b).unwrap(),
            "corruption did not take on the synthetic overlay"
        );
    }
    let cfg = ExecutorConfig { workers: 4, ..ExecutorConfig::default() };
    let report = run_scrub(&cluster, policy.as_ref(), stripes, cfg, 3).unwrap();
    assert_eq!(report.scanned, stripes * cluster.code().len() as u64);
    assert_eq!(report.quarantined, planted.len() as u64);
    assert_eq!(report.repaired, planted.len() as u64);
    // every repaired block matches the write-time oracle again
    for &(sid, b) in &planted {
        assert_eq!(
            BlockFabric::stored_checksum(&cluster, sid, b).unwrap(),
            cluster.expected_checksum(sid, b).unwrap(),
        );
    }
    let again = run_scrub(&cluster, policy.as_ref(), stripes, cfg, 3).unwrap();
    assert_eq!(again.quarantined, 0, "scrub re-quarantined a repaired block");
}

#[test]
fn auto_mode_picks_synthetic_only_past_the_footprint_threshold() {
    // 40 stripes x 9 blocks x 16 KiB = 5.6 MB: stays materialized
    assert!(!StoreMode::Auto.synthetic_for(40, 9, 16 << 10));
    // the ISSUE's 10k-node invocation: 2M stripes x 9 x 256 KiB = 4.5 TB
    assert!(StoreMode::Auto.synthetic_for(2_000_000, 9, 256 << 10));
    assert!(!StoreMode::Materialized.synthetic_for(2_000_000, 9, 256 << 10));
    assert!(StoreMode::Synthetic.synthetic_for(1, 9, 16 << 10));
}

#[test]
fn warm_cache_bends_the_zipf_degraded_read_tail() {
    // Zipf-skewed degraded burst: the same hot lost blocks are hit over
    // and over, so with the cache tier on, all but the first touches are
    // served from memory and skip both the store and the modeled links.
    // With enough requests, the tail lands in cache-hit territory too.
    let spec = SystemSpec::paper_default();
    let policy = d3_policy(&spec, CodeSpec::Rs { k: 6, m: 3 });
    let reads = 4000;
    let scenario = FailureScenario::degraded_burst(reads, 16, 7)
        .with_fg(FgSpec::burst(reads).with_zipf(1.2));

    let off = backend(StoreMode::Synthetic, 0).run(&scenario, &policy, &spec).unwrap();
    let on = backend(StoreMode::Synthetic, 64).run(&scenario, &policy, &spec).unwrap();
    let off_lat = off.fg_latency.expect("burst always reports latency");
    let on_lat = on.fg_latency.expect("burst always reports latency");
    assert_eq!(off_lat.count, reads);
    assert_eq!(on_lat.count, reads);
    assert!(
        on_lat.p50 < off_lat.p50,
        "cache did not bend the median: on {} vs off {}",
        on_lat.p50,
        off_lat.p50
    );
    assert!(
        on_lat.p99 < off_lat.p99,
        "cache did not bend the tail: on {} vs off {}",
        on_lat.p99,
        off_lat.p99
    );
}
