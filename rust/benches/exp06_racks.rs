//! Regenerates Fig 14 (Exp 6: number of racks) at the paper's configuration.
//! Run: `cargo bench --bench exp06_racks` (all benches: `cargo bench`).
use d3ec::experiments as exp;
use d3ec::topology::SystemSpec;

fn main() {
    let spec = SystemSpec::paper_default();
    let t0 = std::time::Instant::now();
    let _ = exp::exp06_racks(&spec, exp::STRIPES);
    eprintln!("[exp06_racks] completed in {:.2?}", t0.elapsed());
}
