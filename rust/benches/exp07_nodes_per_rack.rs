//! Regenerates Fig 15 (Exp 7: nodes per rack) at the paper's configuration.
//! Run: `cargo bench --bench exp07_nodes_per_rack` (all benches: `cargo bench`).
use d3ec::experiments as exp;
use d3ec::topology::SystemSpec;

fn main() {
    let spec = SystemSpec::paper_default();
    let t0 = std::time::Instant::now();
    let _ = exp::exp07_nodes_per_rack(&spec, exp::STRIPES);
    eprintln!("[exp07_nodes_per_rack] completed in {:.2?}", t0.elapsed());
}
