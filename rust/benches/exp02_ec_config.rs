//! Regenerates Fig 9 (Exp 2: erasure-code configuration) at the paper's configuration.
//! Run: `cargo bench --bench exp02_ec_config` (all benches: `cargo bench`).
use d3ec::experiments as exp;
use d3ec::topology::SystemSpec;

fn main() {
    let spec = SystemSpec::paper_default();
    let t0 = std::time::Instant::now();
    let _ = exp::exp02_ec_config(&spec, exp::STRIPES);
    eprintln!("[exp02_ec_config] completed in {:.2?}", t0.elapsed());
}
