//! Regenerates Figs 18/19 (Exps 10-11: front-end benchmarks) at the paper's configuration.
//! Run: `cargo bench --bench exp10_frontend` (all benches: `cargo bench`).
use d3ec::experiments as exp;
use d3ec::topology::SystemSpec;

fn main() {
    let spec = SystemSpec::paper_default();
    let t0 = std::time::Instant::now();
    let _ = exp::frontend_exp::exp10_frontend_normal(&spec);
    let _ = exp::frontend_exp::exp11_frontend_recovery(&spec, 3000);
    eprintln!("[exp10_frontend] completed in {:.2?}", t0.elapsed());
}
