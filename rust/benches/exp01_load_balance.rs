//! Regenerates Fig 8 (Exp 1: repair load balance) at the paper's configuration.
//! Run: `cargo bench --bench exp01_load_balance` (all benches: `cargo bench`).
use d3ec::experiments as exp;
use d3ec::topology::SystemSpec;

fn main() {
    let spec = SystemSpec::paper_default();
    let t0 = std::time::Instant::now();
    let _ = exp::exp01_load_balance(&spec, exp::STRIPES);
    eprintln!("[exp01_load_balance] completed in {:.2?}", t0.elapsed());
}
