//! Regenerates Fig 12 (Exp 4: block size) at the paper's configuration.
//! Run: `cargo bench --bench exp04_block_size` (all benches: `cargo bench`).
use d3ec::experiments as exp;
use d3ec::topology::SystemSpec;

fn main() {
    let spec = SystemSpec::paper_default();
    let t0 = std::time::Instant::now();
    let _ = exp::exp04_block_size(&spec, exp::STRIPES);
    eprintln!("[exp04_block_size] completed in {:.2?}", t0.elapsed());
}
