//! Regenerates Fig 13 (Exp 5: cross-rack bandwidth) at the paper's configuration.
//! Run: `cargo bench --bench exp05_bandwidth` (all benches: `cargo bench`).
use d3ec::experiments as exp;
use d3ec::topology::SystemSpec;

fn main() {
    let spec = SystemSpec::paper_default();
    let t0 = std::time::Instant::now();
    let _ = exp::exp05_bandwidth(&spec, exp::STRIPES);
    eprintln!("[exp05_bandwidth] completed in {:.2?}", t0.elapsed());
}
