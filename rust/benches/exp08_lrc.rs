//! Regenerates Figs 16/17 (Exps 8-9: LRC recovery + block size) at the paper's configuration.
//! Run: `cargo bench --bench exp08_lrc` (all benches: `cargo bench`).
use d3ec::experiments as exp;
use d3ec::topology::SystemSpec;

fn main() {
    let spec = SystemSpec::paper_default();
    let t0 = std::time::Instant::now();
    let _ = exp::exp08_lrc_recovery(&spec, exp::STRIPES);
    let _ = exp::exp09_lrc_block_size(&spec, exp::STRIPES);
    eprintln!("[exp08_lrc] completed in {:.2?}", t0.elapsed());
}
