//! Ablations of D³'s design decisions (DESIGN.md §6): knock out one
//! balancing mechanism at a time and measure recovery on the paper's
//! default testbed, plus the batch-synchronized scheduler variant.
use d3ec::experiments::{avg_recovery, build_policy};
use d3ec::codes::CodeSpec;
use d3ec::recovery::node_recovery_plans;
use d3ec::sim::recovery::{run_recovery, RecoveryConfig};
use d3ec::topology::{Location, SystemSpec};

fn main() {
    let spec = SystemSpec::paper_default();
    let code = CodeSpec::Rs { k: 3, m: 2 };
    println!("\n=== Ablation: D³ mechanisms — (3,2)-RS, 8 racks × 3 nodes ===");
    println!("variant\tthroughput(MB/s)\tlambda");
    for name in ["d3", "d3-norot", "d3-rr", "rdd", "hdd"] {
        let policy = build_policy(name, code, &spec, 5);
        let out = avg_recovery(&policy, &spec, 1008, 5, 5);
        println!("{name}\t{:.1}\t{:.3}", out.throughput_mb_s, out.lambda);
    }
    println!("\n=== Ablation: scheduler — continuous vs barrier waves ===");
    println!("policy\tscheduler\tthroughput(MB/s)");
    let failed = Location::new(1, 0);
    for name in ["d3", "rdd"] {
        for (label, sync) in [("continuous", false), ("waves", true)] {
            let policy = build_policy(name, code, &spec, 3);
            let plans = node_recovery_plans(policy.as_ref(), 1008, failed, 3);
            let out = run_recovery(
                &spec,
                &plans,
                failed,
                RecoveryConfig { streams_per_node: 8, batch_sync: sync, ..Default::default() },
            );
            println!("{name}\t{label}\t{:.1}", out.throughput_mb_s);
        }
    }
    println!("\n=== Ablation: recovered-block placement (last 𝓜 column) ===");
    println!("(covered by d3-rr: round-robin region map also reroutes recovery racks)");
}
