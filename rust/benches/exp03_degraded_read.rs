//! Regenerates Figs 10/11 (Exp 3: degraded read) at the paper's configuration.
//! Run: `cargo bench --bench exp03_degraded_read` (all benches: `cargo bench`).
use d3ec::experiments as exp;
use d3ec::topology::SystemSpec;

fn main() {
    let spec = SystemSpec::paper_default();
    let t0 = std::time::Instant::now();
    let _ = exp::exp03_degraded_read(&spec);
    eprintln!("[exp03_degraded_read] completed in {:.2?}", t0.elapsed());
}
