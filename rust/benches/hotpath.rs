//! Hot-path microbenchmarks (§Perf): GF combine throughput native vs PJRT,
//! matrix inversion, placement lookups (raw OA arithmetic vs the
//! table-backed cache), and simulator event rate.
use d3ec::codes::CodeSpec;
use d3ec::gf;
use d3ec::placement::{D3Placement, Placement, PlacementTable};
use d3ec::recovery::node_recovery_plans;
use d3ec::runtime::Coder;
use d3ec::sim::recovery::{run_recovery, RecoveryConfig};
use d3ec::topology::{Location, SystemSpec};
use std::sync::Arc;
use std::time::Instant;

fn bench<F: FnMut()>(name: &str, iters: usize, mut f: F) -> f64 {
    f(); // warmup
    let t0 = Instant::now();
    for _ in 0..iters {
        f();
    }
    let per = t0.elapsed().as_secs_f64() / iters as f64;
    println!("{name}: {:.3} ms/iter", per * 1e3);
    per
}

fn main() {
    println!("=== hot path: GF combine (k=6, 16 MB blocks) ===");
    let len = 16 << 20;
    let shards: Vec<Vec<u8>> = (0..6).map(|i| vec![i as u8 + 1; len]).collect();
    let refs: Vec<&[u8]> = shards.iter().map(|v| v.as_slice()).collect();
    let coeffs: Vec<u8> = (1..=6u8).collect();

    let native = Coder::native();
    let per = bench("native combine", 5, || {
        let _ = native.combine(&coeffs, &refs).unwrap();
    });
    println!("  native: {:.0} MB/s output, {:.0} MB/s streamed", len as f64 / per / 1e6, (len * 6) as f64 / per / 1e6);

    match Coder::pjrt() {
        Ok(pjrt) => {
            let per = bench("pjrt combine", 5, || {
                let _ = pjrt.combine(&coeffs, &refs).unwrap();
            });
            println!("  pjrt: {:.0} MB/s output, {:.0} MB/s streamed", len as f64 / per / 1e6, (len * 6) as f64 / per / 1e6);
        }
        Err(e) => eprintln!("pjrt skipped: {e}"),
    }

    println!("\n=== hot path: xor fast path (c=1) ===");
    let per = bench("xor combine (k=2)", 10, || {
        let _ = gf::combine(&[1, 1], &[&refs[0], &refs[1]]);
    });
    println!("  {:.0} MB/s output", len as f64 / per / 1e6);

    println!("\n=== control path: placement + planning ===");
    let spec = SystemSpec::paper_default();
    let policy = D3Placement::new(CodeSpec::Rs { k: 6, m: 3 }, spec.cluster).unwrap();
    let raw = bench("stripe() x 10k (raw OA arithmetic)", 10, || {
        for sid in 0..10_000u64 {
            let _ = std::hint::black_box(policy.stripe(sid));
        }
    });
    let shared: Arc<dyn Placement> =
        Arc::new(D3Placement::new(CodeSpec::Rs { k: 6, m: 3 }, spec.cluster).unwrap());
    let table = PlacementTable::build(shared.clone(), 10_000);
    let cached = bench("stripe() x 10k (PlacementTable)", 10, || {
        for sid in 0..10_000u64 {
            let _ = std::hint::black_box(table.stripe(sid));
        }
    });
    println!(
        "  table-backed lookup: {:.1}x faster ({} cached stripes, {} fallbacks)",
        raw / cached,
        table.cached_stripes(),
        table.fallback_computes()
    );
    bench("node_recovery_plans(1000 stripes, raw)", 5, || {
        let _ = std::hint::black_box(node_recovery_plans(&policy, 1000, Location::new(0, 0), 0));
    });
    bench("node_recovery_plans(1000 stripes, table)", 5, || {
        let _ = std::hint::black_box(node_recovery_plans(&table, 1000, Location::new(0, 0), 0));
    });

    println!("\n=== simulator: full recovery run (1000 stripes) ===");
    let plans = node_recovery_plans(&policy, 1000, Location::new(0, 0), 0);
    println!("  plans: {}", plans.len());
    bench("run_recovery", 3, || {
        let _ = std::hint::black_box(run_recovery(
            &spec,
            &plans,
            Location::new(0, 0),
            RecoveryConfig::default(),
        ));
    });
}
