//! Hot-path microbenchmarks (§Perf, DESIGN.md §9): the fused GF combine
//! engine vs its scalar/sequential baselines, the zero-allocation
//! pipelined cluster recovery executor at 1 vs 8 workers (both via
//! [`d3ec::perf`], shared with `d3ctl bench`), plus GF combine native vs
//! PJRT, matrix/placement control-path lookups, and simulator event rate.
//!
//! `cargo bench --bench hotpath -- [--quick] [--json <path>]`
//!
//! `--json` writes the machine-readable `{bench_name: ns_per_byte}`
//! report (the perf-trajectory `BENCH_*.json` format); `--quick` is the
//! reduced-iteration CI mode.
use d3ec::codes::CodeSpec;
use d3ec::gf;
use d3ec::perf::{run_hotpath, BenchOpts};
use d3ec::placement::{D3Placement, Placement, PlacementTable};
use d3ec::recovery::node_recovery_plans;
use d3ec::runtime::Coder;
use d3ec::sim::recovery::{run_recovery, RecoveryConfig};
use d3ec::topology::{Location, SystemSpec};
use std::sync::Arc;
use std::time::Instant;

fn bench<F: FnMut()>(name: &str, iters: usize, mut f: F) -> f64 {
    f(); // warmup
    let t0 = Instant::now();
    for _ in 0..iters {
        f();
    }
    let per = t0.elapsed().as_secs_f64() / iters as f64;
    println!("{name}: {:.3} ms/iter", per * 1e3);
    per
}

fn main() {
    // args after `cargo bench --bench hotpath --`
    let args: Vec<String> = std::env::args().skip(1).collect();
    let quick = args.iter().any(|a| a == "--quick");
    let json_path = args
        .iter()
        .position(|a| a == "--json")
        .and_then(|i| args.get(i + 1))
        .map(std::path::PathBuf::from);

    // the fused-kernel + cluster-executor suite (shared with `d3ctl bench`)
    let report = run_hotpath(&BenchOpts { quick });
    if let Some(r) = report.ratio("combine_k6_sequential", "combine_k6_fused") {
        println!("headline: fused k=6 combine is {r:.2}x the sequential path");
    }
    if let Some(r) = report.ratio("sched_fifo_8w", "sched_balanced_8w") {
        println!("headline: balanced schedule is {r:.2}x FIFO on contended links");
    }
    if let Some(r) = report.ns_per_byte.get("simd_vs_swar_mac") {
        println!("headline: simd MAC lane is {r:.2}x the swar kernel");
    }
    if let Some(r) = report.ns_per_byte.get("encode_ingest_1w_vs_8w") {
        println!("headline: 8-writer encode ingest is {r:.2}x one writer");
    }
    if let Some(path) = &json_path {
        report.write_json(path).expect("write bench json");
        println!("wrote {} bench rows to {}", report.ns_per_byte.len(), path.display());
    }
    if quick {
        // CI quick mode stops at the machine-readable suite
        return;
    }

    println!("\n=== hot path: GF combine native vs PJRT (k=6, 16 MB blocks) ===");
    let len = 16 << 20;
    let shards: Vec<Vec<u8>> = (0..6).map(|i| vec![i as u8 + 1; len]).collect();
    let refs: Vec<&[u8]> = shards.iter().map(|v| v.as_slice()).collect();
    let coeffs: Vec<u8> = (1..=6u8).collect();

    let native = Coder::native();
    let per = bench("native combine", 5, || {
        let _ = native.combine(&coeffs, &refs).unwrap();
    });
    println!(
        "  native: {:.0} MB/s output, {:.0} MB/s streamed",
        len as f64 / per / 1e6,
        (len * 6) as f64 / per / 1e6
    );

    match Coder::pjrt() {
        Ok(pjrt) => {
            let per = bench("pjrt combine", 5, || {
                let _ = pjrt.combine(&coeffs, &refs).unwrap();
            });
            println!(
                "  pjrt: {:.0} MB/s output, {:.0} MB/s streamed",
                len as f64 / per / 1e6,
                (len * 6) as f64 / per / 1e6
            );
        }
        Err(e) => eprintln!("pjrt skipped: {e}"),
    }

    println!("\n=== hot path: slice-table MAC kernel vs per-byte reference ===");
    let mut acc = vec![0u8; len];
    let per_slice = bench("slice mac (c=0x8e, 16 MB, cached table)", 10, || {
        gf::kernel::table(0x8e).mac(&mut acc, &refs[0]);
    });
    println!("  slice kernel: {:.0} MB/s streamed", len as f64 / per_slice / 1e6);
    let per_ref = bench("per-byte gf::mul reference", 5, || {
        for (a, &s) in acc.iter_mut().zip(refs[0]) {
            *a ^= gf::mul(0x8e, s);
        }
    });
    println!(
        "  reference: {:.0} MB/s streamed → slice kernel {:.2}x",
        len as f64 / per_ref / 1e6,
        per_ref / per_slice
    );

    println!("\n=== control path: placement + planning ===");
    let spec = SystemSpec::paper_default();
    let policy = D3Placement::new(CodeSpec::Rs { k: 6, m: 3 }, spec.cluster).unwrap();
    let raw = bench("stripe() x 10k (raw OA arithmetic)", 10, || {
        for sid in 0..10_000u64 {
            let _ = std::hint::black_box(policy.stripe(sid));
        }
    });
    let shared: Arc<dyn Placement> =
        Arc::new(D3Placement::new(CodeSpec::Rs { k: 6, m: 3 }, spec.cluster).unwrap());
    let table = PlacementTable::build(shared.clone(), 10_000);
    let cached = bench("stripe() x 10k (PlacementTable)", 10, || {
        for sid in 0..10_000u64 {
            let _ = std::hint::black_box(table.stripe(sid));
        }
    });
    println!(
        "  table-backed lookup: {:.1}x faster ({} cached stripes, {} fallbacks)",
        raw / cached,
        table.cached_stripes(),
        table.fallback_computes()
    );
    bench("node_recovery_plans(1000 stripes, raw)", 5, || {
        let _ = std::hint::black_box(node_recovery_plans(&policy, 1000, Location::new(0, 0), 0));
    });
    bench("node_recovery_plans(1000 stripes, table)", 5, || {
        let _ = std::hint::black_box(node_recovery_plans(&table, 1000, Location::new(0, 0), 0));
    });

    println!("\n=== simulator: full recovery run (1000 stripes) ===");
    let plans = node_recovery_plans(&policy, 1000, Location::new(0, 0), 0);
    println!("  plans: {}", plans.len());
    bench("run_recovery", 3, || {
        let _ = std::hint::black_box(run_recovery(
            &spec,
            &plans,
            Location::new(0, 0),
            RecoveryConfig::default(),
        ));
    });
}
