//! Hot-path microbenchmarks (§Perf): GF combine throughput native vs PJRT,
//! the two-nibble slice MAC kernel vs a naive per-byte reference, the
//! pipelined cluster recovery executor at 1 vs 8 workers, matrix
//! inversion, placement lookups (raw OA arithmetic vs the table-backed
//! cache), and simulator event rate.
use d3ec::cluster::MiniCluster;
use d3ec::codes::CodeSpec;
use d3ec::gf;
use d3ec::placement::{D3Placement, Placement, PlacementTable};
use d3ec::recovery::{node_recovery_plans, ExecutorConfig};
use d3ec::runtime::Coder;
use d3ec::sim::recovery::{run_recovery, RecoveryConfig};
use d3ec::topology::{Location, SystemSpec};
use std::sync::Arc;
use std::time::Instant;

fn bench<F: FnMut()>(name: &str, iters: usize, mut f: F) -> f64 {
    f(); // warmup
    let t0 = Instant::now();
    for _ in 0..iters {
        f();
    }
    let per = t0.elapsed().as_secs_f64() / iters as f64;
    println!("{name}: {:.3} ms/iter", per * 1e3);
    per
}

fn main() {
    println!("=== hot path: GF combine (k=6, 16 MB blocks) ===");
    let len = 16 << 20;
    let shards: Vec<Vec<u8>> = (0..6).map(|i| vec![i as u8 + 1; len]).collect();
    let refs: Vec<&[u8]> = shards.iter().map(|v| v.as_slice()).collect();
    let coeffs: Vec<u8> = (1..=6u8).collect();

    let native = Coder::native();
    let per = bench("native combine", 5, || {
        let _ = native.combine(&coeffs, &refs).unwrap();
    });
    println!("  native: {:.0} MB/s output, {:.0} MB/s streamed", len as f64 / per / 1e6, (len * 6) as f64 / per / 1e6);

    match Coder::pjrt() {
        Ok(pjrt) => {
            let per = bench("pjrt combine", 5, || {
                let _ = pjrt.combine(&coeffs, &refs).unwrap();
            });
            println!("  pjrt: {:.0} MB/s output, {:.0} MB/s streamed", len as f64 / per / 1e6, (len * 6) as f64 / per / 1e6);
        }
        Err(e) => eprintln!("pjrt skipped: {e}"),
    }

    println!("\n=== hot path: xor fast path (c=1) ===");
    let per = bench("xor combine (k=2)", 10, || {
        let _ = gf::combine(&[1, 1], &[&refs[0], &refs[1]]);
    });
    println!("  {:.0} MB/s output", len as f64 / per / 1e6);

    println!("\n=== hot path: slice-table MAC kernel vs per-byte reference ===");
    let mut acc = vec![0u8; len];
    let table = gf::SliceTable::new(0x8e);
    let per_slice = bench("slice mac (c=0x8e, 16 MB)", 10, || {
        table.mac(&mut acc, &refs[0]);
    });
    println!("  slice kernel: {:.0} MB/s streamed", len as f64 / per_slice / 1e6);
    let per_ref = bench("per-byte gf::mul reference", 5, || {
        for (a, &s) in acc.iter_mut().zip(refs[0]) {
            *a ^= gf::mul(0x8e, s);
        }
    });
    println!(
        "  reference: {:.0} MB/s streamed → slice kernel {:.2}x",
        len as f64 / per_ref / 1e6,
        per_ref / per_slice
    );

    println!("\n=== control path: placement + planning ===");
    let spec = SystemSpec::paper_default();
    let policy = D3Placement::new(CodeSpec::Rs { k: 6, m: 3 }, spec.cluster).unwrap();
    let raw = bench("stripe() x 10k (raw OA arithmetic)", 10, || {
        for sid in 0..10_000u64 {
            let _ = std::hint::black_box(policy.stripe(sid));
        }
    });
    let shared: Arc<dyn Placement> =
        Arc::new(D3Placement::new(CodeSpec::Rs { k: 6, m: 3 }, spec.cluster).unwrap());
    let table = PlacementTable::build(shared.clone(), 10_000);
    let cached = bench("stripe() x 10k (PlacementTable)", 10, || {
        for sid in 0..10_000u64 {
            let _ = std::hint::black_box(table.stripe(sid));
        }
    });
    println!(
        "  table-backed lookup: {:.1}x faster ({} cached stripes, {} fallbacks)",
        raw / cached,
        table.cached_stripes(),
        table.fallback_computes()
    );
    bench("node_recovery_plans(1000 stripes, raw)", 5, || {
        let _ = std::hint::black_box(node_recovery_plans(&policy, 1000, Location::new(0, 0), 0));
    });
    bench("node_recovery_plans(1000 stripes, table)", 5, || {
        let _ = std::hint::black_box(node_recovery_plans(&table, 1000, Location::new(0, 0), 0));
    });

    println!("\n=== cluster: pipelined recovery executor (1 vs 8 workers) ===");
    // Acceptance check for the executor: same seed and plan set, only the
    // worker count changes; 8 workers must be measurably faster and the
    // recovered bytes identical (the byte identity is pinned by
    // tests/executor_concurrency.rs).
    // 1 MB blocks over a 20 MB/s cross-rack port (1 MB token burst): every
    // cross-rack block drains its port's bucket, so a serial executor
    // sleeps on each transfer while 8 workers overlap the sleeps across
    // ports — the speedup measures transfer pipelining, not core count.
    let recover_wall = |workers: usize| -> f64 {
        let mut cspec = SystemSpec::paper_default();
        cspec.block_size = 1 << 20;
        cspec.net.inner_mbps = 1600.0;
        cspec.net.cross_mbps = 160.0;
        let policy: Arc<dyn Placement> =
            Arc::new(D3Placement::new(CodeSpec::Rs { k: 3, m: 2 }, cspec.cluster).unwrap());
        let cluster = MiniCluster::new(cspec, policy.clone(), "native", 5).unwrap();
        let stripes = 40u64;
        cluster
            .write_stripes_parallel(stripes, 8, |sid| {
                (0..3)
                    .map(|b| {
                        let mut v = vec![0u8; 1 << 20];
                        let mut s = sid.wrapping_mul(0x9e37).wrapping_add(b as u64) | 1;
                        for byte in v.iter_mut() {
                            s ^= s << 13;
                            s ^= s >> 7;
                            s ^= s << 17;
                            *byte = (s >> 24) as u8;
                        }
                        v
                    })
                    .collect()
            })
            .unwrap();
        let failed = Location::new(1, 0);
        cluster.fail_node(failed);
        let plans = node_recovery_plans(policy.as_ref(), stripes, failed, 5);
        let cfg = ExecutorConfig { workers, chunk_size: 256 << 10, ..Default::default() };
        let stats = cluster.recover_with_plans_cfg(plans, cfg, &[failed.rack]).unwrap();
        println!(
            "  {} worker(s): {} blocks / {} chunks in {:.0} ms → {:.1} MB/s, mean util {:.0}%",
            workers,
            stats.blocks,
            stats.chunks,
            stats.wall.as_secs_f64() * 1e3,
            stats.throughput_mb_s,
            stats.worker_utilization.iter().sum::<f64>()
                / stats.worker_utilization.len().max(1) as f64
                * 100.0
        );
        stats.wall.as_secs_f64()
    };
    let w1 = recover_wall(1);
    let w8 = recover_wall(8);
    println!("  8-worker speedup over 1 worker: {:.2}x", w1 / w8);

    println!("\n=== simulator: full recovery run (1000 stripes) ===");
    let plans = node_recovery_plans(&policy, 1000, Location::new(0, 0), 0);
    println!("  plans: {}", plans.len());
    bench("run_recovery", 3, || {
        let _ = std::hint::black_box(run_recovery(
            &spec,
            &plans,
            Location::new(0, 0),
            RecoveryConfig::default(),
        ));
    });
}
