//! Machine-readable hot-path benchmarks (DESIGN.md §9): the before/after
//! measurements for the fused GF combine engine and the zero-allocation
//! recovery data path, shared by `cargo bench --bench hotpath` and
//! `d3ctl bench` so CI and the CLI emit the same `BENCH_*.json` schema.
//!
//! Every entry reports **nanoseconds per byte of accumulator output**
//! (lower is better): `{bench_name: ns_per_byte}`. Two rows pin
//! pre-fusion mechanics as fixed baselines — `mac_16kb_chunks_rebuild`
//! (a `SliceTable::new` per 16 KiB chunk, the old `combine_into` tax at
//! executor chunk granularity) and `xor_16mb_scalar` (byte-at-a-time
//! XOR). `combine_k6_sequential` deliberately uses *today's*
//! `gf::combine_into` (table-cached, lane-dispatched) as its baseline, so
//! the fused-vs-sequential ratio isolates the cache-blocking win alone
//! and keeps measuring it even as `combine_into` itself improves.
//!
//! Kernel rows that compare lanes pin their lane explicitly
//! ([`gf::dispatch`]): `xor_16mb_swar` and `mac_16mb` always measure the
//! portable kernels regardless of what the process would auto-select, and
//! the `simd_vs_swar_*` ratio rows measure the AVX2/NEON shuffle kernels
//! against them (on CPUs without a SIMD lane both sides run SWAR and the
//! ratio degenerates to 1.0 — noted on stdout, kept in the JSON so the
//! schema is machine-independent). `encode_ingest_1w/8w` time the full
//! `write_stripes_parallel` ingest path (encode pool + link model) at 1
//! vs 8 client writers.

use std::collections::BTreeMap;
use std::path::Path;
use std::sync::Arc;
use std::time::Instant;

use crate::cluster::MiniCluster;
use crate::codes::CodeSpec;
use crate::gf::{self, dispatch, dispatch::Lane};
use crate::placement::{D3Placement, Placement};
use crate::recovery::{node_recovery_plans, ExecutorConfig, SchedulePolicy};
use crate::topology::{ClusterSpec, Location, SystemSpec};
use crate::util::json::Json;
use crate::util::rng::xorshift_bytes as deterministic_bytes;

/// Bench harness knobs.
#[derive(Clone, Copy, Debug, Default)]
pub struct BenchOpts {
    /// CI quick mode: fewer iterations and a smaller cluster population;
    /// bench names and buffer sizes stay identical so JSON rows compare.
    pub quick: bool,
}

/// `bench name → ns per output byte`, ready for `BENCH_*.json`.
#[derive(Clone, Debug, Default)]
pub struct BenchReport {
    pub ns_per_byte: BTreeMap<String, f64>,
}

impl BenchReport {
    fn record(&mut self, name: &str, ns_per_byte: f64) {
        self.ns_per_byte.insert(name.to_string(), ns_per_byte);
    }

    /// Ratio `ns_per_byte[a] / ns_per_byte[b]` (how many times slower a
    /// is than b), if both entries exist.
    pub fn ratio(&self, a: &str, b: &str) -> Option<f64> {
        Some(self.ns_per_byte.get(a)? / self.ns_per_byte.get(b)?)
    }

    pub fn to_json(&self) -> Json {
        Json::Obj(
            self.ns_per_byte
                .iter()
                .map(|(k, &v)| (k.clone(), Json::Num(v)))
                .collect(),
        )
    }

    /// Write the `{bench_name: ns_per_byte}` document to `path`.
    pub fn write_json(&self, path: &Path) -> anyhow::Result<()> {
        std::fs::write(path, self.to_json().to_string() + "\n")?;
        Ok(())
    }
}

/// Time `f` over `iters` runs (after one warmup) and return ns per byte,
/// where each run processes `bytes` accumulator bytes.
fn bench_ns_per_byte<F: FnMut()>(iters: usize, bytes: usize, mut f: F) -> f64 {
    f(); // warmup
    let t0 = Instant::now();
    for _ in 0..iters {
        f();
    }
    t0.elapsed().as_secs_f64() * 1e9 / (iters as f64) / bytes as f64
}

/// GF kernel micro-benches: the 16 MB MAC (cached vs per-chunk table
/// rebuild), the SWAR vs scalar XOR lane, and the fused vs sequential
/// k = 6 combine over 16 MB shards.
pub fn run_kernel_benches(opts: &BenchOpts, report: &mut BenchReport) {
    let len = 16 << 20;
    let iters = if opts.quick { 2 } else { 8 };
    let c = 0x8eu8;
    let src = deterministic_bytes(len, 1);
    let mut acc = deterministic_bytes(len, 2);

    println!("=== gf kernel: 16 MB multiply-accumulate ===");
    let mac = bench_ns_per_byte(iters, len, || gf::kernel::table(c).mac(&mut acc, &src));
    report.record("mac_16mb", mac);
    println!("  mac_16mb (cached table): {mac:.3} ns/B ({:.0} MB/s)", 1e3 / mac);

    // the executor touches sources one 16 KiB chunk at a time — measure
    // the per-chunk table-rebuild tax the kernel cache removes
    let chunk = 16 << 10;
    let cached = bench_ns_per_byte(iters, len, || {
        for off in (0..len).step_by(chunk) {
            gf::kernel::table(c).mac(&mut acc[off..off + chunk], &src[off..off + chunk]);
        }
    });
    let rebuilt = bench_ns_per_byte(iters, len, || {
        for off in (0..len).step_by(chunk) {
            gf::SliceTable::new(c).mac(&mut acc[off..off + chunk], &src[off..off + chunk]);
        }
    });
    report.record("mac_16kb_chunks_cached", cached);
    report.record("mac_16kb_chunks_rebuild", rebuilt);
    println!(
        "  16 KiB-chunked mac: cached {cached:.3} vs rebuild {rebuilt:.3} ns/B → {:.2}x",
        rebuilt / cached
    );

    println!("=== gf kernel: c == 1 XOR lane ===");
    // pinned to the SWAR lane: this row is the portable-kernel baseline,
    // stable no matter which lane the process auto-selects
    let swar =
        bench_ns_per_byte(iters, len, || dispatch::xor_into_lane(Lane::Swar, &mut acc, &src));
    let scalar = bench_ns_per_byte(iters, len, || {
        for (a, s) in acc.iter_mut().zip(&src) {
            *a ^= s;
        }
    });
    report.record("xor_16mb_swar", swar);
    report.record("xor_16mb_scalar", scalar);
    println!("  swar {swar:.3} vs scalar {scalar:.3} ns/B → {:.2}x", scalar / swar);

    println!("=== gf kernel: simd vs swar lanes (16 MB) ===");
    // swar MAC re-timed through the lane surface so both ratio legs pay
    // the identical call shape
    let mac_swar =
        bench_ns_per_byte(iters, len, || dispatch::mac_into_lane(Lane::Swar, c, &mut acc, &src));
    let (mac_simd, xor_simd) = if dispatch::simd_available() {
        let m = bench_ns_per_byte(iters, len, || {
            dispatch::mac_into_lane(Lane::Simd, c, &mut acc, &src)
        });
        let x = bench_ns_per_byte(iters, len, || {
            dispatch::xor_into_lane(Lane::Simd, &mut acc, &src)
        });
        (m, x)
    } else {
        println!("  (no SIMD lane on this CPU — simd rows mirror swar, ratios 1.0)");
        (mac_swar, swar)
    };
    report.record("mac_16mb_simd", mac_simd);
    report.record("xor_16mb_simd", xor_simd);
    report.record("simd_vs_swar_mac", mac_swar / mac_simd);
    report.record("simd_vs_swar_xor", swar / xor_simd);
    println!(
        "  mac: swar {mac_swar:.3} vs simd {mac_simd:.3} ns/B → {:.2}x; \
         xor: swar {swar:.3} vs simd {xor_simd:.3} ns/B → {:.2}x",
        mac_swar / mac_simd,
        swar / xor_simd
    );

    println!("=== gf kernel: k = 6 combine over 16 MB shards ===");
    let shards: Vec<Vec<u8>> = (0..6).map(|i| deterministic_bytes(len, 10 + i)).collect();
    let coeffs: Vec<u8> = (1..=6u8).collect();
    // one accumulator sweep per source, through today's combine_into —
    // the delta against the fused row is pure cache blocking
    let seq = bench_ns_per_byte(iters, len, || {
        acc.iter_mut().for_each(|b| *b = 0);
        for (&cf, shard) in coeffs.iter().zip(&shards) {
            gf::combine_into(&mut acc, cf, shard);
        }
    });
    let fused = bench_ns_per_byte(iters, len, || {
        acc.iter_mut().for_each(|b| *b = 0);
        let pairs: Vec<(u8, &[u8])> =
            coeffs.iter().zip(&shards).map(|(&cf, s)| (cf, s.as_slice())).collect();
        gf::combine_many_into(&mut acc, &pairs);
    });
    report.record("combine_k6_sequential", seq);
    report.record("combine_k6_fused", fused);
    println!(
        "  sequential {seq:.3} vs fused {fused:.3} ns/B → fused {:.2}x faster",
        seq / fused
    );
}

/// End-to-end cluster recovery at 1 vs 8 workers (the executor
/// acceptance bench): 1 MB blocks over a deliberately slow cross-rack
/// port so the speedup measures transfer pipelining. Also prints the
/// scratch-pool reuse rate — the zero-allocation data path's witness.
pub fn run_cluster_benches(opts: &BenchOpts, report: &mut BenchReport) {
    let stripes: u64 = if opts.quick { 12 } else { 40 };
    println!("=== cluster: pipelined recovery (1 vs 8 workers, {stripes} stripes) ===");
    let mut recover = |workers: usize, name: &str| {
        let mut cspec = SystemSpec::paper_default();
        cspec.block_size = 1 << 20;
        cspec.net.inner_mbps = 1600.0;
        cspec.net.cross_mbps = 160.0;
        let policy: Arc<dyn Placement> =
            Arc::new(D3Placement::new(CodeSpec::Rs { k: 3, m: 2 }, cspec.cluster).unwrap());
        let cluster = MiniCluster::new(cspec, policy.clone(), "native", 5).unwrap();
        cluster
            .write_stripes_parallel(stripes, 8, |sid| {
                (0..3).map(|b| deterministic_bytes(1 << 20, sid * 3 + b)).collect()
            })
            .unwrap();
        let failed = Location::new(1, 0);
        cluster.fail_node(failed);
        let plans = node_recovery_plans(policy.as_ref(), stripes, failed, 5);
        let cfg = ExecutorConfig { workers, chunk_size: 256 << 10, ..Default::default() };
        let stats = cluster.recover_with_plans_cfg(plans, cfg, &[failed.rack]).unwrap();
        let ns_per_byte = stats.wall.as_secs_f64() * 1e9 / stats.bytes.max(1) as f64;
        report.record(name, ns_per_byte);
        println!(
            "  {} worker(s): {} blocks / {} chunks in {:.0} ms → {:.1} MB/s, \
             scratch reuse {:.0}%",
            workers,
            stats.blocks,
            stats.chunks,
            stats.wall.as_secs_f64() * 1e3,
            stats.throughput_mb_s,
            stats.scratch.hit_rate() * 100.0
        );
        stats.wall.as_secs_f64()
    };
    let w1 = recover(1, "cluster_recover_1w");
    let w8 = recover(8, "cluster_recover_8w");
    println!("  8-worker speedup over 1 worker: {:.2}x", w1 / w8);
}

/// Stripe-encode ingest at 1 vs 8 client writers (the PR 6 acceptance
/// bench): `write_stripes_parallel` drives the full write path — encode
/// through the coder pool, then block distribution over the link model —
/// so the 8-writer row measures how far the pooled coder service lets
/// concurrent writers overlap each other's encode and transfer time.
/// Rows are ns per ingested *data* byte.
pub fn run_encode_benches(opts: &BenchOpts, report: &mut BenchReport) {
    let stripes: u64 = if opts.quick { 8 } else { 16 };
    let block: usize = if opts.quick { 512 << 10 } else { 1 << 20 };
    println!("=== cluster: stripe-encode ingest (1 vs 8 writers, {stripes} stripes) ===");
    let mut ingest = |workers: usize, name: &str| {
        let mut cspec = SystemSpec::paper_default();
        cspec.block_size = block as u64;
        cspec.net.inner_mbps = 1600.0;
        cspec.net.cross_mbps = 160.0;
        let policy: Arc<dyn Placement> =
            Arc::new(D3Placement::new(CodeSpec::Rs { k: 3, m: 2 }, cspec.cluster).unwrap());
        let cluster = MiniCluster::new(cspec, policy, "native", 5).unwrap();
        let bytes = stripes * 3 * block as u64;
        let t0 = Instant::now();
        cluster
            .write_stripes_parallel(stripes, workers, |sid| {
                (0..3).map(|b| deterministic_bytes(block, sid * 3 + b)).collect()
            })
            .unwrap();
        let secs = t0.elapsed().as_secs_f64();
        let ns_per_byte = secs * 1e9 / bytes as f64;
        report.record(name, ns_per_byte);
        println!(
            "  {workers} writer(s): {stripes} stripes ({} MB data) in {:.0} ms → {:.1} MB/s",
            bytes >> 20,
            secs * 1e3,
            bytes as f64 / secs / 1e6
        );
        secs
    };
    let w1 = ingest(1, "encode_ingest_1w");
    let w8 = ingest(8, "encode_ingest_8w");
    report.record("encode_ingest_1w_vs_8w", w1 / w8);
    println!("  8-writer ingest speedup over 1 writer: {:.2}x", w1 / w8);
}

/// One whole-node recovery on a 4-rack topology with contended cross-rack
/// links, returning wall seconds and recording ns per rebuilt byte.
#[allow(clippy::too_many_arguments)]
fn recover_contended(
    report: &mut BenchReport,
    name: &str,
    stripes: u64,
    block: u64,
    chunk: u64,
    schedule: SchedulePolicy,
    coalesce: usize,
    batched_fetch: bool,
) -> f64 {
    let mut cspec = SystemSpec::paper_default();
    cspec.cluster = ClusterSpec::new(4, 4);
    cspec.block_size = block;
    cspec.net.inner_mbps = 1600.0;
    cspec.net.cross_mbps = 160.0; // scarce core-router ports: the contended case
    let policy: Arc<dyn Placement> =
        Arc::new(D3Placement::new(CodeSpec::Rs { k: 3, m: 2 }, cspec.cluster).unwrap());
    let cluster = MiniCluster::new(cspec, policy.clone(), "native", 7).unwrap();
    cluster
        .write_stripes_parallel(stripes, 8, |sid| {
            (0..3).map(|b| deterministic_bytes(block as usize, sid * 3 + b)).collect()
        })
        .unwrap();
    // pick a failed node that actually stores blocks
    let failed = (0..cspec.cluster.node_count())
        .map(|i| cspec.cluster.unflat(i))
        .find(|&l| (0..stripes).any(|sid| policy.stripe(sid).locs.contains(&l)))
        .expect("no node holds blocks");
    cluster.fail_node(failed);
    let plans = node_recovery_plans(policy.as_ref(), stripes, failed, 7);
    let cfg = ExecutorConfig {
        workers: 8,
        chunk_size: chunk,
        schedule,
        coalesce,
        batched_fetch,
        ..Default::default()
    };
    let stats = cluster.recover_with_plans_cfg(plans, cfg, &[failed.rack]).unwrap();
    let ns_per_byte = stats.wall.as_secs_f64() * 1e9 / stats.bytes.max(1) as f64;
    report.record(name, ns_per_byte);
    let stall: f64 = stats.link_busy_stall.iter().map(|&(_, s)| s).sum();
    println!(
        "  {name}: {} blocks / {} chunks / {} rounds in {:.0} ms → {:.1} MB/s \
         (link stall {:.2} s)",
        stats.blocks,
        stats.chunks,
        stats.rounds,
        stats.wall.as_secs_f64() * 1e3,
        stats.throughput_mb_s,
        stall,
    );
    stats.wall.as_secs_f64()
}

/// The PR 4 acceptance benches (DESIGN.md §10): 8-worker whole-node
/// recovery on a 4-rack topology with contended cross-rack links, FIFO vs
/// the balanced wavefront schedule, and per-chunk vs batched coalesced
/// fetches. The `*_vs_*` rows are **ratios** (first ÷ second, > 1 means
/// the second is faster), recorded alongside the raw ns/B rows so the
/// trajectory file carries both.
pub fn run_sched_benches(opts: &BenchOpts, report: &mut BenchReport) {
    let stripes: u64 = if opts.quick { 16 } else { 32 };
    let block: u64 = if opts.quick { 512 << 10 } else { 1 << 20 };
    println!(
        "=== scheduler: 8-worker node recovery, 4 racks, contended links \
         ({stripes} stripes) ==="
    );
    // 8 chunks per block, so FIFO's plan-major drain keeps the whole pool
    // on one plan's sources while balanced spreads across classes; both
    // runs use the default per-source fetch path so the ratio isolates
    // the admission schedule alone
    let chunk = block / 8;
    let fifo = recover_contended(
        report,
        "sched_fifo_8w",
        stripes,
        block,
        chunk,
        SchedulePolicy::Fifo,
        1,
        false,
    );
    let balanced = recover_contended(
        report,
        "sched_balanced_8w",
        stripes,
        block,
        chunk,
        SchedulePolicy::Balanced,
        1,
        false,
    );
    report.record("sched_fifo_vs_balanced", fifo / balanced);
    println!("  balanced schedule speedup over FIFO: {:.2}x", fifo / balanced);

    println!("=== scheduler: per-source vs batched gated fetches ===");
    // identical coalescing window on both sides so the ratio isolates the
    // single-gate-acquisition batch alone; finer chunks magnify the
    // per-fetch gate round trips it amortizes
    let chunk = block / 16;
    let per_chunk = recover_contended(
        report,
        "fetch_per_chunk_8w",
        stripes,
        block,
        chunk,
        SchedulePolicy::Balanced,
        4,
        false,
    );
    let batched = recover_contended(
        report,
        "fetch_batched_8w",
        stripes,
        block,
        chunk,
        SchedulePolicy::Balanced,
        4,
        true,
    );
    report.record("batched_vs_per_chunk_fetch", per_chunk / batched);
    println!("  batched-fetch speedup over per-chunk: {:.2}x", per_chunk / batched);
}

/// The PR 5 acceptance benches (DESIGN.md §11): foreground-only,
/// recovery-only and the QoS-split mixed run on the contended 4-rack
/// topology, all at 8 workers. `mixed_vs_isolated` is the recovery
/// interference factor (mixed recovery wall ÷ isolated recovery wall) —
/// the quantity the QoS split trades against foreground tail latency.
pub fn run_fg_benches(opts: &BenchOpts, report: &mut BenchReport) {
    use crate::client::{ArrivalModel, FgSpec, QosConfig};
    let stripes: u64 = if opts.quick { 12 } else { 24 };
    let block: u64 = 256 << 10;
    let requests: usize = if opts.quick { 24 } else { 48 };
    println!(
        "=== client engine: fg-only vs recovery-only vs QoS-mixed \
         ({stripes} stripes, {requests} requests) ==="
    );
    let build = || -> (Arc<dyn Placement>, MiniCluster) {
        let mut cspec = SystemSpec::paper_default();
        cspec.cluster = ClusterSpec::new(4, 4);
        cspec.block_size = block;
        cspec.net.inner_mbps = 1600.0;
        cspec.net.cross_mbps = 160.0; // scarce rack ports: the contended case
        let policy: Arc<dyn Placement> =
            Arc::new(D3Placement::new(CodeSpec::Rs { k: 3, m: 2 }, cspec.cluster).unwrap());
        let cluster = MiniCluster::new(cspec, policy.clone(), "native", 11).unwrap();
        cluster
            .write_stripes_parallel(stripes, 8, |sid| {
                (0..3).map(|b| deterministic_bytes(block as usize, sid * 3 + b)).collect()
            })
            .unwrap();
        (policy, cluster)
    };
    let fg_spec = FgSpec::reads(requests, ArrivalModel::Closed { clients: 8, think_s: 0.0 });
    let arrival = fg_spec.arrival;
    // a failed node that actually stores blocks at this population
    let failed = {
        let cspec = ClusterSpec::new(4, 4);
        let policy = D3Placement::new(CodeSpec::Rs { k: 3, m: 2 }, cspec).unwrap();
        (0..cspec.node_count())
            .map(|i| cspec.unflat(i))
            .find(|&l| (0..stripes).any(|sid| policy.stripe(sid).locs.contains(&l)))
            .expect("no node holds blocks")
    };
    let cfg = ExecutorConfig { workers: 8, chunk_size: block / 8, ..Default::default() };

    // foreground alone: healthy cluster, closed-loop reads
    {
        let (policy, cluster) = build();
        let reqs = fg_spec.generate(&policy, stripes, &[], 11).unwrap();
        let out = crate::client::run_on_cluster(&cluster, &reqs, arrival, 8, None).unwrap();
        let bytes = out.served() as u64 * block;
        report.record("fg_only_8w", out.seconds * 1e9 / bytes.max(1) as f64);
        let p99 = out.summary().map(|s| s.p99 * 1e3).unwrap_or(0.0);
        println!(
            "  fg_only_8w: {} reads in {:.0} ms (p99 {p99:.1} ms)",
            out.served(),
            out.seconds * 1e3
        );
    }

    // recovery alone: whole-node rebuild at 8 workers
    let isolated_wall = {
        let (policy, cluster) = build();
        cluster.fail_node(failed);
        let plans = node_recovery_plans(policy.as_ref(), stripes, failed, 11);
        let stats = cluster.recover_with_plans_cfg(plans, cfg, &[failed.rack]).unwrap();
        report.record(
            "recovery_only_8w",
            stats.wall.as_secs_f64() * 1e9 / stats.bytes.max(1) as f64,
        );
        println!(
            "  recovery_only_8w: {} blocks in {:.0} ms → {:.1} MB/s",
            stats.blocks,
            stats.wall.as_secs_f64() * 1e3,
            stats.throughput_mb_s
        );
        stats.wall.as_secs_f64()
    };

    // both together under a 50% recovery share
    let mixed_wall = {
        let (policy, cluster) = build();
        cluster.fail_node(failed);
        let reqs = fg_spec.generate(&policy, stripes, &[failed], 11).unwrap();
        let plans = node_recovery_plans(policy.as_ref(), stripes, failed, 11);
        let (stats, fgout) = cluster
            .run_mixed_load(
                plans,
                cfg,
                &[failed.rack],
                &reqs,
                arrival,
                8,
                QosConfig { recovery_share: 0.5, fg_weight: 1.0 },
            )
            .unwrap();
        report.record(
            "mixed_qos_8w",
            stats.wall.as_secs_f64() * 1e9 / stats.bytes.max(1) as f64,
        );
        let p99 = fgout.summary().map(|s| s.p99 * 1e3).unwrap_or(0.0);
        println!(
            "  mixed_qos_8w: recovery {:.0} ms alongside {} fg reads (fg p99 {p99:.1} ms)",
            stats.wall.as_secs_f64() * 1e3,
            fgout.served()
        );
        stats.wall.as_secs_f64()
    };
    report.record("mixed_vs_isolated", mixed_wall / isolated_wall);
    println!(
        "  recovery slowdown under foreground load at share 0.5: {:.2}x",
        mixed_wall / isolated_wall
    );
}

/// DESIGN.md §16 rows: what regenerate-on-read costs against a resident
/// block map, how far a warm hot-block cache bends the degraded-read
/// path, and the sharded checksum registry against a single global mutex
/// under 8 writers. The two ratio rows
/// (`store_synthetic_vs_materialized_read`,
/// `cache_hit_vs_miss_degraded_read`) are gated by `bench-compare`.
pub fn run_store_benches(opts: &BenchOpts, report: &mut BenchReport) {
    use crate::cluster::{
        parity_matrix, BlockStore, ChecksumRegistry, MaterializedStore, SyntheticStore,
    };

    let block: usize = 64 << 10;
    let code = CodeSpec::Rs { k: 3, m: 2 };
    let len = code.len();
    let stripes: u64 = if opts.quick { 32 } else { 128 };
    println!(
        "=== block store: synthetic regenerate-on-read vs materialized \
         ({stripes} stripes, {} KiB blocks) ===",
        block >> 10
    );

    // Store-layer head-to-head on one node: the synthetic store derives
    // every payload from the canonical generator; the materialized store
    // holds byte-identical copies written up front. Both sinks fold the
    // same bytes, so asserting them equal doubles as a parity check and
    // keeps the reads from being optimized away.
    let synthetic = SyntheticStore::new(1, code.k(), len, block, parity_matrix(&code));
    assert!(synthetic.populate(stripes));
    let materialized = MaterializedStore::new(1);
    for sid in 0..stripes {
        for b in 0..len {
            materialized.insert(0, (sid, b), synthetic.canonical_window(sid, b, 0, block));
        }
    }
    let iters = if opts.quick { 2 } else { 4 };
    let total = stripes as usize * len * block;
    let mut sink_mat = 0u64;
    let mat = bench_ns_per_byte(iters, total, || {
        for sid in 0..stripes {
            for b in 0..len {
                let v = materialized.read(0, (sid, b)).expect("materialized block");
                sink_mat = sink_mat.wrapping_add(u64::from(v[0]) + u64::from(v[block - 1]));
            }
        }
    });
    let mut sink_syn = 0u64;
    let syn = bench_ns_per_byte(iters, total, || {
        for sid in 0..stripes {
            for b in 0..len {
                let v = synthetic.read(0, (sid, b)).expect("synthetic block");
                sink_syn = sink_syn.wrapping_add(u64::from(v[0]) + u64::from(v[block - 1]));
            }
        }
    });
    assert_eq!(sink_mat, sink_syn, "synthetic reads diverged from materialized");
    report.record("store_read_materialized", mat);
    report.record("store_read_synthetic", syn);
    report.record("store_synthetic_vs_materialized_read", syn / mat);
    println!(
        "  read: materialized {mat:.3} vs synthetic {syn:.3} ns/B → \
         regeneration costs {:.2}x (buys O(metadata) memory)",
        syn / mat
    );

    // Hot-block cache tier on the degraded-read path: a 4x4 cluster with
    // a failed node; the miss leg reconstructs every lost block through
    // the modeled links, the hit leg serves the same keys from a warmed
    // cache (which skips the store *and* the links).
    println!("=== hot-block cache: degraded read, warm hit vs reconstruction miss ===");
    let fg_stripes: u64 = if opts.quick { 8 } else { 16 };
    let build = || -> (Arc<dyn Placement>, MiniCluster) {
        let mut cspec = SystemSpec::paper_default();
        cspec.cluster = ClusterSpec::new(4, 4);
        cspec.block_size = block as u64;
        let policy: Arc<dyn Placement> =
            Arc::new(D3Placement::new(CodeSpec::Rs { k: 3, m: 2 }, cspec.cluster).unwrap());
        let cluster = MiniCluster::new(cspec, policy.clone(), "native", 17).unwrap();
        cluster
            .write_stripes_parallel(fg_stripes, 8, |sid| {
                (0..3).map(|b| deterministic_bytes(block, sid * 3 + b)).collect()
            })
            .unwrap();
        (policy, cluster)
    };
    let cspec = ClusterSpec::new(4, 4);
    let probe = D3Placement::new(CodeSpec::Rs { k: 3, m: 2 }, cspec).unwrap();
    let failed = (0..cspec.node_count())
        .map(|i| cspec.unflat(i))
        .find(|&l| (0..fg_stripes).any(|sid| probe.stripe(sid).locs.contains(&l)))
        .expect("no node holds blocks");
    let client = (0..cspec.node_count())
        .map(|i| cspec.unflat(i))
        .find(|l| l.rack != failed.rack)
        .expect("no healthy client rack");
    let lost: Vec<(u64, usize)> = (0..fg_stripes)
        .flat_map(|sid| (0..len).map(move |b| (sid, b)))
        .filter(|&(sid, b)| probe.block_at(sid, b) == failed)
        .collect();
    assert!(!lost.is_empty());
    let lost_bytes = lost.len() * block;

    let miss = {
        let (_, cluster) = build();
        cluster.fail_node(failed);
        bench_ns_per_byte(iters, lost_bytes, || {
            for &(sid, b) in &lost {
                cluster.degraded_read(sid, b, client).expect("degraded read");
            }
        })
    };
    let hit = {
        let (_, mut cluster) = build();
        cluster.set_cache(64 << 20);
        cluster.fail_node(failed);
        // first touch lands in the ghost list, second admits; after the
        // warmup sweep inside bench_ns_per_byte every timed read hits
        for &(sid, b) in &lost {
            cluster.degraded_read(sid, b, client).expect("cache warm");
        }
        let ns = bench_ns_per_byte(iters, lost_bytes, || {
            for &(sid, b) in &lost {
                cluster.degraded_read(sid, b, client).expect("cached read");
            }
        });
        let stats = cluster.cache_stats().expect("cache installed");
        assert!(stats.hits > 0, "warmed cache never hit");
        ns
    };
    report.record("cache_miss_read", miss);
    report.record("cache_hit_read", hit);
    report.record("cache_hit_vs_miss_degraded_read", hit / miss);
    println!(
        "  degraded read over {} lost blocks: miss {miss:.3} vs hit {hit:.3} ns/B → \
         cache serves at {:.3}x of reconstruction cost",
        lost.len(),
        hit / miss
    );

    // Checksum registry under write contention: 8 workers hammering a
    // single global mutex vs the 64-shard registry. Reported in ns per
    // *operation* (one or_insert + one get), not ns/B.
    println!("=== checksum registry: 8-worker contention, global mutex vs 64 shards ===");
    let workers: u64 = 8;
    let ops_per: u64 = if opts.quick { 20_000 } else { 80_000 };
    let total_ops = (workers * ops_per) as f64;
    let global: std::sync::Mutex<std::collections::HashMap<(u64, usize), u64>> =
        std::sync::Mutex::new(std::collections::HashMap::new());
    let t0 = Instant::now();
    std::thread::scope(|s| {
        for w in 0..workers {
            let global = &global;
            s.spawn(move || {
                for i in 0..ops_per {
                    let key = (i % 4096, w as usize);
                    let mut g = global.lock().unwrap();
                    g.entry(key).or_insert(i);
                    let _ = g.get(&key);
                }
            });
        }
    });
    let global_ns = t0.elapsed().as_secs_f64() * 1e9 / total_ops;
    let sharded = ChecksumRegistry::new();
    let t0 = Instant::now();
    std::thread::scope(|s| {
        for w in 0..workers {
            let sharded = &sharded;
            s.spawn(move || {
                for i in 0..ops_per {
                    let key = (i % 4096, w as usize);
                    sharded.or_insert(key, i);
                    let _ = sharded.get(key);
                }
            });
        }
    });
    let sharded_ns = t0.elapsed().as_secs_f64() * 1e9 / total_ops;
    report.record("checksums_global_8w", global_ns);
    report.record("checksums_sharded_8w", sharded_ns);
    report.record("checksums_sharded_vs_global_8w", sharded_ns / global_ns);
    println!(
        "  or_insert+get: global {global_ns:.1} vs sharded {sharded_ns:.1} ns/op → \
         shards run at {:.2}x of the global lock",
        sharded_ns / global_ns
    );
}

/// The full hot-path suite (`d3ctl bench`, `cargo bench --bench hotpath`).
pub fn run_hotpath(opts: &BenchOpts) -> BenchReport {
    let mut report = BenchReport::default();
    run_kernel_benches(opts, &mut report);
    run_cluster_benches(opts, &mut report);
    run_encode_benches(opts, &mut report);
    run_sched_benches(opts, &mut report);
    run_fg_benches(opts, &mut report);
    run_store_benches(opts, &mut report);
    report
}

/// One row of a [`compare_bench_json`] result.
pub struct CompareRow {
    pub name: String,
    pub old: f64,
    pub new: f64,
    /// Relative change, `new / old - 1` (positive = slower).
    pub delta: f64,
}

impl std::fmt::Display for CompareRow {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "{}: {:.4} → {:.4} ns/B ({:+.1}%)",
            self.name,
            self.old,
            self.new,
            self.delta * 100.0
        )
    }
}

/// Outcome of diffing two bench JSON files over the tracked keys.
pub struct BenchComparison {
    pub rows: Vec<CompareRow>,
    /// Human-readable description of every key that regressed beyond the
    /// tolerance; empty = gate passes.
    pub regressions: Vec<String>,
}

/// Diff two `{bench_name: ns_per_byte}` files over `keys`, flagging every
/// key whose ns/B grew by more than `tolerance` (0.15 = 15%) — the CI
/// perf gate between the PR 3 and PR 4 trajectory files. Keys missing
/// from the *old* file are skipped (new benches have no baseline); keys
/// missing from the *new* file are regressions (a tracked bench
/// disappeared).
pub fn compare_bench_json(
    old_path: &Path,
    new_path: &Path,
    keys: &[&str],
    tolerance: f64,
) -> anyhow::Result<BenchComparison> {
    let read = |p: &Path| -> anyhow::Result<Json> {
        let text = std::fs::read_to_string(p)
            .map_err(|e| anyhow::anyhow!("read {}: {e}", p.display()))?;
        Json::parse(&text).map_err(|e| anyhow::anyhow!("parse {}: {e}", p.display()))
    };
    let old = read(old_path)?;
    let new = read(new_path)?;
    let mut rows = Vec::new();
    let mut regressions = Vec::new();
    for &key in keys {
        let Some(o) = old.get(key).and_then(Json::as_f64) else {
            println!("{key}: no baseline in {} — skipped", old_path.display());
            continue;
        };
        match new.get(key).and_then(Json::as_f64) {
            Some(n) => {
                let delta = if o > 0.0 { n / o - 1.0 } else { 0.0 };
                if delta > tolerance {
                    regressions.push(format!(
                        "{key} regressed {:.1}% ({o:.4} → {n:.4} ns/B)",
                        delta * 100.0
                    ));
                }
                rows.push(CompareRow { name: key.to_string(), old: o, new: n, delta });
            }
            None => regressions.push(format!(
                "{key} missing from {} (tracked bench disappeared)",
                new_path.display()
            )),
        }
    }
    Ok(BenchComparison { rows, regressions })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn compare_flags_only_regressions_beyond_tolerance() {
        let dir = std::env::temp_dir();
        let old_p = dir.join("d3ec_bench_old_test.json");
        let new_p = dir.join("d3ec_bench_new_test.json");
        let mut old = BenchReport::default();
        old.record("mac_16mb", 1.0);
        old.record("combine_k6_fused", 2.0);
        old.record("xor_16mb_swar", 0.5);
        old.write_json(&old_p).unwrap();
        let mut new = BenchReport::default();
        new.record("mac_16mb", 1.10); // +10%: within the 15% gate
        new.record("combine_k6_fused", 2.5); // +25%: regression
        // xor_16mb_swar missing from new: regression
        new.record("sched_fifo_vs_balanced", 1.4); // untracked: ignored
        new.write_json(&new_p).unwrap();
        let cmp = compare_bench_json(
            &old_p,
            &new_p,
            &["mac_16mb", "combine_k6_fused", "xor_16mb_swar", "brand_new_bench"],
            0.15,
        )
        .unwrap();
        assert_eq!(cmp.rows.len(), 2, "only keys present in both files get rows");
        assert_eq!(cmp.regressions.len(), 2, "{:?}", cmp.regressions);
        assert!(cmp.regressions[0].contains("combine_k6_fused"));
        assert!(cmp.regressions[1].contains("xor_16mb_swar"));
        let _ = (std::fs::remove_file(&old_p), std::fs::remove_file(&new_p));
    }

    #[test]
    fn report_json_is_flat_name_to_number() {
        let mut r = BenchReport::default();
        r.record("combine_k6_fused", 0.25);
        r.record("combine_k6_sequential", 0.75);
        let json = r.to_json().to_string();
        let parsed = Json::parse(&json).unwrap();
        assert_eq!(
            parsed.get("combine_k6_fused").and_then(Json::as_f64),
            Some(0.25)
        );
        assert!((r.ratio("combine_k6_sequential", "combine_k6_fused").unwrap() - 3.0).abs()
            < 1e-12);
        assert_eq!(r.ratio("missing", "combine_k6_fused"), None);
    }
}
