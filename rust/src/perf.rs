//! Machine-readable hot-path benchmarks (DESIGN.md §9): the before/after
//! measurements for the fused GF combine engine and the zero-allocation
//! recovery data path, shared by `cargo bench --bench hotpath` and
//! `d3ctl bench` so CI and the CLI emit the same `BENCH_*.json` schema.
//!
//! Every entry reports **nanoseconds per byte of accumulator output**
//! (lower is better): `{bench_name: ns_per_byte}`. Two rows pin
//! pre-fusion mechanics as fixed baselines — `mac_16kb_chunks_rebuild`
//! (a `SliceTable::new` per 16 KiB chunk, the old `combine_into` tax at
//! executor chunk granularity) and `xor_16mb_scalar` (byte-at-a-time
//! XOR). `combine_k6_sequential` deliberately uses *today's*
//! `gf::combine_into` (table-cached, SWAR) as its baseline, so the
//! fused-vs-sequential ratio isolates the cache-blocking win alone and
//! keeps measuring it even as `combine_into` itself improves.

use std::collections::BTreeMap;
use std::path::Path;
use std::sync::Arc;
use std::time::Instant;

use crate::cluster::MiniCluster;
use crate::codes::CodeSpec;
use crate::gf;
use crate::placement::{D3Placement, Placement};
use crate::recovery::{node_recovery_plans, ExecutorConfig};
use crate::topology::{Location, SystemSpec};
use crate::util::json::Json;
use crate::util::rng::xorshift_bytes as deterministic_bytes;

/// Bench harness knobs.
#[derive(Clone, Copy, Debug, Default)]
pub struct BenchOpts {
    /// CI quick mode: fewer iterations and a smaller cluster population;
    /// bench names and buffer sizes stay identical so JSON rows compare.
    pub quick: bool,
}

/// `bench name → ns per output byte`, ready for `BENCH_*.json`.
#[derive(Clone, Debug, Default)]
pub struct BenchReport {
    pub ns_per_byte: BTreeMap<String, f64>,
}

impl BenchReport {
    fn record(&mut self, name: &str, ns_per_byte: f64) {
        self.ns_per_byte.insert(name.to_string(), ns_per_byte);
    }

    /// Ratio `ns_per_byte[a] / ns_per_byte[b]` (how many times slower a
    /// is than b), if both entries exist.
    pub fn ratio(&self, a: &str, b: &str) -> Option<f64> {
        Some(self.ns_per_byte.get(a)? / self.ns_per_byte.get(b)?)
    }

    pub fn to_json(&self) -> Json {
        Json::Obj(
            self.ns_per_byte
                .iter()
                .map(|(k, &v)| (k.clone(), Json::Num(v)))
                .collect(),
        )
    }

    /// Write the `{bench_name: ns_per_byte}` document to `path`.
    pub fn write_json(&self, path: &Path) -> anyhow::Result<()> {
        std::fs::write(path, self.to_json().to_string() + "\n")?;
        Ok(())
    }
}

/// Time `f` over `iters` runs (after one warmup) and return ns per byte,
/// where each run processes `bytes` accumulator bytes.
fn bench_ns_per_byte<F: FnMut()>(iters: usize, bytes: usize, mut f: F) -> f64 {
    f(); // warmup
    let t0 = Instant::now();
    for _ in 0..iters {
        f();
    }
    t0.elapsed().as_secs_f64() * 1e9 / (iters as f64) / bytes as f64
}

/// GF kernel micro-benches: the 16 MB MAC (cached vs per-chunk table
/// rebuild), the SWAR vs scalar XOR lane, and the fused vs sequential
/// k = 6 combine over 16 MB shards.
pub fn run_kernel_benches(opts: &BenchOpts, report: &mut BenchReport) {
    let len = 16 << 20;
    let iters = if opts.quick { 2 } else { 8 };
    let c = 0x8eu8;
    let src = deterministic_bytes(len, 1);
    let mut acc = deterministic_bytes(len, 2);

    println!("=== gf kernel: 16 MB multiply-accumulate ===");
    let mac = bench_ns_per_byte(iters, len, || gf::kernel::table(c).mac(&mut acc, &src));
    report.record("mac_16mb", mac);
    println!("  mac_16mb (cached table): {mac:.3} ns/B ({:.0} MB/s)", 1e3 / mac);

    // the executor touches sources one 16 KiB chunk at a time — measure
    // the per-chunk table-rebuild tax the kernel cache removes
    let chunk = 16 << 10;
    let cached = bench_ns_per_byte(iters, len, || {
        for off in (0..len).step_by(chunk) {
            gf::kernel::table(c).mac(&mut acc[off..off + chunk], &src[off..off + chunk]);
        }
    });
    let rebuilt = bench_ns_per_byte(iters, len, || {
        for off in (0..len).step_by(chunk) {
            gf::SliceTable::new(c).mac(&mut acc[off..off + chunk], &src[off..off + chunk]);
        }
    });
    report.record("mac_16kb_chunks_cached", cached);
    report.record("mac_16kb_chunks_rebuild", rebuilt);
    println!(
        "  16 KiB-chunked mac: cached {cached:.3} vs rebuild {rebuilt:.3} ns/B → {:.2}x",
        rebuilt / cached
    );

    println!("=== gf kernel: c == 1 XOR lane ===");
    let swar = bench_ns_per_byte(iters, len, || gf::xor_into(&mut acc, &src));
    let scalar = bench_ns_per_byte(iters, len, || {
        for (a, s) in acc.iter_mut().zip(&src) {
            *a ^= s;
        }
    });
    report.record("xor_16mb_swar", swar);
    report.record("xor_16mb_scalar", scalar);
    println!("  swar {swar:.3} vs scalar {scalar:.3} ns/B → {:.2}x", scalar / swar);

    println!("=== gf kernel: k = 6 combine over 16 MB shards ===");
    let shards: Vec<Vec<u8>> = (0..6).map(|i| deterministic_bytes(len, 10 + i)).collect();
    let coeffs: Vec<u8> = (1..=6u8).collect();
    // one accumulator sweep per source, through today's combine_into —
    // the delta against the fused row is pure cache blocking
    let seq = bench_ns_per_byte(iters, len, || {
        acc.iter_mut().for_each(|b| *b = 0);
        for (&cf, shard) in coeffs.iter().zip(&shards) {
            gf::combine_into(&mut acc, cf, shard);
        }
    });
    let fused = bench_ns_per_byte(iters, len, || {
        acc.iter_mut().for_each(|b| *b = 0);
        let pairs: Vec<(u8, &[u8])> =
            coeffs.iter().zip(&shards).map(|(&cf, s)| (cf, s.as_slice())).collect();
        gf::combine_many_into(&mut acc, &pairs);
    });
    report.record("combine_k6_sequential", seq);
    report.record("combine_k6_fused", fused);
    println!(
        "  sequential {seq:.3} vs fused {fused:.3} ns/B → fused {:.2}x faster",
        seq / fused
    );
}

/// End-to-end cluster recovery at 1 vs 8 workers (the executor
/// acceptance bench): 1 MB blocks over a deliberately slow cross-rack
/// port so the speedup measures transfer pipelining. Also prints the
/// scratch-pool reuse rate — the zero-allocation data path's witness.
pub fn run_cluster_benches(opts: &BenchOpts, report: &mut BenchReport) {
    let stripes: u64 = if opts.quick { 12 } else { 40 };
    println!("=== cluster: pipelined recovery (1 vs 8 workers, {stripes} stripes) ===");
    let mut recover = |workers: usize, name: &str| {
        let mut cspec = SystemSpec::paper_default();
        cspec.block_size = 1 << 20;
        cspec.net.inner_mbps = 1600.0;
        cspec.net.cross_mbps = 160.0;
        let policy: Arc<dyn Placement> =
            Arc::new(D3Placement::new(CodeSpec::Rs { k: 3, m: 2 }, cspec.cluster).unwrap());
        let cluster = MiniCluster::new(cspec, policy.clone(), "native", 5).unwrap();
        cluster
            .write_stripes_parallel(stripes, 8, |sid| {
                (0..3).map(|b| deterministic_bytes(1 << 20, sid * 3 + b)).collect()
            })
            .unwrap();
        let failed = Location::new(1, 0);
        cluster.fail_node(failed);
        let plans = node_recovery_plans(policy.as_ref(), stripes, failed, 5);
        let cfg = ExecutorConfig { workers, chunk_size: 256 << 10, ..Default::default() };
        let stats = cluster.recover_with_plans_cfg(plans, cfg, &[failed.rack]).unwrap();
        let ns_per_byte = stats.wall.as_secs_f64() * 1e9 / stats.bytes.max(1) as f64;
        report.record(name, ns_per_byte);
        println!(
            "  {} worker(s): {} blocks / {} chunks in {:.0} ms → {:.1} MB/s, \
             scratch reuse {:.0}%",
            workers,
            stats.blocks,
            stats.chunks,
            stats.wall.as_secs_f64() * 1e3,
            stats.throughput_mb_s,
            stats.scratch.hit_rate() * 100.0
        );
        stats.wall.as_secs_f64()
    };
    let w1 = recover(1, "cluster_recover_1w");
    let w8 = recover(8, "cluster_recover_8w");
    println!("  8-worker speedup over 1 worker: {:.2}x", w1 / w8);
}

/// The full hot-path suite (`d3ctl bench`, `cargo bench --bench hotpath`).
pub fn run_hotpath(opts: &BenchOpts) -> BenchReport {
    let mut report = BenchReport::default();
    run_kernel_benches(opts, &mut report);
    run_cluster_benches(opts, &mut report);
    report
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn report_json_is_flat_name_to_number() {
        let mut r = BenchReport::default();
        r.record("combine_k6_fused", 0.25);
        r.record("combine_k6_sequential", 0.75);
        let json = r.to_json().to_string();
        let parsed = Json::parse(&json).unwrap();
        assert_eq!(
            parsed.get("combine_k6_fused").and_then(Json::as_f64),
            Some(0.25)
        );
        assert!((r.ratio("combine_k6_sequential", "combine_k6_fused").unwrap() - 3.0).abs()
            < 1e-12);
        assert_eq!(r.ratio("missing", "combine_k6_fused"), None);
    }
}
