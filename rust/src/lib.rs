//! # d3ec — Deterministic Data Distribution (D³) for erasure-coded storage
//!
//! Production-style reproduction of *Deterministic Data Distribution for
//! Efficient Recovery in Erasure-Coded Storage Systems* (Xu, Lyu, Li, Li,
//! Xu — TPDS 2020), built as a three-layer Rust + JAX + Pallas stack:
//!
//! * [`gf`], [`codes`] — GF(2⁸) arithmetic and RS/LRC erasure codes;
//! * [`oa`] — orthogonal arrays (the combinatorial core of D³);
//! * [`placement`] — D³ (paper §4), RDD and HDD baselines;
//! * [`recovery`] — minimum-cross-rack repair planning (§5), multi-erasure
//!   planning, and migration;
//! * [`scenario`] — first-class failure scenarios executed on either
//!   backend through one `RecoveryBackend` pipeline (DESIGN.md §5);
//! * [`client`] — the QoS-aware foreground-traffic engine: one request
//!   generator and one execution path for front-end load on both
//!   backends (DESIGN.md §11);
//! * [`sim`] — flow-level discrete-event cluster simulator (the testbed
//!   substitute; see DESIGN.md §2);
//! * [`runtime`] — PJRT execution of the AOT-lowered GF kernels;
//! * [`cluster`] — mini-HDFS (NameNode + DataNodes) with a real data path;
//! * [`net`] — the same cluster as N socket-served node workers behind a
//!   coordinator with join/drain/fail membership (DESIGN.md §13);
//! * [`scrub`] — the continuous background scrub daemon with adaptive
//!   intensity throttling (DESIGN.md §15);
//! * [`workloads`], [`metrics`], [`experiments`] — the paper's evaluation.

pub mod client;
pub mod cluster;
pub mod codes;
pub mod experiments;
pub mod gf;
pub mod metrics;
pub mod net;
pub mod oa;
pub mod perf;
pub mod placement;
pub mod recovery;
pub mod runtime;
pub mod scenario;
pub mod scrub;
pub mod sim;
pub mod topology;
pub mod util;
pub mod workloads;
