//! d3ctl — CLI for the D³ reproduction.
//!
//! ```text
//! d3ctl exp <1..11|all> [--stripes N] [--racks R] [--nodes N] [--block MB]
//! d3ctl scenario --kind single-node|multi-node|rack-failure|frontend-mix|degraded-burst
//!                [--policy d3|rdd|hdd] [--code rs-6-3] [--failures K] [--rack R]
//!                [--backend sim|cluster|net|both|all] [--stripes N] [--racks R] [--nodes N]
//!                [--workers N] [--chunk-size KB]   # pipelined recovery executor
//!                [--schedule fifo|balanced] [--coalesce N] [--batched-fetch true|false]
//!                [--fg-rate RPS | --fg-clients N] [--fg-requests N]  # client engine
//!                [--recovery-share S] [--fg-weight W] [--json]       # QoS + machine output
//!                [--store auto|materialized|synthetic] [--cache-mb N] [--zipf THETA]
//! d3ctl chaos [--backend cluster|net] [--drop P] [--delay P] [--delay-ms MS] [--corrupt P]
//!             [--truncate P] [--corrupt-stored P] [--crash N] [--scrub] [--stripes N] [--seed S] [--json]
//! d3ctl trace [--backend sim|cluster|net|all] [--rate PER_HOUR] [--horizon-h H]
//!             [--repair-mb-s R] [--file TRACE] [--stripes N] [--seed S] [--json]
//! d3ctl scrub-daemon [--backend cluster|net] [--cycles N] [--interval-s S] [--idle-mb-s R]
//!                    [--busy-mb-s R] [--batch N] [--corrupt-stored P] [--stripes N] [--seed S] [--json]
//! d3ctl durability [--quick] [--backend sim|cluster|net|all] [--trials N] [--horizon-h H]
//!                  [--rack-fail-prob P] [--scrub-interval-h H] [--repair-mb-s R] [--stripes N] [--json]
//! d3ctl layout --policy d3|rdd|hdd --code rs-3-2 [--stripes N] [--racks R] [--nodes N]
//! d3ctl mu --code rs-6-3               # Lemma 4 closed form vs planner
//! d3ctl oa --n 5 [--cols 4]            # print + verify an orthogonal array
//! d3ctl cluster-demo [--backend pjrt|native] [--stripes N]
//! d3ctl calibrate                      # coding throughput, native vs PJRT
//! d3ctl kernel-info                    # CPU features + selected GF kernel lane
//! d3ctl bench [--quick] [--json PATH]  # hot-path suite → BENCH_PR10.json
//! d3ctl bench-compare --old A.json --new B.json [--tolerance 0.15]
//! ```

use std::collections::HashMap;
use std::sync::atomic::AtomicBool;

use d3ec::client::{ArrivalModel, FgSpec, QosConfig};
use d3ec::cluster::fabric::{crash_victim, recover_with_replan, run_scrub};
use d3ec::cluster::{deterministic_data, BlockFabric, ClusterBackend, MiniCluster, StoreMode};
use d3ec::codes::CodeSpec;
use d3ec::experiments as exp;
use d3ec::util::json::Json;
use d3ec::net::chaos::{corrupt_set, FaultSpec};
use d3ec::net::{NetCluster, NetClusterBackend};
use d3ec::oa::{max_columns, OrthogonalArray};
use d3ec::placement::Placement;
use d3ec::recovery::mu::mu_rs;
use d3ec::recovery::{scenario_recovery_plans, ExecutorConfig, SchedulePolicy};
use d3ec::runtime::Coder;
use d3ec::scenario::durability::{
    run_durability_trial, run_durability_trial_model, run_matrix, DurabilitySpec,
};
use d3ec::scenario::trace::{parse_trace, run_trace, run_trace_sim, TraceSpec, TraceSummary};
use d3ec::scenario::{run_cross_backend, FailureScenario, RecoveryBackend};
use d3ec::scrub::{run_daemon, ScrubConfig};
use d3ec::sim::recovery::RecoveryConfig;
use d3ec::sim::SimBackend;
use d3ec::topology::{Location, SystemSpec};

fn parse_flags(args: &[String]) -> HashMap<String, String> {
    let mut out = HashMap::new();
    let mut i = 0;
    while i < args.len() {
        if let Some(key) = args[i].strip_prefix("--") {
            // a following `--flag` is the next flag, not this one's value
            // (so bare boolean flags like `--json` don't swallow it)
            match args.get(i + 1).filter(|v| !v.starts_with("--")) {
                Some(val) => {
                    out.insert(key.to_string(), val.clone());
                    i += 2;
                }
                None => {
                    out.insert(key.to_string(), String::new());
                    i += 1;
                }
            }
        } else {
            i += 1;
        }
    }
    out
}

fn flag<T: std::str::FromStr>(flags: &HashMap<String, String>, key: &str, default: T) -> T {
    flags.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn spec_from(flags: &HashMap<String, String>) -> SystemSpec {
    let mut spec = SystemSpec::paper_default();
    spec.cluster.racks = flag(flags, "racks", spec.cluster.racks);
    spec.cluster.nodes_per_rack = flag(flags, "nodes", spec.cluster.nodes_per_rack);
    let mb: u64 = flag(flags, "block", 16u64);
    spec.block_size = mb << 20;
    spec.net.cross_mbps = flag(flags, "cross-mbps", spec.net.cross_mbps);
    spec.net.inner_mbps = flag(flags, "inner-mbps", spec.net.inner_mbps);
    spec
}

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let cmd = args.first().map(String::as_str).unwrap_or("help");
    let flags = parse_flags(&args);
    match cmd {
        "exp" => cmd_exp(&args, &flags),
        "scenario" => cmd_scenario(&args, &flags),
        "chaos" => cmd_chaos(&flags),
        "trace" => cmd_trace(&flags),
        "scrub-daemon" => cmd_scrub_daemon(&flags),
        "durability" => cmd_durability(&flags),
        "layout" => cmd_layout(&flags),
        "mu" => cmd_mu(&flags),
        "oa" => cmd_oa(&flags),
        "cluster-demo" => cmd_cluster_demo(&flags),
        "calibrate" => cmd_calibrate(&flags),
        "kernel-info" => cmd_kernel_info(),
        "bench" => cmd_bench(&args),
        "bench-compare" => cmd_bench_compare(&flags),
        _ => {
            println!("d3ctl — Deterministic Data Distribution (D³) reproduction");
            println!("{}", include_str!("main.rs").lines().skip(2).take(27)
                .map(|l| l.trim_start_matches("//! ")).collect::<Vec<_>>().join("\n"));
        }
    }
}

/// `d3ctl kernel-info`: which GF kernel lane this process runs, and why —
/// the CPU-feature probe rows behind the decision, the runnable lanes,
/// and the `D3_FORCE_KERNEL` override if one is set (DESIGN.md §12).
fn cmd_kernel_info() {
    use d3ec::gf::dispatch;
    println!("arch: {}", std::env::consts::ARCH);
    let probes = dispatch::cpu_features();
    if probes.is_empty() {
        println!("cpu features: (no SIMD probes on this architecture)");
    } else {
        println!("cpu features:");
        for (name, detected) in probes {
            println!("  {name}: {}", if detected { "yes" } else { "no" });
        }
    }
    let lanes: Vec<&str> = dispatch::available_lanes().iter().map(|l| l.name()).collect();
    println!("available lanes: {}", lanes.join(", "));
    match std::env::var("D3_FORCE_KERNEL") {
        Ok(v) => println!("D3_FORCE_KERNEL: {v}"),
        Err(_) => println!("D3_FORCE_KERNEL: unset"),
    }
    println!("selected lane: {}", dispatch::active_lane().name());
}

/// `d3ctl bench`: the machine-readable hot-path suite (same harness as
/// `cargo bench --bench hotpath`, DESIGN.md §9). Writes the
/// `{bench_name: ns_per_byte}` perf-trajectory file — `BENCH_PR10.json`
/// by default, `--json PATH` to override; `--quick` for CI-sized runs.
fn cmd_bench(args: &[String]) {
    let quick = args.iter().any(|a| a == "--quick");
    let path = args
        .iter()
        .position(|a| a == "--json")
        .and_then(|i| args.get(i + 1))
        .cloned()
        .unwrap_or_else(|| "BENCH_PR10.json".to_string());
    let report = d3ec::perf::run_hotpath(&d3ec::perf::BenchOpts { quick });
    if let Some(r) = report.ratio("sched_fifo_8w", "sched_balanced_8w") {
        println!("headline: balanced schedule is {r:.2}x FIFO on contended links");
    }
    if let Some(r) = report.ns_per_byte.get("simd_vs_swar_mac") {
        println!("headline: simd MAC lane is {r:.2}x the swar kernel");
    }
    if let Some(r) = report.ns_per_byte.get("encode_ingest_1w_vs_8w") {
        println!("headline: 8-writer encode ingest is {r:.2}x one writer");
    }
    match report.write_json(std::path::Path::new(&path)) {
        Ok(()) => println!("wrote {} bench rows to {path}", report.ns_per_byte.len()),
        Err(e) => eprintln!("failed to write {path}: {e}"),
    }
}

/// `d3ctl bench-compare`: diff two `{bench_name: ns_per_byte}` reports
/// and fail (exit 1) when any tracked kernel regressed beyond the
/// tolerance — the CI perf gate between the previous PR's trajectory
/// file and `BENCH_PR10.json` (lower is better for every tracked key:
/// raw kernel rows are ns/B, and the two tracked store/cache rows are
/// cost ratios that must not grow).
fn cmd_bench_compare(flags: &HashMap<String, String>) {
    let old: String = flag(flags, "old", "BENCH_PR6.json".into());
    let new: String = flag(flags, "new", "BENCH_PR10.json".into());
    let tolerance: f64 = flag(flags, "tolerance", 0.15);
    let keys: String = flag(
        flags,
        "keys",
        "mac_16mb,mac_16kb_chunks_cached,xor_16mb_swar,combine_k6_fused,\
         store_synthetic_vs_materialized_read,cache_hit_vs_miss_degraded_read"
            .into(),
    );
    let keys: Vec<&str> = keys.split(',').filter(|k| !k.is_empty()).collect();
    match d3ec::perf::compare_bench_json(
        std::path::Path::new(&old),
        std::path::Path::new(&new),
        &keys,
        tolerance,
    ) {
        Ok(cmp) => {
            for row in &cmp.rows {
                println!("{row}");
            }
            if cmp.regressions.is_empty() {
                println!(
                    "bench-compare OK: no tracked kernel regressed more than {:.0}%",
                    tolerance * 100.0
                );
            } else {
                eprintln!("bench-compare FAILED: {}", cmp.regressions.join("; "));
                std::process::exit(1);
            }
        }
        Err(e) => {
            eprintln!("bench-compare error: {e}");
            std::process::exit(1);
        }
    }
}

/// `d3ctl scenario`: run one failure scenario on the fluid simulator, the
/// MiniCluster, and/or the socket-backed NetCluster (`--backend net`,
/// `all` for all three) through the same `FailureScenario →
/// RecoveryBackend` pipeline and report the outcomes side by side. `--fg-rate`/
/// `--fg-clients` attach client-engine foreground traffic to any kind,
/// `--recovery-share`/`--fg-weight` set the QoS split, and `--json`
/// emits the full `ScenarioOutcome`s as one JSON array for sweeps.
fn cmd_scenario(args: &[String], flags: &HashMap<String, String>) {
    let spec = spec_from(flags);
    let code = CodeSpec::parse(&flag::<String>(flags, "code", "rs-6-3".into()))
        .expect("bad --code (rs-K-M or lrc-K-L-G)");
    let policy_name: String = flag(flags, "policy", "d3".into());
    let seed: u64 = flag(flags, "seed", 1u64);
    let stripes: u64 = flag(flags, "stripes", 200u64);
    let kind: String = flag(flags, "kind", "single-node".into());
    let scenario = match kind.as_str() {
        "single-node" => FailureScenario::single_node(stripes, seed),
        "multi-node" => {
            FailureScenario::multi_node(flag(flags, "failures", 2usize), stripes, seed)
        }
        "rack-failure" => {
            FailureScenario::rack_failure(flag(flags, "rack", 0u32), stripes, seed)
        }
        "frontend-mix" => FailureScenario::frontend_mix(
            &flag::<String>(flags, "workload", "terasort".into()),
            stripes,
            seed,
        ),
        "degraded-burst" => {
            FailureScenario::degraded_burst(flag(flags, "reads", 32usize), stripes, seed)
        }
        other => {
            eprintln!(
                "unknown --kind {other} (single-node, multi-node, rack-failure, \
                 frontend-mix, degraded-burst)"
            );
            return;
        }
    };
    // QoS split + optional client-engine foreground traffic (DESIGN.md
    // §11): --fg-rate attaches an open-loop read stream, --fg-clients a
    // closed-loop one; either turns any kind into a mixed-load scenario.
    // Only explicit flags override a kind's QoS default (frontend-mix
    // ships with recovery_share 0.25, the HDFS max-streams throttle).
    let mut scenario = scenario;
    if flags.contains_key("recovery-share") || flags.contains_key("fg-weight") {
        let base = scenario.qos;
        scenario = scenario.with_qos(QosConfig {
            recovery_share: flag::<f64>(flags, "recovery-share", base.recovery_share)
                .clamp(0.01, 1.0),
            fg_weight: flag::<f64>(flags, "fg-weight", base.fg_weight).max(0.0),
        });
    }
    let fg_rate: f64 = flag(flags, "fg-rate", 0.0);
    let fg_clients: usize = flag(flags, "fg-clients", 0);
    let zipf: f64 = flag(flags, "zipf", 0.0);
    if fg_rate > 0.0 || fg_clients > 0 {
        let requests: usize = flag(flags, "fg-requests", 64);
        let arrival = if fg_rate > 0.0 {
            ArrivalModel::Open { rate_rps: fg_rate }
        } else {
            ArrivalModel::Closed {
                clients: fg_clients,
                think_s: flag(flags, "fg-think", 0.0),
            }
        };
        scenario = scenario.with_fg(FgSpec::reads(requests, arrival).with_zipf(zipf));
    } else if zipf > 0.0 {
        // skew the kind-derived foreground spec (degraded-burst reads,
        // frontend-mix) without changing anything else about it
        if let Ok(Some(fg)) = scenario.fg_spec() {
            scenario = scenario.with_fg(fg.with_zipf(zipf));
        }
    }
    let json_out = args.iter().any(|a| a == "--json");
    let policy = exp::build_policy(&policy_name, code, &spec, seed);
    if !json_out {
        println!(
            "# scenario {} · {} · {} on {} racks × {} nodes · {} stripes",
            scenario.name(),
            policy.name(),
            code.name(),
            spec.cluster.racks,
            spec.cluster.nodes_per_rack,
            stripes
        );
    }
    // pipelined executor knobs: same worker count and admission schedule
    // on both backends so the recovery-time comparison runs at matched
    // concurrency and in the same order (DESIGN.md §10)
    let workers: usize = flag(flags, "workers", 8usize);
    let chunk_kb: u64 = flag(flags, "chunk-size", 16u64);
    let schedule: SchedulePolicy = flag(flags, "schedule", SchedulePolicy::Fifo);
    let coalesce: usize = flag::<usize>(flags, "coalesce", 1).max(1);
    // batched fetches default on exactly when a window is coalesced
    let batched: bool = flag(flags, "batched-fetch", coalesce > 1);
    let mut sim = SimBackend::default();
    sim.cfg.workers = workers;
    sim.cfg.schedule = schedule;
    let mut cluster = ClusterBackend::default();
    cluster.block_size = flag::<u64>(flags, "cluster-block-kb", 64) << 10;
    cluster.data_backend = flag::<String>(flags, "data-backend", "native".into());
    cluster.workers = workers;
    cluster.chunk_size = chunk_kb.max(1) << 10;
    cluster.schedule = schedule;
    cluster.coalesce = coalesce;
    cluster.batched_fetch = batched;
    // PR 10 scale knobs: block-store representation (synthetic regenerates
    // payloads on read, bounding memory by metadata) and the client-side
    // hot-block cache budget (0 = off)
    cluster.store = flag::<StoreMode>(flags, "store", StoreMode::Auto);
    cluster.cache_mb = flag(flags, "cache-mb", 0u64);
    // the socket-backed backend shares the cluster backend's knobs, so
    // `--backend all` runs all three at matched block size / schedule
    let mut net = NetClusterBackend::default();
    net.block_size = cluster.block_size;
    net.workers = workers;
    net.chunk_size = cluster.chunk_size;
    net.schedule = schedule;
    net.coalesce = coalesce;
    net.batched_fetch = batched;
    let backend_sel: String = flag(flags, "backend", "both".into());
    let mut backends: Vec<&dyn RecoveryBackend> = Vec::new();
    if matches!(backend_sel.as_str(), "sim" | "both" | "all") {
        backends.push(&sim);
    }
    if matches!(backend_sel.as_str(), "cluster" | "both" | "all") {
        backends.push(&cluster);
    }
    if matches!(backend_sel.as_str(), "net" | "all") {
        backends.push(&net);
    }
    if backends.is_empty() {
        eprintln!("unknown --backend {backend_sel} (sim, cluster, net, both, all)");
        return;
    }
    if json_out {
        // machine-readable path: one JSON array of full outcomes on
        // stdout, nothing else (sweep scripts pipe this)
        let mut outs = Vec::with_capacity(backends.len());
        for backend in &backends {
            match backend.run(&scenario, &policy, &spec) {
                Ok(out) => outs.push(out.to_json()),
                Err(e) => {
                    eprintln!("scenario failed on {}: {e}", backend.name());
                    std::process::exit(1);
                }
            }
        }
        println!("{}", Json::Arr(outs).to_string());
        return;
    }
    match run_cross_backend(&scenario, &policy, &spec, &backends) {
        Ok(outs) => {
            if outs.len() >= 2 {
                // every backend must agree with the first on the
                // backend-independent quantities
                let ok = outs.iter().all(|o| {
                    o.planned_cross_rack_blocks == outs[0].planned_cross_rack_blocks
                        && o.blocks == outs[0].blocks
                });
                let sides: Vec<String> = outs
                    .iter()
                    .map(|o| {
                        format!(
                            "{} / {} ({})",
                            o.blocks, o.planned_cross_rack_blocks, o.backend
                        )
                    })
                    .collect();
                println!(
                    "\ncross-check [blocks / planned cross-rack transfers]: {} → {}",
                    sides.join(" vs "),
                    if ok { "consistent" } else { "MISMATCH" }
                );
            }
        }
        Err(e) => eprintln!("scenario failed: {e}"),
    }
}

/// `d3ctl chaos`: a fault-injection drill (DESIGN.md §14). Populates a
/// physical fabric, arms the chaos layer (net backend: frame drop /
/// delay / corrupt / truncate plus an optional mid-recovery worker
/// crash), runs a single-node recovery through the replan-capable
/// driver, then optionally plants latent stored corruption and runs the
/// scrub-and-repair pass. Every block is finally verified against its
/// write-time checksum. `--backend cluster` runs the storage-level
/// faults only (the in-process cluster has no RPC layer).
fn cmd_chaos(flags: &HashMap<String, String>) {
    let mut spec = spec_from(flags);
    spec.block_size = flag::<u64>(flags, "cluster-block-kb", 64) << 10;
    spec.net.inner_mbps = 8000.0;
    spec.net.cross_mbps = 1600.0;
    let code = CodeSpec::parse(&flag::<String>(flags, "code", "rs-6-3".into()))
        .expect("bad --code (rs-K-M or lrc-K-L-G)");
    let policy_name: String = flag(flags, "policy", "d3".into());
    let seed: u64 = flag(flags, "seed", 1u64);
    let stripes: u64 = flag(flags, "stripes", 100u64);
    let policy = exp::build_policy(&policy_name, code, &spec, seed);
    let crash: u64 = flag(flags, "crash", 0u64);
    let fspec = FaultSpec {
        drop: flag(flags, "drop", 0.02),
        delay: flag(flags, "delay", 0.02),
        delay_ms: flag(flags, "delay-ms", 2u64),
        corrupt: flag(flags, "corrupt", 0.02),
        truncate: flag(flags, "truncate", 0.02),
        corrupt_stored: flag(flags, "corrupt-stored", 0.0),
        crash_after_rpcs: (crash > 0).then_some(crash),
        seed,
        ..FaultSpec::default()
    };
    let cfg = ExecutorConfig {
        workers: flag(flags, "workers", 8usize),
        chunk_size: flag::<u64>(flags, "chunk-size", 16u64).max(1) << 10,
        ..ExecutorConfig::default()
    };
    let backend_sel: String = flag(flags, "backend", "net".into());
    let json_out = flags.contains_key("json");
    let k = code.k();
    let bs = spec.block_size as usize;
    if !json_out {
        println!(
            "# chaos drill · {} · {} · {stripes} stripes · backend {backend_sel}",
            policy.name(),
            code.name()
        );
    }
    match backend_sel.as_str() {
        "net" => {
            let cluster = NetCluster::new(spec, policy.clone(), seed).expect("net cluster");
            cluster
                .write_stripes_parallel(stripes, cfg.workers.max(2), |sid| {
                    deterministic_data(sid, k, bs)
                })
                .expect("populate");
            cluster.arm_chaos(fspec);
            run_chaos_drill(&cluster, policy.as_ref(), stripes, &fspec, cfg, seed, flags);
        }
        "cluster" => {
            if fspec.any_frame_faults() && !json_out {
                println!(
                    "note: frame faults apply to the net backend only; \
                     running storage-level faults"
                );
            }
            let cluster =
                MiniCluster::new(spec, policy.clone(), "native", seed).expect("cluster");
            for sid in 0..stripes {
                cluster
                    .write_stripe(sid, deterministic_data(sid, k, bs))
                    .expect("populate");
            }
            run_chaos_drill(&cluster, policy.as_ref(), stripes, &fspec, cfg, seed, flags);
        }
        other => eprintln!("unknown --backend {other} (cluster, net)"),
    }
}

/// The backend-generic body of `d3ctl chaos`: fail one node, recover
/// with replanning (surviving an armed crash), plant latent corruption,
/// scrub, verify everything against write-time checksums. `--json`
/// swaps the narrative for one JSON object (recovery, scrub, oracle,
/// and the chaos layer's full `FaultReport`) on stdout.
fn run_chaos_drill<F: BlockFabric>(
    fabric: &F,
    policy: &dyn Placement,
    stripes: u64,
    fspec: &FaultSpec,
    cfg: ExecutorConfig,
    seed: u64,
    flags: &HashMap<String, String>,
) {
    use std::collections::BTreeMap;
    let json_out = flags.contains_key("json");
    let mut doc = BTreeMap::new();
    let scenario = FailureScenario::single_node(stripes, seed);
    let failed = scenario.failed_nodes(policy);
    let plans = scenario_recovery_plans(policy, stripes, &failed, seed).expect("plans");
    for &loc in &failed {
        fabric.fail_node(loc);
    }
    if fspec.crash_after_rpcs.is_some() {
        if let Some(victim) = crash_victim(&plans, &failed) {
            fabric.arm_crash_victim(victim);
            if !json_out {
                println!(
                    "crash armed on {victim} after {:?} RPCs",
                    fspec.crash_after_rpcs
                );
            }
        }
    }
    match recover_with_replan(fabric, policy, stripes, failed, plans, cfg, seed, 3) {
        Ok((stats, replan)) => {
            if json_out {
                let mut r = BTreeMap::new();
                r.insert("blocks".into(), Json::Num(stats.blocks as f64));
                r.insert("bytes".into(), Json::Num(stats.bytes as f64));
                r.insert("wall_s".into(), Json::Num(stats.wall.as_secs_f64()));
                r.insert("throughput_mb_s".into(), Json::Num(stats.throughput_mb_s));
                r.insert("replan_rounds".into(), Json::Num(replan.rounds as f64));
                r.insert("replanned".into(), Json::Num(replan.replanned as f64));
                r.insert("detected".into(), Json::Num(replan.detected as f64));
                doc.insert("recovery".to_string(), Json::Obj(r));
            } else {
                println!(
                    "recovered {} blocks ({:.1} MB) in {:.2?} → {:.1} MB/s · {} rounds, \
                     {} blocks replanned, {} extra failures detected",
                    stats.blocks,
                    stats.bytes as f64 / 1e6,
                    stats.wall,
                    stats.throughput_mb_s,
                    replan.rounds,
                    replan.replanned,
                    replan.detected,
                );
            }
        }
        Err(e) => {
            eprintln!("recovery failed: {e}");
            return;
        }
    }
    // latent storage corruption, found and fixed by the scrub pass
    let victims = corrupt_set(fspec, stripes, policy.code().len());
    for &(sid, b) in &victims {
        if let Err(e) = fabric.corrupt_stored(sid, b) {
            eprintln!("corrupt ({sid},{b}): {e}");
        }
    }
    if !victims.is_empty() || flags.contains_key("scrub") {
        match run_scrub(fabric, policy, stripes, cfg, seed) {
            Ok(rep) => {
                if json_out {
                    let mut s = BTreeMap::new();
                    s.insert("scanned".into(), Json::Num(rep.scanned as f64));
                    s.insert("quarantined".into(), Json::Num(rep.quarantined as f64));
                    s.insert("repaired".into(), Json::Num(rep.repaired as f64));
                    doc.insert("scrub".to_string(), Json::Obj(s));
                } else {
                    println!(
                        "scrub: scanned {} blocks → quarantined {}, repaired {}",
                        rep.scanned, rep.quarantined, rep.repaired
                    );
                }
            }
            Err(e) => eprintln!("scrub failed: {e}"),
        }
    }
    // oracle check: every live block matches its write-time checksum
    let (mut checked, mut bad) = (0u64, 0u64);
    for sid in 0..stripes {
        for b in 0..policy.code().len() {
            let Some(want) = fabric.expected_checksum(sid, b) else { continue };
            match fabric.stored_checksum(sid, b) {
                Ok(got) if got == want => checked += 1,
                _ => bad += 1,
            }
        }
    }
    if json_out {
        let mut o = BTreeMap::new();
        o.insert("checked".into(), Json::Num(checked as f64));
        o.insert("corrupt".into(), Json::Num(bad as f64));
        doc.insert("oracle".to_string(), Json::Obj(o));
    } else {
        println!("oracle check: {checked} blocks match write-time checksums, {bad} corrupt");
    }
    if let Some(rep) = fabric.fault_report() {
        if json_out {
            doc.insert("faults".to_string(), rep.to_json());
        } else {
            println!(
                "faults: {} injected (drops {} · delays {} · corrupts {} · truncates {}) · \
                 retries {} · evictions {} · crashes {} · failovers {} · replans {} · \
                 quarantined {} · scrub-repaired {}",
                rep.total_injected(),
                rep.drops,
                rep.delays,
                rep.corrupts,
                rep.truncates,
                rep.retries,
                rep.evictions,
                rep.crashes,
                rep.failovers,
                rep.replans,
                rep.quarantined,
                rep.scrub_repaired,
            );
        }
    }
    if json_out {
        println!("{}", Json::Obj(doc).to_string());
    }
}

/// `d3ctl trace`: long-horizon failure arrivals (Poisson at `--rate`
/// events/hour, or replayed from `--file`) with repair overlapping
/// subsequent failures, on any of the three backends (DESIGN.md §14).
/// All backends batch events against the same modeled clock, so their
/// counters agree; each reports its own measured sustained repair rate.
fn cmd_trace(flags: &HashMap<String, String>) {
    let mut spec = spec_from(flags);
    spec.block_size = flag::<u64>(flags, "cluster-block-kb", 64) << 10;
    spec.net.inner_mbps = 8000.0;
    spec.net.cross_mbps = 1600.0;
    let code = CodeSpec::parse(&flag::<String>(flags, "code", "rs-6-3".into()))
        .expect("bad --code (rs-K-M or lrc-K-L-G)");
    let policy_name: String = flag(flags, "policy", "d3".into());
    let seed: u64 = flag(flags, "seed", 1u64);
    let stripes: u64 = flag(flags, "stripes", 100u64);
    let policy = exp::build_policy(&policy_name, code, &spec, seed);
    let mut tspec = TraceSpec {
        horizon_s: flag::<f64>(flags, "horizon-h", 24.0) * 3600.0,
        rate_per_hour: flag(flags, "rate", 2.0),
        repair_mb_s: flag(flags, "repair-mb-s", 64.0),
        events: None,
    };
    if let Some(path) = flags.get("file").filter(|p| !p.is_empty()) {
        let text = std::fs::read_to_string(path).expect("read trace file");
        tspec.events = Some(parse_trace(&text, &spec.cluster).expect("parse trace"));
    }
    let cfg = ExecutorConfig {
        workers: flag(flags, "workers", 8usize),
        chunk_size: flag::<u64>(flags, "chunk-size", 16u64).max(1) << 10,
        ..ExecutorConfig::default()
    };
    let backend_sel: String = flag(flags, "backend", "sim".into());
    let json_out = flags.contains_key("json");
    let k = code.k();
    let bs = spec.block_size as usize;
    if !json_out {
        println!(
            "# trace · {} · {} · {stripes} stripes · horizon {:.1} h · rate {:.2}/h",
            policy.name(),
            code.name(),
            tspec.horizon_s / 3600.0,
            tspec.rate_per_hour
        );
    }
    // `--json` emits one `{backend: TraceSummary}` object on stdout
    let mut json_doc = std::collections::BTreeMap::new();
    let mut emit = |backend: &str, s: &TraceSummary| {
        if json_out {
            json_doc.insert(backend.to_string(), s.to_json());
        } else {
            print_trace(backend, s);
        }
    };
    if matches!(backend_sel.as_str(), "sim" | "all") {
        let scfg = RecoveryConfig { workers: cfg.workers, ..RecoveryConfig::default() };
        match run_trace_sim(&spec, policy.as_ref(), stripes, &tspec, scfg, seed) {
            Ok(s) => emit("sim", &s),
            Err(e) => eprintln!("sim trace failed: {e}"),
        }
    }
    if matches!(backend_sel.as_str(), "cluster" | "all") {
        let cluster = MiniCluster::new(spec, policy.clone(), "native", seed).expect("cluster");
        for sid in 0..stripes {
            cluster
                .write_stripe(sid, deterministic_data(sid, k, bs))
                .expect("populate");
        }
        match run_trace(&cluster, policy.as_ref(), stripes, &tspec, cfg, seed) {
            Ok(s) => emit("cluster", &s),
            Err(e) => eprintln!("cluster trace failed: {e}"),
        }
    }
    if matches!(backend_sel.as_str(), "net" | "all") {
        let cluster = NetCluster::new(spec, policy.clone(), seed).expect("net cluster");
        cluster
            .write_stripes_parallel(stripes, cfg.workers.max(2), |sid| {
                deterministic_data(sid, k, bs)
            })
            .expect("populate");
        match run_trace(&cluster, policy.as_ref(), stripes, &tspec, cfg, seed) {
            Ok(s) => emit("net", &s),
            Err(e) => eprintln!("net trace failed: {e}"),
        }
    }
    if !matches!(backend_sel.as_str(), "sim" | "cluster" | "net" | "all") {
        eprintln!("unknown --backend {backend_sel} (sim, cluster, net, all)");
    }
    if json_out {
        println!("{}", Json::Obj(json_doc).to_string());
    }
}

fn print_trace(backend: &str, s: &TraceSummary) {
    println!(
        "{backend}: {} failures → {} rounds · {} blocks repaired · backlog peak {} · \
         lost stripes {} · arrival {:.2} MB/s vs sustained {:.1} MB/s",
        s.failures,
        s.rounds,
        s.blocks_repaired,
        s.backlog_peak,
        s.lost_stripes,
        s.arrival_mb_s,
        s.sustained_mb_s
    );
}

/// `d3ctl scrub-daemon`: populate a physical fabric, plant latent
/// stored corruption (`--corrupt-stored P`), then run the continuous
/// scrub daemon (DESIGN.md §15) for `--cycles` full registry passes and
/// report each cycle's scan/repair counters and deadline verdict.
fn cmd_scrub_daemon(flags: &HashMap<String, String>) {
    let mut spec = spec_from(flags);
    spec.block_size = flag::<u64>(flags, "cluster-block-kb", 64) << 10;
    spec.net.inner_mbps = 8000.0;
    spec.net.cross_mbps = 1600.0;
    let code = CodeSpec::parse(&flag::<String>(flags, "code", "rs-6-3".into()))
        .expect("bad --code (rs-K-M or lrc-K-L-G)");
    let policy_name: String = flag(flags, "policy", "d3".into());
    let seed: u64 = flag(flags, "seed", 1u64);
    let stripes: u64 = flag(flags, "stripes", 100u64);
    let policy = exp::build_policy(&policy_name, code, &spec, seed);
    let scfg = ScrubConfig {
        interval_s: flag(flags, "interval-s", 86_400.0),
        idle_mb_s: flag(flags, "idle-mb-s", 64.0),
        busy_mb_s: flag(flags, "busy-mb-s", 8.0),
        batch: flag(flags, "batch", 64usize),
    };
    let cycles: u64 = flag(flags, "cycles", 2u64);
    let cfg = ExecutorConfig {
        workers: flag(flags, "workers", 8usize),
        chunk_size: flag::<u64>(flags, "chunk-size", 16u64).max(1) << 10,
        ..ExecutorConfig::default()
    };
    let fspec = FaultSpec {
        corrupt_stored: flag(flags, "corrupt-stored", 0.02),
        seed,
        ..FaultSpec::default()
    };
    let json_out = flags.contains_key("json");
    let backend_sel: String = flag(flags, "backend", "cluster".into());
    let k = code.k();
    let bs = spec.block_size as usize;
    if !json_out {
        println!(
            "# scrub daemon · {} · {} · {stripes} stripes · backend {backend_sel} · \
             {cycles} cycles · interval {:.0} s",
            policy.name(),
            code.name(),
            scfg.interval_s
        );
    }
    match backend_sel.as_str() {
        "cluster" => {
            let cluster =
                MiniCluster::new(spec, policy.clone(), "native", seed).expect("cluster");
            for sid in 0..stripes {
                cluster
                    .write_stripe(sid, deterministic_data(sid, k, bs))
                    .expect("populate");
            }
            run_daemon_drill(&cluster, policy.as_ref(), stripes, &scfg, cfg, cycles, &fspec, seed, json_out);
        }
        "net" => {
            let cluster = NetCluster::new(spec, policy.clone(), seed).expect("net cluster");
            cluster
                .write_stripes_parallel(stripes, cfg.workers.max(2), |sid| {
                    deterministic_data(sid, k, bs)
                })
                .expect("populate");
            run_daemon_drill(&cluster, policy.as_ref(), stripes, &scfg, cfg, cycles, &fspec, seed, json_out);
        }
        other => eprintln!("unknown --backend {other} (cluster, net)"),
    }
}

/// Backend-generic body of `d3ctl scrub-daemon`: plant corruption, run
/// the daemon to completion, print (or JSON-emit) the report.
#[allow(clippy::too_many_arguments)]
fn run_daemon_drill<F: BlockFabric>(
    fabric: &F,
    policy: &dyn Placement,
    stripes: u64,
    scfg: &ScrubConfig,
    cfg: ExecutorConfig,
    cycles: u64,
    fspec: &FaultSpec,
    seed: u64,
    json_out: bool,
) {
    let victims = corrupt_set(fspec, stripes, policy.code().len());
    for &(sid, b) in &victims {
        if let Err(e) = fabric.corrupt_stored(sid, b) {
            eprintln!("corrupt ({sid},{b}): {e}");
        }
    }
    let stop = AtomicBool::new(false);
    match run_daemon(fabric, policy, stripes, scfg, cfg, cycles, seed, &stop) {
        Ok(rep) => {
            if json_out {
                println!("{}", rep.to_json().to_string());
                return;
            }
            for (i, c) in rep.cycles.iter().enumerate() {
                println!(
                    "cycle {i}: scanned {} (skipped {}) → corrupt {} · repaired {} · \
                     {} batches ({} throttled) · {:.0} s modeled · deadline {}",
                    c.scanned,
                    c.skipped,
                    c.corrupt_found,
                    c.repaired,
                    c.batches,
                    c.throttled_batches,
                    c.modeled_s,
                    if c.deadline_met { "met" } else { "MISSED" }
                );
            }
            println!(
                "daemon: planted {} · scanned {} · corrupt found {} · repaired {} · \
                 deadline misses {}",
                victims.len(),
                rep.scanned(),
                rep.corrupt_found(),
                rep.repaired(),
                rep.deadline_misses
            );
        }
        Err(e) => {
            eprintln!("scrub daemon failed: {e}");
            std::process::exit(1);
        }
    }
}

/// `d3ctl durability`: the Monte-Carlo MTTDL engine (DESIGN.md §15).
/// Runs the D³-vs-RDD × RS-vs-LRC matrix of seeded trials on the model
/// backend and reports MTTDL + loss-probability estimates with 95%
/// confidence intervals; `--backend cluster|net|all` additionally
/// replays one reduced-spec trial on the physical backend(s) and
/// demands bit-identical counters against the model (the cross-backend
/// spot check). `--quick` shrinks trials and horizon to CI size.
fn cmd_durability(flags: &HashMap<String, String>) {
    let spec = spec_from(flags);
    let seed: u64 = flag(flags, "seed", 1u64);
    let quick = flags.contains_key("quick");
    let json_out = flags.contains_key("json");
    let mut dspec = DurabilitySpec::default();
    if quick {
        dspec.trials = 12;
        dspec.horizon_s = 48.0 * 3600.0;
    }
    dspec.trials = flag(flags, "trials", dspec.trials);
    dspec.horizon_s = flag::<f64>(flags, "horizon-h", dspec.horizon_s / 3600.0) * 3600.0;
    dspec.fail_rate_per_hour = flag(flags, "rate", dspec.fail_rate_per_hour);
    dspec.rack_fail_prob = flag(flags, "rack-fail-prob", dspec.rack_fail_prob);
    dspec.corrupt_rate_per_hour = flag(flags, "corrupt-rate", dspec.corrupt_rate_per_hour);
    dspec.repair_mb_s = flag(flags, "repair-mb-s", dspec.repair_mb_s);
    if let Some(v) = flags.get("scrub-interval-h") {
        dspec.scrub_interval_s = v.parse::<f64>().ok().map(|h| h * 3600.0);
    }
    let stripes: u64 = flag(flags, "stripes", 60u64);
    let policies: Vec<String> = flag::<String>(flags, "policies", "d3,rdd".into())
        .split(',')
        .filter(|s| !s.is_empty())
        .map(str::to_string)
        .collect();
    let codes: Vec<(String, CodeSpec)> =
        flag::<String>(flags, "codes", "rs-6-3,lrc-4-2-1".into())
            .split(',')
            .filter(|s| !s.is_empty())
            .map(|s| (s.to_string(), CodeSpec::parse(s).expect("bad code in --codes")))
            .collect();
    if !json_out {
        println!(
            "# durability · {} trials × {:.0} h horizon · fail {:.1}/h (rack {:.0}%) · \
             corrupt {:.1}/h · scrub {} · repair {:.2} MB/s · {stripes} stripes",
            dspec.trials,
            dspec.horizon_s / 3600.0,
            dspec.fail_rate_per_hour,
            dspec.rack_fail_prob * 100.0,
            dspec.corrupt_rate_per_hour,
            dspec
                .scrub_interval_s
                .map_or("off".to_string(), |s| format!("{:.0} h", s / 3600.0)),
            dspec.repair_mb_s
        );
    }
    let cells = run_matrix(&spec, &dspec, &policies, &codes, stripes, seed)
        .expect("durability matrix");
    if !json_out {
        for c in &cells {
            let e = &c.est;
            let fmt_h = |v: f64| {
                if v.is_finite() { format!("{v:.1}") } else { "inf".to_string() }
            };
            println!(
                "{:>4} × {:<11}: losses {}/{} · MTTDL {} h (95% CI [{}, {}]) · \
                 P(loss) {:.2} [{:.2}, {:.2}] · lost {} stripes · {} corruptions \
                 ({} scrub-detected)",
                c.policy,
                c.code,
                e.losses,
                e.trials,
                e.mttdl_s.map_or("inf".to_string(), |s| format!("{:.1}", s / 3600.0)),
                fmt_h(e.mttdl_lo_s / 3600.0),
                fmt_h(e.mttdl_hi_s / 3600.0),
                e.loss_prob,
                e.loss_prob_lo,
                e.loss_prob_hi,
                c.lost_stripes,
                c.corruptions,
                c.scrub_detections
            );
        }
    }
    // cross-backend spot check: one reduced trial, bit-identical
    // counters demanded between the model and each physical backend
    let backend_sel: String = flag(flags, "backend", "sim".into());
    let spot = match backend_sel.as_str() {
        "sim" => Vec::new(),
        "cluster" | "net" | "all" => durability_spot_check(&backend_sel, seed, json_out),
        other => {
            eprintln!("unknown --backend {other} (sim, cluster, net, all)");
            return;
        }
    };
    if json_out {
        let mut doc = std::collections::BTreeMap::new();
        doc.insert("spec".to_string(), dspec.to_json());
        doc.insert(
            "matrix".to_string(),
            Json::Arr(cells.iter().map(|c| c.to_json()).collect()),
        );
        doc.insert("spot_check".to_string(), Json::Arr(spot));
        println!("{}", Json::Obj(doc).to_string());
    }
}

/// Replay durability trial 0 of a reduced spec on the model and on the
/// selected physical backend(s); every modeled counter must agree
/// exactly (`sustained_mb_s` is backend-measured and excluded). Exits
/// non-zero on divergence — this is the acceptance gate CI runs.
fn durability_spot_check(backend_sel: &str, seed: u64, json_out: bool) -> Vec<Json> {
    let mut spec = SystemSpec::paper_default();
    spec.block_size = 64 << 10;
    spec.net.inner_mbps = 8000.0;
    spec.net.cross_mbps = 1600.0;
    let code = CodeSpec::Rs { k: 6, m: 3 };
    let policy = exp::build_policy("d3", code, &spec, seed);
    let dspec = DurabilitySpec {
        horizon_s: 6.0 * 3600.0,
        fail_rate_per_hour: 6.0,
        rack_fail_prob: 0.25,
        corrupt_rate_per_hour: 12.0,
        scrub_interval_s: Some(2.0 * 3600.0),
        repair_mb_s: 0.05,
        trials: 1,
    };
    let stripes = 24u64;
    let cfg = ExecutorConfig { workers: 4, ..ExecutorConfig::default() };
    let model = run_durability_trial_model(
        policy.as_ref(),
        spec.block_size,
        stripes,
        &dspec,
        seed,
        0,
    )
    .expect("model trial");
    let k = code.k();
    let bs = spec.block_size as usize;
    let mut out = Vec::new();
    let mut check = |backend: &str, got: TraceSummary| {
        let ok = counters_match(&model, &got);
        if json_out {
            let mut m = std::collections::BTreeMap::new();
            m.insert("backend".to_string(), Json::Str(backend.into()));
            m.insert("consistent".to_string(), Json::Bool(ok));
            m.insert("trial".to_string(), got.to_json());
            out.push(Json::Obj(m));
        } else {
            println!(
                "spot check {backend}: {} failures · {} rounds · {} lost stripes · \
                 first loss {} → {}",
                got.failures,
                got.rounds,
                got.lost_stripes,
                got.first_loss_s.map_or("none".to_string(), |t| format!("{t:.0} s")),
                if ok { "consistent with model" } else { "MISMATCH" }
            );
        }
        if !ok {
            eprintln!("durability spot check diverged from the model on {backend}");
            std::process::exit(1);
        }
    };
    if matches!(backend_sel, "cluster" | "all") {
        let cluster =
            MiniCluster::new(spec, policy.clone(), "native", seed).expect("cluster");
        for sid in 0..stripes {
            cluster
                .write_stripe(sid, deterministic_data(sid, k, bs))
                .expect("populate");
        }
        let got =
            run_durability_trial(&cluster, policy.as_ref(), stripes, &dspec, cfg, seed, 0)
                .expect("cluster trial");
        check("cluster", got);
    }
    if matches!(backend_sel, "net" | "all") {
        let cluster = NetCluster::new(spec, policy.clone(), seed).expect("net cluster");
        cluster
            .write_stripes_parallel(stripes, cfg.workers.max(2), |sid| {
                deterministic_data(sid, k, bs)
            })
            .expect("populate");
        let got =
            run_durability_trial(&cluster, policy.as_ref(), stripes, &dspec, cfg, seed, 0)
                .expect("net trial");
        check("net", got);
    }
    out
}

/// Field-by-field equality of the modeled counters; `sustained_mb_s`
/// is the one backend-measured (wall-clock) field and is excluded.
fn counters_match(a: &TraceSummary, b: &TraceSummary) -> bool {
    a.failures == b.failures
        && a.rounds == b.rounds
        && a.blocks_repaired == b.blocks_repaired
        && a.lost_stripes == b.lost_stripes
        && a.corruptions == b.corruptions
        && a.scrub_detections == b.scrub_detections
        && a.corrupt_repaired == b.corrupt_repaired
        && a.backlog_peak == b.backlog_peak
        && a.arrival_mb_s == b.arrival_mb_s
        && a.horizon_s == b.horizon_s
        && a.first_loss_s == b.first_loss_s
}

fn cmd_exp(args: &[String], flags: &HashMap<String, String>) {
    let which = args.get(1).map(String::as_str).unwrap_or("all");
    let spec = spec_from(flags);
    let stripes: u64 = flag(flags, "stripes", exp::STRIPES);
    let run = |id: usize| match id {
        1 => drop(exp::exp01_load_balance(&spec, stripes)),
        2 => drop(exp::exp02_ec_config(&spec, stripes)),
        3 => drop(exp::exp03_degraded_read(&spec)),
        4 => drop(exp::exp04_block_size(&spec, stripes)),
        5 => drop(exp::exp05_bandwidth(&spec, stripes)),
        6 => drop(exp::exp06_racks(&spec, stripes)),
        7 => drop(exp::exp07_nodes_per_rack(&spec, stripes)),
        8 => drop(exp::exp08_lrc_recovery(&spec, stripes)),
        9 => drop(exp::exp09_lrc_block_size(&spec, stripes)),
        10 => drop(exp::frontend_exp::exp10_frontend_normal(&spec)),
        11 => drop(exp::frontend_exp::exp11_frontend_recovery(&spec, stripes)),
        _ => eprintln!("unknown experiment {id}"),
    };
    if which == "all" {
        for id in 1..=11 {
            run(id);
        }
    } else if let Ok(id) = which.parse::<usize>() {
        run(id);
    } else {
        eprintln!("usage: d3ctl exp <1..11|all>");
    }
}

fn cmd_layout(flags: &HashMap<String, String>) {
    let spec = spec_from(flags);
    let code = CodeSpec::parse(&flag::<String>(flags, "code", "rs-3-2".into()))
        .expect("bad --code (rs-K-M or lrc-K-L-G)");
    let policy_name: String = flag(flags, "policy", "d3".into());
    let policy = exp::build_policy(&policy_name, code, &spec, flag(flags, "seed", 1u64));
    let stripes: u64 = flag(flags, "stripes", 9u64);
    println!(
        "# {} layout of {} on {} racks × {} nodes",
        policy.name(),
        code.name(),
        spec.cluster.racks,
        spec.cluster.nodes_per_rack
    );
    for sid in 0..stripes {
        let sp = policy.stripe(sid);
        let cells: Vec<String> =
            sp.locs.iter().enumerate().map(|(b, l)| format!("B{b}@{l}")).collect();
        println!("S{sid}: {}", cells.join("  "));
    }
    // per-node totals
    let mut counts: HashMap<Location, usize> = HashMap::new();
    for sid in 0..stripes {
        for l in policy.stripe(sid).locs {
            *counts.entry(l).or_default() += 1;
        }
    }
    let mut nodes: Vec<_> = counts.into_iter().collect();
    nodes.sort();
    println!("\nper-node block counts over {stripes} stripes:");
    for (l, c) in nodes {
        println!("  {l}: {c}");
    }
}

fn cmd_mu(flags: &HashMap<String, String>) {
    let code = CodeSpec::parse(&flag::<String>(flags, "code", "rs-3-2".into()))
        .expect("bad --code");
    if let CodeSpec::Rs { k, m } = code {
        println!("Lemma 4: μ({k},{m}) = {:.4} cross-rack blocks/repair", mu_rs(k, m));
        println!("one-block-per-rack layout reads {k} cross-rack blocks/repair");
        println!("traffic saving: {:.1}%", (1.0 - mu_rs(k, m) / k as f64) * 100.0);
    } else {
        println!("μ closed form applies to RS codes (Lemma 4)");
    }
}

fn cmd_oa(flags: &HashMap<String, String>) {
    let n: usize = flag(flags, "n", 5);
    let cols: usize = flag(flags, "cols", max_columns(n).min(n));
    match OrthogonalArray::construct(n, cols) {
        Ok(oa) => {
            println!("OA({n},{cols}) — {} rows; Definition 1 verified: {}", oa.rows(), oa.verify());
            for r in 0..oa.rows() {
                let row: Vec<String> = (0..cols).map(|c| oa.entry(r, c).to_string()).collect();
                println!("{}", row.join(" "));
            }
        }
        Err(e) => eprintln!("{e}"),
    }
}

fn cmd_cluster_demo(flags: &HashMap<String, String>) {
    let backend: String = flag(flags, "backend", "pjrt".into());
    let mut spec = spec_from(flags);
    spec.block_size = flag::<u64>(flags, "block-kb", 256) << 10;
    spec.net.inner_mbps = 8000.0;
    spec.net.cross_mbps = 1600.0;
    let stripes: u64 = flag(flags, "stripes", 100);
    let code = CodeSpec::parse(&flag::<String>(flags, "code", "rs-3-2".into())).unwrap();
    let policy = exp::build_policy("d3", code, &spec, 0);
    println!("mini-HDFS demo: {} × {stripes} stripes, backend={backend}", code.name());
    let cluster = MiniCluster::new(spec, policy, &backend, 1).expect("cluster");
    let t0 = std::time::Instant::now();
    for sid in 0..stripes {
        let data: Vec<Vec<u8>> = (0..code.k())
            .map(|b| vec![(sid as u8).wrapping_mul(31).wrapping_add(b as u8); spec.block_size as usize])
            .collect();
        cluster.write_stripe(sid, data).expect("write");
    }
    println!("wrote {stripes} stripes in {:.2?}", t0.elapsed());
    let failed = Location::new(0, 0);
    cluster.fail_node(failed);
    let stats = cluster.recover_node(failed, stripes, 8).expect("recover");
    println!(
        "recovered {} blocks ({:.1} MB) in {:.2?} → {:.1} MB/s, λ={:.3}",
        stats.blocks,
        stats.bytes as f64 / 1e6,
        stats.wall,
        stats.throughput_mb_s,
        stats.lambda
    );
}

fn cmd_calibrate(flags: &HashMap<String, String>) {
    let len: usize = flag(flags, "len", 16 << 20);
    let k = 6usize;
    let shards: Vec<Vec<u8>> = (0..k).map(|i| vec![i as u8 + 1; len]).collect();
    let refs: Vec<&[u8]> = shards.iter().map(|v| v.as_slice()).collect();
    let coeffs: Vec<u8> = (1..=k as u8).collect();
    for backend in ["native", "pjrt"] {
        let coder = match backend {
            "native" => Coder::native(),
            _ => match Coder::pjrt() {
                Ok(c) => c,
                Err(e) => {
                    eprintln!("pjrt unavailable: {e}");
                    continue;
                }
            },
        };
        // warmup + timed runs
        let _ = coder.combine(&coeffs, &refs).unwrap();
        let t0 = std::time::Instant::now();
        let iters = 5;
        for _ in 0..iters {
            let _ = coder.combine(&coeffs, &refs).unwrap();
        }
        let per = t0.elapsed().as_secs_f64() / iters as f64;
        println!(
            "{backend}: combine k={k} over {} MB: {:.1} ms → {:.0} MB/s output ({:.0} MB/s source-stream)",
            len >> 20,
            per * 1e3,
            len as f64 / per / 1e6,
            (len * k) as f64 / per / 1e6,
        );
    }
    let _ = flags;
}
