//! Evaluation metrics (paper §6.2.1): load-imbalance λ, throughput, and
//! small summary-statistics helpers shared by the CLI and experiments.

/// λ = (Lmax − Lavg)/Lavg over a set of load samples (paper Exp 1).
/// Returns 0 for empty/zero loads.
pub fn lambda(loads: &[f64]) -> f64 {
    if loads.is_empty() {
        return 0.0;
    }
    let avg = loads.iter().sum::<f64>() / loads.len() as f64;
    if avg <= 0.0 {
        return 0.0;
    }
    let max = loads.iter().cloned().fold(f64::MIN, f64::max);
    (max - avg) / avg
}

/// Max/min ratio over the strictly-positive load samples — the
/// per-rack-link balance witness of the balanced recovery scheduler
/// (DESIGN.md §10): 1.0 is perfectly even; large values mean one link
/// carried far more repair traffic than another. Returns 1.0 when fewer
/// than two samples are positive (nothing to compare).
pub fn max_min_ratio(loads: &[f64]) -> f64 {
    let mut min = f64::INFINITY;
    let mut max = 0.0f64;
    let mut positive = 0usize;
    for &x in loads {
        if x > 0.0 {
            positive += 1;
            min = min.min(x);
            max = max.max(x);
        }
    }
    if positive < 2 {
        return 1.0;
    }
    max / min
}

/// Coefficient of variation (σ/μ) — secondary balance metric.
pub fn cv(loads: &[f64]) -> f64 {
    if loads.is_empty() {
        return 0.0;
    }
    let n = loads.len() as f64;
    let mean = loads.iter().sum::<f64>() / n;
    if mean <= 0.0 {
        return 0.0;
    }
    let var = loads.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n;
    var.sqrt() / mean
}

/// Simple percentile summary over latency samples (seconds). The tail
/// percentiles (p95/p99) are the client engine's QoS witnesses
/// (DESIGN.md §11): throttling recovery must show up here.
#[derive(Clone, Debug)]
pub struct Summary {
    pub count: usize,
    pub mean: f64,
    pub p50: f64,
    pub p95: f64,
    pub p99: f64,
    pub max: f64,
}

pub fn summarize(samples: &[f64]) -> Summary {
    assert!(!samples.is_empty(), "summarize of empty sample set");
    let mut v = samples.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let pct = |p: f64| v[((v.len() as f64 - 1.0) * p).round() as usize];
    Summary {
        count: v.len(),
        mean: v.iter().sum::<f64>() / v.len() as f64,
        p50: pct(0.50),
        p95: pct(0.95),
        p99: pct(0.99),
        max: *v.last().unwrap(),
    }
}

/// MB/s from bytes and seconds.
pub fn throughput_mb_s(bytes: u64, secs: f64) -> f64 {
    if secs <= 0.0 {
        return 0.0;
    }
    bytes as f64 / secs / 1e6
}

/// Hit/miss counters of a buffer pool (the recovery executor's per-worker
/// scratch pools, DESIGN.md §9). A *hit* reuses a pooled buffer; a *miss*
/// allocates. Steady-state recovery should be almost all hits — the
/// executor surfaces these through `ExecStats` and `ScenarioOutcome` so a
/// regression back to per-chunk allocation is visible in the metrics.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct PoolStats {
    pub hits: u64,
    pub misses: u64,
}

impl PoolStats {
    /// Fold another pool's counters into this one (per-worker → total).
    pub fn merge(&mut self, other: PoolStats) {
        self.hits += other.hits;
        self.misses += other.misses;
    }

    /// Hits as a fraction of all takes (0 when the pool was never used).
    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            0.0
        } else {
            self.hits as f64 / total as f64
        }
    }
}

/// Fault-injection and robustness counters of one scenario run
/// (DESIGN.md §14): what the chaos layer injected and what the
/// coordinator did to survive it. Injection decisions are keyed off
/// message *content* (not arrival order), so for a fixed seed + fault
/// spec the drop/delay/corrupt/truncate/retry/eviction counts replay
/// exactly across runs and thread interleavings; `failovers`/`replans`
/// depend on crash timing and are excluded from that determinism
/// contract.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct FaultReport {
    /// RPC frames dropped before they hit the wire.
    pub drops: u64,
    /// RPC frames delivered after an injected delay.
    pub delays: u64,
    /// RPC frames delivered with a flipped bit.
    pub corrupts: u64,
    /// RPC frames delivered with a truncated body.
    pub truncates: u64,
    /// Retry attempts the coordinator made after a failed round trip.
    pub retries: u64,
    /// Pooled connections evicted (closed instead of returned).
    pub evictions: u64,
    /// Workers crashed by the chaos layer.
    pub crashes: u64,
    /// Silent workers the heartbeat sweep escalated to Failed.
    pub failovers: u64,
    /// Repair plans re-issued against surviving sources after a failover.
    pub replans: u64,
    /// Corrupt replicas the scrub pass quarantined.
    pub quarantined: u64,
    /// Quarantined blocks rebuilt and re-verified by targeted re-repair.
    pub scrub_repaired: u64,
}

impl FaultReport {
    /// Total frames the chaos layer interfered with.
    pub fn total_injected(&self) -> u64 {
        self.drops + self.delays + self.corrupts + self.truncates
    }

    /// The counters as a JSON object — the `faults` block of
    /// `d3ctl scenario --json` and `d3ctl chaos --json` share this so
    /// the two commands can never drift apart on key names.
    pub fn to_json(&self) -> crate::util::json::Json {
        use crate::util::json::Json;
        let mut m = std::collections::BTreeMap::new();
        m.insert("drops".into(), Json::Num(self.drops as f64));
        m.insert("delays".into(), Json::Num(self.delays as f64));
        m.insert("corrupts".into(), Json::Num(self.corrupts as f64));
        m.insert("truncates".into(), Json::Num(self.truncates as f64));
        m.insert("retries".into(), Json::Num(self.retries as f64));
        m.insert("evictions".into(), Json::Num(self.evictions as f64));
        m.insert("crashes".into(), Json::Num(self.crashes as f64));
        m.insert("failovers".into(), Json::Num(self.failovers as f64));
        m.insert("replans".into(), Json::Num(self.replans as f64));
        m.insert("quarantined".into(), Json::Num(self.quarantined as f64));
        m.insert("scrub_repaired".into(), Json::Num(self.scrub_repaired as f64));
        Json::Obj(m)
    }
}

/// Per-worker utilization: each worker's busy seconds as a fraction of the
/// wall clock, clamped to [0, 1] (timer jitter can push busy ≳ wall).
/// Used by the recovery executor's `ExecStats` and `d3ctl scenario`.
pub fn utilization(busy_s: &[f64], wall_s: f64) -> Vec<f64> {
    if wall_s <= 0.0 {
        return vec![0.0; busy_s.len()];
    }
    busy_s.iter().map(|b| (b / wall_s).clamp(0.0, 1.0)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lambda_balanced_is_zero() {
        assert_eq!(lambda(&[5.0, 5.0, 5.0, 5.0]), 0.0);
        assert_eq!(lambda(&[]), 0.0);
        assert_eq!(lambda(&[0.0, 0.0]), 0.0);
    }

    #[test]
    fn lambda_matches_paper_formula() {
        // Lmax = 9, Lavg = 6 → λ = 0.5
        let l = lambda(&[3.0, 6.0, 9.0]);
        assert!((l - 0.5).abs() < 1e-12);
    }

    #[test]
    fn max_min_ratio_over_positive_samples() {
        assert_eq!(max_min_ratio(&[4.0, 4.0, 4.0]), 1.0);
        assert!((max_min_ratio(&[2.0, 8.0, 0.0]) - 4.0).abs() < 1e-12);
        assert_eq!(max_min_ratio(&[0.0, 0.0]), 1.0, "degenerate sets compare even");
        assert_eq!(max_min_ratio(&[5.0]), 1.0);
        assert_eq!(max_min_ratio(&[]), 1.0);
    }

    #[test]
    fn cv_zero_for_uniform_positive_for_skew() {
        assert_eq!(cv(&[2.0, 2.0]), 0.0);
        assert!(cv(&[1.0, 3.0]) > 0.4);
    }

    #[test]
    fn summary_percentiles() {
        let samples: Vec<f64> = (1..=100).map(|x| x as f64).collect();
        let s = summarize(&samples);
        assert_eq!(s.count, 100);
        assert!((s.mean - 50.5).abs() < 1e-9);
        assert!((s.p50 - 50.0).abs() <= 1.0);
        assert!((s.p95 - 95.0).abs() <= 1.0);
        assert!((s.p99 - 99.0).abs() <= 1.0);
        assert!(s.p95 <= s.p99 && s.p99 <= s.max);
        assert_eq!(s.max, 100.0);
    }

    #[test]
    fn throughput() {
        assert!((throughput_mb_s(32_000_000, 2.0) - 16.0).abs() < 1e-9);
        assert_eq!(throughput_mb_s(1, 0.0), 0.0);
    }

    #[test]
    fn pool_stats_merge_and_rate() {
        let mut p = PoolStats::default();
        assert_eq!(p.hit_rate(), 0.0);
        p.merge(PoolStats { hits: 3, misses: 1 });
        p.merge(PoolStats { hits: 1, misses: 1 });
        assert_eq!(p, PoolStats { hits: 4, misses: 2 });
        assert!((p.hit_rate() - 4.0 / 6.0).abs() < 1e-12);
    }

    #[test]
    fn utilization_fractions() {
        let u = utilization(&[1.0, 0.5, 2.5], 2.0);
        assert!((u[0] - 0.5).abs() < 1e-12);
        assert!((u[1] - 0.25).abs() < 1e-12);
        assert_eq!(u[2], 1.0, "clamped");
        assert_eq!(utilization(&[1.0, 1.0], 0.0), vec![0.0, 0.0]);
    }
}
