//! Cluster topology and hardware specs (paper §2.1, §6.1).
//!
//! A DSS is `r` racks × `n` nodes; nodes within a rack share a ToR switch
//! (inner-rack bandwidth), racks share an oversubscribed core router
//! (cross-rack bandwidth, typically 1/20–1/5 of inner-rack per node).

/// A storage node, addressed as (rack, node-within-rack) — paper's N_{i,j}.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct Location {
    pub rack: u32,
    pub node: u32,
}

impl Location {
    pub fn new(rack: usize, node: usize) -> Location {
        Location { rack: rack as u32, node: node as u32 }
    }
}

impl std::fmt::Display for Location {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "N{},{}", self.rack, self.node)
    }
}

/// Rack/node counts.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ClusterSpec {
    pub racks: usize,
    pub nodes_per_rack: usize,
}

impl ClusterSpec {
    pub fn new(racks: usize, nodes_per_rack: usize) -> ClusterSpec {
        ClusterSpec { racks, nodes_per_rack }
    }

    pub fn node_count(&self) -> usize {
        self.racks * self.nodes_per_rack
    }

    pub fn flat(&self, loc: Location) -> usize {
        loc.rack as usize * self.nodes_per_rack + loc.node as usize
    }

    pub fn unflat(&self, idx: usize) -> Location {
        Location::new(idx / self.nodes_per_rack, idx % self.nodes_per_rack)
    }

    pub fn contains(&self, loc: Location) -> bool {
        (loc.rack as usize) < self.racks && (loc.node as usize) < self.nodes_per_rack
    }

    pub fn iter_nodes(&self) -> impl Iterator<Item = Location> + '_ {
        let n = self.nodes_per_rack;
        (0..self.racks).flat_map(move |r| (0..n).map(move |j| Location::new(r, j)))
    }
}

/// Network rates in Mb/s per port, full duplex (paper §6.1: ToR ports at
/// 1000 Mb/s, core router ports at 100 Mb/s by default).
#[derive(Clone, Copy, Debug)]
pub struct NetSpec {
    /// Per-node ToR port rate (inner-rack), Mb/s.
    pub inner_mbps: f64,
    /// Per-rack core-router port rate (cross-rack), Mb/s.
    pub cross_mbps: f64,
}

impl Default for NetSpec {
    fn default() -> NetSpec {
        NetSpec { inner_mbps: 1000.0, cross_mbps: 100.0 }
    }
}

/// Disk model (paper testbed: 7200 RPM SATA HDD).
#[derive(Clone, Copy, Debug)]
pub struct DiskSpec {
    pub seq_read_mbps: f64,
    pub seq_write_mbps: f64,
    /// Average seek+rotational latency charged per *random* block access.
    pub seek_ms: f64,
}

impl Default for DiskSpec {
    fn default() -> DiskSpec {
        // ST1000DM010-class: ~160 MB/s sequential, ~12 ms random access.
        DiskSpec { seq_read_mbps: 160.0 * 8.0, seq_write_mbps: 150.0 * 8.0, seek_ms: 12.0 }
    }
}

/// CPU model: GF(2^8) coding throughput per node (measured from the PJRT
/// hot path by `d3ctl calibrate`, defaulted from the i5-7500 testbed).
#[derive(Clone, Copy, Debug)]
pub struct CpuSpec {
    /// XOR/GF combine throughput per source stream, Mb/s.
    pub gf_mbps: f64,
}

impl Default for CpuSpec {
    fn default() -> CpuSpec {
        CpuSpec { gf_mbps: 2500.0 * 8.0 }
    }
}

/// Everything the simulator and the mini-HDFS need to model the testbed.
#[derive(Clone, Copy, Debug)]
pub struct SystemSpec {
    pub cluster: ClusterSpec,
    pub net: NetSpec,
    pub disk: DiskSpec,
    pub cpu: CpuSpec,
    /// Block size in bytes (paper default 16 MB).
    pub block_size: u64,
}

impl SystemSpec {
    /// The paper's default testbed: 8 racks × 3 DataNodes, 16 MB blocks,
    /// 1000 Mb/s inner, 100 Mb/s cross.
    pub fn paper_default() -> SystemSpec {
        SystemSpec {
            cluster: ClusterSpec::new(8, 3),
            net: NetSpec::default(),
            disk: DiskSpec::default(),
            cpu: CpuSpec::default(),
            block_size: 16 << 20,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn flat_roundtrip() {
        let c = ClusterSpec::new(8, 3);
        assert_eq!(c.node_count(), 24);
        for idx in 0..24 {
            assert_eq!(c.flat(c.unflat(idx)), idx);
        }
        assert_eq!(c.iter_nodes().count(), 24);
        assert!(c.contains(Location::new(7, 2)));
        assert!(!c.contains(Location::new(8, 0)));
    }

    #[test]
    fn paper_default_matches_evaluation_setup() {
        let s = SystemSpec::paper_default();
        assert_eq!(s.cluster.racks, 8);
        assert_eq!(s.cluster.nodes_per_rack, 3);
        assert_eq!(s.block_size, 16 << 20);
        assert!((s.net.inner_mbps - 1000.0).abs() < 1e-9);
        assert!((s.net.cross_mbps - 100.0).abs() < 1e-9);
    }
}
