//! Exp 10/11 (Figs 18/19): front-end benchmark completion times in normal
//! state and during recovery, D³ vs RDD.
//!
//! The four Table-2 workloads run through the same fluid engine as
//! recovery. In the recovery experiment, the workload job and the repair
//! jobs share the engine so they contend for the same ports — the paper's
//! interference measurement.

use crate::codes::CodeSpec;
use crate::recovery::node::node_recovery_plans;
use crate::sim::engine::Engine;
use crate::sim::frontend::{workload_job, RandomPlacer, TaskPlacer, UniformPlacer};
use crate::sim::resources::ResourceTable;
use crate::topology::SystemSpec;
use crate::workloads;

use super::{build_policy, typical_failed_node, Point};

/// Fig 18: normal-state completion times. D³'s uniform layout of
/// intermediate data vs RDD's random layout.
pub fn exp10_frontend_normal(spec: &SystemSpec) -> Vec<Point> {
    let mut rows = Vec::new();
    super::fmt_pub_header(
        "Exp 10 (Fig 18): benchmarks in normal state",
        &["workload", "RDD(s)", "D3(s)", "gain"],
    );
    for w in workloads::specs() {
        let rt = ResourceTable::new(spec);
        let uni = UniformPlacer::new(spec);
        let d3_t = {
            let mut e = Engine::new(rt.caps.clone());
            e.spawn(workload_job(&w, &uni, &rt, spec));
            e.run_to_completion();
            e.now()
        };
        let mut rdd_t = 0.0;
        for seed in 1..=3u64 {
            let rnd = RandomPlacer::new(spec, seed);
            let mut e = Engine::new(rt.caps.clone());
            e.spawn(workload_job(&w, &rnd, &rt, spec));
            e.run_to_completion();
            rdd_t += e.now();
        }
        rdd_t /= 3.0;
        println!("{}\t{rdd_t:.1}\t{d3_t:.1}\t{:.1}%", w.name, (1.0 - d3_t / rdd_t) * 100.0);
        rows.push(Point { label: format!("rdd-{}", w.name), value: rdd_t, extra: 0.0 });
        rows.push(Point { label: format!("d3-{}", w.name), value: d3_t, extra: rdd_t / d3_t });
    }
    rows
}

/// Fig 19: completion times while a node recovery is in flight
/// ((2,1)-RS, 3000 stripes in the paper; scaled via `stripes`).
pub fn exp11_frontend_recovery(spec: &SystemSpec, stripes: u64) -> Vec<Point> {
    let code = CodeSpec::Rs { k: 2, m: 1 };
    let mut rows = Vec::new();
    super::fmt_pub_header(
        "Exp 11 (Fig 19): benchmarks during recovery",
        &["workload", "RDD(s)", "D3(s)", "gain"],
    );
    for w0 in workloads::specs() {
        // Real Hadoop runs of Table 2's configs last minutes (multi-wave
        // task execution); recovery lasts ~1 minute. Scale the workload so
        // it outlives recovery, as in the paper — the interference window
        // then depends on how *fast* and how *balanced* recovery is.
        let w = w0.scaled(20);
        let mut times = std::collections::HashMap::new();
        for name in ["rdd", "d3"] {
            let policy = build_policy(name, code, spec, 3);
            // fair comparison: fail a node with a *typical* block load under
            // each policy (RDD's weighted placement makes arbitrary nodes
            // hold very different volumes)
            let failed = typical_failed_node(policy.as_ref(), spec, stripes);
            let plans = node_recovery_plans(policy.as_ref(), stripes, failed, 3);
            let rt = ResourceTable::new(spec);
            let wl_job = if name == "d3" {
                let placer = UniformPlacer::new(spec);
                workload_job(&w, &placer as &dyn TaskPlacer, &rt, spec)
            } else {
                let placer = RandomPlacer::new(spec, 5);
                workload_job(&w, &placer as &dyn TaskPlacer, &rt, spec)
            };
            // the workload contends with a *throttled* recovery: HDFS
            // limits reconstruction to 2 streams per DataNode
            // (dfs.namenode.replication.max-streams), so recovery is a
            // bounded background load rather than an elastic one
            let cfg = crate::sim::recovery::RecoveryConfig {
                streams_per_node: 2,
                ..Default::default()
            };
            let (_, extra) = crate::sim::recovery::run_recovery_with_background(
                spec, &plans, failed, cfg, vec![wl_job],
            );
            times.insert(name, extra[0]);
        }
        let (r, d) = (times["rdd"], times["d3"]);
        println!("{}\t{r:.1}\t{d:.1}\t{:.1}%", w.name, (1.0 - d / r) * 100.0);
        rows.push(Point { label: format!("rdd-{}", w.name), value: r, extra: 0.0 });
        rows.push(Point { label: format!("d3-{}", w.name), value: d, extra: r / d });
    }
    rows
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exp10_d3_not_slower() {
        let rows = exp10_frontend_normal(&SystemSpec::paper_default());
        for w in ["pi", "terasort", "wordcount", "grep"] {
            let d3 = rows.iter().find(|r| r.label == format!("d3-{w}")).unwrap();
            assert!(d3.extra >= 0.95, "{w}: D³ normal-state regression ({})", d3.extra);
        }
    }

    #[test]
    fn exp11_recovery_interference_bounded() {
        let rows = exp11_frontend_recovery(&SystemSpec::paper_default(), 200);
        for w in ["terasort", "wordcount", "grep"] {
            let d3 = rows.iter().find(|r| r.label == format!("d3-{w}")).unwrap();
            assert!(d3.extra >= 0.9, "{w}: D³ should not be much slower in recovery");
        }
    }
}
