//! Experiment drivers — one per figure of the paper's evaluation (§6.2).
//!
//! The RDD-vs-D³ sweeps (Exps 2, 4–9) are *declarative*: each driver
//! builds a [`SweepSpec`] — rows of (label, system spec, code) plus the
//! baseline's seed sampling — and one generic runner ([`run_sweep`])
//! executes every row through the scenario primitives with a table-backed
//! placement lookup (DESIGN.md §5/§7). Exps 1, 3, 10, 11 keep bespoke
//! drivers (sorted-λ row sets, degraded-read sampling, front-end mixes)
//! on the same primitives. Every driver prints its figure's rows and
//! returns the series for programmatic checks (benches assert the paper's
//! qualitative shape: who wins, monotonicity, rough factors).

pub mod frontend_exp;

use std::sync::Arc;

use crate::codes::CodeSpec;
use crate::placement::{
    D3LrcPlacement, D3Placement, D3Variant, HddPlacement, Placement, PlacementTable,
    RddPlacement,
};
use crate::recovery::node::node_recovery_plans;
use crate::recovery::plan::plan_degraded_read;
use crate::sim::recovery::{run_degraded_read, run_recovery, RecoveryConfig, RecoveryOutcome};
use crate::topology::{Location, SystemSpec};
use crate::util::Rng;

/// Paper defaults (§6.2): 8 racks × 3 DataNodes, 16 MB blocks, (2,1)-RS,
/// 1000 stripes, 5-run averages.
pub const STRIPES: u64 = 1000;
pub const RUNS: usize = 5;

/// One printed series point.
#[derive(Clone, Debug)]
pub struct Point {
    pub label: String,
    pub value: f64,
    pub extra: f64,
}

pub fn build_policy(
    name: &str,
    code: CodeSpec,
    spec: &SystemSpec,
    seed: u64,
) -> Arc<dyn Placement> {
    match (name, code.is_lrc()) {
        ("d3", false) => Arc::new(D3Placement::new(code, spec.cluster).expect("d3 config")),
        ("d3-norot", false) => Arc::new(
            D3Placement::with_variant(code, spec.cluster, D3Variant::NoRotation).expect("config"),
        ),
        ("d3-rr", false) => Arc::new(
            D3Placement::with_variant(code, spec.cluster, D3Variant::RoundRobinRegions)
                .expect("config"),
        ),
        ("d3" | "d3-lrc", true) => {
            Arc::new(D3LrcPlacement::new(code, spec.cluster).expect("d3-lrc config"))
        }
        ("rdd", _) => Arc::new(RddPlacement::new(code, spec.cluster, seed)),
        ("hdd", _) => Arc::new(HddPlacement::new(code, spec.cluster, seed as u32)),
        _ => panic!("unknown policy {name}"),
    }
}

/// Average recovery over `runs` random failed nodes (the paper's protocol).
/// The policy's stripe → locations map is precomputed once
/// ([`PlacementTable`]), so the per-run planning loops do O(1) lookups.
pub fn avg_recovery(
    policy: &Arc<dyn Placement>,
    spec: &SystemSpec,
    stripes: u64,
    runs: usize,
    seed: u64,
) -> RecoveryOutcome {
    let table = PlacementTable::build(policy.clone(), stripes);
    let mut rng = Rng::keyed(seed, 0xfa11ed, 0);
    let mut acc: Option<RecoveryOutcome> = None;
    for _ in 0..runs {
        let failed = loop {
            let idx = rng.below(spec.cluster.node_count());
            let loc = spec.cluster.unflat(idx);
            // only meaningful if the node holds blocks
            let plans = node_recovery_plans(&table, stripes.min(50), loc, seed);
            if !plans.is_empty() {
                break loc;
            }
        };
        let plans = node_recovery_plans(&table, stripes, failed, seed);
        let out = run_recovery(spec, &plans, failed, RecoveryConfig::default());
        acc = Some(match acc {
            None => out,
            Some(prev) => RecoveryOutcome {
                makespan: prev.makespan + out.makespan,
                throughput_mb_s: prev.throughput_mb_s + out.throughput_mb_s,
                lambda: prev.lambda + out.lambda,
                rack_loads: prev.rack_loads,
                blocks: prev.blocks + out.blocks,
            },
        });
    }
    let mut out = acc.unwrap();
    out.makespan /= runs as f64;
    out.throughput_mb_s /= runs as f64;
    out.lambda /= runs as f64;
    out
}

pub(crate) fn fmt_pub_header(title: &str, cols: &[&str]) {
    fmt_header(title, cols)
}

/// The node whose stored-block count is closest to the cluster average —
/// used when experiments must compare equal recovery volumes.
pub fn typical_failed_node(policy: &dyn Placement, spec: &SystemSpec, stripes: u64) -> Location {
    let mut counts: std::collections::HashMap<Location, usize> = std::collections::HashMap::new();
    for sid in 0..stripes {
        for l in policy.stripe(sid).locs {
            *counts.entry(l).or_default() += 1;
        }
    }
    let avg = counts.values().sum::<usize>() as f64 / spec.cluster.node_count() as f64;
    spec.cluster
        .iter_nodes()
        .min_by_key(|l| {
            let c = counts.get(l).copied().unwrap_or(0) as f64;
            ((c - avg).abs() * 1000.0) as u64
        })
        .unwrap()
}

fn fmt_header(title: &str, cols: &[&str]) {
    println!("\n=== {title} ===");
    println!("{}", cols.join("\t"));
}

// ------------------------------------------------- declarative sweeps

/// One row of a declarative RDD-vs-D³ sweep: the printed first column,
/// the suffix used in the returned [`Point`] labels, and the fully
/// resolved system spec + code for this point.
struct SweepRow {
    print_label: String,
    key: String,
    spec: SystemSpec,
    code: CodeSpec,
}

/// How the last printed column renders the D³/RDD ratio.
enum GainColumn {
    /// `1.25x`
    Speedup,
    /// `25.0%`
    Percent,
    /// no gain column (Exp 7)
    None,
}

/// A declarative experiment: RDD baseline (averaged over `rdd_seeds`,
/// `rdd_runs` failed nodes each) vs D³ (`d3_runs` failed nodes), swept
/// over `rows`. [`run_sweep`] is the single generic runner behind
/// Exps 2 and 4–9.
struct SweepSpec {
    title: &'static str,
    columns: &'static [&'static str],
    rows: Vec<SweepRow>,
    rdd_seeds: Vec<u64>,
    rdd_runs: usize,
    d3_runs: usize,
    gain: GainColumn,
}

fn run_sweep(sw: &SweepSpec, stripes: u64) -> Vec<Point> {
    fmt_header(sw.title, sw.columns);
    let mut out = Vec::new();
    for row in &sw.rows {
        let mut rdd_sum = 0.0;
        for &seed in &sw.rdd_seeds {
            rdd_sum += avg_recovery(
                &build_policy("rdd", row.code, &row.spec, seed),
                &row.spec,
                stripes,
                sw.rdd_runs,
                seed,
            )
            .throughput_mb_s;
        }
        let rdd = rdd_sum / sw.rdd_seeds.len() as f64;
        let d3 = avg_recovery(
            &build_policy("d3", row.code, &row.spec, 0),
            &row.spec,
            stripes,
            sw.d3_runs,
            0,
        )
        .throughput_mb_s;
        match sw.gain {
            GainColumn::Speedup => {
                println!("{}\t{rdd:.1}\t{d3:.1}\t{:.2}x", row.print_label, d3 / rdd)
            }
            GainColumn::Percent => println!(
                "{}\t{rdd:.1}\t{d3:.1}\t{:.1}%",
                row.print_label,
                (d3 / rdd - 1.0) * 100.0
            ),
            GainColumn::None => println!("{}\t{rdd:.1}\t{d3:.1}", row.print_label),
        }
        out.push(Point { label: format!("rdd-{}", row.key), value: rdd, extra: 0.0 });
        out.push(Point { label: format!("d3-{}", row.key), value: d3, extra: d3 / rdd });
    }
    out
}

// ---------------------------------------------------------------- Exp 1

/// Fig 8: recovery throughput + λ for RDD₁..₅ (sorted by λ), HDD, D³
/// under (2,1)-RS on the default testbed.
pub fn exp01_load_balance(spec: &SystemSpec, stripes: u64) -> Vec<Point> {
    let code = CodeSpec::Rs { k: 2, m: 1 };
    let mut rows: Vec<Point> = Vec::new();
    let mut rdd: Vec<(f64, f64)> = Vec::new();
    for seed in 1..=5u64 {
        let policy = build_policy("rdd", code, spec, seed);
        let out = avg_recovery(&policy, spec, stripes, RUNS, seed);
        rdd.push((out.lambda, out.throughput_mb_s));
    }
    rdd.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
    for (i, (lam, tput)) in rdd.iter().enumerate() {
        rows.push(Point { label: format!("RDD_{}", i + 1), value: *tput, extra: *lam });
    }
    let hdd = avg_recovery(&build_policy("hdd", code, spec, 7), spec, stripes, RUNS, 7);
    rows.push(Point { label: "HDD".into(), value: hdd.throughput_mb_s, extra: hdd.lambda });
    let d3 = avg_recovery(&build_policy("d3", code, spec, 0), spec, stripes, RUNS, 0);
    rows.push(Point { label: "D3".into(), value: d3.throughput_mb_s, extra: d3.lambda });
    fmt_header("Exp 1 (Fig 8): repair load balance — (2,1)-RS, 8 racks × 3 nodes", &[
        "scheme", "throughput(MB/s)", "lambda",
    ]);
    for r in &rows {
        println!("{}\t{:.1}\t{:.3}", r.label, r.value, r.extra);
    }
    rows
}

// ---------------------------------------------------------------- Exp 2

/// Fig 9: recovery throughput for (2,1), (3,2), (6,3)-RS × {RDD, D³}.
pub fn exp02_ec_config(spec: &SystemSpec, stripes: u64) -> Vec<Point> {
    let rows = [(2usize, 1usize), (3, 2), (6, 3)]
        .iter()
        .map(|&(k, m)| SweepRow {
            print_label: format!("({k},{m})-RS"),
            key: format!("({k},{m})"),
            spec: *spec,
            code: CodeSpec::Rs { k, m },
        })
        .collect();
    run_sweep(
        &SweepSpec {
            title: "Exp 2 (Fig 9): erasure-code configuration",
            columns: &["code", "RDD(MB/s)", "D3(MB/s)", "speedup"],
            rows,
            rdd_seeds: vec![1, 2, 3],
            rdd_runs: 3,
            d3_runs: RUNS,
            gain: GainColumn::Speedup,
        },
        stripes,
    )
}

// ---------------------------------------------------------------- Exp 3

/// Figs 10 & 11: degraded-read latency and single-block recovery rate.
pub fn exp03_degraded_read(spec: &SystemSpec) -> Vec<Point> {
    let mut rows = Vec::new();
    fmt_header("Exp 3 (Figs 10/11): degraded read", &[
        "code", "RDD lat(s)", "D3 lat(s)", "D3 saving", "D3 rate(MB/s)",
    ]);
    let samples = 30;
    for (k, m) in [(2usize, 1usize), (3, 2), (6, 3)] {
        let code = CodeSpec::Rs { k, m };
        let mut lat = std::collections::HashMap::new();
        for name in ["rdd", "d3"] {
            let policy = build_policy(name, code, spec, 1);
            let table = PlacementTable::build(policy, 1000);
            let mut rng = Rng::keyed(42, k as u64, m as u64);
            let mut total = 0.0;
            for s in 0..samples {
                let sid = rng.below(1000) as u64;
                let block = rng.below(k); // data block, like the paper
                let client = spec.cluster.unflat(rng.below(spec.cluster.node_count()));
                let plan = plan_degraded_read(&table, sid, block, client, s as u64);
                total += run_degraded_read(spec, &plan);
            }
            lat.insert(name, total / samples as f64);
        }
        let (r, d) = (lat["rdd"], lat["d3"]);
        let rate = spec.block_size as f64 / d / 1e6;
        println!("({k},{m})-RS\t{r:.2}\t{d:.2}\t{:.1}%\t{rate:.1}", (1.0 - d / r) * 100.0);
        rows.push(Point { label: format!("rdd-({k},{m})"), value: r, extra: 0.0 });
        rows.push(Point { label: format!("d3-({k},{m})"), value: d, extra: rate });
    }
    rows
}

// ---------------------------------------------------------------- Exp 4

/// Fig 12: block-size sweep 2–64 MB, (2,1)-RS, RDD fixed at a skewed
/// distribution (the paper pins λ = 0.75; we pin the most skewed of 20
/// candidate seeds and report its λ).
pub fn exp04_block_size(spec: &SystemSpec, stripes: u64) -> Vec<Point> {
    let code = CodeSpec::Rs { k: 2, m: 1 };
    let rdd_seed = most_skewed_seed(spec, code, stripes);
    let rows = [2u64, 4, 8, 16, 32, 64]
        .iter()
        .map(|&mb| {
            let mut s = *spec;
            s.block_size = mb << 20;
            SweepRow {
                print_label: format!("{mb}"),
                key: format!("{mb}MB"),
                spec: s,
                code,
            }
        })
        .collect();
    run_sweep(
        &SweepSpec {
            title: "Exp 4 (Fig 12): block size sweep — (2,1)-RS",
            columns: &["block(MB)", "RDD(MB/s)", "D3(MB/s)", "gain"],
            rows,
            rdd_seeds: vec![rdd_seed],
            rdd_runs: 3,
            d3_runs: 3,
            gain: GainColumn::Percent,
        },
        stripes,
    )
}

/// Pick the most λ-skewed RDD seed among 20 candidates (cheap probe).
pub fn most_skewed_seed(spec: &SystemSpec, code: CodeSpec, stripes: u64) -> u64 {
    let mut best = (1u64, -1.0f64);
    for seed in 1..=20u64 {
        let policy = build_policy("rdd", code, spec, seed);
        let failed = Location::new(0, 0);
        let plans = node_recovery_plans(policy.as_ref(), stripes, failed, seed);
        if plans.is_empty() {
            continue;
        }
        let out = run_recovery(spec, &plans, failed, RecoveryConfig::default());
        if out.lambda > best.1 {
            best = (seed, out.lambda);
        }
    }
    best.0
}

// ---------------------------------------------------------------- Exp 5

/// Fig 13: cross-rack bandwidth 100 vs 1000 Mb/s, (2,1)-RS.
pub fn exp05_bandwidth(spec: &SystemSpec, stripes: u64) -> Vec<Point> {
    let code = CodeSpec::Rs { k: 2, m: 1 };
    let rows = [100.0f64, 1000.0]
        .iter()
        .map(|&cross| {
            let mut s = *spec;
            s.net.cross_mbps = cross;
            SweepRow {
                print_label: format!("{cross:.0}"),
                key: format!("{cross:.0}"),
                spec: s,
                code,
            }
        })
        .collect();
    run_sweep(
        &SweepSpec {
            title: "Exp 5 (Fig 13): cross-rack bandwidth",
            columns: &["cross(Mb/s)", "RDD(MB/s)", "D3(MB/s)", "gain"],
            rows,
            rdd_seeds: vec![3, 11],
            rdd_runs: 3,
            d3_runs: 3,
            gain: GainColumn::Percent,
        },
        stripes,
    )
}

// ---------------------------------------------------------------- Exp 6

/// Fig 14: 5 / 7 / 9 racks (3 nodes each), (2,1)-RS.
pub fn exp06_racks(spec: &SystemSpec, stripes: u64) -> Vec<Point> {
    let code = CodeSpec::Rs { k: 2, m: 1 };
    let rows = [5usize, 7, 9]
        .iter()
        .map(|&racks| {
            let mut s = *spec;
            s.cluster.racks = racks;
            SweepRow {
                print_label: format!("{racks}"),
                key: format!("r{racks}"),
                spec: s,
                code,
            }
        })
        .collect();
    run_sweep(
        &SweepSpec {
            title: "Exp 6 (Fig 14): number of racks",
            columns: &["racks", "RDD(MB/s)", "D3(MB/s)", "speedup"],
            rows,
            rdd_seeds: vec![1, 2, 3],
            rdd_runs: 3,
            d3_runs: 3,
            gain: GainColumn::Speedup,
        },
        stripes,
    )
}

// ---------------------------------------------------------------- Exp 7

/// Fig 15: 3 / 4 / 5 nodes per rack (5 racks), (2,1)-RS.
pub fn exp07_nodes_per_rack(spec: &SystemSpec, stripes: u64) -> Vec<Point> {
    let code = CodeSpec::Rs { k: 2, m: 1 };
    let rows = [3usize, 4, 5]
        .iter()
        .map(|&n| {
            let mut s = *spec;
            s.cluster.racks = 5;
            s.cluster.nodes_per_rack = n;
            SweepRow {
                print_label: format!("{n}"),
                key: format!("n{n}"),
                spec: s,
                code,
            }
        })
        .collect();
    run_sweep(
        &SweepSpec {
            title: "Exp 7 (Fig 15): nodes per rack",
            columns: &["nodes/rack", "RDD(MB/s)", "D3(MB/s)"],
            rows,
            rdd_seeds: vec![1, 2, 3],
            rdd_runs: 3,
            d3_runs: 3,
            gain: GainColumn::None,
        },
        stripes,
    )
}

// ---------------------------------------------------------------- Exp 8 / 9

/// Fig 16: (4,2,1)-LRC recovery at 100 / 1000 Mb/s cross-rack.
pub fn exp08_lrc_recovery(spec: &SystemSpec, stripes: u64) -> Vec<Point> {
    let code = CodeSpec::Lrc { k: 4, l: 2, g: 1 };
    let rows = [100.0f64, 1000.0]
        .iter()
        .map(|&cross| {
            let mut s = *spec;
            s.net.cross_mbps = cross;
            SweepRow {
                print_label: format!("{cross:.0}"),
                key: format!("{cross:.0}"),
                spec: s,
                code,
            }
        })
        .collect();
    run_sweep(
        &SweepSpec {
            title: "Exp 8 (Fig 16): (4,2,1)-LRC recovery",
            columns: &["cross(Mb/s)", "RDD(MB/s)", "D3(MB/s)", "gain"],
            rows,
            rdd_seeds: vec![1, 2, 3],
            rdd_runs: 3,
            d3_runs: 3,
            gain: GainColumn::Percent,
        },
        stripes,
    )
}

/// Fig 17: (4,2,1)-LRC block-size sweep.
pub fn exp09_lrc_block_size(spec: &SystemSpec, stripes: u64) -> Vec<Point> {
    let code = CodeSpec::Lrc { k: 4, l: 2, g: 1 };
    let rdd_seed = most_skewed_seed(spec, code, stripes);
    let rows = [2u64, 4, 8, 16, 32, 64]
        .iter()
        .map(|&mb| {
            let mut s = *spec;
            s.block_size = mb << 20;
            SweepRow {
                print_label: format!("{mb}"),
                key: format!("{mb}MB"),
                spec: s,
                code,
            }
        })
        .collect();
    run_sweep(
        &SweepSpec {
            title: "Exp 9 (Fig 17): (4,2,1)-LRC block size sweep",
            columns: &["block(MB)", "RDD(MB/s)", "D3(MB/s)", "gain"],
            rows,
            rdd_seeds: vec![rdd_seed],
            rdd_runs: 3,
            d3_runs: 3,
            gain: GainColumn::Percent,
        },
        stripes,
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    fn quick_spec() -> SystemSpec {
        SystemSpec::paper_default()
    }

    #[test]
    fn exp01_shape_d3_balances_and_wins() {
        // 2 full placement cycles (r(r-1)·n² = 504 stripes each): D³'s
        // balance guarantees hold per cycle
        let rows = exp01_load_balance(&quick_spec(), 1008);
        let d3 = rows.iter().find(|r| r.label == "D3").unwrap();
        assert!(d3.extra < 0.15, "D³ λ should be near 0, got {}", d3.extra);
        let rdd_best = rows
            .iter()
            .filter(|r| r.label.starts_with("RDD"))
            .map(|r| r.value)
            .fold(0.0f64, f64::max);
        assert!(d3.value >= rdd_best * 0.95, "D³ {} vs best RDD {rdd_best}", d3.value);
        // RDD throughput should broadly decrease as λ grows (paper Fig 8)
        let rdds: Vec<&Point> =
            rows.iter().filter(|r| r.label.starts_with("RDD")).collect();
        assert!(rdds.first().unwrap().extra <= rdds.last().unwrap().extra);
    }

    #[test]
    fn exp02_shape_speedup_grows_with_stripe_size() {
        let rows = exp02_ec_config(&quick_spec(), 300);
        let speedup = |kk: &str| {
            rows.iter().find(|r| r.label == format!("d3-{kk}")).unwrap().extra
        };
        let s21 = speedup("(2,1)");
        let s32 = speedup("(3,2)");
        let s63 = speedup("(6,3)");
        assert!(s32 > s21, "(3,2) speedup {s32} should exceed (2,1) {s21}");
        assert!(s63 > 1.5, "(6,3) speedup {s63} too small");
        assert!(s32 > 1.5, "(3,2) speedup {s32} too small");
    }

    #[test]
    fn exp03_shape_d3_cuts_degraded_read_latency_for_wide_codes() {
        let rows = exp03_degraded_read(&quick_spec());
        let get = |l: &str| rows.iter().find(|r| r.label == l).unwrap().value;
        // (2,1): identical layout per paper — latencies close
        let r21 = get("rdd-(2,1)");
        let d21 = get("d3-(2,1)");
        assert!((d21 / r21 - 1.0).abs() < 0.35, "(2,1) should be close: {d21} vs {r21}");
        // (3,2)/(6,3): D³ reads fewer cross-rack blocks — faster
        assert!(get("d3-(3,2)") < get("rdd-(3,2)"));
        assert!(get("d3-(6,3)") < get("rdd-(6,3)"));
    }
}
