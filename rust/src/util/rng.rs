//! Deterministic PRNG: xoshiro256** seeded via splitmix64.
//!
//! Used wherever the paper's evaluation needs "random": RDD placement,
//! failed-node choice, workload arrival jitter. Streams are keyed so the
//! same (seed, key) always replays the same sequence — the reproducibility
//! the paper gets by fixing an RDD distribution per experiment group.

/// splitmix64 step — also used standalone for cheap hashing.
#[inline]
pub fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9e3779b97f4a7c15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xbf58476d1ce4e5b9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94d049bb133111eb);
    z ^ (z >> 31)
}

/// Deterministic xorshift64 byte fill — the shared data generator for
/// benches and differential tests (`perf`, the GF kernel suites). Same
/// recurrence the per-file copies in the older test suites use, so
/// seeded streams stay reproducible and cheap.
pub fn xorshift_bytes(len: usize, seed: u64) -> Vec<u8> {
    let mut s = seed.wrapping_mul(0x9e3779b97f4a7c15) | 1;
    (0..len)
        .map(|_| {
            s ^= s << 13;
            s ^= s >> 7;
            s ^= s << 17;
            (s >> 24) as u8
        })
        .collect()
}

/// xoshiro256** — fast, high-quality, 256-bit state.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    pub fn new(seed: u64) -> Rng {
        let mut sm = seed;
        Rng { s: [splitmix64(&mut sm), splitmix64(&mut sm), splitmix64(&mut sm), splitmix64(&mut sm)] }
    }

    /// Independent stream keyed by (seed, key1, key2).
    pub fn keyed(seed: u64, key1: u64, key2: u64) -> Rng {
        let mut sm = seed ^ key1.rotate_left(21) ^ key2.rotate_left(43);
        // extra whitening so nearby keys decorrelate
        let a = splitmix64(&mut sm);
        let b = splitmix64(&mut sm);
        let mut sm2 = a ^ b.rotate_left(17);
        Rng {
            s: [
                splitmix64(&mut sm2),
                splitmix64(&mut sm2),
                splitmix64(&mut sm2),
                splitmix64(&mut sm2),
            ],
        }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, bound) without modulo bias (Lemire's method).
    ///
    /// The full-width variant: callers that carry 64-bit quantities
    /// (stripe ids, weight sums) use this directly instead of
    /// round-tripping through `usize`, which truncates on 32-bit
    /// targets. Draws the same stream as `below` for equal bounds.
    #[inline]
    pub fn below_u64(&mut self, bound: u64) -> u64 {
        assert!(bound > 0);
        loop {
            let x = self.next_u64();
            let m = (x as u128).wrapping_mul(bound as u128);
            let lo = m as u64;
            if lo >= bound {
                return (m >> 64) as u64;
            }
            // reject the biased low zone
            let t = bound.wrapping_neg() % bound;
            if lo >= t {
                return (m >> 64) as u64;
            }
        }
    }

    /// Uniform in [0, bound) without modulo bias (Lemire's method).
    #[inline]
    pub fn below(&mut self, bound: usize) -> usize {
        self.below_u64(bound as u64) as usize
    }

    /// Uniform f64 in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Exponential with mean `mean` (Poisson inter-arrival times).
    pub fn exp(&mut self, mean: f64) -> f64 {
        let u = loop {
            let u = self.f64();
            if u > 0.0 {
                break u;
            }
        };
        -mean * u.ln()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, slice: &mut [T]) {
        for i in (1..slice.len()).rev() {
            let j = self.below(i + 1);
            slice.swap(i, j);
        }
    }

    /// Pick one element uniformly.
    pub fn choose<'a, T>(&mut self, slice: &'a [T]) -> &'a T {
        &slice[self.below(slice.len())]
    }

    /// Sample `count` distinct indices from 0..n (partial Fisher–Yates).
    pub fn sample_indices(&mut self, n: usize, count: usize) -> Vec<usize> {
        assert!(count <= n);
        let mut idx: Vec<usize> = (0..n).collect();
        for i in 0..count {
            let j = i + self.below(n - i);
            idx.swap(i, j);
        }
        idx.truncate(count);
        idx
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        let mut c = Rng::new(43);
        assert_ne!(a.next_u64(), c.next_u64());
    }

    #[test]
    fn keyed_streams_decorrelate() {
        let a: Vec<u64> = (0..8).map(|i| Rng::keyed(1, i, 0).next_u64()).collect();
        let distinct: std::collections::HashSet<u64> = a.iter().copied().collect();
        assert_eq!(distinct.len(), 8);
    }

    #[test]
    fn below_is_in_range_and_covers() {
        let mut rng = Rng::new(7);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let v = rng.below(10);
            assert!(v < 10);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn below_roughly_uniform() {
        let mut rng = Rng::new(99);
        let mut counts = [0usize; 8];
        let n = 80_000;
        for _ in 0..n {
            counts[rng.below(8)] += 1;
        }
        for &c in &counts {
            let expect = n / 8;
            assert!((c as i64 - expect as i64).abs() < (expect / 10) as i64, "{counts:?}");
        }
    }

    #[test]
    fn below_u64_handles_bounds_past_u32() {
        // Regression: client/gen.rs used to funnel 64-bit bounds through
        // `below(bound as usize)`, truncating for bounds >= 2^32 (and on
        // 32-bit targets for anything past 2^32-1). The wide variant must
        // stay in range AND actually reach the region above u32::MAX.
        let bound = 1u64 << 33;
        let mut rng = Rng::new(17);
        let mut above_u32 = 0usize;
        for _ in 0..256 {
            let v = rng.below_u64(bound);
            assert!(v < bound);
            if v > u32::MAX as u64 {
                above_u32 += 1;
            }
        }
        // half the range lies above u32::MAX; 256 draws all landing
        // below it would be a 2^-256 event
        assert!(above_u32 > 0, "draws never exceeded u32::MAX — truncation regressed");
    }

    #[test]
    fn below_u64_matches_below_stream_for_small_bounds() {
        // `below` delegates to `below_u64`; equal bounds must consume the
        // identical stream so every seeded test in the tree stays green.
        let mut a = Rng::new(23);
        let mut b = Rng::new(23);
        for bound in [1usize, 2, 7, 100, 1 << 20] {
            assert_eq!(a.below(bound) as u64, b.below_u64(bound as u64));
        }
    }

    #[test]
    fn shuffle_is_a_permutation() {
        let mut rng = Rng::new(3);
        let mut v: Vec<usize> = (0..50).collect();
        rng.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
        assert_ne!(v, (0..50).collect::<Vec<_>>(), "astronomically unlikely");
    }

    #[test]
    fn sample_indices_distinct() {
        let mut rng = Rng::new(5);
        for _ in 0..50 {
            let s = rng.sample_indices(20, 7);
            assert_eq!(s.len(), 7);
            let set: std::collections::HashSet<usize> = s.iter().copied().collect();
            assert_eq!(set.len(), 7);
            assert!(s.iter().all(|&x| x < 20));
        }
    }

    #[test]
    fn exp_mean_close() {
        let mut rng = Rng::new(11);
        let n = 50_000;
        let sum: f64 = (0..n).map(|_| rng.exp(4.0)).sum();
        let mean = sum / n as f64;
        assert!((mean - 4.0).abs() < 0.15, "mean={mean}");
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut rng = Rng::new(13);
        for _ in 0..1000 {
            let x = rng.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }
}
