//! Small self-contained utilities (the offline build has no crates.io
//! access beyond the `xla` dependency tree, so PRNG/JSON/stats live here).

pub mod json;
pub mod rng;

pub use rng::Rng;
