//! Minimal JSON: enough to read `artifacts/manifest.json` and write
//! experiment result files. No external crates (offline build).

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|f| f as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// Parse a JSON document.
    pub fn parse(src: &str) -> Result<Json, String> {
        let mut p = Parser { b: src.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            return Err(format!("trailing garbage at byte {}", p.i));
        }
        Ok(v)
    }

    /// Serialize (compact).
    #[allow(clippy::inherent_to_string)]
    pub fn to_string(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(out, k);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), String> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(format!("expected '{}' at byte {}", c as char, self.i))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        self.skip_ws();
        match self.peek().ok_or("unexpected eof")? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'n' => self.lit("null", Json::Null),
            _ => self.number(),
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, String> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.i))
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.i;
        while self
            .peek()
            .is_some_and(|c| c.is_ascii_digit() || matches!(c, b'-' | b'+' | b'.' | b'e' | b'E'))
        {
            self.i += 1;
        }
        let s = std::str::from_utf8(&self.b[start..self.i]).map_err(|e| e.to_string())?;
        s.parse::<f64>().map(Json::Num).map_err(|e| format!("bad number '{s}': {e}"))
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek().ok_or("unterminated string")? {
                b'"' => {
                    self.i += 1;
                    return Ok(out);
                }
                b'\\' => {
                    self.i += 1;
                    let e = self.peek().ok_or("bad escape")?;
                    self.i += 1;
                    match e {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'n' => out.push('\n'),
                        b't' => out.push('\t'),
                        b'r' => out.push('\r'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'u' => {
                            let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])
                                .map_err(|e| e.to_string())?;
                            let cp = u32::from_str_radix(hex, 16).map_err(|e| e.to_string())?;
                            self.i += 4;
                            out.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                        }
                        _ => return Err(format!("bad escape at byte {}", self.i)),
                    }
                }
                _ => {
                    // copy one utf-8 scalar
                    let s = std::str::from_utf8(&self.b[self.i..]).map_err(|e| e.to_string())?;
                    let c = s.chars().next().unwrap();
                    out.push(c);
                    self.i += c.len_utf8();
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.expect(b'[')?;
        let mut out = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            out.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(out));
                }
                _ => return Err(format!("expected ',' or ']' at byte {}", self.i)),
            }
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.expect(b'{')?;
        let mut out = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(out));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            out.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(out));
                }
                _ => return Err(format!("expected ',' or '}}' at byte {}", self.i)),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_manifest_shape() {
        let src = r#"{
            "width": 65536,
            "dtype": "u8",
            "entries": [
                {"name": "gf_combine_k2_w65536", "file": "gf_combine_k2_w65536.hlo.txt",
                 "op": "combine", "k": 2, "w": 65536}
            ]
        }"#;
        let v = Json::parse(src).unwrap();
        assert_eq!(v.get("width").and_then(Json::as_usize), Some(65536));
        let entries = v.get("entries").and_then(Json::as_arr).unwrap();
        assert_eq!(entries.len(), 1);
        assert_eq!(entries[0].get("op").and_then(Json::as_str), Some("combine"));
        assert_eq!(entries[0].get("k").and_then(Json::as_usize), Some(2));
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"a":[1,2.5,-3],"b":"x\ny","c":true,"d":null,"e":{}}"#;
        let v = Json::parse(src).unwrap();
        let s = v.to_string();
        assert_eq!(Json::parse(&s).unwrap(), v);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("hello").is_err());
        assert!(Json::parse("{} extra").is_err());
    }

    #[test]
    fn unicode_escape() {
        let v = Json::parse(r#""Aé""#).unwrap();
        assert_eq!(v.as_str(), Some("Aé"));
    }
}
