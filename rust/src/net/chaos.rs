//! Deterministic, seeded fault injection for the socket backend
//! (DESIGN.md §14).
//!
//! The chaos layer sits in the coordinator's single RPC choke point
//! ([`crate::net::NetCluster`]'s `call`): before each attempt of each
//! round trip it draws one [`FaultAction`] — drop, delay, corrupt, or
//! truncate the request frame — and the coordinator's retry loop
//! (bounded attempts, exponential backoff + jitter, connection-pool
//! eviction and re-dial) must absorb it.
//!
//! **Determinism contract:** every draw is keyed off the *content* of the
//! message (`proto::checksum` of the encoded body, mixed with the target
//! node) plus the attempt number — never off arrival order or wall clock.
//! The set of RPCs a recovery issues is a pure function of the plan set,
//! so two runs with the same seed and fault spec inject the identical
//! fault multiset regardless of thread interleaving, and the injection
//! counters in [`crate::metrics::FaultReport`] replay exactly.
//!
//! Faults never perturb byte accounting: the coordinator charges modeled
//! transfers once per *successful* logical operation, so a fault-injected
//! run reports byte-identical per-rack traffic to a fault-free run of the
//! same scenario — the chaos-parity cross-check CI runs.

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Duration;

use crate::metrics::FaultReport;
use crate::topology::Location;
use crate::util::Rng;

/// Domain-separation keys for the chaos RNG streams.
const KEY_ACTION: u64 = 0xfa_017_ac7;
const KEY_BACKOFF: u64 = 0xbac_0ff;
const KEY_MUTATE: u64 = 0x5e1ec7_b17;
const KEY_STORED: u64 = 0x5c_2b_c0_22;

/// What the chaos layer may inject into one RPC round trip.
///
/// Frame-drop probability also covers heartbeats — a probe that draws
/// `Drop` on every bounded attempt looks exactly like a silent worker, so
/// the failure detector's false-positive path is exercised too.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct FaultSpec {
    /// Probability a request frame is dropped before hitting the wire.
    pub drop: f64,
    /// Probability a request is delayed by up to `delay_ms` (jittered).
    pub delay: f64,
    /// Maximum injected delay, milliseconds.
    pub delay_ms: u64,
    /// Probability one bit of the request body is flipped.
    pub corrupt: f64,
    /// Probability the request body is truncated (frame stays well-formed,
    /// the message inside does not).
    pub truncate: f64,
    /// Probability each stored replica is latently corrupted at populate
    /// time (the scrub pass's workload; see [`corrupt_set`]).
    pub corrupt_stored: f64,
    /// Crash the worker hosting the most repair writes after this many
    /// chaos-armed recovery RPCs have completed (`None` = no crash).
    pub crash_after_rpcs: Option<u64>,
    /// Attempts on which injection still applies; from this attempt on the
    /// chaos layer stands down so a bounded retry loop always converges
    /// (real transport failures are still possible).
    pub give_up_after: u32,
    /// Bounded retry attempts per RPC.
    pub max_attempts: u32,
    /// Per-attempt RPC deadline (read timeout), milliseconds.
    pub rpc_timeout_ms: u64,
    /// Seed of every chaos stream (independent of the scenario seed).
    pub seed: u64,
}

impl Default for FaultSpec {
    fn default() -> FaultSpec {
        FaultSpec {
            drop: 0.0,
            delay: 0.0,
            delay_ms: 2,
            corrupt: 0.0,
            truncate: 0.0,
            corrupt_stored: 0.0,
            crash_after_rpcs: None,
            give_up_after: 3,
            max_attempts: 5,
            rpc_timeout_ms: 2000,
            seed: 0,
        }
    }
}

/// The decision for one attempt of one RPC.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FaultAction {
    None,
    Drop,
    /// Deliver after sleeping this long.
    Delay(Duration),
    /// Deliver with one bit of the body flipped at this bit index.
    Corrupt(usize),
    /// Deliver only the first `n` bytes of the body.
    Truncate(usize),
}

impl FaultSpec {
    /// A spec with the given uniform fault probability on drop, delay,
    /// corrupt, and truncate (the CI chaos-parity configuration).
    pub fn uniform(p: f64, seed: u64) -> FaultSpec {
        FaultSpec { drop: p, delay: p, corrupt: p, truncate: p, seed, ..FaultSpec::default() }
    }

    /// True when any frame-level fault can fire.
    pub fn any_frame_faults(&self) -> bool {
        self.drop > 0.0 || self.delay > 0.0 || self.corrupt > 0.0 || self.truncate > 0.0
    }

    /// Draw the fault action for `(content_key, attempt)`. `body_len` is
    /// the encoded request length, used to pick corrupt/truncate offsets.
    pub fn decide(&self, content_key: u64, attempt: u32, body_len: usize) -> FaultAction {
        if attempt >= self.give_up_after {
            return FaultAction::None;
        }
        let mut rng = Rng::keyed(self.seed ^ KEY_ACTION, content_key, attempt as u64);
        let p = rng.f64();
        if p < self.drop {
            return FaultAction::Drop;
        }
        if p < self.drop + self.delay {
            let mut jitter = Rng::keyed(self.seed ^ KEY_MUTATE, content_key, attempt as u64);
            let ms = 1 + jitter.below_u64(self.delay_ms.max(1));
            return FaultAction::Delay(Duration::from_millis(ms));
        }
        if body_len > 0 {
            if p < self.drop + self.delay + self.corrupt {
                let mut pick = Rng::keyed(self.seed ^ KEY_MUTATE, content_key, attempt as u64);
                return FaultAction::Corrupt(pick.below(body_len * 8));
            }
            if p < self.drop + self.delay + self.corrupt + self.truncate {
                let mut pick = Rng::keyed(self.seed ^ KEY_MUTATE, content_key, attempt as u64);
                return FaultAction::Truncate(pick.below(body_len));
            }
        }
        FaultAction::None
    }

    /// Exponential backoff with seeded jitter before retry `attempt`
    /// (attempt ≥ 1): `2^(attempt-1)` milliseconds base, plus up to 100%
    /// jitter, capped at 50 ms so chaos tests stay fast.
    pub fn backoff(&self, content_key: u64, attempt: u32) -> Duration {
        let base_ms = 1u64 << (attempt.saturating_sub(1)).min(6);
        let mut rng = Rng::keyed(self.seed ^ KEY_BACKOFF, content_key, attempt as u64);
        let jitter_us = rng.below_u64(base_ms * 1000 + 1);
        Duration::from_micros((base_ms * 1000 + jitter_us).min(50_000))
    }
}

/// The content key of one RPC: FNV over the encoded body, mixed with the
/// flat index of the target node so identical messages to different
/// workers draw independent streams.
pub fn content_key(body: &[u8], target_flat: usize) -> u64 {
    super::proto::checksum(body) ^ (target_flat as u64).wrapping_mul(0x9e3779b97f4a7c15)
}

/// Apply a corrupt/truncate action to an encoded body (drop/delay/none
/// leave it untouched). Returns the bytes to actually put on the wire.
pub fn mutate_body(body: &[u8], action: FaultAction) -> Vec<u8> {
    match action {
        FaultAction::Corrupt(bit) => {
            let mut out = body.to_vec();
            let bit = bit % (out.len() * 8).max(1);
            out[bit / 8] ^= 1 << (bit % 8);
            out
        }
        FaultAction::Truncate(n) => body[..n.min(body.len())].to_vec(),
        _ => body.to_vec(),
    }
}

/// The deterministic latent-corruption set: every `(stripe, block)` the
/// chaos seed marks corrupt with probability `spec.corrupt_stored`. Both
/// physical fabrics inject exactly this set after populate, and the fluid
/// backend derives it analytically to price the same scrub traffic.
pub fn corrupt_set(spec: &FaultSpec, stripes: u64, code_len: usize) -> Vec<(u64, usize)> {
    if spec.corrupt_stored <= 0.0 {
        return Vec::new();
    }
    let mut out = Vec::new();
    for sid in 0..stripes {
        for b in 0..code_len {
            let mut rng = Rng::keyed(spec.seed ^ KEY_STORED, sid, b as u64);
            if rng.f64() < spec.corrupt_stored {
                out.push((sid, b));
            }
        }
    }
    out
}

/// Shared atomic fault counters — one per armed [`crate::net::NetCluster`],
/// held by `Arc` so the scenario backend can read the totals after the
/// cluster itself is dropped.
#[derive(Debug, Default)]
pub struct FaultCounters {
    pub drops: AtomicU64,
    pub delays: AtomicU64,
    pub corrupts: AtomicU64,
    pub truncates: AtomicU64,
    pub retries: AtomicU64,
    pub evictions: AtomicU64,
    pub crashes: AtomicU64,
    pub failovers: AtomicU64,
    pub replans: AtomicU64,
    pub quarantined: AtomicU64,
    pub scrub_repaired: AtomicU64,
}

impl FaultCounters {
    pub fn bump(counter: &AtomicU64) {
        counter.fetch_add(1, Ordering::Relaxed);
    }

    pub fn report(&self) -> FaultReport {
        FaultReport {
            drops: self.drops.load(Ordering::Relaxed),
            delays: self.delays.load(Ordering::Relaxed),
            corrupts: self.corrupts.load(Ordering::Relaxed),
            truncates: self.truncates.load(Ordering::Relaxed),
            retries: self.retries.load(Ordering::Relaxed),
            evictions: self.evictions.load(Ordering::Relaxed),
            crashes: self.crashes.load(Ordering::Relaxed),
            failovers: self.failovers.load(Ordering::Relaxed),
            replans: self.replans.load(Ordering::Relaxed),
            quarantined: self.quarantined.load(Ordering::Relaxed),
            scrub_repaired: self.scrub_repaired.load(Ordering::Relaxed),
        }
    }
}

/// One armed chaos runtime: the spec, its counters, and the crash
/// trigger's remaining-RPC countdown + victim.
#[derive(Debug)]
pub struct ChaosRuntime {
    pub spec: FaultSpec,
    pub counters: FaultCounters,
    /// Recovery RPCs left before the crash directive fires (u64::MAX when
    /// no crash is armed). Decremented once per completed chaos-armed RPC
    /// — but only after a victim is armed, so "crash after N RPCs" counts
    /// from mid-recovery, not from populate.
    pub crash_fuse: AtomicU64,
    /// The worker the crash directive kills. Armed by the scenario driver
    /// once plans exist (the busiest plan writer makes the best victim).
    crash_victim: std::sync::Mutex<Option<Location>>,
}

impl ChaosRuntime {
    pub fn new(spec: FaultSpec) -> ChaosRuntime {
        let fuse = spec.crash_after_rpcs.unwrap_or(u64::MAX);
        ChaosRuntime {
            spec,
            counters: FaultCounters::default(),
            crash_fuse: AtomicU64::new(fuse),
            crash_victim: std::sync::Mutex::new(None),
        }
    }

    /// Arm the crash directive's victim (no-op unless the spec asked for
    /// a crash; the fuse only burns once a victim is set).
    pub fn set_victim(&self, loc: Location) {
        if self.spec.crash_after_rpcs.is_some() {
            *self.crash_victim.lock().unwrap() = Some(loc);
        }
    }

    /// Burn one RPC off the crash fuse; returns the victim exactly once,
    /// on the call that crosses zero.
    pub fn burn_fuse(&self) -> Option<Location> {
        let victim = *self.crash_victim.lock().unwrap();
        victim?;
        let prev = self.crash_fuse.fetch_sub(1, Ordering::Relaxed);
        if prev == 1 {
            victim
        } else {
            None
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn decisions_are_deterministic_and_content_keyed() {
        let spec = FaultSpec::uniform(0.05, 42);
        for key in [1u64, 99, 0xdead_beef] {
            for attempt in 0..5 {
                assert_eq!(
                    spec.decide(key, attempt, 64),
                    spec.decide(key, attempt, 64),
                    "key={key} attempt={attempt}"
                );
            }
        }
        // different keys decorrelate: with 20% total fault rate over many
        // keys, at least one key must draw a fault and one must not
        let faulted = (0..500u64)
            .filter(|&k| spec.decide(k, 0, 64) != FaultAction::None)
            .count();
        assert!(faulted > 0 && faulted < 500, "{faulted}/500 keys faulted");
    }

    #[test]
    fn injection_stands_down_after_give_up_attempt() {
        let spec = FaultSpec::uniform(1.0, 7);
        assert_ne!(spec.decide(3, 0, 64), FaultAction::None);
        assert_ne!(spec.decide(3, spec.give_up_after - 1, 64), FaultAction::None);
        assert_eq!(spec.decide(3, spec.give_up_after, 64), FaultAction::None);
        assert_eq!(spec.decide(3, spec.give_up_after + 1, 64), FaultAction::None);
    }

    #[test]
    fn fault_rate_roughly_matches_probability() {
        let spec = FaultSpec { drop: 0.05, seed: 11, ..FaultSpec::default() };
        let n = 20_000u64;
        let drops = (0..n).filter(|&k| spec.decide(k, 0, 32) == FaultAction::Drop).count();
        let rate = drops as f64 / n as f64;
        assert!((rate - 0.05).abs() < 0.01, "drop rate {rate}");
    }

    #[test]
    fn mutate_body_flips_exactly_one_bit() {
        let body = vec![0u8; 16];
        let out = mutate_body(&body, FaultAction::Corrupt(37));
        let flipped: u32 = out.iter().zip(&body).map(|(a, b)| (a ^ b).count_ones()).sum();
        assert_eq!(flipped, 1);
        assert_eq!(mutate_body(&body, FaultAction::Truncate(5)).len(), 5);
        assert_eq!(mutate_body(&body, FaultAction::None), body);
    }

    #[test]
    fn backoff_grows_and_stays_bounded() {
        let spec = FaultSpec::default();
        let b1 = spec.backoff(9, 1);
        let b4 = spec.backoff(9, 4);
        assert!(b1 >= Duration::from_millis(1));
        assert!(b4 > b1, "backoff must grow with attempts");
        assert!(spec.backoff(9, 30) <= Duration::from_millis(50));
        assert_eq!(spec.backoff(9, 2), spec.backoff(9, 2), "jitter must be seeded");
    }

    #[test]
    fn corrupt_set_is_deterministic_and_rate_matched() {
        let spec = FaultSpec { corrupt_stored: 0.1, seed: 5, ..FaultSpec::default() };
        let a = corrupt_set(&spec, 200, 5);
        assert_eq!(a, corrupt_set(&spec, 200, 5));
        let rate = a.len() as f64 / 1000.0;
        assert!((rate - 0.1).abs() < 0.04, "corruption rate {rate}");
        assert!(corrupt_set(&FaultSpec::default(), 200, 5).is_empty());
    }

    #[test]
    fn crash_fuse_fires_exactly_once() {
        let spec =
            FaultSpec { crash_after_rpcs: Some(3), ..FaultSpec::default() };
        let rt = ChaosRuntime::new(spec);
        assert_eq!(rt.burn_fuse(), None, "fuse must not burn before a victim is armed");
        rt.set_victim(Location::new(1, 2));
        assert_eq!(rt.burn_fuse(), None);
        assert_eq!(rt.burn_fuse(), None);
        assert_eq!(rt.burn_fuse(), Some(Location::new(1, 2)));
        assert_eq!(rt.burn_fuse(), None);
        let unarmed = ChaosRuntime::new(FaultSpec::default());
        unarmed.set_victim(Location::new(0, 0));
        assert_eq!(unarmed.burn_fuse(), None, "no crash directive, no fuse");
    }
}
