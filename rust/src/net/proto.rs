//! Length-prefixed RPC protocol between the NetCluster coordinator and
//! its node workers (DESIGN.md §13).
//!
//! Framing: every message is `u32 little-endian length ‖ body ‖ u64
//! FNV-1a(body)`, capped at [`MAX_FRAME`]. The checksum trailer makes
//! on-the-wire bit-flips *detectable*: a corrupted body can never decode
//! as a different valid message (which would, e.g., let a flipped
//! `WriteBlock` payload silently poison a replica) — the receiver gets a
//! clean integrity error and drops the connection instead. Bodies are a
//! one-byte tag followed by fixed-width little-endian integers and
//! length-prefixed byte strings — hand-rolled (std-only, no serde) and
//! round-trip tested below. Requests are [`Msg`]; every request gets
//! exactly one [`Reply`] on the same connection, so a pooled connection
//! is always in a known state.

use std::io::{Read, Write};

use anyhow::{bail, Result};

/// Frame-size cap: a 16 MiB paper-default block plus headers fits with
/// lots of slack; anything larger is a corrupt or hostile length prefix.
pub const MAX_FRAME: usize = 64 << 20;

/// Worker membership states (DESIGN.md §13 state machine).
pub const STATE_UP: u8 = 0;
pub const STATE_DRAINING: u8 = 1;
pub const STATE_FAILED: u8 = 2;

/// Write one `len ‖ body ‖ fnv(body)` frame.
pub fn write_frame(w: &mut impl Write, body: &[u8]) -> std::io::Result<()> {
    w.write_all(&(body.len() as u32).to_le_bytes())?;
    w.write_all(body)?;
    w.write_all(&checksum(body).to_le_bytes())?;
    w.flush()
}

/// Read one frame; errors on EOF mid-frame, an oversized length, or an
/// integrity-trailer mismatch (a bit flipped anywhere in the body).
pub fn read_frame(r: &mut impl Read) -> std::io::Result<Vec<u8>> {
    let mut len = [0u8; 4];
    r.read_exact(&mut len)?;
    let len = u32::from_le_bytes(len) as usize;
    if len > MAX_FRAME {
        return Err(std::io::Error::new(
            std::io::ErrorKind::InvalidData,
            format!("frame of {len} bytes exceeds the {MAX_FRAME}-byte cap"),
        ));
    }
    let mut body = vec![0u8; len];
    r.read_exact(&mut body)?;
    let mut sum = [0u8; 8];
    r.read_exact(&mut sum)?;
    if u64::from_le_bytes(sum) != checksum(&body) {
        return Err(std::io::Error::new(
            std::io::ErrorKind::InvalidData,
            "frame integrity checksum mismatch",
        ));
    }
    Ok(body)
}

/// FNV-1a over a block's bytes — the recovered-block integrity digest
/// workers return from `RecoverPlan`, cheap enough to run inline.
pub fn checksum(bytes: &[u8]) -> u64 {
    let mut h = 0xcbf29ce484222325u64;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

/// One source of a worker-side rebuild: fetch `block` of the plan's
/// stripe from the worker at `addr` and scale it by `coeff`.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct PlanSource {
    pub coeff: u8,
    pub block: u32,
    /// Socket address of the worker currently holding the block.
    pub addr: String,
}

/// Coordinator → worker requests.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum Msg {
    /// Liveness + occupancy probe.
    Heartbeat,
    /// (Re)join as an empty replacement machine at the same address.
    Join,
    /// Stop accepting writes; reads keep working while blocks move off.
    Drain,
    /// Crash: drop all blocks, reject reads and writes.
    Fail,
    /// Store one block replica.
    WriteBlock { sid: u64, block: u32, bytes: Vec<u8> },
    /// Read one whole block.
    FetchBlock { sid: u64, block: u32 },
    /// Read bytes `[off, off + len)` of a block (executor chunk fetch).
    FetchChunk { sid: u64, block: u32, off: u64, len: u32 },
    /// Drop one block replica (after it was re-homed elsewhere).
    RemoveBlock { sid: u64, block: u32 },
    /// Enumerate held blocks (drain orchestration).
    ListBlocks,
    /// Pure-compute parity encode: `rows` is the m×k coefficient matrix
    /// flattened row-major, `shards` is k data shards of `shard_len`
    /// bytes back to back; the reply is the m parity shards back to back.
    Encode { k: u32, rows: Vec<u8>, shard_len: u32, shards: Vec<u8> },
    /// Worker-side block rebuild: pull every source from its peer,
    /// GF-combine, store the result, reply with its checksum.
    RecoverPlan { sid: u64, block: u32, block_len: u32, sources: Vec<PlanSource> },
    /// Scrub probe: FNV checksum of the stored replica's bytes — a
    /// node-local disk read, so the coordinator charges no link traffic.
    HashBlock { sid: u64, block: u32 },
}

/// Worker → coordinator replies.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum Reply {
    Ok,
    Err(String),
    Data(Vec<u8>),
    Blocks(Vec<(u64, u32)>),
    Beat { state: u8, blocks: u64 },
    Sum(u64),
}

const TAG_HEARTBEAT: u8 = 0x01;
const TAG_JOIN: u8 = 0x02;
const TAG_DRAIN: u8 = 0x03;
const TAG_FAIL: u8 = 0x04;
const TAG_WRITE_BLOCK: u8 = 0x05;
const TAG_FETCH_BLOCK: u8 = 0x06;
const TAG_FETCH_CHUNK: u8 = 0x07;
const TAG_REMOVE_BLOCK: u8 = 0x08;
const TAG_LIST_BLOCKS: u8 = 0x09;
const TAG_ENCODE: u8 = 0x0a;
const TAG_RECOVER_PLAN: u8 = 0x0b;
const TAG_HASH_BLOCK: u8 = 0x0c;

const TAG_OK: u8 = 0x80;
const TAG_ERR: u8 = 0x81;
const TAG_DATA: u8 = 0x82;
const TAG_BLOCKS: u8 = 0x83;
const TAG_BEAT: u8 = 0x84;
const TAG_SUM: u8 = 0x85;

fn put_bytes(out: &mut Vec<u8>, b: &[u8]) {
    out.extend_from_slice(&(b.len() as u32).to_le_bytes());
    out.extend_from_slice(b);
}

/// Byte-cursor over a frame body; every getter checks bounds.
struct Cursor<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Cursor<'a> {
    fn new(buf: &'a [u8]) -> Cursor<'a> {
        Cursor { buf, pos: 0 }
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        // checked_add: an adversarial length prefix near usize::MAX must
        // not wrap the bounds check into accepting a huge read
        let end = self
            .pos
            .checked_add(n)
            .ok_or_else(|| anyhow::anyhow!("length overflow at offset {}", self.pos))?;
        if end > self.buf.len() {
            bail!("truncated frame: wanted {n} bytes at offset {}", self.pos);
        }
        let s = &self.buf[self.pos..end];
        self.pos += n;
        Ok(s)
    }

    fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    fn bytes(&mut self) -> Result<Vec<u8>> {
        let len = self.u32()? as usize;
        Ok(self.take(len)?.to_vec())
    }

    fn string(&mut self) -> Result<String> {
        String::from_utf8(self.bytes()?).map_err(|_| anyhow::anyhow!("non-UTF-8 string field"))
    }

    fn finish(&self) -> Result<()> {
        if self.pos != self.buf.len() {
            bail!("{} trailing bytes after message", self.buf.len() - self.pos);
        }
        Ok(())
    }
}

impl Msg {
    pub fn encode(&self) -> Vec<u8> {
        let mut out = Vec::new();
        match self {
            Msg::Heartbeat => out.push(TAG_HEARTBEAT),
            Msg::Join => out.push(TAG_JOIN),
            Msg::Drain => out.push(TAG_DRAIN),
            Msg::Fail => out.push(TAG_FAIL),
            Msg::WriteBlock { sid, block, bytes } => {
                out.push(TAG_WRITE_BLOCK);
                out.extend_from_slice(&sid.to_le_bytes());
                out.extend_from_slice(&block.to_le_bytes());
                put_bytes(&mut out, bytes);
            }
            Msg::FetchBlock { sid, block } => {
                out.push(TAG_FETCH_BLOCK);
                out.extend_from_slice(&sid.to_le_bytes());
                out.extend_from_slice(&block.to_le_bytes());
            }
            Msg::FetchChunk { sid, block, off, len } => {
                out.push(TAG_FETCH_CHUNK);
                out.extend_from_slice(&sid.to_le_bytes());
                out.extend_from_slice(&block.to_le_bytes());
                out.extend_from_slice(&off.to_le_bytes());
                out.extend_from_slice(&len.to_le_bytes());
            }
            Msg::RemoveBlock { sid, block } => {
                out.push(TAG_REMOVE_BLOCK);
                out.extend_from_slice(&sid.to_le_bytes());
                out.extend_from_slice(&block.to_le_bytes());
            }
            Msg::ListBlocks => out.push(TAG_LIST_BLOCKS),
            Msg::Encode { k, rows, shard_len, shards } => {
                out.push(TAG_ENCODE);
                out.extend_from_slice(&k.to_le_bytes());
                put_bytes(&mut out, rows);
                out.extend_from_slice(&shard_len.to_le_bytes());
                put_bytes(&mut out, shards);
            }
            Msg::RecoverPlan { sid, block, block_len, sources } => {
                out.push(TAG_RECOVER_PLAN);
                out.extend_from_slice(&sid.to_le_bytes());
                out.extend_from_slice(&block.to_le_bytes());
                out.extend_from_slice(&block_len.to_le_bytes());
                out.extend_from_slice(&(sources.len() as u32).to_le_bytes());
                for s in sources {
                    out.push(s.coeff);
                    out.extend_from_slice(&s.block.to_le_bytes());
                    put_bytes(&mut out, s.addr.as_bytes());
                }
            }
            Msg::HashBlock { sid, block } => {
                out.push(TAG_HASH_BLOCK);
                out.extend_from_slice(&sid.to_le_bytes());
                out.extend_from_slice(&block.to_le_bytes());
            }
        }
        out
    }

    pub fn decode(body: &[u8]) -> Result<Msg> {
        let mut c = Cursor::new(body);
        let msg = match c.u8()? {
            TAG_HEARTBEAT => Msg::Heartbeat,
            TAG_JOIN => Msg::Join,
            TAG_DRAIN => Msg::Drain,
            TAG_FAIL => Msg::Fail,
            TAG_WRITE_BLOCK => {
                Msg::WriteBlock { sid: c.u64()?, block: c.u32()?, bytes: c.bytes()? }
            }
            TAG_FETCH_BLOCK => Msg::FetchBlock { sid: c.u64()?, block: c.u32()? },
            TAG_FETCH_CHUNK => Msg::FetchChunk {
                sid: c.u64()?,
                block: c.u32()?,
                off: c.u64()?,
                len: c.u32()?,
            },
            TAG_REMOVE_BLOCK => Msg::RemoveBlock { sid: c.u64()?, block: c.u32()? },
            TAG_LIST_BLOCKS => Msg::ListBlocks,
            TAG_ENCODE => Msg::Encode {
                k: c.u32()?,
                rows: c.bytes()?,
                shard_len: c.u32()?,
                shards: c.bytes()?,
            },
            TAG_RECOVER_PLAN => {
                let (sid, block, block_len) = (c.u64()?, c.u32()?, c.u32()?);
                let n = c.u32()? as usize;
                let mut sources = Vec::with_capacity(n.min(1024));
                for _ in 0..n {
                    sources.push(PlanSource {
                        coeff: c.u8()?,
                        block: c.u32()?,
                        addr: c.string()?,
                    });
                }
                Msg::RecoverPlan { sid, block, block_len, sources }
            }
            TAG_HASH_BLOCK => Msg::HashBlock { sid: c.u64()?, block: c.u32()? },
            t => bail!("unknown request tag 0x{t:02x}"),
        };
        c.finish()?;
        Ok(msg)
    }
}

impl Reply {
    pub fn encode(&self) -> Vec<u8> {
        let mut out = Vec::new();
        match self {
            Reply::Ok => out.push(TAG_OK),
            Reply::Err(e) => {
                out.push(TAG_ERR);
                put_bytes(&mut out, e.as_bytes());
            }
            Reply::Data(b) => {
                out.push(TAG_DATA);
                put_bytes(&mut out, b);
            }
            Reply::Blocks(blocks) => {
                out.push(TAG_BLOCKS);
                out.extend_from_slice(&(blocks.len() as u32).to_le_bytes());
                for &(sid, b) in blocks {
                    out.extend_from_slice(&sid.to_le_bytes());
                    out.extend_from_slice(&b.to_le_bytes());
                }
            }
            Reply::Beat { state, blocks } => {
                out.push(TAG_BEAT);
                out.push(*state);
                out.extend_from_slice(&blocks.to_le_bytes());
            }
            Reply::Sum(s) => {
                out.push(TAG_SUM);
                out.extend_from_slice(&s.to_le_bytes());
            }
        }
        out
    }

    pub fn decode(body: &[u8]) -> Result<Reply> {
        let mut c = Cursor::new(body);
        let reply = match c.u8()? {
            TAG_OK => Reply::Ok,
            TAG_ERR => Reply::Err(c.string()?),
            TAG_DATA => Reply::Data(c.bytes()?),
            TAG_BLOCKS => {
                let n = c.u32()? as usize;
                let mut blocks = Vec::with_capacity(n.min(1024));
                for _ in 0..n {
                    blocks.push((c.u64()?, c.u32()?));
                }
                Reply::Blocks(blocks)
            }
            TAG_BEAT => Reply::Beat { state: c.u8()?, blocks: c.u64()? },
            TAG_SUM => Reply::Sum(c.u64()?),
            t => bail!("unknown reply tag 0x{t:02x}"),
        };
        c.finish()?;
        Ok(reply)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn roundtrip_msg(m: Msg) {
        assert_eq!(Msg::decode(&m.encode()).unwrap(), m);
    }

    fn roundtrip_reply(r: Reply) {
        assert_eq!(Reply::decode(&r.encode()).unwrap(), r);
    }

    #[test]
    fn every_message_roundtrips() {
        roundtrip_msg(Msg::Heartbeat);
        roundtrip_msg(Msg::Join);
        roundtrip_msg(Msg::Drain);
        roundtrip_msg(Msg::Fail);
        roundtrip_msg(Msg::WriteBlock { sid: 7, block: 3, bytes: vec![1, 2, 3] });
        roundtrip_msg(Msg::FetchBlock { sid: u64::MAX, block: 11 });
        roundtrip_msg(Msg::FetchChunk { sid: 9, block: 0, off: 1 << 40, len: 4096 });
        roundtrip_msg(Msg::RemoveBlock { sid: 1, block: 2 });
        roundtrip_msg(Msg::ListBlocks);
        roundtrip_msg(Msg::Encode {
            k: 3,
            rows: vec![1, 2, 3, 4, 5, 6],
            shard_len: 2,
            shards: vec![9; 6],
        });
        roundtrip_msg(Msg::RecoverPlan {
            sid: 42,
            block: 4,
            block_len: 65536,
            sources: vec![
                PlanSource { coeff: 0x1d, block: 0, addr: "127.0.0.1:4000".into() },
                PlanSource { coeff: 1, block: 2, addr: "127.0.0.1:4001".into() },
            ],
        });
        roundtrip_msg(Msg::HashBlock { sid: 8, block: 4 });
    }

    #[test]
    fn every_reply_roundtrips() {
        roundtrip_reply(Reply::Ok);
        roundtrip_reply(Reply::Err("node N1,2 is failed".into()));
        roundtrip_reply(Reply::Data(vec![0xab; 100]));
        roundtrip_reply(Reply::Blocks(vec![(0, 1), (9, 4)]));
        roundtrip_reply(Reply::Beat { state: STATE_DRAINING, blocks: 12 });
        roundtrip_reply(Reply::Sum(0xdead_beef_cafe));
    }

    #[test]
    fn decode_rejects_garbage() {
        assert!(Msg::decode(&[]).is_err());
        assert!(Msg::decode(&[0x7f]).is_err());
        assert!(Msg::decode(&[TAG_WRITE_BLOCK, 1, 2]).is_err(), "truncated body");
        // trailing bytes after a complete message are an error, not ignored
        let mut ok = Msg::Heartbeat.encode();
        ok.push(0);
        assert!(Msg::decode(&ok).is_err());
        assert!(Reply::decode(&[0x01]).is_err());
    }

    #[test]
    fn frames_roundtrip_over_a_buffer() {
        let mut wire = Vec::new();
        write_frame(&mut wire, &Msg::FetchBlock { sid: 3, block: 1 }.encode()).unwrap();
        write_frame(&mut wire, &Reply::Ok.encode()).unwrap();
        let mut r = &wire[..];
        let m = Msg::decode(&read_frame(&mut r).unwrap()).unwrap();
        assert_eq!(m, Msg::FetchBlock { sid: 3, block: 1 });
        let rep = Reply::decode(&read_frame(&mut r).unwrap()).unwrap();
        assert_eq!(rep, Reply::Ok);
        assert!(r.is_empty());
    }

    #[test]
    fn oversized_frame_is_rejected() {
        let mut wire = Vec::new();
        wire.extend_from_slice(&(MAX_FRAME as u32 + 1).to_le_bytes());
        let mut r = &wire[..];
        assert!(read_frame(&mut r).is_err());
    }

    #[test]
    fn flipped_body_bit_fails_frame_integrity() {
        let body = Msg::WriteBlock { sid: 1, block: 0, bytes: vec![7; 32] }.encode();
        let mut wire = Vec::new();
        write_frame(&mut wire, &body).unwrap();
        for bit in [0usize, 13, body.len() * 8 - 1] {
            let mut bad = wire.clone();
            bad[4 + bit / 8] ^= 1 << (bit % 8);
            let mut r = &bad[..];
            let e = read_frame(&mut r).unwrap_err();
            assert_eq!(e.kind(), std::io::ErrorKind::InvalidData, "bit {bit}");
        }
        // untouched frame still reads back
        let mut r = &wire[..];
        assert_eq!(read_frame(&mut r).unwrap(), body);
    }

    #[test]
    fn checksum_is_stable_and_content_sensitive() {
        let a = checksum(b"hello");
        assert_eq!(a, checksum(b"hello"));
        assert_ne!(a, checksum(b"hellp"));
        assert_ne!(checksum(&[]), 0);
    }
}
