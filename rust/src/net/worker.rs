//! One storage node as a real network server (DESIGN.md §13): a TCP
//! listener on an ephemeral loopback port, a block store behind it, and
//! the membership state machine (Up → Draining/Failed → Up via Join).
//!
//! Workers are OS threads inside the test process — which keeps the
//! `D3_FORCE_KERNEL` GF-lane selection uniform across "machines" — but
//! nothing in the protocol knows that: every byte a worker serves or
//! rebuilds crosses a real socket, and worker-to-worker source fetches
//! during `RecoverPlan` open their own peer connections.

use std::collections::HashMap;
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;

use anyhow::{bail, Result};

use crate::gf;
use crate::topology::Location;

use super::proto::{self, Msg, PlanSource, Reply, STATE_DRAINING, STATE_FAILED, STATE_UP};

/// Coordinator-side handle to one spawned worker.
pub struct WorkerHandle {
    pub addr: SocketAddr,
    shutdown: Arc<AtomicBool>,
    listener: Option<JoinHandle<()>>,
}

impl WorkerHandle {
    /// Stop the accept loop and join the listener thread. Idempotent;
    /// also runs on drop so a panicking test never leaks the thread.
    pub fn stop(&mut self) {
        self.shutdown.store(true, Ordering::Relaxed);
        // wake the blocking accept with a throwaway connection
        let _ = TcpStream::connect(self.addr);
        if let Some(h) = self.listener.take() {
            let _ = h.join();
        }
    }
}

impl Drop for WorkerHandle {
    fn drop(&mut self) {
        self.stop();
    }
}

/// Server-side state of one node.
struct NodeWorker {
    loc: Location,
    /// One of [`STATE_UP`], [`STATE_DRAINING`], [`STATE_FAILED`].
    state: Mutex<u8>,
    store: Mutex<HashMap<(u64, u32), Vec<u8>>>,
}

/// Bind a listener on `127.0.0.1:0` and serve until the handle stops it.
/// Each accepted connection gets its own detached handler thread that
/// answers frames until the peer hangs up.
pub fn spawn_worker(loc: Location) -> Result<WorkerHandle> {
    let listener = TcpListener::bind(("127.0.0.1", 0))?;
    let addr = listener.local_addr()?;
    let shutdown = Arc::new(AtomicBool::new(false));
    let node = Arc::new(NodeWorker {
        loc,
        state: Mutex::new(STATE_UP),
        store: Mutex::new(HashMap::new()),
    });
    let stop = shutdown.clone();
    let handle = std::thread::spawn(move || {
        for conn in listener.incoming() {
            if stop.load(Ordering::Relaxed) {
                break;
            }
            let Ok(conn) = conn else { break };
            let node = node.clone();
            std::thread::spawn(move || serve_conn(&node, conn));
        }
    });
    Ok(WorkerHandle { addr, shutdown, listener: Some(handle) })
}

fn serve_conn(node: &NodeWorker, mut conn: TcpStream) {
    let _ = conn.set_nodelay(true);
    loop {
        // EOF (peer closed or pooled connection dropped) ends the handler
        let Ok(body) = proto::read_frame(&mut conn) else { return };
        let reply = match Msg::decode(&body) {
            Ok(msg) => node.serve(msg),
            Err(e) => Reply::Err(format!("bad request: {e}")),
        };
        if proto::write_frame(&mut conn, &reply.encode()).is_err() {
            return;
        }
    }
}

impl NodeWorker {
    fn serve(&self, msg: Msg) -> Reply {
        match msg {
            Msg::Heartbeat => Reply::Beat {
                state: *self.state.lock().unwrap(),
                blocks: self.store.lock().unwrap().len() as u64,
            },
            Msg::Join => {
                // a replacement machine at the same address: empty store
                self.store.lock().unwrap().clear();
                *self.state.lock().unwrap() = STATE_UP;
                Reply::Ok
            }
            Msg::Drain => {
                *self.state.lock().unwrap() = STATE_DRAINING;
                Reply::Ok
            }
            Msg::Fail => {
                self.store.lock().unwrap().clear();
                *self.state.lock().unwrap() = STATE_FAILED;
                Reply::Ok
            }
            Msg::WriteBlock { sid, block, bytes } => match *self.state.lock().unwrap() {
                STATE_UP => {
                    self.store.lock().unwrap().insert((sid, block), bytes);
                    Reply::Ok
                }
                STATE_DRAINING => {
                    Reply::Err(format!("draining node {} rejects writes", self.loc))
                }
                _ => Reply::Err(format!("failed node {} rejects writes", self.loc)),
            },
            Msg::FetchBlock { sid, block } => {
                if *self.state.lock().unwrap() == STATE_FAILED {
                    return Reply::Err(format!("failed node {} rejects reads", self.loc));
                }
                match self.store.lock().unwrap().get(&(sid, block)) {
                    Some(b) => Reply::Data(b.clone()),
                    None => {
                        Reply::Err(format!("block ({sid},{block}) missing at {}", self.loc))
                    }
                }
            }
            Msg::FetchChunk { sid, block, off, len } => {
                if *self.state.lock().unwrap() == STATE_FAILED {
                    return Reply::Err(format!("failed node {} rejects reads", self.loc));
                }
                let store = self.store.lock().unwrap();
                let Some(blk) = store.get(&(sid, block)) else {
                    return Reply::Err(format!(
                        "block ({sid},{block}) missing at {}",
                        self.loc
                    ));
                };
                let (off, len) = (off as usize, len as usize);
                if off + len > blk.len() {
                    return Reply::Err(format!(
                        "chunk [{off}, {}) out of range for block ({sid},{block}) of {} bytes",
                        off + len,
                        blk.len()
                    ));
                }
                Reply::Data(blk[off..off + len].to_vec())
            }
            Msg::RemoveBlock { sid, block } => {
                self.store.lock().unwrap().remove(&(sid, block));
                Reply::Ok
            }
            Msg::ListBlocks => {
                let mut blocks: Vec<(u64, u32)> =
                    self.store.lock().unwrap().keys().copied().collect();
                blocks.sort_unstable();
                Reply::Blocks(blocks)
            }
            Msg::Encode { k, rows, shard_len, shards } => {
                // pure compute — served in every state (a client may pick
                // any node as its encoder, exactly as the in-process
                // cluster models the client-side encode)
                self.encode(k as usize, &rows, shard_len as usize, &shards)
            }
            Msg::RecoverPlan { sid, block, block_len, sources } => {
                self.recover_plan(sid, block, block_len as usize, &sources)
            }
        }
    }

    /// GF parity encode: one fused multiply-accumulate per parity row,
    /// the same [`gf::combine_many_into`] kernel the coder service runs —
    /// so worker-side parity is byte-identical to MiniCluster parity.
    fn encode(&self, k: usize, rows: &[u8], shard_len: usize, shards: &[u8]) -> Reply {
        if k == 0 || shard_len == 0 {
            return Reply::Err("encode: empty shards".into());
        }
        if shards.len() != k * shard_len || rows.len() % k != 0 || rows.is_empty() {
            return Reply::Err(format!(
                "encode: shape mismatch (k={k}, {} coeffs, {} shard bytes)",
                rows.len(),
                shards.len()
            ));
        }
        let m = rows.len() / k;
        let mut parity = vec![0u8; m * shard_len];
        for (j, out) in parity.chunks_mut(shard_len).enumerate() {
            let pairs: Vec<(u8, &[u8])> = (0..k)
                .map(|i| (rows[j * k + i], &shards[i * shard_len..(i + 1) * shard_len]))
                .collect();
            gf::combine_many_into(out, &pairs);
        }
        Reply::Data(parity)
    }

    /// Rebuild one block ON the worker: fetch every source block from the
    /// peer worker named in the plan (real worker-to-worker sockets),
    /// GF-combine with the plan's decode coefficients, store the result,
    /// and return its [`proto::checksum`].
    fn recover_plan(
        &self,
        sid: u64,
        block: u32,
        block_len: usize,
        sources: &[PlanSource],
    ) -> Reply {
        if *self.state.lock().unwrap() != STATE_UP {
            return Reply::Err(format!("node {} cannot host a rebuild", self.loc));
        }
        let mut pairs: Vec<(u8, Vec<u8>)> = Vec::with_capacity(sources.len());
        for s in sources {
            match fetch_peer_block(&s.addr, sid, s.block) {
                Ok(bytes) if bytes.len() == block_len => pairs.push((s.coeff, bytes)),
                Ok(bytes) => {
                    return Reply::Err(format!(
                        "source block {} from {} is {} bytes, want {block_len}",
                        s.block,
                        s.addr,
                        bytes.len()
                    ));
                }
                Err(e) => {
                    return Reply::Err(format!(
                        "fetch source block {} from {}: {e}",
                        s.block, s.addr
                    ));
                }
            }
        }
        let mut acc = vec![0u8; block_len];
        gf::combine_many_into(&mut acc, &pairs);
        let sum = proto::checksum(&acc);
        self.store.lock().unwrap().insert((sid, block), acc);
        Reply::Sum(sum)
    }
}

/// One-shot fetch of a whole block from a peer worker.
fn fetch_peer_block(addr: &str, sid: u64, block: u32) -> Result<Vec<u8>> {
    let mut conn = TcpStream::connect(addr)?;
    conn.set_nodelay(true)?;
    proto::write_frame(&mut conn, &Msg::FetchBlock { sid, block }.encode())?;
    match Reply::decode(&proto::read_frame(&mut conn)?)? {
        Reply::Data(b) => Ok(b),
        Reply::Err(e) => bail!("{e}"),
        other => bail!("unexpected reply {other:?}"),
    }
}
