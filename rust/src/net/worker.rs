//! One storage node as a real network server (DESIGN.md §13): a TCP
//! listener on an ephemeral loopback port, a block store behind it, and
//! the membership state machine (Up → Draining/Failed → Up via Join).
//!
//! Workers are OS threads inside the test process — which keeps the
//! `D3_FORCE_KERNEL` GF-lane selection uniform across "machines" — but
//! nothing in the protocol knows that: every byte a worker serves or
//! rebuilds crosses a real socket, and worker-to-worker source fetches
//! during `RecoverPlan` open their own peer connections.
//!
//! Per-connection handler threads are *tracked*: the listener records
//! every spawned handler and [`WorkerHandle::stop`] joins them all under
//! a drain deadline, so tests that churn workers never leak threads or
//! race the next test's port. Handlers read with a short poll timeout so
//! they notice shutdown (and chaos crashes) between frames.
//!
//! The chaos layer (DESIGN.md §14) drives two hooks here: `crash()`
//! makes the worker fall silent — existing handlers close their sockets
//! without replying and new connections are accepted then dropped,
//! exactly what a dead process looks like to the coordinator — and
//! `corrupt_block()` flips a bit in a stored replica to model latent
//! disk corruption for the scrub pass.

use std::io::Read;
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{bail, Result};

use crate::cluster::{BlockStore, MaterializedStore};
use crate::gf;
use crate::topology::Location;

use super::proto::{self, Msg, PlanSource, Reply, STATE_DRAINING, STATE_FAILED, STATE_UP};

/// How often an idle handler wakes to poll shutdown/crash flags.
const POLL_INTERVAL: Duration = Duration::from_millis(50);
/// How long `stop()` waits for handler threads before abandoning them.
const DRAIN_DEADLINE: Duration = Duration::from_secs(5);

/// Coordinator-side handle to one spawned worker.
pub struct WorkerHandle {
    pub addr: SocketAddr,
    shutdown: Arc<AtomicBool>,
    listener: Option<JoinHandle<()>>,
    handlers: Arc<Mutex<Vec<JoinHandle<()>>>>,
    node: Arc<NodeWorker>,
}

impl WorkerHandle {
    /// Stop the accept loop, join the listener thread, then drain every
    /// tracked per-connection handler under [`DRAIN_DEADLINE`].
    /// Idempotent; also runs on drop so a panicking test never leaks the
    /// thread or races the next test's port.
    pub fn stop(&mut self) {
        self.shutdown.store(true, Ordering::Relaxed);
        // wake the blocking accept with a throwaway connection
        let _ = TcpStream::connect(self.addr);
        if let Some(h) = self.listener.take() {
            let _ = h.join();
        }
        let deadline = Instant::now() + DRAIN_DEADLINE;
        loop {
            let mut guard = self.handlers.lock().unwrap();
            let mut pending = Vec::new();
            for h in guard.drain(..) {
                if h.is_finished() {
                    let _ = h.join();
                } else {
                    pending.push(h);
                }
            }
            if pending.is_empty() {
                return;
            }
            if Instant::now() >= deadline {
                // abandon stragglers (a wedged socket); dropping the
                // handles detaches them without blocking teardown
                return;
            }
            *guard = pending;
            drop(guard);
            std::thread::sleep(Duration::from_millis(5));
        }
    }

    /// Chaos crash: the worker falls silent. In-flight handlers close
    /// their connections without replying; new connections are accepted
    /// and immediately dropped. The process-level state (store, listener)
    /// survives so a later `revive()` + `Join` models a machine reboot.
    pub fn crash(&self) {
        self.node.crashed.store(true, Ordering::Relaxed);
    }

    /// Undo a chaos crash so the membership `Join` RPC can reach the
    /// worker again (the replacement machine booting at the same address).
    pub fn revive(&self) {
        self.node.crashed.store(false, Ordering::Relaxed);
    }

    /// Latent-corruption hook: flip one bit of the stored replica of
    /// `(sid, block)`. Returns false when the worker holds no such block.
    /// This models silent disk corruption, not a network event, so it is
    /// an in-process hook rather than an RPC.
    pub fn corrupt_block(&self, sid: u64, block: u32) -> bool {
        self.node.store.corrupt(0, (sid, block as usize)).is_ok()
    }
}

impl Drop for WorkerHandle {
    fn drop(&mut self) {
        self.stop();
    }
}

/// Server-side state of one node.
struct NodeWorker {
    loc: Location,
    /// One of [`STATE_UP`], [`STATE_DRAINING`], [`STATE_FAILED`].
    state: Mutex<u8>,
    /// Single-node [`MaterializedStore`] (flat index 0) — the same store
    /// type the in-process fabric uses, so payload semantics match.
    store: MaterializedStore,
    /// Chaos crash flag: when set the worker never writes another byte.
    crashed: AtomicBool,
}

/// Bind a listener on `127.0.0.1:0` and serve until the handle stops it.
/// Each accepted connection gets its own handler thread, tracked in the
/// handle so shutdown can join it.
pub fn spawn_worker(loc: Location) -> Result<WorkerHandle> {
    let listener = TcpListener::bind(("127.0.0.1", 0))?;
    let addr = listener.local_addr()?;
    let shutdown = Arc::new(AtomicBool::new(false));
    let handlers: Arc<Mutex<Vec<JoinHandle<()>>>> = Arc::new(Mutex::new(Vec::new()));
    let node = Arc::new(NodeWorker {
        loc,
        state: Mutex::new(STATE_UP),
        store: MaterializedStore::new(1),
        crashed: AtomicBool::new(false),
    });
    let stop = shutdown.clone();
    let track = handlers.clone();
    let served = node.clone();
    let handle = std::thread::spawn(move || {
        for conn in listener.incoming() {
            if stop.load(Ordering::Relaxed) {
                break;
            }
            let Ok(conn) = conn else { break };
            let node = served.clone();
            let stop = stop.clone();
            let h = std::thread::spawn(move || serve_conn(&node, &stop, conn));
            let mut guard = track.lock().unwrap();
            // reap finished handlers as we go so the list stays bounded
            let mut live = Vec::with_capacity(guard.len() + 1);
            for old in guard.drain(..) {
                if old.is_finished() {
                    let _ = old.join();
                } else {
                    live.push(old);
                }
            }
            live.push(h);
            *guard = live;
        }
    });
    Ok(WorkerHandle { addr, shutdown, listener: Some(handle), handlers, node })
}

/// Read one frame with [`POLL_INTERVAL`] wakeups: returns `Ok(None)` on a
/// clean close (EOF between frames) or when `should_stop` fires, `Err` on
/// EOF mid-frame, oversized lengths, or integrity failures.
fn read_frame_polled(
    conn: &mut TcpStream,
    should_stop: &impl Fn() -> bool,
) -> std::io::Result<Option<Vec<u8>>> {
    use std::io::ErrorKind;
    let read_exact_polled =
        |conn: &mut TcpStream, buf: &mut [u8], clean_eof_at_zero: bool| -> std::io::Result<bool> {
            let mut got = 0usize;
            while got < buf.len() {
                match conn.read(&mut buf[got..]) {
                    Ok(0) => {
                        if got == 0 && clean_eof_at_zero {
                            return Ok(false);
                        }
                        return Err(ErrorKind::UnexpectedEof.into());
                    }
                    Ok(n) => got += n,
                    Err(e)
                        if e.kind() == ErrorKind::WouldBlock
                            || e.kind() == ErrorKind::TimedOut =>
                    {
                        if should_stop() {
                            return Ok(false);
                        }
                    }
                    Err(e) if e.kind() == ErrorKind::Interrupted => {}
                    Err(e) => return Err(e),
                }
            }
            Ok(true)
        };
    let mut len = [0u8; 4];
    if !read_exact_polled(conn, &mut len, true)? {
        return Ok(None);
    }
    let len = u32::from_le_bytes(len) as usize;
    if len > proto::MAX_FRAME {
        return Err(std::io::Error::new(
            ErrorKind::InvalidData,
            format!("frame of {len} bytes exceeds the {}-byte cap", proto::MAX_FRAME),
        ));
    }
    let mut body = vec![0u8; len];
    if !read_exact_polled(conn, &mut body, false)? {
        return Ok(None);
    }
    let mut sum = [0u8; 8];
    if !read_exact_polled(conn, &mut sum, false)? {
        return Ok(None);
    }
    if u64::from_le_bytes(sum) != proto::checksum(&body) {
        return Err(std::io::Error::new(
            ErrorKind::InvalidData,
            "frame integrity checksum mismatch",
        ));
    }
    Ok(Some(body))
}

fn serve_conn(node: &NodeWorker, stop: &AtomicBool, mut conn: TcpStream) {
    let _ = conn.set_nodelay(true);
    let _ = conn.set_read_timeout(Some(POLL_INTERVAL));
    let should_stop =
        || stop.load(Ordering::Relaxed) || node.crashed.load(Ordering::Relaxed);
    loop {
        // a decode/integrity failure poisons the stream framing, so the
        // handler drops the connection; the coordinator re-dials
        let body = match read_frame_polled(&mut conn, &should_stop) {
            Ok(Some(b)) => b,
            Ok(None) | Err(_) => return,
        };
        if node.crashed.load(Ordering::Relaxed) {
            return; // crashed: never write another byte
        }
        let reply = match Msg::decode(&body) {
            Ok(msg) => node.serve(msg),
            Err(e) => Reply::Err(format!("bad request: {e}")),
        };
        if node.crashed.load(Ordering::Relaxed) {
            return;
        }
        if proto::write_frame(&mut conn, &reply.encode()).is_err() {
            return;
        }
    }
}

impl NodeWorker {
    fn serve(&self, msg: Msg) -> Reply {
        match msg {
            Msg::Heartbeat => Reply::Beat {
                state: *self.state.lock().unwrap(),
                blocks: self.store.len(0) as u64,
            },
            Msg::Join => {
                // a replacement machine at the same address: empty store
                self.store.clear_node(0);
                *self.state.lock().unwrap() = STATE_UP;
                Reply::Ok
            }
            Msg::Drain => {
                *self.state.lock().unwrap() = STATE_DRAINING;
                Reply::Ok
            }
            Msg::Fail => {
                self.store.clear_node(0);
                *self.state.lock().unwrap() = STATE_FAILED;
                Reply::Ok
            }
            Msg::WriteBlock { sid, block, bytes } => match *self.state.lock().unwrap() {
                STATE_UP => {
                    self.store.insert(0, (sid, block as usize), bytes);
                    Reply::Ok
                }
                STATE_DRAINING => {
                    Reply::Err(format!("draining node {} rejects writes", self.loc))
                }
                _ => Reply::Err(format!("failed node {} rejects writes", self.loc)),
            },
            Msg::FetchBlock { sid, block } => {
                if *self.state.lock().unwrap() == STATE_FAILED {
                    return Reply::Err(format!("failed node {} rejects reads", self.loc));
                }
                match self.store.read(0, (sid, block as usize)) {
                    Some(b) => Reply::Data(b),
                    None => {
                        Reply::Err(format!("block ({sid},{block}) missing at {}", self.loc))
                    }
                }
            }
            Msg::FetchChunk { sid, block, off, len } => {
                if *self.state.lock().unwrap() == STATE_FAILED {
                    return Reply::Err(format!("failed node {} rejects reads", self.loc));
                }
                let (off, len) = (off as usize, len as usize);
                let mut buf = Vec::new();
                match self.store.read_chunk(0, (sid, block as usize), off, len, &mut buf) {
                    Ok(()) => Reply::Data(buf),
                    Err(crate::cluster::ChunkError::Missing) => Reply::Err(format!(
                        "block ({sid},{block}) missing at {}",
                        self.loc
                    )),
                    Err(crate::cluster::ChunkError::OutOfRange { have }) => Reply::Err(format!(
                        "chunk [{off}, {}) out of range for block ({sid},{block}) of {have} bytes",
                        off + len
                    )),
                }
            }
            Msg::RemoveBlock { sid, block } => {
                self.store.remove(0, (sid, block as usize));
                Reply::Ok
            }
            Msg::ListBlocks => {
                let blocks: Vec<(u64, u32)> = self
                    .store
                    .keys_sorted(0)
                    .into_iter()
                    .map(|(sid, b)| (sid, b as u32))
                    .collect();
                Reply::Blocks(blocks)
            }
            Msg::Encode { k, rows, shard_len, shards } => {
                // pure compute — served in every state (a client may pick
                // any node as its encoder, exactly as the in-process
                // cluster models the client-side encode)
                self.encode(k as usize, &rows, shard_len as usize, &shards)
            }
            Msg::RecoverPlan { sid, block, block_len, sources } => {
                self.recover_plan(sid, block, block_len as usize, &sources)
            }
            Msg::HashBlock { sid, block } => {
                if *self.state.lock().unwrap() == STATE_FAILED {
                    return Reply::Err(format!("failed node {} rejects reads", self.loc));
                }
                match self.store.stored_checksum(0, (sid, block as usize)) {
                    Some(sum) => Reply::Sum(sum),
                    None => {
                        Reply::Err(format!("block ({sid},{block}) missing at {}", self.loc))
                    }
                }
            }
        }
    }

    /// GF parity encode: one fused multiply-accumulate per parity row,
    /// the same [`gf::combine_many_into`] kernel the coder service runs —
    /// so worker-side parity is byte-identical to MiniCluster parity.
    fn encode(&self, k: usize, rows: &[u8], shard_len: usize, shards: &[u8]) -> Reply {
        if k == 0 || shard_len == 0 {
            return Reply::Err("encode: empty shards".into());
        }
        if shards.len() != k * shard_len || rows.len() % k != 0 || rows.is_empty() {
            return Reply::Err(format!(
                "encode: shape mismatch (k={k}, {} coeffs, {} shard bytes)",
                rows.len(),
                shards.len()
            ));
        }
        let m = rows.len() / k;
        let mut parity = vec![0u8; m * shard_len];
        for (j, out) in parity.chunks_mut(shard_len).enumerate() {
            let pairs: Vec<(u8, &[u8])> = (0..k)
                .map(|i| (rows[j * k + i], &shards[i * shard_len..(i + 1) * shard_len]))
                .collect();
            gf::combine_many_into(out, &pairs);
        }
        Reply::Data(parity)
    }

    /// Rebuild one block ON the worker: fetch every source block from the
    /// peer worker named in the plan (real worker-to-worker sockets),
    /// GF-combine with the plan's decode coefficients, store the result,
    /// and return its [`proto::checksum`].
    fn recover_plan(
        &self,
        sid: u64,
        block: u32,
        block_len: usize,
        sources: &[PlanSource],
    ) -> Reply {
        if *self.state.lock().unwrap() != STATE_UP {
            return Reply::Err(format!("node {} cannot host a rebuild", self.loc));
        }
        let mut pairs: Vec<(u8, Vec<u8>)> = Vec::with_capacity(sources.len());
        for s in sources {
            match fetch_peer_block(&s.addr, sid, s.block) {
                Ok(bytes) if bytes.len() == block_len => pairs.push((s.coeff, bytes)),
                Ok(bytes) => {
                    return Reply::Err(format!(
                        "source block {} from {} is {} bytes, want {block_len}",
                        s.block,
                        s.addr,
                        bytes.len()
                    ));
                }
                Err(e) => {
                    return Reply::Err(format!(
                        "fetch source block {} from {}: {e}",
                        s.block, s.addr
                    ));
                }
            }
        }
        let mut acc = vec![0u8; block_len];
        gf::combine_many_into(&mut acc, &pairs);
        let sum = proto::checksum(&acc);
        self.store.insert(0, (sid, block as usize), acc);
        Reply::Sum(sum)
    }
}

/// One-shot fetch of a whole block from a peer worker.
fn fetch_peer_block(addr: &str, sid: u64, block: u32) -> Result<Vec<u8>> {
    let mut conn = TcpStream::connect(addr)?;
    conn.set_nodelay(true)?;
    proto::write_frame(&mut conn, &Msg::FetchBlock { sid, block }.encode())?;
    match Reply::decode(&proto::read_frame(&mut conn)?)? {
        Reply::Data(b) => Ok(b),
        Reply::Err(e) => bail!("{e}"),
        other => bail!("unexpected reply {other:?}"),
    }
}
