//! NetCluster: the socket-backed recovery backend (DESIGN.md §13).
//!
//! N node workers — one real TCP listener each on loopback — hold the
//! blocks; a coordinator ([`NetCluster`]) owns the NameNode metadata,
//! cluster membership (join / drain / fail transitions, rebalancing
//! blocks onto joined nodes) and all byte accounting. The same
//! [`crate::scenario::FailureScenario`] + client-engine suite that
//! drives the fluid simulator and the in-process `MiniCluster` runs here
//! unchanged, through the shared [`fabric`] orchestration.
//!
//! **Byte-accounting contract** (what makes three-way parity exact): the
//! coordinator charges the identical modeled [`LinkSet`] transfers and
//! per-rack counters as `MiniCluster` for every logical movement, while
//! the payload bytes additionally traverse real sockets. The modeled
//! counters are the authoritative numbers in [`ScenarioOutcome`]; the
//! sockets prove the data path is real (checksums of rebuilt blocks come
//! from worker-side GF combines over bytes fetched worker-to-worker).

pub mod chaos;
pub mod proto;
mod worker;

use std::collections::HashMap;
use std::io::Write;
use std::net::{SocketAddr, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex, RwLock};
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Context, Result};

use crate::client::QosConfig;
use crate::cluster::fabric::{self, BlockFabric};
use crate::cluster::links::{LinkSet, TrafficClass};
use crate::cluster::{deterministic_data, parity_matrix, ChecksumRegistry, ClusterRecoveryStats};
use crate::codes::CodeSpec;
use crate::gf;
use crate::placement::Placement;
use crate::recovery::executor::ExecutorConfig;
use crate::recovery::migration::MigrationBatch;
use crate::recovery::plan::{plan_coefficients, plan_degraded_read, RepairPlan};
use crate::recovery::schedule::SchedulePolicy;
use crate::scenario::ScenarioOutcome;
use crate::topology::{Location, SystemSpec};

use proto::{Msg, PlanSource, Reply};
use worker::WorkerHandle;

type BlockKey = (u64, usize);

/// Coordinator-side view of a worker's membership state.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum NodeState {
    Up,
    Draining,
    Failed,
}

impl NodeState {
    fn from_wire(b: u8) -> NodeState {
        match b {
            proto::STATE_DRAINING => NodeState::Draining,
            proto::STATE_FAILED => NodeState::Failed,
            _ => NodeState::Up,
        }
    }
}

/// The socket-backed cluster: real listeners, real frames, modeled time.
pub struct NetCluster {
    spec: SystemSpec,
    policy: Arc<dyn Placement>,
    links: Arc<LinkSet>,
    /// Flattened m×k parity coefficient rows for the `Encode` RPC — the
    /// same generator rows the MiniCluster's coder service multiplies.
    enc_rows: Vec<u8>,
    enc_m: usize,
    addrs: Vec<SocketAddr>,
    /// Per-node pool of idle coordinator→worker connections. A call pops
    /// one (or dials), runs request/reply, and returns it on success —
    /// concurrent executor workers each get their own stream.
    conns: Vec<Mutex<Vec<TcpStream>>>,
    /// metadata overrides after recovery/drain (NameNode block map)
    relocated: Mutex<HashMap<BlockKey, Location>>,
    failed: Mutex<Vec<Location>>,
    membership: Mutex<Vec<NodeState>>,
    /// cross-rack traffic accounting (up, down) per rack
    rack_up: Vec<AtomicU64>,
    rack_down: Vec<AtomicU64>,
    /// Same pairwise-consistency discipline as the MiniCluster: transfers
    /// hold this as readers, snapshots as writer.
    accounting: RwLock<()>,
    qos: Mutex<Option<(QosConfig, Arc<AtomicBool>)>>,
    qos_on: AtomicBool,
    /// Expected block checksums, recorded at write/persist time — the
    /// NameNode-style integrity registry the scrub pass compares against.
    checksums: ChecksumRegistry,
    /// Armed fault-injection runtime (DESIGN.md §14); `chaos_on` mirrors
    /// it so the fault-free RPC fast path stays branch-cheap.
    chaos: Mutex<Option<Arc<chaos::ChaosRuntime>>>,
    chaos_on: AtomicBool,
    seed: u64,
    /// Held last so every pooled connection (above) closes before the
    /// listener threads are joined on drop.
    workers: Vec<WorkerHandle>,
}

/// Assemble one wire frame (`len ‖ body ‖ fnv(body)`). The chaos send
/// path needs raw frame bytes so a corruption can be injected *after*
/// the integrity trailer is computed — genuine on-the-wire damage.
fn frame_bytes(body: &[u8]) -> Vec<u8> {
    let mut f = Vec::with_capacity(body.len() + 12);
    f.extend_from_slice(&(body.len() as u32).to_le_bytes());
    f.extend_from_slice(body);
    f.extend_from_slice(&proto::checksum(body).to_le_bytes());
    f
}

impl NetCluster {
    /// Spawn one worker per node of `spec.cluster` and connect the
    /// coordinator. Workers bind ephemeral loopback ports; the cluster is
    /// fully torn down (listeners joined) on drop.
    pub fn new(spec: SystemSpec, policy: Arc<dyn Placement>, seed: u64) -> Result<NetCluster> {
        assert_eq!(policy.cluster(), spec.cluster, "policy/topology mismatch");
        let n = spec.cluster.node_count();
        let mut workers = Vec::with_capacity(n);
        let mut addrs = Vec::with_capacity(n);
        for i in 0..n {
            let h = worker::spawn_worker(spec.cluster.unflat(i))
                .with_context(|| format!("spawn worker {i}"))?;
            addrs.push(h.addr);
            workers.push(h);
        }
        let pm = parity_matrix(&policy.code());
        let mut enc_rows = Vec::with_capacity(pm.rows() * pm.cols());
        for r in 0..pm.rows() {
            enc_rows.extend_from_slice(pm.row(r));
        }
        Ok(NetCluster {
            links: Arc::new(LinkSet::new(&spec)),
            enc_m: pm.rows(),
            enc_rows,
            conns: (0..n).map(|_| Mutex::new(Vec::new())).collect(),
            relocated: Mutex::new(HashMap::new()),
            failed: Mutex::new(Vec::new()),
            membership: Mutex::new(vec![NodeState::Up; n]),
            rack_up: (0..spec.cluster.racks).map(|_| AtomicU64::new(0)).collect(),
            rack_down: (0..spec.cluster.racks).map(|_| AtomicU64::new(0)).collect(),
            accounting: RwLock::new(()),
            qos: Mutex::new(None),
            qos_on: AtomicBool::new(false),
            checksums: ChecksumRegistry::new(),
            chaos: Mutex::new(None),
            chaos_on: AtomicBool::new(false),
            spec,
            policy,
            addrs,
            seed,
            workers,
        })
    }

    pub fn spec(&self) -> &SystemSpec {
        &self.spec
    }

    pub fn policy(&self) -> &dyn Placement {
        self.policy.as_ref()
    }

    /// The worker's real socket address (tests dial it directly).
    pub fn addr_of(&self, loc: Location) -> SocketAddr {
        self.addrs[self.spec.cluster.flat(loc)]
    }

    /// One RPC round trip on a pooled connection. With chaos armed
    /// (DESIGN.md §14) this is the coordinator's survival loop: per-
    /// attempt fault injection keyed off the message *content* (so two
    /// same-seed runs inject the identical fault multiset regardless of
    /// thread interleaving), bounded retries with exponential backoff +
    /// seeded jitter, a per-attempt read deadline, and eviction of any
    /// connection whose stream may be out of sync.
    fn call(&self, loc: Location, msg: &Msg) -> Result<Reply> {
        let flat = self.spec.cluster.flat(loc);
        let body = msg.encode();
        if !self.chaos_on.load(Ordering::Relaxed) {
            return self.call_once(flat, loc, &frame_bytes(&body), None);
        }
        let rt = match self.chaos.lock().unwrap().clone() {
            Some(rt) => rt,
            None => return self.call_once(flat, loc, &frame_bytes(&body), None),
        };
        let key = chaos::content_key(&body, flat);
        let timeout = Duration::from_millis(rt.spec.rpc_timeout_ms.max(1));
        let attempts = rt.spec.max_attempts.max(1);
        let mut last_err = anyhow!("rpc to {loc}: no attempt made");
        for attempt in 0..attempts {
            if attempt > 0 {
                chaos::FaultCounters::bump(&rt.counters.retries);
                std::thread::sleep(rt.spec.backoff(key, attempt));
            }
            let mut frame = frame_bytes(&body);
            match rt.spec.decide(key, attempt, body.len()) {
                chaos::FaultAction::None => {}
                chaos::FaultAction::Drop => {
                    chaos::FaultCounters::bump(&rt.counters.drops);
                    last_err = anyhow!("rpc to {loc}: request frame dropped (injected)");
                    continue;
                }
                chaos::FaultAction::Delay(d) => {
                    chaos::FaultCounters::bump(&rt.counters.delays);
                    std::thread::sleep(d);
                }
                chaos::FaultAction::Corrupt(bit) => {
                    // flip a bit *after* the integrity trailer was
                    // computed: the worker must detect the damage and
                    // drop the connection, never act on the frame
                    chaos::FaultCounters::bump(&rt.counters.corrupts);
                    let bit = bit % (body.len() * 8).max(1);
                    frame[4 + bit / 8] ^= 1 << (bit % 8);
                }
                chaos::FaultAction::Truncate(n) => {
                    // a shortened but well-framed request: the worker's
                    // hardened decode must reject it cleanly, never panic
                    chaos::FaultCounters::bump(&rt.counters.truncates);
                    frame = frame_bytes(&body[..n.min(body.len())]);
                }
            }
            match self.call_once(flat, loc, &frame, Some(timeout)) {
                Ok(Reply::Err(e)) if e.starts_with("bad request") => {
                    // the worker rejected a mutated request; retry clean
                    last_err = anyhow!("worker {loc}: {e}");
                    continue;
                }
                Ok(reply) => {
                    if let Some(victim) = rt.burn_fuse() {
                        chaos::FaultCounters::bump(&rt.counters.crashes);
                        self.crash_worker(victim);
                    }
                    return Ok(reply);
                }
                Err(e) => {
                    last_err = e;
                    continue;
                }
            }
        }
        Err(last_err.context(format!("rpc to {loc}: all {attempts} attempts failed")))
    }

    /// One attempt: pop a pooled connection (or dial), write the raw
    /// frame, read one reply. The connection returns to the pool only
    /// after a complete round trip; any failure evicts it — its stream
    /// may hold a half-read frame — and the next attempt re-dials.
    fn call_once(
        &self,
        flat: usize,
        loc: Location,
        frame: &[u8],
        timeout: Option<Duration>,
    ) -> Result<Reply> {
        let mut conn = match self.conns[flat].lock().unwrap().pop() {
            Some(c) => c,
            None => {
                let c = TcpStream::connect(self.addrs[flat])
                    .with_context(|| format!("connect worker {loc}"))?;
                c.set_nodelay(true)?;
                c
            }
        };
        conn.set_read_timeout(timeout)?;
        let result = (|| -> Result<Reply> {
            conn.write_all(frame).with_context(|| format!("send to {loc}"))?;
            conn.flush()?;
            let body =
                proto::read_frame(&mut conn).with_context(|| format!("reply from {loc}"))?;
            Reply::decode(&body)
        })();
        match result {
            Ok(reply) => {
                self.conns[flat].lock().unwrap().push(conn);
                Ok(reply)
            }
            Err(e) => {
                if let Some(rt) = self.chaos.lock().unwrap().as_ref() {
                    chaos::FaultCounters::bump(&rt.counters.evictions);
                }
                Err(e)
            }
        }
    }

    fn rpc_ok(&self, loc: Location, msg: &Msg) -> Result<()> {
        match self.call(loc, msg)? {
            Reply::Ok => Ok(()),
            Reply::Err(e) => bail!("worker {loc}: {e}"),
            other => bail!("worker {loc}: unexpected reply {other:?}"),
        }
    }

    fn rpc_data(&self, loc: Location, msg: &Msg) -> Result<Vec<u8>> {
        match self.call(loc, msg)? {
            Reply::Data(b) => Ok(b),
            Reply::Err(e) => bail!("worker {loc}: {e}"),
            other => bail!("worker {loc}: unexpected reply {other:?}"),
        }
    }

    /// Current location of a block (NameNode metadata).
    pub fn locate(&self, sid: u64, block: usize) -> Location {
        if let Some(loc) = self.relocated.lock().unwrap().get(&(sid, block)) {
            return *loc;
        }
        self.policy.stripe(sid).locs[block]
    }

    /// Identical modeled charge to [`crate::cluster::MiniCluster`]'s
    /// transfer — the parity-critical accounting path.
    fn transfer(&self, src: Location, dst: Location, bytes: u64, class: TrafficClass) {
        if src.rack != dst.rack {
            let _pairwise = self.accounting.read().unwrap();
            self.rack_up[src.rack as usize].fetch_add(bytes, Ordering::Relaxed);
            self.rack_down[dst.rack as usize].fetch_add(bytes, Ordering::Relaxed);
        }
        self.links.transfer_class(src, dst, bytes, class);
    }

    fn transfer_group(&self, to: Location, flows: &[(Location, u64)]) {
        {
            let _pairwise = self.accounting.read().unwrap();
            for &(src, bytes) in flows {
                if src.rack != to.rack && bytes > 0 {
                    self.rack_up[src.rack as usize].fetch_add(bytes, Ordering::Relaxed);
                    self.rack_down[to.rack as usize].fetch_add(bytes, Ordering::Relaxed);
                }
            }
        }
        self.links.transfer_batch(to, flows, TrafficClass::Recovery);
    }

    pub fn rack_byte_snapshot(&self) -> Vec<(u64, u64)> {
        let _barrier = self.accounting.write().unwrap();
        (0..self.spec.cluster.racks)
            .map(|r| {
                (
                    self.rack_up[r].load(Ordering::Relaxed),
                    self.rack_down[r].load(Ordering::Relaxed),
                )
            })
            .collect()
    }

    fn set_state(&self, loc: Location, state: NodeState) {
        self.membership.lock().unwrap()[self.spec.cluster.flat(loc)] = state;
    }

    /// Coordinator-side membership view (as of the last transition RPC).
    pub fn node_state(&self, loc: Location) -> NodeState {
        self.membership.lock().unwrap()[self.spec.cluster.flat(loc)]
    }

    /// Probe a worker over the wire: its own state + block count.
    pub fn heartbeat(&self, loc: Location) -> Result<(NodeState, u64)> {
        match self.call(loc, &Msg::Heartbeat)? {
            Reply::Beat { state, blocks } => Ok((NodeState::from_wire(state), blocks)),
            Reply::Err(e) => bail!("heartbeat {loc}: {e}"),
            other => bail!("heartbeat {loc}: unexpected reply {other:?}"),
        }
    }

    /// Blocks currently stored on `loc` (over the wire).
    pub fn block_count(&self, loc: Location) -> usize {
        self.heartbeat(loc).map(|(_, n)| n as usize).unwrap_or(0)
    }

    /// Crash `loc`: the worker drops its blocks and rejects I/O, the
    /// coordinator marks it failed. Recovery must rebuild from peers.
    pub fn fail(&self, loc: Location) -> Result<()> {
        self.rpc_ok(loc, &Msg::Fail)?;
        self.mark_failed(loc);
        Ok(())
    }

    /// Record a failure in coordinator metadata only — no data-plane RPC.
    /// Used when the worker is already unreachable (a chaos crash) and a
    /// `Fail` RPC could never be delivered.
    pub fn mark_failed(&self, loc: Location) {
        let mut failed = self.failed.lock().unwrap();
        if !failed.contains(&loc) {
            failed.push(loc);
        }
        drop(failed);
        self.set_state(loc, NodeState::Failed);
    }

    /// Heartbeat sweep over every node not already marked failed: a
    /// worker that cannot answer within the bounded retry budget (or
    /// answers with a Failed state the coordinator missed) is escalated
    /// to a coordinator-side `Fail` transition. Returns the newly
    /// detected failures. Heartbeats encode identically, so under
    /// injected frame loss the per-(seed, node, attempt) decision is
    /// fixed and detection stays deterministic.
    pub fn detect_failures(&self) -> Vec<Location> {
        let known = self.failed.lock().unwrap().clone();
        let mut found = Vec::new();
        for i in 0..self.spec.cluster.node_count() {
            let loc = self.spec.cluster.unflat(i);
            if known.contains(&loc) {
                continue;
            }
            match self.heartbeat(loc) {
                Ok((NodeState::Failed, _)) | Err(_) => {
                    self.mark_failed(loc);
                    if let Some(rt) = self.chaos.lock().unwrap().as_ref() {
                        chaos::FaultCounters::bump(&rt.counters.failovers);
                    }
                    found.push(loc);
                }
                Ok(_) => {}
            }
        }
        found
    }

    /// Arm the chaos layer (DESIGN.md §14). Call *after* populate so the
    /// injected faults hit recovery traffic, not the write path — that
    /// separation is what keeps fault-run byte accounting identical to a
    /// fault-free run. Returns the runtime handle for counter inspection.
    pub fn arm_chaos(&self, spec: chaos::FaultSpec) -> Arc<chaos::ChaosRuntime> {
        let rt = Arc::new(chaos::ChaosRuntime::new(spec));
        *self.chaos.lock().unwrap() = Some(rt.clone());
        self.chaos_on.store(true, Ordering::Relaxed);
        rt
    }

    /// The armed chaos runtime, if any.
    pub fn chaos_runtime(&self) -> Option<Arc<chaos::ChaosRuntime>> {
        self.chaos.lock().unwrap().clone()
    }

    /// Kill the worker *process* at `loc`: it stops replying entirely and
    /// closes every connection without a byte. No membership transition
    /// happens here — noticing the silence is the failure detector's job
    /// ([`NetCluster::detect_failures`]).
    pub fn crash_worker(&self, loc: Location) {
        let flat = self.spec.cluster.flat(loc);
        self.workers[flat].crash();
        // pooled connections to the dead process are useless now
        self.conns[flat].lock().unwrap().clear();
    }

    /// Scrub probe: the checksum of the stored replica of `(sid, block)`
    /// wherever it currently lives — a `HashBlock` RPC, i.e. a node-local
    /// disk read that moves no block bytes over the modeled links.
    pub fn stored_checksum(&self, sid: u64, block: usize) -> Result<u64> {
        let loc = self.locate(sid, block);
        match self.call(loc, &Msg::HashBlock { sid, block: block as u32 })? {
            Reply::Sum(s) => Ok(s),
            Reply::Err(e) => bail!("hash ({sid},{block}) on {loc}: {e}"),
            other => bail!("hash ({sid},{block}) on {loc}: unexpected reply {other:?}"),
        }
    }

    /// Gracefully drain `loc`: the worker stops accepting writes, then
    /// every block it holds is re-homed (same rack first, then anywhere
    /// Up that holds no block of the stripe) with recovery-class
    /// accounting. Returns the number of blocks moved.
    pub fn drain(&self, loc: Location) -> Result<usize> {
        self.rpc_ok(loc, &Msg::Drain)?;
        self.set_state(loc, NodeState::Draining);
        let mut held = match self.call(loc, &Msg::ListBlocks)? {
            Reply::Blocks(b) => b,
            Reply::Err(e) => bail!("list blocks on {loc}: {e}"),
            other => bail!("list blocks on {loc}: unexpected reply {other:?}"),
        };
        held.sort_unstable();
        let code_len = self.policy.code().len();
        let mut moved = 0;
        for (sid, b) in held {
            let block = b as usize;
            let dst = self.relocation_target(sid, code_len, loc)?;
            let bytes = self.rpc_data(loc, &Msg::FetchBlock { sid, block: b })?;
            self.transfer(loc, dst, bytes.len() as u64, TrafficClass::Recovery);
            self.rpc_ok(dst, &Msg::WriteBlock { sid, block: b, bytes })?;
            self.rpc_ok(loc, &Msg::RemoveBlock { sid, block: b })?;
            let canonical = self.policy.stripe(sid).locs[block];
            let mut rel = self.relocated.lock().unwrap();
            if canonical == dst {
                rel.remove(&(sid, block));
            } else {
                rel.insert((sid, block), dst);
            }
            moved += 1;
        }
        Ok(moved)
    }

    /// Pick a destination for a block leaving `avoid`: an Up node in the
    /// same rack that holds no block of stripe `sid`, else any such Up
    /// node, else any Up node.
    fn relocation_target(&self, sid: u64, code_len: usize, avoid: Location) -> Result<Location> {
        let holders: Vec<Location> = (0..code_len).map(|b| self.locate(sid, b)).collect();
        let membership = self.membership.lock().unwrap();
        let candidates: Vec<Location> = (0..self.spec.cluster.node_count())
            .map(|i| self.spec.cluster.unflat(i))
            .filter(|&cand| {
                cand != avoid && membership[self.spec.cluster.flat(cand)] == NodeState::Up
            })
            .collect();
        candidates
            .iter()
            .find(|c| c.rack == avoid.rack && !holders.contains(c))
            .or_else(|| candidates.iter().find(|c| !holders.contains(c)))
            .or_else(|| candidates.first())
            .copied()
            .ok_or_else(|| anyhow!("no Up node to relocate stripe {sid} off {avoid}"))
    }

    /// A replacement machine comes up empty at `loc`'s address (Join RPC,
    /// state → Up) without any data movement — the §5.3 "relived" node
    /// that [`NetCluster::run_migration`] batches restore onto, mirror of
    /// [`crate::cluster::MiniCluster::relive_node`].
    pub fn relive(&self, loc: Location) -> Result<()> {
        // a chaos-crashed worker process "reboots" before it can serve
        // the Join RPC at the same address
        self.workers[self.spec.cluster.flat(loc)].revive();
        self.rpc_ok(loc, &Msg::Join)?;
        self.set_state(loc, NodeState::Up);
        self.failed.lock().unwrap().retain(|&f| f != loc);
        Ok(())
    }

    /// A replacement machine joins at `loc`'s address (empty store, state
    /// Up) and the coordinator rebalances: every block whose *canonical*
    /// placement is `loc` but which recovery or drain parked elsewhere is
    /// moved back — the §5.3 layout-restoring transition. Returns the
    /// number of blocks rebalanced home.
    pub fn join(&self, loc: Location) -> Result<usize> {
        self.relive(loc)?;
        let mut moves: Vec<(BlockKey, Location)> = self
            .relocated
            .lock()
            .unwrap()
            .iter()
            .filter(|&(_, &cur)| cur != loc)
            .map(|(&key, &cur)| (key, cur))
            .collect();
        moves.retain(|&((sid, b), _)| self.policy.stripe(sid).locs[b] == loc);
        moves.sort_unstable_by_key(|&(key, _)| key);
        let mut rebalanced = 0;
        for ((sid, block), cur) in moves {
            let b = block as u32;
            let bytes = self.rpc_data(cur, &Msg::FetchBlock { sid, block: b })?;
            self.transfer(cur, loc, bytes.len() as u64, TrafficClass::Recovery);
            self.rpc_ok(loc, &Msg::WriteBlock { sid, block: b, bytes })?;
            self.rpc_ok(cur, &Msg::RemoveBlock { sid, block: b })?;
            self.relocated.lock().unwrap().remove(&(sid, block));
            rebalanced += 1;
        }
        Ok(rebalanced)
    }

    /// Push one repair plan down to its writer worker as a `RecoverPlan`
    /// RPC: the worker fetches every source block from its current-holder
    /// peer over worker-to-worker sockets, GF-combines with the plan's
    /// decode coefficients and stores the result. The coordinator charges
    /// one whole-block recovery-class transfer per source (holder →
    /// writer) and re-points the block map. Returns the rebuilt block's
    /// [`proto::checksum`].
    pub fn recover_block_on_worker(&self, plan: &RepairPlan) -> Result<u64> {
        let code = self.policy.code();
        let sources = plan.source_blocks();
        let coeffs = plan_coefficients(&code, plan);
        let mut srcs = Vec::with_capacity(sources.len());
        for (&b, &c) in sources.iter().zip(&coeffs) {
            let holder = self.locate(plan.stripe, b);
            self.transfer(holder, plan.writer, self.spec.block_size, TrafficClass::Recovery);
            srcs.push(PlanSource {
                coeff: c,
                block: b as u32,
                addr: self.addr_of(holder).to_string(),
            });
        }
        let msg = Msg::RecoverPlan {
            sid: plan.stripe,
            block: plan.failed_block as u32,
            block_len: self.spec.block_size as u32,
            sources: srcs,
        };
        let sum = match self.call(plan.writer, &msg)? {
            Reply::Sum(s) => s,
            Reply::Err(e) => bail!("recover plan on {}: {e}", plan.writer),
            other => bail!("recover plan on {}: unexpected reply {other:?}", plan.writer),
        };
        if plan.persist {
            let canonical = self.policy.stripe(plan.stripe).locs[plan.failed_block];
            let mut rel = self.relocated.lock().unwrap();
            if canonical == plan.writer {
                rel.remove(&(plan.stripe, plan.failed_block));
            } else {
                rel.insert((plan.stripe, plan.failed_block), plan.writer);
            }
            drop(rel);
            // first write wins: the registry keeps the populate-time oracle
            self.checksums.or_insert((plan.stripe, plan.failed_block), sum);
        }
        Ok(sum)
    }

    /// Encode k data shards into m parity shards on the worker at `at`
    /// (the modeled client-side encode happens wherever the client is).
    fn encode_at(&self, at: Location, data: &[Vec<u8>]) -> Result<Vec<Vec<u8>>> {
        let k = data.len();
        let shard_len = data[0].len();
        let mut shards = Vec::with_capacity(k * shard_len);
        for d in data {
            if d.len() != shard_len {
                bail!("ragged data shards: {} vs {shard_len}", d.len());
            }
            shards.extend_from_slice(d);
        }
        let msg = Msg::Encode {
            k: k as u32,
            rows: self.enc_rows.clone(),
            shard_len: shard_len as u32,
            shards,
        };
        let parity = self.rpc_data(at, &msg)?;
        if parity.len() != self.enc_m * shard_len {
            bail!("encode reply: {} bytes, want {}", parity.len(), self.enc_m * shard_len);
        }
        Ok(parity.chunks(shard_len).map(|c| c.to_vec()).collect())
    }

    /// Client write path — byte-accounting mirror of
    /// [`crate::cluster::MiniCluster::write_stripe_inner`]: encode at the
    /// client (an `Encode` RPC there), then one foreground-class transfer
    /// plus a `WriteBlock` RPC per surviving placement.
    fn write_stripe_inner(
        &self,
        sid: u64,
        data: Vec<Vec<u8>>,
        client: Option<Location>,
    ) -> Result<()> {
        let code = self.policy.code();
        if data.len() != code.k() {
            bail!("expected {} data shards, got {}", code.k(), data.len());
        }
        let sp = self.policy.stripe(sid);
        let client = client.unwrap_or(sp.locs[0]);
        let parity = self.encode_at(client, &data)?;
        let failed = self.failed.lock().unwrap().clone();
        for (bi, bytes) in data.into_iter().chain(parity).enumerate() {
            // record the expected checksum for every block — including
            // ones whose destination is down: their canonical content is
            // still what any later rebuild must reproduce
            self.checksums.insert((sid, bi), proto::checksum(&bytes));
            let dst = sp.locs[bi];
            if failed.contains(&dst) {
                continue;
            }
            self.transfer(client, dst, bytes.len() as u64, TrafficClass::Foreground);
            self.rpc_ok(dst, &Msg::WriteBlock { sid, block: bi as u32, bytes })?;
        }
        Ok(())
    }

    pub fn write_stripe(&self, sid: u64, data: Vec<Vec<u8>>) -> Result<()> {
        self.write_stripe_inner(sid, data, None)
    }

    /// Write many stripes concurrently (`workers` client threads) using a
    /// data generator — same populate path as the MiniCluster.
    pub fn write_stripes_parallel(
        &self,
        stripes: u64,
        workers: usize,
        gen: impl Fn(u64) -> Vec<Vec<u8>> + Sync,
    ) -> Result<()> {
        let next = AtomicU64::new(0);
        let errors: Mutex<Vec<String>> = Mutex::new(Vec::new());
        std::thread::scope(|scope| {
            for _ in 0..workers.max(1) {
                scope.spawn(|| loop {
                    let sid = next.fetch_add(1, Ordering::Relaxed);
                    if sid >= stripes {
                        break;
                    }
                    if let Err(e) = self.write_stripe(sid, gen(sid)) {
                        errors.lock().unwrap().push(e.to_string());
                        break;
                    }
                });
            }
        });
        let errs = errors.into_inner().unwrap();
        if !errs.is_empty() {
            bail!("write errors: {}", errs.join("; "));
        }
        Ok(())
    }

    /// Whole-block fetch with foreground-class accounting — the degraded
    /// read path's mirror of [`crate::cluster::MiniCluster`]'s `fetch`.
    fn fetch(&self, sid: u64, block: usize, to: Location) -> Result<Vec<u8>> {
        let loc = self.locate(sid, block);
        let data = self.rpc_data(loc, &Msg::FetchBlock { sid, block: block as u32 })?;
        self.transfer(loc, to, data.len() as u64, TrafficClass::Foreground);
        Ok(data)
    }

    /// Coordinator-side plan execution for degraded reads: the identical
    /// modeled transfer sequence as the MiniCluster's `execute_plan`
    /// (per-source block to the aggregator, ONE aggregated block to the
    /// compute node, directs straight there), with the GF combines run by
    /// the coordinator over the fetched bytes.
    fn execute_plan(&self, plan: &RepairPlan) -> Result<Vec<u8>> {
        let code = self.policy.code();
        let sources = plan.source_blocks();
        let coeffs = plan_coefficients(&code, plan);
        let coeff_of =
            |b: usize| -> u8 { coeffs[sources.binary_search(&b).expect("source present")] };
        let mut final_pairs: Vec<(u8, Vec<u8>)> = Vec::new();
        for agg in &plan.aggregations {
            let mut pairs: Vec<(u8, Vec<u8>)> = Vec::with_capacity(agg.inputs.len());
            for &(b, _) in &agg.inputs {
                pairs.push((coeff_of(b), self.fetch(plan.stripe, b, agg.at)?));
            }
            let len = pairs.first().map_or(0, |(_, v)| v.len());
            let mut partial = vec![0u8; len];
            gf::combine_many_into(&mut partial, &pairs);
            // ship ONE aggregated block to the compute node
            self.transfer(agg.at, plan.compute_at, len as u64, TrafficClass::Foreground);
            final_pairs.push((1, partial));
        }
        for &(b, _) in &plan.direct {
            final_pairs.push((coeff_of(b), self.fetch(plan.stripe, b, plan.compute_at)?));
        }
        let len = final_pairs.first().map_or(0, |(_, v)| v.len());
        let mut rebuilt = vec![0u8; len];
        gf::combine_many_into(&mut rebuilt, &final_pairs);
        if plan.persist {
            let bytes = rebuilt.clone();
            BlockFabric::persist_block(self, plan.stripe, plan.failed_block, plan.writer, bytes)?;
        }
        Ok(rebuilt)
    }

    /// Plan-set recovery through the shared pipelined executor
    /// ([`fabric::recover_with_plans_cfg`]) — chunk fetches and block
    /// persists are RPCs, scheduling/accounting identical to MiniCluster.
    pub fn recover_with_plans_cfg(
        &self,
        plans: Vec<RepairPlan>,
        cfg: ExecutorConfig,
        failed_racks: &[u32],
    ) -> Result<ClusterRecoveryStats> {
        fabric::recover_with_plans_cfg(self, plans, cfg, failed_racks)
    }

    /// Execute §5.3 migration batches over the wire
    /// ([`fabric::run_migration`]).
    pub fn run_migration(&self, batches: &[MigrationBatch], relived: Location) -> Result<Vec<f64>> {
        fabric::run_migration(self, batches, relived)
    }

    fn qos_pace_inner(&self, busy_s: f64) {
        if !self.qos_on.load(Ordering::Relaxed) {
            return;
        }
        let rt = self.qos.lock().unwrap().clone();
        let Some((cfg, fg_active)) = rt else { return };
        if !cfg.is_active() || cfg.fg_weight <= 0.0 || !fg_active.load(Ordering::Relaxed) {
            return;
        }
        let pause = busy_s * cfg.fg_weight * (1.0 / cfg.recovery_share - 1.0);
        if pause > 0.0 {
            std::thread::sleep(Duration::from_secs_f64(pause.min(0.05)));
        }
    }
}

impl BlockFabric for NetCluster {
    fn code(&self) -> CodeSpec {
        self.policy.code()
    }

    fn period(&self) -> Option<u64> {
        self.policy.period()
    }

    fn block_size(&self) -> u64 {
        self.spec.block_size
    }

    fn links(&self) -> &LinkSet {
        &self.links
    }

    fn locate(&self, sid: u64, block: usize) -> Location {
        NetCluster::locate(self, sid, block)
    }

    fn read_chunk(
        &self,
        sid: u64,
        block: usize,
        off: u64,
        len: usize,
        buf: &mut Vec<u8>,
    ) -> Result<Location> {
        let loc = self.locate(sid, block);
        let msg = Msg::FetchChunk { sid, block: block as u32, off, len: len as u32 };
        let data = self.rpc_data(loc, &msg)?;
        if data.len() != len {
            bail!("chunk reply: {} bytes, want {len}", data.len());
        }
        buf.clear();
        buf.extend_from_slice(&data);
        Ok(loc)
    }

    fn persist_block(&self, sid: u64, block: usize, at: Location, bytes: Vec<u8>) -> Result<()> {
        let sum = proto::checksum(&bytes);
        self.rpc_ok(at, &Msg::WriteBlock { sid, block: block as u32, bytes })?;
        let canonical = self.policy.stripe(sid).locs[block];
        let mut rel = self.relocated.lock().unwrap();
        if canonical == at {
            rel.remove(&(sid, block));
        } else {
            rel.insert((sid, block), at);
        }
        drop(rel);
        // first write wins: the registry keeps the populate-time oracle
        self.checksums.or_insert((sid, block), sum);
        Ok(())
    }

    fn remove_block(&self, sid: u64, block: usize, at: Location) -> Result<()> {
        self.rpc_ok(at, &Msg::RemoveBlock { sid, block: block as u32 })
    }

    fn transfer(&self, src: Location, dst: Location, bytes: u64, class: TrafficClass) {
        NetCluster::transfer(self, src, dst, bytes, class);
    }

    fn transfer_group(&self, to: Location, flows: &[(Location, u64)]) {
        NetCluster::transfer_group(self, to, flows);
    }

    fn rack_byte_snapshot(&self) -> Vec<(u64, u64)> {
        NetCluster::rack_byte_snapshot(self)
    }

    fn fail_node(&self, loc: Location) {
        // a crashed worker cannot serve its own Fail RPC — fall back to
        // the coordinator-side transition so planning can proceed
        if self.fail(loc).is_err() {
            self.mark_failed(loc);
        }
    }

    fn failed_nodes(&self) -> Vec<Location> {
        self.failed.lock().unwrap().clone()
    }

    fn mark_failed(&self, loc: Location) {
        NetCluster::mark_failed(self, loc);
    }

    fn detect_failures(&self) -> Vec<Location> {
        NetCluster::detect_failures(self)
    }

    fn stored_checksum(&self, sid: u64, block: usize) -> Result<u64> {
        NetCluster::stored_checksum(self, sid, block)
    }

    fn expected_checksum(&self, sid: u64, block: usize) -> Option<u64> {
        self.checksums.get((sid, block))
    }

    fn corrupt_stored(&self, sid: u64, block: usize) -> Result<()> {
        let loc = self.locate(sid, block);
        let flat = self.spec.cluster.flat(loc);
        if self.workers[flat].corrupt_block(sid, block as u32) {
            Ok(())
        } else {
            bail!("corrupt_stored: block ({sid},{block}) not held at {loc}")
        }
    }

    fn rejoin_node(&self, loc: Location) -> Result<usize> {
        self.join(loc)
    }

    fn fault_report(&self) -> Option<crate::metrics::FaultReport> {
        self.chaos.lock().unwrap().as_ref().map(|rt| rt.counters.report())
    }

    fn arm_crash_victim(&self, loc: Location) {
        if let Some(rt) = self.chaos.lock().unwrap().as_ref() {
            rt.set_victim(loc);
        }
    }

    fn set_qos(&self, cfg: QosConfig, fg_active: Arc<AtomicBool>) {
        self.links.set_qos(cfg.recovery_share, fg_active.clone());
        *self.qos.lock().unwrap() = Some((cfg, fg_active));
        self.qos_on.store(true, Ordering::Relaxed);
    }

    fn clear_qos(&self) {
        self.links.clear_qos();
        *self.qos.lock().unwrap() = None;
        self.qos_on.store(false, Ordering::Relaxed);
    }

    fn qos_pace(&self, busy_s: f64) {
        self.qos_pace_inner(busy_s);
    }
}

impl crate::client::ClientIo for NetCluster {
    fn data_shards(&self) -> usize {
        self.policy.code().k()
    }

    fn block_len(&self) -> usize {
        self.spec.block_size as usize
    }

    fn read_block(&self, sid: u64, block: usize, client: Location) -> Result<Vec<u8>> {
        let loc = self.locate(sid, block);
        if self.failed.lock().unwrap().contains(&loc) {
            bail!("block ({sid},{block}) is on failed node {loc} — use degraded_read");
        }
        self.fetch(sid, block, client)
    }

    fn degraded_read(
        &self,
        sid: u64,
        block: usize,
        client: Location,
    ) -> Result<(Vec<u8>, Duration)> {
        let t0 = Instant::now();
        let plan = plan_degraded_read(self.policy.as_ref(), sid, block, client, self.seed);
        let data = self.execute_plan(&plan)?;
        Ok((data, t0.elapsed()))
    }

    fn write_stripe_from(&self, sid: u64, data: Vec<Vec<u8>>, client: Location) -> Result<()> {
        self.write_stripe_inner(sid, data, Some(client))
    }
}

/// The NetCluster implementation of the scenario engine
/// ([`crate::scenario::RecoveryBackend`]): same knobs as the in-process
/// `ClusterBackend` (minus the coder-service selector — workers always
/// run the in-process GF kernels, honoring `D3_FORCE_KERNEL` uniformly),
/// same scaled block size and link rates, same shared scenario body.
pub struct NetClusterBackend {
    /// Scaled block size (bytes) for the loopback run.
    pub block_size: u64,
    pub inner_mbps: f64,
    pub cross_mbps: f64,
    /// Concurrent reconstruction workers (HDFS xmits analogue).
    pub workers: usize,
    /// Executor chunk size (bytes) — one `FetchChunk` RPC per source per
    /// chunk, so this is also the RPC payload granularity.
    pub chunk_size: u64,
    pub schedule: SchedulePolicy,
    pub coalesce: usize,
    pub batched_fetch: bool,
    /// Fault-injection spec, armed after populate so injected faults hit
    /// recovery traffic only (DESIGN.md §14). `None` = fault-free.
    pub faults: Option<chaos::FaultSpec>,
}

impl Default for NetClusterBackend {
    fn default() -> NetClusterBackend {
        NetClusterBackend {
            block_size: 64 << 10,
            inner_mbps: 8000.0,
            cross_mbps: 1600.0,
            workers: 8,
            chunk_size: 16 << 10,
            schedule: SchedulePolicy::Fifo,
            coalesce: 1,
            batched_fetch: false,
            faults: None,
        }
    }
}

impl NetClusterBackend {
    fn exec_cfg(&self) -> ExecutorConfig {
        ExecutorConfig {
            workers: self.workers,
            chunk_size: self.chunk_size,
            schedule: self.schedule,
            coalesce: self.coalesce,
            batched_fetch: self.batched_fetch,
            ..ExecutorConfig::default()
        }
    }
}

impl crate::scenario::RecoveryBackend for NetClusterBackend {
    fn name(&self) -> &'static str {
        "net"
    }

    fn run(
        &self,
        scenario: &crate::scenario::FailureScenario,
        policy: &Arc<dyn Placement>,
        spec: &SystemSpec,
    ) -> Result<ScenarioOutcome> {
        let mut cspec = *spec;
        cspec.block_size = self.block_size;
        cspec.net.inner_mbps = self.inner_mbps;
        cspec.net.cross_mbps = self.cross_mbps;
        let k = policy.code().k();
        let bs = self.block_size as usize;
        let populate = || -> Result<NetCluster> {
            let cluster = NetCluster::new(cspec, policy.clone(), scenario.seed)?;
            cluster.write_stripes_parallel(scenario.stripes, self.workers.max(2), |sid| {
                deterministic_data(sid, k, bs)
            })?;
            if let Some(faults) = self.faults {
                cluster.arm_chaos(faults);
            }
            Ok(cluster)
        };
        fabric::run_scenario(
            "net",
            scenario,
            policy,
            populate,
            self.exec_cfg(),
            self.workers,
            self.block_size,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::client::ClientIo;
    use crate::placement::D3Placement;

    fn small_spec() -> SystemSpec {
        let mut s = SystemSpec::paper_default();
        s.block_size = 16 * 1024;
        s.net.inner_mbps = 8000.0;
        s.net.cross_mbps = 1600.0;
        s
    }

    fn net_cluster(seed: u64) -> NetCluster {
        let spec = small_spec();
        let policy =
            Arc::new(D3Placement::new(CodeSpec::Rs { k: 3, m: 2 }, spec.cluster).unwrap());
        NetCluster::new(spec, policy, seed).unwrap()
    }

    #[test]
    fn write_read_roundtrip_over_sockets() {
        let cluster = net_cluster(7);
        let data = deterministic_data(0, 3, 16 * 1024);
        cluster.write_stripe(0, data.clone()).unwrap();
        for (b, want) in data.iter().enumerate() {
            let got = cluster.read_block(0, b, Location::new(7, 0)).unwrap();
            assert_eq!(&got, want);
        }
        // parity blocks exist on their placed workers too
        for b in 3..5 {
            let loc = cluster.locate(0, b);
            assert!(cluster.block_count(loc) > 0);
        }
    }

    #[test]
    fn degraded_read_rebuilds_over_sockets() {
        let cluster = net_cluster(7);
        let data = deterministic_data(5, 3, 16 * 1024);
        cluster.write_stripe(5, data.clone()).unwrap();
        let victim = cluster.locate(5, 1);
        cluster.fail(victim).unwrap();
        let (got, _) = cluster.degraded_read(5, 1, Location::new(6, 2)).unwrap();
        assert_eq!(got, data[1]);
    }

    #[test]
    fn drained_worker_rejects_writes_but_serves_reads() {
        let cluster = net_cluster(3);
        cluster.write_stripe(0, deterministic_data(0, 3, 16 * 1024)).unwrap();
        let loc = cluster.locate(0, 0);
        cluster.rpc_ok(loc, &Msg::Drain).unwrap();
        assert!(cluster
            .rpc_ok(loc, &Msg::WriteBlock { sid: 9, block: 0, bytes: vec![1] })
            .is_err());
        assert!(cluster.rpc_data(loc, &Msg::FetchBlock { sid: 0, block: 0 }).is_ok());
        cluster.rpc_ok(loc, &Msg::Join).unwrap();
    }

    #[test]
    fn failed_worker_rejects_reads_until_join() {
        let cluster = net_cluster(11);
        cluster.write_stripe(0, deterministic_data(0, 3, 16 * 1024)).unwrap();
        let loc = cluster.locate(0, 2);
        cluster.fail(loc).unwrap();
        assert_eq!(cluster.node_state(loc), NodeState::Failed);
        assert!(cluster.rpc_data(loc, &Msg::FetchBlock { sid: 0, block: 2 }).is_err());
        let (state, blocks) = cluster.heartbeat(loc).unwrap();
        assert_eq!(state, NodeState::Failed);
        assert_eq!(blocks, 0, "Fail must drop the store");
        cluster.join(loc).unwrap();
        assert_eq!(cluster.heartbeat(loc).unwrap().0, NodeState::Up);
    }
}
