//! Deterministic foreground-request generation (DESIGN.md §11).
//!
//! A [`FgSpec`] describes a foreground workload abstractly — how many
//! requests, how they arrive (open loop at a fixed rate, or closed loop
//! with N clients and think time), and the class mix (normal reads,
//! degraded reads, writes). [`FgSpec::generate`] expands it into a
//! concrete, seed-keyed [`Request`] sequence against a placement: every
//! derived choice (class, target block, issuing client, arrival time) is
//! a pure function of `(spec, policy, stripes, failed set, seed)`, so the
//! fluid simulator and the MiniCluster consume **bit-identical** request
//! sequences and their foreground measurements are cross-checkable.

use std::sync::Arc;

use anyhow::{bail, Result};

use crate::placement::{Placement, PlacementTable};
use crate::topology::Location;
use crate::util::Rng;
use crate::workloads::WorkloadSpec;

/// What one foreground request does.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RequestClass {
    /// Read a healthy data block.
    NormalRead { stripe: u64, block: usize },
    /// Read a block lost to the failure set (rebuilt on the fly).
    DegradedRead { stripe: u64, block: usize },
    /// Write (encode + distribute) a fresh stripe.
    Write { stripe: u64 },
}

/// One generated foreground request.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Request {
    /// Position in the generated sequence.
    pub id: usize,
    /// Closed-loop client slot serving this request (0 under open loop).
    pub slot: usize,
    pub class: RequestClass,
    /// Node issuing the request (never a failed node).
    pub client: Location,
    /// Scheduled arrival in seconds from the run's start. Open loop:
    /// `id / rate`. Closed loop: the think-time pacing of the request's
    /// slot — the fluid backend admits at these times; the cluster
    /// backend paces each slot by real completions instead.
    pub arrival_s: f64,
}

/// How requests arrive.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum ArrivalModel {
    /// Fixed-rate open loop: request `i` arrives at `i / rate_rps`
    /// regardless of completions (an infinite rate arrives everything at
    /// t = 0 — the burst case).
    Open { rate_rps: f64 },
    /// Closed loop: `clients` concurrent clients, each issuing its next
    /// request `think_s` after the previous one completes.
    Closed { clients: usize, think_s: f64 },
}

/// A foreground workload, abstract of any backend.
#[derive(Clone, Debug, PartialEq)]
pub struct FgSpec {
    /// Total requests in the sequence.
    pub requests: usize,
    pub arrival: ArrivalModel,
    /// Relative weight of [`RequestClass::NormalRead`] in the mix.
    pub read_weight: u32,
    /// Relative weight of [`RequestClass::DegradedRead`].
    pub degraded_weight: u32,
    /// Relative weight of [`RequestClass::Write`].
    pub write_weight: u32,
    /// Zipf skew exponent θ for read targets. 0.0 (the default everywhere)
    /// keeps the original uniform draws and their exact RNG stream;
    /// θ > 0 makes stripe 0 the hottest object (and, for degraded reads,
    /// skews which lost block is hammered) — the millions-of-users
    /// popularity model the hot-block cache is measured against.
    pub zipf: f64,
}

impl FgSpec {
    /// Pure normal-read traffic.
    pub fn reads(requests: usize, arrival: ArrivalModel) -> FgSpec {
        FgSpec {
            requests,
            arrival,
            read_weight: 1,
            degraded_weight: 0,
            write_weight: 0,
            zipf: 0.0,
        }
    }

    /// The degraded-read burst (paper Exp 3 as a concurrent burst): all
    /// requests target lost blocks and arrive at t = 0.
    pub fn burst(reads: usize) -> FgSpec {
        FgSpec {
            requests: reads,
            arrival: ArrivalModel::Open { rate_rps: f64::INFINITY },
            read_weight: 0,
            degraded_weight: 1,
            write_weight: 0,
            zipf: 0.0,
        }
    }

    /// Same spec with a Zipf skew exponent applied to read targets.
    pub fn with_zipf(mut self, theta: f64) -> FgSpec {
        self.zipf = theta.max(0.0);
        self
    }

    /// A MapReduce-shaped job (paper Table 2) as a block-request mix: the
    /// map phase reads one input block per map task, reducers write their
    /// output stripes, and four concurrent clients drive the job (the
    /// task-slot analogue). Both backends then serve the *same* request
    /// sequence instead of one simulating shuffles while the other
    /// samples ad-hoc reads.
    pub fn from_workload(w: &WorkloadSpec) -> FgSpec {
        let reads = w.maps.max(1);
        let writes = if w.output_bytes > 0 { w.reduces } else { 0 };
        FgSpec {
            requests: reads + writes,
            arrival: ArrivalModel::Closed { clients: 4, think_s: 0.0 },
            read_weight: reads as u32,
            degraded_weight: 0,
            write_weight: writes as u32,
            zipf: 0.0,
        }
    }

    /// [`FgSpec::from_workload`] by Table-2 benchmark name.
    pub fn from_workload_name(name: &str) -> Result<FgSpec> {
        let all = crate::workloads::specs();
        let w = all
            .iter()
            .find(|w| w.name == name)
            .ok_or_else(|| anyhow::anyhow!("unknown workload {name}"))?;
        Ok(FgSpec::from_workload(w))
    }

    /// Expand into the concrete request sequence. Deterministic: the same
    /// arguments always produce the same sequence, on every backend.
    pub fn generate(
        &self,
        policy: &Arc<dyn Placement>,
        stripes: u64,
        failed: &[Location],
        seed: u64,
    ) -> Result<Vec<Request>> {
        let stripes = stripes.max(1);
        let table = PlacementTable::build(policy.clone(), stripes);
        self.generate_with(&table, stripes, failed, seed)
    }

    /// [`FgSpec::generate`] against a placement table the caller already
    /// built — scenario runs build ONE table and share it between request
    /// generation and plan derivation instead of rebuilding per use.
    pub fn generate_with(
        &self,
        table: &PlacementTable,
        stripes: u64,
        failed: &[Location],
        seed: u64,
    ) -> Result<Vec<Request>> {
        let cluster = table.cluster();
        let stripes = stripes.max(1);
        let k = table.code().k();
        let total_weight = self.read_weight + self.degraded_weight + self.write_weight;
        if total_weight == 0 {
            bail!("foreground spec has an all-zero class mix");
        }
        // lost blocks (any block on a failed node) for the degraded class;
        // probed per block via the alloc-free `block_at` lookup
        let lost: Vec<(u64, usize)> = if self.degraded_weight > 0 {
            let len = table.code().len();
            let mut lost = Vec::new();
            for sid in 0..stripes {
                for bi in 0..len {
                    if failed.contains(&table.block_at(sid, bi)) {
                        lost.push((sid, bi));
                    }
                }
            }
            if lost.is_empty() {
                bail!("degraded foreground traffic: failure set holds no blocks");
            }
            lost
        } else {
            Vec::new()
        };
        let mut rng = Rng::keyed(seed, 0xf9_c11e, 7);
        let mut out = Vec::with_capacity(self.requests);
        let mut writes = 0u64;
        for id in 0..self.requests {
            let pick = rng.below_u64(u64::from(total_weight)) as u32;
            let class = if pick < self.read_weight {
                // healthy data block: rejection-sample away from the
                // failure set (bounded; the failure set never covers
                // every data block of every stripe in practice)
                let mut choice = None;
                for _ in 0..64 {
                    let sid = if self.zipf > 0.0 {
                        zipf_rank(&mut rng, stripes, self.zipf)
                    } else {
                        rng.below_u64(stripes)
                    };
                    let block = rng.below(k);
                    if !failed.contains(&table.block_at(sid, block)) {
                        choice = Some(RequestClass::NormalRead { stripe: sid, block });
                        break;
                    }
                }
                let Some(c) = choice else {
                    bail!("no healthy data block found in {stripes} stripes");
                };
                c
            } else if pick < self.read_weight + self.degraded_weight {
                let idx = if self.zipf > 0.0 {
                    zipf_rank(&mut rng, lost.len() as u64, self.zipf) as usize
                } else {
                    rng.below(lost.len())
                };
                let (stripe, block) = lost[idx];
                RequestClass::DegradedRead { stripe, block }
            } else {
                // fresh stripes land beyond the stored population
                let stripe = stripes + writes;
                writes += 1;
                RequestClass::Write { stripe }
            };
            let client = loop {
                let c = cluster.unflat(rng.below(cluster.node_count()));
                if !failed.contains(&c) {
                    break c;
                }
            };
            let (slot, arrival_s) = match self.arrival {
                ArrivalModel::Open { rate_rps } => {
                    let arrival = if rate_rps.is_finite() && rate_rps > 0.0 {
                        id as f64 / rate_rps
                    } else {
                        0.0
                    };
                    (0, arrival)
                }
                ArrivalModel::Closed { clients, think_s } => {
                    let clients = clients.max(1);
                    (id % clients, (id / clients) as f64 * think_s.max(0.0))
                }
            };
            out.push(Request { id, slot, class, client, arrival_s });
        }
        Ok(out)
    }
}

/// Inverse-CDF draw from the continuous bounded-Pareto approximation of a
/// Zipf(θ) law over ranks `0..n` (rank 0 hottest): for u ~ U[0,1),
/// x = (1 − u + u·n^(1−θ))^(1/(1−θ)) on [1, n], degenerating to x = n^u at
/// θ = 1; rank = ⌊x⌋ − 1. One uniform per draw, no per-n precomputation,
/// fully deterministic under the seeded [`Rng`].
fn zipf_rank(rng: &mut Rng, n: u64, theta: f64) -> u64 {
    debug_assert!(n > 0 && theta > 0.0);
    let u = rng.f64();
    let nf = n as f64;
    let x = if (theta - 1.0).abs() < 1e-9 {
        nf.powf(u)
    } else {
        let q = 1.0 - theta;
        (1.0 - u + u * nf.powf(q)).powf(1.0 / q)
    };
    (x.floor() as u64).saturating_sub(1).min(n - 1)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::codes::CodeSpec;
    use crate::placement::D3Placement;
    use crate::topology::ClusterSpec;

    fn policy() -> Arc<dyn Placement> {
        Arc::new(
            D3Placement::new(CodeSpec::Rs { k: 3, m: 2 }, ClusterSpec::new(8, 3)).unwrap(),
        )
    }

    #[test]
    fn generation_is_deterministic() {
        let p = policy();
        let spec = FgSpec {
            requests: 50,
            arrival: ArrivalModel::Open { rate_rps: 100.0 },
            read_weight: 3,
            degraded_weight: 1,
            write_weight: 1,
            zipf: 0.0,
        };
        // a node that certainly stores blocks
        let failed = vec![p.stripe(0).locs[0]];
        let a = spec.generate(&p, 40, &failed, 9).unwrap();
        let b = spec.generate(&p, 40, &failed, 9).unwrap();
        assert_eq!(a, b);
        let c = spec.generate(&p, 40, &failed, 10).unwrap();
        assert_ne!(a, c, "different seeds must differ");
    }

    #[test]
    fn requests_respect_the_failure_set() {
        let p = policy();
        let failed = vec![p.stripe(1).locs[2]];
        let spec = FgSpec {
            requests: 80,
            arrival: ArrivalModel::Closed { clients: 4, think_s: 0.5 },
            read_weight: 2,
            degraded_weight: 1,
            write_weight: 0,
            zipf: 0.0,
        };
        let reqs = spec.generate(&p, 60, &failed, 3).unwrap();
        assert_eq!(reqs.len(), 80);
        let mut saw_degraded = false;
        for r in &reqs {
            assert!(!failed.contains(&r.client), "client on failed node");
            match r.class {
                RequestClass::NormalRead { stripe, block } => {
                    assert!(block < 3);
                    assert!(!failed.contains(&p.stripe(stripe).locs[block]));
                }
                RequestClass::DegradedRead { stripe, block } => {
                    saw_degraded = true;
                    assert_eq!(p.stripe(stripe).locs[block], failed[0]);
                }
                RequestClass::Write { .. } => unreachable!("write weight is 0"),
            }
            assert!(r.slot < 4);
        }
        assert!(saw_degraded, "80 draws at weight 1/3 must hit degraded");
    }

    #[test]
    fn open_loop_arrivals_are_fixed_rate_and_burst_is_t0() {
        let p = policy();
        let spec = FgSpec::reads(10, ArrivalModel::Open { rate_rps: 4.0 });
        let reqs = spec.generate(&p, 20, &[], 1).unwrap();
        for (i, r) in reqs.iter().enumerate() {
            assert!((r.arrival_s - i as f64 / 4.0).abs() < 1e-12);
        }
        let burst = FgSpec::burst(6)
            .generate(&p, 20, &[p.stripe(0).locs[0]], 1)
            .unwrap();
        assert!(burst.iter().all(|r| r.arrival_s == 0.0));
        assert!(burst
            .iter()
            .all(|r| matches!(r.class, RequestClass::DegradedRead { .. })));
    }

    #[test]
    fn closed_loop_slots_round_robin_with_think_pacing() {
        let p = policy();
        let spec = FgSpec::reads(9, ArrivalModel::Closed { clients: 3, think_s: 2.0 });
        let reqs = spec.generate(&p, 20, &[], 5).unwrap();
        for r in &reqs {
            assert_eq!(r.slot, r.id % 3);
            assert!((r.arrival_s - (r.id / 3) as f64 * 2.0).abs() < 1e-12);
        }
    }

    #[test]
    fn workload_mix_reflects_table_2_shape() {
        let all = crate::workloads::specs();
        let grep = all.iter().find(|w| w.name == "grep").unwrap();
        let spec = FgSpec::from_workload(grep);
        assert_eq!(spec.requests, grep.maps + grep.reduces);
        assert_eq!(spec.read_weight, grep.maps as u32);
        assert_eq!(spec.write_weight, grep.reduces as u32);
        let pi = all.iter().find(|w| w.name == "pi").unwrap();
        let spec = FgSpec::from_workload(pi);
        assert!(spec.write_weight > 0, "pi writes its tiny output");
        assert!(FgSpec::from_workload_name("nope").is_err());
    }

    #[test]
    fn writes_target_fresh_stripes_in_order() {
        let p = policy();
        let spec = FgSpec {
            requests: 12,
            arrival: ArrivalModel::Open { rate_rps: f64::INFINITY },
            read_weight: 0,
            degraded_weight: 0,
            write_weight: 1,
            zipf: 0.0,
        };
        let reqs = spec.generate(&p, 30, &[], 2).unwrap();
        let sids: Vec<u64> = reqs
            .iter()
            .map(|r| match r.class {
                RequestClass::Write { stripe } => stripe,
                _ => unreachable!(),
            })
            .collect();
        assert_eq!(sids, (30..42).collect::<Vec<u64>>());
    }

    #[test]
    fn zipf_zero_is_exactly_the_uniform_stream() {
        let p = policy();
        let spec = FgSpec::reads(60, ArrivalModel::Open { rate_rps: 8.0 });
        let uniform = spec.generate(&p, 200, &[], 13).unwrap();
        let zipfed = spec.clone().with_zipf(0.0).generate(&p, 200, &[], 13).unwrap();
        assert_eq!(uniform, zipfed, "θ = 0 must not perturb the RNG stream");
    }

    #[test]
    fn zipf_skew_concentrates_reads_on_hot_stripes() {
        let p = policy();
        let spec = FgSpec::reads(2000, ArrivalModel::Open { rate_rps: f64::INFINITY });
        let stripes = 1000u64;
        let count_top10 = |reqs: &[Request]| {
            reqs.iter()
                .filter(|r| matches!(r.class, RequestClass::NormalRead { stripe, .. } if stripe < 10))
                .count()
        };
        let uniform = spec.generate(&p, stripes, &[], 21).unwrap();
        let skewed = spec.clone().with_zipf(0.99).generate(&p, stripes, &[], 21).unwrap();
        let (u10, s10) = (count_top10(&uniform), count_top10(&skewed));
        // Uniform puts ~1% of reads on the 10 hottest stripes; Zipf(0.99)
        // puts ~ln(11)/ln(1001) ≈ 35% there.
        assert!(u10 < 100, "uniform top-10 share unexpectedly high: {u10}");
        assert!(s10 > 400, "zipf top-10 share too low: {s10}");
        // Deterministic and in bounds.
        let again = spec.with_zipf(0.99).generate(&p, stripes, &[], 21).unwrap();
        assert_eq!(skewed, again);
        for r in &skewed {
            if let RequestClass::NormalRead { stripe, .. } = r.class {
                assert!(stripe < stripes);
            }
        }
    }

    #[test]
    fn zipf_rank_sampler_is_bounded_and_hot_at_rank_zero() {
        let mut rng = Rng::keyed(7, 1, 2);
        let mut counts = [0usize; 16];
        for _ in 0..4000 {
            let r = zipf_rank(&mut rng, 16, 1.2);
            assert!(r < 16);
            counts[r as usize] += 1;
        }
        assert!(counts[0] > counts[8], "rank 0 must dominate mid ranks");
        assert!(counts[0] > 4000 / 16, "rank 0 must beat the uniform share");
        // degenerate n = 1 never panics and always returns rank 0
        assert_eq!(zipf_rank(&mut rng, 1, 0.9), 0);
    }

    #[test]
    fn empty_mix_and_vacuous_degraded_are_errors() {
        let p = policy();
        let none = FgSpec {
            requests: 4,
            arrival: ArrivalModel::Open { rate_rps: 1.0 },
            read_weight: 0,
            degraded_weight: 0,
            write_weight: 0,
            zipf: 0.0,
        };
        assert!(none.generate(&p, 10, &[], 0).is_err());
        // a degraded mix against an empty failure set is vacuous
        assert!(FgSpec::burst(4).generate(&p, 10, &[], 0).is_err());
    }
}
