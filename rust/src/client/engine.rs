//! Executing a generated request sequence (DESIGN.md §11).
//!
//! [`run_on_cluster`] drives real reads/writes through the MiniCluster —
//! open loop (workers sleep until each request's scheduled arrival, so
//! latency includes queueing behind a saturated pool) or closed loop
//! (one thread per client slot, think-time paced by real completions).
//! [`request_job`] lowers one request into a fluid-simulator job whose
//! first activity is the arrival delay, so the simulator admits the
//! *same* sequence at the *same* scheduled times.

use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::Mutex;
use std::time::{Duration, Instant};

use anyhow::{bail, Result};

use crate::metrics::Summary;
use crate::placement::{Placement, PlacementTable};
use crate::recovery::plan::plan_degraded_read;
use crate::sim::engine::{JobSpec, Work};
use crate::sim::recovery::plan_to_job_with;
use crate::sim::resources::ResourceTable;
use crate::topology::{Location, SystemSpec};
use crate::util::rng::xorshift_bytes;

use super::gen::{ArrivalModel, Request, RequestClass};

/// A cluster the client engine can drive requests against — implemented
/// by the in-process [`crate::cluster::MiniCluster`] and the
/// socket-backed [`crate::net::NetCluster`], so the identical generated
/// request sequence exercises both data planes (DESIGN.md §11, §13).
pub trait ClientIo: Sync {
    /// Data shards per stripe (the code's k) — sizes a write's payload.
    fn data_shards(&self) -> usize;
    /// Block size in bytes.
    fn block_len(&self) -> usize;
    /// Plain read of a healthy block at `client`.
    fn read_block(&self, sid: u64, block: usize, client: Location) -> Result<Vec<u8>>;
    /// Rebuild `(sid, block)` at `client` (paper Exp 3).
    fn degraded_read(
        &self,
        sid: u64,
        block: usize,
        client: Location,
    ) -> Result<(Vec<u8>, Duration)>;
    /// Encode + distribute a stripe, charging the issuing `client`.
    fn write_stripe_from(&self, sid: u64, data: Vec<Vec<u8>>, client: Location) -> Result<()>;
}

/// What the engine measured for one foreground run.
#[derive(Clone, Debug)]
pub struct FgOutcome {
    /// Per-request latency in seconds, indexed by request id. Open loop:
    /// completion − scheduled arrival (queueing included). Closed loop:
    /// service time.
    pub latencies: Vec<f64>,
    /// Wall/simulated seconds until the last request completed.
    pub seconds: f64,
    /// Served requests per class: (normal reads, degraded reads, writes).
    pub by_class: (usize, usize, usize),
}

impl FgOutcome {
    pub fn served(&self) -> usize {
        self.latencies.len()
    }

    /// Latency percentile summary (None for an empty run).
    pub fn summary(&self) -> Option<Summary> {
        if self.latencies.is_empty() {
            None
        } else {
            Some(crate::metrics::summarize(&self.latencies))
        }
    }
}

/// Classify a request sequence (shared by both backends' reports).
pub fn class_counts(reqs: &[Request]) -> (usize, usize, usize) {
    let mut counts = (0, 0, 0);
    for r in reqs {
        match r.class {
            RequestClass::NormalRead { .. } => counts.0 += 1,
            RequestClass::DegradedRead { .. } => counts.1 += 1,
            RequestClass::Write { .. } => counts.2 += 1,
        }
    }
    counts
}

/// Deterministic shard data for a foreground [`RequestClass::Write`] —
/// both the writer and any later verification regenerate it.
pub fn fg_write_data(stripe: u64, k: usize, len: usize) -> Vec<Vec<u8>> {
    (0..k)
        .map(|b| xorshift_bytes(len, stripe.wrapping_mul(131).wrapping_add(b as u64)))
        .collect()
}

fn execute_one<C: ClientIo>(cluster: &C, req: &Request) -> Result<()> {
    match req.class {
        RequestClass::NormalRead { stripe, block } => {
            cluster.read_block(stripe, block, req.client)?;
        }
        RequestClass::DegradedRead { stripe, block } => {
            cluster.degraded_read(stripe, block, req.client)?;
        }
        RequestClass::Write { stripe } => {
            let k = cluster.data_shards();
            let len = cluster.block_len();
            // charge encode + distribution to the requesting node, exactly
            // as request_job models it for the fluid backend
            cluster.write_stripe_from(stripe, fg_write_data(stripe, k, len), req.client)?;
        }
    }
    Ok(())
}

/// Run a request sequence against a cluster (any [`ClientIo`] data
/// plane), measuring per-request latency. `workers` bounds the open-loop
/// pool (closed loop spawns the arrival model's client count). While
/// running, `fg_active` (when given) is held `true` so the recovery
/// executor's QoS throttle and the link split apply exactly while
/// foreground load exists.
pub fn run_on_cluster<C: ClientIo>(
    cluster: &C,
    reqs: &[Request],
    arrival: ArrivalModel,
    workers: usize,
    fg_active: Option<&AtomicBool>,
) -> Result<FgOutcome> {
    let by_class = class_counts(reqs);
    if reqs.is_empty() {
        // an empty run is never "active": a caller-initialized flag must
        // not leave recovery throttled against nonexistent traffic
        if let Some(flag) = fg_active {
            flag.store(false, Ordering::Relaxed);
        }
        return Ok(FgOutcome { latencies: Vec::new(), seconds: 0.0, by_class });
    }
    if let Some(flag) = fg_active {
        flag.store(true, Ordering::Relaxed);
    }
    let latencies: Mutex<Vec<f64>> = Mutex::new(vec![0.0; reqs.len()]);
    let errors: Mutex<Vec<String>> = Mutex::new(Vec::new());
    let t0 = Instant::now();
    match arrival {
        ArrivalModel::Open { .. } => {
            let next = AtomicUsize::new(0);
            std::thread::scope(|scope| {
                for _ in 0..workers.max(1) {
                    scope.spawn(|| loop {
                        let i = next.fetch_add(1, Ordering::Relaxed);
                        if i >= reqs.len() {
                            break;
                        }
                        let req = &reqs[i];
                        let target = t0 + Duration::from_secs_f64(req.arrival_s);
                        let now = Instant::now();
                        if target > now {
                            std::thread::sleep(target - now);
                        }
                        match execute_one(cluster, req) {
                            Ok(()) => {
                                let lat = target.elapsed().as_secs_f64();
                                latencies.lock().unwrap()[req.id] = lat;
                            }
                            Err(e) => errors.lock().unwrap().push(e.to_string()),
                        }
                    });
                }
            });
        }
        ArrivalModel::Closed { clients, think_s } => {
            let clients = clients.max(1);
            std::thread::scope(|scope| {
                for slot in 0..clients {
                    let (latencies, errors) = (&latencies, &errors);
                    scope.spawn(move || {
                        for req in reqs.iter().filter(|r| r.slot == slot) {
                            let start = Instant::now();
                            match execute_one(cluster, req) {
                                Ok(()) => {
                                    let lat = start.elapsed().as_secs_f64();
                                    latencies.lock().unwrap()[req.id] = lat;
                                }
                                Err(e) => errors.lock().unwrap().push(e.to_string()),
                            }
                            if think_s > 0.0 {
                                std::thread::sleep(Duration::from_secs_f64(think_s));
                            }
                        }
                    });
                }
            });
        }
    }
    let seconds = t0.elapsed().as_secs_f64();
    if let Some(flag) = fg_active {
        flag.store(false, Ordering::Relaxed);
    }
    let errs = errors.into_inner().unwrap();
    if !errs.is_empty() {
        bail!("foreground engine errors: {}", errs.join("; "));
    }
    Ok(FgOutcome { latencies: latencies.into_inner().unwrap(), seconds, by_class })
}

/// Lower one request into a fluid-simulator job. The first activity is a
/// `Delay(arrival_s)`, so spawning every request at t = 0 reproduces the
/// generated arrival sequence exactly; a request's simulated latency is
/// its job's finish time minus its arrival. `failed` is the scenario's
/// failure set: write flows toward dead nodes are dropped, mirroring
/// [`crate::cluster::MiniCluster::write_stripe_from`].
pub fn request_job(
    req: &Request,
    table: &PlacementTable,
    rt: &ResourceTable,
    spec: &SystemSpec,
    seed: u64,
    failed: &[crate::topology::Location],
) -> JobSpec {
    let bytes = spec.block_size as f64;
    let seek = spec.disk.seek_ms / 1e3;
    let arrival = req.arrival_s.max(0.0);
    match req.class {
        RequestClass::DegradedRead { stripe, block } => {
            // same plan the cluster's degraded_read builds, so both
            // backends move the same blocks over the same links
            let plan = plan_degraded_read(table, stripe, block, req.client, seed);
            plan_to_job_with(&plan, rt, spec, arrival)
        }
        RequestClass::NormalRead { stripe, block } => {
            let mut job = JobSpec::default();
            let arrive = job.push(Work::Delay(arrival), vec![]);
            let loc = table.stripe(stripe).locs[block];
            let s = job.push(Work::Delay(seek), vec![arrive]);
            let read =
                job.push(Work::Flow { resources: vec![rt.disk(loc)], bytes }, vec![s]);
            job.push(
                Work::Flow { resources: rt.transfer(loc, req.client), bytes },
                vec![read],
            );
            job
        }
        RequestClass::Write { stripe } => {
            let mut job = JobSpec::default();
            let arrive = job.push(Work::Delay(arrival), vec![]);
            let k = table.code().k();
            // client-side encode streams all k sources through the GF path
            let enc = job.push(
                Work::Flow {
                    resources: vec![rt.cpu(req.client)],
                    bytes: bytes * k as f64,
                },
                vec![arrive],
            );
            for loc in table.stripe(stripe).locs {
                if failed.contains(&loc) {
                    // a dead DataNode cannot accept the replica
                    continue;
                }
                let xfer = job.push(
                    Work::Flow { resources: rt.transfer(req.client, loc), bytes },
                    vec![enc],
                );
                let sw = job.push(Work::Delay(seek), vec![xfer]);
                job.push(Work::Flow { resources: vec![rt.disk(loc)], bytes }, vec![sw]);
            }
            job
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::client::gen::FgSpec;
    use crate::codes::CodeSpec;
    use crate::placement::D3Placement;
    use crate::sim::engine::Engine;
    use std::sync::Arc;

    fn policy() -> Arc<dyn Placement> {
        let spec = SystemSpec::paper_default();
        Arc::new(D3Placement::new(CodeSpec::Rs { k: 3, m: 2 }, spec.cluster).unwrap())
    }

    #[test]
    fn sim_jobs_complete_with_arrival_offset_latencies() {
        let spec = SystemSpec::paper_default();
        let p = policy();
        let table = PlacementTable::build(p.clone(), 30);
        let rt = ResourceTable::new(&spec);
        let fg = FgSpec {
            requests: 12,
            arrival: ArrivalModel::Open { rate_rps: 2.0 },
            read_weight: 2,
            degraded_weight: 1,
            write_weight: 1,
            zipf: 0.0,
        };
        let failed = vec![p.stripe(2).locs[1]];
        let reqs = fg.generate(&p, 30, &failed, 4).unwrap();
        let mut engine = Engine::new(rt.caps.clone());
        let ids: Vec<(u32, f64)> = reqs
            .iter()
            .map(|r| {
                let job = request_job(r, &table, &rt, &spec, 4, &failed);
                (engine.spawn(job), r.arrival_s)
            })
            .collect();
        engine.run_to_completion();
        for &(id, arrival) in &ids {
            let lat = engine.finish_time(id) - arrival;
            assert!(lat > 0.0, "request finished before doing any work");
            assert!(lat < 600.0, "implausible latency {lat}");
        }
    }

    #[test]
    fn class_counts_partition_the_sequence() {
        let p = policy();
        let fg = FgSpec {
            requests: 40,
            arrival: ArrivalModel::Open { rate_rps: f64::INFINITY },
            read_weight: 1,
            degraded_weight: 1,
            write_weight: 1,
            zipf: 0.0,
        };
        let reqs = fg.generate(&p, 30, &[p.stripe(0).locs[3]], 8).unwrap();
        let (r, d, w) = class_counts(&reqs);
        assert_eq!(r + d + w, 40);
        assert!(r > 0 && d > 0 && w > 0, "{r}/{d}/{w}");
    }

    #[test]
    fn fg_write_data_is_deterministic_and_distinct() {
        let a = fg_write_data(7, 3, 1024);
        let b = fg_write_data(7, 3, 1024);
        assert_eq!(a, b);
        assert_ne!(a[0], a[1]);
        assert_ne!(fg_write_data(8, 3, 1024)[0], a[0]);
    }
}
