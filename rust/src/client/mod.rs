//! The QoS-aware client I/O engine (DESIGN.md §11): **one** foreground-
//! traffic path shared by every backend.
//!
//! The paper's second headline claim — "D³ supports front-end applications
//! better than RDD in both of normal and recovery states" (§6.2.3–§6.2.4)
//! — used to be served by three disjoint ad-hoc code paths (the
//! ClusterBackend's reader-thread hack, a bespoke degraded-burst loop, and
//! the standalone `sim::frontend` job builder). Production systems treat
//! foreground I/O and recovery as one scheduled resource problem: recovery
//! traffic is throttled so repair does not destroy tail latency (Rashmi et
//! al., arXiv:1309.0186; XORing Elephants, arXiv:1301.3791). This module
//! is that one problem's one implementation:
//!
//! * [`gen`] — request classes ([`RequestClass`]) and deterministic seeded
//!   open-loop / closed-loop generators ([`FgSpec::generate`]); both
//!   backends consume the **same** generated [`Request`] sequence, so
//!   foreground arrival patterns are bit-identical across the fluid
//!   simulator and the MiniCluster.
//! * [`engine`] — executes a request sequence: real reads/writes through
//!   [`crate::cluster::MiniCluster`] (per-request wall-clock latency), or
//!   fluid-engine jobs for the simulator (per-request simulated latency).
//! * [`QosConfig`] — the recovery/foreground split: `recovery_share`
//!   throttles recovery-class traffic at node ports and rack links
//!   ([`crate::cluster::links::LinkSet`]), and `fg_weight` scales the
//!   recovery executor's inter-chunk pacing while foreground load is
//!   active ([`crate::recovery::executor::ChunkRunner::throttle`]).

pub mod engine;
pub mod gen;

pub use engine::{request_job, run_on_cluster, ClientIo, FgOutcome};
pub use gen::{ArrivalModel, FgSpec, Request, RequestClass};

/// The QoS policy a mixed-load scenario carries (DESIGN.md §11): how the
/// cluster's scarce ports are split between recovery and foreground
/// traffic. The background scrub daemon's probes
/// ([`crate::scrub::run_daemon`]) are a third consumer of the same
/// split: scrub-class traffic drains the identical `recovery_share`
/// bucket bank while foreground load is active, so an installed split
/// caps recovery and scrub *together* — the daemon can never take
/// bandwidth the split reserved for client I/O (DESIGN.md §15).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct QosConfig {
    /// Fraction (0, 1] of every node port and rack link available to
    /// recovery-class traffic while foreground load is active. `1.0`
    /// disables the split entirely — byte-for-byte the pre-QoS data path.
    pub recovery_share: f64,
    /// Weight of the recovery executor's inter-chunk pacing under
    /// foreground load: after a chunk that took `b` busy seconds, the
    /// worker yields `b · fg_weight · (1/recovery_share − 1)` seconds.
    /// `0.0` keeps only the link-level split.
    pub fg_weight: f64,
}

impl Default for QosConfig {
    fn default() -> QosConfig {
        QosConfig { recovery_share: 1.0, fg_weight: 1.0 }
    }
}

impl QosConfig {
    /// True when this config actually constrains recovery traffic.
    pub fn is_active(&self) -> bool {
        self.recovery_share < 1.0 && self.recovery_share > 0.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_qos_is_inactive() {
        let q = QosConfig::default();
        assert!(!q.is_active());
        assert!(QosConfig { recovery_share: 0.5, fg_weight: 1.0 }.is_active());
        assert!(!QosConfig { recovery_share: 0.0, fg_weight: 1.0 }.is_active());
    }
}
