//! Mini-HDFS: an in-process erasure-coded storage cluster with a *real*
//! data path — real bytes, real GF(2^8) coding through the PJRT artifacts
//! (or the native fallback), real concurrent transfers throttled to the
//! paper's bandwidth hierarchy by token buckets.
//!
//! This is the substitution for the 28-machine Hadoop testbed (DESIGN.md
//! §2): one thread pool plays the DataNodes, [`links::LinkSet`] plays the
//! switches, and the NameNode role (metadata + recovery orchestration)
//! lives in [`MiniCluster`]. The discrete-event simulator answers the
//! paper's parameter sweeps; this cluster proves the layers compose.

pub mod links;
pub mod service;

use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex, RwLock};
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Context};

use crate::client::QosConfig;
use crate::codes::CodeSpec;
use crate::gf;
use crate::metrics::PoolStats;
use crate::placement::{Placement, PlacementTable};
use crate::recovery::executor::{execute_plans, ChunkRunner, ExecutorConfig, Scratch};
use crate::recovery::plan::{plan_coefficients, plan_degraded_read, plan_repair, RepairPlan};
use crate::recovery::schedule::SchedulePolicy;
use crate::topology::{Location, SystemSpec};

use links::{LinkSet, TrafficClass};
use service::CoderService;

type BlockKey = (u64, usize);

/// Outcome of [`MiniCluster::recover_node`].
#[derive(Clone, Debug)]
pub struct ClusterRecoveryStats {
    pub blocks: usize,
    pub bytes: u64,
    pub wall: Duration,
    pub throughput_mb_s: f64,
    /// cross-rack bytes per rack (up, down)
    pub rack_bytes: Vec<(u64, u64)>,
    pub lambda: f64,
    /// Chunk tasks executed by the pipelined executor.
    pub chunks: usize,
    /// Admission rounds of the schedule (1 for FIFO).
    pub rounds: usize,
    /// Per-worker busy fraction of the recovery wall clock.
    pub worker_utilization: Vec<f64>,
    /// Scratch-pool hit/miss totals of the executor's worker pools.
    pub scratch: PoolStats,
    /// Per-rack-link (busy, stall) seconds during this recovery
    /// ([`links::LinkSet::link_busy_stall`]).
    pub link_busy_stall: Vec<(f64, f64)>,
}

/// The in-process cluster.
pub struct MiniCluster {
    spec: SystemSpec,
    policy: Arc<dyn Placement>,
    links: Arc<LinkSet>,
    coder: CoderService,
    /// Parity rows of the policy's code, computed once at construction —
    /// every stripe encode reuses them instead of rebuilding the
    /// generator matrix per stripe.
    parity_rows: crate::gf::Matrix,
    /// per-node block store
    stores: Vec<Arc<Mutex<HashMap<BlockKey, Vec<u8>>>>>,
    /// metadata overrides after recovery (NameNode block map)
    relocated: Mutex<HashMap<BlockKey, Location>>,
    failed: Mutex<Vec<Location>>,
    /// cross-rack traffic accounting (up, down) per rack
    rack_up: Vec<AtomicU64>,
    rack_down: Vec<AtomicU64>,
    /// Transfers hold this as readers while bumping their (up, down) pair;
    /// [`MiniCluster::rack_byte_snapshot`] takes it as writer, so a
    /// snapshot can never observe a transfer's up-count without its
    /// down-count under the multi-threaded executor.
    accounting: RwLock<()>,
    /// Mixed-load QoS runtime (DESIGN.md §11): the active split and the
    /// foreground-activity flag the client engine toggles.
    qos: Mutex<Option<QosRuntime>>,
    /// Lock-free mirror of `qos.is_some()`: the per-chunk throttle hook
    /// checks this first, so plain recovery never touches the mutex.
    qos_on: AtomicBool,
    seed: u64,
}

/// The QoS parameters in force during a mixed-load run.
#[derive(Clone)]
struct QosRuntime {
    cfg: QosConfig,
    fg_active: Arc<AtomicBool>,
}

impl MiniCluster {
    /// `backend`: "native" or "pjrt".
    pub fn new(
        spec: SystemSpec,
        policy: Arc<dyn Placement>,
        backend: &str,
        seed: u64,
    ) -> anyhow::Result<MiniCluster> {
        assert_eq!(policy.cluster(), spec.cluster, "policy/topology mismatch");
        let coder = CoderService::spawn_pool(backend, encode_pool_size())?;
        let parity_rows = parity_matrix(&policy.code());
        Ok(MiniCluster {
            links: Arc::new(LinkSet::new(&spec)),
            stores: (0..spec.cluster.node_count())
                .map(|_| Arc::new(Mutex::new(HashMap::new())))
                .collect(),
            relocated: Mutex::new(HashMap::new()),
            failed: Mutex::new(Vec::new()),
            rack_up: (0..spec.cluster.racks).map(|_| AtomicU64::new(0)).collect(),
            rack_down: (0..spec.cluster.racks).map(|_| AtomicU64::new(0)).collect(),
            accounting: RwLock::new(()),
            qos: Mutex::new(None),
            qos_on: AtomicBool::new(false),
            spec,
            policy,
            coder,
            parity_rows,
            seed,
        })
    }

    pub fn spec(&self) -> &SystemSpec {
        &self.spec
    }

    pub fn policy(&self) -> &dyn Placement {
        self.policy.as_ref()
    }

    fn store_of(&self, loc: Location) -> &Arc<Mutex<HashMap<BlockKey, Vec<u8>>>> {
        &self.stores[self.spec.cluster.flat(loc)]
    }

    /// Current location of a block (NameNode metadata).
    pub fn locate(&self, sid: u64, block: usize) -> Location {
        if let Some(loc) = self.relocated.lock().unwrap().get(&(sid, block)) {
            return *loc;
        }
        self.policy.stripe(sid).locs[block]
    }

    fn transfer(&self, src: Location, dst: Location, bytes: u64, class: TrafficClass) {
        if src.rack != dst.rack {
            let _pairwise = self.accounting.read().unwrap();
            self.rack_up[src.rack as usize].fetch_add(bytes, Ordering::Relaxed);
            self.rack_down[dst.rack as usize].fetch_add(bytes, Ordering::Relaxed);
        }
        self.links.transfer_class(src, dst, bytes, class);
    }

    /// Batched inbound transfer (recovery-class): account every flow's
    /// cross-rack bytes under one pairwise-consistency hold, then move the
    /// whole group through the links under a single ordered gate
    /// acquisition ([`links::LinkSet::transfer_batch`]) — the
    /// fetch-coalescing path.
    fn transfer_group(&self, to: Location, flows: &[(Location, u64)]) {
        {
            let _pairwise = self.accounting.read().unwrap();
            for &(src, bytes) in flows {
                if src.rack != to.rack && bytes > 0 {
                    self.rack_up[src.rack as usize].fetch_add(bytes, Ordering::Relaxed);
                    self.rack_down[to.rack as usize].fetch_add(bytes, Ordering::Relaxed);
                }
            }
        }
        self.links.transfer_batch(to, flows, TrafficClass::Recovery);
    }

    /// Install a QoS split for a mixed-load run (DESIGN.md §11): recovery
    /// traffic is capped at `cfg.recovery_share` of every port while
    /// `fg_active` holds true, and the executor's throttle hook paces
    /// recovery workers by `cfg.fg_weight`. [`MiniCluster::clear_qos`]
    /// restores the unsplit data path.
    pub fn set_qos(&self, cfg: QosConfig, fg_active: Arc<AtomicBool>) {
        self.links.set_qos(cfg.recovery_share, fg_active.clone());
        *self.qos.lock().unwrap() = Some(QosRuntime { cfg, fg_active });
        self.qos_on.store(true, Ordering::Relaxed);
    }

    /// Remove the QoS split.
    pub fn clear_qos(&self) {
        self.links.clear_qos();
        *self.qos.lock().unwrap() = None;
        self.qos_on.store(false, Ordering::Relaxed);
    }

    /// The recovery executor's pacing hook ([`ChunkRunner::throttle`]):
    /// after a chunk that kept a worker busy for `busy_s`, yield
    /// `busy_s × fg_weight × (1/recovery_share − 1)` seconds while
    /// foreground load is active, so recovery's *compute admission* backs
    /// off in the same proportion as its link share. Each yield is capped
    /// at 50 ms so a slow chunk cannot park a worker for seconds — the
    /// link-level bucket split ([`links::LinkSet::set_qos`]) remains the
    /// bandwidth guarantee; this hook only adds admission back-pressure.
    fn qos_pace(&self, busy_s: f64) {
        if !self.qos_on.load(Ordering::Relaxed) {
            return;
        }
        let rt = self.qos.lock().unwrap().clone();
        let Some(rt) = rt else { return };
        if !rt.cfg.is_active()
            || rt.cfg.fg_weight <= 0.0
            || !rt.fg_active.load(Ordering::Relaxed)
        {
            return;
        }
        let share = rt.cfg.recovery_share;
        let pause = busy_s * rt.cfg.fg_weight * (1.0 / share - 1.0);
        if pause > 0.0 {
            std::thread::sleep(Duration::from_secs_f64(pause.min(0.05)));
        }
    }

    /// Client write path: encode `data` (k shards) and distribute the
    /// stripe per the placement policy. The client is modeled at the
    /// location of block 0 (HDFS writes the first replica locally).
    ///
    /// Takes the data shards by value: they are moved through the coder
    /// service (one `Encode` round trip computes every parity row) and
    /// then moved into the node stores — ingest copies **zero** blocks.
    /// Callers that need the bytes afterwards clone at the call site or
    /// regenerate from their deterministic generator.
    pub fn write_stripe(&self, sid: u64, data: Vec<Vec<u8>>) -> anyhow::Result<()> {
        self.write_stripe_inner(sid, data, None)
    }

    /// [`MiniCluster::write_stripe`] with an explicit issuing client — the
    /// client engine's write path (DESIGN.md §11). Encode and every block
    /// distribution are charged to `client`, exactly as the fluid backend
    /// models the same request, so cross-backend byte accounting agrees.
    pub fn write_stripe_from(
        &self,
        sid: u64,
        data: Vec<Vec<u8>>,
        client: Location,
    ) -> anyhow::Result<()> {
        self.write_stripe_inner(sid, data, Some(client))
    }

    /// Shared write path: one placement derivation per stripe; `client`
    /// defaults to the first replica's node (HDFS write-local). Replicas
    /// whose placement lands on a failed node are skipped (a dead
    /// DataNode cannot accept data; [`crate::client::request_job`] drops
    /// the same flows), leaving the stripe degraded until recovery.
    fn write_stripe_inner(
        &self,
        sid: u64,
        data: Vec<Vec<u8>>,
        client: Option<Location>,
    ) -> anyhow::Result<()> {
        let code = self.policy.code();
        if data.len() != code.k() {
            bail!("expected {} data shards, got {}", code.k(), data.len());
        }
        let (data, parity) =
            self.coder.encode(self.parity_rows.clone(), data).context("encode")?;
        let sp = self.policy.stripe(sid);
        let client = client.unwrap_or(sp.locs[0]);
        let failed = self.failed.lock().unwrap().clone();
        for (bi, bytes) in data.into_iter().chain(parity).enumerate() {
            let dst = sp.locs[bi];
            if failed.contains(&dst) {
                continue;
            }
            self.transfer(client, dst, bytes.len() as u64, TrafficClass::Foreground);
            self.store_of(dst).lock().unwrap().insert((sid, bi), bytes);
        }
        Ok(())
    }

    /// Write many stripes concurrently (`workers` client threads) using a
    /// data generator. Each generated stripe is moved straight into the
    /// cluster; callers that verify afterwards re-invoke their (by
    /// contract deterministic) generator instead of keeping a copy here.
    pub fn write_stripes_parallel(
        &self,
        stripes: u64,
        workers: usize,
        gen: impl Fn(u64) -> Vec<Vec<u8>> + Sync,
    ) -> anyhow::Result<()> {
        let next = std::sync::atomic::AtomicU64::new(0);
        let errors: Mutex<Vec<String>> = Mutex::new(Vec::new());
        std::thread::scope(|scope| {
            for _ in 0..workers.max(1) {
                scope.spawn(|| loop {
                    let sid = next.fetch_add(1, Ordering::Relaxed);
                    if sid >= stripes {
                        break;
                    }
                    if let Err(e) = self.write_stripe(sid, gen(sid)) {
                        errors.lock().unwrap().push(e.to_string());
                        break;
                    }
                });
            }
        });
        let errs = errors.into_inner().unwrap();
        if !errs.is_empty() {
            bail!("write errors: {}", errs.join("; "));
        }
        Ok(())
    }

    /// Plain read of a healthy block at `client`.
    pub fn read_block(&self, sid: u64, block: usize, client: Location) -> anyhow::Result<Vec<u8>> {
        let loc = self.locate(sid, block);
        if self.failed.lock().unwrap().contains(&loc) {
            bail!("block ({sid},{block}) is on failed node {loc} — use degraded_read");
        }
        let data = self
            .store_of(loc)
            .lock()
            .unwrap()
            .get(&(sid, block))
            .cloned()
            .ok_or_else(|| anyhow!("block ({sid},{block}) missing at {loc}"))?;
        self.transfer(loc, client, data.len() as u64, TrafficClass::Foreground);
        Ok(data)
    }

    /// Kill a node: erase its storage (recovery must rebuild from peers).
    pub fn fail_node(&self, loc: Location) {
        self.failed.lock().unwrap().push(loc);
        self.store_of(loc).lock().unwrap().clear();
    }

    fn fetch(&self, sid: u64, block: usize, to: Location) -> anyhow::Result<Vec<u8>> {
        let loc = self.locate(sid, block);
        let data = self
            .store_of(loc)
            .lock()
            .unwrap()
            .get(&(sid, block))
            .cloned()
            .ok_or_else(|| anyhow!("source block ({sid},{block}) missing at {loc}"))?;
        self.transfer(loc, to, data.len() as u64, TrafficClass::Foreground);
        Ok(data)
    }

    /// Fetch bytes `[off, off + len)` of a source block to `to` — the
    /// executor's chunk-granular read + throttled transfer. The bytes
    /// land in `buf` (cleared first), so a pooled scratch buffer can be
    /// reused across fetches instead of allocating per chunk.
    fn fetch_chunk_into(
        &self,
        sid: u64,
        block: usize,
        off: u64,
        len: usize,
        to: Location,
        buf: &mut Vec<u8>,
    ) -> anyhow::Result<()> {
        let loc = self.read_chunk_into(sid, block, off, len, buf)?;
        self.transfer(loc, to, len as u64, TrafficClass::Recovery);
        Ok(())
    }

    /// Disk half of a chunk fetch: copy bytes `[off, off + len)` of a
    /// source block into `buf` (cleared first) and return where the
    /// block lives. The caller owes the network a matching transfer —
    /// either per chunk ([`MiniCluster::fetch_chunk_into`]) or batched
    /// per window ([`MiniCluster::transfer_group`]).
    fn read_chunk_into(
        &self,
        sid: u64,
        block: usize,
        off: u64,
        len: usize,
        buf: &mut Vec<u8>,
    ) -> anyhow::Result<Location> {
        let loc = self.locate(sid, block);
        let store = self.store_of(loc).lock().unwrap();
        let blk = store
            .get(&(sid, block))
            .ok_or_else(|| anyhow!("source block ({sid},{block}) missing at {loc}"))?;
        let off = off as usize;
        if off + len > blk.len() {
            bail!(
                "chunk [{off}, {}) out of range for block ({sid},{block}) of {} bytes",
                off + len,
                blk.len()
            );
        }
        buf.clear();
        buf.extend_from_slice(&blk[off..off + len]);
        Ok(loc)
    }

    /// Execute one repair plan: inner-rack aggregation (D³) or direct
    /// fetches (RDD/LRC), final combine, optional store.
    fn execute_plan(&self, plan: &RepairPlan) -> anyhow::Result<Vec<u8>> {
        let code = self.policy.code();
        let sources = plan.source_blocks();
        let coeffs = plan_coefficients(&code, plan);
        let coeff_of = |b: usize| -> u8 {
            coeffs[sources.binary_search(&b).expect("source present")]
        };
        // All fetches run concurrently (HDFS striped reads are parallel);
        // scoped threads because transfers block on the token buckets.
        // §Perf: serial fetches made degraded reads latency-bound on the
        // slowest sequential chain instead of the slowest link.
        let mut final_coeffs: Vec<u8> = Vec::new();
        let mut final_shards: Vec<Vec<u8>> = Vec::new();
        let (agg_results, direct_results) = std::thread::scope(|scope| {
            let agg_handles: Vec<_> = plan
                .aggregations
                .iter()
                .map(|agg| {
                    scope.spawn(move || -> anyhow::Result<Vec<u8>> {
                        let fetch_handles: Vec<_> = std::thread::scope(|inner| {
                            agg.inputs
                                .iter()
                                .map(|&(b, _)| {
                                    inner.spawn(move || self.fetch(plan.stripe, b, agg.at))
                                })
                                .collect::<Vec<_>>()
                                .into_iter()
                                .map(|h| h.join().expect("fetch thread"))
                                .collect()
                        });
                        let mut c = Vec::with_capacity(agg.inputs.len());
                        let mut shards = Vec::with_capacity(agg.inputs.len());
                        for (res, &(b, _)) in fetch_handles.into_iter().zip(&agg.inputs) {
                            shards.push(res?);
                            c.push(coeff_of(b));
                        }
                        let partial = self.coder.combine(c, shards)?;
                        // ship ONE aggregated block to the compute node
                        self.transfer(
                            agg.at,
                            plan.compute_at,
                            partial.len() as u64,
                            TrafficClass::Foreground,
                        );
                        Ok(partial)
                    })
                })
                .collect();
            let direct_handles: Vec<_> = plan
                .direct
                .iter()
                .map(|&(b, _)| scope.spawn(move || self.fetch(plan.stripe, b, plan.compute_at)))
                .collect();
            (
                agg_handles.into_iter().map(|h| h.join().expect("agg thread")).collect::<Vec<_>>(),
                direct_handles
                    .into_iter()
                    .map(|h| h.join().expect("direct thread"))
                    .collect::<Vec<_>>(),
            )
        });
        for res in agg_results {
            final_shards.push(res?);
            final_coeffs.push(1);
        }
        for (res, &(b, _)) in direct_results.into_iter().zip(&plan.direct) {
            final_shards.push(res?);
            final_coeffs.push(coeff_of(b));
        }
        let rebuilt = self.coder.combine(final_coeffs, final_shards)?;
        if plan.persist {
            self.store_of(plan.writer)
                .lock()
                .unwrap()
                .insert((plan.stripe, plan.failed_block), rebuilt.clone());
            self.relocated
                .lock()
                .unwrap()
                .insert((plan.stripe, plan.failed_block), plan.writer);
        }
        Ok(rebuilt)
    }

    /// Degraded read: rebuild `(sid, block)` at `client` (paper Exp 3).
    pub fn degraded_read(
        &self,
        sid: u64,
        block: usize,
        client: Location,
    ) -> anyhow::Result<(Vec<u8>, Duration)> {
        let t0 = Instant::now();
        let plan = plan_degraded_read(self.policy.as_ref(), sid, block, client, self.seed);
        let data = self.execute_plan(&plan)?;
        Ok((data, t0.elapsed()))
    }

    /// Full-node recovery with `workers` concurrent reconstruction tasks.
    pub fn recover_node(
        &self,
        failed: Location,
        stripes: u64,
        workers: usize,
    ) -> anyhow::Result<ClusterRecoveryStats> {
        let mut plans = Vec::new();
        for sid in 0..stripes {
            let sp = self.policy.stripe(sid);
            for (bi, &loc) in sp.locs.iter().enumerate() {
                if loc == failed {
                    plans.push(plan_repair(self.policy.as_ref(), sid, bi, self.seed));
                }
            }
        }
        self.recover_with_plans(plans, workers, &[failed.rack])
    }

    /// Execute an arbitrary plan set (the scenario engine's entry point —
    /// single node, K nodes, a whole rack) with `workers` concurrent
    /// reconstruction tasks at the default chunking/caps. λ is computed
    /// over the racks not in `failed_racks`; traffic accounting covers
    /// exactly this recovery.
    pub fn recover_with_plans(
        &self,
        plans: Vec<RepairPlan>,
        workers: usize,
        failed_racks: &[u32],
    ) -> anyhow::Result<ClusterRecoveryStats> {
        self.recover_with_plans_cfg(
            plans,
            ExecutorConfig { workers, ..ExecutorConfig::default() },
            failed_racks,
        )
    }

    /// [`MiniCluster::recover_with_plans`] with full control of the
    /// pipelined executor (DESIGN.md §8): plans are split into
    /// `cfg.chunk_size` tasks, scheduled over `cfg.workers` threads, and
    /// every transfer runs under the per-node / per-rack-link in-flight
    /// caps.
    pub fn recover_with_plans_cfg(
        &self,
        plans: Vec<RepairPlan>,
        cfg: ExecutorConfig,
        failed_racks: &[u32],
    ) -> anyhow::Result<ClusterRecoveryStats> {
        let mut cfg = cfg;
        // the balanced scheduler tiles its coloring across the placement
        // period when the policy is periodic (DESIGN.md §10)
        if cfg.period.is_none() {
            cfg.period = self.policy.period();
        }
        let before = self.rack_byte_snapshot();
        let links_before = self.links.link_busy_stall();
        let blocks = plans.len();
        let bytes: u64 = blocks as u64 * self.spec.block_size;
        self.links.set_inflight_caps(cfg.node_inflight, cfg.link_inflight);
        let io = ChunkIo::new(self, &plans, cfg.batched_fetch);
        let run = execute_plans(&io, &plans, self.spec.block_size, &cfg);
        // lift the caps so post-recovery traffic (reads, writes) is ungated
        self.links.set_inflight_caps(0, 0);
        let stats = run?;
        let after = self.rack_byte_snapshot();
        let rack_bytes: Vec<(u64, u64)> = before
            .iter()
            .zip(&after)
            .map(|(&(u0, d0), &(u1, d1))| (u1 - u0, d1 - d0))
            .collect();
        let link_busy_stall = self.link_busy_stall_since(&links_before);
        let loads: Vec<(f64, f64)> =
            rack_bytes.iter().map(|&(u, d)| (u as f64, d as f64)).collect();
        let lambda = crate::sim::recovery::lambda_metric_excluding(&loads, failed_racks);
        let secs = stats.wall_s;
        Ok(ClusterRecoveryStats {
            blocks,
            bytes,
            wall: Duration::from_secs_f64(secs),
            throughput_mb_s: if secs > 0.0 { bytes as f64 / secs / 1e6 } else { 0.0 },
            rack_bytes,
            lambda,
            chunks: stats.chunks,
            rounds: stats.rounds,
            worker_utilization: stats.utilization(),
            scratch: stats.scratch,
            link_busy_stall,
        })
    }

    /// Run recovery and a foreground request sequence concurrently under
    /// `qos` (DESIGN.md §11): install the split, drive the client engine
    /// beside the recovery executor, remove the split afterwards. The ONE
    /// mixed-load orchestration, shared by the scenario backend and the
    /// perf harness — the fg-activity flag's lifecycle lives here.
    #[allow(clippy::too_many_arguments)]
    pub fn run_mixed_load(
        &self,
        plans: Vec<RepairPlan>,
        cfg: ExecutorConfig,
        failed_racks: &[u32],
        reqs: &[crate::client::Request],
        arrival: crate::client::ArrivalModel,
        fg_workers: usize,
        qos: QosConfig,
    ) -> anyhow::Result<(ClusterRecoveryStats, crate::client::FgOutcome)> {
        let fg_active = Arc::new(AtomicBool::new(true));
        self.set_qos(qos, fg_active.clone());
        let flag: &AtomicBool = fg_active.as_ref();
        let (stats, fgout) = std::thread::scope(|scope| {
            let engine = scope.spawn(move || {
                crate::client::run_on_cluster(self, reqs, arrival, fg_workers, Some(flag))
            });
            let stats = self.recover_with_plans_cfg(plans, cfg, failed_racks);
            (stats, engine.join().expect("client engine thread"))
        });
        self.clear_qos();
        Ok((stats?, fgout?))
    }

    /// Blocks currently stored on `loc`.
    pub fn block_count(&self, loc: Location) -> usize {
        self.store_of(loc).lock().unwrap().len()
    }

    /// Per-rack-link (busy, stall) seconds accumulated since `before`, a
    /// snapshot taken with [`links::LinkSet::link_busy_stall`] — the time
    /// analogue of diffing two [`MiniCluster::rack_byte_snapshot`]s.
    fn link_busy_stall_since(&self, before: &[(f64, f64)]) -> Vec<(f64, f64)> {
        before
            .iter()
            .zip(self.links.link_busy_stall())
            .map(|(&(b0, s0), (b1, s1))| (b1 - b0, s1 - s0))
            .collect()
    }

    /// Snapshot of the per-rack cross-rack byte counters (up, down) —
    /// callers diff two snapshots to attribute traffic to a phase. Takes
    /// the accounting lock as writer so no in-flight transfer's (up, down)
    /// pair is observed half-applied.
    pub fn rack_byte_snapshot(&self) -> Vec<(u64, u64)> {
        let _barrier = self.accounting.write().unwrap();
        (0..self.spec.cluster.racks)
            .map(|r| {
                (
                    self.rack_up[r].load(Ordering::Relaxed),
                    self.rack_down[r].load(Ordering::Relaxed),
                )
            })
            .collect()
    }
}

/// One plan's fetch structure with decode coefficients resolved at build
/// time (once per plan, not once per chunk): inner-rack aggregation
/// groups and the direct source set, each as `(block, coeff)` lists.
struct PlanFetch {
    /// (aggregator location, that rack's inputs).
    aggs: Vec<(Location, Vec<(usize, u8)>)>,
    /// Sources shipped straight to the compute node.
    direct: Vec<(usize, u8)>,
}

/// Chunk-level IO behind the pipelined executor: fetches source-chunk
/// bytes through the gated, token-bucket-throttled links into pooled
/// scratch buffers — per source, or per window through the batched
/// single-gate-acquisition path (DESIGN.md §10) — runs ONE fused
/// cache-blocked multiply-accumulate per aggregation group and per
/// direct-source set ([`gf::combine_many_into`], DESIGN.md §9), and
/// persists finished blocks into the NameNode metadata. Decode
/// coefficients are resolved once per plan, not once per chunk, and the
/// steady-state chunk loop allocates nothing — every buffer (including
/// the batched-fetch flow list) cycles through the worker's [`Scratch`]
/// pool.
struct ChunkIo<'a> {
    cluster: &'a MiniCluster,
    /// Per-plan resolved fetch groups.
    fetch: Vec<PlanFetch>,
    /// Coalesce each task's same-destination fetches into one batched
    /// gated round trip (DESIGN.md §10) instead of one per source.
    batched: bool,
}

impl<'a> ChunkIo<'a> {
    fn new(cluster: &'a MiniCluster, plans: &[RepairPlan], batched: bool) -> ChunkIo<'a> {
        let code = cluster.policy.code();
        let fetch = plans
            .iter()
            .map(|p| {
                let sources = p.source_blocks();
                let coeffs = plan_coefficients(&code, p);
                let coeff_of = |b: usize| -> u8 {
                    coeffs[sources.binary_search(&b).expect("source present")]
                };
                PlanFetch {
                    aggs: p
                        .aggregations
                        .iter()
                        .map(|agg| {
                            (
                                agg.at,
                                agg.inputs
                                    .iter()
                                    .map(|&(b, _)| (b, coeff_of(b)))
                                    .collect(),
                            )
                        })
                        .collect(),
                    direct: p.direct.iter().map(|&(b, _)| (b, coeff_of(b))).collect(),
                }
            })
            .collect();
        ChunkIo { cluster, fetch, batched }
    }

    /// Fetch every `(block, coeff)` source's `[off, off + len)` window to
    /// `to`, pushing `(coeff, bytes)` pairs onto `fetched`. Batched mode
    /// reads all windows from disk first and then moves the whole group
    /// through the links in one gated round trip; per-chunk mode issues
    /// one gated transfer per source (the pre-§10 baseline).
    #[allow(clippy::too_many_arguments)]
    fn fetch_sources(
        &self,
        stripe: u64,
        blocks: &[(usize, u8)],
        off: u64,
        len: usize,
        to: Location,
        scratch: &mut Scratch,
        fetched: &mut Vec<(u8, Vec<u8>)>,
    ) -> anyhow::Result<()> {
        if self.batched {
            let mut flows = scratch.take_flows();
            for &(b, c) in blocks {
                let mut buf = scratch.take();
                match self.cluster.read_chunk_into(stripe, b, off, len, &mut buf) {
                    Ok(src) => {
                        flows.push((src, len as u64));
                        fetched.push((c, buf));
                    }
                    Err(e) => {
                        scratch.put(buf);
                        scratch.put_flows(flows);
                        return Err(e);
                    }
                }
            }
            self.cluster.transfer_group(to, &flows);
            scratch.put_flows(flows);
        } else {
            for &(b, c) in blocks {
                let mut buf = scratch.take();
                self.cluster.fetch_chunk_into(stripe, b, off, len, to, &mut buf)?;
                fetched.push((c, buf));
            }
        }
        Ok(())
    }
}

impl ChunkRunner for ChunkIo<'_> {
    fn run_chunk(
        &self,
        plan_idx: usize,
        plan: &RepairPlan,
        off: u64,
        len: usize,
        scratch: &mut Scratch,
    ) -> anyhow::Result<Vec<u8>> {
        let fetch = &self.fetch[plan_idx];
        let mut acc = scratch.take_zeroed(len);
        let mut fetched = scratch.take_staging();
        for (at, inputs) in &fetch.aggs {
            // inner-rack aggregation at `at`, then ship ONE aggregated
            // chunk to the compute node
            let mut partial = scratch.take_zeroed(len);
            self.fetch_sources(plan.stripe, inputs, off, len, *at, scratch, &mut fetched)?;
            gf::combine_many_into(&mut partial, &fetched);
            for (_, buf) in fetched.drain(..) {
                scratch.put(buf);
            }
            self.cluster
                .transfer(*at, plan.compute_at, len as u64, TrafficClass::Recovery);
            gf::xor_into(&mut acc, &partial);
            scratch.put(partial);
        }
        self.fetch_sources(
            plan.stripe,
            &fetch.direct,
            off,
            len,
            plan.compute_at,
            scratch,
            &mut fetched,
        )?;
        gf::combine_many_into(&mut acc, &fetched);
        scratch.put_staging(fetched);
        Ok(acc)
    }

    fn finish_plan(
        &self,
        _plan_idx: usize,
        plan: &RepairPlan,
        block: Vec<u8>,
    ) -> anyhow::Result<()> {
        if plan.persist {
            self.cluster
                .store_of(plan.writer)
                .lock()
                .unwrap()
                .insert((plan.stripe, plan.failed_block), block);
            self.cluster
                .relocated
                .lock()
                .unwrap()
                .insert((plan.stripe, plan.failed_block), plan.writer);
        }
        Ok(())
    }

    fn throttle(&self, busy_s: f64) {
        self.cluster.qos_pace(busy_s);
    }
}

/// The MiniCluster implementation of the scenario engine
/// ([`crate::scenario::RecoveryBackend`], DESIGN.md §5): real bytes moved
/// through token-bucket links and the real GF data path.
///
/// Runs at a scaled-down block size and scaled-up link rates (same 5:1
/// inner/cross ratio as the paper) so wall-clock stays interactive;
/// backend-independent quantities — blocks rebuilt, planned cross-rack
/// block transfers, *relative* cross-rack bytes between policies — are the
/// cross-check against the fluid backend. Foreground traffic (mixed-load
/// kinds) runs through the shared client engine (DESIGN.md §11), so both
/// backends serve the identical generated request sequence; its byte
/// accounting lands in the same rack counters (foreground and recovery
/// share the links, as on a real cluster).
pub struct ClusterBackend {
    /// Coding data path: "native" or "pjrt".
    pub data_backend: String,
    /// Scaled block size (bytes) for the in-process run.
    pub block_size: u64,
    pub inner_mbps: f64,
    pub cross_mbps: f64,
    /// Concurrent reconstruction workers (HDFS xmits analogue).
    pub workers: usize,
    /// Executor chunk size (bytes); blocks split into chunk tasks so
    /// fetch/decode/write of different chunks pipeline (DESIGN.md §8).
    pub chunk_size: u64,
    /// Task-admission order: FIFO or the link-balanced wavefront
    /// schedule (DESIGN.md §10, `d3ctl scenario --schedule`).
    pub schedule: SchedulePolicy,
    /// Fetch-coalescing window in chunks (`--coalesce`, DESIGN.md §10).
    pub coalesce: usize,
    /// Move each task's same-destination fetches in one batched gated
    /// round trip (`--batched-fetch`, DESIGN.md §10).
    pub batched_fetch: bool,
}

impl Default for ClusterBackend {
    fn default() -> ClusterBackend {
        ClusterBackend {
            data_backend: "native".into(),
            block_size: 64 << 10,
            inner_mbps: 8000.0,
            cross_mbps: 1600.0,
            workers: 8,
            chunk_size: 16 << 10,
            schedule: SchedulePolicy::Fifo,
            coalesce: 1,
            batched_fetch: false,
        }
    }
}

impl ClusterBackend {
    fn exec_cfg(&self) -> ExecutorConfig {
        ExecutorConfig {
            workers: self.workers,
            chunk_size: self.chunk_size,
            schedule: self.schedule,
            coalesce: self.coalesce,
            batched_fetch: self.batched_fetch,
            ..ExecutorConfig::default()
        }
    }
}

/// Deterministic per-stripe data (xorshift fill keyed by stripe + block).
fn deterministic_data(sid: u64, k: usize, len: usize) -> Vec<Vec<u8>> {
    (0..k)
        .map(|b| {
            let mut v = vec![0u8; len];
            let mut s = sid.wrapping_mul(0x9e3779b9).wrapping_add(b as u64) | 1;
            for byte in v.iter_mut() {
                s ^= s << 13;
                s ^= s >> 7;
                s ^= s << 17;
                *byte = (s >> 24) as u8;
            }
            v
        })
        .collect()
}

use crate::scenario::distinct_racks;

impl crate::scenario::RecoveryBackend for ClusterBackend {
    fn name(&self) -> &'static str {
        "cluster"
    }

    fn run(
        &self,
        scenario: &crate::scenario::FailureScenario,
        policy: &Arc<dyn Placement>,
        spec: &SystemSpec,
    ) -> anyhow::Result<crate::scenario::ScenarioOutcome> {
        use crate::scenario::{planned_cross_rack_blocks, ScenarioKind, ScenarioOutcome};
        let mut cspec = *spec;
        cspec.block_size = self.block_size;
        cspec.net.inner_mbps = self.inner_mbps;
        cspec.net.cross_mbps = self.cross_mbps;
        let k = policy.code().k();
        let bs = self.block_size as usize;
        let populate = || -> anyhow::Result<MiniCluster> {
            let cluster =
                MiniCluster::new(cspec, policy.clone(), &self.data_backend, scenario.seed)?;
            cluster.write_stripes_parallel(scenario.stripes, self.workers.max(2), |sid| {
                deterministic_data(sid, k, bs)
            })?;
            Ok(cluster)
        };
        let cluster = populate()?;

        if matches!(scenario.kind, ScenarioKind::DegradedBurst { .. }) {
            // pure foreground load: the client engine *is* the scenario —
            // no separate burst loop (DESIGN.md §11); one table serves
            // generation and plan derivation
            let table = PlacementTable::build(policy.clone(), scenario.stripes);
            let (fgspec, reqs) = scenario
                .fg_requests_with(&table)?
                .expect("degraded burst always carries fg traffic");
            let failed = scenario.failed_nodes(policy.as_ref())[0];
            cluster.fail_node(failed);
            let plans = crate::scenario::degraded_read_plans(&table, &reqs, scenario.seed);
            let before = cluster.rack_byte_snapshot();
            let links_before = cluster.links.link_busy_stall();
            let out = crate::client::run_on_cluster(
                &cluster,
                &reqs,
                fgspec.arrival,
                self.workers,
                None,
            )?;
            let after = cluster.rack_byte_snapshot();
            let rack_cross_bytes: Vec<(u64, u64)> = before
                .iter()
                .zip(&after)
                .map(|(&(u0, d0), &(u1, d1))| (u1 - u0, d1 - d0))
                .collect();
            let link_busy_stall = cluster.link_busy_stall_since(&links_before);
            let summary = out.summary();
            let mean = summary.as_ref().map(|s| s.mean).unwrap_or(0.0);
            let loads: Vec<(f64, f64)> = rack_cross_bytes
                .iter()
                .map(|&(u, d)| (u as f64, d as f64))
                .collect();
            let wall = out.seconds;
            let bytes = out.served() as u64 * self.block_size;
            return Ok(ScenarioOutcome {
                backend: "cluster",
                scenario: scenario.name(),
                policy: policy.name().to_string(),
                blocks: out.served(),
                bytes,
                seconds: wall,
                throughput_mb_s: if wall > 0.0 { bytes as f64 / wall / 1e6 } else { 0.0 },
                lambda: crate::sim::recovery::lambda_metric_excluding(
                    &loads,
                    &[failed.rack],
                ),
                rack_cross_bytes,
                planned_cross_rack_blocks: planned_cross_rack_blocks(&plans),
                degraded_read_mean_s: Some(mean),
                frontend_seconds: None,
                worker_utilization: None,
                scratch_pool: None,
                link_busy_stall: Some(link_busy_stall),
                fg_latency: summary,
                recovery_slowdown: None,
            });
        }

        let (failed, plans) = scenario.recovery_plans(policy)?;
        for &f in &failed {
            cluster.fail_node(f);
        }
        let planned = planned_cross_rack_blocks(&plans);
        let racks = distinct_racks(&failed);
        let Some((fgspec, reqs)) = scenario.fg_requests(policy)? else {
            // plain recovery: no foreground traffic, no QoS split
            let stats = cluster.recover_with_plans_cfg(plans, self.exec_cfg(), &racks)?;
            return Ok(cluster_outcome(scenario, policy.name(), &stats, planned, None));
        };

        // mixed load: recovery and the client engine share the links under
        // the scenario's QoS split. The slowdown factor needs the same
        // recovery measured alone, on an identically populated cluster.
        let baseline_s = {
            let isolated = populate()?;
            for &f in &failed {
                isolated.fail_node(f);
            }
            isolated
                .recover_with_plans_cfg(plans.clone(), self.exec_cfg(), &racks)?
                .wall
                .as_secs_f64()
        };
        let (stats, fgout) = cluster.run_mixed_load(
            plans,
            self.exec_cfg(),
            &racks,
            &reqs,
            fgspec.arrival,
            self.workers,
            scenario.qos,
        )?;
        let mut out = cluster_outcome(
            scenario,
            policy.name(),
            &stats,
            planned,
            Some(fgout.seconds),
        );
        out.fg_latency = fgout.summary();
        out.recovery_slowdown = Some(stats.wall.as_secs_f64() / baseline_s.max(1e-9));
        Ok(out)
    }
}

fn cluster_outcome(
    scenario: &crate::scenario::FailureScenario,
    policy_name: &str,
    stats: &ClusterRecoveryStats,
    planned_cross_rack_blocks: usize,
    frontend_seconds: Option<f64>,
) -> crate::scenario::ScenarioOutcome {
    crate::scenario::ScenarioOutcome {
        backend: "cluster",
        scenario: scenario.name(),
        policy: policy_name.to_string(),
        blocks: stats.blocks,
        bytes: stats.bytes,
        seconds: stats.wall.as_secs_f64(),
        throughput_mb_s: stats.throughput_mb_s,
        lambda: stats.lambda,
        rack_cross_bytes: stats.rack_bytes.clone(),
        planned_cross_rack_blocks,
        degraded_read_mean_s: None,
        frontend_seconds,
        worker_utilization: Some(stats.worker_utilization.clone()),
        scratch_pool: Some(stats.scratch),
        link_busy_stall: Some(stats.link_busy_stall.clone()),
        fg_latency: None,
        recovery_slowdown: None,
    }
}

/// Parity rows of the code's generator (encode matrix).
fn parity_matrix(code: &CodeSpec) -> crate::gf::Matrix {
    match *code {
        CodeSpec::Rs { k, m } => crate::codes::RsCode::new(k, m).parity_rows(),
        CodeSpec::Lrc { k, l, g } => crate::codes::LrcCode::new(k, l, g).parity_rows(),
    }
}

/// Coder-pool width for the native backend: one worker per core, capped —
/// encode is CPU-bound GF arithmetic, so wider pools only add contention
/// on the shared request channel. `spawn_pool` pins pjrt to 1 regardless.
fn encode_pool_size() -> usize {
    std::thread::available_parallelism().map_or(1, |n| n.get()).min(8)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::placement::D3Placement;

    fn small_spec() -> SystemSpec {
        let mut s = SystemSpec::paper_default();
        s.block_size = 64 * 1024;
        s.net.inner_mbps = 8000.0; // keep unit tests fast
        s.net.cross_mbps = 1600.0;
        s
    }

    fn data_for(sid: u64, k: usize, len: usize) -> Vec<Vec<u8>> {
        (0..k)
            .map(|b| {
                let mut v = vec![0u8; len];
                let mut s = sid.wrapping_mul(31).wrapping_add(b as u64) | 1;
                for byte in v.iter_mut() {
                    s ^= s << 13;
                    s ^= s >> 7;
                    s ^= s << 17;
                    *byte = (s >> 24) as u8;
                }
                v
            })
            .collect()
    }

    #[test]
    fn write_read_roundtrip() {
        let spec = small_spec();
        let policy =
            Arc::new(D3Placement::new(CodeSpec::Rs { k: 3, m: 2 }, spec.cluster).unwrap());
        let cluster = MiniCluster::new(spec, policy, "native", 7).unwrap();
        let data = data_for(0, 3, 64 * 1024);
        cluster.write_stripe(0, data.clone()).unwrap();
        for (b, want) in data.iter().enumerate() {
            let got = cluster.read_block(0, b, Location::new(7, 0)).unwrap();
            assert_eq!(&got, want);
        }
    }

    #[test]
    fn degraded_read_rebuilds_correct_bytes() {
        let spec = small_spec();
        let policy =
            Arc::new(D3Placement::new(CodeSpec::Rs { k: 3, m: 2 }, spec.cluster).unwrap());
        let cluster = MiniCluster::new(spec, policy, "native", 7).unwrap();
        let data = data_for(5, 3, 64 * 1024);
        cluster.write_stripe(5, data.clone()).unwrap();
        let victim = cluster.locate(5, 1);
        cluster.fail_node(victim);
        let (got, latency) = cluster.degraded_read(5, 1, Location::new(6, 2)).unwrap();
        assert_eq!(got, data[1]);
        assert!(latency.as_secs_f64() > 0.0);
    }

    #[test]
    fn node_recovery_rebuilds_every_block() {
        let spec = small_spec();
        let policy =
            Arc::new(D3Placement::new(CodeSpec::Rs { k: 2, m: 1 }, spec.cluster).unwrap());
        let cluster = MiniCluster::new(spec, policy, "native", 3).unwrap();
        let stripes = 24u64;
        let mut originals = Vec::new();
        for sid in 0..stripes {
            let data = data_for(sid, 2, 64 * 1024);
            cluster.write_stripe(sid, data.clone()).unwrap();
            originals.push(data);
        }
        let failed = Location::new(1, 1);
        let lost: Vec<(u64, usize)> = (0..stripes)
            .flat_map(|sid| {
                cluster
                    .policy()
                    .stripe(sid)
                    .locs
                    .iter()
                    .enumerate()
                    .filter(|(_, &l)| l == failed)
                    .map(|(b, _)| (sid, b))
                    .collect::<Vec<_>>()
            })
            .collect();
        cluster.fail_node(failed);
        let stats = cluster.recover_node(failed, stripes, 8).unwrap();
        assert_eq!(stats.blocks, lost.len());
        assert!(stats.throughput_mb_s > 0.0);
        // every lost block must be readable again with the right content
        let client = Location::new(0, 0);
        for (sid, b) in lost {
            let got = cluster.read_block(sid, b, client).unwrap();
            if b < 2 {
                assert_eq!(got, originals[sid as usize][b], "sid={sid} b={b}");
            }
            let newloc = cluster.locate(sid, b);
            assert_ne!(newloc, failed);
        }
    }

    #[test]
    fn chunked_recovery_rebuilds_identical_bytes() {
        // chunk < block exercises the multi-task assembly path end to end
        let spec = small_spec();
        let policy =
            Arc::new(D3Placement::new(CodeSpec::Rs { k: 3, m: 2 }, spec.cluster).unwrap());
        let cluster = MiniCluster::new(spec, policy.clone(), "native", 9).unwrap();
        let stripes = 12u64;
        let mut originals = Vec::new();
        for sid in 0..stripes {
            let data = data_for(sid, 3, 64 * 1024);
            cluster.write_stripe(sid, data.clone()).unwrap();
            originals.push(data);
        }
        let failed = Location::new(3, 0);
        cluster.fail_node(failed);
        let plans = crate::recovery::node_recovery_plans(
            policy.as_ref(),
            stripes,
            failed,
            9,
        );
        let lost: Vec<(u64, usize)> =
            plans.iter().map(|p| (p.stripe, p.failed_block)).collect();
        let cfg = ExecutorConfig {
            workers: 4,
            chunk_size: 4096, // 16 chunks per 64 KiB block
            ..ExecutorConfig::default()
        };
        let stats = cluster.recover_with_plans_cfg(plans, cfg, &[failed.rack]).unwrap();
        assert_eq!(stats.blocks, lost.len());
        assert_eq!(stats.chunks, lost.len() * 16);
        assert_eq!(stats.worker_utilization.len(), 4);
        for (sid, b) in lost {
            let loc = cluster.locate(sid, b);
            assert_ne!(loc, failed);
            let got = cluster.read_block(sid, b, loc).unwrap();
            if b < 3 {
                assert_eq!(got, originals[sid as usize][b], "sid={sid} b={b}");
            }
        }
    }

    #[test]
    fn recovery_respects_rack_limits() {
        let spec = small_spec();
        let policy =
            Arc::new(D3Placement::new(CodeSpec::Rs { k: 3, m: 2 }, spec.cluster).unwrap());
        let cluster = MiniCluster::new(spec, policy, "native", 1).unwrap();
        let stripes = 18u64;
        for sid in 0..stripes {
            cluster.write_stripe(sid, data_for(sid, 3, 64 * 1024)).unwrap();
        }
        let failed = Location::new(0, 0);
        cluster.fail_node(failed);
        cluster.recover_node(failed, stripes, 4).unwrap();
        for sid in 0..stripes {
            let mut per_rack: HashMap<u32, usize> = HashMap::new();
            for b in 0..5 {
                let loc = cluster.locate(sid, b);
                *per_rack.entry(loc.rack).or_default() += 1;
            }
            assert!(per_rack.values().all(|&c| c <= 2), "sid={sid}: {per_rack:?}");
        }
    }
}
