//! Mini-HDFS: an in-process erasure-coded storage cluster with a *real*
//! data path — real bytes, real GF(2^8) coding through the PJRT artifacts
//! (or the native fallback), real concurrent transfers throttled to the
//! paper's bandwidth hierarchy by token buckets.
//!
//! This is the substitution for the 28-machine Hadoop testbed (DESIGN.md
//! §2): one thread pool plays the DataNodes, [`links::LinkSet`] plays the
//! switches, and the NameNode role (metadata + recovery orchestration)
//! lives in [`MiniCluster`]. The discrete-event simulator answers the
//! paper's parameter sweeps; this cluster proves the layers compose.

pub mod cache;
pub mod fabric;
pub mod links;
pub mod service;
pub mod store;

use std::collections::HashMap;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex, RwLock};
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Context};

use crate::client::QosConfig;
use crate::codes::CodeSpec;
use crate::metrics::PoolStats;
use crate::placement::Placement;
use crate::recovery::executor::ExecutorConfig;
use crate::recovery::migration::MigrationBatch;
use crate::recovery::plan::{plan_coefficients, plan_degraded_read, plan_repair, RepairPlan};
use crate::recovery::schedule::SchedulePolicy;
use crate::topology::{Location, SystemSpec};

pub use cache::{CacheStats, HotBlockCache};
pub use fabric::BlockFabric;
use links::{LinkSet, TrafficClass};
use service::CoderService;
pub use store::{
    BlockKey, BlockStore, ChecksumRegistry, ChunkError, MaterializedStore, SyntheticStore,
};

/// Relocation-table shards (block map overrides after recovery): keyed by
/// block, so the executor's persist path and the NameNode's lookups only
/// collide when they touch the same key neighborhood.
const RELOC_SHARDS: usize = 64;

#[inline]
fn reloc_shard(key: BlockKey) -> usize {
    let h = key.0.wrapping_mul(0x9e3779b97f4a7c15) ^ (key.1 as u64).wrapping_mul(31);
    (h as usize) & (RELOC_SHARDS - 1)
}

/// Outcome of [`MiniCluster::recover_node`].
#[derive(Clone, Debug)]
pub struct ClusterRecoveryStats {
    pub blocks: usize,
    pub bytes: u64,
    pub wall: Duration,
    pub throughput_mb_s: f64,
    /// cross-rack bytes per rack (up, down)
    pub rack_bytes: Vec<(u64, u64)>,
    pub lambda: f64,
    /// Chunk tasks executed by the pipelined executor.
    pub chunks: usize,
    /// Admission rounds of the schedule (1 for FIFO).
    pub rounds: usize,
    /// Per-worker busy fraction of the recovery wall clock.
    pub worker_utilization: Vec<f64>,
    /// Scratch-pool hit/miss totals of the executor's worker pools.
    pub scratch: PoolStats,
    /// Per-rack-link (busy, stall) seconds during this recovery
    /// ([`links::LinkSet::link_busy_stall`]).
    pub link_busy_stall: Vec<(f64, f64)>,
}

/// The in-process cluster.
pub struct MiniCluster {
    spec: SystemSpec,
    policy: Arc<dyn Placement>,
    links: Arc<LinkSet>,
    coder: CoderService,
    /// Parity rows of the policy's code, computed once at construction —
    /// every stripe encode reuses them instead of rebuilding the
    /// generator matrix per stripe.
    parity_rows: crate::gf::Matrix,
    /// Block payload storage behind the [`BlockStore`] trait (DESIGN.md
    /// §16): materialized per-node maps, or the synthetic
    /// regenerate-on-read store for at-scale runs.
    store: Box<dyn BlockStore>,
    /// Metadata overrides after recovery (NameNode block map), sharded by
    /// block key; `relocated_count` mirrors the total entry count so the
    /// common no-override lookup is a single relaxed atomic load.
    relocated: Vec<Mutex<HashMap<BlockKey, Location>>>,
    relocated_count: AtomicUsize,
    failed: Mutex<Vec<Location>>,
    /// Write-time checksum registry (first write wins): the scrub pass's
    /// oracle for detecting silent replica corruption (DESIGN.md §14).
    /// Sharded — 8-writer ingest used to serialize on one global mutex.
    checksums: ChecksumRegistry,
    /// Optional hot-block read cache tier (DESIGN.md §16): a hit serves
    /// client reads without touching the store or the modeled links.
    cache: Option<Arc<HotBlockCache>>,
    /// cross-rack traffic accounting (up, down) per rack
    rack_up: Vec<AtomicU64>,
    rack_down: Vec<AtomicU64>,
    /// Transfers hold this as readers while bumping their (up, down) pair;
    /// [`MiniCluster::rack_byte_snapshot`] takes it as writer, so a
    /// snapshot can never observe a transfer's up-count without its
    /// down-count under the multi-threaded executor.
    accounting: RwLock<()>,
    /// Mixed-load QoS runtime (DESIGN.md §11): the active split and the
    /// foreground-activity flag the client engine toggles.
    qos: Mutex<Option<QosRuntime>>,
    /// Lock-free mirror of `qos.is_some()`: the per-chunk throttle hook
    /// checks this first, so plain recovery never touches the mutex.
    qos_on: AtomicBool,
    seed: u64,
}

/// The QoS parameters in force during a mixed-load run.
#[derive(Clone)]
struct QosRuntime {
    cfg: QosConfig,
    fg_active: Arc<AtomicBool>,
}

impl MiniCluster {
    /// `backend`: "native" or "pjrt". Blocks live in the materialized
    /// per-node store — the original representation.
    pub fn new(
        spec: SystemSpec,
        policy: Arc<dyn Placement>,
        backend: &str,
        seed: u64,
    ) -> anyhow::Result<MiniCluster> {
        let store = Box::new(MaterializedStore::new(spec.cluster.node_count()));
        MiniCluster::with_store(spec, policy, backend, seed, store)
    }

    /// [`MiniCluster::new`] on the synthetic regenerate-on-read store
    /// (DESIGN.md §16): payloads are derived from the canonical populate
    /// generator plus the code's parity rows, so resident memory is
    /// O(metadata). Pair with [`MiniCluster::populate_synthetic`] instead
    /// of writing stripes.
    pub fn new_synthetic(
        spec: SystemSpec,
        policy: Arc<dyn Placement>,
        backend: &str,
        seed: u64,
    ) -> anyhow::Result<MiniCluster> {
        let code = policy.code();
        let store = Box::new(SyntheticStore::new(
            spec.cluster.node_count(),
            code.k(),
            code.len(),
            spec.block_size as usize,
            parity_matrix(&code),
        ));
        MiniCluster::with_store(spec, policy, backend, seed, store)
    }

    /// Construct on an explicit [`BlockStore`] implementation.
    pub fn with_store(
        spec: SystemSpec,
        policy: Arc<dyn Placement>,
        backend: &str,
        seed: u64,
        store: Box<dyn BlockStore>,
    ) -> anyhow::Result<MiniCluster> {
        assert_eq!(policy.cluster(), spec.cluster, "policy/topology mismatch");
        let coder = CoderService::spawn_pool(backend, encode_pool_size())?;
        let parity_rows = parity_matrix(&policy.code());
        Ok(MiniCluster {
            links: Arc::new(LinkSet::new(&spec)),
            store,
            relocated: (0..RELOC_SHARDS).map(|_| Mutex::new(HashMap::new())).collect(),
            relocated_count: AtomicUsize::new(0),
            failed: Mutex::new(Vec::new()),
            checksums: ChecksumRegistry::new(),
            cache: None,
            rack_up: (0..spec.cluster.racks).map(|_| AtomicU64::new(0)).collect(),
            rack_down: (0..spec.cluster.racks).map(|_| AtomicU64::new(0)).collect(),
            accounting: RwLock::new(()),
            qos: Mutex::new(None),
            qos_on: AtomicBool::new(false),
            spec,
            policy,
            coder,
            parity_rows,
            seed,
        })
    }

    /// Adopt `stripes` canonically-placed stripes without materializing a
    /// byte — the synthetic store's populate path. No modeled transfers
    /// run (the scenario runner diffs its byte counters *after* populate,
    /// so accounting parity with the written-out path holds) and the
    /// checksum registry stays empty: the write-time oracle is derivable
    /// on demand ([`BlockStore::baseline_checksum`]).
    pub fn populate_synthetic(&self, stripes: u64) -> anyhow::Result<()> {
        if !self.store.populate(stripes) {
            bail!("this store materializes payloads — write stripes instead");
        }
        Ok(())
    }

    /// Install a hot-block read cache tier of `capacity_bytes` (DESIGN.md
    /// §16). Off by default; a cache changes *latency*, never bytes-on-
    /// disk correctness.
    pub fn set_cache(&mut self, capacity_bytes: u64) {
        self.cache = Some(Arc::new(HotBlockCache::new(capacity_bytes)));
    }

    /// Counters of the installed cache tier, if any.
    pub fn cache_stats(&self) -> Option<CacheStats> {
        self.cache.as_ref().map(|c| c.stats())
    }

    pub fn spec(&self) -> &SystemSpec {
        &self.spec
    }

    pub fn policy(&self) -> &dyn Placement {
        self.policy.as_ref()
    }

    /// Current location of a block (NameNode metadata).
    pub fn locate(&self, sid: u64, block: usize) -> Location {
        self.locate_flat(sid, block).0
    }

    /// One-pass metadata lookup for the chunk hot path: location and flat
    /// node index together, so store access never re-derives
    /// `cluster.flat(loc)` (or worse, a full stripe placement) per call.
    /// When no block has ever been relocated the override check is a
    /// single relaxed load — no lock.
    fn locate_flat(&self, sid: u64, block: usize) -> (Location, usize) {
        let key = (sid, block);
        if self.relocated_count.load(Ordering::Relaxed) > 0 {
            if let Some(&loc) = self.relocated[reloc_shard(key)].lock().unwrap().get(&key) {
                return (loc, self.spec.cluster.flat(loc));
            }
        }
        let loc = self.policy.block_at(sid, block);
        (loc, self.spec.cluster.flat(loc))
    }

    /// Point the block map's override for `key` at `loc`.
    fn set_relocation(&self, key: BlockKey, loc: Location) {
        let prev = self.relocated[reloc_shard(key)].lock().unwrap().insert(key, loc);
        if prev.is_none() {
            self.relocated_count.fetch_add(1, Ordering::Relaxed);
        }
    }

    /// Drop the override for `key` (the block is home).
    fn clear_relocation(&self, key: BlockKey) {
        let prev = self.relocated[reloc_shard(key)].lock().unwrap().remove(&key);
        if prev.is_some() {
            self.relocated_count.fetch_sub(1, Ordering::Relaxed);
        }
    }

    fn transfer(&self, src: Location, dst: Location, bytes: u64, class: TrafficClass) {
        if src.rack != dst.rack {
            let _pairwise = self.accounting.read().unwrap();
            self.rack_up[src.rack as usize].fetch_add(bytes, Ordering::Relaxed);
            self.rack_down[dst.rack as usize].fetch_add(bytes, Ordering::Relaxed);
        }
        self.links.transfer_class(src, dst, bytes, class);
    }

    /// Batched inbound transfer (recovery-class): account every flow's
    /// cross-rack bytes under one pairwise-consistency hold, then move the
    /// whole group through the links under a single ordered gate
    /// acquisition ([`links::LinkSet::transfer_batch`]) — the
    /// fetch-coalescing path.
    fn transfer_group(&self, to: Location, flows: &[(Location, u64)]) {
        {
            let _pairwise = self.accounting.read().unwrap();
            for &(src, bytes) in flows {
                if src.rack != to.rack && bytes > 0 {
                    self.rack_up[src.rack as usize].fetch_add(bytes, Ordering::Relaxed);
                    self.rack_down[to.rack as usize].fetch_add(bytes, Ordering::Relaxed);
                }
            }
        }
        self.links.transfer_batch(to, flows, TrafficClass::Recovery);
    }

    /// Install a QoS split for a mixed-load run (DESIGN.md §11): recovery
    /// traffic is capped at `cfg.recovery_share` of every port while
    /// `fg_active` holds true, and the executor's throttle hook paces
    /// recovery workers by `cfg.fg_weight`. [`MiniCluster::clear_qos`]
    /// restores the unsplit data path.
    pub fn set_qos(&self, cfg: QosConfig, fg_active: Arc<AtomicBool>) {
        self.links.set_qos(cfg.recovery_share, fg_active.clone());
        *self.qos.lock().unwrap() = Some(QosRuntime { cfg, fg_active });
        self.qos_on.store(true, Ordering::Relaxed);
    }

    /// Remove the QoS split.
    pub fn clear_qos(&self) {
        self.links.clear_qos();
        *self.qos.lock().unwrap() = None;
        self.qos_on.store(false, Ordering::Relaxed);
    }

    /// The recovery executor's pacing hook ([`ChunkRunner::throttle`]):
    /// after a chunk that kept a worker busy for `busy_s`, yield
    /// `busy_s × fg_weight × (1/recovery_share − 1)` seconds while
    /// foreground load is active, so recovery's *compute admission* backs
    /// off in the same proportion as its link share. Each yield is capped
    /// at 50 ms so a slow chunk cannot park a worker for seconds — the
    /// link-level bucket split ([`links::LinkSet::set_qos`]) remains the
    /// bandwidth guarantee; this hook only adds admission back-pressure.
    fn qos_pace(&self, busy_s: f64) {
        if !self.qos_on.load(Ordering::Relaxed) {
            return;
        }
        let rt = self.qos.lock().unwrap().clone();
        let Some(rt) = rt else { return };
        if !rt.cfg.is_active()
            || rt.cfg.fg_weight <= 0.0
            || !rt.fg_active.load(Ordering::Relaxed)
        {
            return;
        }
        let share = rt.cfg.recovery_share;
        let pause = busy_s * rt.cfg.fg_weight * (1.0 / share - 1.0);
        if pause > 0.0 {
            std::thread::sleep(Duration::from_secs_f64(pause.min(0.05)));
        }
    }

    /// Client write path: encode `data` (k shards) and distribute the
    /// stripe per the placement policy. The client is modeled at the
    /// location of block 0 (HDFS writes the first replica locally).
    ///
    /// Takes the data shards by value: they are moved through the coder
    /// service (one `Encode` round trip computes every parity row) and
    /// then moved into the node stores — ingest copies **zero** blocks.
    /// Callers that need the bytes afterwards clone at the call site or
    /// regenerate from their deterministic generator.
    pub fn write_stripe(&self, sid: u64, data: Vec<Vec<u8>>) -> anyhow::Result<()> {
        self.write_stripe_inner(sid, data, None)
    }

    /// [`MiniCluster::write_stripe`] with an explicit issuing client — the
    /// client engine's write path (DESIGN.md §11). Encode and every block
    /// distribution are charged to `client`, exactly as the fluid backend
    /// models the same request, so cross-backend byte accounting agrees.
    pub fn write_stripe_from(
        &self,
        sid: u64,
        data: Vec<Vec<u8>>,
        client: Location,
    ) -> anyhow::Result<()> {
        self.write_stripe_inner(sid, data, Some(client))
    }

    /// Shared write path: one placement derivation per stripe; `client`
    /// defaults to the first replica's node (HDFS write-local). Replicas
    /// whose placement lands on a failed node are skipped (a dead
    /// DataNode cannot accept data; [`crate::client::request_job`] drops
    /// the same flows), leaving the stripe degraded until recovery.
    fn write_stripe_inner(
        &self,
        sid: u64,
        data: Vec<Vec<u8>>,
        client: Option<Location>,
    ) -> anyhow::Result<()> {
        let code = self.policy.code();
        if data.len() != code.k() {
            bail!("expected {} data shards, got {}", code.k(), data.len());
        }
        let (data, parity) =
            self.coder.encode(self.parity_rows.clone(), data).context("encode")?;
        let sp = self.policy.stripe(sid);
        let client = client.unwrap_or(sp.locs[0]);
        let failed = self.failed.lock().unwrap().clone();
        for (bi, bytes) in data.into_iter().chain(parity).enumerate() {
            let dst = sp.locs[bi];
            // register the checksum even when the replica is skipped —
            // it is the oracle the eventual recovery is verified against
            self.checksums.insert((sid, bi), crate::net::proto::checksum(&bytes));
            if failed.contains(&dst) {
                continue;
            }
            self.transfer(client, dst, bytes.len() as u64, TrafficClass::Foreground);
            if let Some(cache) = &self.cache {
                // a rewrite must never leave stale payloads servable
                cache.invalidate((sid, bi));
            }
            self.store.insert(self.spec.cluster.flat(dst), (sid, bi), bytes);
        }
        Ok(())
    }

    /// Write many stripes concurrently (`workers` client threads) using a
    /// data generator. Each generated stripe is moved straight into the
    /// cluster; callers that verify afterwards re-invoke their (by
    /// contract deterministic) generator instead of keeping a copy here.
    pub fn write_stripes_parallel(
        &self,
        stripes: u64,
        workers: usize,
        gen: impl Fn(u64) -> Vec<Vec<u8>> + Sync,
    ) -> anyhow::Result<()> {
        let next = std::sync::atomic::AtomicU64::new(0);
        let errors: Mutex<Vec<String>> = Mutex::new(Vec::new());
        std::thread::scope(|scope| {
            for _ in 0..workers.max(1) {
                scope.spawn(|| loop {
                    let sid = next.fetch_add(1, Ordering::Relaxed);
                    if sid >= stripes {
                        break;
                    }
                    if let Err(e) = self.write_stripe(sid, gen(sid)) {
                        errors.lock().unwrap().push(e.to_string());
                        break;
                    }
                });
            }
        });
        let errs = errors.into_inner().unwrap();
        if !errs.is_empty() {
            bail!("write errors: {}", errs.join("; "));
        }
        Ok(())
    }

    /// Plain read of a healthy block at `client`. A cache-tier hit serves
    /// the payload without touching the store *or* the modeled links —
    /// the client already holds the bytes in local memory.
    pub fn read_block(&self, sid: u64, block: usize, client: Location) -> anyhow::Result<Vec<u8>> {
        if let Some(cache) = &self.cache {
            if let Some(data) = cache.get((sid, block)) {
                return Ok(data);
            }
        }
        let (loc, at) = self.locate_flat(sid, block);
        if self.failed.lock().unwrap().contains(&loc) {
            bail!("block ({sid},{block}) is on failed node {loc} — use degraded_read");
        }
        let data = self
            .store
            .read(at, (sid, block))
            .ok_or_else(|| anyhow!("block ({sid},{block}) missing at {loc}"))?;
        self.transfer(loc, client, data.len() as u64, TrafficClass::Foreground);
        if let Some(cache) = &self.cache {
            cache.admit((sid, block), &data);
        }
        Ok(data)
    }

    /// Kill a node: erase its storage (recovery must rebuild from peers).
    pub fn fail_node(&self, loc: Location) {
        self.failed.lock().unwrap().push(loc);
        self.store.clear_node(self.spec.cluster.flat(loc));
    }

    fn fetch(&self, sid: u64, block: usize, to: Location) -> anyhow::Result<Vec<u8>> {
        let (loc, at) = self.locate_flat(sid, block);
        let data = self
            .store
            .read(at, (sid, block))
            .ok_or_else(|| anyhow!("source block ({sid},{block}) missing at {loc}"))?;
        self.transfer(loc, to, data.len() as u64, TrafficClass::Foreground);
        Ok(data)
    }

    /// Disk half of a chunk fetch: copy bytes `[off, off + len)` of a
    /// source block into `buf` (cleared first) and return where the
    /// block lives. The caller owes the network a matching transfer —
    /// either per chunk or batched per window
    /// ([`MiniCluster::transfer_group`]).
    fn read_chunk_into(
        &self,
        sid: u64,
        block: usize,
        off: u64,
        len: usize,
        buf: &mut Vec<u8>,
    ) -> anyhow::Result<Location> {
        let (loc, at) = self.locate_flat(sid, block);
        let off = off as usize;
        match self.store.read_chunk(at, (sid, block), off, len, buf) {
            Ok(()) => Ok(loc),
            Err(ChunkError::Missing) => {
                Err(anyhow!("source block ({sid},{block}) missing at {loc}"))
            }
            Err(ChunkError::OutOfRange { have }) => Err(anyhow!(
                "chunk [{off}, {}) out of range for block ({sid},{block}) of {have} bytes",
                off + len,
            )),
        }
    }

    /// Execute one repair plan: inner-rack aggregation (D³) or direct
    /// fetches (RDD/LRC), final combine, optional store.
    fn execute_plan(&self, plan: &RepairPlan) -> anyhow::Result<Vec<u8>> {
        let code = self.policy.code();
        let sources = plan.source_blocks();
        let coeffs = plan_coefficients(&code, plan);
        let coeff_of = |b: usize| -> u8 {
            coeffs[sources.binary_search(&b).expect("source present")]
        };
        // All fetches run concurrently (HDFS striped reads are parallel);
        // scoped threads because transfers block on the token buckets.
        // §Perf: serial fetches made degraded reads latency-bound on the
        // slowest sequential chain instead of the slowest link.
        let mut final_coeffs: Vec<u8> = Vec::new();
        let mut final_shards: Vec<Vec<u8>> = Vec::new();
        let (agg_results, direct_results) = std::thread::scope(|scope| {
            let agg_handles: Vec<_> = plan
                .aggregations
                .iter()
                .map(|agg| {
                    scope.spawn(move || -> anyhow::Result<Vec<u8>> {
                        let fetch_handles: Vec<_> = std::thread::scope(|inner| {
                            agg.inputs
                                .iter()
                                .map(|&(b, _)| {
                                    inner.spawn(move || self.fetch(plan.stripe, b, agg.at))
                                })
                                .collect::<Vec<_>>()
                                .into_iter()
                                .map(|h| h.join().expect("fetch thread"))
                                .collect()
                        });
                        let mut c = Vec::with_capacity(agg.inputs.len());
                        let mut shards = Vec::with_capacity(agg.inputs.len());
                        for (res, &(b, _)) in fetch_handles.into_iter().zip(&agg.inputs) {
                            shards.push(res?);
                            c.push(coeff_of(b));
                        }
                        let partial = self.coder.combine(c, shards)?;
                        // ship ONE aggregated block to the compute node
                        self.transfer(
                            agg.at,
                            plan.compute_at,
                            partial.len() as u64,
                            TrafficClass::Foreground,
                        );
                        Ok(partial)
                    })
                })
                .collect();
            let direct_handles: Vec<_> = plan
                .direct
                .iter()
                .map(|&(b, _)| scope.spawn(move || self.fetch(plan.stripe, b, plan.compute_at)))
                .collect();
            (
                agg_handles.into_iter().map(|h| h.join().expect("agg thread")).collect::<Vec<_>>(),
                direct_handles
                    .into_iter()
                    .map(|h| h.join().expect("direct thread"))
                    .collect::<Vec<_>>(),
            )
        });
        for res in agg_results {
            final_shards.push(res?);
            final_coeffs.push(1);
        }
        for (res, &(b, _)) in direct_results.into_iter().zip(&plan.direct) {
            final_shards.push(res?);
            final_coeffs.push(coeff_of(b));
        }
        let rebuilt = self.coder.combine(final_coeffs, final_shards)?;
        if plan.persist {
            let key = (plan.stripe, plan.failed_block);
            self.store.insert(self.spec.cluster.flat(plan.writer), key, rebuilt.clone());
            self.set_relocation(key, plan.writer);
        }
        Ok(rebuilt)
    }

    /// Degraded read: rebuild `(sid, block)` at `client` (paper Exp 3). A
    /// cache-tier hit short-circuits the whole rebuild — no source
    /// fetches, no combine, no modeled transfers — which is how the hot
    /// tail of a Zipf-skewed degraded burst stops paying the k-fetch
    /// latency on every repeat access.
    pub fn degraded_read(
        &self,
        sid: u64,
        block: usize,
        client: Location,
    ) -> anyhow::Result<(Vec<u8>, Duration)> {
        let t0 = Instant::now();
        if let Some(cache) = &self.cache {
            if let Some(data) = cache.get((sid, block)) {
                return Ok((data, t0.elapsed()));
            }
        }
        let plan = plan_degraded_read(self.policy.as_ref(), sid, block, client, self.seed);
        let data = self.execute_plan(&plan)?;
        if let Some(cache) = &self.cache {
            cache.admit((sid, block), &data);
        }
        Ok((data, t0.elapsed()))
    }

    /// Full-node recovery with `workers` concurrent reconstruction tasks.
    pub fn recover_node(
        &self,
        failed: Location,
        stripes: u64,
        workers: usize,
    ) -> anyhow::Result<ClusterRecoveryStats> {
        let mut plans = Vec::new();
        for sid in 0..stripes {
            let sp = self.policy.stripe(sid);
            for (bi, &loc) in sp.locs.iter().enumerate() {
                if loc == failed {
                    plans.push(plan_repair(self.policy.as_ref(), sid, bi, self.seed));
                }
            }
        }
        self.recover_with_plans(plans, workers, &[failed.rack])
    }

    /// Execute an arbitrary plan set (the scenario engine's entry point —
    /// single node, K nodes, a whole rack) with `workers` concurrent
    /// reconstruction tasks at the default chunking/caps. λ is computed
    /// over the racks not in `failed_racks`; traffic accounting covers
    /// exactly this recovery.
    pub fn recover_with_plans(
        &self,
        plans: Vec<RepairPlan>,
        workers: usize,
        failed_racks: &[u32],
    ) -> anyhow::Result<ClusterRecoveryStats> {
        self.recover_with_plans_cfg(
            plans,
            ExecutorConfig { workers, ..ExecutorConfig::default() },
            failed_racks,
        )
    }

    /// [`MiniCluster::recover_with_plans`] with full control of the
    /// pipelined executor (DESIGN.md §8): plans are split into
    /// `cfg.chunk_size` tasks, scheduled over `cfg.workers` threads, and
    /// every transfer runs under the per-node / per-rack-link in-flight
    /// caps.
    pub fn recover_with_plans_cfg(
        &self,
        plans: Vec<RepairPlan>,
        cfg: ExecutorConfig,
        failed_racks: &[u32],
    ) -> anyhow::Result<ClusterRecoveryStats> {
        fabric::recover_with_plans_cfg(self, plans, cfg, failed_racks)
    }

    /// Execute §5.3 layout-maintenance migration batches against the real
    /// stores (see [`fabric::run_migration`]); per-batch wall seconds,
    /// index-aligned with [`crate::sim::recovery::run_migration`].
    pub fn run_migration(
        &self,
        batches: &[MigrationBatch],
        relived: Location,
    ) -> anyhow::Result<Vec<f64>> {
        fabric::run_migration(self, batches, relived)
    }

    /// Bring a failed node back as an empty replacement machine at the
    /// same location (the §5.3 "relived" node migration restores onto).
    pub fn relive_node(&self, loc: Location) {
        self.failed.lock().unwrap().retain(|&f| f != loc);
    }

    /// A replacement machine joins at `loc` and the NameNode rebalances:
    /// every block whose *canonical* placement is `loc` but which
    /// recovery parked elsewhere is moved back (recovery-class traffic),
    /// dropping its relocation override — the trait-level twin of
    /// [`crate::net::NetCluster::join`]. Returns the blocks moved home.
    pub fn rejoin_node(&self, loc: Location) -> anyhow::Result<usize> {
        self.relive_node(loc);
        let mut moves: Vec<(BlockKey, Location)> = Vec::new();
        for shard in &self.relocated {
            let guard = shard.lock().unwrap();
            for (&(sid, block), &cur) in guard.iter() {
                if cur != loc && self.policy.block_at(sid, block) == loc {
                    moves.push(((sid, block), cur));
                }
            }
        }
        moves.sort_unstable_by_key(|&(key, _)| key);
        for &((sid, block), from) in &moves {
            let from_at = self.spec.cluster.flat(from);
            let bytes = self
                .store
                .read(from_at, (sid, block))
                .ok_or_else(|| anyhow!("relocated block ({sid},{block}) missing at {from}"))?;
            self.transfer(from, loc, bytes.len() as u64, TrafficClass::Recovery);
            BlockFabric::persist_block(self, sid, block, loc, bytes)?;
            self.store.remove(from_at, (sid, block));
        }
        Ok(moves.len())
    }

    /// Run recovery and a foreground request sequence concurrently under
    /// `qos` (DESIGN.md §11): install the split, drive the client engine
    /// beside the recovery executor, remove the split afterwards. The ONE
    /// mixed-load orchestration, shared by the scenario backend and the
    /// perf harness — the fg-activity flag's lifecycle lives here.
    #[allow(clippy::too_many_arguments)]
    pub fn run_mixed_load(
        &self,
        plans: Vec<RepairPlan>,
        cfg: ExecutorConfig,
        failed_racks: &[u32],
        reqs: &[crate::client::Request],
        arrival: crate::client::ArrivalModel,
        fg_workers: usize,
        qos: QosConfig,
    ) -> anyhow::Result<(ClusterRecoveryStats, crate::client::FgOutcome)> {
        fabric::run_mixed_load(self, plans, cfg, failed_racks, reqs, arrival, fg_workers, qos)
    }

    /// Blocks currently stored on `loc` (for the synthetic store: resident
    /// overlay entries — the implicit base population is not enumerated).
    pub fn block_count(&self, loc: Location) -> usize {
        self.store.len(self.spec.cluster.flat(loc))
    }

    /// Snapshot of the per-rack cross-rack byte counters (up, down) —
    /// callers diff two snapshots to attribute traffic to a phase. Takes
    /// the accounting lock as writer so no in-flight transfer's (up, down)
    /// pair is observed half-applied.
    pub fn rack_byte_snapshot(&self) -> Vec<(u64, u64)> {
        let _barrier = self.accounting.write().unwrap();
        (0..self.spec.cluster.racks)
            .map(|r| {
                (
                    self.rack_up[r].load(Ordering::Relaxed),
                    self.rack_down[r].load(Ordering::Relaxed),
                )
            })
            .collect()
    }
}

/// The in-process data plane behind the shared orchestration layers
/// (DESIGN.md §13): blocks live in per-node hash maps, every modeled
/// transfer is charged through the token-bucket links and rack counters.
impl BlockFabric for MiniCluster {
    fn code(&self) -> CodeSpec {
        self.policy.code()
    }

    fn period(&self) -> Option<u64> {
        self.policy.period()
    }

    fn block_size(&self) -> u64 {
        self.spec.block_size
    }

    fn links(&self) -> &LinkSet {
        &self.links
    }

    fn locate(&self, sid: u64, block: usize) -> Location {
        MiniCluster::locate(self, sid, block)
    }

    fn read_chunk(
        &self,
        sid: u64,
        block: usize,
        off: u64,
        len: usize,
        buf: &mut Vec<u8>,
    ) -> anyhow::Result<Location> {
        self.read_chunk_into(sid, block, off, len, buf)
    }

    fn persist_block(
        &self,
        sid: u64,
        block: usize,
        at: Location,
        bytes: Vec<u8>,
    ) -> anyhow::Result<()> {
        let sum = crate::net::proto::checksum(&bytes);
        self.store.insert(self.spec.cluster.flat(at), (sid, block), bytes);
        if self.policy.block_at(sid, block) == at {
            self.clear_relocation((sid, block));
        } else {
            self.set_relocation((sid, block), at);
        }
        // first write wins: a recovered block must reproduce the bytes
        // the original write registered, never redefine them
        self.checksums.or_insert((sid, block), sum);
        Ok(())
    }

    fn remove_block(&self, sid: u64, block: usize, at: Location) -> anyhow::Result<()> {
        self.store.remove(self.spec.cluster.flat(at), (sid, block));
        Ok(())
    }

    fn transfer(&self, src: Location, dst: Location, bytes: u64, class: TrafficClass) {
        MiniCluster::transfer(self, src, dst, bytes, class);
    }

    fn transfer_group(&self, to: Location, flows: &[(Location, u64)]) {
        MiniCluster::transfer_group(self, to, flows);
    }

    fn rack_byte_snapshot(&self) -> Vec<(u64, u64)> {
        MiniCluster::rack_byte_snapshot(self)
    }

    fn fail_node(&self, loc: Location) {
        MiniCluster::fail_node(self, loc);
    }

    fn failed_nodes(&self) -> Vec<Location> {
        self.failed.lock().unwrap().clone()
    }

    fn mark_failed(&self, loc: Location) {
        let mut failed = self.failed.lock().unwrap();
        if !failed.contains(&loc) {
            failed.push(loc);
        }
    }

    fn stored_checksum(&self, sid: u64, block: usize) -> anyhow::Result<u64> {
        let (loc, at) = self.locate_flat(sid, block);
        self.store
            .stored_checksum(at, (sid, block))
            .ok_or_else(|| anyhow!("block ({sid},{block}) missing at {loc}"))
    }

    fn expected_checksum(&self, sid: u64, block: usize) -> Option<u64> {
        // the registry wins; the synthetic store derives the write-time
        // oracle for its unregistered base population on demand
        self.checksums
            .get((sid, block))
            .or_else(|| self.store.baseline_checksum((sid, block)))
    }

    fn corrupt_stored(&self, sid: u64, block: usize) -> anyhow::Result<()> {
        let (loc, at) = self.locate_flat(sid, block);
        if let Some(cache) = &self.cache {
            // never serve bytes the store just disowned
            cache.invalidate((sid, block));
        }
        self.store
            .corrupt(at, (sid, block))
            .map_err(|e| anyhow!("{e} at {loc}"))
    }

    fn rejoin_node(&self, loc: Location) -> anyhow::Result<usize> {
        MiniCluster::rejoin_node(self, loc)
    }

    fn set_qos(&self, cfg: QosConfig, fg_active: Arc<AtomicBool>) {
        MiniCluster::set_qos(self, cfg, fg_active);
    }

    fn clear_qos(&self) {
        MiniCluster::clear_qos(self);
    }

    fn qos_pace(&self, busy_s: f64) {
        MiniCluster::qos_pace(self, busy_s);
    }
}

/// The client engine's view of the MiniCluster (DESIGN.md §11).
impl crate::client::ClientIo for MiniCluster {
    fn data_shards(&self) -> usize {
        self.policy.code().k()
    }

    fn block_len(&self) -> usize {
        self.spec.block_size as usize
    }

    fn read_block(&self, sid: u64, block: usize, client: Location) -> anyhow::Result<Vec<u8>> {
        MiniCluster::read_block(self, sid, block, client)
    }

    fn degraded_read(
        &self,
        sid: u64,
        block: usize,
        client: Location,
    ) -> anyhow::Result<(Vec<u8>, Duration)> {
        MiniCluster::degraded_read(self, sid, block, client)
    }

    fn write_stripe_from(
        &self,
        sid: u64,
        data: Vec<Vec<u8>>,
        client: Location,
    ) -> anyhow::Result<()> {
        MiniCluster::write_stripe_from(self, sid, data, client)
    }
}

/// The MiniCluster implementation of the scenario engine
/// ([`crate::scenario::RecoveryBackend`], DESIGN.md §5): real bytes moved
/// through token-bucket links and the real GF data path.
///
/// Runs at a scaled-down block size and scaled-up link rates (same 5:1
/// inner/cross ratio as the paper) so wall-clock stays interactive;
/// backend-independent quantities — blocks rebuilt, planned cross-rack
/// block transfers, *relative* cross-rack bytes between policies — are the
/// cross-check against the fluid backend. Foreground traffic (mixed-load
/// kinds) runs through the shared client engine (DESIGN.md §11), so both
/// backends serve the identical generated request sequence; its byte
/// accounting lands in the same rack counters (foreground and recovery
/// share the links, as on a real cluster).
pub struct ClusterBackend {
    /// Coding data path: "native" or "pjrt".
    pub data_backend: String,
    /// Scaled block size (bytes) for the in-process run.
    pub block_size: u64,
    pub inner_mbps: f64,
    pub cross_mbps: f64,
    /// Concurrent reconstruction workers (HDFS xmits analogue).
    pub workers: usize,
    /// Executor chunk size (bytes); blocks split into chunk tasks so
    /// fetch/decode/write of different chunks pipeline (DESIGN.md §8).
    pub chunk_size: u64,
    /// Task-admission order: FIFO or the link-balanced wavefront
    /// schedule (DESIGN.md §10, `d3ctl scenario --schedule`).
    pub schedule: SchedulePolicy,
    /// Fetch-coalescing window in chunks (`--coalesce`, DESIGN.md §10).
    pub coalesce: usize,
    /// Move each task's same-destination fetches in one batched gated
    /// round trip (`--batched-fetch`, DESIGN.md §10).
    pub batched_fetch: bool,
    /// Block representation (`--store`, DESIGN.md §16): materialized
    /// payloads, synthetic regenerate-on-read, or auto by footprint.
    pub store: StoreMode,
    /// Hot-block read cache capacity in MiB (`--cache-mb`); 0 disables
    /// the tier (DESIGN.md §16).
    pub cache_mb: u64,
}

/// Which [`BlockStore`] a scenario run populates (DESIGN.md §16).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum StoreMode {
    /// Synthetic iff the virtual payload footprint
    /// (stripes × code len × block size) exceeds 1 GiB.
    #[default]
    Auto,
    Materialized,
    Synthetic,
}

impl StoreMode {
    /// Resolve against a scenario's virtual payload footprint.
    pub fn synthetic_for(self, stripes: u64, code_len: usize, block_size: u64) -> bool {
        match self {
            StoreMode::Materialized => false,
            StoreMode::Synthetic => true,
            StoreMode::Auto => {
                let virt = stripes as u128 * code_len as u128 * block_size as u128;
                virt > (1u128 << 30)
            }
        }
    }
}

impl std::str::FromStr for StoreMode {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> anyhow::Result<StoreMode> {
        match s {
            "auto" => Ok(StoreMode::Auto),
            "materialized" => Ok(StoreMode::Materialized),
            "synthetic" => Ok(StoreMode::Synthetic),
            other => bail!("unknown store mode {other:?} (auto|materialized|synthetic)"),
        }
    }
}

impl Default for ClusterBackend {
    fn default() -> ClusterBackend {
        ClusterBackend {
            data_backend: "native".into(),
            block_size: 64 << 10,
            inner_mbps: 8000.0,
            cross_mbps: 1600.0,
            workers: 8,
            chunk_size: 16 << 10,
            schedule: SchedulePolicy::Fifo,
            coalesce: 1,
            batched_fetch: false,
            store: StoreMode::Auto,
            cache_mb: 0,
        }
    }
}

impl ClusterBackend {
    fn exec_cfg(&self) -> ExecutorConfig {
        ExecutorConfig {
            workers: self.workers,
            chunk_size: self.chunk_size,
            schedule: self.schedule,
            coalesce: self.coalesce,
            batched_fetch: self.batched_fetch,
            ..ExecutorConfig::default()
        }
    }
}

/// Deterministic per-stripe data (xorshift fill keyed by stripe + block)
/// — the shared populate oracle: every backend (and the parity tests)
/// regenerates the identical stripe contents from `(sid, k, len)`.
pub fn deterministic_data(sid: u64, k: usize, len: usize) -> Vec<Vec<u8>> {
    (0..k)
        .map(|b| {
            let mut v = vec![0u8; len];
            let mut s = sid.wrapping_mul(0x9e3779b9).wrapping_add(b as u64) | 1;
            for byte in v.iter_mut() {
                s ^= s << 13;
                s ^= s >> 7;
                s ^= s << 17;
                *byte = (s >> 24) as u8;
            }
            v
        })
        .collect()
}

impl crate::scenario::RecoveryBackend for ClusterBackend {
    fn name(&self) -> &'static str {
        "cluster"
    }

    fn run(
        &self,
        scenario: &crate::scenario::FailureScenario,
        policy: &Arc<dyn Placement>,
        spec: &SystemSpec,
    ) -> anyhow::Result<crate::scenario::ScenarioOutcome> {
        let mut cspec = *spec;
        cspec.block_size = self.block_size;
        cspec.net.inner_mbps = self.inner_mbps;
        cspec.net.cross_mbps = self.cross_mbps;
        let k = policy.code().k();
        let bs = self.block_size as usize;
        let synthetic =
            self.store.synthetic_for(scenario.stripes, policy.code().len(), self.block_size);
        let populate = || -> anyhow::Result<MiniCluster> {
            let mut cluster = if synthetic {
                MiniCluster::new_synthetic(cspec, policy.clone(), &self.data_backend, scenario.seed)?
            } else {
                MiniCluster::new(cspec, policy.clone(), &self.data_backend, scenario.seed)?
            };
            if self.cache_mb > 0 {
                cluster.set_cache(self.cache_mb << 20);
            }
            if synthetic {
                cluster.populate_synthetic(scenario.stripes)?;
            } else {
                cluster.write_stripes_parallel(scenario.stripes, self.workers.max(2), |sid| {
                    deterministic_data(sid, k, bs)
                })?;
            }
            Ok(cluster)
        };
        fabric::run_scenario(
            "cluster",
            scenario,
            policy,
            populate,
            self.exec_cfg(),
            self.workers,
            self.block_size,
        )
    }
}

/// Parity rows of the code's generator (encode matrix).
pub(crate) fn parity_matrix(code: &CodeSpec) -> crate::gf::Matrix {
    match *code {
        CodeSpec::Rs { k, m } => crate::codes::RsCode::new(k, m).parity_rows(),
        CodeSpec::Lrc { k, l, g } => crate::codes::LrcCode::new(k, l, g).parity_rows(),
    }
}

/// Coder-pool width for the native backend: one worker per core, capped —
/// encode is CPU-bound GF arithmetic, so wider pools only add contention
/// on the shared request channel. `spawn_pool` pins pjrt to 1 regardless.
fn encode_pool_size() -> usize {
    std::thread::available_parallelism().map_or(1, |n| n.get()).min(8)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::placement::D3Placement;

    fn small_spec() -> SystemSpec {
        let mut s = SystemSpec::paper_default();
        s.block_size = 64 * 1024;
        s.net.inner_mbps = 8000.0; // keep unit tests fast
        s.net.cross_mbps = 1600.0;
        s
    }

    fn data_for(sid: u64, k: usize, len: usize) -> Vec<Vec<u8>> {
        (0..k)
            .map(|b| {
                let mut v = vec![0u8; len];
                let mut s = sid.wrapping_mul(31).wrapping_add(b as u64) | 1;
                for byte in v.iter_mut() {
                    s ^= s << 13;
                    s ^= s >> 7;
                    s ^= s << 17;
                    *byte = (s >> 24) as u8;
                }
                v
            })
            .collect()
    }

    #[test]
    fn write_read_roundtrip() {
        let spec = small_spec();
        let policy =
            Arc::new(D3Placement::new(CodeSpec::Rs { k: 3, m: 2 }, spec.cluster).unwrap());
        let cluster = MiniCluster::new(spec, policy, "native", 7).unwrap();
        let data = data_for(0, 3, 64 * 1024);
        cluster.write_stripe(0, data.clone()).unwrap();
        for (b, want) in data.iter().enumerate() {
            let got = cluster.read_block(0, b, Location::new(7, 0)).unwrap();
            assert_eq!(&got, want);
        }
    }

    #[test]
    fn degraded_read_rebuilds_correct_bytes() {
        let spec = small_spec();
        let policy =
            Arc::new(D3Placement::new(CodeSpec::Rs { k: 3, m: 2 }, spec.cluster).unwrap());
        let cluster = MiniCluster::new(spec, policy, "native", 7).unwrap();
        let data = data_for(5, 3, 64 * 1024);
        cluster.write_stripe(5, data.clone()).unwrap();
        let victim = cluster.locate(5, 1);
        cluster.fail_node(victim);
        let (got, latency) = cluster.degraded_read(5, 1, Location::new(6, 2)).unwrap();
        assert_eq!(got, data[1]);
        assert!(latency.as_secs_f64() > 0.0);
    }

    #[test]
    fn node_recovery_rebuilds_every_block() {
        let spec = small_spec();
        let policy =
            Arc::new(D3Placement::new(CodeSpec::Rs { k: 2, m: 1 }, spec.cluster).unwrap());
        let cluster = MiniCluster::new(spec, policy, "native", 3).unwrap();
        let stripes = 24u64;
        let mut originals = Vec::new();
        for sid in 0..stripes {
            let data = data_for(sid, 2, 64 * 1024);
            cluster.write_stripe(sid, data.clone()).unwrap();
            originals.push(data);
        }
        let failed = Location::new(1, 1);
        let lost: Vec<(u64, usize)> = (0..stripes)
            .flat_map(|sid| {
                cluster
                    .policy()
                    .stripe(sid)
                    .locs
                    .iter()
                    .enumerate()
                    .filter(|(_, &l)| l == failed)
                    .map(|(b, _)| (sid, b))
                    .collect::<Vec<_>>()
            })
            .collect();
        cluster.fail_node(failed);
        let stats = cluster.recover_node(failed, stripes, 8).unwrap();
        assert_eq!(stats.blocks, lost.len());
        assert!(stats.throughput_mb_s > 0.0);
        // every lost block must be readable again with the right content
        let client = Location::new(0, 0);
        for (sid, b) in lost {
            let got = cluster.read_block(sid, b, client).unwrap();
            if b < 2 {
                assert_eq!(got, originals[sid as usize][b], "sid={sid} b={b}");
            }
            let newloc = cluster.locate(sid, b);
            assert_ne!(newloc, failed);
        }
    }

    #[test]
    fn chunked_recovery_rebuilds_identical_bytes() {
        // chunk < block exercises the multi-task assembly path end to end
        let spec = small_spec();
        let policy =
            Arc::new(D3Placement::new(CodeSpec::Rs { k: 3, m: 2 }, spec.cluster).unwrap());
        let cluster = MiniCluster::new(spec, policy.clone(), "native", 9).unwrap();
        let stripes = 12u64;
        let mut originals = Vec::new();
        for sid in 0..stripes {
            let data = data_for(sid, 3, 64 * 1024);
            cluster.write_stripe(sid, data.clone()).unwrap();
            originals.push(data);
        }
        let failed = Location::new(3, 0);
        cluster.fail_node(failed);
        let plans = crate::recovery::node_recovery_plans(
            policy.as_ref(),
            stripes,
            failed,
            9,
        );
        let lost: Vec<(u64, usize)> =
            plans.iter().map(|p| (p.stripe, p.failed_block)).collect();
        let cfg = ExecutorConfig {
            workers: 4,
            chunk_size: 4096, // 16 chunks per 64 KiB block
            ..ExecutorConfig::default()
        };
        let stats = cluster.recover_with_plans_cfg(plans, cfg, &[failed.rack]).unwrap();
        assert_eq!(stats.blocks, lost.len());
        assert_eq!(stats.chunks, lost.len() * 16);
        assert_eq!(stats.worker_utilization.len(), 4);
        for (sid, b) in lost {
            let loc = cluster.locate(sid, b);
            assert_ne!(loc, failed);
            let got = cluster.read_block(sid, b, loc).unwrap();
            if b < 3 {
                assert_eq!(got, originals[sid as usize][b], "sid={sid} b={b}");
            }
        }
    }

    #[test]
    fn synthetic_cluster_serves_identical_bytes() {
        let spec = small_spec();
        let policy =
            Arc::new(D3Placement::new(CodeSpec::Rs { k: 3, m: 2 }, spec.cluster).unwrap());
        let mat = MiniCluster::new(spec, policy.clone(), "native", 7).unwrap();
        let syn = MiniCluster::new_synthetic(spec, policy, "native", 7).unwrap();
        let stripes = 6u64;
        mat.write_stripes_parallel(stripes, 2, |sid| deterministic_data(sid, 3, 64 * 1024))
            .unwrap();
        syn.populate_synthetic(stripes).unwrap();
        let client = Location::new(0, 0);
        for sid in 0..stripes {
            for b in 0..5 {
                assert_eq!(
                    mat.read_block(sid, b, client).unwrap(),
                    syn.read_block(sid, b, client).unwrap(),
                    "sid={sid} b={b}"
                );
            }
        }
    }

    #[test]
    fn synthetic_degraded_read_and_recovery_work() {
        let spec = small_spec();
        let policy =
            Arc::new(D3Placement::new(CodeSpec::Rs { k: 2, m: 1 }, spec.cluster).unwrap());
        let cluster = MiniCluster::new_synthetic(spec, policy, "native", 3).unwrap();
        let stripes = 24u64;
        cluster.populate_synthetic(stripes).unwrap();
        let failed = Location::new(1, 1);
        cluster.fail_node(failed);
        // degraded read of any block on the dead node rebuilds canonical
        for sid in 0..stripes {
            let sp = cluster.policy().stripe(sid);
            for (b, &loc) in sp.locs.iter().enumerate() {
                if loc != failed || b >= 2 {
                    continue;
                }
                let (got, _) = cluster.degraded_read(sid, b, Location::new(0, 0)).unwrap();
                assert_eq!(got, deterministic_data(sid, 2, 64 * 1024)[b], "sid={sid} b={b}");
            }
        }
        let stats = cluster.recover_node(failed, stripes, 4).unwrap();
        assert!(stats.blocks > 0);
        // recovered blocks read back canonical from their new homes
        for sid in 0..stripes {
            for b in 0..2 {
                let got = cluster.read_block(sid, b, Location::new(0, 0)).unwrap();
                assert_eq!(got, deterministic_data(sid, 2, 64 * 1024)[b], "sid={sid} b={b}");
            }
        }
    }

    #[test]
    fn cache_hit_skips_the_rebuild_after_admission() {
        let spec = small_spec();
        let policy =
            Arc::new(D3Placement::new(CodeSpec::Rs { k: 3, m: 2 }, spec.cluster).unwrap());
        let mut cluster = MiniCluster::new(spec, policy, "native", 7).unwrap();
        cluster.set_cache(8 << 20);
        let data = data_for(5, 3, 64 * 1024);
        cluster.write_stripe(5, data.clone()).unwrap();
        let victim = cluster.locate(5, 1);
        cluster.fail_node(victim);
        let client = Location::new(6, 2);
        // popularity-aware admission: first rebuild only registers the
        // key in the ghost list, the second admits, the third hits
        for _ in 0..3 {
            let (got, _) = cluster.degraded_read(5, 1, client).unwrap();
            assert_eq!(got, data[1]);
        }
        let stats = cluster.cache_stats().unwrap();
        assert_eq!(stats.hits, 1);
        assert_eq!(stats.misses, 2);
        assert_eq!(stats.admitted, 1);
    }

    #[test]
    fn recovery_respects_rack_limits() {
        let spec = small_spec();
        let policy =
            Arc::new(D3Placement::new(CodeSpec::Rs { k: 3, m: 2 }, spec.cluster).unwrap());
        let cluster = MiniCluster::new(spec, policy, "native", 1).unwrap();
        let stripes = 18u64;
        for sid in 0..stripes {
            cluster.write_stripe(sid, data_for(sid, 3, 64 * 1024)).unwrap();
        }
        let failed = Location::new(0, 0);
        cluster.fail_node(failed);
        cluster.recover_node(failed, stripes, 4).unwrap();
        for sid in 0..stripes {
            let mut per_rack: HashMap<u32, usize> = HashMap::new();
            for b in 0..5 {
                let loc = cluster.locate(sid, b);
                *per_rack.entry(loc.rack).or_default() += 1;
            }
            assert!(per_rack.values().all(|&c| c <= 2), "sid={sid}: {per_rack:?}");
        }
    }
}
