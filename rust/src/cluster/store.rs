//! Block storage behind the [`BlockStore`] trait (DESIGN.md §16): the
//! fabric's NameNode layer addresses per-node block payloads through this
//! narrow surface, so the *representation* of a block is swappable.
//!
//! Two implementations:
//!
//! * [`MaterializedStore`] — the original per-node `HashMap<BlockKey,
//!   Vec<u8>>`, every payload resident. Memory is O(data).
//! * [`SyntheticStore`] — regenerates canonical payloads on read from the
//!   seeded per-stripe generator (the same xorshift stream
//!   [`crate::cluster::deterministic_data`] feeds the populate path) and
//!   the code's parity rows. Only *divergent* state is resident — an
//!   overlay of markers and materialized exceptions — so memory is
//!   O(metadata) while scenarios address terabytes of virtual payload.
//!
//! Regeneration proof sketch: data shard `b < k` of stripe `sid` is a pure
//! function of `(sid, b)` (xorshift keyed by `sid·φ + b`), and parity
//! shard `b ≥ k` is `Σ_j P[b−k][j] · data_j` over GF(256) — a *bytewise*
//! combine, so any window `[off, off+len)` of any block regenerates from
//! the same-window data shards. A read through the synthetic store is
//! therefore bit-identical to a read of the materialized bytes the encode
//! path would have stored, which the differential suite
//! (`tests/store_parity.rs`) asserts end to end.
//!
//! [`ChecksumRegistry`] shards the write-time checksum oracle by block key
//! so 8-writer ingest does not serialize on one global mutex (the
//! `checksums_sharded_vs_global_8w` bench row measures the win).

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

use anyhow::{anyhow, bail};

use crate::gf;

/// `(stripe id, block index)` — the NameNode's block name.
pub type BlockKey = (u64, usize);

/// Why a chunk read failed — callers format the location context.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ChunkError {
    /// No such block on the node.
    Missing,
    /// The window exceeds the stored block of `have` bytes.
    OutOfRange { have: usize },
}

/// Per-node block payload storage, addressed by flat node index. All
/// methods are `&self` and internally locked per node, so the recovery
/// executor's workers operate on distinct nodes without contention.
pub trait BlockStore: Send + Sync {
    /// Store `bytes` for `key` on node `at` (replacing any prior copy).
    fn insert(&self, at: usize, key: BlockKey, bytes: Vec<u8>);

    /// Full copy of the block's bytes, if present.
    fn read(&self, at: usize, key: BlockKey) -> Option<Vec<u8>>;

    /// Copy bytes `[off, off + len)` into `buf` (cleared first).
    fn read_chunk(
        &self,
        at: usize,
        key: BlockKey,
        off: usize,
        len: usize,
        buf: &mut Vec<u8>,
    ) -> Result<(), ChunkError>;

    /// Drop the block from node `at` (no-op if absent).
    fn remove(&self, at: usize, key: BlockKey);

    /// Erase every block on node `at` (node death).
    fn clear_node(&self, at: usize);

    /// Resident blocks on node `at`. For the synthetic store this counts
    /// only overlay entries — the implicit base population is not
    /// enumerated (doing so would require a placement scan).
    fn len(&self, at: usize) -> usize;

    /// Checksum of the bytes a [`BlockStore::read`] would return.
    fn stored_checksum(&self, at: usize, key: BlockKey) -> Option<u64>;

    /// Flip the first stored byte (scrub-fault injection).
    fn corrupt(&self, at: usize, key: BlockKey) -> anyhow::Result<()>;

    /// Write-time checksum derivable without a registry entry — the
    /// synthetic store computes it from the canonical generator for
    /// base-population stripes; materialized stores return `None`.
    fn baseline_checksum(&self, key: BlockKey) -> Option<u64>;

    /// Adopt `stripes` canonically-placed, canonically-filled stripes
    /// without materializing them. Returns `false` when the store cannot
    /// (materialized backends need a physical write per block).
    fn populate(&self, stripes: u64) -> bool;
}

// ---------------------------------------------------------------- material

/// The original representation: every payload resident in a per-node map.
pub struct MaterializedStore {
    nodes: Vec<Mutex<HashMap<BlockKey, Vec<u8>>>>,
}

impl MaterializedStore {
    pub fn new(nodes: usize) -> MaterializedStore {
        MaterializedStore { nodes: (0..nodes).map(|_| Mutex::new(HashMap::new())).collect() }
    }

    /// All block keys on node `at`, ascending — the worker's ListBlocks
    /// inventory path.
    pub fn keys_sorted(&self, at: usize) -> Vec<BlockKey> {
        let mut keys: Vec<BlockKey> =
            self.nodes[at].lock().unwrap().keys().copied().collect();
        keys.sort_unstable();
        keys
    }
}

impl BlockStore for MaterializedStore {
    fn insert(&self, at: usize, key: BlockKey, bytes: Vec<u8>) {
        self.nodes[at].lock().unwrap().insert(key, bytes);
    }

    fn read(&self, at: usize, key: BlockKey) -> Option<Vec<u8>> {
        self.nodes[at].lock().unwrap().get(&key).cloned()
    }

    fn read_chunk(
        &self,
        at: usize,
        key: BlockKey,
        off: usize,
        len: usize,
        buf: &mut Vec<u8>,
    ) -> Result<(), ChunkError> {
        let node = self.nodes[at].lock().unwrap();
        let blk = node.get(&key).ok_or(ChunkError::Missing)?;
        if off + len > blk.len() {
            return Err(ChunkError::OutOfRange { have: blk.len() });
        }
        buf.clear();
        buf.extend_from_slice(&blk[off..off + len]);
        Ok(())
    }

    fn remove(&self, at: usize, key: BlockKey) {
        self.nodes[at].lock().unwrap().remove(&key);
    }

    fn clear_node(&self, at: usize) {
        self.nodes[at].lock().unwrap().clear();
    }

    fn len(&self, at: usize) -> usize {
        self.nodes[at].lock().unwrap().len()
    }

    fn stored_checksum(&self, at: usize, key: BlockKey) -> Option<u64> {
        self.nodes[at].lock().unwrap().get(&key).map(|b| crate::net::proto::checksum(b))
    }

    fn corrupt(&self, at: usize, key: BlockKey) -> anyhow::Result<()> {
        let mut node = self.nodes[at].lock().unwrap();
        let blk = node
            .get_mut(&key)
            .ok_or_else(|| anyhow!("block ({},{}) not stored", key.0, key.1))?;
        let Some(byte) = blk.first_mut() else {
            bail!("block ({},{}) is empty", key.0, key.1);
        };
        *byte ^= 1;
        Ok(())
    }

    fn baseline_checksum(&self, _key: BlockKey) -> Option<u64> {
        None
    }

    fn populate(&self, _stripes: u64) -> bool {
        false
    }
}

// ----------------------------------------------------------------- synthetic

/// How a block on a synthetic node diverges from the canonical base.
enum Overlay {
    /// Present with exactly the canonical generator bytes (marker only —
    /// a recovered block that reproduced the original payload).
    Canonical,
    /// Explicitly absent (removed, or skipped at write time).
    Absent,
    /// Present with non-canonical bytes, kept materialized (foreground
    /// writes beyond the base population, partial blocks).
    Bytes(Vec<u8>),
    /// Canonical bytes with the first byte flipped (scrub-fault injection
    /// — regenerated with the flip applied on read).
    Corrupt,
}

struct NodeState {
    /// Node died: the implicit base population on it is gone.
    cleared: bool,
    overlay: HashMap<BlockKey, Overlay>,
}

/// What a read should produce, decided under the node lock, executed
/// (payload generation) after it is dropped.
enum ReadAction {
    Canonical,
    CanonicalCorrupt,
    Bytes(Vec<u8>),
    Missing,
}

/// Regenerate-on-read block store: stripes `0..base` exist implicitly on
/// their canonical nodes; everything else is an overlay entry.
pub struct SyntheticStore {
    k: usize,
    code_len: usize,
    block_size: usize,
    /// Parity rows of the code's generator, `(code_len − k) × k`.
    parity: gf::Matrix,
    /// Stripes `0..base` are implicitly present (canonical placement,
    /// canonical payload) on every non-cleared node the NameNode
    /// addresses them at.
    base: AtomicU64,
    nodes: Vec<Mutex<NodeState>>,
}

impl SyntheticStore {
    pub fn new(
        nodes: usize,
        k: usize,
        code_len: usize,
        block_size: usize,
        parity: gf::Matrix,
    ) -> SyntheticStore {
        assert_eq!(parity.rows(), code_len - k, "parity rows must cover the code");
        SyntheticStore {
            k,
            code_len,
            block_size,
            parity,
            base: AtomicU64::new(0),
            nodes: (0..nodes)
                .map(|_| Mutex::new(NodeState { cleared: false, overlay: HashMap::new() }))
                .collect(),
        }
    }

    fn base_stripes(&self) -> u64 {
        self.base.load(Ordering::Relaxed)
    }

    /// Canonical bytes `[off, off + len)` of block `block` of stripe
    /// `sid`: data shards replay the populate generator's xorshift stream;
    /// parity shards combine the k same-window data shards through the
    /// code's parity row (GF combine is bytewise, so windows compose).
    pub fn canonical_window(&self, sid: u64, block: usize, off: usize, len: usize) -> Vec<u8> {
        assert!(block < self.code_len, "block index out of code range");
        if block < self.k {
            let mut out = vec![0u8; len];
            fill_data_window(sid, block, off, &mut out);
            return out;
        }
        let shards: Vec<Vec<u8>> = (0..self.k)
            .map(|b| {
                let mut v = vec![0u8; len];
                fill_data_window(sid, b, off, &mut v);
                v
            })
            .collect();
        let mut out = vec![0u8; len];
        let pairs: Vec<(u8, &[u8])> = self
            .parity
            .row(block - self.k)
            .iter()
            .zip(&shards)
            .map(|(&c, s)| (c, s.as_slice()))
            .collect();
        gf::combine_many_into(&mut out, &pairs);
        out
    }

    /// Checksum of the canonical full block (the write-time oracle the
    /// populate path would have registered).
    pub fn canonical_checksum(&self, sid: u64, block: usize) -> u64 {
        crate::net::proto::checksum(&self.canonical_window(sid, block, 0, self.block_size))
    }

    /// Decide a read's outcome under the node lock; generation happens
    /// after the lock is dropped so regeneration never serializes peers.
    fn plan_read(&self, at: usize, key: BlockKey) -> ReadAction {
        let node = self.nodes[at].lock().unwrap();
        match node.overlay.get(&key) {
            Some(Overlay::Canonical) => ReadAction::Canonical,
            Some(Overlay::Corrupt) => ReadAction::CanonicalCorrupt,
            Some(Overlay::Bytes(v)) => ReadAction::Bytes(v.clone()),
            Some(Overlay::Absent) => ReadAction::Missing,
            None if !node.cleared && key.0 < self.base_stripes() => ReadAction::Canonical,
            None => ReadAction::Missing,
        }
    }
}

impl BlockStore for SyntheticStore {
    fn insert(&self, at: usize, key: BlockKey, bytes: Vec<u8>) {
        // A byte-exact reproduction of a base-population block (the common
        // case: recovery rebuilt the canonical payload) collapses to a
        // marker — O(1) resident per relocated block.
        let canonical = key.0 < self.base_stripes()
            && key.1 < self.code_len
            && bytes.len() == self.block_size
            && bytes == self.canonical_window(key.0, key.1, 0, self.block_size);
        let ov = if canonical { Overlay::Canonical } else { Overlay::Bytes(bytes) };
        self.nodes[at].lock().unwrap().overlay.insert(key, ov);
    }

    fn read(&self, at: usize, key: BlockKey) -> Option<Vec<u8>> {
        match self.plan_read(at, key) {
            ReadAction::Canonical => {
                Some(self.canonical_window(key.0, key.1, 0, self.block_size))
            }
            ReadAction::CanonicalCorrupt => {
                let mut v = self.canonical_window(key.0, key.1, 0, self.block_size);
                v[0] ^= 1;
                Some(v)
            }
            ReadAction::Bytes(v) => Some(v),
            ReadAction::Missing => None,
        }
    }

    fn read_chunk(
        &self,
        at: usize,
        key: BlockKey,
        off: usize,
        len: usize,
        buf: &mut Vec<u8>,
    ) -> Result<(), ChunkError> {
        match self.plan_read(at, key) {
            ReadAction::Canonical | ReadAction::CanonicalCorrupt => {
                if off + len > self.block_size {
                    return Err(ChunkError::OutOfRange { have: self.block_size });
                }
                let corrupt = matches!(self.plan_read(at, key), ReadAction::CanonicalCorrupt);
                let window = self.canonical_window(key.0, key.1, off, len);
                buf.clear();
                buf.extend_from_slice(&window);
                if corrupt && off == 0 && len > 0 {
                    buf[0] ^= 1;
                }
                Ok(())
            }
            ReadAction::Bytes(v) => {
                if off + len > v.len() {
                    return Err(ChunkError::OutOfRange { have: v.len() });
                }
                buf.clear();
                buf.extend_from_slice(&v[off..off + len]);
                Ok(())
            }
            ReadAction::Missing => Err(ChunkError::Missing),
        }
    }

    fn remove(&self, at: usize, key: BlockKey) {
        let mut node = self.nodes[at].lock().unwrap();
        let implicit = !node.cleared && key.0 < self.base_stripes();
        if implicit {
            node.overlay.insert(key, Overlay::Absent);
        } else {
            node.overlay.remove(&key);
        }
    }

    fn clear_node(&self, at: usize) {
        let mut node = self.nodes[at].lock().unwrap();
        node.cleared = true;
        node.overlay.clear();
    }

    fn len(&self, at: usize) -> usize {
        self.nodes[at]
            .lock()
            .unwrap()
            .overlay
            .values()
            .filter(|ov| !matches!(ov, Overlay::Absent))
            .count()
    }

    fn stored_checksum(&self, at: usize, key: BlockKey) -> Option<u64> {
        self.read(at, key).map(|b| crate::net::proto::checksum(&b))
    }

    fn corrupt(&self, at: usize, key: BlockKey) -> anyhow::Result<()> {
        let mut node = self.nodes[at].lock().unwrap();
        let implicit = !node.cleared && key.0 < self.base_stripes();
        match node.overlay.get_mut(&key) {
            Some(Overlay::Canonical) => {
                node.overlay.insert(key, Overlay::Corrupt);
            }
            // a second flip restores the canonical bytes
            Some(Overlay::Corrupt) => {
                node.overlay.insert(key, Overlay::Canonical);
            }
            Some(Overlay::Bytes(v)) => {
                let Some(byte) = v.first_mut() else {
                    bail!("block ({},{}) is empty", key.0, key.1);
                };
                *byte ^= 1;
            }
            Some(Overlay::Absent) => {
                bail!("block ({},{}) not stored", key.0, key.1)
            }
            None if implicit => {
                node.overlay.insert(key, Overlay::Corrupt);
            }
            None => bail!("block ({},{}) not stored", key.0, key.1),
        }
        Ok(())
    }

    fn baseline_checksum(&self, key: BlockKey) -> Option<u64> {
        if key.0 < self.base_stripes() && key.1 < self.code_len {
            // computed on demand, never memoized: a scrub scan over
            // millions of blocks must not accumulate O(total blocks)
            Some(self.canonical_checksum(key.0, key.1))
        } else {
            None
        }
    }

    fn populate(&self, stripes: u64) -> bool {
        self.base.store(stripes, Ordering::Relaxed);
        true
    }
}

/// The populate generator's per-shard xorshift stream, started at byte
/// `off` — must stay bit-identical to
/// [`crate::cluster::deterministic_data`].
fn fill_data_window(sid: u64, shard: usize, off: usize, out: &mut [u8]) {
    let mut s = sid.wrapping_mul(0x9e3779b9).wrapping_add(shard as u64) | 1;
    for _ in 0..off {
        s ^= s << 13;
        s ^= s >> 7;
        s ^= s << 17;
    }
    for byte in out.iter_mut() {
        s ^= s << 13;
        s ^= s >> 7;
        s ^= s << 17;
        *byte = (s >> 24) as u8;
    }
}

// ------------------------------------------------------------------ registry

const REGISTRY_SHARDS: usize = 64;

/// Write-time checksum registry, sharded by block key so concurrent
/// writers and the recovery executor's persist path do not serialize on
/// one global mutex (the PR 10 contention fix for `cluster/mod.rs`'s old
/// `checksums: Mutex<HashMap<..>>`).
pub struct ChecksumRegistry {
    shards: Vec<Mutex<HashMap<BlockKey, u64>>>,
}

impl Default for ChecksumRegistry {
    fn default() -> ChecksumRegistry {
        ChecksumRegistry::new()
    }
}

impl ChecksumRegistry {
    pub fn new() -> ChecksumRegistry {
        ChecksumRegistry {
            shards: (0..REGISTRY_SHARDS).map(|_| Mutex::new(HashMap::new())).collect(),
        }
    }

    #[inline]
    fn shard(&self, key: BlockKey) -> &Mutex<HashMap<BlockKey, u64>> {
        let h = key.0.wrapping_mul(0x9e3779b97f4a7c15) ^ (key.1 as u64).wrapping_mul(31);
        &self.shards[(h as usize) & (REGISTRY_SHARDS - 1)]
    }

    pub fn get(&self, key: BlockKey) -> Option<u64> {
        self.shard(key).lock().unwrap().get(&key).copied()
    }

    /// Register (overwriting) — the client write path.
    pub fn insert(&self, key: BlockKey, sum: u64) {
        self.shard(key).lock().unwrap().insert(key, sum);
    }

    /// First write wins — the recovery persist path: a recovered block
    /// must reproduce the bytes the original write registered, never
    /// redefine them.
    pub fn or_insert(&self, key: BlockKey, sum: u64) {
        self.shard(key).lock().unwrap().entry(key).or_insert(sum);
    }

    pub fn len(&self) -> usize {
        self.shards.iter().map(|s| s.lock().unwrap().len()).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::codes::CodeSpec;

    fn synthetic(k: usize, m: usize, bs: usize) -> SyntheticStore {
        let parity = crate::cluster::parity_matrix(&CodeSpec::Rs { k, m });
        SyntheticStore::new(4, k, k + m, bs, parity)
    }

    #[test]
    fn synthetic_data_matches_populate_generator() {
        let s = synthetic(3, 2, 4096);
        s.populate(5);
        let want = crate::cluster::deterministic_data(2, 3, 4096);
        for b in 0..3 {
            assert_eq!(s.read(0, (2, b)).unwrap(), want[b], "data shard {b}");
        }
    }

    #[test]
    fn synthetic_parity_matches_encode() {
        let (k, m, bs) = (3usize, 2usize, 2048usize);
        let s = synthetic(k, m, bs);
        s.populate(4);
        let data = crate::cluster::deterministic_data(3, k, bs);
        let refs: Vec<&[u8]> = data.iter().map(|v| v.as_slice()).collect();
        let parity = crate::codes::RsCode::new(k, m).encode(&refs);
        for (i, want) in parity.iter().enumerate() {
            assert_eq!(&s.read(1, (3, k + i)).unwrap(), want, "parity {i}");
        }
    }

    #[test]
    fn windows_compose_for_data_and_parity() {
        let s = synthetic(2, 2, 1024);
        s.populate(2);
        for b in 0..4usize {
            let full = s.read(0, (1, b)).unwrap();
            for (off, len) in [(0usize, 100usize), (511, 13), (1000, 24)] {
                let mut buf = Vec::new();
                s.read_chunk(0, (1, b), off, len, &mut buf).unwrap();
                assert_eq!(buf, &full[off..off + len], "b={b} off={off}");
            }
        }
    }

    #[test]
    fn overlay_transitions() {
        let s = synthetic(2, 1, 256);
        s.populate(10);
        // implicit present
        assert!(s.read(0, (3, 0)).is_some());
        // remove → absent marker beats the implicit base
        s.remove(0, (3, 0));
        assert!(s.read(0, (3, 0)).is_none());
        assert_eq!(
            s.read_chunk(0, (3, 0), 0, 16, &mut Vec::new()),
            Err(ChunkError::Missing)
        );
        // a canonical re-insert collapses to a marker and reads back
        let canon = s.canonical_window(3, 0, 0, 256);
        s.insert(0, (3, 0), canon.clone());
        assert_eq!(s.read(0, (3, 0)).unwrap(), canon);
        // divergent insert is kept materialized
        s.insert(1, (20, 0), vec![7u8; 256]);
        assert_eq!(s.read(1, (20, 0)).unwrap(), vec![7u8; 256]);
        // clear_node kills the implicit base and the overlay
        s.clear_node(1);
        assert!(s.read(1, (20, 0)).is_none());
        assert!(s.read(1, (4, 0)).is_none());
        // other nodes unaffected
        assert!(s.read(0, (4, 0)).is_some());
    }

    #[test]
    fn corrupt_flips_first_byte_and_double_flip_restores() {
        let s = synthetic(2, 1, 128);
        s.populate(3);
        let clean = s.read(0, (1, 1)).unwrap();
        let sum = s.stored_checksum(0, (1, 1)).unwrap();
        s.corrupt(0, (1, 1)).unwrap();
        let dirty = s.read(0, (1, 1)).unwrap();
        assert_eq!(dirty[0], clean[0] ^ 1);
        assert_eq!(&dirty[1..], &clean[1..]);
        assert_ne!(s.stored_checksum(0, (1, 1)).unwrap(), sum);
        // chunked read off the front carries the flip; tails do not
        let mut buf = Vec::new();
        s.read_chunk(0, (1, 1), 0, 4, &mut buf).unwrap();
        assert_eq!(buf[0], clean[0] ^ 1);
        s.read_chunk(0, (1, 1), 64, 4, &mut buf).unwrap();
        assert_eq!(buf, &clean[64..68]);
        s.corrupt(0, (1, 1)).unwrap();
        assert_eq!(s.read(0, (1, 1)).unwrap(), clean);
        // corrupting a missing block errors
        assert!(s.corrupt(0, (99, 0)).is_err());
    }

    #[test]
    fn baseline_checksum_only_covers_the_base_population() {
        let s = synthetic(2, 1, 512);
        s.populate(4);
        let sum = s.baseline_checksum((2, 1)).unwrap();
        assert_eq!(sum, s.stored_checksum(0, (2, 1)).unwrap());
        assert!(s.baseline_checksum((4, 0)).is_none(), "beyond base");
        assert!(s.baseline_checksum((2, 3)).is_none(), "beyond code len");
    }

    #[test]
    fn materialized_store_roundtrip_and_bounds() {
        let m = MaterializedStore::new(2);
        assert!(!m.populate(5), "materialized cannot adopt a synthetic base");
        m.insert(0, (1, 0), vec![1, 2, 3, 4]);
        assert_eq!(m.read(0, (1, 0)).unwrap(), vec![1, 2, 3, 4]);
        assert_eq!(m.len(0), 1);
        assert_eq!(m.len(1), 0);
        let mut buf = Vec::new();
        m.read_chunk(0, (1, 0), 1, 2, &mut buf).unwrap();
        assert_eq!(buf, vec![2, 3]);
        assert_eq!(
            m.read_chunk(0, (1, 0), 2, 10, &mut buf),
            Err(ChunkError::OutOfRange { have: 4 })
        );
        assert_eq!(m.read_chunk(1, (1, 0), 0, 1, &mut buf), Err(ChunkError::Missing));
        m.insert(0, (2, 1), vec![9]);
        assert_eq!(m.keys_sorted(0), vec![(1, 0), (2, 1)]);
        m.remove(0, (1, 0));
        assert!(m.read(0, (1, 0)).is_none());
        m.clear_node(0);
        assert_eq!(m.len(0), 0);
    }

    #[test]
    fn registry_shards_agree_with_a_flat_map() {
        let reg = ChecksumRegistry::new();
        let mut flat = HashMap::new();
        for sid in 0..200u64 {
            for b in 0..5usize {
                let sum = sid * 31 + b as u64;
                reg.insert((sid, b), sum);
                flat.insert((sid, b), sum);
            }
        }
        assert_eq!(reg.len(), flat.len());
        for (&key, &want) in &flat {
            assert_eq!(reg.get(key), Some(want));
        }
        // first-write-wins
        reg.or_insert((0, 0), 999);
        assert_eq!(reg.get((0, 0)), Some(0));
        reg.insert((0, 0), 999);
        assert_eq!(reg.get((0, 0)), Some(999));
        assert_eq!(reg.get((1000, 0)), None);
    }
}
