//! Token-bucket link throttling for the mini-HDFS: reproduces the paper's
//! bandwidth hierarchy (fast ToR ports, scarce core-router ports) on real
//! in-process transfers, so wall-clock recovery times are network-shaped
//! exactly like the testbed's.

use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, MutexGuard, PoisonError};
use std::time::{Duration, Instant};

use crate::topology::Location;

/// Lock a mutex, clearing any poison. Every invariant guarded in this
/// module survives a panicking holder — gate holder counts are released
/// by RAII, token-bucket balances are only ever read-modify-written
/// atomically under the lock, and the QoS bank is a swap-in/out Option —
/// so a worker that dies mid-transfer must surface *its* panic, not
/// cascade an opaque `PoisonError` into every later transfer on the same
/// link (mandatory once RPC node workers can fail mid-flight).
#[inline]
fn lock_clean<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(PoisonError::into_inner)
}

/// Which traffic class a transfer belongs to (DESIGN.md §11, §15): client
/// I/O (reads, degraded reads, writes) is foreground; the recovery
/// executor's fetches and aggregated-partial shipments are recovery; the
/// background scrub daemon's checksum probes are scrub. The QoS split
/// ([`LinkSet::set_qos`]) throttles the recovery and scrub classes —
/// scrub drains the same share-scaled bank as recovery (they compete for
/// the non-foreground fraction of each port) but, like foreground, never
/// holds the reconstruction in-flight gates: a throttled scrub pass must
/// not occupy xmits slots queued repair chunks are waiting on.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TrafficClass {
    Foreground,
    Recovery,
    Scrub,
}

/// Counting in-flight gate: at most `cap` concurrent holders, 0 = no limit.
/// The recovery executor (DESIGN.md §8) sets per-node and per-rack-link
/// caps so chunk tasks queue at busy endpoints (the HDFS xmits analogue)
/// instead of oversubscribing them.
pub struct Gate {
    cap: AtomicUsize,
    holders: Mutex<usize>,
    cv: Condvar,
}

/// RAII hold on a [`Gate`]; dropping releases the slot.
pub struct GateGuard<'a>(Option<&'a Gate>);

/// RAII marker for an in-flight recovery execution
/// ([`LinkSet::mark_recovery`]); dropping decrements the counter.
pub struct RecoveryMark<'a>(&'a LinkSet);

impl Drop for RecoveryMark<'_> {
    fn drop(&mut self) {
        self.0.recovery_marks.fetch_sub(1, Ordering::Relaxed);
    }
}

impl Drop for GateGuard<'_> {
    fn drop(&mut self) {
        if let Some(g) = self.0 {
            let mut n = lock_clean(&g.holders);
            *n -= 1;
            g.cv.notify_one();
        }
    }
}

impl Gate {
    pub fn new() -> Gate {
        Gate { cap: AtomicUsize::new(0), holders: Mutex::new(0), cv: Condvar::new() }
    }

    /// Change the cap; 0 disables the gate (guards already held stay valid).
    pub fn set_cap(&self, cap: usize) {
        // store + notify under the holders lock: a waiter between its cap
        // re-check and cv.wait() would otherwise miss the wakeup
        let _holders = lock_clean(&self.holders);
        self.cap.store(cap, Ordering::Relaxed);
        self.cv.notify_all();
    }

    /// Block until a slot is free (immediately when uncapped).
    pub fn enter(&self) -> GateGuard<'_> {
        if self.cap.load(Ordering::Relaxed) == 0 {
            return GateGuard(None);
        }
        let mut n = lock_clean(&self.holders);
        loop {
            let cap = self.cap.load(Ordering::Relaxed);
            if cap == 0 || *n < cap {
                break;
            }
            n = self.cv.wait(n).unwrap_or_else(PoisonError::into_inner);
        }
        *n += 1;
        GateGuard(Some(self))
    }
}

impl Default for Gate {
    fn default() -> Gate {
        Gate::new()
    }
}

/// A token bucket: `rate` bytes/second, capped burst.
pub struct TokenBucket {
    rate: f64,
    burst: f64,
    state: Mutex<BucketState>,
}

struct BucketState {
    tokens: f64,
    last: Instant,
}

impl TokenBucket {
    pub fn new(rate_bytes_per_s: f64) -> TokenBucket {
        let burst = (rate_bytes_per_s * 0.05).max(64.0 * 1024.0); // 50 ms of burst
        TokenBucket {
            rate: rate_bytes_per_s,
            burst,
            state: Mutex::new(BucketState { tokens: burst, last: Instant::now() }),
        }
    }

    /// Block until `bytes` tokens have been consumed.
    ///
    /// §Perf: drains whatever is available, then *sleeps* for the time the
    /// remainder needs (an earlier version spun consuming micro-tokens as
    /// they accrued, burning a full core and serializing every transfer on
    /// the single-CPU host).
    pub fn acquire(&self, bytes: u64) {
        let mut remaining = bytes as f64;
        loop {
            let wait;
            {
                let mut st = lock_clean(&self.state);
                let now = Instant::now();
                st.tokens = (st.tokens + now.duration_since(st.last).as_secs_f64() * self.rate)
                    .min(self.burst);
                st.last = now;
                if st.tokens >= remaining {
                    st.tokens -= remaining;
                    return;
                }
                remaining -= st.tokens;
                st.tokens = 0.0;
                let need = remaining.min(self.burst.max(1.0));
                wait = Duration::from_secs_f64(need / self.rate);
            }
            std::thread::sleep(wait.min(Duration::from_millis(50)));
        }
    }
}

/// Per-rack-link busy/stall meter (nanosecond counters): *busy* is wall
/// time a transfer spent moving bytes through the rack's router port
/// (token-bucket pacing included), *stall* is wall time spent queued on
/// in-flight gates before the first byte moved. The recovery path diffs
/// snapshots around a run, so a schedule that piles onto one link shows
/// up as stall on that link rather than vanishing into the wall clock.
#[derive(Default)]
struct LinkMeter {
    busy_ns: AtomicU64,
    stall_ns: AtomicU64,
}

impl LinkMeter {
    fn add(&self, busy: Duration, stall: Duration) {
        self.busy_ns.fetch_add(busy.as_nanos() as u64, Ordering::Relaxed);
        self.stall_ns.fetch_add(stall.as_nanos() as u64, Ordering::Relaxed);
    }
}

/// The recovery-class rate split (DESIGN.md §11): a second bank of token
/// buckets at `share × rate` on every node port and rack link. Recovery
/// transfers charge both banks, so while foreground load is active
/// (`fg_active`) recovery can use at most its share of any port and the
/// remainder stays available to client I/O. Foreground transfers never
/// touch this bank.
struct QosSplit {
    nodes: Vec<(TokenBucket, TokenBucket)>,
    racks: Vec<(TokenBucket, TokenBucket)>,
    fg_active: Arc<AtomicBool>,
}

/// All throttled links of the cluster.
pub struct LinkSet {
    /// per-node NIC (up, down)
    nics: Vec<(TokenBucket, TokenBucket)>,
    /// per-rack core-router port (up, down)
    racks: Vec<(TokenBucket, TokenBucket)>,
    /// per-node in-flight transfer gate (counts both directions)
    node_gates: Vec<Gate>,
    /// per-rack-link in-flight gate for cross-rack transfers
    rack_gates: Vec<Gate>,
    /// per-rack-link busy/stall accounting for cross-rack transfers
    meters: Vec<LinkMeter>,
    /// recovery-class QoS bucket bank, present while a split is set
    qos: Mutex<Option<Arc<QosSplit>>>,
    /// lock-free fast-path flag mirroring `qos.is_some()`, so the common
    /// no-QoS recovery path never touches the mutex (DESIGN.md §9's
    /// zero-overhead hot path stays zero-overhead)
    qos_on: AtomicBool,
    /// count of recovery executions currently in flight on this fabric;
    /// the scrub daemon polls it ([`LinkSet::recovery_active`]) to back
    /// off while repairs are running (DESIGN.md §15)
    recovery_marks: AtomicUsize,
    /// full port rates (bytes/s), kept to size the QoS bank
    inner_rate: f64,
    cross_rate: f64,
    nodes_per_rack: usize,
}

impl LinkSet {
    pub fn new(spec: &crate::topology::SystemSpec) -> LinkSet {
        let inner = spec.net.inner_mbps * 1e6 / 8.0;
        let cross = spec.net.cross_mbps * 1e6 / 8.0;
        LinkSet {
            nics: (0..spec.cluster.node_count())
                .map(|_| (TokenBucket::new(inner), TokenBucket::new(inner)))
                .collect(),
            racks: (0..spec.cluster.racks)
                .map(|_| (TokenBucket::new(cross), TokenBucket::new(cross)))
                .collect(),
            node_gates: (0..spec.cluster.node_count()).map(|_| Gate::new()).collect(),
            rack_gates: (0..spec.cluster.racks).map(|_| Gate::new()).collect(),
            meters: (0..spec.cluster.racks).map(|_| LinkMeter::default()).collect(),
            qos: Mutex::new(None),
            qos_on: AtomicBool::new(false),
            recovery_marks: AtomicUsize::new(0),
            inner_rate: inner,
            cross_rate: cross,
            nodes_per_rack: spec.cluster.nodes_per_rack,
        }
    }

    /// Install the recovery/foreground split: recovery-class transfers are
    /// capped at `share` of every node port and rack link while
    /// `fg_active` holds true. `share` outside (0, 1) removes the split.
    pub fn set_qos(&self, share: f64, fg_active: Arc<AtomicBool>) {
        let mut qos = lock_clean(&self.qos);
        *qos = if share > 0.0 && share < 1.0 {
            Some(Arc::new(QosSplit {
                nodes: (0..self.nics.len())
                    .map(|_| {
                        (
                            TokenBucket::new(self.inner_rate * share),
                            TokenBucket::new(self.inner_rate * share),
                        )
                    })
                    .collect(),
                racks: (0..self.racks.len())
                    .map(|_| {
                        (
                            TokenBucket::new(self.cross_rate * share),
                            TokenBucket::new(self.cross_rate * share),
                        )
                    })
                    .collect(),
                fg_active,
            }))
        } else {
            None
        };
        self.qos_on.store(qos.is_some(), Ordering::Relaxed);
    }

    /// Remove the recovery/foreground split.
    pub fn clear_qos(&self) {
        *lock_clean(&self.qos) = None;
        self.qos_on.store(false, Ordering::Relaxed);
    }

    /// True while client load is active under an installed QoS split.
    /// Without a split there is no foreground-activity signal and this
    /// reads false — the scrub daemon then only backs off for recovery.
    pub fn fg_active(&self) -> bool {
        if !self.qos_on.load(Ordering::Relaxed) {
            return false;
        }
        lock_clean(&self.qos)
            .as_deref()
            .is_some_and(|q| q.fg_active.load(Ordering::Relaxed))
    }

    /// Mark a recovery execution in flight; drop the guard when it ends.
    /// Nests across concurrent recoveries (a plain counter).
    pub fn mark_recovery(&self) -> RecoveryMark<'_> {
        self.recovery_marks.fetch_add(1, Ordering::Relaxed);
        RecoveryMark(self)
    }

    /// True while at least one recovery execution is in flight.
    pub fn recovery_active(&self) -> bool {
        self.recovery_marks.load(Ordering::Relaxed) > 0
    }

    /// Charge a scrub checksum probe of `bytes` read at `at` (DESIGN.md
    /// §15): the replica is read locally but leaves the node through its
    /// port on the way to the verifier, so the probe drains the node's
    /// up-NIC — and, while a QoS split is installed and foreground load
    /// is active, the scrub/recovery bank's share-scaled bucket too, so
    /// an aggressive scrub pass can never eat into the foreground
    /// fraction of the port. Chunked like [`LinkSet::transfer_class`] so
    /// the activity flag is honored mid-probe; never touches the
    /// reconstruction gates.
    pub fn scrub_probe(&self, at: Location, bytes: u64) {
        if bytes == 0 {
            return;
        }
        let i = at.rack as usize * self.nodes_per_rack + at.node as usize;
        let qos: Option<Arc<QosSplit>> = if self.qos_on.load(Ordering::Relaxed) {
            lock_clean(&self.qos).clone()
        } else {
            None
        };
        let chunk = 256 * 1024;
        let mut left = bytes;
        while left > 0 {
            let take = left.min(chunk);
            if let Some(q) = qos.as_deref() {
                if q.fg_active.load(Ordering::Relaxed) {
                    q.nodes[i].0.acquire(take);
                }
            }
            self.nics[i].0.acquire(take);
            left -= take;
        }
    }

    /// Per-rack-link (busy seconds, stall seconds) accumulated by
    /// cross-rack transfers so far; callers diff two snapshots to
    /// attribute time to a phase (mirrors [`LinkSet`] byte accounting).
    pub fn link_busy_stall(&self) -> Vec<(f64, f64)> {
        self.meters
            .iter()
            .map(|m| {
                (
                    m.busy_ns.load(Ordering::Relaxed) as f64 / 1e9,
                    m.stall_ns.load(Ordering::Relaxed) as f64 / 1e9,
                )
            })
            .collect()
    }

    /// Set the in-flight caps the recovery executor runs under (0 = off).
    pub fn set_inflight_caps(&self, per_node: usize, per_rack_link: usize) {
        for g in &self.node_gates {
            g.set_cap(per_node);
        }
        for g in &self.rack_gates {
            g.set_cap(per_rack_link);
        }
    }

    /// [`LinkSet::transfer_class`] for foreground traffic.
    pub fn transfer(&self, src: Location, dst: Location, bytes: u64) {
        self.transfer_class(src, dst, bytes, TrafficClass::Foreground);
    }

    /// Throttle a `src → dst` transfer of `bytes` (blocking). Transfers are
    /// chunked so concurrent flows interleave fairly. The in-flight gates
    /// are the recovery executor's xmits analogue (DESIGN.md §8) and gate
    /// **recovery-class** transfers only — client I/O is not subject to
    /// reconstruction caps, so a QoS-throttled recovery flow can never
    /// hold a gate slot a foreground read is queued on (no priority
    /// inversion under the split). Gates are held for the whole transfer
    /// and acquired in a single global order (node gates by flat index,
    /// then rack gates by rack index) so concurrent transfers can never
    /// deadlock on them. Recovery-class transfers additionally charge the
    /// QoS bucket bank when a split is installed ([`LinkSet::set_qos`]).
    pub fn transfer_class(
        &self,
        src: Location,
        dst: Location,
        bytes: u64,
        class: TrafficClass,
    ) {
        if src == dst || bytes == 0 {
            return;
        }
        let src_i = src.rack as usize * self.nodes_per_rack + src.node as usize;
        let dst_i = dst.rack as usize * self.nodes_per_rack + dst.node as usize;
        let t0 = Instant::now();
        let mut guards: Vec<GateGuard<'_>> = Vec::with_capacity(4);
        if class == TrafficClass::Recovery {
            let (lo, hi) = if src_i < dst_i { (src_i, dst_i) } else { (dst_i, src_i) };
            guards.push(self.node_gates[lo].enter());
            guards.push(self.node_gates[hi].enter());
            if src.rack != dst.rack {
                let (rlo, rhi) = if src.rack < dst.rack {
                    (src.rack, dst.rack)
                } else {
                    (dst.rack, src.rack)
                };
                guards.push(self.rack_gates[rlo as usize].enter());
                guards.push(self.rack_gates[rhi as usize].enter());
            }
        }
        let stall = t0.elapsed();
        let t1 = Instant::now();
        self.pace(src, dst, src_i, dst_i, bytes, class);
        if src.rack != dst.rack {
            let busy = t1.elapsed();
            self.meters[src.rack as usize].add(busy, stall);
            self.meters[dst.rack as usize].add(busy, stall);
        }
    }

    /// Batched inbound transfer: move every `(source, bytes)` flow to
    /// `dst` under **one** gate acquisition covering all endpoints — the
    /// per-source fetch-coalescing path of the balanced scheduler
    /// (DESIGN.md §10). Gates are acquired in the same global order as
    /// [`LinkSet::transfer`] (node gates by flat index, then rack gates
    /// by rack index), so singles and batches can never deadlock; token
    /// buckets still charge per flow, so byte pacing and accounting are
    /// identical to issuing the transfers one by one.
    pub fn transfer_batch(
        &self,
        dst: Location,
        flows: &[(Location, u64)],
        class: TrafficClass,
    ) {
        let dst_i = dst.rack as usize * self.nodes_per_rack + dst.node as usize;
        let mut nodes: Vec<usize> = Vec::with_capacity(flows.len() + 1);
        let mut rack_ids: Vec<usize> = Vec::new();
        for &(src, bytes) in flows {
            if src == dst || bytes == 0 {
                continue;
            }
            nodes.push(src.rack as usize * self.nodes_per_rack + src.node as usize);
            if src.rack != dst.rack {
                rack_ids.push(src.rack as usize);
                rack_ids.push(dst.rack as usize);
            }
        }
        if nodes.is_empty() {
            return;
        }
        nodes.push(dst_i);
        nodes.sort_unstable();
        nodes.dedup();
        rack_ids.sort_unstable();
        rack_ids.dedup();
        let t0 = Instant::now();
        let mut guards: Vec<GateGuard<'_>> =
            Vec::with_capacity(nodes.len() + rack_ids.len());
        if class == TrafficClass::Recovery {
            // gates are the reconstruction xmits caps; see transfer_class
            for &i in &nodes {
                guards.push(self.node_gates[i].enter());
            }
            for &r in &rack_ids {
                guards.push(self.rack_gates[r].enter());
            }
        }
        let stall = t0.elapsed();
        for &(src, bytes) in flows {
            if src == dst || bytes == 0 {
                continue;
            }
            let src_i = src.rack as usize * self.nodes_per_rack + src.node as usize;
            let t1 = Instant::now();
            self.pace(src, dst, src_i, dst_i, bytes, class);
            if src.rack != dst.rack {
                // busy is metered per flow, so inner-rack flows in the
                // batch never inflate a rack link's busy time
                let busy = t1.elapsed();
                self.meters[src.rack as usize].add(busy, Duration::ZERO);
                self.meters[dst.rack as usize].add(busy, Duration::ZERO);
            }
        }
        // the single gate acquisition stalls the whole batch; charge it
        // to every cross-rack link the batch touches
        for &r in &rack_ids {
            self.meters[r].add(Duration::ZERO, stall);
        }
    }

    /// Token-bucket pacing of one flow (chunked so concurrent flows
    /// interleave fairly); gates must already be held. Recovery-class
    /// flows also drain the share-scaled QoS bank while foreground load
    /// is active, so recovery can never exceed its configured fraction of
    /// a port that client I/O is competing for.
    fn pace(
        &self,
        src: Location,
        dst: Location,
        src_i: usize,
        dst_i: usize,
        bytes: u64,
        class: TrafficClass,
    ) {
        let throttled =
            matches!(class, TrafficClass::Recovery | TrafficClass::Scrub);
        let qos: Option<Arc<QosSplit>> =
            if throttled && self.qos_on.load(Ordering::Relaxed) {
                lock_clean(&self.qos).clone()
            } else {
                None
            };
        let chunk = 256 * 1024;
        let mut left = bytes;
        while left > 0 {
            let take = left.min(chunk);
            // re-sample the foreground-activity flag per chunk, so a long
            // flow starts (and stops) honoring the split as client load
            // comes and goes mid-transfer
            if let Some(q) = qos.as_deref() {
                if q.fg_active.load(Ordering::Relaxed) {
                    q.nodes[src_i].0.acquire(take);
                    q.nodes[dst_i].1.acquire(take);
                    if src.rack != dst.rack {
                        q.racks[src.rack as usize].0.acquire(take);
                        q.racks[dst.rack as usize].1.acquire(take);
                    }
                }
            }
            self.nics[src_i].0.acquire(take);
            self.nics[dst_i].1.acquire(take);
            if src.rack != dst.rack {
                self.racks[src.rack as usize].0.acquire(take);
                self.racks[dst.rack as usize].1.acquire(take);
            }
            left -= take;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::topology::{Location, SystemSpec};

    #[test]
    fn bucket_enforces_rate() {
        let b = TokenBucket::new(10e6); // 10 MB/s
        b.acquire(1); // drain any timing slack
        let start = Instant::now();
        b.acquire(5_000_000); // 5 MB beyond the burst
        let secs = start.elapsed().as_secs_f64();
        assert!(secs > 0.35, "5MB at 10MB/s should take ~0.45s, took {secs}");
        assert!(secs < 1.5, "took way too long: {secs}");
    }

    #[test]
    fn cross_rack_much_slower_than_inner() {
        let mut spec = SystemSpec::paper_default();
        spec.net.inner_mbps = 800.0; // 100 MB/s
        spec.net.cross_mbps = 80.0; // 10 MB/s
        let links = LinkSet::new(&spec);
        let a = Location::new(0, 0);
        let b = Location::new(0, 1);
        let c = Location::new(1, 0);
        let n = 4_000_000u64;
        let t0 = Instant::now();
        links.transfer(a, b, n);
        let inner = t0.elapsed().as_secs_f64();
        let t1 = Instant::now();
        links.transfer(a, c, n);
        let cross = t1.elapsed().as_secs_f64();
        assert!(cross > inner * 3.0, "cross {cross} vs inner {inner}");
    }

    #[test]
    fn gate_caps_concurrency_and_uncapped_is_free() {
        let g = std::sync::Arc::new(Gate::new());
        // uncapped: many concurrent holders
        let a = g.enter();
        let b = g.enter();
        drop((a, b));
        g.set_cap(2);
        let active = std::sync::Arc::new(AtomicUsize::new(0));
        let peak = std::sync::Arc::new(AtomicUsize::new(0));
        let hs: Vec<_> = (0..6)
            .map(|_| {
                let (g, active, peak) = (g.clone(), active.clone(), peak.clone());
                std::thread::spawn(move || {
                    let _hold = g.enter();
                    let now = active.fetch_add(1, Ordering::SeqCst) + 1;
                    peak.fetch_max(now, Ordering::SeqCst);
                    std::thread::sleep(Duration::from_millis(20));
                    active.fetch_sub(1, Ordering::SeqCst);
                })
            })
            .collect();
        for h in hs {
            h.join().unwrap();
        }
        assert!(peak.load(Ordering::SeqCst) <= 2, "cap 2 exceeded");
    }

    #[test]
    fn gated_transfers_complete_without_deadlock() {
        let mut spec = SystemSpec::paper_default();
        spec.net.inner_mbps = 8000.0;
        spec.net.cross_mbps = 1600.0;
        let links = std::sync::Arc::new(LinkSet::new(&spec));
        links.set_inflight_caps(2, 3);
        // a mesh of opposing recovery transfers (the gated class) that
        // would deadlock under unordered two-gate acquisition
        let hs: Vec<_> = (0..12u64)
            .map(|i| {
                let l = links.clone();
                std::thread::spawn(move || {
                    let a = Location::new((i % 4) as usize, (i % 3) as usize);
                    let b = Location::new(((i + 1) % 4) as usize, ((i + 2) % 3) as usize);
                    l.transfer_class(a, b, 64 * 1024, TrafficClass::Recovery);
                    l.transfer_class(b, a, 64 * 1024, TrafficClass::Recovery);
                })
            })
            .collect();
        for h in hs {
            h.join().unwrap();
        }
    }

    #[test]
    fn foreground_transfers_bypass_the_reconstruction_gates() {
        // the in-flight caps are the recovery xmits analogue: with every
        // gate slot held by (simulated) recovery, a foreground transfer
        // must still go through immediately
        let mut spec = SystemSpec::paper_default();
        spec.net.inner_mbps = 8000.0;
        spec.net.cross_mbps = 1600.0;
        let links = LinkSet::new(&spec);
        links.set_inflight_caps(1, 1);
        let holds: Vec<_> = links.node_gates.iter().map(|g| g.enter()).collect();
        let rack_holds: Vec<_> = links.rack_gates.iter().map(|g| g.enter()).collect();
        let t0 = Instant::now();
        links.transfer(Location::new(0, 0), Location::new(1, 1), 64 * 1024);
        assert!(
            t0.elapsed().as_secs_f64() < 1.0,
            "foreground transfer queued behind recovery gates"
        );
        drop((holds, rack_holds));
    }

    #[test]
    fn batched_transfers_complete_and_meter_the_links() {
        let mut spec = SystemSpec::paper_default();
        spec.net.inner_mbps = 8000.0;
        spec.net.cross_mbps = 160.0; // 20 MB/s rack port: the batch must pace
        let links = LinkSet::new(&spec);
        let dst = Location::new(0, 0);
        let flows: Vec<(Location, u64)> = vec![
            (Location::new(1, 0), 2_000_000),
            (Location::new(2, 1), 2_000_000),
            (Location::new(0, 1), 64 * 1024), // inner-rack: unmetered
            (dst, 999),                       // self-flow: skipped
            (Location::new(3, 2), 0),         // empty: skipped
        ];
        let t0 = Instant::now();
        links.transfer_batch(dst, &flows, TrafficClass::Recovery);
        let secs = t0.elapsed().as_secs_f64();
        // 4 MB into one 20 MB/s rack downlink ⇒ well above 0.1 s
        assert!(secs > 0.1, "batch finished implausibly fast: {secs}");
        let stats = links.link_busy_stall();
        assert_eq!(stats.len(), spec.cluster.racks);
        assert!(stats[0].0 > 0.0, "dst rack link never went busy");
        assert!(stats[1].0 > 0.0 && stats[2].0 > 0.0, "src rack links unmetered");
        assert_eq!(stats[3], (0.0, 0.0), "untouched rack picked up time");
    }

    #[test]
    fn batched_and_single_transfers_interleave_without_deadlock() {
        let mut spec = SystemSpec::paper_default();
        spec.net.inner_mbps = 8000.0;
        spec.net.cross_mbps = 1600.0;
        let links = std::sync::Arc::new(LinkSet::new(&spec));
        links.set_inflight_caps(2, 2);
        let hs: Vec<_> = (0..8u64)
            .map(|i| {
                let l = links.clone();
                std::thread::spawn(move || {
                    let dst = Location::new((i % 4) as usize, (i % 3) as usize);
                    let srcs: Vec<(Location, u64)> = (0..3)
                        .map(|j| {
                            (
                                Location::new(((i + j + 1) % 4) as usize, (j % 3) as usize),
                                32 * 1024,
                            )
                        })
                        .collect();
                    l.transfer_batch(dst, &srcs, TrafficClass::Recovery);
                    l.transfer_class(dst, srcs[0].0, 32 * 1024, TrafficClass::Recovery);
                })
            })
            .collect();
        for h in hs {
            h.join().unwrap();
        }
    }

    #[test]
    fn qos_split_caps_recovery_but_not_foreground() {
        let mut spec = SystemSpec::paper_default();
        spec.net.inner_mbps = 8000.0;
        spec.net.cross_mbps = 160.0; // 20 MB/s rack port
        let links = LinkSet::new(&spec);
        let fg_active = Arc::new(AtomicBool::new(true));
        links.set_qos(0.25, fg_active.clone()); // recovery at 5 MB/s
        let n = 2_000_000u64;
        let a = Location::new(1, 0);
        let b = Location::new(0, 0);
        let t0 = Instant::now();
        links.transfer_class(a, b, n, TrafficClass::Recovery);
        let rec = t0.elapsed().as_secs_f64();
        // 2 MB at 25% of 20 MB/s ≈ 0.4 s (minus burst credit)
        assert!(rec > 0.25, "recovery not throttled to its share: {rec}s");
        let t1 = Instant::now();
        links.transfer_class(a, b, n, TrafficClass::Foreground);
        let fg = t1.elapsed().as_secs_f64();
        assert!(fg < rec * 0.8, "foreground throttled like recovery: {fg} vs {rec}");
        // with foreground inactive the split idles and recovery runs at
        // the full port rate again
        fg_active.store(false, Ordering::Relaxed);
        let t2 = Instant::now();
        links.transfer_class(a, b, n, TrafficClass::Recovery);
        let idle = t2.elapsed().as_secs_f64();
        assert!(idle < rec * 0.8, "idle split still throttles: {idle} vs {rec}");
        links.clear_qos();
    }

    #[test]
    fn scrub_class_shares_the_qos_bank_but_skips_gates() {
        let mut spec = SystemSpec::paper_default();
        spec.net.inner_mbps = 160.0; // 20 MB/s node port
        spec.net.cross_mbps = 160.0;
        let links = LinkSet::new(&spec);
        links.set_inflight_caps(1, 1);
        // every reconstruction gate held: a gated scrub would deadlock
        let holds: Vec<_> = links.node_gates.iter().map(|g| g.enter()).collect();
        let fg = Arc::new(AtomicBool::new(true));
        links.set_qos(0.25, fg.clone()); // scrub/recovery bank at 5 MB/s
        let n = 2_000_000u64;
        let t0 = Instant::now();
        links.transfer_class(
            Location::new(0, 1),
            Location::new(0, 0),
            n,
            TrafficClass::Scrub,
        );
        let scrub = t0.elapsed().as_secs_f64();
        assert!(scrub > 0.25, "scrub not paced by the shared bank: {scrub}s");
        let t1 = Instant::now();
        links.scrub_probe(Location::new(0, 2), n);
        let probe = t1.elapsed().as_secs_f64();
        assert!(probe > 0.25, "probe not paced by the shared bank: {probe}s");
        fg.store(false, Ordering::Relaxed);
        let t2 = Instant::now();
        links.scrub_probe(Location::new(0, 2), n);
        let idle = t2.elapsed().as_secs_f64();
        assert!(idle < probe * 0.8, "idle probe still throttled: {idle} vs {probe}");
        drop(holds);
        links.clear_qos();
        links.set_inflight_caps(0, 0);
        // the daemon's backoff signals
        assert!(!links.fg_active(), "fg_active without a split installed");
        assert!(!links.recovery_active());
        let mark = links.mark_recovery();
        assert!(links.recovery_active());
        drop(mark);
        assert!(!links.recovery_active());
    }

    #[test]
    fn poisoned_locks_recover_instead_of_cascading() {
        // Regression: a worker panicking while holding a link-layer lock
        // used to turn every later transfer into an opaque PoisonError
        // panic, burying the original failure. Poison each mutex class
        // and assert the layer keeps working.
        let mut spec = SystemSpec::paper_default();
        spec.net.inner_mbps = 8000.0;
        spec.net.cross_mbps = 1600.0;
        let links = Arc::new(LinkSet::new(&spec));
        links.set_inflight_caps(2, 2);

        // poison a gate's holders mutex mid-hold
        let g = &links.node_gates[0];
        let poison = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let _n = g.holders.lock().unwrap();
            panic!("worker died holding the gate lock");
        }));
        assert!(poison.is_err());
        assert!(g.holders.is_poisoned());
        let hold = g.enter(); // must not panic
        drop(hold);
        g.set_cap(3);

        // poison a token bucket's state
        let bucket = &links.nics[0].0;
        let poison = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let _st = bucket.state.lock().unwrap();
            panic!("worker died holding the bucket lock");
        }));
        assert!(poison.is_err());
        bucket.acquire(1024); // must not panic

        // poison the QoS bank and run a recovery transfer through it
        links.set_qos(0.5, Arc::new(AtomicBool::new(true)));
        let poison = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let _q = links.qos.lock().unwrap();
            panic!("worker died holding the qos lock");
        }));
        assert!(poison.is_err());
        links.transfer_class(
            Location::new(0, 0),
            Location::new(1, 1),
            64 * 1024,
            TrafficClass::Recovery,
        );
        links.clear_qos();
        links.set_inflight_caps(0, 0);
    }

    #[test]
    fn concurrent_flows_share_a_port() {
        let mut spec = SystemSpec::paper_default();
        spec.net.cross_mbps = 160.0; // 20 MB/s rack port
        let links = std::sync::Arc::new(LinkSet::new(&spec));
        let n = 2_000_000u64;
        // two flows into the same rack downlink: ~2x solo time
        let t0 = Instant::now();
        let hs: Vec<_> = (0..2)
            .map(|i| {
                let l = links.clone();
                std::thread::spawn(move || {
                    l.transfer(Location::new(1 + i, 0), Location::new(0, i), n)
                })
            })
            .collect();
        for h in hs {
            h.join().unwrap();
        }
        let both = t0.elapsed().as_secs_f64();
        let solo = n as f64 / 20e6;
        assert!(both > 1.5 * solo, "sharing not enforced: {both} vs solo {solo}");
    }
}
