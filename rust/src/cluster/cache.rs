//! Multi-level hot-block read cache (DESIGN.md §16): a byte-bounded,
//! sharded, **segmented LRU** with popularity-aware admission, layered in
//! front of the [`crate::cluster::store::BlockStore`] on the client read
//! path.
//!
//! Levels:
//!
//! * **ghost** — a payload-free recency list of recently *seen* keys. A
//!   first-touch miss only records the key here; the payload is not
//!   admitted. One-hit wonders (the long Zipf tail) therefore never
//!   displace resident bytes — admission requires a second touch while
//!   the ghost remembers the first.
//! * **probation** — newly admitted payloads. Eviction pressure lands
//!   here first.
//! * **protected** — payloads re-referenced *after* admission. A
//!   protected overflow demotes the coldest entry back to probation
//!   rather than evicting it, so the hot set survives scan traffic.
//!
//! Capacity is bytes of resident payload, split across shards (keyed by
//! block id) so concurrent readers do not serialize. Hit/miss/admission
//! counters are relaxed atomics — the scenario runner and the
//! `cache_hit_vs_miss_degraded_read` bench row read them lock-free.
//!
//! The cache is a *client-side* tier: a hit serves the payload without
//! touching the store **or** the modeled network (no link tokens, no
//! transfer latency), which is exactly how it bends the degraded-read
//! tail — a hot lost block is rebuilt once and then served from memory
//! while recovery grinds on behind it.

use std::collections::{BTreeMap, HashMap};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

use crate::cluster::store::BlockKey;

const SHARDS: usize = 16;
/// Fraction of a shard's byte budget reserved for the protected segment.
const PROTECTED_NUM: usize = 4;
const PROTECTED_DEN: usize = 5;
/// Ghost entries kept per shard (keys only, no payload bytes).
const GHOST_CAP: usize = 4096;

#[derive(Clone, Copy, PartialEq, Eq)]
enum Segment {
    Probation,
    Protected,
}

struct Entry {
    bytes: Vec<u8>,
    seg: Segment,
    /// Recency tick; also the key into the shard's order maps.
    tick: u64,
}

struct Shard {
    map: HashMap<BlockKey, Entry>,
    /// tick → key, per segment: first entry is the coldest.
    probation: BTreeMap<u64, BlockKey>,
    protected: BTreeMap<u64, BlockKey>,
    ghost: HashMap<BlockKey, u64>,
    ghost_order: BTreeMap<u64, BlockKey>,
    probation_bytes: usize,
    protected_bytes: usize,
    tick: u64,
}

impl Shard {
    fn new() -> Shard {
        Shard {
            map: HashMap::new(),
            probation: BTreeMap::new(),
            protected: BTreeMap::new(),
            ghost: HashMap::new(),
            ghost_order: BTreeMap::new(),
            probation_bytes: 0,
            protected_bytes: 0,
            tick: 0,
        }
    }

    fn next_tick(&mut self) -> u64 {
        self.tick += 1;
        self.tick
    }

    fn remember_ghost(&mut self, key: BlockKey) {
        let tick = self.next_tick();
        if let Some(old) = self.ghost.insert(key, tick) {
            self.ghost_order.remove(&old);
        }
        self.ghost_order.insert(tick, key);
        while self.ghost.len() > GHOST_CAP {
            let (_, victim) = self.ghost_order.pop_first().expect("ghost order in sync");
            self.ghost.remove(&victim);
        }
    }

    fn forget_ghost(&mut self, key: BlockKey) -> bool {
        if let Some(tick) = self.ghost.remove(&key) {
            self.ghost_order.remove(&tick);
            true
        } else {
            false
        }
    }

    /// Move `key` to the warm end of its segment (possibly switching
    /// segment), keeping byte counters straight.
    fn touch(&mut self, key: BlockKey, promote: bool) {
        let tick = self.next_tick();
        let Some(entry) = self.map.get_mut(&key) else { return };
        let size = entry.bytes.len();
        match entry.seg {
            Segment::Probation => {
                self.probation.remove(&entry.tick);
                if promote {
                    entry.seg = Segment::Protected;
                    entry.tick = tick;
                    self.protected.insert(tick, key);
                    self.probation_bytes -= size;
                    self.protected_bytes += size;
                } else {
                    entry.tick = tick;
                    self.probation.insert(tick, key);
                }
            }
            Segment::Protected => {
                self.protected.remove(&entry.tick);
                entry.tick = tick;
                self.protected.insert(tick, key);
            }
        }
    }

    /// Demote protected's coldest entries into probation until protected
    /// fits its slice of the budget.
    fn rebalance(&mut self, shard_capacity: usize) {
        let protected_cap = shard_capacity * PROTECTED_NUM / PROTECTED_DEN;
        while self.protected_bytes > protected_cap {
            let Some((_, key)) = self.protected.pop_first() else { break };
            let tick = self.next_tick();
            let entry = self.map.get_mut(&key).expect("order maps in sync");
            let size = entry.bytes.len();
            entry.seg = Segment::Probation;
            entry.tick = tick;
            self.probation.insert(tick, key);
            self.protected_bytes -= size;
            self.probation_bytes += size;
        }
    }

    /// Evict probation's coldest entries until the shard fits. Returns
    /// how many entries were dropped.
    fn evict_to(&mut self, shard_capacity: usize) -> u64 {
        let mut evicted = 0;
        while self.probation_bytes + self.protected_bytes > shard_capacity {
            let Some((_, key)) = self.probation.pop_first() else {
                // probation empty but still over budget: spill protected
                let Some((_, key)) = self.protected.pop_first() else { break };
                let entry = self.map.remove(&key).expect("order maps in sync");
                self.protected_bytes -= entry.bytes.len();
                evicted += 1;
                continue;
            };
            let entry = self.map.remove(&key).expect("order maps in sync");
            self.probation_bytes -= entry.bytes.len();
            evicted += 1;
        }
        evicted
    }

    fn invalidate(&mut self, key: BlockKey) {
        self.forget_ghost(key);
        if let Some(entry) = self.map.remove(&key) {
            match entry.seg {
                Segment::Probation => {
                    self.probation.remove(&entry.tick);
                    self.probation_bytes -= entry.bytes.len();
                }
                Segment::Protected => {
                    self.protected.remove(&entry.tick);
                    self.protected_bytes -= entry.bytes.len();
                }
            }
        }
    }
}

/// Lock-free snapshot of the cache's counters.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct CacheStats {
    pub hits: u64,
    pub misses: u64,
    /// Payloads admitted into probation (second touch within the ghost's
    /// memory).
    pub admitted: u64,
    /// First-touch misses recorded only in the ghost (payload rejected).
    pub rejected: u64,
    pub evicted: u64,
}

impl CacheStats {
    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            0.0
        } else {
            self.hits as f64 / total as f64
        }
    }
}

/// The cache tier. Cheap to share behind an `Arc`; every method is
/// `&self`.
pub struct HotBlockCache {
    shards: Vec<Mutex<Shard>>,
    shard_capacity: usize,
    hits: AtomicU64,
    misses: AtomicU64,
    admitted: AtomicU64,
    rejected: AtomicU64,
    evicted: AtomicU64,
}

impl HotBlockCache {
    /// `capacity_bytes` of resident payload across all shards.
    pub fn new(capacity_bytes: u64) -> HotBlockCache {
        let shard_capacity = ((capacity_bytes as usize) / SHARDS).max(1);
        HotBlockCache {
            shards: (0..SHARDS).map(|_| Mutex::new(Shard::new())).collect(),
            shard_capacity,
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
            admitted: AtomicU64::new(0),
            rejected: AtomicU64::new(0),
            evicted: AtomicU64::new(0),
        }
    }

    #[inline]
    fn shard(&self, key: BlockKey) -> &Mutex<Shard> {
        let h = key.0.wrapping_mul(0x9e3779b97f4a7c15) ^ (key.1 as u64).wrapping_mul(31);
        &self.shards[(h as usize) & (SHARDS - 1)]
    }

    /// Look up a block. A probation hit promotes to protected; any hit
    /// refreshes recency.
    pub fn get(&self, key: BlockKey) -> Option<Vec<u8>> {
        let mut shard = self.shard(key).lock().unwrap();
        if let Some(entry) = shard.map.get(&key) {
            let bytes = entry.bytes.clone();
            shard.touch(key, true);
            shard.rebalance(self.shard_capacity);
            drop(shard);
            self.hits.fetch_add(1, Ordering::Relaxed);
            return Some(bytes);
        }
        drop(shard);
        self.misses.fetch_add(1, Ordering::Relaxed);
        None
    }

    /// Offer a payload after a miss was served from the store. Admission
    /// is popularity-gated: first touch only records the key in the ghost
    /// list; a second touch (while the ghost remembers) admits the bytes
    /// into probation.
    pub fn admit(&self, key: BlockKey, bytes: &[u8]) {
        if bytes.len() > self.shard_capacity {
            return; // larger than a whole shard: never cacheable
        }
        let mut shard = self.shard(key).lock().unwrap();
        if shard.map.contains_key(&key) {
            shard.touch(key, false);
            return;
        }
        if !shard.forget_ghost(key) {
            shard.remember_ghost(key);
            drop(shard);
            self.rejected.fetch_add(1, Ordering::Relaxed);
            return;
        }
        let tick = shard.next_tick();
        shard.map.insert(key, Entry { bytes: bytes.to_vec(), seg: Segment::Probation, tick });
        shard.probation.insert(tick, key);
        shard.probation_bytes += bytes.len();
        let evicted = shard.evict_to(self.shard_capacity);
        drop(shard);
        self.admitted.fetch_add(1, Ordering::Relaxed);
        self.evicted.fetch_add(evicted, Ordering::Relaxed);
    }

    /// Drop a (possibly stale) payload — corruption injection and block
    /// rewrites call this so the cache never serves bytes the store
    /// disowned.
    pub fn invalidate(&self, key: BlockKey) {
        self.shard(key).lock().unwrap().invalidate(key);
    }

    pub fn stats(&self) -> CacheStats {
        CacheStats {
            hits: self.hits.load(Ordering::Relaxed),
            misses: self.misses.load(Ordering::Relaxed),
            admitted: self.admitted.load(Ordering::Relaxed),
            rejected: self.rejected.load(Ordering::Relaxed),
            evicted: self.evicted.load(Ordering::Relaxed),
        }
    }

    /// Resident payload bytes (all shards).
    pub fn resident_bytes(&self) -> usize {
        self.shards
            .iter()
            .map(|s| {
                let s = s.lock().unwrap();
                s.probation_bytes + s.protected_bytes
            })
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn key(i: u64) -> BlockKey {
        (i, 0)
    }

    #[test]
    fn first_touch_is_rejected_second_touch_admits() {
        let c = HotBlockCache::new(1 << 20);
        assert!(c.get(key(1)).is_none());
        c.admit(key(1), &[1, 2, 3]);
        assert!(c.get(key(1)).is_none(), "one-hit wonder stays out");
        c.admit(key(1), &[1, 2, 3]);
        assert_eq!(c.get(key(1)).unwrap(), vec![1, 2, 3]);
        let stats = c.stats();
        assert_eq!(stats.rejected, 1);
        assert_eq!(stats.admitted, 1);
        assert_eq!(stats.hits, 1);
        assert_eq!(stats.misses, 2);
    }

    #[test]
    fn capacity_is_bounded_and_evicts_cold_probation_first() {
        // one shard's budget is capacity/16; use blocks sized so ~4 fit
        let c = HotBlockCache::new(16 * 4 * 100);
        let block = vec![0u8; 100];
        // admit many distinct keys twice each; resident bytes stay bounded
        for i in 0..200u64 {
            c.admit(key(i), &block);
            c.admit(key(i), &block);
        }
        assert!(
            c.resident_bytes() <= 16 * 4 * 100,
            "resident {} exceeds capacity",
            c.resident_bytes()
        );
        assert!(c.stats().evicted > 0);
    }

    #[test]
    fn hot_keys_survive_a_scan() {
        let c = HotBlockCache::new(16 * 8 * 100);
        let block = vec![0u8; 100];
        // make key 0 hot: admitted and repeatedly re-referenced
        c.admit(key(0), &block);
        c.admit(key(0), &block);
        for _ in 0..5 {
            assert!(c.get(key(0)).is_some());
        }
        // now scan a pile of cold keys through the same shard set
        for i in 1..500u64 {
            c.admit(key(i), &block);
            c.admit(key(i), &block);
        }
        assert!(c.get(key(0)).is_some(), "protected entry evicted by scan traffic");
    }

    #[test]
    fn invalidate_removes_payload_and_ghost_memory() {
        let c = HotBlockCache::new(1 << 20);
        c.admit(key(9), &[1]);
        c.invalidate(key(9)); // ghost forgotten too
        c.admit(key(9), &[1]);
        assert!(c.get(key(9)).is_none(), "ghost should have been reset");
        c.admit(key(9), &[1]);
        assert!(c.get(key(9)).is_some());
        c.invalidate(key(9));
        assert!(c.get(key(9)).is_none());
    }

    #[test]
    fn oversized_payloads_are_never_admitted() {
        let c = HotBlockCache::new(160); // shard budget: 10 bytes
        let big = vec![0u8; 64];
        c.admit(key(1), &big);
        c.admit(key(1), &big);
        assert!(c.get(key(1)).is_none());
        assert_eq!(c.resident_bytes(), 0);
    }
}
