//! The coding service: a bounded pool of coder threads (DESIGN.md §12).
//! Native coding is CPU-bound GF arithmetic, so the pool sizes to the
//! host ([`super::MiniCluster`] passes one worker per core, capped) and
//! every worker owns a recovery-style [`Scratch`] pool for its encode
//! buffers; the PJRT client is not `Send` (and one device queue
//! serializes anyway), so that backend keeps a single dedicated thread.
//! DataNode workers submit requests over a shared channel and block on
//! the reply.

use std::sync::{mpsc, Arc, Mutex};

use crate::gf::{self, Matrix};
use crate::recovery::Scratch;
use crate::runtime::Coder;

pub enum CodeRequest {
    /// One GF linear combination (the decode/aggregation primitive).
    Combine {
        coeffs: Vec<u8>,
        shards: Vec<Vec<u8>>,
        reply: mpsc::Sender<anyhow::Result<Vec<u8>>>,
    },
    /// Full-stripe encode: all parity rows in one round trip. The data
    /// shards are *moved* through the service and handed back with the
    /// parity, so the write path never copies a block (DESIGN.md §9).
    Encode {
        rows: Matrix,
        data: Vec<Vec<u8>>,
        #[allow(clippy::type_complexity)]
        reply: mpsc::Sender<anyhow::Result<(Vec<Vec<u8>>, Vec<Vec<u8>>)>>,
    },
}

/// Handle to the coding pool. Cheap to clone; dropping all handles shuts
/// every worker down.
#[derive(Clone)]
pub struct CoderService {
    tx: mpsc::Sender<CodeRequest>,
}

impl CoderService {
    /// Spawn a single-worker service. `backend` = "native" or "pjrt".
    pub fn spawn(backend: &str) -> anyhow::Result<CoderService> {
        CoderService::spawn_pool(backend, 1)
    }

    /// Spawn the service with a bounded worker pool. Native workers share
    /// the request channel (each parks in `recv()` while holding the
    /// receiver lock; the lock is released the moment a request arrives,
    /// so the next idle worker takes over waiting while this one codes)
    /// and each owns its own [`Scratch`]. The pjrt backend is pinned to
    /// one thread regardless of `threads`.
    pub fn spawn_pool(backend: &str, threads: usize) -> anyhow::Result<CoderService> {
        let threads = if backend == "pjrt" { 1 } else { threads.max(1) };
        let (tx, rx) = mpsc::channel::<CodeRequest>();
        let rx = Arc::new(Mutex::new(rx));
        let (ready_tx, ready_rx) = mpsc::channel::<anyhow::Result<()>>();
        for w in 0..threads {
            let rx = Arc::clone(&rx);
            let ready_tx = ready_tx.clone();
            let backend = backend.to_string();
            std::thread::Builder::new()
                .name(format!("coder-{w}"))
                .spawn(move || {
                    let coder = match backend.as_str() {
                        "pjrt" => match Coder::pjrt() {
                            Ok(c) => {
                                let _ = ready_tx.send(Ok(()));
                                c
                            }
                            Err(e) => {
                                let _ = ready_tx.send(Err(e));
                                return;
                            }
                        },
                        _ => {
                            let _ = ready_tx.send(Ok(()));
                            Coder::native()
                        }
                    };
                    let mut scratch = Scratch::new();
                    loop {
                        let req = rx.lock().unwrap().recv();
                        let Ok(req) = req else { break };
                        serve(&coder, req, &mut scratch);
                    }
                })
                .expect("spawn coder service");
        }
        drop(ready_tx);
        for _ in 0..threads {
            ready_rx.recv().expect("coder thread died before ready")?;
        }
        Ok(CoderService { tx })
    }

    /// One GF linear combination, executed on a pool worker.
    pub fn combine(&self, coeffs: Vec<u8>, shards: Vec<Vec<u8>>) -> anyhow::Result<Vec<u8>> {
        let (reply, rx) = mpsc::channel();
        self.tx
            .send(CodeRequest::Combine { coeffs, shards, reply })
            .map_err(|_| anyhow::anyhow!("coder service stopped"))?;
        rx.recv().map_err(|_| anyhow::anyhow!("coder service dropped request"))?
    }

    /// Encode every parity row of `rows` over `data` in one service round
    /// trip; the data shards come back untouched alongside the parity.
    #[allow(clippy::type_complexity)]
    pub fn encode(
        &self,
        rows: Matrix,
        data: Vec<Vec<u8>>,
    ) -> anyhow::Result<(Vec<Vec<u8>>, Vec<Vec<u8>>)> {
        let (reply, rx) = mpsc::channel();
        self.tx
            .send(CodeRequest::Encode { rows, data, reply })
            .map_err(|_| anyhow::anyhow!("coder service stopped"))?;
        rx.recv().map_err(|_| anyhow::anyhow!("coder service dropped request"))?
    }
}

/// Run one request on a worker's coder + scratch.
fn serve(coder: &Coder, req: CodeRequest, scratch: &mut Scratch) {
    match req {
        CodeRequest::Combine { coeffs, shards, reply } => {
            let refs: Vec<&[u8]> = shards.iter().map(|s| s.as_slice()).collect();
            let out = coder.combine(&coeffs, &refs);
            let _ = reply.send(out);
        }
        CodeRequest::Encode { rows, data, reply } => {
            let out = if coder.backend_name() == "native" {
                encode_native(&rows, data, scratch)
            } else {
                let refs: Vec<&[u8]> = data.iter().map(|s| s.as_slice()).collect();
                let parity = coder.encode(&rows, &refs);
                parity.map(|p| (data, p))
            };
            let _ = reply.send(out);
        }
    }
}

/// Native encode with pooled buffers: the data shards move into the
/// worker's `(coeff, buffer)` staging vector, each parity row rewrites
/// the coefficient slots in place and runs one fused lane-dispatched
/// combine into a pooled accumulator, then the shards move back out
/// untouched. The staging vector itself cycles through the worker's
/// [`Scratch`] (the executor's pattern, DESIGN.md §9), so steady-state
/// encode allocates only the parity buffers it returns.
#[allow(clippy::type_complexity)]
fn encode_native(
    rows: &Matrix,
    data: Vec<Vec<u8>>,
    scratch: &mut Scratch,
) -> anyhow::Result<(Vec<Vec<u8>>, Vec<Vec<u8>>)> {
    if rows.cols() != data.len() {
        anyhow::bail!("encode: {} data shards for a {}-column matrix", data.len(), rows.cols());
    }
    let len = data.first().map_or(0, |s| s.len());
    let mut staging = scratch.take_staging();
    staging.extend(data.into_iter().map(|shard| (0u8, shard)));
    let mut parity = Vec::with_capacity(rows.rows());
    for r in 0..rows.rows() {
        for (slot, &c) in staging.iter_mut().zip(rows.row(r)) {
            slot.0 = c;
        }
        let mut out = scratch.take_zeroed(len);
        gf::combine_many_into(&mut out, &staging);
        parity.push(out);
    }
    let data: Vec<Vec<u8>> = staging.drain(..).map(|(_, shard)| shard).collect();
    scratch.put_staging(staging);
    Ok((data, parity))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gf;

    #[test]
    fn native_service_roundtrip() {
        let svc = CoderService::spawn("native").unwrap();
        let a = vec![1u8, 2, 3];
        let b = vec![4u8, 5, 6];
        let got = svc.combine(vec![1, 1], vec![a.clone(), b.clone()]).unwrap();
        assert_eq!(got, gf::combine(&[1, 1], &[&a, &b]));
    }

    #[test]
    fn encode_round_trip_returns_data_and_parity() {
        let svc = CoderService::spawn("native").unwrap();
        let code = crate::codes::RsCode::new(3, 2);
        let data: Vec<Vec<u8>> = (0..3u8).map(|i| vec![i * 11 + 1; 96]).collect();
        let refs: Vec<&[u8]> = data.iter().map(|v| v.as_slice()).collect();
        let want = code.encode(&refs);
        let (back, parity) = svc.encode(code.parity_rows(), data.clone()).unwrap();
        assert_eq!(back, data, "data shards must come back unmodified");
        assert_eq!(parity, want);
    }

    #[test]
    fn pooled_encode_matches_single_worker_encode() {
        let single = CoderService::spawn_pool("native", 1).unwrap();
        let pool = CoderService::spawn_pool("native", 4).unwrap();
        let code = crate::codes::RsCode::new(4, 2);
        for sid in 0..12u8 {
            let data: Vec<Vec<u8>> =
                (0..4u8).map(|i| vec![sid.wrapping_mul(13).wrapping_add(i); 257]).collect();
            let (d1, p1) = single.encode(code.parity_rows(), data.clone()).unwrap();
            let (d2, p2) = pool.encode(code.parity_rows(), data.clone()).unwrap();
            assert_eq!(d1, data);
            assert_eq!(d2, data);
            assert_eq!(p1, p2, "sid={sid}: pool and single worker must agree");
        }
    }

    #[test]
    fn pool_serves_concurrent_encodes() {
        let svc = CoderService::spawn_pool("native", 4).unwrap();
        let code = crate::codes::RsCode::new(3, 2);
        let handles: Vec<_> = (0..8u8)
            .map(|i| {
                let svc = svc.clone();
                let rows = code.parity_rows();
                std::thread::spawn(move || {
                    let data: Vec<Vec<u8>> =
                        (0..3u8).map(|b| vec![i.wrapping_mul(31).wrapping_add(b); 2048]).collect();
                    let refs: Vec<&[u8]> = data.iter().map(|v| v.as_slice()).collect();
                    let want = crate::codes::RsCode::new(3, 2).encode(&refs);
                    let (back, parity) = svc.encode(rows, data.clone()).unwrap();
                    assert_eq!(back, data);
                    assert_eq!(parity, want);
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
    }

    #[test]
    fn encode_rejects_shard_count_mismatch() {
        let svc = CoderService::spawn("native").unwrap();
        let code = crate::codes::RsCode::new(3, 2);
        let data: Vec<Vec<u8>> = (0..2u8).map(|i| vec![i; 32]).collect();
        assert!(svc.encode(code.parity_rows(), data).is_err());
    }

    #[test]
    fn service_usable_from_many_threads() {
        let svc = CoderService::spawn("native").unwrap();
        let handles: Vec<_> = (0..8u8)
            .map(|i| {
                let svc = svc.clone();
                std::thread::spawn(move || {
                    let a = vec![i; 128];
                    let b = vec![i ^ 0xff; 128];
                    let got = svc.combine(vec![1, 1], vec![a, b]).unwrap();
                    assert_eq!(got, vec![0xff; 128]);
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
    }
}
