//! The coding service: a dedicated thread owning the [`Coder`] (the PJRT
//! client is not `Send`, and a single coding executor per host models the
//! paper's per-node coding CPU anyway). DataNode workers submit combine
//! requests over a channel and block on the reply.

use std::sync::mpsc;

use crate::gf::Matrix;
use crate::runtime::Coder;

pub enum CodeRequest {
    /// One GF linear combination (the decode/aggregation primitive).
    Combine {
        coeffs: Vec<u8>,
        shards: Vec<Vec<u8>>,
        reply: mpsc::Sender<anyhow::Result<Vec<u8>>>,
    },
    /// Full-stripe encode: all parity rows in one round trip. The data
    /// shards are *moved* through the service and handed back with the
    /// parity, so the write path never copies a block (DESIGN.md §9).
    Encode {
        rows: Matrix,
        data: Vec<Vec<u8>>,
        #[allow(clippy::type_complexity)]
        reply: mpsc::Sender<anyhow::Result<(Vec<Vec<u8>>, Vec<Vec<u8>>)>>,
    },
}

/// Handle to the coding thread. Cheap to clone; dropping all handles shuts
/// the thread down.
#[derive(Clone)]
pub struct CoderService {
    tx: mpsc::Sender<CodeRequest>,
}

impl CoderService {
    /// Spawn the service. `backend` = "native" or "pjrt".
    pub fn spawn(backend: &str) -> anyhow::Result<CoderService> {
        let (tx, rx) = mpsc::channel::<CodeRequest>();
        let backend = backend.to_string();
        let (ready_tx, ready_rx) = mpsc::channel::<anyhow::Result<()>>();
        std::thread::Builder::new()
            .name("coder-service".into())
            .spawn(move || {
                let coder = match backend.as_str() {
                    "pjrt" => match Coder::pjrt() {
                        Ok(c) => {
                            let _ = ready_tx.send(Ok(()));
                            c
                        }
                        Err(e) => {
                            let _ = ready_tx.send(Err(e));
                            return;
                        }
                    },
                    _ => {
                        let _ = ready_tx.send(Ok(()));
                        Coder::native()
                    }
                };
                while let Ok(req) = rx.recv() {
                    match req {
                        CodeRequest::Combine { coeffs, shards, reply } => {
                            let refs: Vec<&[u8]> =
                                shards.iter().map(|s| s.as_slice()).collect();
                            let out = coder.combine(&coeffs, &refs);
                            let _ = reply.send(out);
                        }
                        CodeRequest::Encode { rows, data, reply } => {
                            let refs: Vec<&[u8]> =
                                data.iter().map(|s| s.as_slice()).collect();
                            let parity = coder.encode(&rows, &refs);
                            let _ = reply.send(parity.map(|p| (data, p)));
                        }
                    }
                }
            })
            .expect("spawn coder service");
        ready_rx.recv().expect("coder thread died before ready")?;
        Ok(CoderService { tx })
    }

    /// One GF linear combination, executed on the service thread.
    pub fn combine(&self, coeffs: Vec<u8>, shards: Vec<Vec<u8>>) -> anyhow::Result<Vec<u8>> {
        let (reply, rx) = mpsc::channel();
        self.tx
            .send(CodeRequest::Combine { coeffs, shards, reply })
            .map_err(|_| anyhow::anyhow!("coder service stopped"))?;
        rx.recv().map_err(|_| anyhow::anyhow!("coder service dropped request"))?
    }

    /// Encode every parity row of `rows` over `data` in one service round
    /// trip; the data shards come back untouched alongside the parity.
    #[allow(clippy::type_complexity)]
    pub fn encode(
        &self,
        rows: Matrix,
        data: Vec<Vec<u8>>,
    ) -> anyhow::Result<(Vec<Vec<u8>>, Vec<Vec<u8>>)> {
        let (reply, rx) = mpsc::channel();
        self.tx
            .send(CodeRequest::Encode { rows, data, reply })
            .map_err(|_| anyhow::anyhow!("coder service stopped"))?;
        rx.recv().map_err(|_| anyhow::anyhow!("coder service dropped request"))?
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gf;

    #[test]
    fn native_service_roundtrip() {
        let svc = CoderService::spawn("native").unwrap();
        let a = vec![1u8, 2, 3];
        let b = vec![4u8, 5, 6];
        let got = svc.combine(vec![1, 1], vec![a.clone(), b.clone()]).unwrap();
        assert_eq!(got, gf::combine(&[1, 1], &[&a, &b]));
    }

    #[test]
    fn encode_round_trip_returns_data_and_parity() {
        let svc = CoderService::spawn("native").unwrap();
        let code = crate::codes::RsCode::new(3, 2);
        let data: Vec<Vec<u8>> = (0..3u8).map(|i| vec![i * 11 + 1; 96]).collect();
        let refs: Vec<&[u8]> = data.iter().map(|v| v.as_slice()).collect();
        let want = code.encode(&refs);
        let (back, parity) = svc.encode(code.parity_rows(), data.clone()).unwrap();
        assert_eq!(back, data, "data shards must come back unmodified");
        assert_eq!(parity, want);
    }

    #[test]
    fn service_usable_from_many_threads() {
        let svc = CoderService::spawn("native").unwrap();
        let handles: Vec<_> = (0..8u8)
            .map(|i| {
                let svc = svc.clone();
                std::thread::spawn(move || {
                    let a = vec![i; 128];
                    let b = vec![i ^ 0xff; 128];
                    let got = svc.combine(vec![1, 1], vec![a, b]).unwrap();
                    assert_eq!(got, vec![0xff; 128]);
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
    }
}
