//! The coding service: a dedicated thread owning the [`Coder`] (the PJRT
//! client is not `Send`, and a single coding executor per host models the
//! paper's per-node coding CPU anyway). DataNode workers submit combine
//! requests over a channel and block on the reply.

use std::sync::mpsc;

use crate::runtime::Coder;

pub struct CodeRequest {
    pub coeffs: Vec<u8>,
    pub shards: Vec<Vec<u8>>,
    pub reply: mpsc::Sender<anyhow::Result<Vec<u8>>>,
}

/// Handle to the coding thread. Cheap to clone; dropping all handles shuts
/// the thread down.
#[derive(Clone)]
pub struct CoderService {
    tx: mpsc::Sender<CodeRequest>,
}

impl CoderService {
    /// Spawn the service. `backend` = "native" or "pjrt".
    pub fn spawn(backend: &str) -> anyhow::Result<CoderService> {
        let (tx, rx) = mpsc::channel::<CodeRequest>();
        let backend = backend.to_string();
        let (ready_tx, ready_rx) = mpsc::channel::<anyhow::Result<()>>();
        std::thread::Builder::new()
            .name("coder-service".into())
            .spawn(move || {
                let coder = match backend.as_str() {
                    "pjrt" => match Coder::pjrt() {
                        Ok(c) => {
                            let _ = ready_tx.send(Ok(()));
                            c
                        }
                        Err(e) => {
                            let _ = ready_tx.send(Err(e));
                            return;
                        }
                    },
                    _ => {
                        let _ = ready_tx.send(Ok(()));
                        Coder::native()
                    }
                };
                while let Ok(req) = rx.recv() {
                    let refs: Vec<&[u8]> = req.shards.iter().map(|s| s.as_slice()).collect();
                    let out = coder.combine(&req.coeffs, &refs);
                    let _ = req.reply.send(out);
                }
            })
            .expect("spawn coder service");
        ready_rx.recv().expect("coder thread died before ready")?;
        Ok(CoderService { tx })
    }

    /// One GF linear combination, executed on the service thread.
    pub fn combine(&self, coeffs: Vec<u8>, shards: Vec<Vec<u8>>) -> anyhow::Result<Vec<u8>> {
        let (reply, rx) = mpsc::channel();
        self.tx
            .send(CodeRequest { coeffs, shards, reply })
            .map_err(|_| anyhow::anyhow!("coder service stopped"))?;
        rx.recv().map_err(|_| anyhow::anyhow!("coder service dropped request"))?
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gf;

    #[test]
    fn native_service_roundtrip() {
        let svc = CoderService::spawn("native").unwrap();
        let a = vec![1u8, 2, 3];
        let b = vec![4u8, 5, 6];
        let got = svc.combine(vec![1, 1], vec![a.clone(), b.clone()]).unwrap();
        assert_eq!(got, gf::combine(&[1, 1], &[&a, &b]));
    }

    #[test]
    fn service_usable_from_many_threads() {
        let svc = CoderService::spawn("native").unwrap();
        let handles: Vec<_> = (0..8u8)
            .map(|i| {
                let svc = svc.clone();
                std::thread::spawn(move || {
                    let a = vec![i; 128];
                    let b = vec![i ^ 0xff; 128];
                    let got = svc.combine(vec![1, 1], vec![a, b]).unwrap();
                    assert_eq!(got, vec![0xff; 128]);
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
    }
}
