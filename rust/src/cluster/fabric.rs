//! Transport-agnostic recovery/scenario orchestration (DESIGN.md §13).
//!
//! [`BlockFabric`] is the narrow waist between the orchestration layers
//! (pipelined recovery executor, client engine, scenario runner, §5.3
//! migration) and a concrete data plane. Two fabrics implement it: the
//! in-process [`super::MiniCluster`] (blocks in per-node hash maps) and
//! the socket-backed [`crate::net::NetCluster`] (blocks on node workers
//! behind a length-prefixed RPC). Everything above the trait — chunking,
//! scheduling, QoS pacing, byte accounting diffs, outcome assembly — is
//! shared code, which is what makes exact cross-backend byte parity a
//! property by construction instead of a tuning exercise.

use std::collections::{BTreeMap, HashMap, HashSet};
use std::sync::atomic::AtomicBool;
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{bail, Result};

use crate::client::{ArrivalModel, ClientIo, FgOutcome, QosConfig, Request};
use crate::codes::CodeSpec;
use crate::gf;
use crate::placement::{Placement, PlacementTable};
use crate::recovery::executor::{execute_plans, ChunkRunner, ExecutorConfig, Scratch};
use crate::recovery::migration::MigrationBatch;
use crate::recovery::plan::{plan_coefficients, RepairPlan};
use crate::scenario::{
    degraded_read_plans, distinct_racks, planned_cross_rack_blocks, FailureScenario,
    ScenarioKind, ScenarioOutcome,
};
use crate::topology::Location;

use super::links::{LinkSet, TrafficClass};
use super::ClusterRecoveryStats;

/// A cluster data plane the shared orchestration layers can drive.
///
/// Contract for implementors: every *modeled* byte movement (the
/// `transfer`/`transfer_group` calls and the rack counters behind
/// [`BlockFabric::rack_byte_snapshot`]) must be charged identically for
/// identical logical operations, regardless of how the payload actually
/// moves — that invariant is what the three-way parity suite checks.
pub trait BlockFabric: Sync {
    /// The placement policy's erasure code.
    fn code(&self) -> CodeSpec;
    /// The policy's placement period, if periodic (DESIGN.md §10).
    fn period(&self) -> Option<u64>;
    /// Block size in bytes.
    fn block_size(&self) -> u64;
    /// The modeled link fabric (token buckets, gates, QoS split).
    fn links(&self) -> &LinkSet;
    /// Current location of a block (NameNode metadata).
    fn locate(&self, sid: u64, block: usize) -> Location;
    /// Read bytes `[off, off + len)` of a block into `buf` (cleared
    /// first) and return where the block lives. Disk half only — the
    /// caller owes the fabric a matching `transfer`/`transfer_group`.
    fn read_chunk(
        &self,
        sid: u64,
        block: usize,
        off: u64,
        len: usize,
        buf: &mut Vec<u8>,
    ) -> Result<Location>;
    /// Store a finished block at `at` and update the block map: if `at`
    /// is the block's canonical (policy) home the relocation override is
    /// dropped, otherwise it is (re)pointed at `at`.
    fn persist_block(&self, sid: u64, block: usize, at: Location, bytes: Vec<u8>) -> Result<()>;
    /// Drop a block replica from `at` (metadata is NOT touched — callers
    /// re-point via [`BlockFabric::persist_block`] first).
    fn remove_block(&self, sid: u64, block: usize, at: Location) -> Result<()>;
    /// Charge one modeled transfer (cross-rack accounting + links).
    fn transfer(&self, src: Location, dst: Location, bytes: u64, class: TrafficClass);
    /// Charge a batched inbound recovery-class group (DESIGN.md §10).
    fn transfer_group(&self, to: Location, flows: &[(Location, u64)]);
    /// Snapshot of the per-rack cross-rack byte counters (up, down).
    fn rack_byte_snapshot(&self) -> Vec<(u64, u64)>;
    /// Kill a node: erase its storage (recovery must rebuild from peers).
    fn fail_node(&self, loc: Location);
    /// Install a QoS split for a mixed-load run (DESIGN.md §11).
    fn set_qos(&self, cfg: QosConfig, fg_active: Arc<AtomicBool>);
    /// Remove the QoS split.
    fn clear_qos(&self);
    /// The recovery executor's per-chunk pacing hook.
    fn qos_pace(&self, _busy_s: f64) {}
    /// Nodes currently marked failed.
    fn failed_nodes(&self) -> Vec<Location>;
    /// Mark a node failed WITHOUT erasing its storage — the failure
    /// detector's escalation path for silent (crashed, partitioned)
    /// nodes whose disks may still hold bytes nobody can reach.
    fn mark_failed(&self, loc: Location);
    /// Probe every node not already failed and escalate unresponsive
    /// ones; returns the newly failed set. Fabrics without a liveness
    /// channel (the in-process cluster cannot lose a heartbeat) detect
    /// nothing.
    fn detect_failures(&self) -> Vec<Location> {
        Vec::new()
    }
    /// Checksum of the stored replica of `(sid, block)`, read back from
    /// its current location — the scrub pass's disk-side witness.
    fn stored_checksum(&self, sid: u64, block: usize) -> Result<u64>;
    /// Checksum recorded when the block was first written or recovered
    /// (`None` if the fabric never stored it).
    fn expected_checksum(&self, sid: u64, block: usize) -> Option<u64>;
    /// Flip one bit of the stored replica in place — the chaos layer's
    /// silent-disk-corruption hook, what [`run_scrub`] must catch.
    fn corrupt_stored(&self, sid: u64, block: usize) -> Result<()>;
    /// A replacement machine joins at a failed node's location and the
    /// fabric rebalances relocated blocks home (§5.3); returns how many
    /// blocks moved.
    fn rejoin_node(&self, loc: Location) -> Result<usize>;
    /// Fault-injection counters, when a chaos layer is armed.
    fn fault_report(&self) -> Option<crate::metrics::FaultReport> {
        None
    }
    /// Tell an armed chaos layer which worker its crash fuse kills.
    fn arm_crash_victim(&self, _loc: Location) {}
}

/// Per-rack-link (busy, stall) seconds accumulated since `before`, a
/// snapshot taken with [`LinkSet::link_busy_stall`] — the time analogue
/// of diffing two [`BlockFabric::rack_byte_snapshot`]s.
fn link_busy_stall_since<F: BlockFabric + ?Sized>(
    fabric: &F,
    before: &[(f64, f64)],
) -> Vec<(f64, f64)> {
    before
        .iter()
        .zip(fabric.links().link_busy_stall())
        .map(|(&(b0, s0), (b1, s1))| (b1 - b0, s1 - s0))
        .collect()
}

/// One plan's fetch structure with decode coefficients resolved at build
/// time (once per plan, not once per chunk): inner-rack aggregation
/// groups and the direct source set, each as `(block, coeff)` lists.
struct PlanFetch {
    /// (aggregator location, that rack's inputs).
    aggs: Vec<(Location, Vec<(usize, u8)>)>,
    /// Sources shipped straight to the compute node.
    direct: Vec<(usize, u8)>,
}

/// Chunk-level IO behind the pipelined executor: fetches source-chunk
/// bytes through the gated, token-bucket-throttled links into pooled
/// scratch buffers — per source, or per window through the batched
/// single-gate-acquisition path (DESIGN.md §10) — runs ONE fused
/// cache-blocked multiply-accumulate per aggregation group and per
/// direct-source set ([`gf::combine_many_into`], DESIGN.md §9), and
/// persists finished blocks into the NameNode metadata. Decode
/// coefficients are resolved once per plan, not once per chunk, and the
/// steady-state chunk loop allocates nothing — every buffer (including
/// the batched-fetch flow list) cycles through the worker's [`Scratch`]
/// pool. Generic over the fabric, so the identical chunk loop drives
/// both the in-process and the socket-backed cluster.
struct ChunkIo<'a, F: BlockFabric> {
    fabric: &'a F,
    /// Per-plan resolved fetch groups.
    fetch: Vec<PlanFetch>,
    /// Coalesce each task's same-destination fetches into one batched
    /// gated round trip (DESIGN.md §10) instead of one per source.
    batched: bool,
}

impl<'a, F: BlockFabric> ChunkIo<'a, F> {
    fn new(fabric: &'a F, plans: &[RepairPlan], batched: bool) -> ChunkIo<'a, F> {
        let code = fabric.code();
        let fetch = plans
            .iter()
            .map(|p| {
                let sources = p.source_blocks();
                let coeffs = plan_coefficients(&code, p);
                let coeff_of = |b: usize| -> u8 {
                    coeffs[sources.binary_search(&b).expect("source present")]
                };
                PlanFetch {
                    aggs: p
                        .aggregations
                        .iter()
                        .map(|agg| {
                            (
                                agg.at,
                                agg.inputs
                                    .iter()
                                    .map(|&(b, _)| (b, coeff_of(b)))
                                    .collect(),
                            )
                        })
                        .collect(),
                    direct: p.direct.iter().map(|&(b, _)| (b, coeff_of(b))).collect(),
                }
            })
            .collect();
        ChunkIo { fabric, fetch, batched }
    }

    /// Fetch every `(block, coeff)` source's `[off, off + len)` window to
    /// `to`, pushing `(coeff, bytes)` pairs onto `fetched`. Batched mode
    /// reads all windows from disk first and then moves the whole group
    /// through the links in one gated round trip; per-chunk mode issues
    /// one gated transfer per source (the pre-§10 baseline).
    #[allow(clippy::too_many_arguments)]
    fn fetch_sources(
        &self,
        stripe: u64,
        blocks: &[(usize, u8)],
        off: u64,
        len: usize,
        to: Location,
        scratch: &mut Scratch,
        fetched: &mut Vec<(u8, Vec<u8>)>,
    ) -> Result<()> {
        if self.batched {
            let mut flows = scratch.take_flows();
            for &(b, c) in blocks {
                let mut buf = scratch.take();
                match self.fabric.read_chunk(stripe, b, off, len, &mut buf) {
                    Ok(src) => {
                        flows.push((src, len as u64));
                        fetched.push((c, buf));
                    }
                    Err(e) => {
                        scratch.put(buf);
                        scratch.put_flows(flows);
                        return Err(e);
                    }
                }
            }
            self.fabric.transfer_group(to, &flows);
            scratch.put_flows(flows);
        } else {
            for &(b, c) in blocks {
                let mut buf = scratch.take();
                let src = self.fabric.read_chunk(stripe, b, off, len, &mut buf)?;
                self.fabric.transfer(src, to, len as u64, TrafficClass::Recovery);
                fetched.push((c, buf));
            }
        }
        Ok(())
    }
}

impl<F: BlockFabric> ChunkRunner for ChunkIo<'_, F> {
    fn run_chunk(
        &self,
        plan_idx: usize,
        plan: &RepairPlan,
        off: u64,
        len: usize,
        scratch: &mut Scratch,
    ) -> Result<Vec<u8>> {
        let fetch = &self.fetch[plan_idx];
        let mut acc = scratch.take_zeroed(len);
        let mut fetched = scratch.take_staging();
        for (at, inputs) in &fetch.aggs {
            // inner-rack aggregation at `at`, then ship ONE aggregated
            // chunk to the compute node
            let mut partial = scratch.take_zeroed(len);
            self.fetch_sources(plan.stripe, inputs, off, len, *at, scratch, &mut fetched)?;
            gf::combine_many_into(&mut partial, &fetched);
            for (_, buf) in fetched.drain(..) {
                scratch.put(buf);
            }
            self.fabric
                .transfer(*at, plan.compute_at, len as u64, TrafficClass::Recovery);
            gf::xor_into(&mut acc, &partial);
            scratch.put(partial);
        }
        self.fetch_sources(
            plan.stripe,
            &fetch.direct,
            off,
            len,
            plan.compute_at,
            scratch,
            &mut fetched,
        )?;
        gf::combine_many_into(&mut acc, &fetched);
        scratch.put_staging(fetched);
        Ok(acc)
    }

    fn finish_plan(&self, _plan_idx: usize, plan: &RepairPlan, block: Vec<u8>) -> Result<()> {
        if plan.persist {
            self.fabric
                .persist_block(plan.stripe, plan.failed_block, plan.writer, block)?;
        }
        Ok(())
    }

    fn throttle(&self, busy_s: f64) {
        self.fabric.qos_pace(busy_s);
    }
}

/// Plan-set recovery with full control of the pipelined executor
/// (DESIGN.md §8) on any [`BlockFabric`]: plans are split into
/// `cfg.chunk_size` tasks, scheduled over `cfg.workers` threads, and
/// every transfer runs under the per-node / per-rack-link in-flight
/// caps. λ is computed over the racks not in `failed_racks`; traffic
/// accounting covers exactly this recovery.
pub fn recover_with_plans_cfg<F: BlockFabric>(
    fabric: &F,
    plans: Vec<RepairPlan>,
    cfg: ExecutorConfig,
    failed_racks: &[u32],
) -> Result<ClusterRecoveryStats> {
    let mut cfg = cfg;
    // the balanced scheduler tiles its coloring across the placement
    // period when the policy is periodic (DESIGN.md §10)
    if cfg.period.is_none() {
        cfg.period = fabric.period();
    }
    // the scrub daemon's backoff signal (DESIGN.md §15): recovery is in
    // flight on this fabric until the executor returns
    let _recovery_mark = fabric.links().mark_recovery();
    let before = fabric.rack_byte_snapshot();
    let links_before = fabric.links().link_busy_stall();
    let blocks = plans.len();
    let bytes: u64 = blocks as u64 * fabric.block_size();
    fabric.links().set_inflight_caps(cfg.node_inflight, cfg.link_inflight);
    let io = ChunkIo::new(fabric, &plans, cfg.batched_fetch);
    let run = execute_plans(&io, &plans, fabric.block_size(), &cfg);
    // lift the caps so post-recovery traffic (reads, writes) is ungated
    fabric.links().set_inflight_caps(0, 0);
    let stats = run?;
    let after = fabric.rack_byte_snapshot();
    let rack_bytes: Vec<(u64, u64)> = before
        .iter()
        .zip(&after)
        .map(|(&(u0, d0), &(u1, d1))| (u1 - u0, d1 - d0))
        .collect();
    let link_busy_stall = link_busy_stall_since(fabric, &links_before);
    let loads: Vec<(f64, f64)> = rack_bytes.iter().map(|&(u, d)| (u as f64, d as f64)).collect();
    let lambda = crate::sim::recovery::lambda_metric_excluding(&loads, failed_racks);
    let secs = stats.wall_s;
    Ok(ClusterRecoveryStats {
        blocks,
        bytes,
        wall: Duration::from_secs_f64(secs),
        throughput_mb_s: if secs > 0.0 { bytes as f64 / secs / 1e6 } else { 0.0 },
        rack_bytes,
        lambda,
        chunks: stats.chunks,
        rounds: stats.rounds,
        worker_utilization: stats.utilization(),
        scratch: stats.scratch,
        link_busy_stall,
    })
}

/// Counters of the failover/replan loop around
/// [`recover_with_plans_cfg`] (DESIGN.md §14).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct ReplanStats {
    /// Executor rounds run (1 = clean first pass).
    pub rounds: u64,
    /// Plans re-issued against surviving sources after a failover.
    pub replanned: u64,
    /// Nodes newly escalated to failed between rounds.
    pub detected: u64,
}

/// Failure-tolerant recovery (DESIGN.md §14): run the plan set, and when
/// a round errors — a worker crashed mid-recovery, sources went silent —
/// sweep for newly failed nodes ([`BlockFabric::detect_failures`]),
/// re-plan every still-missing block against the survivors, and go again
/// (up to `max_rounds` executor rounds). A round that fails without
/// revealing any new failure carries a real error and propagates. A clean
/// first pass returns exactly [`recover_with_plans_cfg`]'s stats, so
/// fault-free and crash-free fault-injected runs keep byte-level parity.
#[allow(clippy::too_many_arguments)]
pub fn recover_with_replan<F: BlockFabric>(
    fabric: &F,
    policy: &dyn Placement,
    stripes: u64,
    mut failed: Vec<Location>,
    mut plans: Vec<RepairPlan>,
    cfg: ExecutorConfig,
    seed: u64,
    max_rounds: u64,
) -> Result<(ClusterRecoveryStats, ReplanStats)> {
    let t0 = Instant::now();
    let before = fabric.rack_byte_snapshot();
    let links_before = fabric.links().link_busy_stall();
    let mut rstats = ReplanStats::default();
    // every block key ever planned — the multi-round block count is how
    // many of these ended up on a live node, not a sum of round sizes
    // (errored rounds persist part of their plan set)
    let mut keys: HashSet<(u64, usize)> =
        plans.iter().map(|p| (p.stripe, p.failed_block)).collect();
    loop {
        rstats.rounds += 1;
        let racks = distinct_racks(&failed);
        match recover_with_plans_cfg(fabric, plans.clone(), cfg, &racks) {
            Ok(stats) => {
                if rstats.rounds == 1 {
                    return Ok((stats, rstats));
                }
                // multi-round: per-round stats only cover the last
                // round's traffic — rebuild aggregates over the whole run
                let after = fabric.rack_byte_snapshot();
                let rack_bytes: Vec<(u64, u64)> = before
                    .iter()
                    .zip(&after)
                    .map(|(&(u0, d0), &(u1, d1))| (u1 - u0, d1 - d0))
                    .collect();
                let blocks = keys
                    .iter()
                    .filter(|&&(sid, b)| !failed.contains(&fabric.locate(sid, b)))
                    .count();
                let bytes = blocks as u64 * fabric.block_size();
                let secs = t0.elapsed().as_secs_f64();
                let loads: Vec<(f64, f64)> =
                    rack_bytes.iter().map(|&(u, d)| (u as f64, d as f64)).collect();
                let lambda =
                    crate::sim::recovery::lambda_metric_excluding(&loads, &racks);
                let link_busy_stall = link_busy_stall_since(fabric, &links_before);
                return Ok((
                    ClusterRecoveryStats {
                        blocks,
                        bytes,
                        wall: t0.elapsed(),
                        throughput_mb_s: if secs > 0.0 {
                            bytes as f64 / secs / 1e6
                        } else {
                            0.0
                        },
                        rack_bytes,
                        lambda,
                        chunks: stats.chunks,
                        rounds: stats.rounds,
                        worker_utilization: stats.worker_utilization,
                        scratch: stats.scratch,
                        link_busy_stall,
                    },
                    rstats,
                ));
            }
            Err(e) => {
                if rstats.rounds >= max_rounds {
                    return Err(e.context(format!(
                        "recovery still failing after {} rounds",
                        rstats.rounds
                    )));
                }
                fabric.detect_failures();
                let now_failed = fabric.failed_nodes();
                let new: Vec<Location> = now_failed
                    .iter()
                    .copied()
                    .filter(|l| !failed.contains(l))
                    .collect();
                if new.is_empty() {
                    // nothing changed underneath us — the error is real
                    return Err(e);
                }
                rstats.detected += new.len() as u64;
                failed = now_failed;
                // re-plan against the survivors, keeping only blocks that
                // are still missing (earlier rounds persisted the rest)
                let mut next = crate::recovery::multi::scenario_recovery_plans(
                    policy, stripes, &failed, seed,
                )?;
                next.retain(|p| failed.contains(&fabric.locate(p.stripe, p.failed_block)));
                keys.extend(next.iter().map(|p| (p.stripe, p.failed_block)));
                rstats.replanned += next.len() as u64;
                plans = next;
            }
        }
    }
}

/// The surviving node writing the most recovered blocks — the chaos
/// layer's crash victim, so an armed crash fuse lands mid-recovery on a
/// node the executor actually depends on. Ties break to the earliest
/// plan order, keeping the choice deterministic.
pub fn crash_victim(plans: &[RepairPlan], failed: &[Location]) -> Option<Location> {
    let mut count: HashMap<Location, usize> = HashMap::new();
    let mut best: Option<(Location, usize)> = None;
    for p in plans {
        if failed.contains(&p.writer) {
            continue;
        }
        let c = count.entry(p.writer).or_insert(0);
        *c += 1;
        match best {
            Some((_, n)) if *c <= n => {}
            _ => best = Some((p.writer, *c)),
        }
    }
    best.map(|(loc, _)| loc)
}

/// Outcome of one scrub-and-repair pass (DESIGN.md §14).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct ScrubReport {
    /// Replicas whose stored checksum was compared to the registry.
    pub scanned: u64,
    /// Corrupt replicas dropped from their node.
    pub quarantined: u64,
    /// Quarantined blocks rebuilt from survivors and re-verified.
    pub repaired: u64,
}

/// Scrub stripes `0..stripes`: read back every reachable replica's
/// checksum ([`BlockFabric::stored_checksum`] — a disk-only probe, no
/// modeled transfer), compare it to the write-time registry, quarantine
/// mismatches (drop the replica), rebuild them from surviving sources
/// through the normal repair planner — priced as recovery traffic — and
/// re-verify the rebuilt bytes. Replicas on failed nodes are the failure
/// detector's job, not the scrub's, and are skipped; a block that is
/// still corrupt after its re-repair is an error.
pub fn run_scrub<F: BlockFabric>(
    fabric: &F,
    policy: &dyn Placement,
    stripes: u64,
    cfg: ExecutorConfig,
    seed: u64,
) -> Result<ScrubReport> {
    let code = fabric.code();
    let failed_set: HashSet<Location> = fabric.failed_nodes().into_iter().collect();
    let mut report = ScrubReport::default();
    // grouped per stripe so same-stripe double corruption goes through
    // the multi-erasure planner instead of two plans reading each other
    let mut bad: BTreeMap<u64, Vec<usize>> = BTreeMap::new();
    for sid in 0..stripes {
        for b in 0..code.len() {
            if failed_set.contains(&fabric.locate(sid, b)) {
                continue;
            }
            let Some(want) = fabric.expected_checksum(sid, b) else { continue };
            let Ok(got) = fabric.stored_checksum(sid, b) else { continue };
            report.scanned += 1;
            if got != want {
                bad.entry(sid).or_default().push(b);
            }
        }
    }
    let (quarantined, repaired) = quarantine_and_repair(fabric, policy, &bad, cfg, seed)?;
    report.quarantined = quarantined;
    report.repaired = repaired;
    Ok(report)
}

/// Quarantine every `stripe → corrupt blocks` entry (drop the replicas),
/// rebuild them from surviving sources through the normal repair planner
/// — priced as recovery traffic — and re-verify the rebuilt bytes. The
/// shared tail of the one-shot scrub pass and the continuous scrub
/// daemon's cycles (DESIGN.md §15). Block lists must be ascending (the
/// planner's contract); same-stripe multi-corruption goes through the
/// multi-erasure planner as one stripe, so plans never read each other's
/// quarantined replicas. Returns `(quarantined, repaired)`; a block that
/// is still corrupt after its re-repair is an error.
pub fn quarantine_and_repair<F: BlockFabric>(
    fabric: &F,
    policy: &dyn Placement,
    bad: &BTreeMap<u64, Vec<usize>>,
    cfg: ExecutorConfig,
    seed: u64,
) -> Result<(u64, u64)> {
    let failed_set: HashSet<Location> = fabric.failed_nodes().into_iter().collect();
    let mut quarantined = 0u64;
    let mut plans = Vec::new();
    for (&sid, blocks) in bad {
        for &b in blocks {
            fabric.remove_block(sid, b, fabric.locate(sid, b))?;
            quarantined += 1;
        }
        plans.extend(crate::recovery::multi::stripe_repair_plans(
            policy, sid, blocks, &failed_set, seed,
        )?);
    }
    if plans.is_empty() {
        return Ok((quarantined, 0));
    }
    recover_with_plans_cfg(fabric, plans, cfg, &[])?;
    let mut repaired = 0u64;
    for (&sid, blocks) in bad {
        for &b in blocks {
            let want = fabric
                .expected_checksum(sid, b)
                .expect("quarantined block had a registry entry");
            if fabric.stored_checksum(sid, b)? != want {
                bail!("scrub re-repair of ({sid},{b}) left a corrupt replica");
            }
            repaired += 1;
        }
    }
    Ok((quarantined, repaired))
}

/// Run recovery and a foreground request sequence concurrently under
/// `qos` (DESIGN.md §11): install the split, drive the client engine
/// beside the recovery executor, remove the split afterwards. The ONE
/// mixed-load orchestration, shared by every backend and the perf
/// harness — the fg-activity flag's lifecycle lives here.
#[allow(clippy::too_many_arguments)]
pub fn run_mixed_load<F: BlockFabric + ClientIo>(
    fabric: &F,
    plans: Vec<RepairPlan>,
    cfg: ExecutorConfig,
    failed_racks: &[u32],
    reqs: &[Request],
    arrival: ArrivalModel,
    fg_workers: usize,
    qos: QosConfig,
) -> Result<(ClusterRecoveryStats, FgOutcome)> {
    let fg_active = Arc::new(AtomicBool::new(true));
    fabric.set_qos(qos, fg_active.clone());
    let flag: &AtomicBool = fg_active.as_ref();
    let (stats, fgout) = std::thread::scope(|scope| {
        let engine = scope.spawn(move || {
            crate::client::run_on_cluster(fabric, reqs, arrival, fg_workers, Some(flag))
        });
        let stats = recover_with_plans_cfg(fabric, plans, cfg, failed_racks);
        (stats, engine.join().expect("client engine thread"))
    });
    fabric.clear_qos();
    Ok((stats?, fgout?))
}

/// Execute §5.3 layout-maintenance migration batches on a fabric: each
/// move reads the block at its post-recovery writer, ships it to the
/// relived node's replacement (recovery-class traffic, exactly the flow
/// [`crate::sim::recovery::run_migration`] models), persists it there —
/// which drops the relocation override when the target is the canonical
/// home — and removes the stray replica. Returns per-batch wall seconds,
/// index-aligned with the sim's per-batch times.
pub fn run_migration<F: BlockFabric>(
    fabric: &F,
    batches: &[MigrationBatch],
    relived: Location,
) -> Result<Vec<f64>> {
    let bs = fabric.block_size();
    let mut times = Vec::with_capacity(batches.len());
    let mut buf = Vec::new();
    for batch in batches {
        let t0 = Instant::now();
        for mv in &batch.moves {
            fabric.read_chunk(mv.stripe, mv.block, 0, bs as usize, &mut buf)?;
            fabric.transfer(mv.from, relived, bs, TrafficClass::Recovery);
            fabric.persist_block(mv.stripe, mv.block, relived, std::mem::take(&mut buf))?;
            if mv.from != relived {
                fabric.remove_block(mv.stripe, mv.block, mv.from)?;
            }
        }
        times.push(t0.elapsed().as_secs_f64());
    }
    Ok(times)
}

/// The scenario engine's shared backend body (DESIGN.md §5, §13): fail,
/// recover (or serve the degraded burst / mixed load) and assemble a
/// [`ScenarioOutcome`] tagged with `backend`. `populate` builds a fresh,
/// fully written fabric — called once for the measured run and once more
/// for the isolated mixed-load baseline.
pub fn run_scenario<F, P>(
    backend: &'static str,
    scenario: &FailureScenario,
    policy: &Arc<dyn Placement>,
    populate: P,
    cfg: ExecutorConfig,
    workers: usize,
    block_size: u64,
) -> Result<ScenarioOutcome>
where
    F: BlockFabric + ClientIo,
    P: Fn() -> Result<F>,
{
    let cluster = populate()?;

    if matches!(scenario.kind, ScenarioKind::DegradedBurst { .. }) {
        // pure foreground load: the client engine *is* the scenario —
        // no separate burst loop (DESIGN.md §11); one table serves
        // generation and plan derivation
        let table = PlacementTable::build(policy.clone(), scenario.stripes);
        let (fgspec, reqs) = scenario
            .fg_requests_with(&table)?
            .expect("degraded burst always carries fg traffic");
        let failed = scenario.failed_nodes(policy.as_ref())[0];
        cluster.fail_node(failed);
        let plans = degraded_read_plans(&table, &reqs, scenario.seed);
        let before = cluster.rack_byte_snapshot();
        let links_before = cluster.links().link_busy_stall();
        let out =
            crate::client::run_on_cluster(&cluster, &reqs, fgspec.arrival, workers, None)?;
        let after = cluster.rack_byte_snapshot();
        let rack_cross_bytes: Vec<(u64, u64)> = before
            .iter()
            .zip(&after)
            .map(|(&(u0, d0), &(u1, d1))| (u1 - u0, d1 - d0))
            .collect();
        let link_busy_stall = link_busy_stall_since(&cluster, &links_before);
        let summary = out.summary();
        let mean = summary.as_ref().map(|s| s.mean).unwrap_or(0.0);
        let loads: Vec<(f64, f64)> =
            rack_cross_bytes.iter().map(|&(u, d)| (u as f64, d as f64)).collect();
        let wall = out.seconds;
        let bytes = out.served() as u64 * block_size;
        return Ok(ScenarioOutcome {
            backend,
            scenario: scenario.name(),
            policy: policy.name().to_string(),
            blocks: out.served(),
            bytes,
            seconds: wall,
            throughput_mb_s: if wall > 0.0 { bytes as f64 / wall / 1e6 } else { 0.0 },
            lambda: crate::sim::recovery::lambda_metric_excluding(&loads, &[failed.rack]),
            rack_cross_bytes,
            planned_cross_rack_blocks: planned_cross_rack_blocks(&plans),
            degraded_read_mean_s: Some(mean),
            frontend_seconds: None,
            worker_utilization: None,
            scratch_pool: None,
            link_busy_stall: Some(link_busy_stall),
            fg_latency: summary,
            recovery_slowdown: None,
            faults: cluster.fault_report(),
            trace: None,
        });
    }

    let (failed, plans) = scenario.recovery_plans(policy)?;
    for &f in &failed {
        cluster.fail_node(f);
    }
    let planned = planned_cross_rack_blocks(&plans);
    let racks = distinct_racks(&failed);
    let Some((fgspec, reqs)) = scenario.fg_requests(policy)? else {
        // plain recovery: no foreground traffic, no QoS split. The
        // failover/replan loop absorbs chaos-layer crashes (§14); a
        // clean first pass is bit-identical to the bare executor call.
        if let Some(victim) = crash_victim(&plans, &failed) {
            cluster.arm_crash_victim(victim);
        }
        let (stats, replans) = recover_with_replan(
            &cluster,
            policy.as_ref(),
            scenario.stripes,
            failed,
            plans,
            cfg,
            scenario.seed,
            3,
        )?;
        let mut out = backend_outcome(backend, scenario, policy.name(), &stats, planned, None);
        // failovers are counted by the fabric's own detection sweep;
        // only the re-issued plan count lives out here
        out.faults = cluster.fault_report().map(|mut f| {
            f.replans += replans.replanned;
            f
        });
        return Ok(out);
    };

    // mixed load: recovery and the client engine share the links under
    // the scenario's QoS split. The slowdown factor needs the same
    // recovery measured alone, on an identically populated cluster.
    let baseline_s = {
        let isolated = populate()?;
        for &f in &failed {
            isolated.fail_node(f);
        }
        recover_with_plans_cfg(&isolated, plans.clone(), cfg, &racks)?.wall.as_secs_f64()
    };
    let (stats, fgout) = run_mixed_load(
        &cluster,
        plans,
        cfg,
        &racks,
        &reqs,
        fgspec.arrival,
        workers,
        scenario.qos,
    )?;
    let mut out =
        backend_outcome(backend, scenario, policy.name(), &stats, planned, Some(fgout.seconds));
    out.fg_latency = fgout.summary();
    out.recovery_slowdown = Some(stats.wall.as_secs_f64() / baseline_s.max(1e-9));
    out.faults = cluster.fault_report();
    Ok(out)
}

fn backend_outcome(
    backend: &'static str,
    scenario: &FailureScenario,
    policy_name: &str,
    stats: &ClusterRecoveryStats,
    planned_cross_rack_blocks: usize,
    frontend_seconds: Option<f64>,
) -> ScenarioOutcome {
    ScenarioOutcome {
        backend,
        scenario: scenario.name(),
        policy: policy_name.to_string(),
        blocks: stats.blocks,
        bytes: stats.bytes,
        seconds: stats.wall.as_secs_f64(),
        throughput_mb_s: stats.throughput_mb_s,
        lambda: stats.lambda,
        rack_cross_bytes: stats.rack_bytes.clone(),
        planned_cross_rack_blocks,
        degraded_read_mean_s: None,
        frontend_seconds,
        worker_utilization: Some(stats.worker_utilization.clone()),
        scratch_pool: Some(stats.scratch),
        link_busy_stall: Some(stats.link_busy_stall.clone()),
        fg_latency: None,
        recovery_slowdown: None,
        faults: None,
        trace: None,
    }
}
