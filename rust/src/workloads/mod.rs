//! Front-end Hadoop benchmark models (paper Table 2).
//!
//! We reproduce the *traffic shape* of each benchmark, not MapReduce
//! semantics (DESIGN.md §2): Pi is CPU-bound with negligible I/O;
//! Terasort is CPU+network (full shuffle of the sampled table); Wordcount
//! and Grep are network-intensive text scans with large shuffles.

/// Resource demands of one benchmark run (bytes are totals across tasks).
#[derive(Clone, Debug)]
pub struct WorkloadSpec {
    pub name: &'static str,
    pub maps: usize,
    pub reduces: usize,
    /// HDFS input read by the map phase.
    pub input_bytes: u64,
    /// Intermediate data shuffled map→reduce (cross-node traffic).
    pub shuffle_bytes: u64,
    /// Final output written by reducers.
    pub output_bytes: u64,
    /// CPU demand expressed as GF-equivalent bytes (calibrated against the
    /// per-node coding throughput in `CpuSpec`).
    pub cpu_bytes_equiv: u64,
}

impl WorkloadSpec {
    /// Scale all demands by `f` (models multi-wave task execution /
    /// framework overhead so simulated durations match real Hadoop jobs,
    /// which run for minutes at Table 2's configurations). Saturating:
    /// an absurd factor pins demands at `u64::MAX` instead of wrapping
    /// into a tiny (or zero) workload.
    pub fn scaled(&self, f: u64) -> WorkloadSpec {
        WorkloadSpec {
            name: self.name,
            maps: self.maps,
            reduces: self.reduces,
            input_bytes: self.input_bytes.saturating_mul(f),
            shuffle_bytes: self.shuffle_bytes.saturating_mul(f),
            output_bytes: self.output_bytes.saturating_mul(f),
            cpu_bytes_equiv: self.cpu_bytes_equiv.saturating_mul(f),
        }
    }
}

/// The four benchmarks of Table 2, scaled to the 24-node testbed.
pub fn specs() -> Vec<WorkloadSpec> {
    vec![
        // Pi: 100 maps × 100m samples — pure compute, tiny I/O.
        WorkloadSpec {
            name: "pi",
            maps: 100,
            reduces: 1,
            input_bytes: 0,
            shuffle_bytes: 100 << 10, // per-map counts only
            output_bytes: 1 << 10,
            cpu_bytes_equiv: 192 << 30, // dominates: BBP iterations
        },
        // Terasort: 5m records × 100 B = 500 MB table, fully shuffled.
        WorkloadSpec {
            name: "terasort",
            maps: 48,
            reduces: 24,
            input_bytes: 500 << 20,
            shuffle_bytes: 500 << 20,
            output_bytes: 500 << 20,
            cpu_bytes_equiv: 24 << 30,
        },
        // Wordcount: 100m words ≈ 700 MB text, combiner shrinks shuffle.
        WorkloadSpec {
            name: "wordcount",
            maps: 48,
            reduces: 24,
            input_bytes: 700 << 20,
            shuffle_bytes: 350 << 20,
            output_bytes: 80 << 20,
            cpu_bytes_equiv: 16 << 30,
        },
        // Grep: scan + extract + sort-by-frequency: big scan, mid shuffle.
        WorkloadSpec {
            name: "grep",
            maps: 48,
            reduces: 24,
            input_bytes: 700 << 20,
            shuffle_bytes: 450 << 20,
            output_bytes: 40 << 20,
            cpu_bytes_equiv: 12 << 30,
        },
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_2_workloads_present() {
        let names: Vec<&str> = specs().iter().map(|w| w.name).collect();
        assert_eq!(names, vec!["pi", "terasort", "wordcount", "grep"]);
    }

    #[test]
    fn pi_is_compute_dominated() {
        let all = specs();
        let pi = &all[0];
        assert!(pi.cpu_bytes_equiv > 100 * (pi.input_bytes + pi.shuffle_bytes));
    }

    #[test]
    fn network_workloads_shuffle_heavily() {
        for w in specs().iter().filter(|w| w.name != "pi") {
            assert!(w.shuffle_bytes > 100 << 20, "{} shuffle too small", w.name);
        }
    }

    #[test]
    fn scaled_saturates_instead_of_wrapping() {
        let all = specs();
        let ts = all.iter().find(|w| w.name == "terasort").unwrap();
        let sane = ts.scaled(20);
        assert_eq!(sane.input_bytes, ts.input_bytes * 20);
        assert_eq!(sane.cpu_bytes_equiv, ts.cpu_bytes_equiv * 20);
        // 500 MB × 2^60 wraps under plain multiplication; it must pin
        let huge = ts.scaled(1 << 60);
        assert_eq!(huge.input_bytes, u64::MAX);
        assert_eq!(huge.shuffle_bytes, u64::MAX);
        assert_eq!(huge.output_bytes, u64::MAX);
        assert_eq!(huge.cpu_bytes_equiv, u64::MAX);
        assert_eq!(huge.maps, ts.maps, "task counts are not scaled");
    }
}
