//! Fluid (flow-level) discrete-event engine with max-min fair sharing.
//!
//! Jobs are small DAGs of activities; an activity is either a fixed
//! [`Work::Delay`] (e.g. disk seek) or a [`Work::Flow`] of `bytes` across a
//! set of resources (disk, NIC, router port, CPU). Active flows share every
//! resource max-min fairly (progressive waterfilling — the standard fluid
//! approximation of TCP fair sharing on a tree network); events are flow /
//! timer completions, and rates are recomputed at each event.
//!
//! This is the testbed substitute (DESIGN.md §2): the paper's recovery
//! results are bandwidth-dominated, and max-min fair port sharing
//! reproduces the contention that produces them.

use super::resources::ResourceId;

/// What an activity does once its dependencies complete.
#[derive(Clone, Debug)]
pub enum Work {
    /// Fixed latency in seconds.
    Delay(f64),
    /// Move/process `bytes` across all `resources` simultaneously
    /// (a transfer holds NIC up + NIC down + router ports; a disk read
    /// holds the disk; compute holds the CPU).
    Flow { resources: Vec<ResourceId>, bytes: f64 },
}

/// One node of a job DAG. `deps` are indices of activities within the
/// same job that must finish first.
#[derive(Clone, Debug)]
pub struct Activity {
    pub work: Work,
    pub deps: Vec<u32>,
}

/// A job: a DAG of activities. The job completes when all activities do.
#[derive(Clone, Debug, Default)]
pub struct JobSpec {
    pub activities: Vec<Activity>,
}

impl JobSpec {
    /// Append an activity, returning its index for later `deps` edges.
    pub fn push(&mut self, work: Work, deps: Vec<u32>) -> u32 {
        self.activities.push(Activity { work, deps });
        (self.activities.len() - 1) as u32
    }
}

pub type JobId = u32;

#[derive(Clone, Copy, Debug, PartialEq)]
struct ActKey {
    job: JobId,
    act: u32,
}

struct JobState {
    spec: JobSpec,
    /// unmet dependency count per activity
    waiting: Vec<u32>,
    /// dependents per activity
    rdeps: Vec<Vec<u32>>,
    remaining_activities: usize,
    finish_time: f64,
    /// accumulated bytes accounted per resource (for load metrics)
    started: bool,
}

struct FlowState {
    key: ActKey,
    resources: Vec<ResourceId>,
    remaining: f64,
    rate: f64,
}

/// The engine. Drive it with [`Engine::add_job`] + [`Engine::run_until`]
/// (or [`Engine::run_to_completion`]).
pub struct Engine {
    now: f64,
    caps: Vec<f64>,
    jobs: Vec<JobState>,
    flows: Vec<FlowState>,
    /// timers: (fire_time, key), min-heap
    timers: std::collections::BinaryHeap<std::cmp::Reverse<(OrdF64, JobId, u32)>>,
    completed_jobs: Vec<JobId>,
    /// total bytes that have traversed each resource (metrics)
    pub resource_bytes: Vec<f64>,
    rates_dirty: bool,
}

/// Total-ordered f64 for the timer heap (times are always finite).
#[derive(Clone, Copy, PartialEq, PartialOrd)]
struct OrdF64(f64);
impl Eq for OrdF64 {}
#[allow(clippy::derive_ord_xor_partial_ord)]
impl Ord for OrdF64 {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        self.partial_cmp(other).expect("finite times")
    }
}

impl Engine {
    pub fn new(caps: Vec<f64>) -> Engine {
        let n = caps.len();
        Engine {
            now: 0.0,
            caps,
            jobs: Vec::new(),
            flows: Vec::new(),
            timers: std::collections::BinaryHeap::new(),
            completed_jobs: Vec::new(),
            resource_bytes: vec![0.0; n],
            rates_dirty: false,
        }
    }

    pub fn now(&self) -> f64 {
        self.now
    }

    /// Register a job without starting it (admission controlled by caller).
    pub fn add_job(&mut self, spec: JobSpec) -> JobId {
        let n = spec.activities.len();
        assert!(n > 0, "empty job");
        let mut waiting = vec![0u32; n];
        let mut rdeps = vec![Vec::new(); n];
        for (i, a) in spec.activities.iter().enumerate() {
            waiting[i] = a.deps.len() as u32;
            for &d in &a.deps {
                assert!((d as usize) < n && d as usize != i, "bad dep edge");
                rdeps[d as usize].push(i as u32);
            }
        }
        self.jobs.push(JobState {
            spec,
            waiting,
            rdeps,
            remaining_activities: n,
            finish_time: f64::NAN,
            started: false,
        });
        (self.jobs.len() - 1) as JobId
    }

    /// Start a previously added job: all zero-dep activities begin now.
    pub fn start_job(&mut self, job: JobId) {
        let state = &mut self.jobs[job as usize];
        assert!(!state.started, "job started twice");
        state.started = true;
        let ready: Vec<u32> = (0..state.spec.activities.len() as u32)
            .filter(|&i| state.waiting[i as usize] == 0)
            .collect();
        assert!(!ready.is_empty(), "job has no root activity (dependency cycle)");
        for act in ready {
            self.start_activity(ActKey { job, act });
        }
    }

    /// Convenience: add + start.
    pub fn spawn(&mut self, spec: JobSpec) -> JobId {
        let id = self.add_job(spec);
        self.start_job(id);
        id
    }

    fn start_activity(&mut self, key: ActKey) {
        let work = self.jobs[key.job as usize].spec.activities[key.act as usize].work.clone();
        match work {
            Work::Delay(secs) => {
                assert!(secs >= 0.0);
                self.timers.push(std::cmp::Reverse((OrdF64(self.now + secs), key.job, key.act)));
            }
            Work::Flow { resources, bytes } => {
                if resources.is_empty() || bytes <= 0.0 {
                    // local no-op (e.g. src == dst transfer): complete now
                    self.timers.push(std::cmp::Reverse((OrdF64(self.now), key.job, key.act)));
                    return;
                }
                for &r in &resources {
                    self.resource_bytes[r as usize] += bytes;
                }
                self.flows.push(FlowState { key, resources, remaining: bytes, rate: 0.0 });
                self.rates_dirty = true;
            }
        }
    }

    /// Progressive max-min waterfilling over all active flows.
    fn recompute_rates(&mut self) {
        self.rates_dirty = false;
        let nf = self.flows.len();
        if nf == 0 {
            return;
        }
        let nr = self.caps.len();
        let mut remaining_cap = self.caps.clone();
        let mut active_count = vec![0u32; nr];
        for f in &self.flows {
            for &r in &f.resources {
                active_count[r as usize] += 1;
            }
        }
        let mut assigned = vec![false; nf];
        let mut unassigned = nf;
        while unassigned > 0 {
            // bottleneck resource: min fair share among resources with flows
            let mut best_share = f64::INFINITY;
            let mut best_res = usize::MAX;
            for r in 0..nr {
                if active_count[r] > 0 {
                    let share = remaining_cap[r] / active_count[r] as f64;
                    if share < best_share {
                        best_share = share;
                        best_res = r;
                    }
                }
            }
            if best_res == usize::MAX {
                break;
            }
            // freeze all unassigned flows crossing the bottleneck
            let mut froze = false;
            for i in 0..nf {
                if assigned[i] || !self.flows[i].resources.contains(&(best_res as ResourceId)) {
                    continue;
                }
                froze = true;
                assigned[i] = true;
                unassigned -= 1;
                self.flows[i].rate = best_share;
                for &r in &self.flows[i].resources {
                    remaining_cap[r as usize] -= best_share;
                    active_count[r as usize] -= 1;
                }
                remaining_cap[best_res] = remaining_cap[best_res].max(0.0);
            }
            if !froze {
                active_count[best_res] = 0; // defensive: no flows on it
            }
        }
    }

    /// Advance until the next event; returns jobs completed at that event.
    /// `None` when nothing is left to run.
    pub fn run_until_event(&mut self) -> Option<Vec<JobId>> {
        if self.rates_dirty {
            self.recompute_rates();
        }
        // next flow completion
        let mut t_flow = f64::INFINITY;
        for f in &self.flows {
            if f.rate > 0.0 {
                t_flow = t_flow.min(self.now + f.remaining / f.rate);
            }
        }
        let t_timer = self.timers.peek().map(|std::cmp::Reverse((t, _, _))| t.0);
        let t_next = match t_timer {
            Some(tt) => t_flow.min(tt),
            None => t_flow,
        };
        if !t_next.is_finite() {
            return None;
        }
        // advance flows
        let dt = t_next - self.now;
        self.now = t_next;
        let mut finished_keys: Vec<ActKey> = Vec::new();
        let eps = 1e-7;
        self.flows.retain_mut(|f| {
            f.remaining -= f.rate * dt;
            if f.remaining <= eps * f.rate.max(1.0) {
                finished_keys.push(f.key);
                false
            } else {
                true
            }
        });
        // fire due timers
        while let Some(std::cmp::Reverse((t, job, act))) = self.timers.peek().copied() {
            if t.0 <= self.now + 1e-12 {
                self.timers.pop();
                finished_keys.push(ActKey { job, act });
            } else {
                break;
            }
        }
        if !finished_keys.is_empty() {
            self.rates_dirty = true;
        }
        let mut completed = Vec::new();
        for key in finished_keys {
            self.finish_activity(key, &mut completed);
        }
        Some(completed)
    }

    fn finish_activity(&mut self, key: ActKey, completed: &mut Vec<JobId>) {
        let js = &mut self.jobs[key.job as usize];
        js.remaining_activities -= 1;
        let ready: Vec<u32> = js.rdeps[key.act as usize]
            .iter()
            .copied()
            .filter(|&d| {
                let w = &mut js.waiting[d as usize];
                *w -= 1;
                *w == 0
            })
            .collect();
        if js.remaining_activities == 0 {
            js.finish_time = self.now;
            completed.push(key.job);
            self.completed_jobs.push(key.job);
        }
        for act in ready {
            self.start_activity(ActKey { job: key.job, act });
        }
    }

    /// Run everything currently started to completion (no admission).
    pub fn run_to_completion(&mut self) {
        while self.run_until_event().is_some() {}
    }

    pub fn finish_time(&self, job: JobId) -> f64 {
        self.jobs[job as usize].finish_time
    }

    pub fn completed_count(&self) -> usize {
        self.completed_jobs.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn flow(res: Vec<ResourceId>, bytes: f64, deps: Vec<u32>) -> Activity {
        Activity { work: Work::Flow { resources: res, bytes }, deps }
    }

    #[test]
    fn single_flow_takes_bytes_over_capacity() {
        let mut e = Engine::new(vec![100.0]);
        let mut j = JobSpec::default();
        j.push(Work::Flow { resources: vec![0], bytes: 500.0 }, vec![]);
        let id = e.spawn(j);
        e.run_to_completion();
        assert!((e.finish_time(id) - 5.0).abs() < 1e-6);
    }

    #[test]
    fn two_flows_share_fairly() {
        // two equal flows on one resource: both finish at 2 × solo time
        let mut e = Engine::new(vec![100.0]);
        let mk = || JobSpec { activities: vec![flow(vec![0], 100.0, vec![])] };
        let a = e.spawn(mk());
        let b = e.spawn(mk());
        e.run_to_completion();
        assert!((e.finish_time(a) - 2.0).abs() < 1e-6);
        assert!((e.finish_time(b) - 2.0).abs() < 1e-6);
    }

    #[test]
    fn max_min_gives_leftover_to_unbottlenecked_flow() {
        // res0 cap 100 shared by f1 (res0) and f2 (res0+res1 cap 30).
        // f2 is capped at 30 by res1; f1 gets 70.
        let mut e = Engine::new(vec![100.0, 30.0]);
        let f1 = e.spawn(JobSpec { activities: vec![flow(vec![0], 700.0, vec![])] });
        let f2 = e.spawn(JobSpec { activities: vec![flow(vec![0, 1], 30.0, vec![])] });
        e.run_to_completion();
        assert!((e.finish_time(f2) - 1.0).abs() < 1e-6, "f2 at rate 30");
        // f1: 70 B/s while f2 active (1s → 70 B), then 100 B/s for 630 B → 7.3s
        assert!((e.finish_time(f1) - 7.3).abs() < 1e-6, "got {}", e.finish_time(f1));
    }

    #[test]
    fn dependencies_serialize_activities() {
        let mut e = Engine::new(vec![100.0]);
        let mut j = JobSpec::default();
        let a = j.push(Work::Flow { resources: vec![0], bytes: 100.0 }, vec![]);
        let b = j.push(Work::Delay(0.5), vec![a]);
        j.push(Work::Flow { resources: vec![0], bytes: 100.0 }, vec![b]);
        let id = e.spawn(j);
        e.run_to_completion();
        assert!((e.finish_time(id) - 2.5).abs() < 1e-6);
    }

    #[test]
    fn diamond_dag_joins() {
        // a -> (b, c) -> d ; b and c share the resource
        let mut e = Engine::new(vec![100.0]);
        let mut j = JobSpec::default();
        let a = j.push(Work::Delay(1.0), vec![]);
        let b = j.push(Work::Flow { resources: vec![0], bytes: 100.0 }, vec![a]);
        let c = j.push(Work::Flow { resources: vec![0], bytes: 100.0 }, vec![a]);
        j.push(Work::Delay(0.25), vec![b, c]);
        let id = e.spawn(j);
        e.run_to_completion();
        // 1.0 + (two fair-shared 100B flows on 100B/s = 2.0) + 0.25
        assert!((e.finish_time(id) - 3.25).abs() < 1e-6, "got {}", e.finish_time(id));
    }

    #[test]
    fn empty_resource_flow_completes_instantly() {
        let mut e = Engine::new(vec![100.0]);
        let mut j = JobSpec::default();
        j.push(Work::Flow { resources: vec![], bytes: 1e9 }, vec![]);
        let id = e.spawn(j);
        e.run_to_completion();
        assert!(e.finish_time(id).abs() < 1e-9);
    }

    #[test]
    fn resource_bytes_accounting() {
        let mut e = Engine::new(vec![50.0, 50.0]);
        let mut j = JobSpec::default();
        j.push(Work::Flow { resources: vec![0, 1], bytes: 123.0 }, vec![]);
        e.spawn(j);
        e.run_to_completion();
        assert!((e.resource_bytes[0] - 123.0).abs() < 1e-9);
        assert!((e.resource_bytes[1] - 123.0).abs() < 1e-9);
    }

    #[test]
    fn staged_admission_runs_after_completion() {
        let mut e = Engine::new(vec![100.0]);
        let first = e.spawn(JobSpec { activities: vec![flow(vec![0], 100.0, vec![])] });
        let second = e.add_job(JobSpec { activities: vec![flow(vec![0], 100.0, vec![])] });
        loop {
            match e.run_until_event() {
                Some(done) => {
                    if done.contains(&first) {
                        e.start_job(second);
                    }
                }
                None => break,
            }
        }
        assert!((e.finish_time(first) - 1.0).abs() < 1e-6);
        assert!((e.finish_time(second) - 2.0).abs() < 1e-6);
    }

    #[test]
    fn many_flows_complete_and_conserve_time() {
        // 100 equal flows on one resource: makespan = total/cap regardless
        // of sharing order (work conservation).
        let mut e = Engine::new(vec![1000.0]);
        for _ in 0..100 {
            e.spawn(JobSpec { activities: vec![flow(vec![0], 10.0, vec![])] });
        }
        e.run_to_completion();
        assert!((e.now() - 1.0).abs() < 1e-6);
        assert_eq!(e.completed_count(), 100);
    }
}
