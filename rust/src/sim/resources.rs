//! Resource table for the fluid simulator: every contended capacity in the
//! testbed becomes one max-min-fair-shared resource.
//!
//! Per node: disk (shared actuator), NIC up, NIC down, CPU.
//! Per rack: core-router port up / down (the scarce cross-rack capacity).

use crate::topology::{Location, SystemSpec};

pub type ResourceId = u32;

const PER_NODE: usize = 4;
const DISK: usize = 0;
const NIC_UP: usize = 1;
const NIC_DOWN: usize = 2;
const CPU: usize = 3;

/// Maps topology entities to resource ids and capacities (bytes/second).
#[derive(Clone, Debug)]
pub struct ResourceTable {
    /// capacity in bytes/sec per resource
    pub caps: Vec<f64>,
    nodes: usize,
    nodes_per_rack: usize,
}

fn mbps_to_bytes(mbps: f64) -> f64 {
    mbps * 1e6 / 8.0
}

impl ResourceTable {
    pub fn new(spec: &SystemSpec) -> ResourceTable {
        let nodes = spec.cluster.node_count();
        let racks = spec.cluster.racks;
        let mut caps = Vec::with_capacity(nodes * PER_NODE + racks * 2);
        for _ in 0..nodes {
            // disk: use sequential read rate as the shared actuator capacity
            caps.push(mbps_to_bytes(spec.disk.seq_read_mbps));
            caps.push(mbps_to_bytes(spec.net.inner_mbps)); // NIC up
            caps.push(mbps_to_bytes(spec.net.inner_mbps)); // NIC down
            caps.push(mbps_to_bytes(spec.cpu.gf_mbps)); // CPU (per-stream GF rate)
        }
        for _ in 0..racks {
            // one full-duplex core-router port per rack (paper Exp 1:
            // "each port ... is full-duplex, with 100 Mb/s upstream and
            // 100 Mb/s downstream available simultaneously")
            caps.push(mbps_to_bytes(spec.net.cross_mbps));
            caps.push(mbps_to_bytes(spec.net.cross_mbps));
        }
        ResourceTable { caps, nodes, nodes_per_rack: spec.cluster.nodes_per_rack }
    }

    fn node_base(&self, loc: Location) -> usize {
        (loc.rack as usize * self.nodes_per_rack + loc.node as usize) * PER_NODE
    }

    pub fn disk(&self, loc: Location) -> ResourceId {
        (self.node_base(loc) + DISK) as ResourceId
    }

    pub fn nic_up(&self, loc: Location) -> ResourceId {
        (self.node_base(loc) + NIC_UP) as ResourceId
    }

    pub fn nic_down(&self, loc: Location) -> ResourceId {
        (self.node_base(loc) + NIC_DOWN) as ResourceId
    }

    pub fn cpu(&self, loc: Location) -> ResourceId {
        (self.node_base(loc) + CPU) as ResourceId
    }

    pub fn rack_up(&self, rack: u32) -> ResourceId {
        (self.nodes * PER_NODE + rack as usize * 2) as ResourceId
    }

    pub fn rack_down(&self, rack: u32) -> ResourceId {
        (self.nodes * PER_NODE + rack as usize * 2 + 1) as ResourceId
    }

    pub fn racks(&self) -> usize {
        (self.caps.len() - self.nodes * PER_NODE) / 2
    }

    /// Resource set for a network transfer `src → dst`.
    pub fn transfer(&self, src: Location, dst: Location) -> Vec<ResourceId> {
        if src == dst {
            return vec![];
        }
        let mut r = vec![self.nic_up(src), self.nic_down(dst)];
        if src.rack != dst.rack {
            r.push(self.rack_up(src.rack));
            r.push(self.rack_down(dst.rack));
        }
        r
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::topology::SystemSpec;

    #[test]
    fn ids_are_disjoint_and_in_range() {
        let spec = SystemSpec::paper_default();
        let rt = ResourceTable::new(&spec);
        let mut seen = std::collections::HashSet::new();
        for loc in spec.cluster.iter_nodes() {
            for id in [rt.disk(loc), rt.nic_up(loc), rt.nic_down(loc), rt.cpu(loc)] {
                assert!(seen.insert(id), "dup id {id}");
                assert!((id as usize) < rt.caps.len());
            }
        }
        for rack in 0..spec.cluster.racks as u32 {
            for id in [rt.rack_up(rack), rt.rack_down(rack)] {
                assert!(seen.insert(id), "dup id {id}");
                assert!((id as usize) < rt.caps.len());
            }
        }
        assert_eq!(seen.len(), rt.caps.len());
    }

    #[test]
    fn transfer_resource_sets() {
        let spec = SystemSpec::paper_default();
        let rt = ResourceTable::new(&spec);
        let a = Location::new(0, 0);
        let b = Location::new(0, 1);
        let c = Location::new(1, 0);
        assert_eq!(rt.transfer(a, a), vec![]);
        assert_eq!(rt.transfer(a, b).len(), 2, "inner-rack skips router ports");
        assert_eq!(rt.transfer(a, c).len(), 4, "cross-rack adds both router ports");
    }

    #[test]
    fn capacities_match_spec() {
        let spec = SystemSpec::paper_default();
        let rt = ResourceTable::new(&spec);
        let loc = Location::new(2, 1);
        assert!((rt.caps[rt.nic_up(loc) as usize] - 1000.0 * 1e6 / 8.0).abs() < 1.0);
        // rack port: one full-duplex 100 Mb/s core-router port per rack
        assert!((rt.caps[rt.rack_up(2) as usize] - 100.0 * 1e6 / 8.0).abs() < 1.0);
    }
}
