//! Front-end workload simulation (paper §6.2.4, Exp 10–11): MapReduce-shaped
//! jobs (Pi, Terasort, Wordcount, Grep) translated into compute + shuffle
//! traffic, optionally competing with an ongoing recovery.

use crate::sim::engine::{Engine, JobSpec, Work};
use crate::sim::resources::ResourceTable;
use crate::topology::{Location, SystemSpec};
use crate::util::Rng;
use crate::workloads::WorkloadSpec;

/// Where a workload's tasks run and where its HDFS output blocks land.
///
/// Task/shuffle placement is the *scheduler's* job and is slot-balanced in
/// any real Hadoop deployment, so both policies share it (round-robin).
/// What differs between D³ and RDD is where HDFS puts the *data* the
/// workload writes (the paper: "D³ achieves a uniform data distribution
/// for the intermediate temporary data... which benefits distribution of
/// network traffic when accessing temporarily stored data across nodes").
pub trait TaskPlacer {
    /// Node executing the i-th map/reduce task (scheduler, slot-balanced).
    fn task_node(&self, task: usize) -> Location;
    /// Node receiving the i-th output/intermediate HDFS block (placement
    /// policy — this is where D³ and RDD differ).
    fn output_node(&self, block: usize) -> Location;
}

/// HDFS output blocks spread deterministically (D³-like).
pub struct UniformPlacer {
    nodes: Vec<Location>,
}

/// HDFS output blocks placed at random (RDD-like).
pub struct RandomPlacer {
    nodes: Vec<Location>,
    seed: u64,
}

impl UniformPlacer {
    pub fn new(spec: &SystemSpec) -> UniformPlacer {
        UniformPlacer { nodes: spec.cluster.iter_nodes().collect() }
    }
}

impl RandomPlacer {
    pub fn new(spec: &SystemSpec, seed: u64) -> RandomPlacer {
        RandomPlacer { nodes: spec.cluster.iter_nodes().collect(), seed }
    }
}

impl TaskPlacer for UniformPlacer {
    fn task_node(&self, task: usize) -> Location {
        self.nodes[task % self.nodes.len()]
    }
    fn output_node(&self, block: usize) -> Location {
        // deterministic rotation decorrelated from the task grid
        self.nodes[(block * 7 + 3) % self.nodes.len()]
    }
}

impl TaskPlacer for RandomPlacer {
    fn task_node(&self, task: usize) -> Location {
        self.nodes[task % self.nodes.len()]
    }
    fn output_node(&self, block: usize) -> Location {
        *Rng::keyed(self.seed, block as u64, 2).choose(&self.nodes)
    }
}

/// Build the job DAG for one MapReduce-shaped workload.
///
/// maps: local read + compute; shuffle: map→reduce flows (cross-node, the
/// network-intensive phase); reduces: compute + local write.
pub fn workload_job(
    w: &WorkloadSpec,
    placer: &dyn TaskPlacer,
    rt: &ResourceTable,
    _spec: &SystemSpec,
) -> JobSpec {
    let mut job = JobSpec::default();
    let maps = w.maps;
    let reduces = w.reduces.max(1);
    let map_in = w.input_bytes as f64 / maps as f64;
    let shuffle_each = w.shuffle_bytes as f64 / (maps * reduces) as f64;
    let out_each = w.output_bytes as f64 / reduces as f64;
    let mut map_done: Vec<(u32, Location)> = Vec::with_capacity(maps);
    for t in 0..maps {
        let node = placer.task_node(t);
        let mut deps = vec![];
        if map_in > 0.0 {
            let read = job.push(
                Work::Flow { resources: vec![rt.disk(node)], bytes: map_in },
                vec![],
            );
            deps.push(read);
        }
        let cpu_bytes = w.cpu_bytes_equiv as f64 / maps as f64;
        let compute = job.push(
            Work::Flow { resources: vec![rt.cpu(node)], bytes: cpu_bytes },
            deps,
        );
        map_done.push((compute, node));
    }
    for r in 0..reduces {
        let dst = placer.task_node(maps + r); // reducer slot (scheduler)
        let mut fetches = Vec::with_capacity(maps);
        if shuffle_each > 0.0 {
            for &(m_act, m_node) in &map_done {
                let f = job.push(
                    Work::Flow { resources: rt.transfer(m_node, dst), bytes: shuffle_each },
                    vec![m_act],
                );
                fetches.push(f);
            }
        } else {
            fetches.extend(map_done.iter().map(|&(a, _)| a));
        }
        let reduce_cpu = job.push(
            Work::Flow {
                resources: vec![rt.cpu(dst)],
                bytes: (shuffle_each * maps as f64).max(1.0),
            },
            fetches,
        );
        if out_each > 0.0 {
            // the reducer writes its output block into HDFS: the target
            // node comes from the block-placement policy (D³ vs RDD)
            let out_loc = placer.output_node(r);
            let write_net = job.push(
                Work::Flow { resources: rt.transfer(dst, out_loc), bytes: out_each },
                vec![reduce_cpu],
            );
            job.push(
                Work::Flow { resources: vec![rt.disk(out_loc)], bytes: out_each },
                vec![write_net],
            );
        }
    }
    job
}

/// Run a workload alone; returns completion time (normal state, Exp 10).
pub fn run_workload(spec: &SystemSpec, w: &WorkloadSpec, placer: &dyn TaskPlacer) -> f64 {
    let rt = ResourceTable::new(spec);
    let mut engine = Engine::new(rt.caps.clone());
    engine.spawn(workload_job(w, placer, &rt, spec));
    engine.run_to_completion();
    engine.now()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workloads;

    #[test]
    fn workloads_complete_with_positive_time() {
        let spec = SystemSpec::paper_default();
        let placer = UniformPlacer::new(&spec);
        for w in workloads::specs() {
            let t = run_workload(&spec, &w, &placer);
            assert!(t > 0.0, "{}: t={t}", w.name);
        }
    }

    #[test]
    fn network_heavy_workloads_slower_than_cpu_only() {
        let spec = SystemSpec::paper_default();
        let placer = UniformPlacer::new(&spec);
        let all = workloads::specs();
        let pi = all.iter().find(|w| w.name == "pi").unwrap();
        let terasort = all.iter().find(|w| w.name == "terasort").unwrap();
        let t_pi = run_workload(&spec, pi, &placer);
        let t_ts = run_workload(&spec, terasort, &placer);
        assert!(t_ts > t_pi, "terasort {t_ts} should exceed pi {t_pi}");
    }

    #[test]
    fn uniform_placement_no_slower_than_random() {
        let spec = SystemSpec::paper_default();
        let uni = UniformPlacer::new(&spec);
        let rnd = RandomPlacer::new(&spec, 5);
        let all = workloads::specs();
        let grep = all.iter().find(|w| w.name == "grep").unwrap();
        let t_u = run_workload(&spec, grep, &uni);
        let t_r = run_workload(&spec, grep, &rnd);
        assert!(t_u <= t_r * 1.05, "uniform {t_u} vs random {t_r}");
    }
}
