//! The testbed substitute: a flow-level discrete-event simulator
//! (DESIGN.md §2). [`engine`] is the generic fluid DES; [`resources`]
//! maps the topology onto shared capacities; [`recovery`] runs repair
//! plans through it; [`frontend`] adds the MapReduce-shaped workloads.

pub mod engine;
pub mod frontend;
pub mod recovery;
pub mod resources;

pub use engine::{Engine, JobSpec, Work};
pub use recovery::SimBackend;
pub use resources::ResourceTable;
