//! Run repair plans through the fluid simulator: node recovery (Exp 1–2,
//! 4–9), degraded reads (Exp 3) and migration (§5.3), with the HDFS-style
//! per-node reconstruction-stream admission that makes recovery proceed
//! batch by batch (the effect RDD's imbalance argument rests on).

use crate::recovery::migration::MigrationBatch;
use crate::recovery::plan::RepairPlan;
use crate::recovery::schedule::{plan_admission_order, SchedulePolicy};
use crate::sim::engine::{Engine, JobSpec, Work};
use crate::sim::resources::ResourceTable;
use crate::topology::{Location, SystemSpec};

/// Scheduler knobs. HDFS-EC dispatches reconstruction work in heartbeat
/// quanta with a per-DataNode xmits budget; the paper leans on the
/// resulting batching: "DSSes rebuild lost blocks batch by batch for a
/// long recovery queue due to limited available system resources" (§3.1).
/// Default: continuous heartbeat-style admission with 8 streams per
/// writer (calibrated so the simulated (3,2)/(6,3) speedups land on the
/// paper's 2.36×/2.49×; see EXPERIMENTS.md). `batch_sync = true` switches
/// to strict barrier waves — the ablation that isolates the paper's
/// within-batch "local load imbalance" argument.
#[derive(Clone, Copy, Debug)]
pub struct RecoveryConfig {
    /// Reconstruction tasks per node per wave (HDFS max-streams).
    pub streams_per_node: usize,
    /// Fixed per-task dispatch cost (NameNode RPC + task setup) in
    /// seconds — the overhead that makes small blocks inefficient
    /// (paper Fig 12's rising curve with the 32 MB knee).
    pub task_overhead_s: f64,
    /// If true (default), waves are barrier-synchronized (batch by batch);
    /// if false, a completed job is immediately replaced (continuous
    /// admission — ablation knob).
    pub batch_sync: bool,
    /// Global cap on concurrently admitted repair jobs, 0 = unbounded —
    /// the fluid analogue of the cluster executor's bounded worker pool
    /// (DESIGN.md §8), so cross-backend recovery-time comparisons run both
    /// backends at the same concurrency.
    pub workers: usize,
    /// Admission order of the repair queue: FIFO stripe order, or the
    /// same link-balanced class order the cluster executor's wavefront
    /// schedule uses (DESIGN.md §10) — so both backends admit recovery
    /// work in the same sequence and stay cross-checkable.
    pub schedule: SchedulePolicy,
    /// Placement period of the plan set (set by [`SimBackend`] from the
    /// policy), so the balanced coloring tiles identically to the
    /// cluster executor's.
    pub period: Option<u64>,
}

impl Default for RecoveryConfig {
    fn default() -> RecoveryConfig {
        RecoveryConfig {
            streams_per_node: 8,
            batch_sync: true,
            task_overhead_s: 0.45,
            workers: 0,
            schedule: SchedulePolicy::Fifo,
            period: None,
        }
    }
}

/// Aggregate outcome of a simulated recovery.
#[derive(Clone, Debug)]
pub struct RecoveryOutcome {
    /// Simulated seconds until the last *repair* completed (foreground
    /// jobs sharing the engine report their own finish times and do not
    /// extend this).
    pub makespan: f64,
    /// Rebuilt volume / makespan, MB/s (the paper's recovery throughput).
    pub throughput_mb_s: f64,
    /// Load-imbalance metric λ = (Lmax − Lavg)/Lavg over the surviving
    /// racks' router-port loads, both directions (paper Exp 1).
    pub lambda: f64,
    /// Per-rack (up, down) router-port bytes.
    pub rack_loads: Vec<(f64, f64)>,
    /// Number of blocks rebuilt.
    pub blocks: usize,
}

/// Build the simulator job for one repair plan.
pub fn plan_to_job(plan: &RepairPlan, rt: &ResourceTable, spec: &SystemSpec) -> JobSpec {
    plan_to_job_with(plan, rt, spec, 0.0)
}

/// Like [`plan_to_job`] with a fixed task-dispatch delay prepended.
pub fn plan_to_job_with(
    plan: &RepairPlan,
    rt: &ResourceTable,
    spec: &SystemSpec,
    overhead_s: f64,
) -> JobSpec {
    let bytes = spec.block_size as f64;
    let seek = spec.disk.seek_ms / 1e3;
    let mut job = JobSpec::default();
    let dispatch = job.push(Work::Delay(overhead_s.max(0.0)), vec![]);
    let mut arrivals: Vec<u32> = Vec::new(); // activities whose output feeds the final combine
    let mut streams = 0usize;

    for agg in &plan.aggregations {
        let mut input_done: Vec<u32> = Vec::new();
        for &(_, loc) in &agg.inputs {
            let s = job.push(Work::Delay(seek), vec![dispatch]);
            let read = job.push(
                Work::Flow { resources: vec![rt.disk(loc)], bytes },
                vec![s],
            );
            if loc == agg.at {
                input_done.push(read);
            } else {
                let xfer = job.push(
                    Work::Flow { resources: rt.transfer(loc, agg.at), bytes },
                    vec![read],
                );
                input_done.push(xfer);
            }
        }
        // inner-rack aggregation compute: k_g input streams
        let compute = job.push(
            Work::Flow {
                resources: vec![rt.cpu(agg.at)],
                bytes: bytes * agg.inputs.len() as f64,
            },
            input_done,
        );
        let send = job.push(
            Work::Flow { resources: rt.transfer(agg.at, plan.compute_at), bytes },
            vec![compute],
        );
        arrivals.push(send);
        streams += 1;
    }
    for &(_, loc) in &plan.direct {
        let s = job.push(Work::Delay(seek), vec![dispatch]);
        let read = job.push(Work::Flow { resources: vec![rt.disk(loc)], bytes }, vec![s]);
        if loc == plan.compute_at {
            arrivals.push(read);
        } else {
            let xfer = job.push(
                Work::Flow { resources: rt.transfer(loc, plan.compute_at), bytes },
                vec![read],
            );
            arrivals.push(xfer);
        }
        streams += 1;
    }
    let combine = job.push(
        Work::Flow {
            resources: vec![rt.cpu(plan.compute_at)],
            bytes: bytes * streams as f64,
        },
        arrivals,
    );
    if plan.persist {
        let s = job.push(Work::Delay(seek), vec![combine]);
        job.push(Work::Flow { resources: vec![rt.disk(plan.writer)], bytes }, vec![s]);
    }
    job
}

/// Simulate full-node recovery for `plans` under the wave scheduler.
pub fn run_recovery(
    spec: &SystemSpec,
    plans: &[RepairPlan],
    failed: Location,
    cfg: RecoveryConfig,
) -> RecoveryOutcome {
    run_recovery_with_background(spec, plans, failed, cfg, Vec::new()).0
}

/// Like [`run_recovery`], with extra foreground jobs (front-end workloads,
/// Exp 11) sharing the same engine/ports. Returns the recovery outcome and
/// the completion time of each extra job.
pub fn run_recovery_with_background(
    spec: &SystemSpec,
    plans: &[RepairPlan],
    failed: Location,
    cfg: RecoveryConfig,
    extra: Vec<crate::sim::engine::JobSpec>,
) -> (RecoveryOutcome, Vec<f64>) {
    run_recovery_multi(spec, plans, &[failed.rack], cfg, extra)
}

/// The general engine driver behind every recovery scenario: arbitrary
/// plan sets (single node, K nodes, a whole rack — DESIGN.md §5), λ
/// computed over the racks *not* in `failed_racks`, optional foreground
/// jobs sharing the ports.
pub fn run_recovery_multi(
    spec: &SystemSpec,
    plans: &[RepairPlan],
    failed_racks: &[u32],
    cfg: RecoveryConfig,
    extra: Vec<crate::sim::engine::JobSpec>,
) -> (RecoveryOutcome, Vec<f64>) {
    let rt = ResourceTable::new(spec);
    let mut engine = Engine::new(rt.caps.clone());
    let extra_ids: Vec<u32> = extra.into_iter().map(|j| engine.spawn(j)).collect();
    // Mirror the cluster executor's admission sequence (DESIGN.md §10):
    // FIFO admits in stripe order; balanced admits conflict-free class by
    // conflict-free class, exactly the order the wavefront schedule first
    // touches each plan.
    let order: Vec<usize> = match cfg.schedule {
        SchedulePolicy::Fifo => (0..plans.len()).collect(),
        SchedulePolicy::Balanced => plan_admission_order(plans, cfg.period),
    };
    let jobs: Vec<(u32, Location)> = order
        .iter()
        .map(|&i| {
            let p = &plans[i];
            (engine.add_job(plan_to_job_with(p, &rt, spec, cfg.task_overhead_s)), p.writer)
        })
        .collect();
    let mut wave_budget = cfg.streams_per_node * spec.cluster.node_count();
    if cfg.workers > 0 {
        // bounded worker pool: a wave can't run more jobs than workers
        wave_budget = wave_budget.min(cfg.workers);
    }

    if cfg.batch_sync {
        // barrier-synchronized waves in stripe order (batch by batch);
        // within a wave, still cap per-writer streams
        // the NameNode scans the reconstruction queue in stripe order and
        // skips items whose assigned worker is already at its stream limit
        // (they stay queued for a later wave)
        let mut pending: std::collections::VecDeque<(u32, Location)> =
            jobs.iter().copied().collect();
        while !pending.is_empty() {
            let mut inflight: std::collections::HashMap<Location, usize> =
                std::collections::HashMap::new();
            let mut admitted = 0usize;
            let mut skipped: std::collections::VecDeque<(u32, Location)> =
                std::collections::VecDeque::new();
            while admitted < wave_budget {
                let Some((job, writer)) = pending.pop_front() else { break };
                let slot = inflight.entry(writer).or_insert(0);
                if *slot >= cfg.streams_per_node {
                    skipped.push_back((job, writer));
                    continue;
                }
                *slot += 1;
                engine.start_job(job);
                admitted += 1;
            }
            assert!(admitted > 0, "wave admitted nothing");
            // skipped items go back to the FRONT (still oldest work)
            while let Some(item) = skipped.pop_back() {
                pending.push_front(item);
            }
            engine.run_to_completion();
        }
    } else {
        // continuous admission with per-writer stream limits and the
        // global worker-pool cap
        let mut inflight: std::collections::HashMap<Location, usize> =
            std::collections::HashMap::new();
        let mut inflight_total = 0usize;
        let mut queue: std::collections::VecDeque<(u32, Location)> =
            jobs.iter().copied().collect();
        let writer_of: std::collections::HashMap<u32, Location> =
            jobs.iter().copied().collect();
        let mut deferred: std::collections::VecDeque<(u32, Location)> =
            std::collections::VecDeque::new();
        let mut admit = |engine: &mut Engine,
                         queue: &mut std::collections::VecDeque<(u32, Location)>,
                         inflight: &mut std::collections::HashMap<Location, usize>,
                         inflight_total: &mut usize| {
            let mut n = queue.len();
            while n > 0 {
                n -= 1;
                let (job, writer) = queue.pop_front().unwrap();
                let count = inflight.entry(writer).or_insert(0);
                let pool_free = cfg.workers == 0 || *inflight_total < cfg.workers;
                if pool_free && *count < cfg.streams_per_node {
                    *count += 1;
                    *inflight_total += 1;
                    engine.start_job(job);
                } else {
                    deferred.push_back((job, writer));
                }
            }
            std::mem::swap(queue, &mut deferred);
        };
        admit(&mut engine, &mut queue, &mut inflight, &mut inflight_total);
        while let Some(done) = engine.run_until_event() {
            for job in done {
                if let Some(writer) = writer_of.get(&job) {
                    *inflight.get_mut(writer).unwrap() -= 1;
                    inflight_total -= 1;
                }
            }
            admit(&mut engine, &mut queue, &mut inflight, &mut inflight_total);
        }
        assert!(queue.is_empty(), "jobs left unadmitted");
    }
    // flush any foreground jobs still in flight (also covers empty plan
    // sets, where the wave loop never runs)
    engine.run_to_completion();
    assert_eq!(
        engine.completed_count(),
        plans.len() + extra_ids.len(),
        "not all repairs completed"
    );

    // recovery completion, not the engine's global clock: foreground jobs
    // sharing the engine may outlast the rebuild and must not inflate
    // recovery time (the cluster backend times recovery alone too)
    let makespan = jobs
        .iter()
        .map(|&(id, _)| engine.finish_time(id))
        .fold(0.0f64, f64::max);
    let rebuilt = plans.len() as f64 * spec.block_size as f64;
    let racks = spec.cluster.racks;
    let mut rack_loads = Vec::with_capacity(racks);
    for rack in 0..racks as u32 {
        rack_loads.push((
            engine.resource_bytes[rt.rack_up(rack) as usize],
            engine.resource_bytes[rt.rack_down(rack) as usize],
        ));
    }
    let lambda = lambda_metric_excluding(&rack_loads, failed_racks);
    let extra_times: Vec<f64> = extra_ids.iter().map(|&id| engine.finish_time(id)).collect();
    (
        RecoveryOutcome {
            makespan,
            throughput_mb_s: if makespan > 0.0 { rebuilt / makespan / 1e6 } else { 0.0 },
            lambda,
            rack_loads,
            blocks: plans.len(),
        },
        extra_times,
    )
}

/// λ = (Lmax − Lavg)/Lavg over surviving racks' port loads, both
/// directions (paper Exp 1).
pub fn lambda_metric(rack_loads: &[(f64, f64)], failed_rack: u32) -> f64 {
    lambda_metric_excluding(rack_loads, &[failed_rack])
}

/// λ over the racks not in `excluded` (multi-node and rack-failure
/// scenarios exclude every rack that lost nodes).
pub fn lambda_metric_excluding(rack_loads: &[(f64, f64)], excluded: &[u32]) -> f64 {
    let mut loads = Vec::new();
    for (rack, &(up, down)) in rack_loads.iter().enumerate() {
        if !excluded.contains(&(rack as u32)) {
            loads.push(up);
            loads.push(down);
        }
    }
    if loads.is_empty() {
        return 0.0;
    }
    let avg = loads.iter().sum::<f64>() / loads.len() as f64;
    if avg <= 0.0 {
        return 0.0;
    }
    let max = loads.iter().cloned().fold(0.0f64, f64::max);
    (max - avg) / avg
}

/// The fluid-simulator implementation of the scenario engine
/// ([`crate::scenario::RecoveryBackend`], DESIGN.md §5): simulated
/// seconds, analytic max-min-fair port loads. Foreground traffic
/// (mixed-load kinds) is the client engine's generated request sequence
/// lowered into fluid jobs ([`crate::client::request_job`], DESIGN.md
/// §11) — the *same* sequence the MiniCluster backend serves.
pub struct SimBackend {
    pub cfg: RecoveryConfig,
}

impl Default for SimBackend {
    fn default() -> SimBackend {
        SimBackend { cfg: RecoveryConfig::default() }
    }
}

use crate::scenario::distinct_racks;

fn loads_to_bytes(rack_loads: &[(f64, f64)]) -> Vec<(u64, u64)> {
    rack_loads.iter().map(|&(u, d)| (u as u64, d as u64)).collect()
}

/// Fluid-backend per-rack-link (busy, stall) seconds: busy is the port's
/// byte volume served at the configured cross-rack rate; stall is zero —
/// max-min fair sharing never queues work in front of a port, it slows
/// every flow instead.
fn fluid_link_busy_stall(rack_loads: &[(f64, f64)], spec: &SystemSpec) -> Vec<(f64, f64)> {
    let rate = (spec.net.cross_mbps * 1e6 / 8.0).max(1.0);
    rack_loads.iter().map(|&(u, d)| ((u + d) / rate, 0.0)).collect()
}

impl crate::scenario::RecoveryBackend for SimBackend {
    fn name(&self) -> &'static str {
        "sim"
    }

    fn run(
        &self,
        scenario: &crate::scenario::FailureScenario,
        policy: &std::sync::Arc<dyn crate::placement::Placement>,
        spec: &SystemSpec,
    ) -> anyhow::Result<crate::scenario::ScenarioOutcome> {
        use crate::client::request_job;
        use crate::placement::PlacementTable;
        use crate::scenario::{planned_cross_rack_blocks, ScenarioKind, ScenarioOutcome};

        if matches!(scenario.kind, ScenarioKind::DegradedBurst { .. }) {
            // pure foreground load: serve the generated request sequence
            // through the fluid engine — no recovery competes; one table
            // serves generation, plan derivation and job lowering
            let table = PlacementTable::build(policy.clone(), scenario.stripes);
            let (_, reqs) = scenario
                .fg_requests_with(&table)?
                .expect("degraded burst always carries fg traffic");
            let failed = scenario.failed_nodes(policy.as_ref())[0];
            let plans =
                crate::scenario::degraded_read_plans(&table, &reqs, scenario.seed);
            let rt = ResourceTable::new(spec);
            let mut engine = Engine::new(rt.caps.clone());
            let ids: Vec<(u32, f64)> = reqs
                .iter()
                .map(|r| {
                    let job = request_job(
                        r,
                        &table,
                        &rt,
                        spec,
                        scenario.seed,
                        std::slice::from_ref(&failed),
                    );
                    (engine.spawn(job), r.arrival_s)
                })
                .collect();
            engine.run_to_completion();
            let latencies: Vec<f64> = ids
                .iter()
                .map(|&(id, arrival)| engine.finish_time(id) - arrival)
                .collect();
            let makespan = engine.now();
            let mut rack_loads = Vec::with_capacity(spec.cluster.racks);
            for rack in 0..spec.cluster.racks as u32 {
                rack_loads.push((
                    engine.resource_bytes[rt.rack_up(rack) as usize],
                    engine.resource_bytes[rt.rack_down(rack) as usize],
                ));
            }
            let summary =
                (!latencies.is_empty()).then(|| crate::metrics::summarize(&latencies));
            let mean = summary.as_ref().map(|s| s.mean).unwrap_or(0.0);
            let bytes = reqs.len() as u64 * spec.block_size;
            return Ok(ScenarioOutcome {
                backend: "sim",
                scenario: scenario.name(),
                policy: policy.name().to_string(),
                blocks: reqs.len(),
                bytes,
                seconds: makespan,
                throughput_mb_s: if makespan > 0.0 {
                    bytes as f64 / makespan / 1e6
                } else {
                    0.0
                },
                lambda: lambda_metric_excluding(&rack_loads, &[failed.rack]),
                rack_cross_bytes: loads_to_bytes(&rack_loads),
                planned_cross_rack_blocks: planned_cross_rack_blocks(&plans),
                degraded_read_mean_s: Some(mean),
                frontend_seconds: None,
                worker_utilization: None,
                scratch_pool: None,
                link_busy_stall: Some(fluid_link_busy_stall(&rack_loads, spec)),
                fg_latency: summary,
                recovery_slowdown: None,
                faults: None,
                trace: None,
            });
        }

        let (failed, plans) = scenario.recovery_plans(policy)?;
        let racks = distinct_racks(&failed);
        let cfg = RecoveryConfig {
            period: self.cfg.period.or_else(|| policy.period()),
            ..self.cfg
        };
        if scenario.fg_spec()?.is_none() {
            let (out, _) = run_recovery_multi(spec, &plans, &racks, cfg, Vec::new());
            return Ok(sim_outcome(scenario, policy.name(), &out, &plans, spec, None));
        }
        let table = PlacementTable::build(policy.clone(), scenario.stripes);
        let (_, reqs) = scenario
            .fg_requests_with(&table)?
            .expect("fg spec presence checked above");

        // mixed load: the fluid analogue of the link split scales the
        // per-node reconstruction-stream admission to recovery's share
        // (only while foreground traffic exists — the isolated baseline
        // below runs unthrottled, like the cluster backend's)
        let mut mixed_cfg = cfg;
        if scenario.qos.is_active() {
            let streams = cfg.streams_per_node as f64 * scenario.qos.recovery_share;
            mixed_cfg.streams_per_node = (streams.round() as usize).max(1);
        }
        let rt = ResourceTable::new(spec);
        let extra: Vec<crate::sim::engine::JobSpec> = reqs
            .iter()
            .map(|r| request_job(r, &table, &rt, spec, scenario.seed, &failed))
            .collect();
        let (out, times) = run_recovery_multi(spec, &plans, &racks, mixed_cfg, extra);
        // the same recovery alone and unthrottled, for the interference
        // factor (QoS applies only while foreground load is active)
        let (isolated, _) = run_recovery_multi(spec, &plans, &racks, cfg, Vec::new());
        let latencies: Vec<f64> = times
            .iter()
            .zip(&reqs)
            .map(|(&t, r)| t - r.arrival_s)
            .collect();
        let fg_done = times.iter().cloned().fold(0.0f64, f64::max);
        let mut o =
            sim_outcome(scenario, policy.name(), &out, &plans, spec, Some(fg_done));
        o.fg_latency = (!latencies.is_empty()).then(|| crate::metrics::summarize(&latencies));
        o.recovery_slowdown = Some(out.makespan / isolated.makespan.max(1e-12));
        Ok(o)
    }
}

fn sim_outcome(
    scenario: &crate::scenario::FailureScenario,
    policy_name: &str,
    out: &RecoveryOutcome,
    plans: &[RepairPlan],
    spec: &SystemSpec,
    frontend_seconds: Option<f64>,
) -> crate::scenario::ScenarioOutcome {
    crate::scenario::ScenarioOutcome {
        backend: "sim",
        scenario: scenario.name(),
        policy: policy_name.to_string(),
        blocks: out.blocks,
        bytes: out.blocks as u64 * spec.block_size,
        seconds: out.makespan,
        throughput_mb_s: out.throughput_mb_s,
        lambda: out.lambda,
        rack_cross_bytes: loads_to_bytes(&out.rack_loads),
        planned_cross_rack_blocks: crate::scenario::planned_cross_rack_blocks(plans),
        degraded_read_mean_s: None,
        frontend_seconds,
        worker_utilization: None,
        scratch_pool: None,
        link_busy_stall: Some(fluid_link_busy_stall(&out.rack_loads, spec)),
        fg_latency: None,
        recovery_slowdown: None,
        faults: None,
        trace: None,
    }
}

/// Simulate one degraded read and return its latency (paper Exp 3).
pub fn run_degraded_read(spec: &SystemSpec, plan: &RepairPlan) -> f64 {
    let rt = ResourceTable::new(spec);
    let mut engine = Engine::new(rt.caps.clone());
    engine.spawn(plan_to_job(plan, &rt, spec));
    engine.run_to_completion();
    engine.now()
}

/// Simulate migration batches sequentially (§5.3); returns per-batch times.
pub fn run_migration(
    spec: &SystemSpec,
    batches: &[MigrationBatch],
    relived: Location,
) -> Vec<f64> {
    let rt = ResourceTable::new(spec);
    let bytes = spec.block_size as f64;
    let seek = spec.disk.seek_ms / 1e3;
    let mut times = Vec::with_capacity(batches.len());
    for batch in batches {
        let mut engine = Engine::new(rt.caps.clone());
        for mv in &batch.moves {
            let mut job = JobSpec::default();
            let s = job.push(Work::Delay(seek), vec![]);
            let read = job.push(
                Work::Flow { resources: vec![rt.disk(mv.from)], bytes },
                vec![s],
            );
            let xfer = job.push(
                Work::Flow { resources: rt.transfer(mv.from, relived), bytes },
                vec![read],
            );
            let sw = job.push(Work::Delay(seek), vec![xfer]);
            job.push(Work::Flow { resources: vec![rt.disk(relived)], bytes }, vec![sw]);
            engine.spawn(job);
        }
        engine.run_to_completion();
        times.push(engine.now());
    }
    times
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::codes::CodeSpec;
    use crate::placement::{D3Placement, RddPlacement};
    use crate::recovery::node::node_recovery_plans;

    fn spec() -> SystemSpec {
        SystemSpec::paper_default()
    }

    #[test]
    fn recovery_completes_and_throughput_positive() {
        let s = spec();
        let p = D3Placement::new(CodeSpec::Rs { k: 2, m: 1 }, s.cluster).unwrap();
        let failed = Location::new(0, 0);
        let plans = node_recovery_plans(&p, 200, failed, 0);
        let out = run_recovery(&s, &plans, failed, RecoveryConfig::default());
        assert!(out.makespan > 0.0);
        assert!(out.throughput_mb_s > 0.0);
        assert_eq!(out.blocks, plans.len());
    }

    #[test]
    fn d3_lambda_much_smaller_than_rdd() {
        let s = spec();
        let failed = Location::new(0, 0);
        let d3 = D3Placement::new(CodeSpec::Rs { k: 2, m: 1 }, s.cluster).unwrap();
        let rdd = RddPlacement::new(CodeSpec::Rs { k: 2, m: 1 }, s.cluster, 17);
        let stripes = 1000;
        let d3_out = run_recovery(
            &s,
            &node_recovery_plans(&d3, stripes, failed, 0),
            failed,
            RecoveryConfig::default(),
        );
        let rdd_out = run_recovery(
            &s,
            &node_recovery_plans(&rdd, stripes, failed, 17),
            failed,
            RecoveryConfig::default(),
        );
        assert!(
            d3_out.lambda < 0.3,
            "D³ λ should be small, got {}",
            d3_out.lambda
        );
        assert!(
            rdd_out.lambda > d3_out.lambda,
            "RDD λ {} should exceed D³ λ {}",
            rdd_out.lambda,
            d3_out.lambda
        );
    }

    #[test]
    fn d3_recovers_faster_than_rdd_on_paper_default() {
        // the headline effect (Exp 1): deterministic balance speeds recovery
        let s = spec();
        let failed = Location::new(2, 1);
        let d3 = D3Placement::new(CodeSpec::Rs { k: 2, m: 1 }, s.cluster).unwrap();
        let rdd = RddPlacement::new(CodeSpec::Rs { k: 2, m: 1 }, s.cluster, 3);
        let stripes = 500;
        let a = run_recovery(
            &s,
            &node_recovery_plans(&d3, stripes, failed, 0),
            failed,
            RecoveryConfig::default(),
        );
        let b = run_recovery(
            &s,
            &node_recovery_plans(&rdd, stripes, failed, 3),
            failed,
            RecoveryConfig::default(),
        );
        assert!(
            a.throughput_mb_s > b.throughput_mb_s,
            "D³ {} MB/s <= RDD {} MB/s",
            a.throughput_mb_s,
            b.throughput_mb_s
        );
    }

    #[test]
    fn degraded_read_latency_sane() {
        use crate::recovery::plan::plan_degraded_read;
        let s = spec();
        let p = D3Placement::new(CodeSpec::Rs { k: 3, m: 2 }, s.cluster).unwrap();
        let client = Location::new(7, 2);
        let plan = plan_degraded_read(&p, 4, 0, client, 0);
        let t = run_degraded_read(&s, &plan);
        // one 16 MB cross-rack block at 100 Mb/s ≈ 1.34 s minimum
        assert!(t > 1.0 && t < 60.0, "latency {t}");
    }

    #[test]
    fn admission_respects_stream_limit() {
        // with 1 stream/node on a single-writer workload, jobs serialize:
        // makespan ≈ n_jobs × per-job time
        let s = spec();
        let p = D3Placement::new(CodeSpec::Rs { k: 2, m: 1 }, s.cluster).unwrap();
        let failed = Location::new(0, 0);
        let plans = node_recovery_plans(&p, 50, failed, 0);
        let fast = run_recovery(
            &s,
            &plans,
            failed,
            RecoveryConfig { streams_per_node: 8, ..RecoveryConfig::default() },
        );
        let slow = run_recovery(
            &s,
            &plans,
            failed,
            RecoveryConfig { streams_per_node: 1, ..RecoveryConfig::default() },
        );
        assert!(slow.makespan >= fast.makespan, "more streams can't be slower");
    }

    #[test]
    fn balanced_admission_rebuilds_everything_with_identical_traffic() {
        // the balanced order is a permutation of the same plan set, so
        // blocks and port bytes must match FIFO exactly
        let s = spec();
        let p = D3Placement::new(CodeSpec::Rs { k: 3, m: 2 }, s.cluster).unwrap();
        let failed = Location::new(1, 0);
        let plans = node_recovery_plans(&p, 120, failed, 0);
        assert!(!plans.is_empty(), "failed node holds no blocks");
        let fifo = run_recovery(&s, &plans, failed, RecoveryConfig::default());
        let bal = run_recovery(
            &s,
            &plans,
            failed,
            RecoveryConfig {
                schedule: SchedulePolicy::Balanced,
                ..RecoveryConfig::default()
            },
        );
        assert_eq!(fifo.blocks, bal.blocks);
        assert!(bal.makespan > 0.0);
        let total =
            |o: &RecoveryOutcome| o.rack_loads.iter().map(|&(u, d)| u + d).sum::<f64>();
        assert!((total(&fifo) - total(&bal)).abs() < 1.0);
    }

    #[test]
    fn worker_pool_cap_slows_or_matches_unbounded() {
        let s = spec();
        let p = D3Placement::new(CodeSpec::Rs { k: 2, m: 1 }, s.cluster).unwrap();
        let failed = Location::new(0, 0);
        let plans = node_recovery_plans(&p, 60, failed, 0);
        let unbounded = run_recovery(&s, &plans, failed, RecoveryConfig::default());
        let pooled = run_recovery(
            &s,
            &plans,
            failed,
            RecoveryConfig { workers: 2, ..RecoveryConfig::default() },
        );
        assert!(
            pooled.makespan >= unbounded.makespan,
            "2-worker pool {} s beat unbounded {} s",
            pooled.makespan,
            unbounded.makespan
        );
        // both rebuild everything and move identical cross-rack bytes
        assert_eq!(pooled.blocks, unbounded.blocks);
        let total = |o: &RecoveryOutcome| -> f64 {
            o.rack_loads.iter().map(|&(u, d)| u + d).sum()
        };
        assert!((total(&pooled) - total(&unbounded)).abs() < 1.0);
    }
}
