//! Block placement policies: D³ (the paper's contribution), RDD (random,
//! the deployed default), and HDD (CRUSH-like pseudo-random hashing).
//!
//! A policy deterministically answers "where does block `b` of stripe `s`
//! live?" and "where does its recovered replacement go after node `f`
//! fails?". Both the discrete-event simulator and the mini-HDFS NameNode
//! are driven purely through the [`Placement`] trait.

pub mod d3;
pub mod d3_lrc;
pub mod hdd;
pub mod rdd;

pub use d3::{D3Placement, D3Variant};
pub use d3_lrc::D3LrcPlacement;
pub use hdd::HddPlacement;
pub use rdd::RddPlacement;

use crate::codes::CodeSpec;
use crate::topology::{ClusterSpec, Location};

/// Locations of all `len` blocks of one stripe (index = block index).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct StripePlacement {
    pub locs: Vec<Location>,
}

impl StripePlacement {
    /// Blocks (indices) hosted on `loc`.
    pub fn blocks_on(&self, loc: Location) -> Vec<usize> {
        (0..self.locs.len()).filter(|&i| self.locs[i] == loc).collect()
    }

    /// Blocks hosted anywhere in rack `rack`.
    pub fn blocks_in_rack(&self, rack: u32) -> Vec<usize> {
        (0..self.locs.len()).filter(|&i| self.locs[i].rack == rack).collect()
    }

    /// True iff no rack holds more than `limit` blocks (fault-tolerance
    /// invariant: `limit = m` for RS, 1 for LRC).
    pub fn rack_limit_ok(&self, limit: usize) -> bool {
        let mut counts = std::collections::HashMap::new();
        for l in &self.locs {
            *counts.entry(l.rack).or_insert(0usize) += 1;
        }
        counts.values().all(|&c| c <= limit)
    }

    /// True iff all blocks are on distinct nodes (m-node fault tolerance).
    pub fn nodes_distinct(&self) -> bool {
        let mut set = std::collections::HashSet::new();
        self.locs.iter().all(|l| set.insert(*l))
    }
}

/// A block placement policy.
pub trait Placement: Send + Sync {
    fn name(&self) -> &'static str;
    fn code(&self) -> CodeSpec;
    fn cluster(&self) -> ClusterSpec;

    /// Placement of stripe `sid` (deterministic per policy + seed).
    fn stripe(&self, sid: u64) -> StripePlacement;

    /// Location of a single block — the non-cloning hot-path lookup
    /// (DESIGN.md §16). The default derives it from [`Placement::stripe`];
    /// policies with direct per-block arithmetic (D³) and the table
    /// override it to avoid materializing a full `StripePlacement` per
    /// call.
    fn block_at(&self, sid: u64, block: usize) -> Location {
        self.stripe(sid).locs[block]
    }

    /// Where the recovered copy of block `block` of stripe `sid` goes when
    /// node `failed` fails. Must not be `failed` itself, must not collide
    /// with a surviving block of the stripe, and must preserve the rack
    /// limit.
    fn recovery_target(&self, sid: u64, block: usize, failed: Location) -> Location;

    /// Layout period: `Some(p)` iff `stripe(sid) == stripe(sid % p)` for
    /// all `sid` (D³'s OA constructions repeat every region-cycle ×
    /// region-size stripes). `None` for aperiodic policies (RDD, HDD).
    /// [`PlacementTable`] uses this to cache one full period.
    fn period(&self) -> Option<u64> {
        None
    }
}

/// Table-backed placement lookup (DESIGN.md §7): precomputes stripe →
/// locations once per run, so planning loops over 10k+ stripes do an O(1)
/// indexed lookup instead of re-running OA/hash arithmetic per stripe per
/// wave. Periodic policies (D³, D³-LRC) cache exactly one period and serve
/// *every* stripe id from it; aperiodic policies cache the run's stripe
/// range and fall through to the wrapped policy beyond it.
pub struct PlacementTable {
    inner: std::sync::Arc<dyn Placement>,
    table: Vec<StripePlacement>,
    /// `Some(p)` when `table` covers one full period `p`.
    full_period: Option<u64>,
    /// Lookups that fell through to the wrapped policy.
    fallback_computes: std::sync::atomic::AtomicU64,
}

impl PlacementTable {
    /// Hard cap on cached stripe placements, so at-scale runs (millions
    /// of stripes, or D³ periods in the billions at n = 10k) build in
    /// bounded memory: lookups past the cap stream through the wrapped
    /// policy's arithmetic instead (DESIGN.md §16).
    pub const MAX_CACHED: u64 = 1 << 18;

    /// Precompute the lookup table for a run over stripes `0..stripes`.
    pub fn build(inner: std::sync::Arc<dyn Placement>, stripes: u64) -> PlacementTable {
        let stripes = stripes.max(1);
        let (len, full_period) = match inner.period() {
            Some(p) if p <= stripes => (p, Some(p)),
            Some(_) | None => (stripes, None),
        };
        let table = (0..len.min(Self::MAX_CACHED)).map(|sid| inner.stripe(sid)).collect();
        PlacementTable {
            inner,
            table,
            full_period,
            fallback_computes: std::sync::atomic::AtomicU64::new(0),
        }
    }

    /// Number of cached stripe placements.
    pub fn cached_stripes(&self) -> usize {
        self.table.len()
    }

    /// How many `stripe()` calls had to recompute (cache misses). Zero for
    /// periodic policies once built.
    pub fn fallback_computes(&self) -> u64 {
        self.fallback_computes.load(std::sync::atomic::Ordering::Relaxed)
    }
}

impl Placement for PlacementTable {
    fn name(&self) -> &'static str {
        self.inner.name()
    }

    fn code(&self) -> CodeSpec {
        self.inner.code()
    }

    fn cluster(&self) -> ClusterSpec {
        self.inner.cluster()
    }

    fn stripe(&self, sid: u64) -> StripePlacement {
        let idx = match self.full_period {
            Some(p) => sid % p,
            None => sid,
        };
        if let Some(sp) = self.table.get(idx as usize) {
            return sp.clone();
        }
        self.fallback_computes
            .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        self.inner.stripe(sid)
    }

    fn block_at(&self, sid: u64, block: usize) -> Location {
        let idx = match self.full_period {
            Some(p) => sid % p,
            None => sid,
        };
        if let Some(sp) = self.table.get(idx as usize) {
            return sp.locs[block];
        }
        self.fallback_computes
            .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        self.inner.block_at(sid, block)
    }

    fn recovery_target(&self, sid: u64, block: usize, failed: Location) -> Location {
        self.inner.recovery_target(sid, block, failed)
    }

    fn period(&self) -> Option<u64> {
        self.inner.period()
    }
}

/// D³'s stripe grouping (paper §4.1): `len` blocks into N_g = ⌈len/m⌉
/// groups; the first `t = len mod N_g` groups hold ⌈len/N_g⌉ blocks, the
/// rest ⌊len/N_g⌋. Returns the half-open block-index range of each group.
pub fn d3_groups(len: usize, m: usize) -> Vec<std::ops::Range<usize>> {
    assert!(m >= 1 && len > m, "grouping needs len > m >= 1");
    let ng = len.div_ceil(m);
    let size_max = len.div_ceil(ng);
    let size_min = len / ng;
    let t = len % ng;
    let mut out = Vec::with_capacity(ng);
    let mut start = 0;
    for gidx in 0..ng {
        let sz = if t > 0 && gidx < t { size_max } else { size_min };
        out.push(start..start + sz);
        start += sz;
    }
    assert_eq!(start, len);
    out
}

/// Group index of `block` under [`d3_groups`].
pub fn d3_group_of(groups: &[std::ops::Range<usize>], block: usize) -> usize {
    groups
        .iter()
        .position(|g| g.contains(&block))
        .expect("block out of stripe range")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn grouping_matches_paper_examples() {
        // (3,2)-RS: len 5, m 2 -> groups 2,2,1 (§3.2.1)
        assert_eq!(d3_groups(5, 2), vec![0..2, 2..4, 4..5]);
        // (2,1)-RS: len 3, m 1 -> 1,1,1
        assert_eq!(d3_groups(3, 1), vec![0..1, 1..2, 2..3]);
        // (6,3)-RS: len 9, m 3 -> 3,3,3 (b = 0 case)
        assert_eq!(d3_groups(9, 3), vec![0..3, 3..6, 6..9]);
    }

    #[test]
    fn grouping_respects_lemma_1() {
        // At most m blocks per group, for a sweep of shapes.
        for k in 1..=16usize {
            for m in 1..=6usize {
                let len = k + m;
                if len <= m {
                    continue;
                }
                let groups = d3_groups(len, m);
                assert_eq!(groups.len(), len.div_ceil(m));
                for g in &groups {
                    assert!(g.len() <= m, "k={k} m={m} group {g:?}");
                    assert!(!g.is_empty());
                }
            }
        }
    }

    #[test]
    fn grouping_respects_lemma_2() {
        // If 0 < b < m-1 there are >= 2 groups with <= m-1 blocks.
        for k in 1..=20usize {
            for m in 2..=6usize {
                let len = k + m;
                let b = len % m;
                if b == 0 || b == m - 1 {
                    continue;
                }
                let groups = d3_groups(len, m);
                let small = groups.iter().filter(|g| g.len() <= m - 1).count();
                assert!(small >= 2, "k={k} m={m} groups={groups:?}");
            }
        }
    }

    #[test]
    fn group_of_lookup() {
        let groups = d3_groups(5, 2);
        assert_eq!(d3_group_of(&groups, 0), 0);
        assert_eq!(d3_group_of(&groups, 3), 1);
        assert_eq!(d3_group_of(&groups, 4), 2);
    }

    struct CountingPolicy {
        inner: D3Placement,
        calls: std::sync::atomic::AtomicU64,
    }

    impl Placement for CountingPolicy {
        fn name(&self) -> &'static str {
            self.inner.name()
        }
        fn code(&self) -> CodeSpec {
            self.inner.code()
        }
        fn cluster(&self) -> ClusterSpec {
            self.inner.cluster()
        }
        fn stripe(&self, sid: u64) -> StripePlacement {
            self.calls.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
            self.inner.stripe(sid)
        }
        fn recovery_target(&self, sid: u64, block: usize, failed: Location) -> Location {
            self.inner.recovery_target(sid, block, failed)
        }
        fn period(&self) -> Option<u64> {
            self.inner.period()
        }
    }

    #[test]
    fn placement_table_computes_each_stripe_once_per_period() {
        let inner = D3Placement::new(
            CodeSpec::Rs { k: 3, m: 2 },
            ClusterSpec::new(8, 3),
        )
        .unwrap();
        let period = inner.period().expect("D³ is periodic");
        let counting = std::sync::Arc::new(CountingPolicy {
            inner,
            calls: std::sync::atomic::AtomicU64::new(0),
        });
        let table = PlacementTable::build(counting.clone(), 10_000);
        let built = counting.calls.load(std::sync::atomic::Ordering::Relaxed);
        assert_eq!(built, period, "build computes exactly one period");
        // 10k queries: answers match the raw policy (read via the inner
        // field so the counter only sees table-driven calls)
        for sid in 0..10_000u64 {
            assert_eq!(table.stripe(sid), counting.inner.stripe(sid), "sid={sid}");
        }
        let after = counting.calls.load(std::sync::atomic::Ordering::Relaxed);
        assert_eq!(after, built, "10k lookups must not recompute OA arithmetic");
        assert_eq!(table.fallback_computes(), 0);
        assert_eq!(table.cached_stripes() as u64, period);
    }

    #[test]
    fn placement_table_falls_back_beyond_range_for_aperiodic() {
        let inner = std::sync::Arc::new(RddPlacement::new(
            CodeSpec::Rs { k: 2, m: 1 },
            ClusterSpec::new(8, 3),
            7,
        ));
        let table = PlacementTable::build(inner.clone(), 100);
        for sid in [0u64, 50, 99, 100, 500] {
            assert_eq!(table.stripe(sid), inner.stripe(sid), "sid={sid}");
        }
        assert_eq!(table.fallback_computes(), 2, "two out-of-range lookups");
    }

    #[test]
    fn block_at_matches_stripe_everywhere() {
        let inner = std::sync::Arc::new(
            D3Placement::new(CodeSpec::Rs { k: 3, m: 2 }, ClusterSpec::new(5, 3)).unwrap(),
        );
        let table = PlacementTable::build(inner.clone(), 64);
        for sid in 0..500u64 {
            let sp = inner.stripe(sid);
            for (b, &want) in sp.locs.iter().enumerate() {
                assert_eq!(inner.block_at(sid, b), want, "policy sid={sid} b={b}");
                assert_eq!(table.block_at(sid, b), want, "table sid={sid} b={b}");
            }
        }
    }

    #[test]
    fn placement_table_is_capped_but_exact_beyond_the_cap() {
        let inner = std::sync::Arc::new(RddPlacement::new(
            CodeSpec::Rs { k: 2, m: 1 },
            ClusterSpec::new(8, 3),
            11,
        ));
        let stripes = PlacementTable::MAX_CACHED + 2;
        let table = PlacementTable::build(inner.clone(), stripes);
        assert_eq!(table.cached_stripes() as u64, PlacementTable::MAX_CACHED);
        // beyond-cap lookups stream through the wrapped policy, exactly
        for sid in [PlacementTable::MAX_CACHED, stripes - 1] {
            assert_eq!(table.stripe(sid), inner.stripe(sid), "sid={sid}");
            assert_eq!(table.block_at(sid, 0), inner.block_at(sid, 0), "sid={sid}");
        }
        assert_eq!(table.fallback_computes(), 4);
    }

    #[test]
    fn stripe_placement_helpers() {
        let sp = StripePlacement {
            locs: vec![
                Location::new(0, 0),
                Location::new(0, 1),
                Location::new(1, 2),
            ],
        };
        assert_eq!(sp.blocks_in_rack(0), vec![0, 1]);
        assert_eq!(sp.blocks_on(Location::new(1, 2)), vec![2]);
        assert!(sp.rack_limit_ok(2));
        assert!(!sp.rack_limit_ok(1));
        assert!(sp.nodes_distinct());
    }
}
