//! Block placement policies: D³ (the paper's contribution), RDD (random,
//! the deployed default), and HDD (CRUSH-like pseudo-random hashing).
//!
//! A policy deterministically answers "where does block `b` of stripe `s`
//! live?" and "where does its recovered replacement go after node `f`
//! fails?". Both the discrete-event simulator and the mini-HDFS NameNode
//! are driven purely through the [`Placement`] trait.

pub mod d3;
pub mod d3_lrc;
pub mod hdd;
pub mod rdd;

pub use d3::{D3Placement, D3Variant};
pub use d3_lrc::D3LrcPlacement;
pub use hdd::HddPlacement;
pub use rdd::RddPlacement;

use crate::codes::CodeSpec;
use crate::topology::{ClusterSpec, Location};

/// Locations of all `len` blocks of one stripe (index = block index).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct StripePlacement {
    pub locs: Vec<Location>,
}

impl StripePlacement {
    /// Blocks (indices) hosted on `loc`.
    pub fn blocks_on(&self, loc: Location) -> Vec<usize> {
        (0..self.locs.len()).filter(|&i| self.locs[i] == loc).collect()
    }

    /// Blocks hosted anywhere in rack `rack`.
    pub fn blocks_in_rack(&self, rack: u32) -> Vec<usize> {
        (0..self.locs.len()).filter(|&i| self.locs[i].rack == rack).collect()
    }

    /// True iff no rack holds more than `limit` blocks (fault-tolerance
    /// invariant: `limit = m` for RS, 1 for LRC).
    pub fn rack_limit_ok(&self, limit: usize) -> bool {
        let mut counts = std::collections::HashMap::new();
        for l in &self.locs {
            *counts.entry(l.rack).or_insert(0usize) += 1;
        }
        counts.values().all(|&c| c <= limit)
    }

    /// True iff all blocks are on distinct nodes (m-node fault tolerance).
    pub fn nodes_distinct(&self) -> bool {
        let mut set = std::collections::HashSet::new();
        self.locs.iter().all(|l| set.insert(*l))
    }
}

/// A block placement policy.
pub trait Placement: Send + Sync {
    fn name(&self) -> &'static str;
    fn code(&self) -> CodeSpec;
    fn cluster(&self) -> ClusterSpec;

    /// Placement of stripe `sid` (deterministic per policy + seed).
    fn stripe(&self, sid: u64) -> StripePlacement;

    /// Where the recovered copy of block `block` of stripe `sid` goes when
    /// node `failed` fails. Must not be `failed` itself, must not collide
    /// with a surviving block of the stripe, and must preserve the rack
    /// limit.
    fn recovery_target(&self, sid: u64, block: usize, failed: Location) -> Location;
}

/// D³'s stripe grouping (paper §4.1): `len` blocks into N_g = ⌈len/m⌉
/// groups; the first `t = len mod N_g` groups hold ⌈len/N_g⌉ blocks, the
/// rest ⌊len/N_g⌋. Returns the half-open block-index range of each group.
pub fn d3_groups(len: usize, m: usize) -> Vec<std::ops::Range<usize>> {
    assert!(m >= 1 && len > m, "grouping needs len > m >= 1");
    let ng = len.div_ceil(m);
    let size_max = len.div_ceil(ng);
    let size_min = len / ng;
    let t = len % ng;
    let mut out = Vec::with_capacity(ng);
    let mut start = 0;
    for gidx in 0..ng {
        let sz = if t > 0 && gidx < t { size_max } else { size_min };
        out.push(start..start + sz);
        start += sz;
    }
    assert_eq!(start, len);
    out
}

/// Group index of `block` under [`d3_groups`].
pub fn d3_group_of(groups: &[std::ops::Range<usize>], block: usize) -> usize {
    groups
        .iter()
        .position(|g| g.contains(&block))
        .expect("block out of stripe range")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn grouping_matches_paper_examples() {
        // (3,2)-RS: len 5, m 2 -> groups 2,2,1 (§3.2.1)
        assert_eq!(d3_groups(5, 2), vec![0..2, 2..4, 4..5]);
        // (2,1)-RS: len 3, m 1 -> 1,1,1
        assert_eq!(d3_groups(3, 1), vec![0..1, 1..2, 2..3]);
        // (6,3)-RS: len 9, m 3 -> 3,3,3 (b = 0 case)
        assert_eq!(d3_groups(9, 3), vec![0..3, 3..6, 6..9]);
    }

    #[test]
    fn grouping_respects_lemma_1() {
        // At most m blocks per group, for a sweep of shapes.
        for k in 1..=16usize {
            for m in 1..=6usize {
                let len = k + m;
                if len <= m {
                    continue;
                }
                let groups = d3_groups(len, m);
                assert_eq!(groups.len(), len.div_ceil(m));
                for g in &groups {
                    assert!(g.len() <= m, "k={k} m={m} group {g:?}");
                    assert!(!g.is_empty());
                }
            }
        }
    }

    #[test]
    fn grouping_respects_lemma_2() {
        // If 0 < b < m-1 there are >= 2 groups with <= m-1 blocks.
        for k in 1..=20usize {
            for m in 2..=6usize {
                let len = k + m;
                let b = len % m;
                if b == 0 || b == m - 1 {
                    continue;
                }
                let groups = d3_groups(len, m);
                let small = groups.iter().filter(|g| g.len() <= m - 1).count();
                assert!(small >= 2, "k={k} m={m} groups={groups:?}");
            }
        }
    }

    #[test]
    fn group_of_lookup() {
        let groups = d3_groups(5, 2);
        assert_eq!(d3_group_of(&groups, 0), 0);
        assert_eq!(d3_group_of(&groups, 3), 1);
        assert_eq!(d3_group_of(&groups, 4), 2);
    }

    #[test]
    fn stripe_placement_helpers() {
        let sp = StripePlacement {
            locs: vec![
                Location::new(0, 0),
                Location::new(0, 1),
                Location::new(1, 2),
            ],
        };
        assert_eq!(sp.blocks_in_rack(0), vec![0, 1]);
        assert_eq!(sp.blocks_on(Location::new(1, 2)), vec![2]);
        assert!(sp.rack_limit_ok(2));
        assert!(!sp.rack_limit_ok(1));
        assert!(sp.nodes_distinct());
    }
}
