//! RDD — the random data distribution baseline (paper §6.1).
//!
//! Blocks of each stripe go to random distinct nodes subject to
//! single-rack fault tolerance (≤ `rack_limit` blocks of a stripe per
//! rack). Recovery writes the rebuilt block to a random node that holds no
//! block of the stripe (paper §6.1: node-level exclusion only).
//!
//! **Calibrated skew.** HDFS's "random" placement is not IID-uniform: the
//! chooser weights nodes by free space / load, and real clusters are
//! heterogeneous. The paper's five RDD groups measured λ between 0.33 and
//! 0.97 (Fig 8) — far beyond what IID-uniform placement can produce on
//! this topology (binomially λ ≈ 0.3). We therefore draw nodes from a
//! per-seed *weighted* distribution (`w ∝ exp(γ·u)`, u ∈ [−1, 1]),
//! with γ calibrated so the simulated λ range matches Fig 8. γ = 0
//! (`RddPlacement::uniform`) gives the idealized IID baseline used in the
//! ablation bench.
//!
//! Randomness is a seeded, keyed stream, so placements are reproducible
//! run-to-run (the paper reruns each RDD "group" with a fixed
//! distribution; our seed plays that role).

use crate::codes::CodeSpec;
use crate::topology::{ClusterSpec, Location};
use crate::util::Rng;

use super::{Placement, StripePlacement};

/// Calibrated default skew (see module docs / EXPERIMENTS.md Exp 1).
pub const DEFAULT_SKEW: f64 = 1.0;

pub struct RddPlacement {
    code: CodeSpec,
    cluster: ClusterSpec,
    seed: u64,
    /// log-weight of each node: node i is sampled ∝ exp(weight_i).
    log_w: Vec<f64>,
}

impl RddPlacement {
    pub fn new(code: CodeSpec, cluster: ClusterSpec, seed: u64) -> RddPlacement {
        RddPlacement::with_skew(code, cluster, seed, DEFAULT_SKEW)
    }

    /// Idealized IID-uniform RDD (ablation baseline).
    pub fn uniform(code: CodeSpec, cluster: ClusterSpec, seed: u64) -> RddPlacement {
        RddPlacement::with_skew(code, cluster, seed, 0.0)
    }

    pub fn with_skew(code: CodeSpec, cluster: ClusterSpec, seed: u64, gamma: f64) -> RddPlacement {
        let limit = code.rack_limit();
        assert!(
            cluster.racks * limit >= code.len(),
            "cluster cannot host a stripe within the rack limit"
        );
        assert!(cluster.node_count() >= code.len() + 1, "need a spare node for recovery");
        let mut wrng = Rng::keyed(seed, 0x5eed, 0x77);
        let log_w = (0..cluster.node_count())
            .map(|_| gamma * (wrng.f64() * 2.0 - 1.0))
            .collect();
        RddPlacement { code, cluster, seed, log_w }
    }

    fn rng_for(&self, sid: u64, salt: u64) -> Rng {
        Rng::keyed(self.seed, sid, salt)
    }

    /// Weighted shuffle via Gumbel keys: sorting by `log w + Gumbel` draws
    /// a weighted sample without replacement.
    fn weighted_order(&self, rng: &mut Rng) -> Vec<Location> {
        let mut keyed: Vec<(f64, usize)> = (0..self.cluster.node_count())
            .map(|i| {
                let u = rng.f64().max(1e-12);
                let gumbel = -(-u.ln()).ln();
                (self.log_w[i] + gumbel, i)
            })
            .collect();
        keyed.sort_by(|a, b| b.0.partial_cmp(&a.0).unwrap());
        keyed.into_iter().map(|(_, i)| self.cluster.unflat(i)).collect()
    }
}

impl Placement for RddPlacement {
    fn name(&self) -> &'static str {
        "rdd"
    }

    fn code(&self) -> CodeSpec {
        self.code
    }

    fn cluster(&self) -> ClusterSpec {
        self.cluster
    }

    fn stripe(&self, sid: u64) -> StripePlacement {
        let mut rng = self.rng_for(sid, 0);
        let limit = self.code.rack_limit();
        let nodes = self.weighted_order(&mut rng);
        let mut rack_count = vec![0usize; self.cluster.racks];
        let mut locs = Vec::with_capacity(self.code.len());
        for loc in nodes {
            if locs.len() == self.code.len() {
                break;
            }
            if rack_count[loc.rack as usize] < limit {
                rack_count[loc.rack as usize] += 1;
                locs.push(loc);
            }
        }
        assert_eq!(locs.len(), self.code.len(), "greedy fill must succeed");
        StripePlacement { locs }
    }

    /// Paper §6.1 verbatim: "sends them to a randomly selected node
    /// excluding the nodes containing the blocks of the same stripe" —
    /// note: *node*-level exclusion only; HDFS's random recovery target
    /// does not re-establish the rack spread (that is exactly the layout
    /// drift D³'s deterministic recovery placement avoids).
    fn recovery_target(&self, sid: u64, block: usize, failed: Location) -> Location {
        let sp = self.stripe(sid);
        debug_assert_eq!(sp.locs[block], failed);
        let mut rng = self.rng_for(sid, 1 + block as u64);
        let nodes = self.weighted_order(&mut rng);
        for loc in nodes {
            let holds_block = sp.locs.iter().enumerate().any(|(bi, l)| bi != block && *l == loc);
            if loc != failed && !holds_block {
                return loc;
            }
        }
        unreachable!("constructor guarantees a spare node exists");
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn placement_respects_constraints() {
        for (code, limit) in [
            (CodeSpec::Rs { k: 6, m: 3 }, 3),
            (CodeSpec::Rs { k: 2, m: 1 }, 1),
            (CodeSpec::Lrc { k: 4, l: 2, g: 1 }, 1),
        ] {
            let p = RddPlacement::new(code, ClusterSpec::new(8, 3), 1);
            for sid in 0..1000u64 {
                let sp = p.stripe(sid);
                assert!(sp.nodes_distinct());
                assert!(sp.rack_limit_ok(limit), "{code:?} sid={sid}");
            }
        }
    }

    #[test]
    fn deterministic_per_seed_and_stripe() {
        let p1 = RddPlacement::new(CodeSpec::Rs { k: 3, m: 2 }, ClusterSpec::new(8, 3), 7);
        let p2 = RddPlacement::new(CodeSpec::Rs { k: 3, m: 2 }, ClusterSpec::new(8, 3), 7);
        let p3 = RddPlacement::new(CodeSpec::Rs { k: 3, m: 2 }, ClusterSpec::new(8, 3), 8);
        assert_eq!(p1.stripe(42), p2.stripe(42));
        // different seeds should (overwhelmingly) differ somewhere
        assert!((0..50).any(|sid| p1.stripe(sid) != p3.stripe(sid)));
    }

    #[test]
    fn placements_actually_random_across_stripes() {
        let p = RddPlacement::new(CodeSpec::Rs { k: 2, m: 1 }, ClusterSpec::new(8, 3), 1);
        let distinct: std::collections::HashSet<Vec<Location>> =
            (0..50u64).map(|sid| p.stripe(sid).locs).collect();
        assert!(distinct.len() > 10, "suspiciously repetitive placement");
    }

    #[test]
    fn recovery_target_valid() {
        let p = RddPlacement::new(CodeSpec::Rs { k: 3, m: 2 }, ClusterSpec::new(8, 3), 3);
        for sid in 0..500u64 {
            let sp = p.stripe(sid);
            for (bi, &loc) in sp.locs.iter().enumerate() {
                let tgt = p.recovery_target(sid, bi, loc);
                assert_ne!(tgt, loc);
                // §6.1: only node-level exclusion (no rack re-spreading)
                assert!(!sp.locs.iter().enumerate().any(|(o, l)| o != bi && *l == tgt));
            }
        }
    }

    #[test]
    fn recovery_targets_spread_over_many_racks() {
        // LRC stripes touch 7 of 8 racks; RDD's node-level rule still
        // spreads the recovered copies over the whole cluster
        let p = RddPlacement::new(CodeSpec::Lrc { k: 4, l: 2, g: 1 }, ClusterSpec::new(8, 3), 3);
        let mut racks = std::collections::HashSet::new();
        for sid in 0..200u64 {
            let sp = p.stripe(sid);
            let tgt = p.recovery_target(sid, 0, sp.locs[0]);
            racks.insert(tgt.rack);
        }
        assert!(racks.len() >= 6, "targets concentrated: {racks:?}");
    }

    #[test]
    #[should_panic(expected = "cannot host")]
    fn impossible_config_rejected() {
        RddPlacement::new(CodeSpec::Rs { k: 6, m: 1 }, ClusterSpec::new(4, 3), 0);
    }
}
