//! D³ placement for (k, l, g)-LRCs (paper §4.4) and recovery targets (§5.2).
//!
//! LRC keeps maximum rack-level fault tolerance: **one block per rack**, so
//! a stripe spans N_g = k + l + g racks and only node choice needs balance:
//!
//! * rack level — 𝓜 from OA(r, N_g + 1) maps each block position (its own
//!   region-group) to a rack; the last column addresses recovered blocks;
//! * node level — an OA(n, N_g^lrc) with N_g^lrc = max(k/l + 1, l + g)
//!   columns; each parity block gets its own column; each data block gets a
//!   column different from its local parity's (§4.4.1), so Property 2
//!   balances the repair reads of any failed block across the nodes of
//!   every surviving rack.

use crate::codes::CodeSpec;
use crate::oa::{max_columns, MMatrix, OrthogonalArray};
use crate::topology::{ClusterSpec, Location};

use super::{Placement, StripePlacement};

pub struct D3LrcPlacement {
    code: CodeSpec,
    cluster: ClusterSpec,
    ng: usize,
    /// Node-level OA(n, N_g^lrc).
    a: OrthogonalArray,
    /// 𝓜 from OA(r, N_g + 1).
    m: MMatrix,
    /// OA column assigned to each block position (§4.4.1 rules).
    col_of: Vec<usize>,
    /// rank[col][value] = ascending rows of 𝓐 column `col` equal to `value`.
    rank: Vec<Vec<Vec<u16>>>,
}

#[derive(Debug, thiserror::Error)]
pub enum D3LrcError {
    #[error("D³-LRC needs an LRC code")]
    NotLrc,
    #[error("node OA(n={n}, {cols}) unavailable (max {max})")]
    NodeOa { n: usize, cols: usize, max: usize },
    #[error("rack OA(r={r}, {cols}) unavailable (max {max}); need r > N_g = k+l+g")]
    RackOa { r: usize, cols: usize, max: usize },
}

impl D3LrcPlacement {
    pub fn new(code: CodeSpec, cluster: ClusterSpec) -> Result<D3LrcPlacement, D3LrcError> {
        let CodeSpec::Lrc { k, l, g } = code else {
            return Err(D3LrcError::NotLrc);
        };
        assert!(k % l == 0, "(k,l,g)-LRC requires l | k");
        let ng = k + l + g;
        let group = k / l;
        let ng_lrc = (group + 1).max(l + g);
        let n = cluster.nodes_per_rack;
        let r = cluster.racks;
        let a = OrthogonalArray::construct(n, ng_lrc.max(2).min(max_columns(n)))
            .map_err(|_| D3LrcError::NodeOa { n, cols: ng_lrc, max: max_columns(n) })?;
        if a.cols() < ng_lrc {
            return Err(D3LrcError::NodeOa { n, cols: ng_lrc, max: max_columns(n) });
        }
        let a_prime = OrthogonalArray::construct(r, (ng + 1).max(2).min(max_columns(r)))
            .map_err(|_| D3LrcError::RackOa { r, cols: ng + 1, max: max_columns(r) })?;
        if a_prime.cols() < ng + 1 {
            return Err(D3LrcError::RackOa { r, cols: ng + 1, max: max_columns(r) });
        }
        // §4.4.1 column assignment: parity blocks first (own column each),
        // then data of group j over the columns != j in order.
        let mut col_of = vec![0usize; ng];
        for j in 0..l {
            col_of[k + j] = j; // local parity j -> column j
        }
        for j in 0..g {
            col_of[k + l + j] = l + j; // global parity j -> column l + j
        }
        for gid in 0..l {
            let avail: Vec<usize> = (0..ng_lrc).filter(|&c| c != gid).collect();
            for (idx, d) in (gid * group..(gid + 1) * group).enumerate() {
                col_of[d] = avail[idx % avail.len()];
            }
        }
        let rank = build_rank(&a, ng_lrc);
        Ok(D3LrcPlacement { code, cluster, ng, a, m: a_prime.m_matrix(), col_of, rank })
    }

    pub fn region_size(&self) -> usize {
        let n = self.cluster.nodes_per_rack;
        n * n
    }

    pub fn region_cycle(&self) -> usize {
        self.m.rows()
    }

    pub fn col_of(&self, block: usize) -> usize {
        self.col_of[block]
    }

    fn decompose(&self, sid: u64) -> (usize, usize) {
        let region_size = self.region_size() as u64;
        let i = (sid % region_size) as usize;
        let row = ((sid / region_size) % self.region_cycle() as u64) as usize;
        (i, row)
    }
}

fn build_rank(a: &OrthogonalArray, cols: usize) -> Vec<Vec<Vec<u16>>> {
    let n = a.n();
    (0..cols)
        .map(|col| {
            let mut per_value = vec![Vec::new(); n];
            for row in 0..a.rows() {
                per_value[a.entry(row, col)].push(row as u16);
            }
            per_value
        })
        .collect()
}

impl Placement for D3LrcPlacement {
    fn name(&self) -> &'static str {
        "d3-lrc"
    }

    fn code(&self) -> CodeSpec {
        self.code
    }

    fn cluster(&self) -> ClusterSpec {
        self.cluster
    }

    fn stripe(&self, sid: u64) -> StripePlacement {
        let (i, row) = self.decompose(sid);
        let locs = (0..self.ng)
            .map(|pos| {
                let rack = self.m.entry(row, pos);
                let node = self.a.entry(i, self.col_of[pos]);
                Location::new(rack, node)
            })
            .collect();
        StripePlacement { locs }
    }

    /// §5.2: recovered blocks go to the rack named by 𝓜's last column,
    /// nodes chosen round-robin (balanced within each region).
    fn recovery_target(&self, sid: u64, block: usize, failed: Location) -> Location {
        let (i, row) = self.decompose(sid);
        debug_assert_eq!(self.stripe(sid).locs[block], failed);
        let rack = self.m.entry(row, self.ng);
        let col = self.col_of[block];
        let v = self.a.entry(i, col);
        let list = &self.rank[col][v];
        let pos = list.iter().position(|&x| x as usize == i).expect("row in rank list");
        Location::new(rack, pos % self.cluster.nodes_per_rack)
    }

    /// The layout repeats every r(r−1) regions × n² stripes.
    fn period(&self) -> Option<u64> {
        Some((self.region_cycle() * self.region_size()) as u64)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::HashMap;

    fn lrc_421() -> D3LrcPlacement {
        D3LrcPlacement::new(CodeSpec::Lrc { k: 4, l: 2, g: 1 }, ClusterSpec::new(8, 3)).unwrap()
    }

    #[test]
    fn one_block_per_rack() {
        let p = lrc_421();
        for sid in 0..1000u64 {
            let sp = p.stripe(sid);
            assert!(sp.rack_limit_ok(1), "sid={sid}");
            assert!(sp.nodes_distinct());
        }
    }

    #[test]
    fn column_assignment_follows_paper_rules() {
        let p = lrc_421();
        // parity blocks all on distinct columns
        let parity_cols: Vec<usize> = (4..7).map(|b| p.col_of(b)).collect();
        let set: std::collections::HashSet<usize> = parity_cols.iter().copied().collect();
        assert_eq!(set.len(), 3);
        // each data block's column differs from its local parity's column
        for d in 0..4 {
            let gid = d / 2;
            assert_ne!(p.col_of(d), p.col_of(4 + gid), "d{d} shares col with its local parity");
        }
        // paper Fig 7 grouping: {p0,d2} col 0, {d0,p1} col 1, {d1,d3,p2} col 2
        assert_eq!(p.col_of(4), 0);
        assert_eq!(p.col_of(2), 0);
        assert_eq!(p.col_of(0), 1);
        assert_eq!(p.col_of(5), 1);
        assert_eq!(p.col_of(1), 2);
        assert_eq!(p.col_of(3), 2);
        assert_eq!(p.col_of(6), 2);
    }

    #[test]
    fn theorem_4_uniform_block_type_distribution() {
        // Over a full cycle each node holds equal counts of data blocks,
        // local parities, and global parities.
        let cluster = ClusterSpec::new(8, 3);
        let p = D3LrcPlacement::new(CodeSpec::Lrc { k: 4, l: 2, g: 1 }, cluster).unwrap();
        let total = (p.region_cycle() * p.region_size()) as u64;
        let mut counts: HashMap<(Location, usize), usize> = HashMap::new(); // (node, type)
        for sid in 0..total {
            let sp = p.stripe(sid);
            for (bi, loc) in sp.locs.iter().enumerate() {
                let ty = if bi < 4 {
                    0
                } else if bi < 6 {
                    1
                } else {
                    2
                };
                *counts.entry((*loc, ty)).or_default() += 1;
            }
        }
        for ty in 0..3usize {
            let vals: Vec<usize> = cluster
                .iter_nodes()
                .map(|l| counts.get(&(l, ty)).copied().unwrap_or(0))
                .collect();
            let first = vals[0];
            assert!(vals.iter().all(|&v| v == first), "type {ty} skew: {vals:?}");
        }
    }

    #[test]
    fn recovery_target_valid_and_new_rack() {
        let p = lrc_421();
        for sid in 0..500u64 {
            let sp = p.stripe(sid);
            for (bi, &loc) in sp.locs.iter().enumerate() {
                let tgt = p.recovery_target(sid, bi, loc);
                assert_ne!(tgt, loc);
                // new rack must differ from every rack the stripe occupies
                assert!(
                    sp.locs.iter().all(|l| l.rack != tgt.rack),
                    "sid={sid} block={bi}: recovered block landed in an occupied rack"
                );
            }
        }
    }

    #[test]
    fn recovery_round_robin_balanced_within_region() {
        let p = lrc_421();
        let failed = Location::new(1, 0);
        let mut per_node: HashMap<Location, usize> = HashMap::new();
        for sid in 0..p.region_size() as u64 {
            let sp = p.stripe(sid);
            for (bi, &loc) in sp.locs.iter().enumerate() {
                if loc == failed {
                    *per_node.entry(p.recovery_target(sid, bi, loc)).or_default() += 1;
                }
            }
        }
        if per_node.is_empty() {
            return; // this rack holds no block in region 0 (depends on M)
        }
        let max = per_node.values().max().unwrap();
        let min = per_node.values().min().unwrap();
        assert!(max - min <= 1, "unbalanced: {per_node:?}");
    }

    #[test]
    fn too_few_racks_rejected() {
        // (4,2,1): N_g + 1 = 8 columns need r >= 8
        assert!(matches!(
            D3LrcPlacement::new(CodeSpec::Lrc { k: 4, l: 2, g: 1 }, ClusterSpec::new(7, 3)),
            Err(D3LrcError::RackOa { .. })
        ));
    }

    #[test]
    fn wide_stripe_config() {
        // (12,2,2)-LRC on 17 racks (prime): N_g + 1 = 17 columns OK
        let p = D3LrcPlacement::new(
            CodeSpec::Lrc { k: 12, l: 2, g: 2 },
            ClusterSpec::new(17, 7),
        )
        .unwrap();
        for sid in 0..200u64 {
            let sp = p.stripe(sid);
            assert!(sp.rack_limit_ok(1));
        }
    }
}
