//! HDD — hash-based data distribution baseline (paper §6.2.1, Exp 1).
//!
//! CRUSH-style [15]: a Jenkins hash maps (stripe, block, attempt) to a
//! node; on conflict the attempt counter bumps and the hash reselects,
//! mirroring CRUSH's reselection behaviour for the three cases the paper
//! lists: (1) node already used by the stripe, (2) rack limit violated,
//! (3) node failed (recovery only).

use crate::codes::CodeSpec;
use crate::topology::{ClusterSpec, Location};

use super::{Placement, StripePlacement};

/// Bob Jenkins' 96-bit mix (the `mix()` used by lookup2/CRUSH's rjenkins1).
fn jenkins_mix(mut a: u32, mut b: u32, mut c: u32) -> u32 {
    a = a.wrapping_sub(b).wrapping_sub(c) ^ (c >> 13);
    b = b.wrapping_sub(c).wrapping_sub(a) ^ (a << 8);
    c = c.wrapping_sub(a).wrapping_sub(b) ^ (b >> 13);
    a = a.wrapping_sub(b).wrapping_sub(c) ^ (c >> 12);
    b = b.wrapping_sub(c).wrapping_sub(a) ^ (a << 16);
    c = c.wrapping_sub(a).wrapping_sub(b) ^ (b >> 5);
    a = a.wrapping_sub(b).wrapping_sub(c) ^ (c >> 3);
    b = b.wrapping_sub(c).wrapping_sub(a) ^ (a << 10);
    c = c.wrapping_sub(a).wrapping_sub(b) ^ (b >> 15);
    c
}

fn jenkins(stripe: u64, block: u32, attempt: u32, seed: u32) -> u32 {
    let h = jenkins_mix(stripe as u32, (stripe >> 32) as u32, 0x9e3779b9 ^ seed);
    jenkins_mix(h, block, attempt)
}

pub struct HddPlacement {
    code: CodeSpec,
    cluster: ClusterSpec,
    seed: u32,
}

impl HddPlacement {
    pub fn new(code: CodeSpec, cluster: ClusterSpec, seed: u32) -> HddPlacement {
        assert!(
            cluster.racks * code.rack_limit() >= code.len(),
            "cluster cannot host a stripe within the rack limit"
        );
        assert!(cluster.node_count() >= code.len() + 1, "need a spare node for recovery");
        HddPlacement { code, cluster, seed }
    }

    /// Pick the node for `block`, skipping candidates that fail `ok`.
    fn select(&self, sid: u64, block: usize, mut ok: impl FnMut(Location) -> bool) -> Location {
        let count = self.cluster.node_count() as u32;
        for attempt in 0..10_000u32 {
            let h = jenkins(sid, block as u32, attempt, self.seed);
            let loc = self.cluster.unflat((h % count) as usize);
            if ok(loc) {
                return loc;
            }
        }
        unreachable!("reselection failed to converge (cluster too tight)");
    }
}

impl Placement for HddPlacement {
    fn name(&self) -> &'static str {
        "hdd"
    }

    fn code(&self) -> CodeSpec {
        self.code
    }

    fn cluster(&self) -> ClusterSpec {
        self.cluster
    }

    fn stripe(&self, sid: u64) -> StripePlacement {
        let limit = self.code.rack_limit();
        let mut locs: Vec<Location> = Vec::with_capacity(self.code.len());
        let mut rack_count = vec![0usize; self.cluster.racks];
        for block in 0..self.code.len() {
            let loc = self.select(sid, block, |cand| {
                !locs.contains(&cand) && rack_count[cand.rack as usize] < limit
            });
            rack_count[loc.rack as usize] += 1;
            locs.push(loc);
        }
        StripePlacement { locs }
    }

    fn recovery_target(&self, sid: u64, block: usize, failed: Location) -> Location {
        let sp = self.stripe(sid);
        debug_assert_eq!(sp.locs[block], failed);
        let limit = self.code.rack_limit();
        let mut rack_count = vec![0usize; self.cluster.racks];
        for (bi, l) in sp.locs.iter().enumerate() {
            if bi != block {
                rack_count[l.rack as usize] += 1;
            }
        }
        // continue the attempt sequence past the original selection with a
        // "failure epoch" salt, mirroring CRUSH's modified-input reselection
        self.select(sid, block + self.code.len(), |cand| {
            cand != failed
                && !sp.locs.iter().enumerate().any(|(bi, l)| bi != block && *l == cand)
                && rack_count[cand.rack as usize] < limit
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn constraints_hold() {
        let p = HddPlacement::new(CodeSpec::Rs { k: 3, m: 2 }, ClusterSpec::new(8, 3), 0);
        for sid in 0..1000u64 {
            let sp = p.stripe(sid);
            assert!(sp.nodes_distinct());
            assert!(sp.rack_limit_ok(2));
        }
    }

    #[test]
    fn deterministic_but_pseudo_random() {
        let p = HddPlacement::new(CodeSpec::Rs { k: 2, m: 1 }, ClusterSpec::new(8, 3), 0);
        assert_eq!(p.stripe(99).locs, p.stripe(99).locs);
        let distinct: std::collections::HashSet<Vec<Location>> =
            (0..50u64).map(|sid| p.stripe(sid).locs).collect();
        assert!(distinct.len() > 10);
    }

    #[test]
    fn hash_distribution_roughly_uniform() {
        // each node should receive a roughly equal share over many stripes
        let cluster = ClusterSpec::new(8, 3);
        let p = HddPlacement::new(CodeSpec::Rs { k: 2, m: 1 }, cluster, 0);
        let mut counts = vec![0usize; cluster.node_count()];
        let stripes = 4000u64;
        for sid in 0..stripes {
            for l in p.stripe(sid).locs {
                counts[cluster.flat(l)] += 1;
            }
        }
        let expect = (stripes as usize * 3) / cluster.node_count();
        for (i, &c) in counts.iter().enumerate() {
            assert!(
                (c as f64) > 0.7 * expect as f64 && (c as f64) < 1.3 * expect as f64,
                "node {i}: {c} vs expected {expect}"
            );
        }
    }

    #[test]
    fn recovery_target_valid() {
        let p = HddPlacement::new(CodeSpec::Rs { k: 3, m: 2 }, ClusterSpec::new(8, 3), 1);
        for sid in 0..300u64 {
            let sp = p.stripe(sid);
            for (bi, &loc) in sp.locs.iter().enumerate() {
                let tgt = p.recovery_target(sid, bi, loc);
                assert_ne!(tgt, loc);
                assert!(!sp.locs.iter().enumerate().any(|(o, l)| o != bi && *l == tgt));
            }
        }
    }
}
