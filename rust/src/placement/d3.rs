//! D³ placement for (k, m)-RS codes (paper §4) and its recovered-block
//! targets (§5.1.2–5.1.3).
//!
//! Layout pipeline:
//! 1. **Stripe grouping** (§4.1): `len = k + m` blocks → N_g = ⌈len/m⌉
//!    groups ([`super::d3_groups`]); each group lives in one rack.
//! 2. **Within-rack balance** (§4.2): an OA(n, N_g) 𝓐 drives node choice —
//!    the kk-th block of group j of stripe i (within its region of n²
//!    stripes) goes to node `(a_ij + kk) mod n` of the group's rack.
//! 3. **Cross-rack balance** (§4.3): an OA(r, N_g + 1) 𝓐′ minus its first r
//!    identical rows (𝓜, r(r−1) rows) maps region-groups to racks; the last
//!    column reserves the rack for recovered blocks that need a *new* rack.
//!
//! Ablation variants ([`D3Variant`]) keep the grouping but knock out one
//! balancing mechanism each (DESIGN.md §6).

use crate::codes::CodeSpec;
use crate::oa::{max_columns, MMatrix, OrthogonalArray};
use crate::topology::{ClusterSpec, Location};

use super::{d3_group_of, d3_groups, Placement, StripePlacement};

/// Which D³ mechanisms are active (ablations knock one out).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum D3Variant {
    /// The full paper design.
    Full,
    /// Grouping + region map kept; within-rack OA rotation replaced by a
    /// per-stripe hash offset (ablation for §4.2).
    NoRotation,
    /// Grouping + rotation kept; 𝓜 replaced by round-robin region→rack
    /// assignment (ablation for §4.3).
    RoundRobinRegions,
}

/// D³ block placement over (k, m)-RS.
pub struct D3Placement {
    code: CodeSpec,
    cluster: ClusterSpec,
    groups: Vec<std::ops::Range<usize>>,
    ng: usize,
    /// OA(n, N_g): within-rack layout.
    a: OrthogonalArray,
    /// 𝓜 from OA(r, N_g + 1): region-group → rack, plus recovery column.
    m: MMatrix,
    variant: D3Variant,
}

/// Errors from D³ construction (§4.5 validity conditions).
#[derive(Debug, thiserror::Error)]
pub enum D3Error {
    #[error("D³ needs an RS code (use D3LrcPlacement for LRC)")]
    NotRs,
    #[error("nodes per rack n={n} must be >= group size {size} (n >= m)")]
    RackTooSmall { n: usize, size: usize },
    #[error("within-rack OA(n={n}, {cols}) unavailable: max columns {max} (§4.5)")]
    NodeOa { n: usize, cols: usize, max: usize },
    #[error("cross-rack OA(r={r}, {cols}) unavailable: max columns {max}; need r > N_g (§4.5)")]
    RackOa { r: usize, cols: usize, max: usize },
}

impl D3Placement {
    pub fn new(code: CodeSpec, cluster: ClusterSpec) -> Result<D3Placement, D3Error> {
        D3Placement::with_variant(code, cluster, D3Variant::Full)
    }

    pub fn with_variant(
        code: CodeSpec,
        cluster: ClusterSpec,
        variant: D3Variant,
    ) -> Result<D3Placement, D3Error> {
        let CodeSpec::Rs { k, m } = code else {
            return Err(D3Error::NotRs);
        };
        let len = k + m;
        let groups = d3_groups(len, m);
        let ng = groups.len();
        let n = cluster.nodes_per_rack;
        let r = cluster.racks;
        let size_max = groups.iter().map(|g| g.len()).max().unwrap();
        if n < size_max {
            return Err(D3Error::RackTooSmall { n, size: size_max });
        }
        let a = OrthogonalArray::construct(n, ng.max(2).min(max_columns(n)))
            .map_err(|_| D3Error::NodeOa { n, cols: ng, max: max_columns(n) })?;
        if a.cols() < ng {
            return Err(D3Error::NodeOa { n, cols: ng, max: max_columns(n) });
        }
        let a_prime = OrthogonalArray::construct(r, (ng + 1).max(2).min(max_columns(r)))
            .map_err(|_| D3Error::RackOa { r, cols: ng + 1, max: max_columns(r) })?;
        if a_prime.cols() < ng + 1 {
            return Err(D3Error::RackOa { r, cols: ng + 1, max: max_columns(r) });
        }
        let m_matrix = a_prime.m_matrix();
        Ok(D3Placement { code, cluster, groups, ng, a, m: m_matrix, variant })
    }

    pub fn groups(&self) -> &[std::ops::Range<usize>] {
        &self.groups
    }

    pub fn ng(&self) -> usize {
        self.ng
    }

    /// Stripes per region: n².
    pub fn region_size(&self) -> usize {
        let n = self.cluster.nodes_per_rack;
        n * n
    }

    /// Regions before the rack pattern repeats: r(r−1).
    pub fn region_cycle(&self) -> usize {
        self.m.rows()
    }

    fn decompose(&self, sid: u64) -> (usize, usize) {
        let region_size = self.region_size() as u64;
        let i = (sid % region_size) as usize;
        let row = ((sid / region_size) % self.region_cycle() as u64) as usize;
        (i, row)
    }

    /// Rack hosting group `j` of the stripe region at 𝓜 row `row`.
    fn group_rack(&self, row: usize, j: usize) -> usize {
        match self.variant {
            D3Variant::RoundRobinRegions => (row + j) % self.cluster.racks,
            _ => self.m.entry(row, j),
        }
    }

    /// Rack reserved for recovered blocks needing a new rack (§5.1.3).
    fn recovery_rack(&self, row: usize) -> usize {
        match self.variant {
            D3Variant::RoundRobinRegions => (row + self.ng) % self.cluster.racks,
            _ => self.m.entry(row, self.ng),
        }
    }

    /// Base node offset for group `j` of within-region stripe `i`.
    fn group_base_node(&self, sid: u64, i: usize, j: usize) -> usize {
        match self.variant {
            D3Variant::NoRotation => {
                // ablation: hash offset instead of OA entry
                (splitmix(sid ^ (j as u64).wrapping_mul(0x9e37)) as usize)
                    % self.cluster.nodes_per_rack
            }
            _ => self.a.entry(i, j),
        }
    }

    /// Round-robin rank of within-region stripe `i` among the region's
    /// stripes whose 𝓐 entry at column `j` equals 𝓐's entry for `i`
    /// (used for node assignment inside a *new* rack, Fig 4(b)).
    ///
    /// Closed form: with row id `i = i₁·n + i₂`, the linear OA entry at
    /// column c is `i₁·c + i₂` over the component fields, so within a
    /// value class each `i₁` appears exactly once and ascending row order
    /// is ascending `i₁` — the rank of row `i` in its class is `i / n`
    /// for every column. (The old explicit rank lists also stored row ids
    /// as `u16`, overflowing silently at n ≥ 256; the closed form scales
    /// to any n and is O(1).)
    fn new_rack_node(&self, i: usize, _j: usize) -> usize {
        (i / self.a.n()) % self.cluster.nodes_per_rack
    }
}

fn splitmix(mut x: u64) -> u64 {
    x = x.wrapping_add(0x9e3779b97f4a7c15);
    x = (x ^ (x >> 30)).wrapping_mul(0xbf58476d1ce4e5b9);
    x = (x ^ (x >> 27)).wrapping_mul(0x94d049bb133111eb);
    x ^ (x >> 31)
}

impl Placement for D3Placement {
    fn name(&self) -> &'static str {
        match self.variant {
            D3Variant::Full => "d3",
            D3Variant::NoRotation => "d3-norot",
            D3Variant::RoundRobinRegions => "d3-rr",
        }
    }

    fn code(&self) -> CodeSpec {
        self.code
    }

    fn cluster(&self) -> ClusterSpec {
        self.cluster
    }

    fn stripe(&self, sid: u64) -> StripePlacement {
        let (i, row) = self.decompose(sid);
        let n = self.cluster.nodes_per_rack;
        let mut locs = Vec::with_capacity(self.code.len());
        for (j, group) in self.groups.iter().enumerate() {
            let rack = self.group_rack(row, j);
            let base = self.group_base_node(sid, i, j);
            for kk in 0..group.len() {
                locs.push(Location::new(rack, (base + kk) % n));
            }
        }
        StripePlacement { locs }
    }

    /// Alloc-free single-block lookup (DESIGN.md §16): the same group
    /// arithmetic as [`D3Placement::stripe`] restricted to `block`'s
    /// group — no `Vec` of the whole stripe on the NameNode hot path.
    fn block_at(&self, sid: u64, block: usize) -> Location {
        let (i, row) = self.decompose(sid);
        let j = d3_group_of(&self.groups, block);
        let rack = self.group_rack(row, j);
        let base = self.group_base_node(sid, i, j);
        let n = self.cluster.nodes_per_rack;
        Location::new(rack, (base + (block - self.groups[j].start)) % n)
    }

    /// §5.1 target selection. Cases keyed by b = len mod m:
    /// * b = 0 → new rack (𝓜 last column), round-robin node;
    /// * 0 < b < m−1 → surviving rack R_x: largest-rack-id group with ≤ m−1
    ///   blocks; node after the stripe's largest-subscript block there;
    /// * b = m−1, failed block in a size-m group → the rack of the
    ///   (m−1)-group, node after its largest-subscript block;
    /// * b = m−1, failed block in the (m−1)-group → new rack, round-robin.
    fn recovery_target(&self, sid: u64, block: usize, failed: Location) -> Location {
        let CodeSpec::Rs { k, m } = self.code else { unreachable!() };
        let len = k + m;
        let b = len % m;
        let (i, row) = self.decompose(sid);
        let n = self.cluster.nodes_per_rack;
        let placement = self.stripe(sid);
        debug_assert_eq!(placement.locs[block], failed, "block must be on the failed node");
        let fg = d3_group_of(&self.groups, block);

        let to_new_rack = b == 0 || (b == m - 1 && self.groups[fg].len() == m - 1);
        if to_new_rack {
            let rack = self.recovery_rack(row);
            return Location::new(rack, self.new_rack_node(i, fg));
        }

        // Recovered block joins an existing rack R_x.
        let target_group = if b == m - 1 {
            // the unique (m−1)-sized group (last group)
            self.groups
                .iter()
                .position(|g| g.len() == m - 1)
                .expect("b == m-1 implies an (m-1)-group")
        } else {
            // 0 < b < m−1: surviving group with ≤ m−1 blocks in the rack
            // with the largest rack id
            (0..self.ng)
                .filter(|&j| j != fg && self.groups[j].len() <= m - 1)
                .max_by_key(|&j| self.group_rack(row, j))
                .expect("Lemma 2 guarantees a small surviving group")
        };
        let rack = self.group_rack(row, target_group) as u32;
        // §5.1.2(1): node after the stripe's largest-subscript block in R_x.
        let largest = placement
            .locs
            .iter()
            .enumerate()
            .filter(|(bi, l)| l.rack == rack && *bi != block)
            .map(|(bi, _)| bi)
            .max()
            .expect("target rack holds surviving blocks");
        let jj = placement.locs[largest].node as usize;
        Location::new(rack as usize, (jj + 1) % n)
    }

    /// The layout repeats every r(r−1) regions × n² stripes. The
    /// `NoRotation` ablation hashes the raw stripe id, so it is aperiodic.
    fn period(&self) -> Option<u64> {
        match self.variant {
            D3Variant::NoRotation => None,
            _ => Some((self.region_cycle() * self.region_size()) as u64),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::HashMap;

    fn paper_cluster() -> ClusterSpec {
        ClusterSpec::new(8, 3)
    }

    fn d3(k: usize, m: usize, cluster: ClusterSpec) -> D3Placement {
        D3Placement::new(CodeSpec::Rs { k, m }, cluster).unwrap()
    }

    #[test]
    fn respects_fault_tolerance_invariants() {
        for (k, m) in [(2, 1), (3, 2), (6, 3), (4, 2)] {
            let p = d3(k, m, paper_cluster());
            for sid in 0..2000u64 {
                let sp = p.stripe(sid);
                assert!(sp.nodes_distinct(), "({k},{m}) sid={sid}: node collision");
                assert!(sp.rack_limit_ok(m), "({k},{m}) sid={sid}: rack over limit");
            }
        }
    }

    #[test]
    fn theorem_2_uniform_distribution() {
        // Over one full cycle (r(r-1) regions × n² stripes) every node holds
        // the same number of data blocks and the same number of parity blocks.
        let cluster = ClusterSpec::new(5, 3);
        for (k, m) in [(3usize, 2usize), (2, 1)] {
            let p = d3(k, m, cluster);
            let total = (p.region_cycle() * p.region_size()) as u64;
            let mut data_cnt: HashMap<Location, usize> = HashMap::new();
            let mut parity_cnt: HashMap<Location, usize> = HashMap::new();
            for sid in 0..total {
                let sp = p.stripe(sid);
                for (bi, loc) in sp.locs.iter().enumerate() {
                    if bi < k {
                        *data_cnt.entry(*loc).or_default() += 1;
                    } else {
                        *parity_cnt.entry(*loc).or_default() += 1;
                    }
                }
            }
            let nodes = cluster.node_count();
            assert_eq!(data_cnt.len(), nodes, "({k},{m}): some node holds no data");
            let d0 = *data_cnt.values().next().unwrap();
            assert!(data_cnt.values().all(|&c| c == d0), "({k},{m}) data skew: {data_cnt:?}");
            let p0 = *parity_cnt.values().next().unwrap();
            assert!(parity_cnt.values().all(|&c| c == p0), "({k},{m}) parity skew");
        }
    }

    #[test]
    fn paper_example_3_2_rs_grouping_layout() {
        // §3.2: (3,2)-RS on 5 racks × 3 nodes: groups {B0,B1},{B2,B3},{B4};
        // groups land in 3 distinct racks with sizes 2,2,1.
        let p = d3(3, 2, ClusterSpec::new(5, 3));
        for sid in 0..45u64 {
            let sp = p.stripe(sid);
            let racks: Vec<u32> = sp.locs.iter().map(|l| l.rack).collect();
            assert_eq!(racks[0], racks[1], "B0,B1 same rack");
            assert_eq!(racks[2], racks[3], "B2,B3 same rack");
            let distinct: std::collections::HashSet<u32> = racks.iter().copied().collect();
            assert_eq!(distinct.len(), 3, "3 racks per stripe");
            // within a group, nodes are consecutive (rotation)
            let n0 = sp.locs[0].node;
            assert_eq!(sp.locs[1].node, (n0 + 1) % 3);
        }
    }

    #[test]
    fn recovery_target_is_valid() {
        for (k, m) in [(2usize, 1usize), (3, 2), (6, 3), (4, 2)] {
            let p = d3(k, m, paper_cluster());
            for sid in 0..600u64 {
                let sp = p.stripe(sid);
                for (bi, &loc) in sp.locs.iter().enumerate() {
                    let tgt = p.recovery_target(sid, bi, loc);
                    assert_ne!(tgt, loc, "target == failed");
                    assert!(
                        !sp.locs.iter().enumerate().any(|(o, l)| o != bi && *l == tgt),
                        "({k},{m}) sid={sid} block={bi}: target collides with survivor"
                    );
                    // rack limit still holds after placing the recovered copy
                    let mut count = sp
                        .locs
                        .iter()
                        .enumerate()
                        .filter(|(o, l)| *o != bi && l.rack == tgt.rack)
                        .count();
                    count += 1;
                    assert!(count <= m, "({k},{m}) sid={sid}: rack {} over limit", tgt.rack);
                }
            }
        }
    }

    #[test]
    fn recovery_new_rack_round_robin_is_balanced() {
        // (2,1)-RS (b=0): all recovered blocks go to the 𝓜-designated new
        // rack; within a region each node of that rack receives the same
        // number of recovered blocks (Fig 4(b)).
        let p = d3(2, 1, paper_cluster());
        let failed = Location::new(0, 0);
        // find stripes of region 0 with a block on `failed`
        let mut per_node: HashMap<Location, usize> = HashMap::new();
        for sid in 0..p.region_size() as u64 {
            let sp = p.stripe(sid);
            for (bi, &loc) in sp.locs.iter().enumerate() {
                if loc == failed {
                    let tgt = p.recovery_target(sid, bi, loc);
                    *per_node.entry(tgt).or_default() += 1;
                }
            }
        }
        // all targets in the same (new) rack, spread evenly
        let racks: std::collections::HashSet<u32> = per_node.keys().map(|l| l.rack).collect();
        assert_eq!(racks.len(), 1, "one new rack per region: {per_node:?}");
        let max = per_node.values().max().unwrap();
        let min = per_node.values().min().unwrap();
        assert!(max - min <= 1, "unbalanced round robin: {per_node:?}");
    }

    #[test]
    fn block_at_agrees_with_stripe() {
        for (k, m) in [(2usize, 1usize), (3, 2), (6, 3)] {
            let p = d3(k, m, paper_cluster());
            for sid in 0..800u64 {
                let sp = p.stripe(sid);
                for (bi, &want) in sp.locs.iter().enumerate() {
                    assert_eq!(p.block_at(sid, bi), want, "({k},{m}) sid={sid} b={bi}");
                }
            }
        }
    }

    #[test]
    fn closed_form_rank_matches_explicit_rank_lists() {
        // the i/n closed form must reproduce the old per-column rank-list
        // scan: within each OA column's value class, ascending rows rank
        // by their i₁ component
        for cluster in [ClusterSpec::new(8, 3), ClusterSpec::new(5, 4), ClusterSpec::new(8, 6)] {
            let p = d3(3, 2, cluster);
            let a = &p.a;
            let n = a.n();
            for col in 0..p.ng() {
                let mut per_value: Vec<Vec<usize>> = vec![Vec::new(); n];
                for row in 0..a.rows() {
                    per_value[a.entry(row, col)].push(row);
                }
                for list in &per_value {
                    for (pos, &row) in list.iter().enumerate() {
                        assert_eq!(
                            row / n,
                            pos,
                            "n={n} col={col}: closed-form rank diverges at row {row}"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn variants_construct_and_obey_rack_limit() {
        for v in [D3Variant::NoRotation, D3Variant::RoundRobinRegions] {
            let p = D3Placement::with_variant(
                CodeSpec::Rs { k: 3, m: 2 },
                paper_cluster(),
                v,
            )
            .unwrap();
            for sid in 0..500u64 {
                let sp = p.stripe(sid);
                assert!(sp.rack_limit_ok(2), "{:?} sid={sid}", v);
                assert!(sp.nodes_distinct());
            }
        }
    }

    #[test]
    fn invalid_configs_rejected() {
        // rack too small: (6,3) group size 3 > 2 nodes/rack
        assert!(matches!(
            D3Placement::new(CodeSpec::Rs { k: 6, m: 3 }, ClusterSpec::new(8, 2)),
            Err(D3Error::RackTooSmall { .. })
        ));
        // r <= N_g: (6,3)-RS needs 4 OA columns but r = 3
        assert!(matches!(
            D3Placement::new(CodeSpec::Rs { k: 6, m: 3 }, ClusterSpec::new(3, 3)),
            Err(D3Error::RackOa { .. })
        ));
        // LRC spec routed to the wrong type
        assert!(matches!(
            D3Placement::new(CodeSpec::Lrc { k: 4, l: 2, g: 1 }, paper_cluster()),
            Err(D3Error::NotRs)
        ));
    }
}
