//! Dense GF(2^8) matrices: the control-path linear algebra behind decode
//! coefficient computation (Gauss-Jordan inversion of generator submatrices).

use super::{div, inv, mul};

/// A dense row-major GF(2^8) matrix.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Matrix {
    rows: usize,
    cols: usize,
    data: Vec<u8>,
}

impl Matrix {
    pub fn zero(rows: usize, cols: usize) -> Matrix {
        Matrix { rows, cols, data: vec![0; rows * cols] }
    }

    pub fn identity(n: usize) -> Matrix {
        let mut m = Matrix::zero(n, n);
        for i in 0..n {
            m[(i, i)] = 1;
        }
        m
    }

    pub fn from_rows(rows: &[&[u8]]) -> Matrix {
        let r = rows.len();
        let c = rows.first().map_or(0, |x| x.len());
        let mut m = Matrix::zero(r, c);
        for (i, row) in rows.iter().enumerate() {
            assert_eq!(row.len(), c, "ragged rows");
            m.data[i * c..(i + 1) * c].copy_from_slice(row);
        }
        m
    }

    pub fn rows(&self) -> usize {
        self.rows
    }

    pub fn cols(&self) -> usize {
        self.cols
    }

    pub fn row(&self, i: usize) -> &[u8] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// Select a subset of rows into a new matrix.
    pub fn select_rows(&self, idx: &[usize]) -> Matrix {
        let mut m = Matrix::zero(idx.len(), self.cols);
        for (out, &i) in idx.iter().enumerate() {
            let (s, c) = (i * self.cols, self.cols);
            m.data[out * c..(out + 1) * c].copy_from_slice(&self.data[s..s + c]);
        }
        m
    }

    /// Matrix product over GF(2^8).
    pub fn matmul(&self, rhs: &Matrix) -> Matrix {
        assert_eq!(self.cols, rhs.rows, "shape mismatch");
        let mut out = Matrix::zero(self.rows, rhs.cols);
        for i in 0..self.rows {
            for t in 0..self.cols {
                let a = self[(i, t)];
                if a == 0 {
                    continue;
                }
                for j in 0..rhs.cols {
                    out[(i, j)] ^= mul(a, rhs[(t, j)]);
                }
            }
        }
        out
    }

    /// Row-vector times matrix: `v * self`.
    pub fn vecmul_left(&self, v: &[u8]) -> Vec<u8> {
        assert_eq!(v.len(), self.rows);
        let mut out = vec![0u8; self.cols];
        for (t, &a) in v.iter().enumerate() {
            if a == 0 {
                continue;
            }
            for j in 0..self.cols {
                out[j] ^= mul(a, self[(t, j)]);
            }
        }
        out
    }

    /// Gauss-Jordan inverse. Returns `None` for singular matrices.
    pub fn inverse(&self) -> Option<Matrix> {
        assert_eq!(self.rows, self.cols, "inverse of non-square");
        let n = self.rows;
        let mut a = self.clone();
        let mut out = Matrix::identity(n);
        for col in 0..n {
            let piv = (col..n).find(|&r| a[(r, col)] != 0)?;
            if piv != col {
                a.swap_rows(piv, col);
                out.swap_rows(piv, col);
            }
            let s = inv(a[(col, col)]);
            a.scale_row(col, s);
            out.scale_row(col, s);
            for r in 0..n {
                if r != col && a[(r, col)] != 0 {
                    let f = a[(r, col)];
                    a.axpy_row(r, col, f);
                    out.axpy_row(r, col, f);
                }
            }
        }
        Some(out)
    }

    /// Determinant by elimination (used by MDS-property tests).
    pub fn det(&self) -> u8 {
        assert_eq!(self.rows, self.cols);
        let n = self.rows;
        let mut a = self.clone();
        let mut det = 1u8;
        for col in 0..n {
            let Some(piv) = (col..n).find(|&r| a[(r, col)] != 0) else {
                return 0;
            };
            if piv != col {
                a.swap_rows(piv, col); // char 2: swap does not flip sign
            }
            det = mul(det, a[(col, col)]);
            let s = inv(a[(col, col)]);
            a.scale_row(col, s);
            for r in col + 1..n {
                if a[(r, col)] != 0 {
                    let f = a[(r, col)];
                    a.axpy_row(r, col, f);
                }
            }
        }
        det
    }

    fn swap_rows(&mut self, i: usize, j: usize) {
        for c in 0..self.cols {
            self.data.swap(i * self.cols + c, j * self.cols + c);
        }
    }

    fn scale_row(&mut self, i: usize, s: u8) {
        for c in 0..self.cols {
            self[(i, c)] = mul(self[(i, c)], s);
        }
    }

    /// row_i ^= f * row_j
    fn axpy_row(&mut self, i: usize, j: usize, f: u8) {
        for c in 0..self.cols {
            let v = mul(f, self[(j, c)]);
            self[(i, c)] ^= v;
        }
    }
}

impl std::ops::Index<(usize, usize)> for Matrix {
    type Output = u8;
    fn index(&self, (r, c): (usize, usize)) -> &u8 {
        &self.data[r * self.cols + c]
    }
}

impl std::ops::IndexMut<(usize, usize)> for Matrix {
    fn index_mut(&mut self, (r, c): (usize, usize)) -> &mut u8 {
        &mut self.data[r * self.cols + c]
    }
}

/// Coefficients `c` with `Σᵢ cᵢ·rows[i] == target` over GF(2^8), if
/// `target` lies in the row span; `None` otherwise. This is the generic
/// multi-erasure decode primitive (DESIGN.md §4): rows are the generator
/// rows of the surviving blocks, target the generator row of a lost block.
///
/// Gauss-Jordan elimination on a copy of `rows` with an identity
/// bookkeeping matrix carried along; the candidate combination is verified
/// against the original rows before returning, so the answer is sound even
/// for rank-deficient inputs.
pub fn express_in_rows(rows: &[&[u8]], target: &[u8]) -> Option<Vec<u8>> {
    let n = rows.len();
    if n == 0 {
        return None;
    }
    let k = target.len();
    let mut a = Matrix::from_rows(rows);
    assert_eq!(a.cols(), k, "row/target width mismatch");
    let mut book = Matrix::identity(n);
    let mut pivot_of_col = vec![usize::MAX; k];
    let mut rank = 0usize;
    for col in 0..k {
        let Some(piv) = (rank..n).find(|&r| a[(r, col)] != 0) else {
            continue;
        };
        if piv != rank {
            a.swap_rows(piv, rank);
            book.swap_rows(piv, rank);
        }
        let s = inv(a[(rank, col)]);
        a.scale_row(rank, s);
        book.scale_row(rank, s);
        for r in 0..n {
            if r != rank && a[(r, col)] != 0 {
                let f = a[(r, col)];
                a.axpy_row(r, rank, f);
                book.axpy_row(r, rank, f);
            }
        }
        pivot_of_col[col] = rank;
        rank += 1;
    }
    let mut coeffs = vec![0u8; n];
    for (col, &tv) in target.iter().enumerate() {
        if tv == 0 {
            continue;
        }
        let piv = pivot_of_col[col];
        if piv == usize::MAX {
            // Non-pivot column: for rank-deficient inputs the target can
            // still be in the span (a pivot row may carry this coordinate
            // as "junk"); the final verification decides.
            continue;
        }
        for (i, c) in coeffs.iter_mut().enumerate() {
            *c ^= mul(tv, book[(piv, i)]);
        }
    }
    // verify against the original rows (sound for rank < k inputs)
    let mut acc = vec![0u8; k];
    for (i, row) in rows.iter().enumerate() {
        if coeffs[i] != 0 {
            for (j, &v) in row.iter().enumerate() {
                acc[j] ^= mul(coeffs[i], v);
            }
        }
    }
    if acc.as_slice() == target {
        Some(coeffs)
    } else {
        None
    }
}

/// Cauchy matrix entry (i + k) vs j: every square submatrix is invertible.
pub fn cauchy(rows: usize, cols: usize, row_offset: usize) -> Matrix {
    let mut m = Matrix::zero(rows, cols);
    for i in 0..rows {
        for j in 0..cols {
            let x = (i + row_offset) as u8;
            let y = j as u8;
            assert_ne!(x, y, "cauchy x/y sets must be disjoint");
            m[(i, j)] = div(1, x ^ y);
        }
    }
    m
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rng_matrix(n: usize, seed: u64) -> Matrix {
        // xorshift-ish deterministic fill
        let mut s = seed.wrapping_mul(0x9e3779b97f4a7c15) | 1;
        let mut m = Matrix::zero(n, n);
        for i in 0..n {
            for j in 0..n {
                s ^= s << 13;
                s ^= s >> 7;
                s ^= s << 17;
                m[(i, j)] = (s >> 32) as u8;
            }
        }
        m
    }

    #[test]
    fn inverse_roundtrip() {
        for n in 1..=8 {
            for seed in 0..8 {
                let m = rng_matrix(n, seed * 100 + n as u64);
                if let Some(inv) = m.inverse() {
                    assert_eq!(m.matmul(&inv), Matrix::identity(n), "n={n} seed={seed}");
                    assert_eq!(inv.matmul(&m), Matrix::identity(n));
                }
            }
        }
    }

    #[test]
    fn singular_matrix_returns_none() {
        let mut m = Matrix::zero(3, 3);
        m[(0, 0)] = 5;
        m[(1, 0)] = 9; // column rank 1
        assert!(m.inverse().is_none());
        assert_eq!(m.det(), 0);
    }

    #[test]
    fn det_multiplicative() {
        let a = rng_matrix(4, 7);
        let b = rng_matrix(4, 13);
        assert_eq!(a.matmul(&b).det(), mul(a.det(), b.det()));
    }

    #[test]
    fn cauchy_every_square_submatrix_invertible_small() {
        let m = 3;
        let k = 6;
        let c = cauchy(m, k, k);
        // all 1x1, 2x2, 3x3 submatrices must be nonsingular
        for r0 in 0..m {
            for c0 in 0..k {
                assert_ne!(c[(r0, c0)], 0);
                for r1 in r0 + 1..m {
                    for c1 in c0 + 1..k {
                        let sub = Matrix::from_rows(&[
                            &[c[(r0, c0)], c[(r0, c1)]],
                            &[c[(r1, c0)], c[(r1, c1)]],
                        ]);
                        assert_ne!(sub.det(), 0);
                    }
                }
            }
        }
    }

    #[test]
    fn express_in_rows_finds_combinations() {
        // rows of a Cauchy-extended RS generator span GF(256)^k; any unit
        // vector must be expressible from k independent rows
        let c = cauchy(3, 4, 4);
        let id = Matrix::identity(4);
        let rows: Vec<&[u8]> = vec![id.row(0), id.row(1), c.row(0), c.row(1)];
        for target_col in 0..4 {
            let mut target = vec![0u8; 4];
            target[target_col] = 1;
            let coeffs = express_in_rows(&rows, &target).expect("in span");
            let mut acc = vec![0u8; 4];
            for (i, row) in rows.iter().enumerate() {
                for (j, &v) in row.iter().enumerate() {
                    acc[j] ^= mul(coeffs[i], v);
                }
            }
            assert_eq!(acc, target);
        }
        // a target outside the span is rejected
        let short: Vec<&[u8]> = vec![id.row(0), id.row(1)];
        assert!(express_in_rows(&short, &[0, 0, 1, 0]).is_none());
        // rank-deficient but in span: non-pivot coordinates may be carried
        // by a pivot row's "junk" — must still succeed
        let dep: Vec<&[u8]> = vec![&[1, 1]];
        assert_eq!(express_in_rows(&dep, &[1, 1]), Some(vec![1]));
        assert!(express_in_rows(&dep, &[1, 0]).is_none());
        // zero-coefficient pruning sanity: expressing row 0 by itself
        let coeffs = express_in_rows(&rows, id.row(0)).unwrap();
        assert_eq!(coeffs[0], 1);
        assert!(coeffs[1..].iter().all(|&c| c == 0));
    }

    #[test]
    fn vecmul_left_matches_matmul() {
        let a = rng_matrix(5, 3);
        let v = [1u8, 20, 0, 255, 7];
        let direct = a.vecmul_left(&v);
        let as_mat = Matrix::from_rows(&[&v]).matmul(&a);
        assert_eq!(direct, as_mat.row(0));
    }
}
