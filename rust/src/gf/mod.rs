//! GF(2^8) arithmetic over x^8 + x^4 + x^3 + x^2 + 1 (0x11d).
//!
//! This is the native mirror of the Layer-1 Pallas kernel's field
//! (`python/compile/kernels/gf.py` — same modulus, same generator 0x02) so
//! coefficients computed here feed the AOT artifacts directly, and the
//! native coder (`runtime::native`) is bit-identical to the PJRT path.
//!
//! The hot combine loop is the fused engine in [`kernel`]
//! ([`combine_into`] / [`combine_many_into`]); it runs on one of three
//! interchangeable **lanes** — scalar (the differential oracle), swar
//! (portable u64 words + unrolled nibble tables), or simd (AVX2/NEON
//! byte shuffles in [`simd`]) — selected once per process by [`dispatch`]
//! (DESIGN.md §12). Everything else (inverse, matrix inversion) runs on
//! the control path only.

pub mod dispatch;
pub mod kernel;
pub mod matrix;
pub mod simd;

pub use kernel::{combine_many_into, xor_into};
pub use matrix::Matrix;

/// The field modulus (must match `python/compile/kernels/gf.py::GF_POLY`).
pub const GF_POLY: u16 = 0x11d;
/// 0x02 generates GF(256)* for this modulus.
pub const GF_GENERATOR: u8 = 0x02;

/// Log/exp tables, built once at startup.
pub struct Tables {
    /// log[x] for x != 0; log[0] is a sentinel (never read on valid input).
    pub log: [u16; 256],
    /// exp[i] = g^(i mod 255), doubled to 512 entries so `log a + log b`
    /// indexes without a mod.
    pub exp: [u8; 512],
    /// mul[a][b] flat 64 KiB table for the scalar hot path.
    mul: Box<[u8; 65536]>,
}

impl Tables {
    fn build() -> Tables {
        let mut exp = [0u8; 512];
        let mut log = [0u16; 256];
        let mut x: u16 = 1;
        for i in 0..255u16 {
            exp[i as usize] = x as u8;
            log[x as usize] = i;
            x <<= 1;
            if x & 0x100 != 0 {
                x ^= GF_POLY;
            }
        }
        for i in 255..510 {
            exp[i] = exp[i - 255];
        }
        let mut mul = Box::new([0u8; 65536]);
        for a in 1..256usize {
            for b in 1..256usize {
                mul[(a << 8) | b] = exp[(log[a] + log[b]) as usize];
            }
        }
        Tables { log, exp, mul }
    }
}

fn tables() -> &'static Tables {
    use std::sync::OnceLock;
    static TABLES: OnceLock<Tables> = OnceLock::new();
    TABLES.get_or_init(Tables::build)
}

/// GF(2^8) multiply.
#[inline]
pub fn mul(a: u8, b: u8) -> u8 {
    tables().mul[((a as usize) << 8) | b as usize]
}

/// GF(2^8) addition/subtraction is XOR.
#[inline]
pub fn add(a: u8, b: u8) -> u8 {
    a ^ b
}

/// Multiplicative inverse. Panics on 0.
pub fn inv(a: u8) -> u8 {
    assert!(a != 0, "gf::inv(0)");
    let t = tables();
    t.exp[(255 - t.log[a as usize]) as usize]
}

/// a / b. Panics if b == 0.
#[inline]
pub fn div(a: u8, b: u8) -> u8 {
    mul(a, inv(b))
}

/// a^e by square-and-multiply (control path only).
pub fn pow(mut a: u8, mut e: u32) -> u8 {
    let mut acc = 1u8;
    while e > 0 {
        if e & 1 == 1 {
            acc = mul(acc, a);
        }
        a = mul(a, a);
        e >>= 1;
    }
    acc
}

/// Two-nibble slice tables for a fixed coefficient `c`: GF multiply is
/// linear over the bits of the source byte, so `c·s = c·(s & 0x0f) ⊕
/// c·(s & 0xf0)` and the 256-entry row table splits into two 16-entry
/// nibble tables that together fit in a single cache line. This is the
/// multiply-accumulate kernel shared by the RS/LRC coders
/// ([`crate::codes`]), the multi-erasure planner's numeric execution
/// ([`crate::recovery::multi`]), and the chunked recovery executor's data
/// path (DESIGN.md §8); `benches/hotpath.rs` tracks its throughput.
#[derive(Clone, Copy)]
pub struct SliceTable {
    lo: [u8; 16],
    hi: [u8; 16],
}

impl SliceTable {
    pub fn new(c: u8) -> SliceTable {
        let mut lo = [0u8; 16];
        let mut hi = [0u8; 16];
        for x in 0..16u8 {
            lo[x as usize] = mul(c, x);
            hi[x as usize] = mul(c, x << 4);
        }
        SliceTable { lo, hi }
    }

    /// `c · s` via the two nibble lookups.
    #[inline]
    pub fn mul(&self, s: u8) -> u8 {
        self.lo[(s & 0x0f) as usize] ^ self.hi[(s >> 4) as usize]
    }

    /// The low-nibble product table (`lo[x] = c·x` for `x < 16`) — exactly
    /// the 16-byte shuffle vector the SIMD lanes feed to `PSHUFB`/`TBL`
    /// ([`crate::gf::simd`]).
    pub fn lo(&self) -> &[u8; 16] {
        &self.lo
    }

    /// The high-nibble product table (`hi[x] = c·(x << 4)` for `x < 16`).
    pub fn hi(&self) -> &[u8; 16] {
        &self.hi
    }

    /// `acc[i] ^= c · src[i]` — the multiply-accumulate hot loop, unrolled
    /// eight bytes per step so both nibble tables stay register/L1-resident.
    pub fn mac(&self, acc: &mut [u8], src: &[u8]) {
        assert_eq!(acc.len(), src.len());
        let mut a = acc.chunks_exact_mut(8);
        let mut s = src.chunks_exact(8);
        for (ac, sc) in a.by_ref().zip(s.by_ref()) {
            for i in 0..8 {
                ac[i] ^= self.mul(sc[i]);
            }
        }
        for (ac, &sc) in a.into_remainder().iter_mut().zip(s.remainder()) {
            *ac ^= self.mul(sc);
        }
    }

    /// `buf[i] = c · buf[i]` — in-place scale (Gaussian-elimination rows).
    pub fn scale(&self, buf: &mut [u8]) {
        for b in buf.iter_mut() {
            *b = self.mul(*b);
        }
    }
}

/// `acc[i] ^= c * src[i]` — the byte-crunching inner loop of the native
/// coder. Specializes c == 0 (no-op) and c == 1 (the wide XOR lane, the
/// LRC/replica path) before falling back to the *cached* two-nibble
/// [`SliceTable`] kernel ([`kernel::table`] — no per-call table build).
/// Both non-trivial classes run on the process-wide active lane
/// ([`dispatch::active_lane`]): AVX2/NEON byte shuffles when detected,
/// the SWAR/table kernels otherwise.
pub fn combine_into(acc: &mut [u8], c: u8, src: &[u8]) {
    assert_eq!(acc.len(), src.len());
    let lane = dispatch::active_lane();
    match c {
        0 => {}
        1 => dispatch::xor_fn(lane)(acc, src),
        _ => dispatch::mac_fn(lane)(kernel::table(c), acc, src),
    }
}

/// `out = XOR_i coeffs[i] * shards[i]` — one GF linear combination,
/// evaluated through the fused cache-blocked engine
/// ([`kernel::combine_many_into`]). This is the native twin of the
/// `gf_combine` AOT artifact.
pub fn combine(coeffs: &[u8], shards: &[&[u8]]) -> Vec<u8> {
    assert_eq!(coeffs.len(), shards.len());
    assert!(!shards.is_empty(), "gf::combine with no shards");
    let len = shards[0].len();
    let mut out = vec![0u8; len];
    let pairs: Vec<(u8, &[u8])> =
        coeffs.iter().zip(shards).map(|(&c, &s)| (c, s)).collect();
    kernel::combine_many_into(&mut out, &pairs);
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Independent polynomial-basis multiply (mirror of python ref.py).
    fn mul_ref(mut a: u16, mut b: u16) -> u8 {
        let mut acc = 0u16;
        for _ in 0..8 {
            if b & 1 == 1 {
                acc ^= a;
            }
            b >>= 1;
            a <<= 1;
            if a & 0x100 != 0 {
                a ^= GF_POLY;
            }
        }
        acc as u8
    }

    #[test]
    fn mul_matches_polynomial_basis_exhaustively() {
        for a in 0..=255u16 {
            for b in 0..=255u16 {
                assert_eq!(mul(a as u8, b as u8), mul_ref(a, b), "a={a} b={b}");
            }
        }
    }

    #[test]
    fn every_nonzero_element_has_inverse() {
        for a in 1..=255u8 {
            assert_eq!(mul(a, inv(a)), 1, "a={a}");
        }
    }

    #[test]
    fn generator_has_full_order() {
        let mut x = 1u8;
        let mut seen = [false; 256];
        for _ in 0..255 {
            assert!(!seen[x as usize], "generator order < 255");
            seen[x as usize] = true;
            x = mul(x, GF_GENERATOR);
        }
        assert_eq!(x, 1);
    }

    #[test]
    fn pow_agrees_with_repeated_mul() {
        for a in [0u8, 1, 2, 7, 131, 255] {
            let mut acc = 1u8;
            for e in 0..20u32 {
                assert_eq!(pow(a, e), acc, "a={a} e={e}");
                acc = mul(acc, a);
            }
        }
    }

    #[test]
    fn slice_table_matches_mul_exhaustively() {
        for c in 0..=255u8 {
            let t = SliceTable::new(c);
            for s in 0..=255u8 {
                assert_eq!(t.mul(s), mul(c, s), "c={c} s={s}");
            }
        }
    }

    #[test]
    fn slice_mac_matches_reference_all_lengths() {
        // cover the unrolled body and every remainder length
        let src: Vec<u8> = (0..41u8).map(|i| i.wrapping_mul(37).wrapping_add(3)).collect();
        for c in [2u8, 29, 147, 255] {
            let t = SliceTable::new(c);
            for len in 0..src.len() {
                let mut acc = vec![0xa5u8; len];
                let mut want = acc.clone();
                for (w, &s) in want.iter_mut().zip(&src[..len]) {
                    *w ^= mul(c, s);
                }
                t.mac(&mut acc, &src[..len]);
                assert_eq!(acc, want, "c={c} len={len}");
            }
        }
    }

    #[test]
    fn slice_scale_matches_mul() {
        let t = SliceTable::new(113);
        let mut buf: Vec<u8> = (0..=255u8).collect();
        t.scale(&mut buf);
        for (i, &b) in buf.iter().enumerate() {
            assert_eq!(b, mul(113, i as u8));
        }
    }

    #[test]
    fn combine_identity_and_zero() {
        let a = vec![1u8, 2, 3, 4];
        let b = vec![5u8, 6, 7, 8];
        let picked = combine(&[0, 1], &[&a, &b]);
        assert_eq!(picked, b);
        let zero = combine(&[0, 0], &[&a, &b]);
        assert_eq!(zero, vec![0; 4]);
    }

    #[test]
    fn combine_is_linear_in_data() {
        let a = [9u8, 30, 200, 7];
        let b = [250u8, 3, 17, 99];
        let ab: Vec<u8> = a.iter().zip(b).map(|(x, y)| x ^ y).collect();
        let c = [77u8, 140];
        let lhs = combine(&c, &[&ab, &ab]);
        let r1 = combine(&c, &[&a[..], &a[..]]);
        let r2 = combine(&c, &[&b[..], &b[..]]);
        let rhs: Vec<u8> = r1.iter().zip(r2).map(|(x, y)| x ^ y).collect();
        assert_eq!(lhs, rhs);
    }
}
