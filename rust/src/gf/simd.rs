//! SIMD GF(2^8) kernels: byte-shuffle nibble lookups (DESIGN.md §12).
//!
//! GF multiply by a fixed coefficient is linear over the source byte's
//! nibbles, so the two 16-entry halves of a [`SliceTable`] are exactly
//! the lookup vectors the x86 `PSHUFB` (`_mm256_shuffle_epi8`) and
//! aarch64 `TBL` (`vqtbl1q_u8`) instructions consume: each shuffle pair
//! produces 32 (AVX2) or 16 (NEON) products per step instead of one per
//! scalar table lookup — the ISA-L / `galois_8` technique.
//!
//! Soundness: the `#[target_feature]` kernels are `unsafe fn`s whose only
//! contract is ISA availability — every memory access is either an
//! *unaligned* vector load/store at an in-bounds offset or a safe slice
//! tail loop, so there is no alignment invariant for callers to uphold.
//! The safe wrappers re-verify detection before entering them (a cached
//! atomic load), so a stray call on an unsupported CPU panics instead of
//! executing illegal instructions; [`super::dispatch`] only routes here
//! when detection succeeded in the first place.
//!
//! On architectures with neither lane, the wrappers fall back to the
//! portable SWAR/table kernels so the module always compiles; the
//! dispatcher never selects the simd lane there.

use super::SliceTable;

#[cfg(any(target_arch = "x86_64", target_arch = "aarch64"))]
use super::dispatch::simd_available;

/// `acc[i] ^= src[i]` on the SIMD lane (AVX2 32-byte / NEON 16-byte wide
/// XOR). Panics if the ISA extension is missing — select lanes through
/// [`super::dispatch`] rather than calling this directly.
pub fn xor_into_simd(acc: &mut [u8], src: &[u8]) {
    assert_eq!(acc.len(), src.len());
    #[cfg(target_arch = "x86_64")]
    {
        assert!(simd_available(), "xor_into_simd without AVX2");
        // SAFETY: AVX2 presence was just verified. The kernel performs
        // only unaligned 32-byte loads/stores at offsets i with
        // i + 32 <= acc.len() == src.len(), plus a safe scalar tail — no
        // alignment invariant exists.
        unsafe { x86::xor_avx2(acc, src) }
    }
    #[cfg(target_arch = "aarch64")]
    {
        assert!(simd_available(), "xor_into_simd without NEON");
        // SAFETY: NEON presence was just verified. The kernel performs
        // only unaligned 16-byte loads/stores at offsets i with
        // i + 16 <= acc.len() == src.len(), plus a safe scalar tail — no
        // alignment invariant exists.
        unsafe { arm::xor_neon(acc, src) }
    }
    #[cfg(not(any(target_arch = "x86_64", target_arch = "aarch64")))]
    super::kernel::xor_into_swar(acc, src);
}

/// `acc[i] ^= t.mul(src[i])` on the SIMD lane: both nibble tables are
/// loaded into vector registers once, then every wide step is two
/// shuffles and two XORs. Panics if the ISA extension is missing —
/// select lanes through [`super::dispatch`] rather than calling this
/// directly.
pub fn mac_simd(t: &SliceTable, acc: &mut [u8], src: &[u8]) {
    assert_eq!(acc.len(), src.len());
    #[cfg(target_arch = "x86_64")]
    {
        assert!(simd_available(), "mac_simd without AVX2");
        // SAFETY: AVX2 presence was just verified. The kernel performs
        // only unaligned 32-byte loads/stores at offsets i with
        // i + 32 <= acc.len() == src.len(), plus a safe scalar tail — no
        // alignment invariant exists.
        unsafe { x86::mac_avx2(t.lo(), t.hi(), acc, src) }
    }
    #[cfg(target_arch = "aarch64")]
    {
        assert!(simd_available(), "mac_simd without NEON");
        // SAFETY: NEON presence was just verified. The kernel performs
        // only unaligned 16-byte loads/stores at offsets i with
        // i + 16 <= acc.len() == src.len(), plus a safe scalar tail — no
        // alignment invariant exists.
        unsafe { arm::mac_neon(t.lo(), t.hi(), acc, src) }
    }
    #[cfg(not(any(target_arch = "x86_64", target_arch = "aarch64")))]
    t.mac(acc, src);
}

#[cfg(target_arch = "x86_64")]
mod x86 {
    use std::arch::x86_64::{
        __m128i, __m256i, _mm256_and_si256, _mm256_broadcastsi128_si256, _mm256_loadu_si256,
        _mm256_set1_epi8, _mm256_shuffle_epi8, _mm256_srli_epi16, _mm256_storeu_si256,
        _mm256_xor_si256, _mm_loadu_si128,
    };

    /// 32-bytes-per-step XOR.
    ///
    /// # Safety
    /// AVX2 must be available. There is no alignment invariant (all
    /// vector memory ops are `loadu`/`storeu`); every vector access is at
    /// an offset `i` with `i + 32 <= acc.len()` and
    /// `acc.len() == src.len()`, and the ragged tail uses safe slices.
    #[target_feature(enable = "avx2")]
    pub(super) unsafe fn xor_avx2(acc: &mut [u8], src: &[u8]) {
        debug_assert_eq!(acc.len(), src.len());
        let len = acc.len();
        let wide = len - len % 32;
        let ap = acc.as_mut_ptr();
        let sp = src.as_ptr();
        let mut i = 0;
        while i < wide {
            let av = _mm256_loadu_si256(ap.add(i) as *const __m256i);
            let sv = _mm256_loadu_si256(sp.add(i) as *const __m256i);
            _mm256_storeu_si256(ap.add(i) as *mut __m256i, _mm256_xor_si256(av, sv));
            i += 32;
        }
        for (a, &s) in acc[wide..].iter_mut().zip(&src[wide..]) {
            *a ^= s;
        }
    }

    /// 32-products-per-step multiply-accumulate: `PSHUFB` over the
    /// broadcast low/high nibble tables.
    ///
    /// # Safety
    /// Same contract as [`xor_avx2`]: AVX2 available, no alignment
    /// invariant, every vector access at `i + 32 <= acc.len() ==
    /// src.len()`.
    #[target_feature(enable = "avx2")]
    pub(super) unsafe fn mac_avx2(lo: &[u8; 16], hi: &[u8; 16], acc: &mut [u8], src: &[u8]) {
        debug_assert_eq!(acc.len(), src.len());
        // broadcast each 16-entry nibble table across both 128-bit halves
        // so one shuffle looks up all 32 lanes
        let lo_t = _mm256_broadcastsi128_si256(_mm_loadu_si128(lo.as_ptr() as *const __m128i));
        let hi_t = _mm256_broadcastsi128_si256(_mm_loadu_si128(hi.as_ptr() as *const __m128i));
        let mask = _mm256_set1_epi8(0x0f);
        let len = acc.len();
        let wide = len - len % 32;
        let ap = acc.as_mut_ptr();
        let sp = src.as_ptr();
        let mut i = 0;
        while i < wide {
            let sv = _mm256_loadu_si256(sp.add(i) as *const __m256i);
            let lo_n = _mm256_and_si256(sv, mask);
            // per-byte `src >> 4`: the 16-bit shift smears bits across
            // byte lanes; the mask drops them
            let hi_n = _mm256_and_si256(_mm256_srli_epi16::<4>(sv), mask);
            let prod = _mm256_xor_si256(
                _mm256_shuffle_epi8(lo_t, lo_n),
                _mm256_shuffle_epi8(hi_t, hi_n),
            );
            let av = _mm256_loadu_si256(ap.add(i) as *const __m256i);
            _mm256_storeu_si256(ap.add(i) as *mut __m256i, _mm256_xor_si256(av, prod));
            i += 32;
        }
        for (a, &s) in acc[wide..].iter_mut().zip(&src[wide..]) {
            *a ^= lo[(s & 0x0f) as usize] ^ hi[(s >> 4) as usize];
        }
    }
}

#[cfg(target_arch = "aarch64")]
mod arm {
    use std::arch::aarch64::{
        vandq_u8, vdupq_n_u8, veorq_u8, vld1q_u8, vqtbl1q_u8, vshrq_n_u8, vst1q_u8,
    };

    /// 16-bytes-per-step XOR.
    ///
    /// # Safety
    /// NEON must be available. There is no alignment invariant
    /// (`vld1q_u8`/`vst1q_u8` accept unaligned pointers); every vector
    /// access is at an offset `i` with `i + 16 <= acc.len()` and
    /// `acc.len() == src.len()`, and the ragged tail uses safe slices.
    #[target_feature(enable = "neon")]
    pub(super) unsafe fn xor_neon(acc: &mut [u8], src: &[u8]) {
        debug_assert_eq!(acc.len(), src.len());
        let len = acc.len();
        let wide = len - len % 16;
        let ap = acc.as_mut_ptr();
        let sp = src.as_ptr();
        let mut i = 0;
        while i < wide {
            let av = vld1q_u8(ap.add(i));
            let sv = vld1q_u8(sp.add(i));
            vst1q_u8(ap.add(i), veorq_u8(av, sv));
            i += 16;
        }
        for (a, &s) in acc[wide..].iter_mut().zip(&src[wide..]) {
            *a ^= s;
        }
    }

    /// 16-products-per-step multiply-accumulate: `TBL` over the low/high
    /// nibble tables.
    ///
    /// # Safety
    /// Same contract as [`xor_neon`]: NEON available, no alignment
    /// invariant, every vector access at `i + 16 <= acc.len() ==
    /// src.len()`.
    #[target_feature(enable = "neon")]
    pub(super) unsafe fn mac_neon(lo: &[u8; 16], hi: &[u8; 16], acc: &mut [u8], src: &[u8]) {
        debug_assert_eq!(acc.len(), src.len());
        let lo_t = vld1q_u8(lo.as_ptr());
        let hi_t = vld1q_u8(hi.as_ptr());
        let mask = vdupq_n_u8(0x0f);
        let len = acc.len();
        let wide = len - len % 16;
        let ap = acc.as_mut_ptr();
        let sp = src.as_ptr();
        let mut i = 0;
        while i < wide {
            let sv = vld1q_u8(sp.add(i));
            let lo_n = vandq_u8(sv, mask);
            // u8-lane logical shift: indices land in 0..=15 directly
            let hi_n = vshrq_n_u8::<4>(sv);
            let prod = veorq_u8(vqtbl1q_u8(lo_t, lo_n), vqtbl1q_u8(hi_t, hi_n));
            let av = vld1q_u8(ap.add(i));
            vst1q_u8(ap.add(i), veorq_u8(av, prod));
            i += 16;
        }
        for (a, &s) in acc[wide..].iter_mut().zip(&src[wide..]) {
            *a ^= lo[(s & 0x0f) as usize] ^ hi[(s >> 4) as usize];
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gf::dispatch::simd_available;
    use crate::gf::{kernel, mul};
    use crate::util::rng::xorshift_bytes as pattern;

    #[test]
    fn simd_mac_and_xor_match_scalar_when_available() {
        if !simd_available() {
            eprintln!("no SIMD lane on this CPU — skipping");
            return;
        }
        // lengths around both vector widths (16/32) plus ragged tails
        for len in [0usize, 1, 15, 16, 17, 31, 32, 33, 63, 64, 65, 1000] {
            let src = pattern(len, 3);
            for c in [0u8, 1, 2, 0x8e, 0xff] {
                let mut acc = pattern(len, 4);
                let mut want = acc.clone();
                for (w, &s) in want.iter_mut().zip(&src) {
                    *w ^= mul(c, s);
                }
                mac_simd(kernel::table(c), &mut acc, &src);
                assert_eq!(acc, want, "c={c} len={len}");
            }
            let mut acc = pattern(len, 5);
            let mut want = acc.clone();
            for (w, &s) in want.iter_mut().zip(&src) {
                *w ^= s;
            }
            xor_into_simd(&mut acc, &src);
            assert_eq!(acc, want, "len={len}");
        }
    }
}
