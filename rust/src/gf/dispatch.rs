//! Runtime kernel-lane dispatch for the GF(2^8) engine (DESIGN.md §12).
//!
//! Three lanes implement the same MAC/XOR contract over equal-length
//! slices:
//!
//! - **scalar** — per-byte nibble-table lookups, no unrolling: the
//!   differential-test oracle.
//! - **swar** — the portable fast path: u64 XOR words plus the unrolled
//!   [`SliceTable::mac`] kernel. Always available.
//! - **simd** — AVX2 (x86_64) / NEON (aarch64) byte-shuffle kernels
//!   ([`super::simd`]). Available only when runtime feature detection
//!   succeeds.
//!
//! Selection happens **once per process** ([`active_lane`]): the
//! `D3_FORCE_KERNEL=scalar|swar|simd` environment variable pins a lane
//! (CI runs the suite under each), otherwise the best detected lane wins.
//! Forcing an unavailable or unknown lane warns on stderr and falls back
//! — it never selects a lane the CPU cannot execute, so the `unsafe`
//! SIMD entry points are only ever reached behind a successful probe.
//!
//! The dispatched entry points ([`kernel::xor_into`],
//! [`kernel::combine_many_into`], [`super::combine_into`]) resolve their
//! lane per call from the process-wide choice; the `*_lane` functions
//! here pin an explicit lane for differential tests and benches.

use std::sync::OnceLock;

use super::{kernel, simd, SliceTable};

/// One implementation of the GF kernel contract.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Lane {
    /// Per-byte oracle.
    Scalar,
    /// u64 SWAR XOR + unrolled two-nibble table MAC (the portable path).
    Swar,
    /// AVX2 / NEON byte-shuffle kernels.
    Simd,
}

impl Lane {
    /// The `D3_FORCE_KERNEL` spelling of this lane.
    pub fn name(self) -> &'static str {
        match self {
            Lane::Scalar => "scalar",
            Lane::Swar => "swar",
            Lane::Simd => "simd",
        }
    }

    /// Inverse of [`Lane::name`].
    pub fn parse(s: &str) -> Option<Lane> {
        match s {
            "scalar" => Some(Lane::Scalar),
            "swar" => Some(Lane::Swar),
            "simd" => Some(Lane::Simd),
            _ => None,
        }
    }
}

/// The MAC kernel contract: `acc[i] ^= t.mul(src[i])` over equal-length
/// slices — one entry per lane, resolved once per combine call.
pub(crate) type MacFn = fn(&SliceTable, &mut [u8], &[u8]);
/// The XOR (c == 1) kernel contract: `acc[i] ^= src[i]`.
pub(crate) type XorFn = fn(&mut [u8], &[u8]);

/// Whether this CPU can run the simd lane (AVX2 on x86_64, NEON on
/// aarch64). The detection macros cache their probe, so this is an atomic
/// load after the first call.
#[cfg(target_arch = "x86_64")]
pub fn simd_available() -> bool {
    std::arch::is_x86_feature_detected!("avx2")
}

/// Whether this CPU can run the simd lane (AVX2 on x86_64, NEON on
/// aarch64).
#[cfg(target_arch = "aarch64")]
pub fn simd_available() -> bool {
    std::arch::is_aarch64_feature_detected!("neon")
}

/// No simd lane exists on other architectures.
#[cfg(not(any(target_arch = "x86_64", target_arch = "aarch64")))]
pub fn simd_available() -> bool {
    false
}

/// `(feature, detected)` probe rows for `d3ctl kernel-info`.
#[cfg(target_arch = "x86_64")]
pub fn cpu_features() -> Vec<(&'static str, bool)> {
    vec![
        ("sse2", std::arch::is_x86_feature_detected!("sse2")),
        ("ssse3", std::arch::is_x86_feature_detected!("ssse3")),
        ("sse4.1", std::arch::is_x86_feature_detected!("sse4.1")),
        ("avx", std::arch::is_x86_feature_detected!("avx")),
        ("avx2", std::arch::is_x86_feature_detected!("avx2")),
    ]
}

/// `(feature, detected)` probe rows for `d3ctl kernel-info`.
#[cfg(target_arch = "aarch64")]
pub fn cpu_features() -> Vec<(&'static str, bool)> {
    vec![("neon", std::arch::is_aarch64_feature_detected!("neon"))]
}

/// No probes on other architectures.
#[cfg(not(any(target_arch = "x86_64", target_arch = "aarch64")))]
pub fn cpu_features() -> Vec<(&'static str, bool)> {
    Vec::new()
}

/// Lanes this CPU can actually run (scalar and swar always, simd when the
/// ISA extension is detected) — the differential-test iteration set.
pub fn available_lanes() -> Vec<Lane> {
    let mut lanes = vec![Lane::Scalar, Lane::Swar];
    if simd_available() {
        lanes.push(Lane::Simd);
    }
    lanes
}

/// Resolve the lane for an optional `D3_FORCE_KERNEL` value. Pure (no
/// environment read) so the policy is unit-testable; an unknown or
/// unavailable request warns on stderr and falls back to the best
/// detected lane rather than failing or selecting something unrunnable.
pub fn resolve_lane(force: Option<&str>) -> Lane {
    let best = if simd_available() { Lane::Simd } else { Lane::Swar };
    let Some(raw) = force else { return best };
    let raw = raw.trim();
    if raw.is_empty() {
        return best;
    }
    match Lane::parse(raw) {
        Some(Lane::Simd) if !simd_available() => {
            eprintln!(
                "D3_FORCE_KERNEL=simd: no SIMD lane on this CPU; using {}",
                best.name()
            );
            best
        }
        Some(lane) => lane,
        None => {
            eprintln!(
                "D3_FORCE_KERNEL={raw}: unknown lane (scalar|swar|simd); using {}",
                best.name()
            );
            best
        }
    }
}

/// The process-wide active lane: `D3_FORCE_KERNEL` if set and runnable,
/// otherwise the best runtime-detected lane. Resolved exactly once.
pub fn active_lane() -> Lane {
    static ACTIVE: OnceLock<Lane> = OnceLock::new();
    *ACTIVE.get_or_init(|| resolve_lane(std::env::var("D3_FORCE_KERNEL").ok().as_deref()))
}

pub(crate) fn xor_fn(lane: Lane) -> XorFn {
    match lane {
        Lane::Scalar => kernel::xor_into_scalar,
        Lane::Swar => kernel::xor_into_swar,
        Lane::Simd => simd::xor_into_simd,
    }
}

pub(crate) fn mac_fn(lane: Lane) -> MacFn {
    match lane {
        Lane::Scalar => kernel::mac_scalar,
        Lane::Swar => SliceTable::mac,
        Lane::Simd => simd::mac_simd,
    }
}

fn xor_mac_scalar(_t: &SliceTable, acc: &mut [u8], src: &[u8]) {
    kernel::xor_into_scalar(acc, src);
}

fn xor_mac_swar(_t: &SliceTable, acc: &mut [u8], src: &[u8]) {
    kernel::xor_into_swar(acc, src);
}

fn xor_mac_simd(_t: &SliceTable, acc: &mut [u8], src: &[u8]) {
    simd::xor_into_simd(acc, src);
}

/// The c == 1 lane expressed under the MAC contract (table ignored), so
/// the fused engine's hoisted per-source op list is a single fn-pointer
/// type for both coefficient classes.
pub(crate) fn xor_as_mac_fn(lane: Lane) -> MacFn {
    match lane {
        Lane::Scalar => xor_mac_scalar,
        Lane::Swar => xor_mac_swar,
        Lane::Simd => xor_mac_simd,
    }
}

fn assert_lane_available(lane: Lane) {
    assert!(
        lane != Lane::Simd || simd_available(),
        "simd lane unavailable on this CPU"
    );
}

/// `acc[i] ^= src[i]` on an explicitly pinned lane (panics if `lane`
/// cannot run on this CPU) — the differential-test and bench surface.
pub fn xor_into_lane(lane: Lane, acc: &mut [u8], src: &[u8]) {
    assert_lane_available(lane);
    assert_eq!(acc.len(), src.len());
    xor_fn(lane)(acc, src);
}

/// `acc[i] ^= c · src[i]` on a pinned lane through the cached table —
/// exercises the MAC kernel for *every* coefficient class, including the
/// 0/1 values the dispatched paths special-case away. Panics if `lane`
/// cannot run on this CPU.
pub fn mac_into_lane(lane: Lane, c: u8, acc: &mut [u8], src: &[u8]) {
    assert_lane_available(lane);
    assert_eq!(acc.len(), src.len());
    mac_fn(lane)(kernel::table(c), acc, src);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gf::mul;
    use crate::util::rng::xorshift_bytes as pattern;

    #[test]
    fn force_values_resolve_as_documented() {
        assert_eq!(resolve_lane(Some("scalar")), Lane::Scalar);
        assert_eq!(resolve_lane(Some("swar")), Lane::Swar);
        let best = resolve_lane(None);
        if simd_available() {
            assert_eq!(best, Lane::Simd);
            assert_eq!(resolve_lane(Some("simd")), Lane::Simd);
        } else {
            assert_eq!(best, Lane::Swar);
            assert_eq!(resolve_lane(Some("simd")), Lane::Swar, "unavailable → fallback");
        }
        assert_eq!(resolve_lane(Some("turbo")), best, "unknown → fallback");
        assert_eq!(resolve_lane(Some("")), best);
        assert_eq!(resolve_lane(Some("  swar  ")), Lane::Swar, "whitespace-trimmed");
    }

    #[test]
    fn lane_names_round_trip() {
        for lane in available_lanes() {
            assert_eq!(Lane::parse(lane.name()), Some(lane));
        }
        assert_eq!(Lane::parse("avx2"), None);
    }

    #[test]
    fn active_lane_is_available_and_stable() {
        let first = active_lane();
        assert!(available_lanes().contains(&first));
        assert_eq!(active_lane(), first, "one-time selection");
    }

    #[test]
    fn every_available_lane_agrees_with_the_scalar_oracle() {
        let len = 257;
        let src = pattern(len, 6);
        for lane in available_lanes() {
            for c in [0u8, 1, 0x8e] {
                let mut acc = pattern(len, 7);
                let mut want = acc.clone();
                for (w, &s) in want.iter_mut().zip(&src) {
                    *w ^= mul(c, s);
                }
                mac_into_lane(lane, c, &mut acc, &src);
                assert_eq!(acc, want, "lane={lane:?} c={c}");
            }
            let mut acc = pattern(len, 8);
            let mut want = acc.clone();
            for (w, &s) in want.iter_mut().zip(&src) {
                *w ^= s;
            }
            xor_into_lane(lane, &mut acc, &src);
            assert_eq!(acc, want, "lane={lane:?} xor");
        }
    }
}
