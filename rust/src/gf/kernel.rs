//! Fused, table-cached, word-parallel GF(2^8) combine engine — the
//! byte-crunching core of the recovery data path (DESIGN.md §9, §12).
//!
//! Four ideas, each attacking a distinct per-byte cost that profiling the
//! chunked executor (PR 2) exposed:
//!
//! 1. **Process-wide table cache.** [`SliceTable`] construction costs 32
//!    GF multiplies; `combine_into` used to pay it on *every* call, which
//!    at the executor's 16 KiB chunk granularity is once per source per
//!    chunk. All 256 tables together are only 8 KiB, so [`table`] builds
//!    them exactly once per process and every caller shares them.
//! 2. **Wide XOR lane.** Coefficient 1 (the LRC/replica/aggregation-merge
//!    lane) is a pure XOR, which is linear over machine words: the u64
//!    fast path in [`xor_into_swar`] moves 8 bytes per op instead of 1,
//!    and the simd lane 16–32.
//! 3. **Cache-blocked fusion.** `XOR_j c_j·src_j` evaluated one source at
//!    a time streams the accumulator through the cache hierarchy once per
//!    source. [`combine_many_into`] instead walks the accumulator in
//!    L1-sized blocks and applies *all* sources to each block before
//!    moving on, so every accumulator byte is read and written once per
//!    block no matter how many sources feed it. Per-source dispatch
//!    (coefficient class, table lookup, lane kernel) is hoisted out of
//!    the window loop into a one-pass op list, so inside a window each
//!    source is a single branch-free indirect call.
//! 4. **Lane dispatch.** The XOR and MAC primitives run on the
//!    process-wide active lane ([`super::dispatch`]): AVX2/NEON
//!    byte-shuffle kernels ([`super::simd`]) when the CPU has them, the
//!    portable SWAR/table kernels otherwise, a per-byte scalar oracle for
//!    differential testing.
//!
//! Every path here is differentially tested against the scalar
//! [`super::mul`] reference (`tests/kernel_equivalence.rs`) — the fused
//! engine must be byte-identical to the per-byte loop for every
//! coefficient class (0, 1, arbitrary), every length, every lane, and
//! any source mix.

use std::sync::OnceLock;

use super::dispatch::{self, Lane};
use super::SliceTable;

/// Accumulator block size for the fused combine: big enough to amortize
/// the per-source loop overhead, small enough that the block plus both
/// nibble tables stay L1-resident while the sources stream through.
pub const FUSE_BLOCK: usize = 16 << 10;

static TABLES: OnceLock<Box<[SliceTable; 256]>> = OnceLock::new();

/// All 256 cached slice tables (8 KiB), built once per process — one
/// `OnceLock` acquisition serves a whole combine call.
pub(crate) fn all_tables() -> &'static [SliceTable; 256] {
    TABLES.get_or_init(|| {
        let mut t = [SliceTable::new(0); 256];
        for (c, slot) in t.iter_mut().enumerate() {
            *slot = SliceTable::new(c as u8);
        }
        Box::new(t)
    })
}

/// The shared slice table for coefficient `c` — all 256 tables (8 KiB)
/// are built once per process on first use.
#[inline]
pub fn table(c: u8) -> &'static SliceTable {
    &all_tables()[c as usize]
}

/// `acc[i] ^= src[i]` — the c == 1 lane, dispatched to the process-wide
/// active kernel lane (AVX2/NEON when detected, u64 SWAR otherwise).
pub fn xor_into(acc: &mut [u8], src: &[u8]) {
    assert_eq!(acc.len(), src.len());
    dispatch::xor_fn(dispatch::active_lane())(acc, src);
}

/// The portable SWAR XOR kernel: u64 words, 8 bytes per op — the `swar`
/// lane, and the fallback wherever no SIMD extension is detected.
pub fn xor_into_swar(acc: &mut [u8], src: &[u8]) {
    assert_eq!(acc.len(), src.len());
    let mut a = acc.chunks_exact_mut(8);
    let mut s = src.chunks_exact(8);
    for (ac, sc) in a.by_ref().zip(s.by_ref()) {
        let x = u64::from_ne_bytes((&*ac).try_into().unwrap())
            ^ u64::from_ne_bytes(sc.try_into().unwrap());
        ac.copy_from_slice(&x.to_ne_bytes());
    }
    for (ac, &sc) in a.into_remainder().iter_mut().zip(s.remainder()) {
        *ac ^= sc;
    }
}

/// The per-byte XOR oracle — the `scalar` lane.
pub fn xor_into_scalar(acc: &mut [u8], src: &[u8]) {
    assert_eq!(acc.len(), src.len());
    for (a, &s) in acc.iter_mut().zip(src) {
        *a ^= s;
    }
}

/// The per-byte MAC oracle — the `scalar` lane: one nibble-table lookup
/// pair per byte, no unrolling. The reference the wide lanes are
/// differentially tested against.
pub fn mac_scalar(t: &SliceTable, acc: &mut [u8], src: &[u8]) {
    assert_eq!(acc.len(), src.len());
    for (a, &s) in acc.iter_mut().zip(src) {
        *a ^= t.mul(s);
    }
}

/// One hoisted per-source op of the fused combine: lane kernel + table +
/// source bytes, resolved once per call so the window loop runs each
/// source as a single indirect call with no per-window branching.
struct SourceOp<'a> {
    run: dispatch::MacFn,
    table: &'static SliceTable,
    src: &'a [u8],
}

/// Fused k-way multiply-accumulate:
/// `acc[i] ^= XOR_j sources[j].0 · sources[j].1[i]`.
///
/// Cache-blocked: the accumulator is processed in [`FUSE_BLOCK`]-sized
/// windows, and within a window every source is applied before the window
/// advances — the accumulator is read/written once per window instead of
/// once per source. Per-source work (coefficient-class dispatch, table
/// lookup, lane selection) is resolved **once per call**: coefficient 0
/// sources drop out of the op list entirely, coefficient 1 sources bind
/// the active lane's XOR kernel, the rest bind its MAC kernel with their
/// cached table.
///
/// Generic over the shard representation (`&[u8]`, `Vec<u8>`, …) so the
/// executor's pooled `(coeff, buffer)` staging vector feeds the kernel
/// directly — no per-chunk borrow-slice vector needs to be built.
pub fn combine_many_into<S: AsRef<[u8]>>(acc: &mut [u8], sources: &[(u8, S)]) {
    combine_many_into_lane(dispatch::active_lane(), acc, sources);
}

/// [`combine_many_into`] pinned to an explicit lane (panics if `lane`
/// cannot run on this CPU) — the differential-test surface that lets the
/// equivalence suite force every lane in one process.
pub fn combine_many_into_lane<S: AsRef<[u8]>>(lane: Lane, acc: &mut [u8], sources: &[(u8, S)]) {
    for (_, src) in sources {
        assert_eq!(src.as_ref().len(), acc.len(), "ragged source shard");
    }
    let mac = dispatch::mac_fn(lane);
    let xor = dispatch::xor_as_mac_fn(lane);
    let tables = all_tables();
    // the hoist: one pass over the sources builds ~three words per live
    // source; the window loop below never re-derives any of it
    let ops: Vec<SourceOp> = sources
        .iter()
        .filter_map(|(c, src)| match *c {
            0 => None,
            1 => Some(SourceOp { run: xor, table: &tables[1], src: src.as_ref() }),
            _ => Some(SourceOp { run: mac, table: &tables[*c as usize], src: src.as_ref() }),
        })
        .collect();
    let len = acc.len();
    let mut off = 0usize;
    while off < len {
        let end = (off + FUSE_BLOCK).min(len);
        let window = &mut acc[off..end];
        for op in &ops {
            (op.run)(op.table, window, &op.src[off..end]);
        }
        off = end;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gf::mul;
    use crate::util::rng::xorshift_bytes as pattern;

    #[test]
    fn cached_tables_match_fresh_tables_for_every_coefficient() {
        for c in 0..=255u8 {
            let cached = table(c);
            for s in 0..=255u8 {
                assert_eq!(cached.mul(s), mul(c, s), "c={c} s={s}");
            }
        }
    }

    #[test]
    fn xor_into_matches_scalar_for_all_alignments() {
        let src = pattern(67, 1);
        for len in 0..src.len() {
            let mut acc = pattern(len, 2);
            let mut want = acc.clone();
            for (w, &s) in want.iter_mut().zip(&src[..len]) {
                *w ^= s;
            }
            xor_into(&mut acc, &src[..len]);
            assert_eq!(acc, want, "len={len}");
        }
    }

    #[test]
    fn swar_xor_kernel_matches_scalar_for_all_alignments() {
        let src = pattern(67, 3);
        for len in 0..src.len() {
            let mut acc = pattern(len, 4);
            let mut want = acc.clone();
            xor_into_scalar(&mut want, &src[..len]);
            xor_into_swar(&mut acc, &src[..len]);
            assert_eq!(acc, want, "len={len}");
        }
    }

    #[test]
    fn fused_combine_crosses_block_boundaries_correctly() {
        // length straddles two FUSE_BLOCK windows plus a ragged tail
        let len = FUSE_BLOCK + FUSE_BLOCK / 2 + 7;
        let srcs: Vec<Vec<u8>> = (0..3).map(|i| pattern(len, 10 + i)).collect();
        let coeffs = [0u8, 1, 0x8e];
        let mut acc = pattern(len, 99);
        let mut want = acc.clone();
        for (&c, src) in coeffs.iter().zip(&srcs) {
            for (w, &s) in want.iter_mut().zip(src) {
                *w ^= mul(c, s);
            }
        }
        let pairs: Vec<(u8, &[u8])> =
            coeffs.iter().zip(&srcs).map(|(&c, s)| (c, s.as_slice())).collect();
        combine_many_into(&mut acc, &pairs);
        assert_eq!(acc, want);
    }

    #[test]
    fn hoisted_ops_respect_window_boundaries_on_every_lane() {
        // regression for the window-loop hoist: a source mix containing
        // the dropped (c == 0), XOR (c == 1) and table classes must apply
        // each live source to every window exactly once, for lengths on
        // both sides of the block boundary
        for lane in dispatch::available_lanes() {
            for len in [FUSE_BLOCK - 1, FUSE_BLOCK, FUSE_BLOCK + 1, 2 * FUSE_BLOCK + 13] {
                let srcs: Vec<Vec<u8>> = (0..4).map(|i| pattern(len, 40 + i)).collect();
                let coeffs = [0u8, 1, 0x1d, 0xff];
                let mut acc = pattern(len, 77);
                let mut want = acc.clone();
                for (&c, src) in coeffs.iter().zip(&srcs) {
                    for (w, &s) in want.iter_mut().zip(src) {
                        *w ^= mul(c, s);
                    }
                }
                let pairs: Vec<(u8, &[u8])> =
                    coeffs.iter().zip(&srcs).map(|(&c, s)| (c, s.as_slice())).collect();
                combine_many_into_lane(lane, &mut acc, &pairs);
                assert_eq!(acc, want, "lane={lane:?} len={len}");
            }
        }
    }

    #[test]
    fn empty_inputs_are_noops() {
        let no_sources: [(u8, &[u8]); 0] = [];
        let empty_source: [(u8, &[u8]); 1] = [(7, &[])];
        let mut acc: Vec<u8> = Vec::new();
        combine_many_into(&mut acc, &no_sources);
        combine_many_into(&mut acc, &empty_source);
        assert!(acc.is_empty());
        let mut acc = pattern(33, 4);
        let before = acc.clone();
        combine_many_into(&mut acc, &no_sources);
        assert_eq!(acc, before);
    }
}
