//! Fused, table-cached, word-parallel GF(2^8) combine engine — the
//! byte-crunching core of the recovery data path (DESIGN.md §9).
//!
//! Three ideas, each attacking a distinct per-byte cost that profiling the
//! chunked executor (PR 2) exposed:
//!
//! 1. **Process-wide table cache.** [`SliceTable`] construction costs 32
//!    GF multiplies; `combine_into` used to pay it on *every* call, which
//!    at the executor's 16 KiB chunk granularity is once per source per
//!    chunk. All 256 tables together are only 8 KiB, so [`table`] builds
//!    them exactly once per process and every caller shares them.
//! 2. **SWAR XOR lane.** Coefficient 1 (the LRC/replica/aggregation-merge
//!    lane) is a pure XOR, which is linear over machine words: the u64
//!    fast path in [`xor_into`] moves 8 bytes per op instead of 1.
//! 3. **Cache-blocked fusion.** `XOR_j c_j·src_j` evaluated one source at
//!    a time streams the accumulator through the cache hierarchy once per
//!    source. [`combine_many_into`] instead walks the accumulator in
//!    L1-sized blocks and applies *all* sources to each block before
//!    moving on, so every accumulator byte is read and written once per
//!    block no matter how many sources feed it.
//!
//! Every path here is differentially tested against the scalar
//! [`super::mul`] reference (`tests/kernel_equivalence.rs`) — the fused
//! engine must be byte-identical to the per-byte loop for every
//! coefficient class (0, 1, arbitrary), every length, and any source mix.

use std::sync::OnceLock;

use super::SliceTable;

/// Accumulator block size for the fused combine: big enough to amortize
/// the per-source loop overhead, small enough that the block plus both
/// nibble tables stay L1-resident while the sources stream through.
pub const FUSE_BLOCK: usize = 16 << 10;

static TABLES: OnceLock<Box<[SliceTable; 256]>> = OnceLock::new();

/// The shared slice table for coefficient `c` — all 256 tables (8 KiB)
/// are built once per process on first use.
#[inline]
pub fn table(c: u8) -> &'static SliceTable {
    let tables = TABLES.get_or_init(|| {
        let mut t = [SliceTable::new(0); 256];
        for (c, slot) in t.iter_mut().enumerate() {
            *slot = SliceTable::new(c as u8);
        }
        Box::new(t)
    });
    &tables[c as usize]
}

/// `acc[i] ^= src[i]` — the c == 1 lane, 8 bytes per op (u64 SWAR).
pub fn xor_into(acc: &mut [u8], src: &[u8]) {
    assert_eq!(acc.len(), src.len());
    let mut a = acc.chunks_exact_mut(8);
    let mut s = src.chunks_exact(8);
    for (ac, sc) in a.by_ref().zip(s.by_ref()) {
        let x = u64::from_ne_bytes((&*ac).try_into().unwrap())
            ^ u64::from_ne_bytes(sc.try_into().unwrap());
        ac.copy_from_slice(&x.to_ne_bytes());
    }
    for (ac, &sc) in a.into_remainder().iter_mut().zip(s.remainder()) {
        *ac ^= sc;
    }
}

/// Fused k-way multiply-accumulate:
/// `acc[i] ^= XOR_j sources[j].0 · sources[j].1[i]`.
///
/// Cache-blocked: the accumulator is processed in [`FUSE_BLOCK`]-sized
/// windows, and within a window every source is applied before the window
/// advances — the accumulator is read/written once per window instead of
/// once per source. Coefficient 0 sources are skipped, coefficient 1
/// sources take the SWAR XOR lane, the rest run the cached two-nibble
/// slice kernel.
///
/// Generic over the shard representation (`&[u8]`, `Vec<u8>`, …) so the
/// executor's pooled `(coeff, buffer)` staging vector feeds the kernel
/// directly — no per-chunk borrow-slice vector needs to be built.
pub fn combine_many_into<S: AsRef<[u8]>>(acc: &mut [u8], sources: &[(u8, S)]) {
    for (_, src) in sources {
        assert_eq!(src.as_ref().len(), acc.len(), "ragged source shard");
    }
    let len = acc.len();
    let mut off = 0usize;
    while off < len {
        let end = (off + FUSE_BLOCK).min(len);
        let window = &mut acc[off..end];
        for (c, src) in sources {
            match *c {
                0 => {}
                1 => xor_into(window, &src.as_ref()[off..end]),
                _ => table(*c).mac(window, &src.as_ref()[off..end]),
            }
        }
        off = end;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gf::mul;
    use crate::util::rng::xorshift_bytes as pattern;

    #[test]
    fn cached_tables_match_fresh_tables_for_every_coefficient() {
        for c in 0..=255u8 {
            let cached = table(c);
            for s in 0..=255u8 {
                assert_eq!(cached.mul(s), mul(c, s), "c={c} s={s}");
            }
        }
    }

    #[test]
    fn xor_into_matches_scalar_for_all_alignments() {
        let src = pattern(67, 1);
        for len in 0..src.len() {
            let mut acc = pattern(len, 2);
            let mut want = acc.clone();
            for (w, &s) in want.iter_mut().zip(&src[..len]) {
                *w ^= s;
            }
            xor_into(&mut acc, &src[..len]);
            assert_eq!(acc, want, "len={len}");
        }
    }

    #[test]
    fn fused_combine_crosses_block_boundaries_correctly() {
        // length straddles two FUSE_BLOCK windows plus a ragged tail
        let len = FUSE_BLOCK + FUSE_BLOCK / 2 + 7;
        let srcs: Vec<Vec<u8>> = (0..3).map(|i| pattern(len, 10 + i)).collect();
        let coeffs = [0u8, 1, 0x8e];
        let mut acc = pattern(len, 99);
        let mut want = acc.clone();
        for (&c, src) in coeffs.iter().zip(&srcs) {
            for (w, &s) in want.iter_mut().zip(src) {
                *w ^= mul(c, s);
            }
        }
        let pairs: Vec<(u8, &[u8])> =
            coeffs.iter().zip(&srcs).map(|(&c, s)| (c, s.as_slice())).collect();
        combine_many_into(&mut acc, &pairs);
        assert_eq!(acc, want);
    }

    #[test]
    fn empty_inputs_are_noops() {
        let no_sources: [(u8, &[u8]); 0] = [];
        let empty_source: [(u8, &[u8]); 1] = [(7, &[])];
        let mut acc: Vec<u8> = Vec::new();
        combine_many_into(&mut acc, &no_sources);
        combine_many_into(&mut acc, &empty_source);
        assert!(acc.is_empty());
        let mut acc = pattern(33, 4);
        let before = acc.clone();
        combine_many_into(&mut acc, &no_sources);
        assert_eq!(acc, before);
    }
}
