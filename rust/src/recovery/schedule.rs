//! Link-balanced deterministic recovery scheduling (DESIGN.md §10).
//!
//! D³ guarantees that repair traffic is uniform across nodes and racks
//! *in aggregate* — but the executor used to drain chunk tasks in FIFO
//! plan order, so at any instant the whole worker pool piled onto one
//! plan's source nodes and rack links while every other port sat idle
//! (the network bottleneck Rashmi et al. measured on the Facebook
//! warehouse cluster). Because D³ placement is deterministic and
//! periodic, the conflict structure of a whole recovery is known *up
//! front*: this module colors repair plans by the transfer resources
//! they occupy — source/destination node ports and cross-rack links —
//! and emits a **wavefront schedule**: every round's tasks are mutually
//! source-disjoint, and tasks are claimed strictly in round order.
//!
//! Three layers, all deterministic:
//!
//! * **Coloring.** Plans are greedily packed into conflict-free classes
//!   (first-fit over their resource signatures). Two plans conflict iff
//!   they share a node (any source, aggregator, or destination) or a
//!   cross-rack link. The placement period makes this cheap: when every
//!   period's plans verifiably occupy the same resources slot for slot,
//!   one period's coloring tiles the entire plan set.
//! * **Wavefront rounds.** Classes are banded (enough classes per band
//!   to keep ≥ 2× the worker pool in flight) and each band is drained
//!   chunk-major: round *(c, class)* holds chunk window `c` of every
//!   plan in the class. Tasks are claimed strictly in round order, so
//!   workers steal freely *within* a round and a later round only opens
//!   once the previous one is fully claimed. The rounds govern
//!   *admission*, not completion: when a round is smaller than the
//!   worker pool, spare workers spill into the next round while it
//!   finishes — residual conflicts are bounded by that spillover,
//!   instead of the whole pool piling onto one plan's ports as under
//!   FIFO.
//! * **Fetch coalescing.** Each task covers `coalesce` consecutive
//!   chunks, so everything a task wants from one source node moves in
//!   one window; with `batched_fetch` on, the window's fetches share a
//!   single gate acquisition instead of one per source
//!   (see [`crate::cluster::links`]).
//!
//! FIFO remains available as the baseline policy (and is the default,
//! preserving every pre-existing behavior bit for bit).

use std::collections::{HashMap, HashSet};
use std::sync::{Arc, Mutex, OnceLock};

use crate::topology::Location;

use super::executor::{chunk_spans, ExecutorConfig};
use super::plan::RepairPlan;

/// How the executor (and the simulator's admission loop) orders work.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum SchedulePolicy {
    /// Plan-major FIFO drain (the NameNode queue order) — the baseline.
    #[default]
    Fifo,
    /// Conflict-free wavefront rounds balanced over node ports and
    /// cross-rack links (DESIGN.md §10).
    Balanced,
}

impl SchedulePolicy {
    pub fn as_str(&self) -> &'static str {
        match self {
            SchedulePolicy::Fifo => "fifo",
            SchedulePolicy::Balanced => "balanced",
        }
    }
}

impl std::fmt::Display for SchedulePolicy {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.as_str())
    }
}

impl std::str::FromStr for SchedulePolicy {
    type Err = String;

    fn from_str(s: &str) -> Result<SchedulePolicy, String> {
        match s {
            "fifo" => Ok(SchedulePolicy::Fifo),
            "balanced" => Ok(SchedulePolicy::Balanced),
            other => Err(format!("unknown schedule policy {other} (fifo, balanced)")),
        }
    }
}

/// The executor's complete task order: `(plan index, offset, length)`
/// windows, flattened round-major. Claiming tasks with one atomic cursor
/// reproduces the wavefront exactly — round r+1's first task can only be
/// claimed after every round-r task has been claimed.
#[derive(Clone, Debug)]
pub struct TaskOrder {
    pub tasks: Vec<(usize, u64, usize)>,
    /// Exclusive end index of each round within `tasks`, ascending.
    pub rounds: Vec<usize>,
    /// Fetch windows per plan (identical for every plan — one block size).
    pub tasks_per_plan: usize,
    /// Conflict-free classes the coloring produced (1 for FIFO).
    pub colors: usize,
}

/// `(offset, length)` fetch windows for one block, computed **once per
/// distinct (block size, window size)** process-wide and shared by every
/// schedule build and executor run — the spans used to be recomputed and
/// reallocated per `execute_plans` call.
pub fn spans_for(block_size: u64, window_bytes: u64) -> Arc<Vec<(u64, usize)>> {
    type SpanCache = Mutex<HashMap<(u64, u64), Arc<Vec<(u64, usize)>>>>;
    static CACHE: OnceLock<SpanCache> = OnceLock::new();
    let cache = CACHE.get_or_init(Default::default);
    let mut map = cache.lock().unwrap();
    map.entry((block_size, window_bytes))
        .or_insert_with(|| Arc::new(chunk_spans(block_size, window_bytes)))
        .clone()
}

/// Opaque resource ids a plan's transfers occupy: every node endpoint
/// (sources, aggregators, compute/writer) plus every cross-rack link
/// (unordered rack pair). Sorted and deduplicated, so signatures compare
/// and intersect deterministically.
pub fn plan_resources(plan: &RepairPlan) -> Vec<u64> {
    const LINK_TAG: u64 = 1 << 62;
    let node = |l: Location| ((l.rack as u64) << 32) | l.node as u64;
    let link = |a: u32, b: u32| {
        let (lo, hi) = if a < b { (a, b) } else { (b, a) };
        LINK_TAG | ((lo as u64) << 32) | hi as u64
    };
    let mut res = Vec::new();
    for agg in &plan.aggregations {
        for &(_, l) in &agg.inputs {
            res.push(node(l));
        }
        if agg.at.rack != plan.compute_at.rack {
            res.push(link(agg.at.rack, plan.compute_at.rack));
        }
    }
    for &(_, l) in &plan.direct {
        res.push(node(l));
        if l.rack != plan.compute_at.rack {
            res.push(link(l.rack, plan.compute_at.rack));
        }
    }
    res.push(node(plan.compute_at));
    res.push(node(plan.writer));
    res.sort_unstable();
    res.dedup();
    res
}

/// Greedy first-fit packing of `0..n` items into conflict-free classes:
/// an item joins the first class whose accumulated resource set is
/// disjoint from its signature.
fn greedy_classes<F: FnMut(usize) -> Arc<Vec<u64>>>(
    n: usize,
    mut sig_of: F,
) -> Vec<Vec<usize>> {
    let mut classes: Vec<(HashSet<u64>, Vec<usize>)> = Vec::new();
    for i in 0..n {
        let sig = sig_of(i);
        match classes
            .iter_mut()
            .find(|(used, _)| sig.iter().all(|r| !used.contains(r)))
        {
            Some((used, members)) => {
                used.extend(sig.iter().copied());
                members.push(i);
            }
            None => classes.push((sig.iter().copied().collect(), vec![i])),
        }
    }
    classes.into_iter().map(|(_, members)| members).collect()
}

/// Conflict-free classes over `plans`, in deterministic class order.
/// `period` is the placement period when known: when the plan set tiles —
/// every period's plans occupy, slot for slot, **verifiably identical
/// resources** to the first period's (the common node/rack-recovery
/// case; the final period may be a partial prefix) — the first period's
/// coloring is stamped across the whole run instead of re-running the
/// quadratic greedy pass. Plan sets that don't tile (e.g. multi-erasure
/// targets rerouted by a raw-stripe-id hash) fall back to plain greedy
/// coloring over per-plan signatures, so the conflict-free invariant
/// never rests on an unchecked periodicity assumption.
pub fn color_classes(plans: &[RepairPlan], period: Option<u64>) -> Vec<Vec<usize>> {
    if plans.is_empty() {
        return Vec::new();
    }
    if let Some(p) = period.filter(|&p| p > 0) {
        if let Some(classes) = tiled_classes(plans, p) {
            return classes;
        }
    }
    greedy_classes(plans.len(), |i| Arc::new(plan_resources(&plans[i])))
}

/// Period-tiling fast path: split `plans` into consecutive period runs
/// (by `stripe / p`) and **verify, resource set by resource set**, that
/// every later run repeats the first run's slots (middle runs exactly,
/// the final run as a prefix). Only then is the first period's coloring
/// replicated — plans in the same relative slot of different periods
/// occupy identical resources by construction of the check, so
/// slot-color classes of distinct periods are exactly the conflict-free
/// classes greedy coloring would rediscover. Any mismatch returns `None`
/// and the caller colors the full set directly.
fn tiled_classes(plans: &[RepairPlan], p: u64) -> Option<Vec<Vec<usize>>> {
    // split into consecutive period runs (stripe / p must be non-decreasing)
    let mut runs: Vec<(usize, usize)> = Vec::new();
    let mut start = 0usize;
    for i in 1..plans.len() {
        let (prev, cur) = (plans[i - 1].stripe / p, plans[i].stripe / p);
        if cur < prev {
            return None;
        }
        if cur > prev {
            runs.push((start, i));
            start = i;
        }
    }
    runs.push((start, plans.len()));
    if runs.len() < 2 {
        return None; // a single period gains nothing from tiling
    }
    let (f0, f1) = runs[0];
    let first_sigs: Vec<Vec<u64>> = plans[f0..f1].iter().map(plan_resources).collect();
    for (ri, &(a, b)) in runs[1..].iter().enumerate() {
        // middle periods must repeat exactly; the final (possibly
        // partial) period may be a prefix of the first
        let exact = ri + 1 < runs.len() - 1;
        if (exact && b - a != first_sigs.len()) || b - a > first_sigs.len() {
            return None;
        }
        for (j, plan) in plans[a..b].iter().enumerate() {
            if plan_resources(plan) != first_sigs[j] {
                return None;
            }
        }
    }
    let sigs: Vec<Arc<Vec<u64>>> = first_sigs.into_iter().map(Arc::new).collect();
    let base = greedy_classes(sigs.len(), |j| sigs[j].clone());
    let colors = base.len();
    let mut color_of = vec![0usize; f1 - f0];
    for (c, members) in base.iter().enumerate() {
        for &j in members {
            color_of[j] = c;
        }
    }
    let mut classes: Vec<Vec<usize>> = vec![Vec::new(); colors * runs.len()];
    for (q, &(a, b)) in runs.iter().enumerate() {
        for (j, i) in (a..b).enumerate() {
            classes[q * colors + color_of[j]].push(i);
        }
    }
    Some(classes)
}

/// The order in which the balanced wavefront first touches each plan —
/// the admission order the fluid simulator mirrors so both backends run
/// recovery in the same sequence ([`crate::sim::recovery`]).
pub fn plan_admission_order(plans: &[RepairPlan], period: Option<u64>) -> Vec<usize> {
    color_classes(plans, period).into_iter().flatten().collect()
}

/// Build the executor's complete task order for `plans` under `cfg`.
pub fn build_task_order(
    plans: &[RepairPlan],
    block_size: u64,
    cfg: &ExecutorConfig,
) -> TaskOrder {
    let window = cfg.chunk_size.max(1).saturating_mul(cfg.coalesce.max(1) as u64);
    let spans = spans_for(block_size, window);
    let mut tasks = Vec::with_capacity(plans.len() * spans.len());
    let mut rounds = Vec::new();
    let colors;
    match cfg.schedule {
        SchedulePolicy::Fifo => {
            // plan-major: a plan's windows pipeline while the next plan's
            // first fetches are already in flight (pre-§10 behavior)
            for pi in 0..plans.len() {
                for &(off, len) in spans.iter() {
                    tasks.push((pi, off, len));
                }
            }
            if !tasks.is_empty() {
                rounds.push(tasks.len());
            }
            colors = usize::from(!plans.is_empty());
        }
        SchedulePolicy::Balanced => {
            let classes = color_classes(plans, cfg.period);
            colors = classes.len();
            // Band the classes so live assembly buffers stay bounded:
            // each band carries enough plans to keep ≥ 2× the worker
            // pool in flight per wavefront row, and a band's plans fully
            // assemble before the next band's buffers materialize.
            let target = cfg.workers.max(1) * 2;
            let mut band: Vec<&Vec<usize>> = Vec::new();
            let mut band_plans = 0usize;
            let mut flush =
                |band: &mut Vec<&Vec<usize>>,
                 tasks: &mut Vec<(usize, u64, usize)>,
                 rounds: &mut Vec<usize>| {
                    for &(off, len) in spans.iter() {
                        for class in band.iter() {
                            let start = tasks.len();
                            for &pi in class.iter() {
                                tasks.push((pi, off, len));
                            }
                            if tasks.len() > start {
                                rounds.push(tasks.len());
                            }
                        }
                    }
                    band.clear();
                };
            for class in &classes {
                band_plans += class.len();
                band.push(class);
                if band_plans >= target {
                    flush(&mut band, &mut tasks, &mut rounds);
                    band_plans = 0;
                }
            }
            if !band.is_empty() {
                flush(&mut band, &mut tasks, &mut rounds);
            }
        }
    }
    TaskOrder { tasks, rounds, tasks_per_plan: spans.len(), colors }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::codes::CodeSpec;
    use crate::placement::{D3Placement, Placement};
    use crate::recovery::node_recovery_plans;
    use crate::topology::ClusterSpec;

    fn node_plans(stripes: u64) -> (Vec<RepairPlan>, Option<u64>) {
        let cluster = ClusterSpec::new(4, 4);
        let p = D3Placement::new(CodeSpec::Rs { k: 3, m: 2 }, cluster).unwrap();
        let failed = (0..cluster.node_count())
            .map(|i| cluster.unflat(i))
            .find(|&l| (0..stripes).any(|sid| p.stripe(sid).locs.contains(&l)))
            .expect("no node holds blocks");
        let plans = node_recovery_plans(&p, stripes, failed, 0);
        assert!(!plans.is_empty());
        (plans, p.period())
    }

    fn cfg(schedule: SchedulePolicy, chunk: u64, coalesce: usize) -> ExecutorConfig {
        ExecutorConfig {
            workers: 4,
            chunk_size: chunk,
            schedule,
            coalesce,
            ..ExecutorConfig::default()
        }
    }

    #[test]
    fn fifo_order_is_plan_major() {
        let (plans, _) = node_plans(20);
        let order = build_task_order(&plans, 1024, &cfg(SchedulePolicy::Fifo, 256, 1));
        assert_eq!(order.tasks_per_plan, 4);
        assert_eq!(order.tasks.len(), plans.len() * 4);
        let expect: Vec<(usize, u64, usize)> = (0..plans.len())
            .flat_map(|pi| (0..4u64).map(move |c| (pi, c * 256, 256usize)))
            .collect();
        assert_eq!(order.tasks, expect);
        assert_eq!(order.rounds, vec![order.tasks.len()]);
    }

    #[test]
    fn balanced_covers_every_task_exactly_once() {
        let (plans, period) = node_plans(40);
        let mut c = cfg(SchedulePolicy::Balanced, 256, 1);
        c.period = period;
        for coalesce in [1usize, 3] {
            c.coalesce = coalesce;
            let order = build_task_order(&plans, 1000, &c);
            let mut seen = std::collections::HashSet::new();
            let mut per_plan = vec![0u64; plans.len()];
            for &(pi, off, len) in &order.tasks {
                assert!(seen.insert((pi, off)), "duplicate task ({pi}, {off})");
                per_plan[pi] += len as u64;
            }
            assert!(per_plan.iter().all(|&b| b == 1000), "coalesce={coalesce}");
            assert_eq!(order.tasks.len(), plans.len() * order.tasks_per_plan);
            assert_eq!(*order.rounds.last().unwrap(), order.tasks.len());
        }
    }

    #[test]
    fn balanced_rounds_are_conflict_free() {
        let (plans, period) = node_plans(40);
        let mut c = cfg(SchedulePolicy::Balanced, 512, 1);
        c.period = period;
        let order = build_task_order(&plans, 1024, &c);
        assert!(order.colors > 1, "node recovery should need several classes");
        let mut start = 0usize;
        for &end in &order.rounds {
            let mut used: HashSet<u64> = HashSet::new();
            for &(pi, _, _) in &order.tasks[start..end] {
                for r in plan_resources(&plans[pi]) {
                    assert!(
                        used.insert(r),
                        "round [{start}, {end}) shares resource {r:#x}"
                    );
                }
            }
            start = end;
        }
    }

    #[test]
    fn period_tiling_matches_plain_greedy_coloring() {
        // 2 full periods + a partial third: the tiling fast path applies
        let (plans, period) = node_plans(2 * 192 + 50);
        let period = period.expect("D3 is periodic");
        assert!(plans.last().unwrap().stripe / period >= 1, "need multiple periods");
        let tiled = color_classes(&plans, Some(period));
        let plain = color_classes(&plans, None);
        // same cover either way...
        let count = |cs: &[Vec<usize>]| cs.iter().map(Vec::len).sum::<usize>();
        assert_eq!(count(&tiled), plans.len());
        assert_eq!(count(&plain), plans.len());
        // ...and every tiled class is genuinely conflict-free
        for class in &tiled {
            let mut used: HashSet<u64> = HashSet::new();
            for &pi in class {
                for r in plan_resources(&plans[pi]) {
                    assert!(used.insert(r), "tiled class shares resource");
                }
            }
        }
    }

    #[test]
    fn admission_order_is_a_permutation() {
        let (plans, period) = node_plans(30);
        let order = plan_admission_order(&plans, period);
        let mut sorted = order.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..plans.len()).collect::<Vec<_>>());
        // deterministic
        assert_eq!(order, plan_admission_order(&plans, period));
    }

    #[test]
    fn span_cache_returns_shared_covering_spans() {
        let a = spans_for(1000, 256);
        let b = spans_for(1000, 256);
        assert!(Arc::ptr_eq(&a, &b), "second lookup must hit the cache");
        let total: u64 = a.iter().map(|&(_, l)| l as u64).sum();
        assert_eq!(total, 1000);
        assert_eq!(spans_for(0, 64).as_slice(), &[(0, 0)]);
    }

    #[test]
    fn schedule_policy_parses_and_prints() {
        assert_eq!("fifo".parse::<SchedulePolicy>().unwrap(), SchedulePolicy::Fifo);
        assert_eq!(
            "balanced".parse::<SchedulePolicy>().unwrap(),
            SchedulePolicy::Balanced
        );
        assert!("fancy".parse::<SchedulePolicy>().is_err());
        assert_eq!(SchedulePolicy::Balanced.to_string(), "balanced");
    }
}
