//! Lemma 4: the closed-form minimum average number of cross-rack accessed
//! blocks μ for recovering one failed block under D³'s stripe layout.

/// μ for a (k, m)-RS code (Eq. (1) of the paper):
///
/// ```text
/// μ = [(a−1)(k+1) + a(m−1)] / (k+m)   if b = m−1
/// μ = a − 1                           otherwise
/// ```
/// with `len = k + m = a·m + b`.
pub fn mu_rs(k: usize, m: usize) -> f64 {
    let len = k + m;
    let a = len / m;
    let b = len % m;
    if m > 1 && b == m - 1 {
        ((a - 1) * (k + 1) + a * (m - 1)) as f64 / len as f64
    } else {
        (a - 1) as f64
    }
}

/// Cross-rack accessed blocks for the "one block per rack" layout: always
/// k (read k survivors, compute at one of the source racks' new node...
/// the paper's Fig 2(a) counts k including the recovered block's shipment
/// pattern: 3 blocks for (3,2)). We count the k source reads.
pub fn mu_one_block_per_rack(k: usize) -> f64 {
    k as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::codes::CodeSpec;
    use crate::placement::D3Placement;
    use crate::recovery::plan::plan_repair;
    use crate::topology::ClusterSpec;

    #[test]
    fn paper_example_values() {
        // (3,2): μ = (1·4 + 2·1)/5 = 1.2 (§3.2.1)
        assert!((mu_rs(3, 2) - 1.2).abs() < 1e-12);
        // (6,3): b = 0 → μ = a−1 = 2
        assert!((mu_rs(6, 3) - 2.0).abs() < 1e-12);
        // (2,1): m = 1 → b = 0, a = 3 → μ = 2 = k (one block per rack)
        assert!((mu_rs(2, 1) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn planner_average_matches_closed_form() {
        // Enumerate every block of full regions and compare the plan's
        // cross-rack count average against Eq. (1).
        for (k, m, n) in [
            (2usize, 1usize, 3usize),
            (3, 2, 3),
            (6, 3, 3),
            (4, 2, 3),
            (6, 4, 4),
            (8, 3, 4),
            (10, 4, 4),
        ] {
            let racks = 11; // prime, plenty of OA columns
            let p = match D3Placement::new(CodeSpec::Rs { k, m }, ClusterSpec::new(racks, n)) {
                Ok(p) => p,
                Err(e) => panic!("({k},{m}) config invalid: {e}"),
            };
            let len = k + m;
            let stripes = (p.region_size() * 4) as u64;
            let mut total = 0usize;
            for sid in 0..stripes {
                for bi in 0..len {
                    total += plan_repair(&p, sid, bi, 0).cross_rack_blocks();
                }
            }
            let avg = total as f64 / (stripes as usize * len) as f64;
            let want = mu_rs(k, m);
            assert!(
                (avg - want).abs() < 1e-9,
                "({k},{m}): planner avg {avg} vs Lemma 4 μ {want}"
            );
        }
    }

    #[test]
    fn d3_always_beats_or_matches_one_block_per_rack() {
        for k in 2..=12usize {
            for m in 1..=4usize {
                assert!(mu_rs(k, m) <= mu_one_block_per_rack(k) + 1e-12, "k={k} m={m}");
            }
        }
    }
}
