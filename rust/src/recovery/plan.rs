//! Per-stripe repair plans (paper §5.1 for RS, §5.2 for LRC).
//!
//! A [`RepairPlan`] is placement-policy specific:
//!
//! * **D³/RS** — the three-case minimum-cross-rack plan of §5.1.1: each
//!   contributing group aggregates its selected blocks inner-rack at the
//!   node holding the group's largest-subscript selected block, then ships
//!   ONE aggregated block to the compute node; blocks already in the
//!   target rack feed the compute node inner-rack.
//! * **RDD/HDD** — the baseline plan of §6.1: k randomly chosen surviving
//!   blocks are each shipped whole to the target node (no aggregation).
//! * **LRC** — the typed plan of §5.2: the code's minimal repair set
//!   (local group for data/local parity, the other parities for a global
//!   parity), shipped whole (sources sit one-per-rack).

use crate::codes::{CodeSpec, LrcCode, RsCode};
use crate::placement::{d3_group_of, d3_groups, Placement, StripePlacement};
use crate::topology::Location;
use crate::util::Rng;

/// One inner-rack aggregation: `at` reads the other `inputs` from its rack,
/// combines them with its own, and forwards a single aggregated block.
#[derive(Clone, Debug)]
pub struct Aggregation {
    /// Aggregator node (holds the largest-subscript selected block).
    pub at: Location,
    /// (block index, location) of every selected block in this group,
    /// including the aggregator's own block.
    pub inputs: Vec<(usize, Location)>,
}

/// The full repair plan for one failed block of one stripe.
#[derive(Clone, Debug)]
pub struct RepairPlan {
    pub stripe: u64,
    pub failed_block: usize,
    /// Node performing the final combine.
    pub compute_at: Location,
    /// Node storing the recovered block (== compute_at for node recovery;
    /// degraded reads have compute_at == client and no persisted copy).
    pub writer: Location,
    /// Whether the recovered block is persisted to `writer`'s disk.
    pub persist: bool,
    /// Inner-rack aggregations feeding one block each to `compute_at`.
    pub aggregations: Vec<Aggregation>,
    /// Blocks shipped whole to `compute_at` (block index, location).
    pub direct: Vec<(usize, Location)>,
    /// Explicit decode coefficients aligned with [`RepairPlan::source_blocks`]
    /// order. `None` = derive from the code's single-failure machinery
    /// (the default for single-erasure plans); multi-erasure plans carry
    /// their solver-produced coefficients here (DESIGN.md §4).
    pub coeffs: Option<Vec<u8>>,
}

impl RepairPlan {
    /// Number of whole-block transfers that cross racks — the paper's
    /// "cross-rack accessed blocks" (Lemma 4 / Objective 2).
    pub fn cross_rack_blocks(&self) -> usize {
        let mut n = 0;
        for agg in &self.aggregations {
            // aggregation inputs are inner-rack; the aggregated block
            // crosses iff the aggregator sits outside the compute rack
            if agg.at.rack != self.compute_at.rack {
                n += 1;
            }
            debug_assert!(agg.inputs.iter().all(|(_, l)| l.rack == agg.at.rack));
        }
        for (_, loc) in &self.direct {
            if loc.rack != self.compute_at.rack {
                n += 1;
            }
        }
        n
    }

    /// Total whole-block disk reads the plan performs.
    pub fn blocks_read(&self) -> usize {
        self.aggregations.iter().map(|a| a.inputs.len()).sum::<usize>() + self.direct.len()
    }

    /// All source block indices, ascending (for coefficient computation).
    pub fn source_blocks(&self) -> Vec<usize> {
        let mut v: Vec<usize> = self
            .aggregations
            .iter()
            .flat_map(|a| a.inputs.iter().map(|(b, _)| *b))
            .chain(self.direct.iter().map(|(b, _)| *b))
            .collect();
        v.sort_unstable();
        v
    }
}

/// Build the repair plan for `(sid, failed_block)` under `policy`.
/// `seed` feeds the randomized source selection of RDD/HDD.
pub fn plan_repair(
    policy: &dyn Placement,
    sid: u64,
    failed_block: usize,
    seed: u64,
) -> RepairPlan {
    let sp = policy.stripe(sid);
    let failed_loc = sp.locs[failed_block];
    let writer = policy.recovery_target(sid, failed_block, failed_loc);
    match (policy.code(), policy.name()) {
        (CodeSpec::Rs { k, m }, "d3" | "d3-norot" | "d3-rr") => {
            plan_d3_rs_at(k, m, sid, failed_block, &sp, writer)
        }
        (CodeSpec::Rs { k, .. }, _) => plan_random_rs(k, sid, failed_block, &sp, writer, seed),
        (CodeSpec::Lrc { k, l, g }, _) => plan_lrc(k, l, g, sid, failed_block, &sp, writer),
    }
}

/// Degraded read: rebuild at `client` without persisting (paper Exp 3).
pub fn plan_degraded_read(
    policy: &dyn Placement,
    sid: u64,
    failed_block: usize,
    client: Location,
    seed: u64,
) -> RepairPlan {
    let sp = policy.stripe(sid);
    let mut plan = match (policy.code(), policy.name()) {
        (CodeSpec::Rs { k, m }, "d3" | "d3-norot" | "d3-rr") => {
            plan_d3_rs_at(k, m, sid, failed_block, &sp, client)
        }
        (CodeSpec::Rs { k, .. }, _) => plan_random_rs(k, sid, failed_block, &sp, client, seed),
        (CodeSpec::Lrc { k, l, g }, _) => plan_lrc(k, l, g, sid, failed_block, &sp, client),
    };
    plan.compute_at = client;
    plan.writer = client;
    plan.persist = false;
    plan
}

/// §5.1.1 D³/RS plan computing/storing the block at `target` (the
/// placement's `recovery_target` for node recovery, the client for
/// degraded reads). Kept in lock-step with the same case analysis used by
/// `D3Placement::recovery_target`.
fn plan_d3_rs_at(
    k: usize,
    m: usize,
    sid: u64,
    failed_block: usize,
    sp: &StripePlacement,
    target: Location,
) -> RepairPlan {
    let len = k + m;
    let b = len % m;
    let groups = d3_groups(len, m);
    let fg = d3_group_of(&groups, failed_block);

    // Blocks already co-located with the compute node's rack contribute
    // directly (the z blocks of §5.1.1 cases 2 / 3.1). The failed group
    // never contributes (the construction never places the target in the
    // failed group's rack).
    let local_group = (0..groups.len())
        .find(|&j| j != fg && sp.locs[groups[j].start].rack == target.rack);

    // Select the k source blocks (smallest subscripts first, per §5.1.1).
    let z = local_group.map_or(0, |j| groups[j].len());
    let mut selected: Vec<usize> = Vec::with_capacity(k);
    if let Some(j) = local_group {
        selected.extend(groups[j].clone());
    }
    let mut pool: Vec<usize> = (0..groups.len())
        .filter(|&j| j != fg && Some(j) != local_group)
        .flat_map(|j| groups[j].clone())
        .collect();
    pool.sort_unstable();
    selected.extend(pool.into_iter().take(k - z));
    debug_assert_eq!(selected.len(), k, "need exactly k sources (b={b})");

    // Partition into per-group aggregations / direct feeds.
    let mut aggregations = Vec::new();
    let mut direct = Vec::new();
    for (j, group) in groups.iter().enumerate() {
        if j == fg {
            continue;
        }
        let sel: Vec<usize> =
            group.clone().filter(|bi| selected.contains(bi)).collect();
        if sel.is_empty() {
            continue;
        }
        if Some(j) == local_group {
            // target-rack blocks feed the compute node inner-rack
            direct.extend(sel.iter().map(|&bi| (bi, sp.locs[bi])));
        } else if sel.len() == 1 {
            direct.push((sel[0], sp.locs[sel[0]]));
        } else {
            // aggregator = holder of the largest-subscript selected block
            let agg_block = *sel.last().unwrap();
            aggregations.push(Aggregation {
                at: sp.locs[agg_block],
                inputs: sel.iter().map(|&bi| (bi, sp.locs[bi])).collect(),
            });
        }
    }
    RepairPlan {
        stripe: sid,
        failed_block,
        compute_at: target,
        writer: target,
        persist: true,
        aggregations,
        direct,
        coeffs: None,
    }
}

/// RDD/HDD plan: k random survivors shipped whole to the target (§6.1).
fn plan_random_rs(
    k: usize,
    sid: u64,
    failed_block: usize,
    sp: &StripePlacement,
    writer: Location,
    seed: u64,
) -> RepairPlan {
    let survivors: Vec<usize> =
        (0..sp.locs.len()).filter(|&b| b != failed_block).collect();
    let mut rng = Rng::keyed(seed, sid, failed_block as u64);
    let chosen = rng.sample_indices(survivors.len(), k);
    let mut direct: Vec<(usize, Location)> =
        chosen.into_iter().map(|i| (survivors[i], sp.locs[survivors[i]])).collect();
    direct.sort_unstable_by_key(|(b, _)| *b);
    RepairPlan {
        stripe: sid,
        failed_block,
        compute_at: writer,
        writer,
        persist: true,
        aggregations: Vec::new(),
        direct,
        coeffs: None,
    }
}

/// LRC typed plan (§5.2): minimal repair set shipped whole (one block per
/// rack, so there is no inner-rack aggregation to exploit).
fn plan_lrc(
    k: usize,
    l: usize,
    g: usize,
    sid: u64,
    failed_block: usize,
    sp: &StripePlacement,
    writer: Location,
) -> RepairPlan {
    let code = LrcCode::new(k, l, g);
    let (sources, _) = code.repair_plan(failed_block);
    let direct = sources.into_iter().map(|b| (b, sp.locs[b])).collect();
    RepairPlan {
        stripe: sid,
        failed_block,
        compute_at: writer,
        writer,
        persist: true,
        aggregations: Vec::new(),
        direct,
        coeffs: None,
    }
}

/// Decode coefficients for a plan's sources (native or PJRT data path),
/// aligned with `plan.source_blocks()` order.
pub fn plan_coefficients(code: &CodeSpec, plan: &RepairPlan) -> Vec<u8> {
    if let Some(c) = &plan.coeffs {
        debug_assert_eq!(c.len(), plan.source_blocks().len());
        return c.clone();
    }
    match *code {
        CodeSpec::Rs { k, m } => {
            let rs = RsCode::new(k, m);
            let sources = plan.source_blocks();
            rs.decode_coeffs(&sources, plan.failed_block)
                .expect("repair plan selected an invalid source set")
        }
        CodeSpec::Lrc { k, l, g } => {
            let lrc = LrcCode::new(k, l, g);
            let (sources, coeffs) = lrc.repair_plan(plan.failed_block);
            let mut order: Vec<(usize, u8)> =
                sources.into_iter().zip(coeffs).collect();
            order.sort_unstable_by_key(|(b, _)| *b);
            debug_assert_eq!(
                order.iter().map(|(b, _)| *b).collect::<Vec<_>>(),
                plan.source_blocks()
            );
            order.into_iter().map(|(_, c)| c).collect()
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::placement::{D3Placement, RddPlacement};
    use crate::topology::ClusterSpec;

    fn d3(k: usize, m: usize, racks: usize, n: usize) -> D3Placement {
        D3Placement::new(CodeSpec::Rs { k, m }, ClusterSpec::new(racks, n)).unwrap()
    }

    #[test]
    fn d3_plan_reads_exactly_k_blocks() {
        for (k, m, n) in [(2usize, 1usize, 3usize), (3, 2, 3), (6, 3, 3), (6, 4, 4)] {
            let p = d3(k, m, 8, n);
            for sid in 0..300u64 {
                let sp = p.stripe(sid);
                for (bi, _) in sp.locs.iter().enumerate() {
                    let plan = plan_repair(&p, sid, bi, 0);
                    assert_eq!(plan.blocks_read(), k, "({k},{m}) sid={sid} b={bi}");
                    let srcs = plan.source_blocks();
                    assert!(!srcs.contains(&bi), "plan reads the failed block");
                    let dedup: std::collections::HashSet<usize> =
                        srcs.iter().copied().collect();
                    assert_eq!(dedup.len(), k, "duplicate sources");
                }
            }
        }
    }

    #[test]
    fn d3_plan_sources_are_decodable() {
        // decode coefficients must exist for every plan's source set
        for (k, m) in [(3usize, 2usize), (6, 3), (6, 4)] {
            let p = d3(k, m, 8, 4);
            for sid in 0..100u64 {
                let sp = p.stripe(sid);
                for bi in 0..sp.locs.len() {
                    let plan = plan_repair(&p, sid, bi, 0);
                    let coeffs = plan_coefficients(&CodeSpec::Rs { k, m }, &plan);
                    assert_eq!(coeffs.len(), k);
                }
            }
        }
    }

    #[test]
    fn d3_aggregation_inputs_share_the_aggregator_rack() {
        let p = d3(6, 3, 8, 3);
        for sid in 0..200u64 {
            for bi in 0..9 {
                let plan = plan_repair(&p, sid, bi, 0);
                for agg in &plan.aggregations {
                    assert!(agg.inputs.iter().all(|(_, l)| l.rack == agg.at.rack));
                    assert!(agg.inputs.iter().any(|(_, l)| *l == agg.at));
                    assert!(agg.inputs.len() >= 2, "1-block aggregation should be direct");
                }
            }
        }
    }

    #[test]
    fn d3_cross_rack_blocks_match_lemma_4_cases() {
        // (6,3): b = 0, a = 3 → μ = a−1 = 2 for every block.
        let p = d3(6, 3, 8, 3);
        for sid in 0..100u64 {
            for bi in 0..9 {
                let plan = plan_repair(&p, sid, bi, 0);
                assert_eq!(plan.cross_rack_blocks(), 2, "sid={sid} b={bi}");
            }
        }
        // (3,2): len 5 = 2·2+1, b = 1 = m−1, a = 2: size-m group blocks
        // (B0..B3) cost a−1 = 1; the (m−1)-group block B4 costs a = 2.
        let p = d3(3, 2, 8, 3);
        for sid in 0..100u64 {
            for bi in 0..5 {
                let plan = plan_repair(&p, sid, bi, 0);
                let want = if bi < 4 { 1 } else { 2 };
                assert_eq!(plan.cross_rack_blocks(), want, "sid={sid} b={bi}");
            }
        }
    }

    #[test]
    fn rdd_plan_reads_k_random_survivors() {
        let p = RddPlacement::new(CodeSpec::Rs { k: 3, m: 2 }, ClusterSpec::new(8, 3), 5);
        let mut cross_total = 0usize;
        for sid in 0..200u64 {
            let sp = p.stripe(sid);
            for bi in 0..5 {
                let plan = plan_repair(&p, sid, bi, 5);
                assert_eq!(plan.blocks_read(), 3);
                assert!(plan.aggregations.is_empty());
                assert!(!plan.source_blocks().contains(&bi));
                let _ = sp;
                cross_total += plan.cross_rack_blocks();
            }
        }
        // RDD ships most sources across racks: strictly worse than D³'s
        // μ = 1.2 average for (3,2) (Lemma 4).
        let avg = cross_total as f64 / 1000.0;
        assert!(avg > 1.8, "RDD cross-rack avg {avg} suspiciously low");
    }

    #[test]
    fn degraded_read_targets_client_without_persist() {
        let p = d3(3, 2, 8, 3);
        let client = Location::new(7, 1);
        let plan = plan_degraded_read(&p, 11, 0, client, 0);
        assert_eq!(plan.compute_at, client);
        assert!(!plan.persist);
        assert_eq!(plan.blocks_read(), 3);
    }

    #[test]
    fn lrc_plan_uses_minimal_typed_sources() {
        use crate::placement::D3LrcPlacement;
        let p = D3LrcPlacement::new(
            CodeSpec::Lrc { k: 4, l: 2, g: 1 },
            ClusterSpec::new(8, 3),
        )
        .unwrap();
        for sid in 0..100u64 {
            for bi in 0..7 {
                let plan = plan_repair(&p, sid, bi, 0);
                assert_eq!(plan.blocks_read(), 2, "every (4,2,1) repair reads 2");
                // one block per rack ⇒ every read crosses racks
                assert_eq!(plan.cross_rack_blocks(), 2);
                let coeffs = plan_coefficients(&CodeSpec::Lrc { k: 4, l: 2, g: 1 }, &plan);
                assert_eq!(coeffs, vec![1, 1]);
            }
        }
    }
}
