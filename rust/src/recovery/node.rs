//! Full-node failure recovery: every stripe with a block on the failed
//! node gets one repair plan (paper §5.1.2–§5.1.3 place the recovered
//! blocks; the plans carry the traffic structure the simulator runs).

use crate::placement::Placement;
use crate::topology::Location;

use super::plan::{plan_repair, RepairPlan};

/// Repair plans for all of `failed`'s blocks among stripes `0..stripes`.
/// Plans are ordered by stripe id — the order the NameNode queues them.
pub fn node_recovery_plans(
    policy: &dyn Placement,
    stripes: u64,
    failed: Location,
    seed: u64,
) -> Vec<RepairPlan> {
    let mut plans = Vec::new();
    for sid in 0..stripes {
        let sp = policy.stripe(sid);
        for (bi, &loc) in sp.locs.iter().enumerate() {
            if loc == failed {
                plans.push(plan_repair(policy, sid, bi, seed));
            }
        }
    }
    plans
}

/// Total bytes lost on `failed` (what recovery must rebuild).
pub fn failed_bytes(policy: &dyn Placement, stripes: u64, failed: Location, block_size: u64) -> u64 {
    let mut count = 0u64;
    for sid in 0..stripes {
        count += policy
            .stripe(sid)
            .locs
            .iter()
            .filter(|&&l| l == failed)
            .count() as u64;
    }
    count * block_size
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::codes::CodeSpec;
    use crate::placement::{D3Placement, RddPlacement};
    use crate::topology::ClusterSpec;
    use std::collections::HashMap;

    #[test]
    fn every_failed_block_gets_a_plan() {
        let cluster = ClusterSpec::new(8, 3);
        let p = D3Placement::new(CodeSpec::Rs { k: 3, m: 2 }, cluster).unwrap();
        let failed = Location::new(2, 1);
        let stripes = 500u64;
        let plans = node_recovery_plans(&p, stripes, failed, 0);
        let mut expected = 0;
        for sid in 0..stripes {
            expected += p.stripe(sid).locs.iter().filter(|&&l| l == failed).count();
        }
        assert_eq!(plans.len(), expected);
        assert!(expected > 0, "failed node held no blocks?");
        for plan in &plans {
            assert_ne!(plan.writer, failed);
            assert!(plan
                .aggregations
                .iter()
                .flat_map(|a| a.inputs.iter())
                .chain(plan.direct.iter())
                .all(|(_, l)| *l != failed));
        }
    }

    #[test]
    fn d3_write_load_balanced_over_full_cycle() {
        // Theorem 6: recovered-block writes spread evenly across surviving
        // nodes (within each region they go round-robin; across regions 𝓜
        // balances racks). Check per-node write counts over a full cycle.
        let cluster = ClusterSpec::new(5, 3);
        let p = D3Placement::new(CodeSpec::Rs { k: 3, m: 2 }, cluster).unwrap();
        let stripes = (p.region_cycle() * p.region_size()) as u64;
        let failed = Location::new(0, 0);
        let plans = node_recovery_plans(&p, stripes, failed, 0);
        let mut writes: HashMap<Location, usize> = HashMap::new();
        for plan in &plans {
            *writes.entry(plan.writer).or_default() += 1;
        }
        assert!(writes.values().all(|&c| c > 0));
        let max = *writes.values().max().unwrap();
        let min = *writes.values().min().unwrap();
        // exact balance not required across *types*, but spread must be tight
        assert!(
            max as f64 <= 2.0 * min as f64,
            "write skew too high: min={min} max={max} ({writes:?})"
        );
        // no writes to the failed node's rack... except D³ writes into
        // surviving racks only
        assert!(writes.keys().all(|l| *l != failed));
    }

    #[test]
    fn rdd_and_d3_rebuild_the_same_bytes() {
        let cluster = ClusterSpec::new(8, 3);
        let d3 = D3Placement::new(CodeSpec::Rs { k: 2, m: 1 }, cluster).unwrap();
        // idealized-uniform RDD: the default (calibrated skew) deliberately
        // loads nodes unevenly, so byte conservation is checked against the
        // IID variant
        let rdd = RddPlacement::uniform(CodeSpec::Rs { k: 2, m: 1 }, cluster, 1);
        let failed = Location::new(3, 0);
        let bs = 16 << 20;
        // both policies place 3 blocks/stripe on 24 nodes; expected loss is
        // similar though not identical (placement-dependent)
        let a = failed_bytes(&d3, 1000, failed, bs);
        let b = failed_bytes(&rdd, 1000, failed, bs);
        let ratio = a as f64 / b as f64;
        assert!(ratio > 0.7 && ratio < 1.4, "loss ratio {ratio}");
    }
}
