//! §5.3 layout maintenance: after recovery completes, migrate the
//! recovered blocks to the relived node (replacement in the failed rack)
//! batch by batch, so the original D³ layout — and its recovery
//! guarantees — are restored with bounded, balanced per-batch traffic.
//!
//! Batch rule (paper): each batch takes all recovered blocks of n−1
//! region-groups *of the same type* (H = recovered blocks in a fresh rack,
//! G* = recovered blocks appended to an existing region-group) from n−1
//! distinct racks.

use crate::topology::Location;

use super::plan::RepairPlan;

/// Type of a region-group holding recovered blocks (paper §3.2.2).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum RegionGroupKind {
    /// H_i: recovered blocks formed a new region-group in a fresh rack.
    FreshRack,
    /// G*_i: recovered blocks appended to an existing region-group.
    Appended,
}

/// One block move of the migration.
#[derive(Clone, Debug)]
pub struct Move {
    pub from: Location,
    pub stripe: u64,
    pub block: usize,
}

/// A migration batch: all moves target the relived node.
#[derive(Clone, Debug)]
pub struct MigrationBatch {
    pub kind: RegionGroupKind,
    pub moves: Vec<Move>,
    /// racks the moves originate from (distinct by construction)
    pub racks: Vec<u32>,
}

/// Plan the §5.3 migration. `stripe_in_rack(plan)` tells whether the
/// recovered block's rack already held other blocks of the stripe (G*) or
/// not (H); we derive it from the plan + a placement callback.
pub fn plan_migration(
    plans: &[RepairPlan],
    is_appended: impl Fn(&RepairPlan) -> bool,
    region_size: usize,
    nodes_per_rack: usize,
) -> Vec<MigrationBatch> {
    use std::collections::BTreeMap;
    // (kind, region, rack) -> moves  — one region-group with recovered blocks
    let mut groups: BTreeMap<(RegionGroupKind, u64, u32), Vec<Move>> = BTreeMap::new();
    for plan in plans {
        let kind = if is_appended(plan) {
            RegionGroupKind::Appended
        } else {
            RegionGroupKind::FreshRack
        };
        let region = plan.stripe / region_size as u64;
        groups
            .entry((kind, region, plan.writer.rack))
            .or_default()
            .push(Move { from: plan.writer, stripe: plan.stripe, block: plan.failed_block });
    }
    // pack region-groups of the same kind into batches of n−1 distinct racks
    let mut batches: Vec<MigrationBatch> = Vec::new();
    for kind in [RegionGroupKind::FreshRack, RegionGroupKind::Appended] {
        let mut pending: Vec<((RegionGroupKind, u64, u32), Vec<Move>)> = groups
            .iter()
            .filter(|((k, _, _), _)| *k == kind)
            .map(|(k, v)| (*k, v.clone()))
            .collect();
        while !pending.is_empty() {
            let mut batch = MigrationBatch { kind, moves: Vec::new(), racks: Vec::new() };
            let mut used_racks = std::collections::HashSet::new();
            let mut rest = Vec::new();
            for (key, moves) in pending {
                let rack = key.2;
                if batch.racks.len() < nodes_per_rack.saturating_sub(1)
                    && used_racks.insert(rack)
                {
                    batch.racks.push(rack);
                    batch.moves.extend(moves);
                } else {
                    rest.push((key, moves));
                }
            }
            pending = rest;
            if batch.moves.is_empty() {
                break;
            }
            batches.push(batch);
        }
    }
    batches
}

/// Total bytes a batch moves cross-rack into the relived node's rack.
pub fn batch_cross_rack_bytes(batch: &MigrationBatch, relived_rack: u32, block_size: u64) -> u64 {
    batch
        .moves
        .iter()
        .filter(|m| m.from.rack != relived_rack)
        .count() as u64
        * block_size
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::codes::CodeSpec;
    use crate::placement::{D3Placement, Placement};
    use crate::recovery::node::node_recovery_plans;
    use crate::topology::ClusterSpec;

    fn setup() -> (D3Placement, Vec<RepairPlan>, Location) {
        let cluster = ClusterSpec::new(5, 3);
        let p = D3Placement::new(CodeSpec::Rs { k: 3, m: 2 }, cluster).unwrap();
        let failed = Location::new(0, 0);
        let stripes = (p.region_cycle() * p.region_size()) as u64;
        let plans = node_recovery_plans(&p, stripes, failed, 0);
        (p, plans, failed)
    }

    fn appended_fn(p: &D3Placement) -> impl Fn(&RepairPlan) -> bool + '_ {
        move |plan: &RepairPlan| {
            let sp = p.stripe(plan.stripe);
            sp.locs
                .iter()
                .enumerate()
                .any(|(bi, l)| bi != plan.failed_block && l.rack == plan.writer.rack)
        }
    }

    #[test]
    fn all_recovered_blocks_migrate_exactly_once() {
        let (p, plans, _) = setup();
        let batches = plan_migration(&plans, appended_fn(&p), p.region_size(), 3);
        let total: usize = batches.iter().map(|b| b.moves.len()).sum();
        assert_eq!(total, plans.len());
        let mut seen = std::collections::HashSet::new();
        for b in &batches {
            for m in &b.moves {
                assert!(seen.insert((m.stripe, m.block)), "double migration");
            }
        }
    }

    #[test]
    fn batch_racks_distinct_and_bounded() {
        let (p, plans, _) = setup();
        let n = 3;
        let batches = plan_migration(&plans, appended_fn(&p), p.region_size(), n);
        assert!(!batches.is_empty());
        for b in &batches {
            let set: std::collections::HashSet<u32> = b.racks.iter().copied().collect();
            assert_eq!(set.len(), b.racks.len(), "duplicate rack in batch");
            assert!(b.racks.len() <= n - 1);
        }
    }

    #[test]
    fn batches_are_type_homogeneous() {
        let (p, plans, _) = setup();
        let batches = plan_migration(&plans, appended_fn(&p), p.region_size(), 3);
        // (3,2)-RS has both fresh-rack (B4 failures) and appended
        // (B0..B3 failures) region-groups
        let kinds: std::collections::HashSet<RegionGroupKind> =
            batches.iter().map(|b| b.kind).collect();
        assert_eq!(kinds.len(), 2, "expected both H and G* batches");
    }

    #[test]
    fn per_batch_traffic_balanced_across_racks() {
        let (p, plans, failed) = setup();
        let batches = plan_migration(&plans, appended_fn(&p), p.region_size(), 3);
        for b in &batches {
            if b.racks.len() < 2 {
                continue;
            }
            let mut per_rack: std::collections::HashMap<u32, usize> =
                std::collections::HashMap::new();
            for m in &b.moves {
                *per_rack.entry(m.from.rack).or_default() += 1;
            }
            let max = *per_rack.values().max().unwrap();
            let min = *per_rack.values().min().unwrap();
            assert!(max - min <= max / 2 + 1, "batch rack skew: {per_rack:?}");
        }
        let _ = failed;
    }
}
