//! Pipelined parallel recovery executor (DESIGN.md §8).
//!
//! The paper's headline speedup comes from D³ spreading repair traffic so
//! every surviving node and rack can work *concurrently*; executing
//! `RepairPlan`s one-at-a-time on one thread forfeits that balance. This
//! module splits every plan into fixed-size **chunk tasks** and schedules
//! them across a bounded worker pool, so the fetch (network), GF
//! multiply-accumulate (CPU) and write (disk) stages of *different* chunks
//! overlap instead of serializing per plan.
//!
//! The executor is backend-agnostic: it owns the scheduling (task queue,
//! worker pool, per-plan chunk assembly, per-worker utilization
//! accounting) and delegates the actual data movement to a
//! [`ChunkRunner`] — the MiniCluster implements it with gated,
//! token-bucket-throttled links ([`crate::cluster`]).
//!
//! **Determinism:** every chunk's value is a pure function of
//! `(plan, offset)` — GF arithmetic over immutable source bytes — and
//! chunks land at disjoint offsets of their plan's buffer, so the
//! recovered blocks are byte-identical for *any* worker count, chunk size
//! or interleaving. Traffic metrics are commutative atomic adds, so their
//! totals are schedule-independent too. `tests/executor_concurrency.rs`
//! pins both properties.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;
use std::time::Instant;

use anyhow::{bail, Result};

use crate::metrics::PoolStats;
use crate::topology::Location;

use super::plan::RepairPlan;
use super::schedule::{build_task_order, SchedulePolicy};

/// Per-worker scratch-buffer pool (DESIGN.md §9): chunk fetch, partial-
/// aggregation, and accumulator buffers — and the `(coeff, buffer)`
/// staging vector that feeds the fused combine — are taken from here and
/// returned after use, so the steady-state recovery data path performs
/// **zero allocations per chunk**: every vector cycles between the worker
/// and its pool with capacity retained. Each worker owns one `Scratch`
/// (no sharing, no locks); hit/miss counts are aggregated into
/// [`ExecStats::scratch`].
#[derive(Default)]
pub struct Scratch {
    free: Vec<Vec<u8>>,
    staging: Vec<(u8, Vec<u8>)>,
    flows: Vec<(Location, u64)>,
    stats: PoolStats,
}

impl Scratch {
    pub fn new() -> Scratch {
        Scratch::default()
    }

    /// An empty buffer (length 0) with whatever capacity the pool has on
    /// hand — for fill-by-extend users (chunk fetches).
    pub fn take(&mut self) -> Vec<u8> {
        match self.free.pop() {
            Some(mut buf) => {
                self.stats.hits += 1;
                buf.clear();
                buf
            }
            None => {
                self.stats.misses += 1;
                Vec::new()
            }
        }
    }

    /// A zero-filled buffer of exactly `len` bytes — for accumulators.
    pub fn take_zeroed(&mut self, len: usize) -> Vec<u8> {
        let mut buf = self.take();
        buf.resize(len, 0);
        buf
    }

    /// Return a buffer to the pool for reuse.
    pub fn put(&mut self, buf: Vec<u8>) {
        self.free.push(buf);
    }

    /// The reusable `(coefficient, buffer)` staging vector for fused
    /// combines — always empty, capacity retained across chunks.
    pub fn take_staging(&mut self) -> Vec<(u8, Vec<u8>)> {
        std::mem::take(&mut self.staging)
    }

    /// Return the staging vector: any buffers still inside go back to the
    /// byte-buffer pool and the emptied vector keeps its capacity for the
    /// next chunk.
    pub fn put_staging(&mut self, mut staging: Vec<(u8, Vec<u8>)>) {
        for (_, buf) in staging.drain(..) {
            self.free.push(buf);
        }
        self.staging = staging;
    }

    /// The reusable `(source, bytes)` flow list for batched fetches —
    /// always empty, capacity retained across chunks.
    pub fn take_flows(&mut self) -> Vec<(Location, u64)> {
        std::mem::take(&mut self.flows)
    }

    /// Return the flow list (cleared, capacity retained).
    pub fn put_flows(&mut self, mut flows: Vec<(Location, u64)>) {
        flows.clear();
        self.flows = flows;
    }

    pub fn stats(&self) -> PoolStats {
        self.stats
    }
}

/// Knobs of the pipelined executor.
#[derive(Clone, Copy, Debug)]
pub struct ExecutorConfig {
    /// Concurrent reconstruction workers (HDFS xmits analogue).
    pub workers: usize,
    /// Chunk size in bytes; each plan becomes `ceil(block / chunk)` tasks.
    pub chunk_size: u64,
    /// Max concurrent transfers touching one node, 0 = unlimited
    /// (enforced by [`crate::cluster::links::LinkSet`]).
    pub node_inflight: usize,
    /// Max concurrent cross-rack transfers per rack link, 0 = unlimited.
    pub link_inflight: usize,
    /// Task-admission order: FIFO plan drain or the link-balanced
    /// wavefront schedule (DESIGN.md §10).
    pub schedule: SchedulePolicy,
    /// Fetch-coalescing window in chunks: each task covers `coalesce`
    /// consecutive chunks, so a source node's whole window moves in one
    /// batched round trip. 1 = per-chunk fetches (the baseline).
    pub coalesce: usize,
    /// Placement period of the plan set, when known — lets the balanced
    /// scheduler tile one period's coloring across the whole recovery.
    pub period: Option<u64>,
    /// Batch each task's same-destination fetches under one ordered gate
    /// acquisition ([`crate::cluster::links::LinkSet::transfer_batch`]).
    /// Off by default so the baseline configuration keeps the pre-§10
    /// one-gated-transfer-per-source path (and its bench rows) intact.
    pub batched_fetch: bool,
}

impl Default for ExecutorConfig {
    fn default() -> ExecutorConfig {
        ExecutorConfig {
            workers: 8,
            chunk_size: 64 << 10,
            node_inflight: 4,
            link_inflight: 8,
            schedule: SchedulePolicy::Fifo,
            coalesce: 1,
            period: None,
            batched_fetch: false,
        }
    }
}

/// What the executor measured.
#[derive(Clone, Debug)]
pub struct ExecStats {
    pub plans: usize,
    pub chunks: usize,
    /// Admission rounds of the schedule (1 for FIFO).
    pub rounds: usize,
    pub wall_s: f64,
    /// Seconds each worker spent executing chunk tasks.
    pub worker_busy_s: Vec<f64>,
    /// Scratch-pool hit/miss totals across all workers.
    pub scratch: PoolStats,
}

impl ExecStats {
    /// Per-worker busy fraction of the wall clock.
    pub fn utilization(&self) -> Vec<f64> {
        crate::metrics::utilization(&self.worker_busy_s, self.wall_s)
    }
}

/// Backend hook: how one chunk of one plan is actually rebuilt.
pub trait ChunkRunner: Sync {
    /// Rebuild bytes `[off, off + len)` of plan `plan_idx`'s failed block:
    /// fetch each source's chunk (through whatever links/throttles the
    /// backend models), multiply-accumulate, and return the rebuilt chunk.
    /// All working buffers — including the returned chunk — should come
    /// from `scratch`; the executor returns the chunk buffer to the same
    /// pool once it has landed in the plan's assembly buffer.
    fn run_chunk(
        &self,
        plan_idx: usize,
        plan: &RepairPlan,
        off: u64,
        len: usize,
        scratch: &mut Scratch,
    ) -> Result<Vec<u8>>;

    /// Every chunk of `plan` has landed; persist the assembled block.
    fn finish_plan(&self, plan_idx: usize, plan: &RepairPlan, block: Vec<u8>) -> Result<()>;

    /// QoS pacing hook (DESIGN.md §11): called after every chunk with the
    /// busy seconds it consumed. Backends that schedule recovery against
    /// foreground traffic yield here (the MiniCluster's `ChunkIo` sleeps
    /// `busy × fg_weight × (1/recovery_share − 1)` while client load is
    /// active); the default is a no-op, so plain recovery pays nothing.
    fn throttle(&self, _busy_s: f64) {}
}

/// `(offset, length)` spans covering one block of `block_size` bytes.
pub fn chunk_spans(block_size: u64, chunk_size: u64) -> Vec<(u64, usize)> {
    let chunk = chunk_size.max(1);
    let mut spans = Vec::new();
    let mut off = 0u64;
    while off < block_size {
        let len = chunk.min(block_size - off) as usize;
        spans.push((off, len));
        off += len as u64;
    }
    if spans.is_empty() {
        spans.push((0, 0)); // degenerate zero-size block still completes
    }
    spans
}

/// Run `plans` (each rebuilding one `block_size`-byte block) through the
/// chunked worker pool. Fails if any chunk or persist step failed; partial
/// plans are never persisted.
pub fn execute_plans<R: ChunkRunner>(
    runner: &R,
    plans: &[RepairPlan],
    block_size: u64,
    cfg: &ExecutorConfig,
) -> Result<ExecStats> {
    struct PlanBuf {
        /// Allocated lazily on the plan's first completed chunk, so live
        /// memory stays O(workers × block) instead of O(plans × block).
        buf: Vec<u8>,
        remaining: usize,
    }
    // The schedule decides the complete task order up front (DESIGN.md
    // §10): FIFO = plan-major drain, balanced = conflict-free wavefront
    // rounds. Claiming through one atomic cursor reproduces the round
    // structure exactly — workers steal within a round, and a round only
    // opens once the previous one is fully claimed.
    let order = build_task_order(plans, block_size, cfg);
    let bufs: Vec<Mutex<PlanBuf>> = plans
        .iter()
        .map(|_| Mutex::new(PlanBuf { buf: Vec::new(), remaining: order.tasks_per_plan }))
        .collect();
    let tasks = &order.tasks;
    let next = AtomicUsize::new(0);
    let errors: Mutex<Vec<String>> = Mutex::new(Vec::new());
    let workers = cfg.workers.max(1);
    let t0 = Instant::now();
    let per_worker: Vec<(f64, PoolStats)> = std::thread::scope(|scope| {
        let handles: Vec<_> = (0..workers)
            .map(|_| {
                scope.spawn(|| {
                    let mut busy = 0.0f64;
                    let mut scratch = Scratch::new();
                    loop {
                        let i = next.fetch_add(1, Ordering::Relaxed);
                        if i >= tasks.len() {
                            break;
                        }
                        let (pi, off, len) = tasks[i];
                        let t = Instant::now();
                        match runner.run_chunk(pi, &plans[pi], off, len, &mut scratch) {
                            Ok(chunk) if chunk.len() != len => {
                                errors.lock().unwrap().push(format!(
                                    "plan {pi}: chunk at {off} returned {} bytes, want {len}",
                                    chunk.len()
                                ));
                            }
                            Ok(chunk) => {
                                let done = {
                                    let mut pb = bufs[pi].lock().unwrap();
                                    if pb.buf.len() != block_size as usize {
                                        pb.buf.resize(block_size as usize, 0);
                                    }
                                    pb.buf[off as usize..off as usize + len]
                                        .copy_from_slice(&chunk);
                                    pb.remaining -= 1;
                                    (pb.remaining == 0).then(|| std::mem::take(&mut pb.buf))
                                };
                                scratch.put(chunk);
                                if let Some(block) = done {
                                    if let Err(e) = runner.finish_plan(pi, &plans[pi], block) {
                                        errors.lock().unwrap().push(e.to_string());
                                    }
                                }
                            }
                            Err(e) => errors.lock().unwrap().push(e.to_string()),
                        }
                        let dt = t.elapsed().as_secs_f64();
                        busy += dt;
                        runner.throttle(dt);
                    }
                    (busy, scratch.stats())
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().expect("executor worker")).collect()
    });
    let errs = errors.into_inner().unwrap();
    if !errs.is_empty() {
        bail!("recovery executor errors: {}", errs.join("; "));
    }
    let mut scratch = PoolStats::default();
    for &(_, s) in &per_worker {
        scratch.merge(s);
    }
    Ok(ExecStats {
        plans: plans.len(),
        chunks: tasks.len(),
        rounds: order.rounds.len(),
        wall_s: t0.elapsed().as_secs_f64(),
        worker_busy_s: per_worker.into_iter().map(|(b, _)| b).collect(),
        scratch,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::topology::Location;
    use std::collections::HashMap;

    fn plan(sid: u64) -> RepairPlan {
        RepairPlan {
            stripe: sid,
            failed_block: 0,
            compute_at: Location::new(0, 0),
            writer: Location::new(0, 0),
            persist: true,
            aggregations: Vec::new(),
            direct: Vec::new(),
            coeffs: None,
        }
    }

    /// Chunk byte j of stripe `sid` is a pure function of (sid, off + j).
    fn expected_block(sid: u64, block_size: u64) -> Vec<u8> {
        (0..block_size).map(|i| (sid as u8).wrapping_mul(31) ^ (i as u8)).collect()
    }

    struct MockRunner {
        finished: Mutex<HashMap<u64, Vec<u8>>>,
        fail_chunk_of: Option<u64>,
    }

    impl ChunkRunner for MockRunner {
        fn run_chunk(
            &self,
            _pi: usize,
            plan: &RepairPlan,
            off: u64,
            len: usize,
            scratch: &mut Scratch,
        ) -> Result<Vec<u8>> {
            if Some(plan.stripe) == self.fail_chunk_of {
                bail!("injected failure for stripe {}", plan.stripe);
            }
            let mut chunk = scratch.take();
            chunk.extend(
                (0..len as u64)
                    .map(|j| (plan.stripe as u8).wrapping_mul(31) ^ ((off + j) as u8)),
            );
            Ok(chunk)
        }

        fn finish_plan(&self, _pi: usize, plan: &RepairPlan, block: Vec<u8>) -> Result<()> {
            let prev = self.finished.lock().unwrap().insert(plan.stripe, block);
            assert!(prev.is_none(), "plan finished twice");
            Ok(())
        }
    }

    #[test]
    fn chunk_spans_cover_block_exactly() {
        for (bs, cs) in [(1024u64, 256u64), (1000, 256), (100, 7), (64, 64), (64, 1 << 20)] {
            let spans = chunk_spans(bs, cs);
            let mut off = 0u64;
            for &(o, l) in &spans {
                assert_eq!(o, off);
                assert!(l > 0);
                off += l as u64;
            }
            assert_eq!(off, bs, "bs={bs} cs={cs}");
        }
        assert_eq!(chunk_spans(0, 64), vec![(0, 0)]);
    }

    #[test]
    fn assembly_is_schedule_independent() {
        let plans: Vec<RepairPlan> = (0..7u64).map(plan).collect();
        let block_size = 1000u64;
        let cases = [
            (1usize, 1000u64, SchedulePolicy::Fifo, 1usize),
            (2, 256, SchedulePolicy::Fifo, 1),
            (8, 64, SchedulePolicy::Fifo, 1),
            (8, 7, SchedulePolicy::Fifo, 1),
            (3, 1 << 20, SchedulePolicy::Fifo, 1),
            (2, 256, SchedulePolicy::Balanced, 1),
            (8, 64, SchedulePolicy::Balanced, 3),
            (8, 7, SchedulePolicy::Balanced, 2),
        ];
        for (workers, chunk, schedule, coalesce) in cases {
            let runner =
                MockRunner { finished: Mutex::new(HashMap::new()), fail_chunk_of: None };
            let cfg = ExecutorConfig {
                workers,
                chunk_size: chunk,
                schedule,
                coalesce,
                ..Default::default()
            };
            let stats = execute_plans(&runner, &plans, block_size, &cfg).unwrap();
            assert_eq!(stats.plans, 7);
            assert_eq!(
                stats.chunks,
                7 * chunk_spans(block_size, chunk * coalesce as u64).len()
            );
            assert!(stats.rounds >= 1);
            assert_eq!(stats.worker_busy_s.len(), workers);
            assert!(stats.utilization().iter().all(|&u| (0.0..=1.0).contains(&u)));
            let finished = runner.finished.into_inner().unwrap();
            assert_eq!(finished.len(), 7);
            for sid in 0..7u64 {
                assert_eq!(
                    finished[&sid],
                    expected_block(sid, block_size),
                    "workers={workers} chunk={chunk} sid={sid}"
                );
            }
        }
    }

    #[test]
    fn scratch_pool_reuses_buffers_after_warmup() {
        // single worker: the first chunk misses (pool empty), every later
        // chunk reuses the buffer the executor returned after assembly
        let plans: Vec<RepairPlan> = (0..3u64).map(plan).collect();
        let runner = MockRunner { finished: Mutex::new(HashMap::new()), fail_chunk_of: None };
        let cfg = ExecutorConfig { workers: 1, chunk_size: 64, ..Default::default() };
        let stats = execute_plans(&runner, &plans, 512, &cfg).unwrap();
        let chunks = stats.chunks as u64;
        assert_eq!(stats.scratch.hits + stats.scratch.misses, chunks);
        assert_eq!(stats.scratch.misses, 1, "{:?}", stats.scratch);
        assert!(stats.scratch.hit_rate() > 0.9);
    }

    #[test]
    fn scratch_take_zeroed_clears_reused_capacity() {
        let mut s = Scratch::new();
        s.put(vec![0xffu8; 32]);
        let buf = s.take_zeroed(16);
        assert_eq!(buf, vec![0u8; 16]);
        assert_eq!(s.stats(), crate::metrics::PoolStats { hits: 1, misses: 0 });
    }

    #[test]
    fn staging_round_trip_recycles_buffers_into_the_pool() {
        let mut s = Scratch::new();
        let mut staging = s.take_staging();
        assert!(staging.is_empty());
        staging.push((3, vec![1u8, 2, 3]));
        staging.push((1, vec![4u8]));
        s.put_staging(staging);
        // both leftover buffers are back in the byte pool...
        let a = s.take();
        let b = s.take();
        assert!(a.capacity() >= 1 && b.capacity() >= 1);
        // ...and the next staging vector is the same (emptied) allocation
        assert!(s.take_staging().capacity() >= 2);
    }

    #[test]
    fn flows_round_trip_keeps_capacity() {
        let mut s = Scratch::new();
        let mut flows = s.take_flows();
        assert!(flows.is_empty());
        flows.push((Location::new(0, 0), 64));
        flows.push((Location::new(1, 2), 128));
        s.put_flows(flows);
        let again = s.take_flows();
        assert!(again.is_empty(), "flow list must come back cleared");
        assert!(again.capacity() >= 2, "flow list must keep its capacity");
    }

    #[test]
    fn chunk_error_fails_the_run_without_persisting_that_plan() {
        let plans: Vec<RepairPlan> = (0..4u64).map(plan).collect();
        let runner =
            MockRunner { finished: Mutex::new(HashMap::new()), fail_chunk_of: Some(2) };
        let cfg = ExecutorConfig { workers: 4, chunk_size: 128, ..Default::default() };
        let err = execute_plans(&runner, &plans, 512, &cfg).unwrap_err();
        assert!(err.to_string().contains("injected failure"), "{err}");
        assert!(!runner.finished.into_inner().unwrap().contains_key(&2));
    }
}
