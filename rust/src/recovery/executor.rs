//! Pipelined parallel recovery executor (DESIGN.md §8).
//!
//! The paper's headline speedup comes from D³ spreading repair traffic so
//! every surviving node and rack can work *concurrently*; executing
//! `RepairPlan`s one-at-a-time on one thread forfeits that balance. This
//! module splits every plan into fixed-size **chunk tasks** and schedules
//! them across a bounded worker pool, so the fetch (network), GF
//! multiply-accumulate (CPU) and write (disk) stages of *different* chunks
//! overlap instead of serializing per plan.
//!
//! The executor is backend-agnostic: it owns the scheduling (task queue,
//! worker pool, per-plan chunk assembly, per-worker utilization
//! accounting) and delegates the actual data movement to a
//! [`ChunkRunner`] — the MiniCluster implements it with gated,
//! token-bucket-throttled links ([`crate::cluster`]).
//!
//! **Determinism:** every chunk's value is a pure function of
//! `(plan, offset)` — GF arithmetic over immutable source bytes — and
//! chunks land at disjoint offsets of their plan's buffer, so the
//! recovered blocks are byte-identical for *any* worker count, chunk size
//! or interleaving. Traffic metrics are commutative atomic adds, so their
//! totals are schedule-independent too. `tests/executor_concurrency.rs`
//! pins both properties.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;
use std::time::Instant;

use anyhow::{bail, Result};

use super::plan::RepairPlan;

/// Knobs of the pipelined executor.
#[derive(Clone, Copy, Debug)]
pub struct ExecutorConfig {
    /// Concurrent reconstruction workers (HDFS xmits analogue).
    pub workers: usize,
    /// Chunk size in bytes; each plan becomes `ceil(block / chunk)` tasks.
    pub chunk_size: u64,
    /// Max concurrent transfers touching one node, 0 = unlimited
    /// (enforced by [`crate::cluster::links::LinkSet`]).
    pub node_inflight: usize,
    /// Max concurrent cross-rack transfers per rack link, 0 = unlimited.
    pub link_inflight: usize,
}

impl Default for ExecutorConfig {
    fn default() -> ExecutorConfig {
        ExecutorConfig {
            workers: 8,
            chunk_size: 64 << 10,
            node_inflight: 4,
            link_inflight: 8,
        }
    }
}

/// What the executor measured.
#[derive(Clone, Debug)]
pub struct ExecStats {
    pub plans: usize,
    pub chunks: usize,
    pub wall_s: f64,
    /// Seconds each worker spent executing chunk tasks.
    pub worker_busy_s: Vec<f64>,
}

impl ExecStats {
    /// Per-worker busy fraction of the wall clock.
    pub fn utilization(&self) -> Vec<f64> {
        crate::metrics::utilization(&self.worker_busy_s, self.wall_s)
    }
}

/// Backend hook: how one chunk of one plan is actually rebuilt.
pub trait ChunkRunner: Sync {
    /// Rebuild bytes `[off, off + len)` of plan `plan_idx`'s failed block:
    /// fetch each source's chunk (through whatever links/throttles the
    /// backend models), multiply-accumulate, and return the rebuilt chunk.
    fn run_chunk(&self, plan_idx: usize, plan: &RepairPlan, off: u64, len: usize)
        -> Result<Vec<u8>>;

    /// Every chunk of `plan` has landed; persist the assembled block.
    fn finish_plan(&self, plan_idx: usize, plan: &RepairPlan, block: Vec<u8>) -> Result<()>;
}

/// `(offset, length)` spans covering one block of `block_size` bytes.
pub fn chunk_spans(block_size: u64, chunk_size: u64) -> Vec<(u64, usize)> {
    let chunk = chunk_size.max(1);
    let mut spans = Vec::new();
    let mut off = 0u64;
    while off < block_size {
        let len = chunk.min(block_size - off) as usize;
        spans.push((off, len));
        off += len as u64;
    }
    if spans.is_empty() {
        spans.push((0, 0)); // degenerate zero-size block still completes
    }
    spans
}

/// Run `plans` (each rebuilding one `block_size`-byte block) through the
/// chunked worker pool. Fails if any chunk or persist step failed; partial
/// plans are never persisted.
pub fn execute_plans<R: ChunkRunner>(
    runner: &R,
    plans: &[RepairPlan],
    block_size: u64,
    cfg: &ExecutorConfig,
) -> Result<ExecStats> {
    struct PlanBuf {
        /// Allocated lazily on the plan's first completed chunk, so live
        /// memory stays O(workers × block) instead of O(plans × block).
        buf: Vec<u8>,
        remaining: usize,
    }
    let spans = chunk_spans(block_size, cfg.chunk_size);
    let bufs: Vec<Mutex<PlanBuf>> = plans
        .iter()
        .map(|_| Mutex::new(PlanBuf { buf: Vec::new(), remaining: spans.len() }))
        .collect();
    // Plan-major task order: a plan's chunks pipeline through the workers
    // while the next plan's first fetches are already in flight.
    let tasks: Vec<(usize, u64, usize)> = (0..plans.len())
        .flat_map(|pi| spans.iter().map(move |&(off, len)| (pi, off, len)))
        .collect();
    let next = AtomicUsize::new(0);
    let errors: Mutex<Vec<String>> = Mutex::new(Vec::new());
    let workers = cfg.workers.max(1);
    let t0 = Instant::now();
    let worker_busy_s: Vec<f64> = std::thread::scope(|scope| {
        let handles: Vec<_> = (0..workers)
            .map(|_| {
                scope.spawn(|| {
                    let mut busy = 0.0f64;
                    loop {
                        let i = next.fetch_add(1, Ordering::Relaxed);
                        if i >= tasks.len() {
                            break;
                        }
                        let (pi, off, len) = tasks[i];
                        let t = Instant::now();
                        match runner.run_chunk(pi, &plans[pi], off, len) {
                            Ok(chunk) if chunk.len() != len => {
                                errors.lock().unwrap().push(format!(
                                    "plan {pi}: chunk at {off} returned {} bytes, want {len}",
                                    chunk.len()
                                ));
                            }
                            Ok(chunk) => {
                                let done = {
                                    let mut pb = bufs[pi].lock().unwrap();
                                    if pb.buf.len() != block_size as usize {
                                        pb.buf.resize(block_size as usize, 0);
                                    }
                                    pb.buf[off as usize..off as usize + len]
                                        .copy_from_slice(&chunk);
                                    pb.remaining -= 1;
                                    (pb.remaining == 0).then(|| std::mem::take(&mut pb.buf))
                                };
                                if let Some(block) = done {
                                    if let Err(e) = runner.finish_plan(pi, &plans[pi], block) {
                                        errors.lock().unwrap().push(e.to_string());
                                    }
                                }
                            }
                            Err(e) => errors.lock().unwrap().push(e.to_string()),
                        }
                        busy += t.elapsed().as_secs_f64();
                    }
                    busy
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().expect("executor worker")).collect()
    });
    let errs = errors.into_inner().unwrap();
    if !errs.is_empty() {
        bail!("recovery executor errors: {}", errs.join("; "));
    }
    Ok(ExecStats {
        plans: plans.len(),
        chunks: tasks.len(),
        wall_s: t0.elapsed().as_secs_f64(),
        worker_busy_s,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::topology::Location;
    use std::collections::HashMap;

    fn plan(sid: u64) -> RepairPlan {
        RepairPlan {
            stripe: sid,
            failed_block: 0,
            compute_at: Location::new(0, 0),
            writer: Location::new(0, 0),
            persist: true,
            aggregations: Vec::new(),
            direct: Vec::new(),
            coeffs: None,
        }
    }

    /// Chunk byte j of stripe `sid` is a pure function of (sid, off + j).
    fn expected_block(sid: u64, block_size: u64) -> Vec<u8> {
        (0..block_size).map(|i| (sid as u8).wrapping_mul(31) ^ (i as u8)).collect()
    }

    struct MockRunner {
        finished: Mutex<HashMap<u64, Vec<u8>>>,
        fail_chunk_of: Option<u64>,
    }

    impl ChunkRunner for MockRunner {
        fn run_chunk(
            &self,
            _pi: usize,
            plan: &RepairPlan,
            off: u64,
            len: usize,
        ) -> Result<Vec<u8>> {
            if Some(plan.stripe) == self.fail_chunk_of {
                bail!("injected failure for stripe {}", plan.stripe);
            }
            Ok((0..len as u64)
                .map(|j| (plan.stripe as u8).wrapping_mul(31) ^ ((off + j) as u8))
                .collect())
        }

        fn finish_plan(&self, _pi: usize, plan: &RepairPlan, block: Vec<u8>) -> Result<()> {
            let prev = self.finished.lock().unwrap().insert(plan.stripe, block);
            assert!(prev.is_none(), "plan finished twice");
            Ok(())
        }
    }

    #[test]
    fn chunk_spans_cover_block_exactly() {
        for (bs, cs) in [(1024u64, 256u64), (1000, 256), (100, 7), (64, 64), (64, 1 << 20)] {
            let spans = chunk_spans(bs, cs);
            let mut off = 0u64;
            for &(o, l) in &spans {
                assert_eq!(o, off);
                assert!(l > 0);
                off += l as u64;
            }
            assert_eq!(off, bs, "bs={bs} cs={cs}");
        }
        assert_eq!(chunk_spans(0, 64), vec![(0, 0)]);
    }

    #[test]
    fn assembly_is_schedule_independent() {
        let plans: Vec<RepairPlan> = (0..7u64).map(plan).collect();
        let block_size = 1000u64;
        for (workers, chunk) in [(1usize, 1000u64), (2, 256), (8, 64), (8, 7), (3, 1 << 20)] {
            let runner =
                MockRunner { finished: Mutex::new(HashMap::new()), fail_chunk_of: None };
            let cfg = ExecutorConfig { workers, chunk_size: chunk, ..Default::default() };
            let stats = execute_plans(&runner, &plans, block_size, &cfg).unwrap();
            assert_eq!(stats.plans, 7);
            assert_eq!(stats.chunks, 7 * chunk_spans(block_size, chunk).len());
            assert_eq!(stats.worker_busy_s.len(), workers);
            assert!(stats.utilization().iter().all(|&u| (0.0..=1.0).contains(&u)));
            let finished = runner.finished.into_inner().unwrap();
            assert_eq!(finished.len(), 7);
            for sid in 0..7u64 {
                assert_eq!(
                    finished[&sid],
                    expected_block(sid, block_size),
                    "workers={workers} chunk={chunk} sid={sid}"
                );
            }
        }
    }

    #[test]
    fn chunk_error_fails_the_run_without_persisting_that_plan() {
        let plans: Vec<RepairPlan> = (0..4u64).map(plan).collect();
        let runner =
            MockRunner { finished: Mutex::new(HashMap::new()), fail_chunk_of: Some(2) };
        let cfg = ExecutorConfig { workers: 4, chunk_size: 128, ..Default::default() };
        let err = execute_plans(&runner, &plans, 512, &cfg).unwrap_err();
        assert!(err.to_string().contains("injected failure"), "{err}");
        assert!(!runner.finished.into_inner().unwrap().contains_key(&2));
    }
}
